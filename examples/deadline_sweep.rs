//! Deadline sweep: miss rates and slot allocations as completion-time
//! goals tighten — exercising the Resource Predictor (Eq. 10) end to end.
//!
//!     cargo run --release --offline --example deadline_sweep
//!
//! Pass --xla to drive the sweep through the PJRT artifacts instead of
//! the native predictor.

use vcsched::config::SimConfig;
use vcsched::coordinator;
use vcsched::predictor::{demand_from_spec, NativePredictor, Predictor};
use vcsched::runtime::XlaPredictor;
use vcsched::scheduler::SchedulerKind;
use vcsched::util::args::Args;
use vcsched::util::benchkit::Table;
use vcsched::workloads::trace::JobTrace;
use vcsched::workloads::{JobSpec, JobType};

fn main() {
    vcsched::util::logger::init();
    let args = Args::parse();
    let cfg = SimConfig::paper();

    let mut predictor: Box<dyn Predictor> = if args.flag("xla") {
        println!("predictor backend: XLA artifacts (PJRT)");
        Box::new(XlaPredictor::load_default().expect("run `make artifacts`"))
    } else {
        Box::new(NativePredictor::new())
    };

    println!("== Eq. 10 slot demand vs deadline (sort, 4 GB) ==\n");
    let mut t = Table::new(&["deadline", "map slots", "reduce slots", "feasible"]);
    for d in [120.0f64, 180.0, 240.0, 360.0, 600.0, 1200.0] {
        let spec = JobSpec::new(JobType::Sort, 4096.0).with_deadline(d);
        let s = predictor.solve_slots(&[demand_from_spec(&cfg, &spec)])[0];
        t.row(&[
            format!("{d:.0}s"),
            s.map_slots.to_string(),
            s.reduce_slots.to_string(),
            (!s.infeasible).to_string(),
        ]);
    }
    t.print();
    println!("\n(the tighter the goal, the more slots Eq. 10 demands; past the\n shuffle bound C<=0 the deadline is infeasible at any allocation)");

    println!("\n== miss rate vs deadline tightness (25-job mix) ==\n");
    let mut t = Table::new(&["deadline factor", "scheduler", "misses", "mean_ct", "locality"]);
    for factor in [1.1f64, 1.5, 2.0, 3.0, 5.0] {
        let trace = JobTrace::poisson(&cfg, 25, 8.0, factor..(factor + 0.01), 13);
        for kind in [SchedulerKind::Edf, SchedulerKind::DeadlineVc] {
            let r = coordinator::run_simulation(&cfg, kind, &trace);
            t.row(&[
                format!("{factor:.1}x ideal"),
                kind.name().to_string(),
                format!("{:.0}%", r.miss_rate() * 100.0),
                format!("{:.1}s", r.mean_completion_s()),
                format!("{:.1}%", r.locality_pct()),
            ]);
        }
    }
    t.print();
    println!(
        "\nReading: EDF ordering alone (edf) cannot hold tight deadlines under \
         load;\nthe proposed scheduler's Eq. 10 allocations + locality routing \
         cut both misses\nand completion times (ablation of the paper's two \
         mechanisms)."
    );
}
