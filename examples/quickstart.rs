//! Quickstart: build a small virtual cluster, submit three deadlined jobs,
//! run the paper's scheduler, and inspect the results.
//!
//!     cargo run --release --offline --example quickstart

use vcsched::config::SimConfig;
use vcsched::coordinator;
use vcsched::scheduler::SchedulerKind;
use vcsched::workloads::trace::JobTrace;
use vcsched::workloads::{JobSpec, JobType};

fn main() {
    vcsched::util::logger::init();

    // An 8-node virtual cluster on 4 physical machines (2 VMs each,
    // 2 map + 2 reduce slots per VM) — `SimConfig::paper()` gives the
    // full 20-machine testbed.
    let cfg = SimConfig::small();

    // Three jobs with completion-time goals, arriving 10 s apart.
    let trace = JobTrace::new(vec![
        JobSpec::new(JobType::WordCount, 512.0).with_deadline(300.0),
        JobSpec::new(JobType::Sort, 768.0).with_deadline(400.0).at(10.0),
        JobSpec::new(JobType::Grep, 512.0).with_deadline(250.0).at(20.0),
    ]);

    // Run under the proposed deadline+reconfiguration scheduler.
    let report = coordinator::run_simulation(&cfg, SchedulerKind::DeadlineVc, &trace);

    println!("scheduler      : {}", report.scheduler);
    println!("jobs completed : {}", report.completed_jobs());
    println!("makespan       : {:.1}s", report.makespan_s);
    println!("map locality   : {:.1}%", report.locality_pct());
    println!("vCPU hot-plugs : {}", report.hotplugs);
    println!();
    for j in &report.jobs {
        println!(
            "  job {:>2} {:<14} {:>6.0} MB  completed in {:>6.1}s  \
             deadline {}  local maps {}/{}",
            j.id.0,
            j.job_type.name(),
            j.input_mb,
            j.completion_s,
            match j.met_deadline {
                Some(true) => "MET   ",
                Some(false) => "MISSED",
                None => "  -   ",
            },
            j.local_maps,
            j.maps,
        );
    }

    // The same trace under the Fair baseline, for contrast.
    let fair = coordinator::run_simulation(&cfg, SchedulerKind::Fair, &trace);
    println!(
        "\nfair baseline  : makespan {:.1}s, locality {:.1}%  (proposed: {:.1}s, {:.1}%)",
        fair.makespan_s,
        fair.locality_pct(),
        report.makespan_s,
        report.locality_pct()
    );
}
