//! Locality study: how replication factor, cluster load and **network
//! topology** shape data locality and completion time across schedulers —
//! the design space the paper's intro motivates (locality vs deadline
//! tension). Locality is reported as the three-tier node/rack/remote
//! split the delay-scheduling literature uses; on the flat (single-rack)
//! topology the rack column is structurally 0.
//!
//!     cargo run --release --offline --example locality_study

use vcsched::cluster::Topology;
use vcsched::config::SimConfig;
use vcsched::coordinator::{self, Report};
use vcsched::scheduler::SchedulerKind;
use vcsched::util::benchkit::Table;
use vcsched::workloads::trace::JobTrace;

/// `node/rack/remote` percentage triple for one run.
fn tier_split(r: &Report) -> String {
    format!(
        "{:.1}/{:.1}/{:.1}%",
        r.locality_pct(),
        r.rack_pct(),
        r.remote_pct()
    )
}

fn main() {
    vcsched::util::logger::init();

    println!("== locality vs replication factor (25-job backlogged mix) ==\n");
    let mut t = Table::new(&[
        "replication", "scheduler", "node/rack/remote", "mean_ct", "thpt/h", "hotplugs",
    ]);
    for repl in [1usize, 2, 3, 5] {
        let cfg = SimConfig {
            replication: repl,
            ..SimConfig::paper()
        };
        let trace = JobTrace::paper_mix(&cfg, 7);
        for kind in [SchedulerKind::Fair, SchedulerKind::Delay, SchedulerKind::DeadlineVc] {
            let r = coordinator::run_simulation(&cfg, kind, &trace);
            t.row(&[
                format!("{repl}x"),
                kind.name().to_string(),
                tier_split(&r),
                format!("{:.1}s", r.mean_completion_s()),
                format!("{:.1}", r.throughput_jobs_per_hour()),
                r.hotplugs.to_string(),
            ]);
        }
    }
    t.print();

    println!("\n== locality vs network topology (3x repl, backlogged mix) ==\n");
    let mut t = Table::new(&[
        "topology", "scheduler", "node/rack/remote", "mean_ct", "thpt/h", "misses",
    ]);
    for topology in [
        Topology::Flat,
        Topology::Racks(2),
        Topology::Racks(4),
        Topology::FatTree(4),
    ] {
        let cfg = SimConfig {
            topology,
            ..SimConfig::paper()
        };
        let trace = JobTrace::paper_mix(&cfg, 7);
        for kind in [SchedulerKind::Fair, SchedulerKind::Delay, SchedulerKind::DeadlineVc] {
            let r = coordinator::run_simulation(&cfg, kind, &trace);
            t.row(&[
                topology.label(),
                kind.name().to_string(),
                tier_split(&r),
                format!("{:.1}s", r.mean_completion_s()),
                format!("{:.1}", r.throughput_jobs_per_hour()),
                format!("{:.0}%", r.miss_rate() * 100.0),
            ]);
        }
    }
    t.print();

    println!("\n== locality vs cluster load (arrival rate sweep, racks-4) ==\n");
    let cfg = SimConfig {
        topology: Topology::Racks(4),
        ..SimConfig::paper()
    };
    let mut t = Table::new(&[
        "mean gap", "scheduler", "node/rack/remote", "mean_ct", "thpt/h", "misses",
    ]);
    for gap in [2.0f64, 5.0, 15.0, 40.0] {
        let trace = JobTrace::poisson(&cfg, 25, gap, 1.6..3.0, 11);
        for kind in [SchedulerKind::Fair, SchedulerKind::DeadlineVc] {
            let r = coordinator::run_simulation(&cfg, kind, &trace);
            t.row(&[
                format!("{gap:.0}s"),
                kind.name().to_string(),
                tier_split(&r),
                format!("{:.1}s", r.mean_completion_s()),
                format!("{:.1}", r.throughput_jobs_per_hour()),
                format!("{:.0}%", r.miss_rate() * 100.0),
            ]);
        }
    }
    t.print();

    println!(
        "\nReading: the proposed scheduler holds ~100% node locality regardless \
         of replication\nor topology, because non-local work is routed (or \
         hot-plugged) to replica nodes.\nFor Fair/Delay the racked topologies \
         convert part of the remote column into the\ncheaper rack column \
         (HDFS rack-aware placement keeps 2 of 3 replicas in one rack),\nbut \
         the residual off-rack reads now contend for the shared core uplink — \
         the gap\nto the reconfiguration-based scheduler widens as the core \
         oversubscription grows\n(racks-4 -> fat-tree-4) and as load rises \
         (paper §1, §5)."
    );
}
