//! Locality study: how replication factor and cluster load shape data
//! locality and completion time across schedulers — the design space the
//! paper's intro motivates (locality vs deadline tension).
//!
//!     cargo run --release --offline --example locality_study

use vcsched::config::SimConfig;
use vcsched::coordinator;
use vcsched::scheduler::SchedulerKind;
use vcsched::util::benchkit::Table;
use vcsched::workloads::trace::JobTrace;

fn main() {
    vcsched::util::logger::init();

    println!("== locality vs replication factor (25-job backlogged mix) ==\n");
    let mut t = Table::new(&[
        "replication", "scheduler", "locality", "mean_ct", "thpt/h", "hotplugs",
    ]);
    for repl in [1usize, 2, 3, 5] {
        let cfg = SimConfig {
            replication: repl,
            ..SimConfig::paper()
        };
        let trace = JobTrace::paper_mix(&cfg, 7);
        for kind in [SchedulerKind::Fair, SchedulerKind::Delay, SchedulerKind::DeadlineVc] {
            let r = coordinator::run_simulation(&cfg, kind, &trace);
            t.row(&[
                format!("{repl}x"),
                kind.name().to_string(),
                format!("{:.1}%", r.locality_pct()),
                format!("{:.1}s", r.mean_completion_s()),
                format!("{:.1}", r.throughput_jobs_per_hour()),
                r.hotplugs.to_string(),
            ]);
        }
    }
    t.print();

    println!("\n== locality vs cluster load (arrival rate sweep, 3x repl) ==\n");
    let cfg = SimConfig::paper();
    let mut t = Table::new(&[
        "mean gap", "scheduler", "locality", "mean_ct", "thpt/h", "misses",
    ]);
    for gap in [2.0f64, 5.0, 15.0, 40.0] {
        let trace = JobTrace::poisson(&cfg, 25, gap, 1.6..3.0, 11);
        for kind in [SchedulerKind::Fair, SchedulerKind::DeadlineVc] {
            let r = coordinator::run_simulation(&cfg, kind, &trace);
            t.row(&[
                format!("{gap:.0}s"),
                kind.name().to_string(),
                format!("{:.1}%", r.locality_pct()),
                format!("{:.1}s", r.mean_completion_s()),
                format!("{:.1}", r.throughput_jobs_per_hour()),
                format!("{:.0}%", r.miss_rate() * 100.0),
            ]);
        }
    }
    t.print();

    println!(
        "\nReading: the proposed scheduler holds ~100% locality regardless of \
         replication,\nbecause non-local work is routed (or hot-plugged) to \
         replica nodes — the gain over\nFair/Delay grows as replication drops \
         and as load rises (paper §1, §5)."
    );
}
