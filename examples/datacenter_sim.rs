//! End-to-end driver (the repo's validation workload).
//!
//! Runs the paper's full five-workload mix on the 20-PM virtual cluster in
//! **Real** execution mode: every map/reduce task actually executes its
//! function over generated corpus bytes while the discrete-event engine
//! simulates the timing; the Resource Predictor runs on the **PJRT
//! artifacts compiled from the JAX/Pallas kernels** (falling back to the
//! native predictor with a warning if `artifacts/` is missing).
//!
//! It verifies, for every job, that the distributed output equals a serial
//! single-pass reference, then reports the paper's headline comparison.
//!
//!     make artifacts && cargo run --release --offline --example datacenter_sim

use vcsched::config::{ExecMode, SimConfig};
use vcsched::coordinator::World;
use vcsched::mapreduce::JobId;
use vcsched::predictor::{NativePredictor, Predictor};
use vcsched::runtime::XlaPredictor;
use vcsched::scheduler::SchedulerKind;
use vcsched::workloads::trace::JobTrace;
use vcsched::workloads::{JobSpec, JobType, ALL_JOB_TYPES};

fn run(
    cfg: &SimConfig,
    kind: SchedulerKind,
    trace: &JobTrace,
    predictor: &mut dyn Predictor,
) -> (vcsched::metrics::RunMetrics, usize) {
    let mut sched = kind.build(cfg);
    let mut world = World::new(cfg.clone(), trace.clone());
    world.run(sched.as_mut(), predictor);

    // E2E verification: distributed output == serial reference, per job.
    let mut verified = 0;
    if let Some(exec) = world.exec_engine() {
        for i in 0..trace.len() {
            let id = JobId(i as u32);
            let got = exec.job_output(id);
            let want = exec.serial_reference(id);
            assert!(
                got == want,
                "job {i} output diverged from serial reference ({} vs {} pairs)",
                got.len(),
                want.len()
            );
            verified += 1;
        }
    }
    (world.into_metrics(kind.name()), verified)
}

fn main() {
    vcsched::util::logger::init();
    let cfg = SimConfig {
        exec: ExecMode::Real,
        ..SimConfig::paper()
    };

    // The five paper workloads at mixed sizes with deadlines, plus a
    // second wave arriving while the first is running.
    let mut jobs = Vec::new();
    for (i, jt) in ALL_JOB_TYPES.iter().enumerate() {
        let mb = 256.0 + 128.0 * i as f64;
        let spec = JobSpec::new(*jt, mb);
        let d = vcsched::workloads::trace::ideal_completion_estimate(&cfg, &spec) * 2.5;
        jobs.push(spec.with_deadline(d).at(i as f64 * 4.0));
        let spec2 = JobSpec::new(*jt, mb * 0.75);
        let d2 = vcsched::workloads::trace::ideal_completion_estimate(&cfg, &spec2) * 2.0;
        jobs.push(spec2.with_deadline(d2).at(40.0 + i as f64 * 4.0));
    }
    let trace = JobTrace::new(jobs);
    println!(
        "datacenter_sim: {} jobs ({} workload types) on {} PMs / {} VMs, REAL execution",
        trace.len(),
        ALL_JOB_TYPES.len(),
        cfg.pms,
        cfg.nodes()
    );

    // Predictor: PJRT artifacts if built, else native fallback.
    let mut xla: Option<XlaPredictor> = match XlaPredictor::load_default() {
        Ok(p) => {
            println!("predictor: XLA artifacts (PJRT CPU) — JAX/Pallas AOT path");
            Some(p)
        }
        Err(e) => {
            eprintln!("WARNING: artifacts not available ({e}); using native predictor");
            None
        }
    };
    let mut native = NativePredictor::new();

    let (fair, v1) = run(&cfg, SchedulerKind::Fair, &trace, &mut native);
    let (prop, v2) = match xla.as_mut() {
        Some(p) => run(&cfg, SchedulerKind::DeadlineVc, &trace, p),
        None => run(&cfg, SchedulerKind::DeadlineVc, &trace, &mut native),
    };
    println!("output verification: {v1} + {v2} jobs checked against serial reference — all equal");

    println!("\n{:<14} {:>10} {:>10} {:>10} {:>8} {:>9}", "scheduler", "makespan", "mean_ct", "thpt/h", "locality", "hotplugs");
    for r in [&fair, &prop] {
        println!(
            "{:<14} {:>9.1}s {:>9.1}s {:>10.2} {:>7.1}% {:>9}",
            r.scheduler,
            r.makespan_s,
            r.mean_completion_s(),
            r.throughput_jobs_per_hour(),
            r.locality_pct(),
            r.hotplugs
        );
    }
    let gain = (prop.throughput_jobs_per_hour() / fair.throughput_jobs_per_hour() - 1.0) * 100.0;
    let ct = (1.0 - prop.mean_completion_s() / fair.mean_completion_s()) * 100.0;
    println!(
        "\nheadline: throughput {gain:+.1}% | mean completion time {ct:+.1}% \
         | locality {:.1}% -> {:.1}% (paper: ~12% throughput gain)",
        fair.locality_pct(),
        prop.locality_pct()
    );
}
