//! Rack-aware network topology: the tiered locality model behind the
//! `--topology` sweep axis.
//!
//! The paper's motivation is that meeting a deadline may force a task onto
//! a node "without local input data for that task causing expensive data
//! transfer from a remote node" — but its §5 testbed is a single rack, so
//! the seed reproduction modelled exactly two costs: local disk scan vs
//! one flat NIC fetch. Real Hadoop deployments (and the delay-scheduling
//! line of work `scheduler/delay.rs` follows, arXiv:1506.00425) see a
//! *three*-tier hierarchy:
//!
//! 1. **node-local** — the block is on the task's own DataNode: read at
//!    disk bandwidth;
//! 2. **rack-local** — a replica sits on another node of the same rack:
//!    one hop through a non-blocking top-of-rack switch, at NIC speed;
//! 3. **remote** (off-rack) — the fetch crosses the rack uplink into the
//!    cluster core, a *shared* link every concurrent cross-rack fetch
//!    divides between itself and its peers.
//!
//! A [`Topology`] names the shape of that hierarchy:
//!
//! * [`Topology::Flat`] — the seed model: one implicit rack, no rack tier,
//!   every non-local read pays exactly `block / net_mbps`. This variant
//!   reproduces the pre-topology simulator *byte for byte* (placement RNG
//!   draws, task timings, metrics), which the regression tests pin down.
//! * [`Topology::Racks`]`(n)` — `n` equal racks (`n >= 2`; PM `i` lands
//!   in rack `i % n`), full bisection inside a rack, and a shared
//!   cross-rack core of one 2-NIC uplink per rack (~5:1 oversubscription
//!   against aggregate NIC demand on the paper's 10-node racks).
//! * [`Topology::FatTree`]`(n)` — same rack structure but a "fat-tree-ish"
//!   budget core of one 1-NIC uplink per rack (~10:1 on the paper
//!   testbed), the regime where off-rack reads hurt most.
//!
//! Bandwidth sharing uses the simplest defensible model: a cross-rack
//! fetch starting while `f` cross-rack fetches (itself included) are in
//! flight gets `min(net_mbps, core_capacity / f)` for its whole duration.
//! There is no per-flow re-fairing when neighbours finish — that keeps
//! the event loop untouched and every run a pure function of its inputs.
//!
//! # Example
//!
//! Build a racks-2 topology over a 4-PM / 8-node cluster and classify
//! locality tiers between nodes:
//!
//! ```
//! use vcsched::cluster::{Cluster, LocalityTier, NodeId, Topology};
//! use vcsched::config::SimConfig;
//!
//! let cfg = SimConfig {
//!     topology: Topology::Racks(2),
//!     ..SimConfig::small() // 4 PMs x 2 VMs
//! };
//! let c = Cluster::build(&cfg);
//! // PM i -> rack i % 2, and a node inherits its PM's rack:
//! // nodes 0,1 (PM 0) and 4,5 (PM 2) are rack 0; 2,3,6,7 are rack 1.
//! assert_eq!(c.rack_of(NodeId(0)), 0);
//! assert_eq!(c.rack_of(NodeId(2)), 1);
//! assert_eq!(c.rack_of(NodeId(4)), 0);
//!
//! // Tier classification: same node < same rack < cross rack.
//! assert_eq!(c.tier(NodeId(0), NodeId(0)), LocalityTier::NodeLocal);
//! assert_eq!(c.tier(NodeId(0), NodeId(4)), LocalityTier::RackLocal);
//! assert_eq!(c.tier(NodeId(0), NodeId(2)), LocalityTier::Remote);
//!
//! // Under the flat topology there is no rack tier at all.
//! let flat = Cluster::build(&SimConfig::small());
//! assert_eq!(flat.tier(NodeId(0), NodeId(2)), LocalityTier::Remote);
//! ```

/// How close a map task runs to its input block. Ordered best-first so
/// `min()` over a replica set yields the best achievable tier.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LocalityTier {
    /// Input block resident on the task's own node (DataNode).
    NodeLocal,
    /// A replica on another node of the same rack (racked topologies
    /// only — the flat topology never produces this tier).
    RackLocal,
    /// Off-rack: the fetch crosses the shared cluster core.
    Remote,
}

impl LocalityTier {
    pub const ALL: [LocalityTier; 3] = [
        LocalityTier::NodeLocal,
        LocalityTier::RackLocal,
        LocalityTier::Remote,
    ];

    /// Stable label used in artifacts and tables.
    pub fn name(self) -> &'static str {
        match self {
            LocalityTier::NodeLocal => "node",
            LocalityTier::RackLocal => "rack",
            LocalityTier::Remote => "remote",
        }
    }
}

/// The cluster's network shape: how PMs group into racks and how much the
/// cross-rack core is oversubscribed. One point on the `vcsched sweep`
/// `--topology` axis; labels (`flat`, `racks-4`, `fat-tree-4`) are stable
/// artifact keys.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Topology {
    /// Single implicit rack (the paper's §5 testbed; the default). No
    /// rack tier, no uplink contention — byte-identical to the
    /// pre-topology simulator.
    #[default]
    Flat,
    /// `n >= 2` equal racks; shared core of one 2-NIC uplink per rack.
    Racks(u32),
    /// `n >= 2` equal racks; "fat-tree-ish" budget core of one 1-NIC
    /// uplink per rack (off-rack reads degrade twice as fast as
    /// [`Topology::Racks`]).
    FatTree(u32),
}

impl Topology {
    /// Does this topology have a rack tier at all?
    pub fn is_racked(self) -> bool {
        !matches!(self, Topology::Flat)
    }

    /// Number of racks (1 for flat).
    pub fn racks(self) -> u32 {
        match self {
            Topology::Flat => 1,
            Topology::Racks(n) | Topology::FatTree(n) => n,
        }
    }

    /// Rack of physical machine `pm_idx` (round-robin assignment, so
    /// every rack holds within one PM of the same count).
    pub fn rack_of_pm(self, pm_idx: usize) -> u32 {
        (pm_idx % self.racks().max(1) as usize) as u32
    }

    /// Stable label used in artifacts, CSV keys and the CLI.
    pub fn label(self) -> String {
        match self {
            Topology::Flat => "flat".to_string(),
            Topology::Racks(n) => format!("racks-{n}"),
            Topology::FatTree(n) => format!("fat-tree-{n}"),
        }
    }

    /// Parse a label produced by [`Topology::label`] (`flat`, `racks-N`,
    /// `fat-tree-N`; N >= 2 — a one-rack "racked" cluster would be the
    /// flat topology wearing a different label while classifying every
    /// off-node read as rack-local, so it is rejected rather than
    /// silently contradicting `flat`'s metrics).
    pub fn from_label(s: &str) -> Option<Topology> {
        if s == "flat" {
            return Some(Topology::Flat);
        }
        if let Some(n) = s.strip_prefix("racks-") {
            let n: u32 = n.parse().ok()?;
            return (n >= 2).then_some(Topology::Racks(n));
        }
        if let Some(n) = s.strip_prefix("fat-tree-") {
            let n: u32 = n.parse().ok()?;
            return (n >= 2).then_some(Topology::FatTree(n));
        }
        None
    }

    /// Parse a comma-separated topology list (`"flat,racks-4"`) — the
    /// `vcsched sweep --topology` axis override. `None` if any label is
    /// unknown.
    pub fn parse_list(s: &str) -> Option<Vec<Topology>> {
        s.split(',')
            .map(|part| Topology::from_label(part.trim()))
            .collect()
    }

    /// Intra-rack (rack-local) fetch bandwidth: the top-of-rack switch is
    /// non-blocking, so the node NIC is the bottleneck.
    pub fn rack_mbps(self, net_mbps: f64) -> f64 {
        net_mbps
    }

    /// Aggregate cross-rack core capacity in MB/s — the shared link every
    /// off-rack fetch draws from: one uplink per rack, provisioned as a
    /// multiple of the node NIC. Flat has no core link (remote reads see
    /// the full NIC, as in the seed model).
    pub fn core_capacity_mbps(self, net_mbps: f64) -> f64 {
        match self {
            Topology::Flat => f64::INFINITY,
            // One 2-NIC uplink per rack (~5:1 oversubscription against
            // the paper testbed's 10 NICs per rack).
            Topology::Racks(n) => net_mbps * 2.0 * n as f64,
            // Budget fabric: one 1-NIC uplink per rack (~10:1).
            Topology::FatTree(n) => net_mbps * n as f64,
        }
    }

    /// Effective bandwidth of one cross-rack fetch when `flows` fetches
    /// (this one included) share the core: the fair share, capped by the
    /// fetching node's NIC.
    pub fn cross_rack_mbps(self, net_mbps: f64, flows: u32) -> f64 {
        let share = self.core_capacity_mbps(net_mbps) / flows.max(1) as f64;
        share.min(net_mbps)
    }

    /// Validate against a cluster of `pms` physical machines.
    pub fn validate(self, pms: usize) -> Result<(), String> {
        let n = self.racks() as usize;
        if self.is_racked() && n < 2 {
            return Err(format!(
                "topology {} needs at least 2 racks (use `flat` for a \
                 single-rack cluster)",
                self.label()
            ));
        }
        if self.is_racked() && n > pms {
            return Err(format!(
                "topology {} has more racks ({n}) than PMs ({pms})",
                self.label()
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_roundtrip() {
        for t in [
            Topology::Flat,
            Topology::Racks(2),
            Topology::Racks(4),
            Topology::FatTree(4),
        ] {
            assert_eq!(Topology::from_label(&t.label()), Some(t));
        }
        assert_eq!(Topology::Flat.label(), "flat");
        assert_eq!(Topology::Racks(4).label(), "racks-4");
        assert_eq!(Topology::FatTree(8).label(), "fat-tree-8");
        assert_eq!(Topology::from_label("racks-0"), None);
        assert_eq!(Topology::from_label("fat-tree-0"), None);
        // One rack == flat; the alias is rejected so identical physical
        // systems can't report contradictory tier splits.
        assert_eq!(Topology::from_label("racks-1"), None);
        assert_eq!(Topology::from_label("fat-tree-1"), None);
        assert_eq!(Topology::from_label("mesh-3"), None);
        assert_eq!(Topology::from_label("racks-"), None);
    }

    #[test]
    fn parse_list_accepts_commas_and_rejects_typos() {
        assert_eq!(
            Topology::parse_list("flat, racks-4"),
            Some(vec![Topology::Flat, Topology::Racks(4)])
        );
        assert_eq!(
            Topology::parse_list("fat-tree-2"),
            Some(vec![Topology::FatTree(2)])
        );
        assert_eq!(Topology::parse_list("flat,bogus"), None);
    }

    #[test]
    fn rack_assignment_round_robin() {
        let t = Topology::Racks(4);
        assert_eq!(t.racks(), 4);
        for pm in 0..20 {
            assert_eq!(t.rack_of_pm(pm), (pm % 4) as u32);
        }
        assert_eq!(Topology::Flat.racks(), 1);
        assert_eq!(Topology::Flat.rack_of_pm(13), 0);
    }

    #[test]
    fn tier_order_best_first() {
        assert!(LocalityTier::NodeLocal < LocalityTier::RackLocal);
        assert!(LocalityTier::RackLocal < LocalityTier::Remote);
        assert_eq!(
            [LocalityTier::Remote, LocalityTier::NodeLocal]
                .iter()
                .min(),
            Some(&LocalityTier::NodeLocal)
        );
    }

    #[test]
    fn cross_rack_bandwidth_shares_the_core() {
        let net = 10.0;
        let t = Topology::Racks(4); // 4 uplinks x 2 NICs = 80 MB/s core
        assert_eq!(t.core_capacity_mbps(net), 80.0);
        // Quiet core: the NIC is the bottleneck.
        assert_eq!(t.cross_rack_mbps(net, 1), 10.0);
        assert_eq!(t.cross_rack_mbps(net, 8), 10.0);
        // Contended: fair share of the core.
        assert_eq!(t.cross_rack_mbps(net, 16), 5.0);
        assert_eq!(t.cross_rack_mbps(net, 40), 2.0);
        // More racks mean more uplinks, so core capacity grows with n.
        assert!(Topology::Racks(8).core_capacity_mbps(net) > t.core_capacity_mbps(net));
        // Fat-tree degrades twice as fast (core = 40 MB/s).
        let ft = Topology::FatTree(4);
        assert_eq!(ft.core_capacity_mbps(net), 40.0);
        assert_eq!(ft.cross_rack_mbps(net, 4), 10.0);
        assert_eq!(ft.cross_rack_mbps(net, 8), 5.0);
        assert_eq!(ft.cross_rack_mbps(net, 16), 2.5);
        // Flat never throttles a remote read (the seed model).
        assert_eq!(Topology::Flat.cross_rack_mbps(net, 1000), net);
    }

    #[test]
    fn validation_bounds_racks_by_pms() {
        Topology::Flat.validate(1).unwrap();
        Topology::Racks(4).validate(4).unwrap();
        Topology::Racks(4).validate(20).unwrap();
        assert!(Topology::Racks(8).validate(4).is_err());
        assert!(Topology::FatTree(21).validate(20).is_err());
        // A racked topology needs a real rack structure.
        assert!(Topology::Racks(1).validate(20).is_err());
        assert!(Topology::FatTree(1).validate(20).is_err());
    }

    #[test]
    fn tier_names_stable() {
        assert_eq!(LocalityTier::NodeLocal.name(), "node");
        assert_eq!(LocalityTier::RackLocal.name(), "rack");
        assert_eq!(LocalityTier::Remote.name(), "remote");
    }
}
