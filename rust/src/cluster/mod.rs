//! Virtualized cluster substrate: physical machines hosting VMs whose
//! vCPU counts can be reconfigured at runtime (Xen credit-scheduler style
//! hot-plug, paper §4.1).
//!
//! Terminology mapping to the paper:
//! * *node* = one VM = one Hadoop TaskTracker = one HDFS DataNode;
//! * a VM's **map capacity** equals its *current* vCPU count (hot-plug adds
//!   a map slot); **reduce slots** are static — the paper reconfigures only
//!   for the map phase (§4.2: "we have considered only the map phase to
//!   maximize data locality").
//!
//! # Heterogeneity
//!
//! Since the `pm_profile` axis (see [`crate::config::PmProfile`]) the
//! cluster is not necessarily homogeneous: each PM takes its core count
//! and relative speed from the profile at build time. Every VM inherits
//! its host PM's speed; the coordinator divides simulated task durations
//! by it, and the per-PM core count bounds how many vCPUs the
//! reconfigurator's Machine Managers can hot-plug onto that machine
//! ([`Cluster::check_invariants`] enforces `assigned <= cores` per PM).
//!
//! # Network topology
//!
//! Since the `topology` axis (see [`topology::Topology`]) the cluster is
//! not necessarily a single rack either: PMs group into racks, every VM
//! inherits its host PM's rack, and [`Cluster::tier`] classifies a
//! (task node, data node) pair as node-local / rack-local / off-rack.
//! Schedulers score placements through that classification and the
//! coordinator charges tier-dependent input-fetch bandwidth (cross-rack
//! fetches share the topology's core link).
//!
//! ```
//! use vcsched::cluster::Cluster;
//! use vcsched::config::{PmProfile, SimConfig};
//!
//! let cfg = SimConfig {
//!     pm_profile: PmProfile::Split2x,
//!     ..SimConfig::small() // 4 PMs x 2 VMs x 2 vCPUs, 4 cores each
//! };
//! let c = Cluster::build(&cfg);
//! // Even PMs are "big": twice the cores, so they start with spare
//! // cores the reconfigurator can plug into either resident VM.
//! assert_eq!(c.pm(vcsched::cluster::PmId(0)).cores, 8);
//! assert_eq!(c.pm(vcsched::cluster::PmId(1)).cores, 4);
//! assert_eq!(c.spare_cores(vcsched::cluster::PmId(0)), 4);
//! ```

pub mod topology;

pub use topology::{LocalityTier, Topology};

use crate::config::SimConfig;
use crate::util::codec::{Dec, Enc};

/// Physical machine index.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct PmId(pub u32);

/// VM (node) index, global across the cluster.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct NodeId(pub u32);

impl NodeId {
    #[inline]
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

impl PmId {
    #[inline]
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

/// A physical machine: a fixed pool of cores shared by its VMs.
#[derive(Clone, Debug)]
pub struct PhysicalMachine {
    pub id: PmId,
    pub cores: u32,
    /// Relative machine speed (1.0 = baseline; see
    /// [`crate::config::PmProfile`]).
    pub speed: f64,
    /// Rack this machine lives in (always 0 under [`Topology::Flat`]).
    pub rack: u32,
    pub vms: Vec<NodeId>,
    /// Fail-stop liveness (failure injection). Dead PMs run nothing:
    /// their VMs' heartbeats are gated and their slots unschedulable.
    /// Always `true` when the failure model is off.
    pub alive: bool,
}

impl PhysicalMachine {
    /// Cores currently assigned across this PM's VMs.
    pub fn assigned_cores(&self, cluster: &Cluster) -> u32 {
        self.vms.iter().map(|&v| cluster.vm(v).vcpus).sum()
    }
}

/// A virtual machine (one Hadoop node).
#[derive(Clone, Debug)]
pub struct Vm {
    pub id: NodeId,
    pub pm: PmId,
    /// Static base configuration (what the user paid for).
    pub base_vcpus: u32,
    /// Current vCPU count (changes through hot-plug).
    pub vcpus: u32,
    /// Map tasks currently running (each occupies one vCPU).
    pub busy_map: u32,
    /// Reduce tasks currently running (separate static slots).
    pub busy_reduce: u32,
    /// Static reduce slots.
    pub reduce_slots: u32,
    /// Host PM's relative speed, inherited at build time. Task durations
    /// on this VM divide by it (a 0.5-speed straggler takes twice as
    /// long).
    pub speed: f64,
}

impl Vm {
    /// Free map slots = free vCPUs.
    pub fn free_map_slots(&self) -> u32 {
        self.vcpus.saturating_sub(self.busy_map)
    }

    pub fn free_reduce_slots(&self) -> u32 {
        self.reduce_slots.saturating_sub(self.busy_reduce)
    }

    /// Can this VM give up a core right now? It must keep >= 1 vCPU and
    /// cannot release a core a running map task occupies.
    pub fn can_release_core(&self) -> bool {
        self.vcpus > 1 && self.free_map_slots() > 0
    }
}

/// The whole virtual cluster.
#[derive(Clone, Debug)]
pub struct Cluster {
    pms: Vec<PhysicalMachine>,
    vms: Vec<Vm>,
    /// Network shape the cluster was built with (rack assignment and
    /// cross-rack bandwidth model).
    topology: Topology,
}

/// Errors from hot-plug operations (hand-rolled Display/Error impls —
/// `thiserror` is unavailable offline).
#[derive(Debug, PartialEq, Eq)]
pub enum HotplugError {
    NoSpareCore(PmId),
    CannotRelease(NodeId, u32, u32),
    CrossPm(NodeId, NodeId),
}

impl std::fmt::Display for HotplugError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HotplugError::NoSpareCore(pm) => {
                write!(f, "PM {pm:?} has no spare physical core")
            }
            HotplugError::CannotRelease(vm, vcpus, busy) => {
                write!(f, "VM {vm:?} cannot release a core (vcpus={vcpus}, busy={busy})")
            }
            HotplugError::CrossPm(a, b) => {
                write!(f, "VMs {a:?} and {b:?} are on different physical machines")
            }
        }
    }
}

impl std::error::Error for HotplugError {}

impl Cluster {
    /// Build the cluster laid out by `cfg`: `pms` machines, each hosting
    /// `vms_per_pm` VMs of `base_vcpus` vCPUs.
    pub fn build(cfg: &SimConfig) -> Self {
        let mut pms = Vec::with_capacity(cfg.pms);
        let mut vms = Vec::with_capacity(cfg.nodes());
        for p in 0..cfg.pms {
            let pm_id = PmId(p as u32);
            let speed = cfg.pm_speed(p);
            let mut pm = PhysicalMachine {
                id: pm_id,
                cores: cfg.pm_cores(p),
                speed,
                rack: cfg.topology.rack_of_pm(p),
                vms: Vec::with_capacity(cfg.vms_per_pm),
                alive: true,
            };
            for _ in 0..cfg.vms_per_pm {
                let id = NodeId(vms.len() as u32);
                pm.vms.push(id);
                vms.push(Vm {
                    id,
                    pm: pm_id,
                    base_vcpus: cfg.base_vcpus,
                    vcpus: cfg.base_vcpus,
                    busy_map: 0,
                    busy_reduce: 0,
                    reduce_slots: cfg.reduce_slots,
                    speed,
                });
            }
            pms.push(pm);
        }
        Self {
            pms,
            vms,
            topology: cfg.topology,
        }
    }

    pub fn num_nodes(&self) -> usize {
        self.vms.len()
    }

    pub fn num_pms(&self) -> usize {
        self.pms.len()
    }

    pub fn vm(&self, id: NodeId) -> &Vm {
        &self.vms[id.idx()]
    }

    pub fn vm_mut(&mut self, id: NodeId) -> &mut Vm {
        &mut self.vms[id.idx()]
    }

    pub fn pm(&self, id: PmId) -> &PhysicalMachine {
        &self.pms[id.idx()]
    }

    pub fn pm_of(&self, node: NodeId) -> PmId {
        self.vm(node).pm
    }

    pub fn vms(&self) -> impl Iterator<Item = &Vm> {
        self.vms.iter()
    }

    pub fn pms(&self) -> impl Iterator<Item = &PhysicalMachine> {
        self.pms.iter()
    }

    /// Are these two nodes co-located on one physical machine?
    pub fn same_pm(&self, a: NodeId, b: NodeId) -> bool {
        self.pm_of(a) == self.pm_of(b)
    }

    /// The topology the cluster was built with.
    pub fn topology(&self) -> Topology {
        self.topology
    }

    /// Number of racks in the cluster (1 under [`Topology::Flat`]).
    pub fn num_racks(&self) -> u32 {
        self.topology.racks()
    }

    /// Rack of `node` (inherited from its host PM).
    pub fn rack_of(&self, node: NodeId) -> u32 {
        self.pm(self.pm_of(node)).rack
    }

    /// Are these two nodes in the same rack?
    pub fn same_rack(&self, a: NodeId, b: NodeId) -> bool {
        self.rack_of(a) == self.rack_of(b)
    }

    /// Classify the locality tier of a map task running on `node` whose
    /// input block lives on `data`. The flat topology has no rack tier:
    /// every off-node read is [`LocalityTier::Remote`], exactly the seed
    /// model's binary local/remote split.
    pub fn tier(&self, node: NodeId, data: NodeId) -> LocalityTier {
        if node == data {
            LocalityTier::NodeLocal
        } else if self.topology.is_racked() && self.same_rack(node, data) {
            LocalityTier::RackLocal
        } else {
            LocalityTier::Remote
        }
    }

    /// Is this PM up? (Always `true` without failure injection.)
    pub fn pm_alive(&self, pm: PmId) -> bool {
        self.pm(pm).alive
    }

    /// Is this node's host PM up? Dead nodes run nothing and take no
    /// launches.
    pub fn node_alive(&self, node: NodeId) -> bool {
        self.pm(self.pm_of(node)).alive
    }

    /// Fail-stop crash of a PM: mark it dead and wipe its VMs back to the
    /// base slot layout (running tasks die — the *coordinator* transitions
    /// their job state before calling this; mid-hotplug cores snap back to
    /// the base allocation, matching the reset the hypervisor would do on
    /// reboot).
    pub fn crash_pm(&mut self, pm: PmId) {
        debug_assert!(self.pms[pm.idx()].alive, "crashing dead PM {pm:?}");
        self.pms[pm.idx()].alive = false;
        let vms = self.pms[pm.idx()].vms.clone();
        for v in vms {
            let vm = self.vm_mut(v);
            vm.vcpus = vm.base_vcpus;
            vm.busy_map = 0;
            vm.busy_reduce = 0;
        }
        debug_assert!(self.check_invariants().is_ok());
    }

    /// Recover a crashed PM: it rejoins with freshly-booted VMs at the
    /// base configuration (all prior state was lost at the crash).
    pub fn recover_pm(&mut self, pm: PmId) {
        debug_assert!(!self.pms[pm.idx()].alive, "recovering live PM {pm:?}");
        self.pms[pm.idx()].alive = true;
        debug_assert!(self.check_invariants().is_ok());
    }

    /// Spare (unassigned) physical cores on a PM.
    pub fn spare_cores(&self, pm: PmId) -> u32 {
        let p = self.pm(pm);
        p.cores.saturating_sub(p.assigned_cores(self))
    }

    /// Move one core `from` -> `to` (both on the same PM). This is the MM's
    /// hot-plug primitive: un-plug a free vCPU from `from`, plug it into
    /// `to`. The releasing VM must have a free vCPU and keep at least one.
    pub fn transfer_core(&mut self, from: NodeId, to: NodeId) -> Result<(), HotplugError> {
        if self.pm_of(from) != self.pm_of(to) {
            return Err(HotplugError::CrossPm(from, to));
        }
        let f = self.vm(from);
        if f.vcpus <= 1 || f.free_map_slots() == 0 {
            return Err(HotplugError::CannotRelease(from, f.vcpus, f.busy_map));
        }
        self.vm_mut(from).vcpus -= 1;
        self.vm_mut(to).vcpus += 1;
        debug_assert!(self.check_invariants().is_ok());
        Ok(())
    }

    /// Plug a *spare* physical core (not currently assigned to any VM)
    /// into `to`. Used when a PM is under-committed.
    pub fn plug_spare_core(&mut self, to: NodeId) -> Result<(), HotplugError> {
        let pm = self.pm_of(to);
        if self.spare_cores(pm) == 0 {
            return Err(HotplugError::NoSpareCore(pm));
        }
        self.vm_mut(to).vcpus += 1;
        debug_assert!(self.check_invariants().is_ok());
        Ok(())
    }

    /// Release one free vCPU from `from` back to the PM's spare pool.
    pub fn unplug_core(&mut self, from: NodeId) -> Result<(), HotplugError> {
        let f = self.vm(from);
        if f.vcpus <= 1 || f.free_map_slots() == 0 {
            return Err(HotplugError::CannotRelease(from, f.vcpus, f.busy_map));
        }
        self.vm_mut(from).vcpus -= 1;
        debug_assert!(self.check_invariants().is_ok());
        Ok(())
    }

    /// Snapshot encoding of the *mutable* cluster state. The static layout
    /// (core counts, speeds, racks, VM placement) is a pure function of
    /// [`SimConfig`], so snapshots store only what `build` cannot rebuild:
    /// per-PM liveness and per-VM vCPU / busy-slot counters, in id order.
    pub(crate) fn encode_state(&self, e: &mut Enc) {
        e.usize(self.pms.len());
        for pm in &self.pms {
            e.bool(pm.alive);
        }
        e.usize(self.vms.len());
        for vm in &self.vms {
            e.u32(vm.vcpus);
            e.u32(vm.busy_map);
            e.u32(vm.busy_reduce);
        }
    }

    /// Overlay snapshot state from [`Self::encode_state`] onto a cluster
    /// freshly built from the *same* config.
    pub(crate) fn restore_state(&mut self, d: &mut Dec) -> Result<(), String> {
        let n_pms = d.usize()?;
        if n_pms != self.pms.len() {
            return Err(format!(
                "snapshot has {} PMs, config builds {}",
                n_pms,
                self.pms.len()
            ));
        }
        for pm in &mut self.pms {
            pm.alive = d.bool()?;
        }
        let n_vms = d.usize()?;
        if n_vms != self.vms.len() {
            return Err(format!(
                "snapshot has {} VMs, config builds {}",
                n_vms,
                self.vms.len()
            ));
        }
        for vm in &mut self.vms {
            vm.vcpus = d.u32()?;
            vm.busy_map = d.u32()?;
            vm.busy_reduce = d.u32()?;
        }
        self.check_invariants()
    }

    /// Invariants the property tests assert after every mutation:
    /// cores assigned on each PM never exceed physical cores; every VM has
    /// >= 1 vCPU; busy counts never exceed capacity.
    pub fn check_invariants(&self) -> Result<(), String> {
        for pm in &self.pms {
            let assigned = pm.assigned_cores(self);
            if assigned > pm.cores {
                return Err(format!(
                    "PM {:?}: {} cores assigned > {} physical",
                    pm.id, assigned, pm.cores
                ));
            }
        }
        for vm in &self.vms {
            if vm.vcpus == 0 {
                return Err(format!("VM {:?} has zero vCPUs", vm.id));
            }
            if vm.speed <= 0.0 {
                return Err(format!("VM {:?} has non-positive speed", vm.id));
            }
            if vm.busy_map > vm.vcpus {
                return Err(format!(
                    "VM {:?}: {} map tasks > {} vCPUs",
                    vm.id, vm.busy_map, vm.vcpus
                ));
            }
            if vm.busy_reduce > vm.reduce_slots {
                return Err(format!(
                    "VM {:?}: {} reduce tasks > {} slots",
                    vm.id, vm.busy_reduce, vm.reduce_slots
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cluster() -> Cluster {
        Cluster::build(&SimConfig::small()) // 4 PMs x 2 VMs x 2 vCPUs
    }

    #[test]
    fn crash_and_recover_reset_vms() {
        let mut c = cluster();
        let pm = PmId(1);
        let nodes = c.pm(pm).vms.clone();
        // Dirty the PM: busy slots and a hot-plugged core imbalance.
        c.transfer_core(nodes[1], nodes[0]).unwrap();
        c.vm_mut(nodes[0]).busy_map = 2;
        c.vm_mut(nodes[1]).busy_reduce = 1;
        assert!(c.node_alive(nodes[0]));
        c.crash_pm(pm);
        assert!(!c.pm_alive(pm));
        assert!(!c.node_alive(nodes[0]));
        for &n in &nodes {
            let vm = c.vm(n);
            assert_eq!(vm.vcpus, vm.base_vcpus);
            assert_eq!(vm.busy_map, 0);
            assert_eq!(vm.busy_reduce, 0);
        }
        // Other PMs untouched.
        assert!(c.pm_alive(PmId(0)));
        c.recover_pm(pm);
        assert!(c.pm_alive(pm));
        c.check_invariants().unwrap();
    }

    #[test]
    fn layout_matches_config() {
        let c = cluster();
        assert_eq!(c.num_pms(), 4);
        assert_eq!(c.num_nodes(), 8);
        for vm in c.vms() {
            assert_eq!(vm.vcpus, 2);
            assert_eq!(vm.reduce_slots, 2);
        }
        for pm in c.pms() {
            assert_eq!(pm.vms.len(), 2);
            assert_eq!(pm.assigned_cores(&c), 4);
        }
        c.check_invariants().unwrap();
    }

    #[test]
    fn heterogeneous_layout_follows_profile() {
        use crate::config::PmProfile;
        // split-2x: even PMs have 2x cores and spare capacity at build.
        let cfg = SimConfig {
            pm_profile: PmProfile::Split2x,
            ..SimConfig::small()
        };
        let c = Cluster::build(&cfg);
        assert_eq!(c.pm(PmId(0)).cores, 8);
        assert_eq!(c.pm(PmId(1)).cores, 4);
        assert_eq!(c.spare_cores(PmId(0)), 4);
        assert_eq!(c.spare_cores(PmId(1)), 0);
        c.check_invariants().unwrap();

        // long-tail: every fourth PM is a half-speed straggler and its
        // VMs inherit the speed.
        let cfg = SimConfig {
            pm_profile: PmProfile::LongTail,
            ..SimConfig::small()
        };
        let c = Cluster::build(&cfg);
        assert_eq!(c.pm(PmId(3)).speed, 0.5);
        for vm in c.vms() {
            assert_eq!(vm.speed, c.pm(vm.pm).speed);
        }
        c.check_invariants().unwrap();
    }

    #[test]
    fn racked_layout_classifies_tiers() {
        use crate::config::SimConfig;
        let cfg = SimConfig {
            topology: Topology::Racks(2),
            ..SimConfig::small() // 4 PMs x 2 VMs
        };
        let c = Cluster::build(&cfg);
        assert_eq!(c.num_racks(), 2);
        // PM i -> rack i % 2; nodes inherit their PM's rack.
        assert_eq!(c.rack_of(NodeId(0)), 0);
        assert_eq!(c.rack_of(NodeId(1)), 0);
        assert_eq!(c.rack_of(NodeId(2)), 1);
        assert_eq!(c.rack_of(NodeId(4)), 0);
        assert!(c.same_rack(NodeId(0), NodeId(5)));
        assert!(!c.same_rack(NodeId(0), NodeId(2)));
        assert_eq!(c.tier(NodeId(3), NodeId(3)), LocalityTier::NodeLocal);
        assert_eq!(c.tier(NodeId(0), NodeId(4)), LocalityTier::RackLocal);
        assert_eq!(c.tier(NodeId(0), NodeId(3)), LocalityTier::Remote);
    }

    #[test]
    fn flat_layout_has_no_rack_tier() {
        let c = cluster(); // SimConfig::small() defaults to Topology::Flat
        assert_eq!(c.topology(), Topology::Flat);
        assert_eq!(c.num_racks(), 1);
        for a in 0..c.num_nodes() {
            for b in 0..c.num_nodes() {
                let (a, b) = (NodeId(a as u32), NodeId(b as u32));
                let t = c.tier(a, b);
                if a == b {
                    assert_eq!(t, LocalityTier::NodeLocal);
                } else {
                    assert_eq!(t, LocalityTier::Remote, "flat must be binary");
                }
            }
        }
    }

    #[test]
    fn transfer_core_same_pm() {
        let mut c = cluster();
        let (a, b) = (NodeId(0), NodeId(1)); // same PM by construction
        assert!(c.same_pm(a, b));
        c.transfer_core(a, b).unwrap();
        assert_eq!(c.vm(a).vcpus, 1);
        assert_eq!(c.vm(b).vcpus, 3);
        c.check_invariants().unwrap();
    }

    #[test]
    fn transfer_cross_pm_rejected() {
        let mut c = cluster();
        let (a, b) = (NodeId(0), NodeId(2));
        assert!(!c.same_pm(a, b));
        assert_eq!(
            c.transfer_core(a, b),
            Err(HotplugError::CrossPm(a, b))
        );
    }

    #[test]
    fn cannot_release_busy_core() {
        let mut c = cluster();
        let a = NodeId(0);
        c.vm_mut(a).busy_map = 2; // both vCPUs running tasks
        assert!(matches!(
            c.transfer_core(a, NodeId(1)),
            Err(HotplugError::CannotRelease(..))
        ));
    }

    #[test]
    fn cannot_release_last_core() {
        let mut c = cluster();
        let (a, b) = (NodeId(0), NodeId(1));
        c.transfer_core(a, b).unwrap(); // a: 1 vCPU left
        assert!(matches!(
            c.transfer_core(a, b),
            Err(HotplugError::CannotRelease(..))
        ));
    }

    #[test]
    fn spare_core_accounting() {
        // Give the PM headroom: 4 cores, 1 VM x 2 vCPUs -> 2 spare.
        let cfg = SimConfig {
            pms: 1,
            vms_per_pm: 1,
            cores_per_pm: 4,
            ..SimConfig::small()
        };
        let mut c = Cluster::build(&cfg);
        let v = NodeId(0);
        assert_eq!(c.spare_cores(PmId(0)), 2);
        c.plug_spare_core(v).unwrap();
        assert_eq!(c.vm(v).vcpus, 3);
        assert_eq!(c.spare_cores(PmId(0)), 1);
        c.plug_spare_core(v).unwrap();
        assert_eq!(c.spare_cores(PmId(0)), 0);
        assert_eq!(
            c.plug_spare_core(v),
            Err(HotplugError::NoSpareCore(PmId(0)))
        );
        c.unplug_core(v).unwrap();
        assert_eq!(c.spare_cores(PmId(0)), 1);
    }

    #[test]
    fn free_slot_math() {
        let mut c = cluster();
        let v = NodeId(3);
        assert_eq!(c.vm(v).free_map_slots(), 2);
        c.vm_mut(v).busy_map = 1;
        assert_eq!(c.vm(v).free_map_slots(), 1);
        c.vm_mut(v).busy_reduce = 2;
        assert_eq!(c.vm(v).free_reduce_slots(), 0);
    }
}
