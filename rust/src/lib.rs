//! # vcsched
//!
//! Deadline-aware MapReduce scheduling through VM reconfiguration on
//! virtual clusters — a reproduction of Rao & Reddy, *"Scheduling Data
//! Intensive Workloads through Virtualization on MapReduce based Clouds"*,
//! IJDPS 3(4), 2012.
//!
//! The crate is a three-layer system:
//!
//! * **L3 (this crate)** — the coordination contribution: a discrete-event
//!   virtual-cluster simulator with a real mini-MapReduce engine
//!   (JobTracker/TaskTrackers, HDFS-like block placement), pluggable
//!   schedulers (FIFO / Fair / Delay / EDF / the paper's deadline+
//!   reconfiguration scheduler), and the Xen-style vCPU hot-plug protocol
//!   (Machine Manager / Configuration Manager with Assign/Release queues).
//! * **L2/L1 (build-time Python)** — the Resource Predictor's math
//!   (Eq. 1/7/10 and the Alg. 1 placement scoring) as JAX + Pallas
//!   kernels, AOT-lowered to HLO text and executed from Rust via PJRT
//!   ([`runtime`]); Python is never on the scheduling path.

pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod harness;
pub mod hdfs;
pub mod mapreduce;
pub mod metrics;
pub mod predictor;
pub mod prop;
pub mod reconfig;
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod util;
pub mod workloads;

/// Convenience re-exports for examples and benches.
pub mod prelude {
    pub use crate::cluster::{LocalityTier, Topology};
    pub use crate::config::{PmProfile, SimConfig};
    pub use crate::coordinator::{self, Report};
    pub use crate::harness::{run_sweep, run_sweep_resumable, JobMix, Journal, ScenarioGrid};
    pub use crate::predictor::{NativePredictor, Predictor};
    pub use crate::scheduler::SchedulerKind;
    pub use crate::sim::SimTime;
    pub use crate::workloads::trace::Arrival;
    pub use crate::workloads::{self, JobType};
}
