//! Task identities and per-task state.

use crate::cluster::{LocalityTier, NodeId};
use crate::sim::SimTime;
use crate::util::codec::{Dec, Enc};

use super::JobId;

/// Task index within its job (map and reduce spaces are separate).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TaskId(pub u32);

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TaskKind {
    Map,
    Reduce,
}

/// Globally unique task handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TaskRef {
    pub job: JobId,
    pub kind: TaskKind,
    pub id: TaskId,
}

impl TaskRef {
    pub fn map(job: JobId, id: u32) -> Self {
        Self {
            job,
            kind: TaskKind::Map,
            id: TaskId(id),
        }
    }

    pub fn reduce(job: JobId, id: u32) -> Self {
        Self {
            job,
            kind: TaskKind::Reduce,
            id: TaskId(id),
        }
    }
}

/// Snapshot codec for [`TaskRef`] (job, kind tag, id).
pub(crate) fn enc_task_ref(e: &mut Enc, t: TaskRef) {
    e.u32(t.job.0);
    e.u8(match t.kind {
        TaskKind::Map => 0,
        TaskKind::Reduce => 1,
    });
    e.u32(t.id.0);
}

pub(crate) fn dec_task_ref(d: &mut Dec) -> Result<TaskRef, String> {
    let job = JobId(d.u32()?);
    let kind = match d.u8()? {
        0 => TaskKind::Map,
        1 => TaskKind::Reduce,
        k => return Err(format!("bad TaskKind tag {k}")),
    };
    let id = TaskId(d.u32()?);
    Ok(TaskRef { job, kind, id })
}

/// Lifecycle of a single task.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskState {
    Pending,
    /// Waiting for a vCPU hot-plug to complete on `target` (Alg. 1's
    /// delayed local launch).
    AwaitingReconfig { target: NodeId },
    Running {
        node: NodeId,
        started: SimTime,
        /// Map only: input-fetch locality tier (node/rack/remote).
        /// Reduces record [`LocalityTier::Remote`] — their shuffle reads
        /// every mapper regardless of placement (paper §4.2).
        tier: LocalityTier,
    },
    Finished {
        node: NodeId,
        started: SimTime,
        finished: SimTime,
        tier: LocalityTier,
    },
}

/// A live speculative (backup) copy of a running map or reduce task —
/// LATE-style speculation, at most one per task. The primary and the spec
/// copy race; the coordinator keeps whichever completion (`MapDone` /
/// `ReduceDone`) arrives first and kills the other (first-finisher wins,
/// kill-the-loser).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpecAttempt {
    /// Attempt id (shares the per-task attempt counter with primaries, so
    /// stale completion events from killed attempts are droppable).
    pub attempt: u32,
    pub node: NodeId,
    pub started: SimTime,
    pub tier: LocalityTier,
}

impl TaskState {
    pub fn is_pending(&self) -> bool {
        matches!(self, TaskState::Pending)
    }

    pub fn is_running(&self) -> bool {
        matches!(self, TaskState::Running { .. })
    }

    pub fn is_finished(&self) -> bool {
        matches!(self, TaskState::Finished { .. })
    }

    pub fn is_awaiting(&self) -> bool {
        matches!(self, TaskState::AwaitingReconfig { .. })
    }

    /// Duration if finished.
    pub fn duration(&self) -> Option<SimTime> {
        match self {
            TaskState::Finished {
                started, finished, ..
            } => Some(*finished - *started),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn refs_distinguish_kind() {
        let m = TaskRef::map(JobId(1), 3);
        let r = TaskRef::reduce(JobId(1), 3);
        assert_ne!(m, r);
        assert_eq!(m.id, r.id);
    }

    #[test]
    fn state_predicates() {
        let s = TaskState::Pending;
        assert!(s.is_pending() && !s.is_running());
        let s = TaskState::Running {
            node: NodeId(0),
            started: SimTime::ZERO,
            tier: LocalityTier::NodeLocal,
        };
        assert!(s.is_running());
        let s = TaskState::Finished {
            node: NodeId(0),
            started: SimTime::from_millis(100),
            finished: SimTime::from_millis(600),
            tier: LocalityTier::Remote,
        };
        assert!(s.is_finished());
        assert_eq!(s.duration(), Some(SimTime::from_millis(500)));
    }
}
