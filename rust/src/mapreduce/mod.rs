//! Mini-MapReduce engine: jobs, tasks, and the JobTracker-side state the
//! schedulers operate on.
//!
//! The engine mirrors Hadoop 0.20's structure (paper §2.1): a job is split
//! into map tasks (one per HDFS block) and reduce tasks; TaskTrackers
//! (VMs) heartbeat every 3 s reporting free slots; the scheduler assigns
//! tasks to slots. Map output is hash-partitioned per reducer; reduce
//! tasks run copy -> sort -> reduce once the map phase finishes.

mod cost;
mod job;
mod task;

pub use cost::{straggler_multiplier, TaskCost};
pub use job::{JobId, JobPhase, JobState};
pub(crate) use job::{
    dec_opt_time, dec_time, decode_job_spec, enc_opt_time, enc_time, encode_job_spec,
};
pub use task::{SpecAttempt, TaskId, TaskKind, TaskRef, TaskState};
pub(crate) use task::{dec_task_ref, enc_task_ref};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, LocalityTier, NodeId, Topology};
    use crate::config::SimConfig;
    use crate::hdfs::NameNode;
    use crate::sim::SimTime;
    use crate::util::Rng;
    use crate::workloads::{JobSpec, JobType};

    fn job_state() -> JobState {
        let cfg = SimConfig::small();
        let mut nn = NameNode::new();
        let mut rng = Rng::new(1);
        let spec = JobSpec::new(JobType::WordCount, 256.0).with_deadline(600.0);
        JobState::create(
            JobId(0),
            spec,
            &cfg,
            &mut nn,
            &mut rng,
            SimTime::from_secs_f64(5.0),
        )
    }

    #[test]
    fn job_splits_into_block_tasks() {
        let js = job_state();
        assert_eq!(js.total_maps(), 4); // 256 MB / 64 MB
        assert!(js.total_reduces() >= 4);
        assert_eq!(js.pending_maps(), 4);
        assert_eq!(js.phase, JobPhase::MapPhase);
    }

    #[test]
    fn lifecycle_map_then_reduce() {
        let mut js = job_state();
        let n = NodeId(0);
        // run all maps
        for i in 0..js.total_maps() {
            let t = js.next_pending_map_any().expect("pending map");
            js.mark_map_launched(t, n, LocalityTier::NodeLocal, SimTime::from_millis(0));
            assert!(js.running_maps() > 0);
            js.mark_map_finished(t, SimTime::from_secs_f64(10.0 * (i + 1) as f64));
        }
        assert!(js.map_finished());
        assert_eq!(js.phase, JobPhase::ReducePhase);
        // run all reduces
        let total_r = js.total_reduces();
        for i in 0..total_r {
            let r = js.next_pending_reduce().expect("pending reduce");
            js.mark_reduce_launched(r, n, SimTime::from_millis(0));
            js.mark_reduce_finished(r, SimTime::from_secs_f64(100.0 + i as f64));
        }
        assert_eq!(js.phase, JobPhase::Done);
        assert!(js.completion_time().is_some());
    }

    #[test]
    fn locality_lookup() {
        let js = job_state();
        let cfg = SimConfig::small();
        // every map task's preferred nodes hold its block
        for m in 0..js.total_maps() {
            let nodes = js.replica_nodes(m);
            assert_eq!(nodes.len(), cfg.replication);
        }
        // local pending map on a replica node is found
        let replica = js.replica_nodes(0)[0];
        assert!(js.next_pending_local_map(replica).is_some());
    }

    #[test]
    fn progress_counters_consistent() {
        let mut js = job_state();
        let n = NodeId(1);
        let t = js.next_pending_map_any().unwrap();
        js.mark_map_launched(t, n, LocalityTier::Remote, SimTime::from_millis(10));
        assert_eq!(js.pending_maps(), js.total_maps() - 1);
        assert_eq!(js.running_maps(), 1);
        js.mark_map_finished(t, SimTime::from_secs_f64(20.0));
        assert_eq!(js.running_maps(), 0);
        assert_eq!(js.completed_maps(), 1);
        assert_eq!(js.local_maps + js.nonlocal_maps(), 1);
        assert_eq!(js.remote_maps, 1);
        assert_eq!(js.rack_maps, 0);
    }

    #[test]
    fn tier_accounting_splits_rack_from_remote() {
        let mut js = job_state();
        let n = NodeId(2);
        let t = js.next_pending_map_any().unwrap();
        js.mark_map_launched(t, n, LocalityTier::RackLocal, SimTime::from_millis(0));
        js.mark_map_finished(t, SimTime::from_secs_f64(9.0));
        assert_eq!(js.rack_maps, 1);
        assert_eq!(js.remote_maps, 0);
        assert_eq!(js.nonlocal_maps(), 1);
        js.check_invariants().unwrap();
    }

    #[test]
    fn cursors_match_scans_and_roll_back_on_await_cancel() {
        let mut js = job_state();
        let n_maps = js.total_maps();
        assert!(n_maps >= 2);
        // Exhausting the cursor iterators must agree with the retained
        // naive scans at every step of a launch sequence.
        let check_agreement = |js: &JobState| {
            assert_eq!(
                js.pending_maps_iter().collect::<Vec<_>>(),
                js.pending_maps_scan().collect::<Vec<_>>()
            );
            assert_eq!(
                js.pending_reduces_iter().collect::<Vec<_>>(),
                js.pending_reduces_scan().collect::<Vec<_>>()
            );
            for node in 0..8u32 {
                assert_eq!(
                    js.pending_local_maps(NodeId(node)).collect::<Vec<_>>(),
                    js.pending_local_maps_scan(NodeId(node)).collect::<Vec<_>>()
                );
            }
            js.check_invariants().unwrap();
        };
        check_agreement(&js);
        // Launch task 0 so the dense cursor advances past it...
        let t0 = js.next_pending_map_any().unwrap();
        js.mark_map_launched(t0, NodeId(0), LocalityTier::Remote, SimTime::ZERO);
        check_agreement(&js);
        // ...then push task 1 through awaiting -> cancelled: it becomes
        // pending again behind the advanced cursor, and the rollback must
        // re-expose it to every iterator.
        let t1 = js.next_pending_map_any().unwrap();
        let target = js.replica_nodes(t1.0)[0];
        js.mark_map_awaiting(t1, target);
        assert_ne!(js.next_pending_map_any(), Some(t1));
        check_agreement(&js);
        js.mark_map_await_cancelled(t1);
        assert_eq!(js.next_pending_map_any(), Some(t1));
        assert!(js.pending_local_maps(target).any(|t| t == t1));
        check_agreement(&js);
    }

    #[test]
    fn rack_index_and_map_tier_consistent() {
        let cfg = SimConfig {
            topology: Topology::Racks(2),
            ..SimConfig::small()
        };
        let cluster = Cluster::build(&cfg);
        let mut nn = NameNode::new();
        let mut rng = Rng::new(3);
        let spec = JobSpec::new(JobType::Sort, 512.0).with_deadline(900.0);
        let js = JobState::create(JobId(0), spec, &cfg, &mut nn, &mut rng, SimTime::ZERO);
        for m in 0..js.total_maps() {
            let t = TaskId(m);
            // A replica node sees NodeLocal; a same-rack non-replica node
            // sees RackLocal; and the pending rack index agrees.
            let reps = js.replica_nodes(m).to_vec();
            assert_eq!(js.map_tier(t, reps[0], &cluster), LocalityTier::NodeLocal);
            for n in 0..cfg.nodes() {
                let node = NodeId(n as u32);
                let tier = js.map_tier(t, node, &cluster);
                let in_rack_index = js.pending_rack_maps(cluster.rack_of(node)).any(|x| x == t);
                match tier {
                    LocalityTier::NodeLocal | LocalityTier::RackLocal => {
                        assert!(in_rack_index, "task {m} missing from rack index")
                    }
                    LocalityTier::Remote => {
                        assert!(!in_rack_index, "task {m} wrongly rack-indexed")
                    }
                }
            }
        }
        // Flat jobs build no rack index at all.
        let flat = job_state();
        assert_eq!(flat.pending_rack_maps(0).count(), 0);
    }
}
