//! Task-duration cost model (synthetic mode) shared by the coordinator.
//!
//! Map task:   scan time (local disk or remote fetch over NIC) + CPU.
//! Reduce task: copy its shuffle partition from every mapper (u_m copies
//! over the NIC) + sort/merge + reduce CPU.
//!
//! Timing is deterministic given the RNG stream (multiplicative lognormal-
//! ish jitter from `SimConfig::jitter_std`).

use crate::config::{FailureModel, SimConfig};
use crate::util::Rng;
use crate::workloads::JobSpec;

/// Heavy-tailed straggler slowdown multiplier for one task launch.
///
/// With probability `fm.straggler_prob` the task is a straggler and its
/// duration multiplies by a Pareto(`straggler_alpha`) draw clamped to
/// `straggler_cap`; otherwise the multiplier is exactly `1.0`. When
/// stragglers are off (`straggler_prob <= 0`) the function returns without
/// touching the RNG at all — this is what keeps `--failures off` runs
/// byte-identical to the pre-failure simulator.
///
/// Sampling is deterministic in the RNG stream:
///
/// ```
/// use vcsched::config::FailureModel;
/// use vcsched::mapreduce::straggler_multiplier;
/// use vcsched::util::Rng;
///
/// let fm = FailureModel::stragglers();
/// let draw = |seed| {
///     let mut rng = Rng::new(seed);
///     (0..100).map(|_| straggler_multiplier(&fm, &mut rng)).collect::<Vec<f64>>()
/// };
/// assert_eq!(draw(7), draw(7)); // same seed, same multipliers
///
/// let mut rng = Rng::new(7);
/// for _ in 0..1000 {
///     let m = straggler_multiplier(&fm, &mut rng);
///     assert!(m >= 1.0 && m <= fm.straggler_cap);
/// }
///
/// // Disabled stragglers consume zero RNG draws.
/// let (mut a, mut b) = (Rng::new(3), Rng::new(3));
/// assert_eq!(straggler_multiplier(&FailureModel::off(), &mut a), 1.0);
/// assert_eq!(a.next_u64(), b.next_u64());
/// ```
pub fn straggler_multiplier(fm: &FailureModel, rng: &mut Rng) -> f64 {
    if fm.straggler_prob <= 0.0 {
        return 1.0;
    }
    if !rng.chance(fm.straggler_prob) {
        return 1.0;
    }
    // Pareto with x_m = 1: inverse-CDF on a (0, 1] uniform.
    let u = 1.0 - rng.f64();
    u.powf(-1.0 / fm.straggler_alpha).min(fm.straggler_cap)
}

/// Hadoop's `mapred.reduce.parallel.copies` default: each reducer fetches
/// from this many mappers concurrently during the copy phase.
pub const PARALLEL_COPIES: f64 = 5.0;

/// Computes simulated task durations for one job.
#[derive(Clone, Debug)]
pub struct TaskCost {
    map_mb_per_s: f64,
    reduce_mb_per_s: f64,
    selectivity: f64,
    reduce_cpu_factor: f64,
    net_mbps: f64,
    disk_mbps: f64,
    jitter_std: f64,
}

impl TaskCost {
    pub fn new(cfg: &SimConfig, spec: &JobSpec) -> Self {
        let m = spec.job_type.cost_model();
        Self {
            map_mb_per_s: m.map_mb_per_s,
            reduce_mb_per_s: m.reduce_mb_per_s,
            selectivity: m.selectivity,
            reduce_cpu_factor: m.reduce_cpu_factor,
            net_mbps: cfg.net_mbps,
            disk_mbps: cfg.disk_mbps,
            jitter_std: cfg.jitter_std,
        }
    }

    fn jitter(&self, rng: &mut Rng) -> f64 {
        if self.jitter_std <= 0.0 {
            1.0
        } else {
            rng.normal_clamped(1.0, self.jitter_std, 0.6, 1.8)
        }
    }

    /// Map task duration in seconds. A non-local task first pulls its
    /// block from a replica over the network (the paper's "expensive data
    /// transfer from a remote node").
    pub fn map_secs(&self, block_mb: f64, local: bool, rng: &mut Rng) -> f64 {
        let io_mbps = if local { self.disk_mbps } else { self.net_mbps };
        self.map_secs_at(block_mb, io_mbps, rng)
    }

    /// Map task duration with an explicit input-scan bandwidth — the
    /// tiered-topology entry point. The coordinator picks `io_mbps` from
    /// the fetch tier: local disk (node-local), the NIC (rack-local), or
    /// the contended share of the cross-rack core (off-rack; see
    /// [`crate::cluster::Topology::cross_rack_mbps`]). Draws exactly one
    /// jitter sample, like [`TaskCost::map_secs`], so flat-topology runs
    /// consume an identical RNG stream.
    pub fn map_secs_at(&self, block_mb: f64, io_mbps: f64, rng: &mut Rng) -> f64 {
        let io = block_mb / io_mbps;
        let cpu = block_mb / self.map_mb_per_s;
        (io + cpu) * self.jitter(rng)
    }

    /// Intermediate MB one map task over `block_mb` feeds to *all*
    /// reducers together.
    pub fn map_output_mb(&self, block_mb: f64) -> f64 {
        block_mb * self.selectivity
    }

    /// One shuffle copy (mapper -> reducer) of `mb`, seconds. Copies run
    /// `PARALLEL_COPIES`-wide per reducer, so the effective per-copy wall
    /// time divides by the fetch parallelism.
    pub fn copy_secs(&self, mb: f64) -> f64 {
        mb / self.net_mbps / PARALLEL_COPIES
    }

    /// Reduce task duration: copy each mapper's partition + sort+reduce.
    ///
    /// `total_intermediate_mb` is the job-wide shuffle volume; each of the
    /// `reducers` takes an even share, copied in `maps` pieces.
    pub fn reduce_secs(
        &self,
        total_intermediate_mb: f64,
        maps: u32,
        reducers: u32,
        rng: &mut Rng,
    ) -> f64 {
        let share_mb = total_intermediate_mb / reducers.max(1) as f64;
        // Copy phase: `maps` sequential fetches of share/maps MB each —
        // bandwidth-bound overall, but each copy pays a fixed setup cost
        // (this is the t_s the predictor estimates).
        let per_copy_mb = share_mb / maps.max(1) as f64;
        let copy = (0..maps)
            .map(|_| self.copy_setup_secs() + self.copy_secs(per_copy_mb))
            .sum::<f64>();
        let sort_reduce = share_mb / self.reduce_mb_per_s * self.reduce_cpu_factor;
        (copy + sort_reduce) * self.jitter(rng)
    }

    /// Fixed per-copy connection setup (dominates t_s for small shuffles).
    pub fn copy_setup_secs(&self) -> f64 {
        0.01
    }

    /// Jitter-free map duration (predictor priors / Table-2 bench).
    pub fn map_secs_nominal(&self, block_mb: f64, local: bool) -> f64 {
        let io = if local {
            block_mb / self.disk_mbps
        } else {
            block_mb / self.net_mbps
        };
        io + block_mb / self.map_mb_per_s
    }

    /// Jitter-free reduce duration (predictor priors / Table-2 bench).
    pub fn reduce_secs_nominal(&self, total_intermediate_mb: f64, maps: u32, reducers: u32) -> f64 {
        let share_mb = total_intermediate_mb / reducers.max(1) as f64;
        let per_copy_mb = share_mb / maps.max(1) as f64;
        let copy = maps as f64 * (self.copy_setup_secs() + self.copy_secs(per_copy_mb));
        copy + share_mb / self.reduce_mb_per_s * self.reduce_cpu_factor
    }

    /// The model's per-copy time for the predictor prior: setup + the
    /// bandwidth share of an "average" copy.
    pub fn t_shuffle_estimate(&self, total_intermediate_mb: f64, maps: u32, reducers: u32) -> f64 {
        let copies = (maps.max(1) as u64 * reducers.max(1) as u64) as f64;
        self.copy_setup_secs() + self.copy_secs(total_intermediate_mb / copies)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::JobType;

    fn cost(jt: JobType) -> TaskCost {
        let cfg = SimConfig {
            jitter_std: 0.0,
            ..SimConfig::paper()
        };
        TaskCost::new(&cfg, &JobSpec::new(jt, 640.0))
    }

    #[test]
    fn local_faster_than_remote() {
        let c = cost(JobType::WordCount);
        let mut rng = Rng::new(1);
        let local = c.map_secs(64.0, true, &mut rng);
        let remote = c.map_secs(64.0, false, &mut rng);
        assert!(remote > local, "{remote} <= {local}");
        // The gap is the paper's motivation: remote adds ~block/net time.
        assert!((remote - local) > 0.3);
    }

    #[test]
    fn map_secs_at_matches_bool_variant() {
        // The tiered entry point with NIC bandwidth must equal the legacy
        // remote path draw-for-draw (the flat byte-identity contract).
        let c = cost(JobType::Sort);
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        for _ in 0..50 {
            let legacy = c.map_secs(64.0, false, &mut r1);
            let tiered = c.map_secs_at(64.0, 10.0, &mut r2);
            assert_eq!(legacy.to_bits(), tiered.to_bits());
        }
        // A throttled cross-rack share is strictly slower.
        let mut r = Rng::new(1);
        let full = c.map_secs_at(64.0, 10.0, &mut r);
        let mut r = Rng::new(1);
        let contended = c.map_secs_at(64.0, 2.5, &mut r);
        assert!(contended > full);
    }

    #[test]
    fn map_time_scales_with_block() {
        let c = cost(JobType::Sort);
        let mut rng = Rng::new(2);
        let t64 = c.map_secs(64.0, true, &mut rng);
        let t32 = c.map_secs(32.0, true, &mut rng);
        assert!((t64 / t32 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn reduce_cost_grows_with_shuffle_volume() {
        let c = cost(JobType::PermutationGenerator);
        let mut rng = Rng::new(3);
        let small = c.reduce_secs(100.0, 10, 4, &mut rng);
        let big = c.reduce_secs(1000.0, 10, 4, &mut rng);
        assert!(big > small * 5.0);
    }

    #[test]
    fn jitter_bounded() {
        let cfg = SimConfig {
            jitter_std: 0.3,
            ..SimConfig::paper()
        };
        let c = TaskCost::new(&cfg, &JobSpec::new(JobType::Grep, 64.0));
        let mut rng = Rng::new(4);
        let base = 64.0 / 400.0 + 64.0 / JobType::Grep.cost_model().map_mb_per_s;
        for _ in 0..200 {
            let t = c.map_secs(64.0, true, &mut rng);
            assert!(t >= base * 0.6 - 1e-9 && t <= base * 1.8 + 1e-9);
        }
    }

    #[test]
    fn straggler_multiplier_distribution_sane() {
        let fm = crate::config::FailureModel {
            straggler_prob: 1.0, // always a straggler
            straggler_alpha: 1.5,
            straggler_cap: 8.0,
            ..crate::config::FailureModel::off()
        };
        let mut rng = Rng::new(11);
        let mut above_one = 0usize;
        for _ in 0..500 {
            let m = straggler_multiplier(&fm, &mut rng);
            assert!((1.0..=8.0).contains(&m));
            if m > 1.0 {
                above_one += 1;
            }
        }
        // A Pareto draw is > 1 almost surely.
        assert!(above_one > 450);
        // prob < 1 stragglers are rarer but still slow.
        let fm = crate::config::FailureModel::stragglers();
        let mut rng = Rng::new(12);
        let slow = (0..2000)
            .filter(|_| straggler_multiplier(&fm, &mut rng) > 1.0)
            .count();
        assert!(slow > 50 && slow < 500, "got {slow} stragglers of 2000");
    }

    #[test]
    fn t_shuffle_estimate_positive() {
        let c = cost(JobType::Sort);
        let ts = c.t_shuffle_estimate(640.0, 10, 8);
        assert!(ts > 0.0 && ts < 10.0);
    }
}
