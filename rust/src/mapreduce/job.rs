//! Per-job state: task tables, phase machine, locality index, statistics.
//!
//! # Pending-task cursors (the scheduler hot path)
//!
//! Every scheduler asks, many times per heartbeat, "first pending map in
//! this node's locality list / this rack's list / block order" and "first
//! pending reduce". A plain filter-scan re-walks the finished prefix of
//! each list on every query, which is O(tasks) per query and O(jobs ×
//! tasks) per heartbeat once the cluster is saturated. Each list therefore
//! carries a *lazily-pruned cursor* ([`Cell<u32>`]): the position of the
//! first possibly-pending entry. A query advances the cursor past leading
//! non-pending entries (each entry is passed at most once over the job's
//! life, so queries are O(1) amortized) and scans only from there.
//!
//! Invariant: **every entry before a cursor is non-pending.** Pending-ness
//! is monotone except for one transition — `AwaitingReconfig -> Pending`
//! when a delayed launch is cancelled — so [`JobState::mark_map_await_cancelled`]
//! rolls the affected cursors back to the cancelled task's position
//! (binary search; the lists are in ascending task order). The pruning is
//! memoization only: cursor-accelerated iterators yield exactly the same
//! task order as the retained `*_scan` variants, which the differential
//! reference tests (`tests/differential_reference.rs`) pin down.

use std::cell::Cell;

use crate::cluster::{Cluster, LocalityTier, NodeId};
use crate::config::SimConfig;
use crate::hdfs::{FileId, NameNode};
use crate::predictor::JobStats;
use crate::sim::SimTime;
use crate::util::codec::{Dec, Enc};
use crate::util::Rng;
use crate::workloads::{JobSpec, JobType, ALL_JOB_TYPES};

use super::task::{SpecAttempt, TaskId, TaskRef, TaskState};

/// Job index in submission order.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct JobId(pub u32);

impl JobId {
    #[inline]
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

/// Coarse job phase (paper: map phase dominates locality concerns; reduce
/// tasks start once the map phase completes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobPhase {
    MapPhase,
    ReducePhase,
    Done,
}

/// Everything the JobTracker knows about one job.
#[derive(Clone, Debug)]
pub struct JobState {
    pub id: JobId,
    pub spec: JobSpec,
    pub input_file: FileId,
    pub submitted: SimTime,
    pub phase: JobPhase,

    maps: Vec<TaskState>,
    reduces: Vec<TaskState>,
    /// node -> indices of map tasks whose block is replicated there.
    locality: Vec<Vec<u32>>,
    /// rack -> indices of map tasks with >= 1 replica in that rack (the
    /// rack-tier analogue of `locality`; all-empty under the flat
    /// topology, where no rack tier exists).
    rack_locality: Vec<Vec<u32>>,
    /// map task -> nodes holding its block (inverse of `locality`,
    /// precomputed — the Alg. 1 target scan is on the heartbeat hot path
    /// and rebuilding it per query was ~50% of the scheduler profile).
    replicas: Vec<Vec<NodeId>>,
    /// Per-map-task block size (tail block may be smaller).
    pub block_mb: Vec<f64>,

    /// Lazily-pruned pending cursors (see module docs): first possibly-
    /// pending position in, respectively, each `locality[node]` list,
    /// each `rack_locality[rack]` list, the dense map array and the dense
    /// reduce array. Interior mutability because pruning happens during
    /// `&self` queries on the scheduler's immutable view; a `World` is
    /// never shared across threads (the purity contract keeps every run's
    /// state thread-private), so `Cell` is safe here.
    local_cursors: Vec<Cell<u32>>,
    rack_cursors: Vec<Cell<u32>>,
    map_cursor: Cell<u32>,
    reduce_cursor: Cell<u32>,

    pending_map_count: u32,
    running_map_count: u32,
    finished_map_count: u32,
    awaiting_map_count: u32,
    pending_reduce_count: u32,
    running_reduce_count: u32,
    finished_reduce_count: u32,

    /// Per-task attempt epochs, incremented on every (re)launch. A
    /// `MapDone`/`ReduceDone` whose attempt doesn't match the current
    /// epoch (primary or live spec) is stale — the attempt was killed by
    /// a PM crash or lost a speculation race — and the coordinator drops
    /// it. With failures off each task launches exactly once, every
    /// event matches, and behavior is identical to the pre-epoch code.
    map_attempt: Vec<u32>,
    reduce_attempt: Vec<u32>,
    /// Live speculative (backup) map copies — at most one per task, only
    /// while the primary is Running.
    specs: Vec<Option<SpecAttempt>>,
    /// Live speculative reduce copies (same one-per-task rule).
    reduce_specs: Vec<Option<SpecAttempt>>,
    /// Count of live spec copies, map and reduce together (cheap queries
    /// + invariants).
    spec_live: u32,

    /// Tiered locality accounting (finished map tasks only): node-local,
    /// rack-local and off-rack counts. `rack_maps` is always 0 under the
    /// flat topology, collapsing to the seed's binary split.
    pub local_maps: u32,
    pub rack_maps: u32,
    pub remote_maps: u32,

    /// Online Eq. 1 statistics.
    pub stats: JobStats,
    /// Latest Eq. 10 answer (the scheduler's concurrency caps).
    pub alloc_map_slots: u32,
    pub alloc_reduce_slots: u32,

    finished_at: Option<SimTime>,
    map_phase_finished_at: Option<SimTime>,
}

impl JobState {
    /// Register the job: create its HDFS input file and task tables.
    pub fn create(
        id: JobId,
        spec: JobSpec,
        cfg: &SimConfig,
        nn: &mut NameNode,
        rng: &mut Rng,
        now: SimTime,
    ) -> Self {
        let node_racks = cfg.node_racks();
        let input_file = nn.create_file_placed(
            spec.input_mb,
            cfg.block_mb,
            cfg.replication,
            &node_racks,
            rng,
        );
        let blocks = nn.blocks(input_file);
        let n_maps = blocks.len().max(1);
        let block_mb: Vec<f64> = if blocks.is_empty() {
            vec![0.0]
        } else {
            blocks.iter().map(|b| b.size_mb).collect()
        };
        let locality = nn.locality_index(input_file, cfg.nodes());
        let mut replicas: Vec<Vec<NodeId>> = vec![Vec::with_capacity(cfg.replication); n_maps];
        for (node, tasks) in locality.iter().enumerate() {
            for &t in tasks {
                replicas[t as usize].push(NodeId(node as u32));
            }
        }
        // Rack index (racked topologies only): task t appears once per
        // rack holding >= 1 of its replicas, in task order per rack.
        let mut rack_locality: Vec<Vec<u32>> =
            vec![Vec::new(); cfg.topology.racks() as usize];
        if cfg.topology.is_racked() {
            for (t, reps) in replicas.iter().enumerate() {
                let mut racks: Vec<u32> =
                    reps.iter().map(|r| node_racks[r.idx()]).collect();
                racks.sort_unstable();
                racks.dedup();
                for rk in racks {
                    rack_locality[rk as usize].push(t as u32);
                }
            }
        }
        let n_reduces = spec.reducers as usize;
        Self {
            id,
            input_file,
            submitted: now,
            phase: JobPhase::MapPhase,
            replicas,
            maps: vec![TaskState::Pending; n_maps],
            reduces: vec![TaskState::Pending; n_reduces],
            map_attempt: vec![0; n_maps],
            reduce_attempt: vec![0; n_reduces],
            specs: vec![None; n_maps],
            reduce_specs: vec![None; n_reduces],
            spec_live: 0,
            local_cursors: vec![Cell::new(0); locality.len()],
            rack_cursors: vec![Cell::new(0); rack_locality.len()],
            map_cursor: Cell::new(0),
            reduce_cursor: Cell::new(0),
            locality,
            rack_locality,
            block_mb,
            pending_map_count: n_maps as u32,
            running_map_count: 0,
            finished_map_count: 0,
            awaiting_map_count: 0,
            pending_reduce_count: n_reduces as u32,
            running_reduce_count: 0,
            finished_reduce_count: 0,
            local_maps: 0,
            rack_maps: 0,
            remote_maps: 0,
            stats: JobStats::new(cfg.prior_map_s, cfg.prior_shuffle_s),
            alloc_map_slots: u32::MAX, // unconstrained until the predictor runs
            alloc_reduce_slots: u32::MAX,
            finished_at: None,
            map_phase_finished_at: None,
            spec,
        }
    }

    // ---- counters ----

    pub fn total_maps(&self) -> u32 {
        self.maps.len() as u32
    }
    pub fn total_reduces(&self) -> u32 {
        self.reduces.len() as u32
    }
    pub fn pending_maps(&self) -> u32 {
        self.pending_map_count
    }
    pub fn running_maps(&self) -> u32 {
        self.running_map_count
    }
    pub fn completed_maps(&self) -> u32 {
        self.finished_map_count
    }
    pub fn awaiting_maps(&self) -> u32 {
        self.awaiting_map_count
    }
    pub fn pending_reduces(&self) -> u32 {
        self.pending_reduce_count
    }
    pub fn running_reduces(&self) -> u32 {
        self.running_reduce_count
    }
    pub fn completed_reduces(&self) -> u32 {
        self.finished_reduce_count
    }

    /// Maps counted against the job's slot allocation (running + waiting
    /// on a hot-plug — they hold a claim on a slot-to-be).
    pub fn scheduled_maps(&self) -> u32 {
        self.running_map_count + self.awaiting_map_count
    }

    pub fn map_finished(&self) -> bool {
        self.finished_map_count == self.total_maps()
    }

    pub fn is_done(&self) -> bool {
        self.phase == JobPhase::Done
    }

    /// True before any task has completed or started (Alg. 2: such jobs
    /// take absolute precedence to bootstrap statistics).
    pub fn cold(&self) -> bool {
        self.stats.cold() && self.running_map_count == 0 && self.awaiting_map_count == 0
    }

    pub fn completion_time(&self) -> Option<SimTime> {
        self.finished_at.map(|t| t - self.submitted)
    }

    pub fn map_phase_duration(&self) -> Option<SimTime> {
        self.map_phase_finished_at.map(|t| t - self.submitted)
    }

    /// Absolute deadline instant (None = best effort).
    pub fn deadline_at(&self) -> Option<SimTime> {
        self.spec
            .deadline_s
            .map(|d| self.submitted + SimTime::from_secs_f64(d))
    }

    /// Did the job meet its deadline? (None when best-effort/unfinished.)
    pub fn met_deadline(&self) -> Option<bool> {
        match (self.finished_at, self.deadline_at()) {
            (Some(f), Some(d)) => Some(f <= d),
            _ => None,
        }
    }

    // ---- task selection ----

    /// Nodes holding task `m`'s input block (precomputed, O(1)).
    pub fn replica_nodes(&self, m: u32) -> &[NodeId] {
        &self.replicas[m as usize]
    }

    /// First pending map task whose block is local to `node`.
    pub fn next_pending_local_map(&self, node: NodeId) -> Option<TaskId> {
        self.pending_local_maps(node).next()
    }

    /// Advance `cursor` past the leading non-pending prefix of `list`
    /// (entries are map-task indices) and return the new position.
    /// Entries are passed at most once over the job's life (modulo the
    /// rare await-cancel rollback), so the amortized cost is O(1).
    fn advance_list_cursor(list: &[u32], cursor: &Cell<u32>, states: &[TaskState]) -> usize {
        let mut i = cursor.get() as usize;
        while i < list.len() && !states[list[i] as usize].is_pending() {
            i += 1;
        }
        cursor.set(i as u32);
        i
    }

    /// [`Self::advance_list_cursor`] for the dense task arrays, where the
    /// list is implicitly `0..states.len()`.
    fn advance_dense_cursor(cursor: &Cell<u32>, states: &[TaskState]) -> usize {
        let mut i = cursor.get() as usize;
        while i < states.len() && !states[i].is_pending() {
            i += 1;
        }
        cursor.set(i as u32);
        i
    }

    /// All pending map tasks local to `node`, in block order
    /// (cursor-accelerated; same order as [`Self::pending_local_maps_scan`]).
    pub fn pending_local_maps(&self, node: NodeId) -> impl Iterator<Item = TaskId> + '_ {
        let list = &self.locality[node.idx()];
        let start = Self::advance_list_cursor(list, &self.local_cursors[node.idx()], &self.maps);
        list[start..]
            .iter()
            .copied()
            .filter(|&m| self.maps[m as usize].is_pending())
            .map(TaskId)
    }

    /// All pending map tasks with a replica in `rack`, in block order
    /// (cursor-accelerated). Always empty under the flat topology (no
    /// rack index is built).
    pub fn pending_rack_maps(&self, rack: u32) -> impl Iterator<Item = TaskId> + '_ {
        let (list, start) = match self.rack_locality.get(rack as usize) {
            Some(list) => (
                list.as_slice(),
                Self::advance_list_cursor(list, &self.rack_cursors[rack as usize], &self.maps),
            ),
            None => (&[][..], 0),
        };
        list[start..]
            .iter()
            .copied()
            .filter(|&m| self.maps[m as usize].is_pending())
            .map(TaskId)
    }

    /// The naive filter-scan behind [`Self::pending_local_maps`] — the
    /// pre-index hot path, retained (with the other `*_scan` variants) as
    /// the reference the differential tests and `benches/simcore.rs`
    /// compare the cursors against. Never advances a cursor.
    pub fn pending_local_maps_scan(&self, node: NodeId) -> impl Iterator<Item = TaskId> + '_ {
        self.locality[node.idx()]
            .iter()
            .copied()
            .filter(|&m| self.maps[m as usize].is_pending())
            .map(TaskId)
    }

    /// Naive filter-scan behind [`Self::pending_rack_maps`].
    pub fn pending_rack_maps_scan(&self, rack: u32) -> impl Iterator<Item = TaskId> + '_ {
        self.rack_locality
            .get(rack as usize)
            .map(|v| v.as_slice())
            .unwrap_or(&[])
            .iter()
            .copied()
            .filter(|&m| self.maps[m as usize].is_pending())
            .map(TaskId)
    }

    /// Best achievable locality tier for map task `t` running on `node`:
    /// the minimum tier over the task's replica set.
    pub fn map_tier(&self, t: TaskId, node: NodeId, cluster: &Cluster) -> LocalityTier {
        self.replica_nodes(t.0)
            .iter()
            .map(|&r| cluster.tier(node, r))
            .min()
            .unwrap_or(LocalityTier::Remote)
    }

    /// Locality accounting shorthand: finished maps that were *not*
    /// node-local (rack-local + off-rack) — the seed metrics' "nonlocal"
    /// bucket.
    pub fn nonlocal_maps(&self) -> u32 {
        self.rack_maps + self.remote_maps
    }

    /// All pending map tasks, in block order (cursor-accelerated).
    pub fn pending_maps_iter(&self) -> impl Iterator<Item = TaskId> + '_ {
        let start = Self::advance_dense_cursor(&self.map_cursor, &self.maps);
        self.maps[start..]
            .iter()
            .enumerate()
            .filter(|(_, t)| t.is_pending())
            .map(move |(i, _)| TaskId((start + i) as u32))
    }

    /// All pending reduce tasks, in index order (cursor-accelerated). The
    /// reduce cursor is monotone except when a PM crash kills a running
    /// reduce ([`Self::mark_reduce_killed`] rolls it back).
    pub fn pending_reduces_iter(&self) -> impl Iterator<Item = TaskId> + '_ {
        let start = Self::advance_dense_cursor(&self.reduce_cursor, &self.reduces);
        self.reduces[start..]
            .iter()
            .enumerate()
            .filter(|(_, t)| t.is_pending())
            .map(move |(i, _)| TaskId((start + i) as u32))
    }

    /// Naive filter-scan behind [`Self::pending_maps_iter`].
    pub fn pending_maps_scan(&self) -> impl Iterator<Item = TaskId> + '_ {
        self.maps
            .iter()
            .enumerate()
            .filter(|(_, t)| t.is_pending())
            .map(|(i, _)| TaskId(i as u32))
    }

    /// Naive filter-scan behind [`Self::pending_reduces_iter`].
    pub fn pending_reduces_scan(&self) -> impl Iterator<Item = TaskId> + '_ {
        self.reduces
            .iter()
            .enumerate()
            .filter(|(_, t)| t.is_pending())
            .map(|(i, _)| TaskId(i as u32))
    }

    /// Any pending map task (first by index).
    pub fn next_pending_map_any(&self) -> Option<TaskId> {
        self.pending_maps_iter().next()
    }

    /// First pending reduce task.
    pub fn next_pending_reduce(&self) -> Option<TaskId> {
        self.pending_reduces_iter().next()
    }

    /// First pending reduce with index `>= from` — the incremental form
    /// of `pending_reduces_iter().nth(k)` the schedulers' reduce cursors
    /// build on (see `scheduler::ClaimLedger::claim_next_reduce`).
    pub fn next_pending_reduce_at(&self, from: u32) -> Option<TaskId> {
        let start = Self::advance_dense_cursor(&self.reduce_cursor, &self.reduces)
            .max(from as usize);
        self.reduces
            .get(start..)?
            .iter()
            .position(|t| t.is_pending())
            .map(|i| TaskId((start + i) as u32))
    }

    pub fn map_state(&self, t: TaskId) -> &TaskState {
        &self.maps[t.0 as usize]
    }

    pub fn reduce_state(&self, t: TaskId) -> &TaskState {
        &self.reduces[t.0 as usize]
    }

    // ---- transitions ----

    /// Is `t`'s input block replicated on `node`?
    pub fn map_is_local(&self, t: TaskId, node: NodeId) -> bool {
        self.locality[node.idx()].contains(&t.0)
    }

    /// AwaitingReconfig -> Pending (delayed launch abandoned). The one
    /// transition that makes a task pending *again*, so every cursor that
    /// may have passed it is rolled back to its position.
    pub fn mark_map_await_cancelled(&mut self, t: TaskId) {
        let s = &mut self.maps[t.0 as usize];
        debug_assert!(s.is_awaiting(), "cancelling non-awaiting map {t:?}");
        *s = TaskState::Pending;
        self.awaiting_map_count -= 1;
        self.pending_map_count += 1;
        self.rollback_cursors(t.0);
    }

    /// Restore the cursor invariant ("everything before a cursor is
    /// non-pending") after map task `t` returned to Pending. The locality
    /// and rack lists are in ascending task order, so the task's position
    /// in each list holding it is found by binary search; cursors only
    /// ever move back, never forward.
    fn rollback_cursors(&mut self, t: u32) {
        if t < self.map_cursor.get() {
            self.map_cursor.set(t);
        }
        for &node in &self.replicas[t as usize] {
            if let Ok(pos) = self.locality[node.idx()].binary_search(&t) {
                let cur = &self.local_cursors[node.idx()];
                if (pos as u32) < cur.get() {
                    cur.set(pos as u32);
                }
            }
        }
        for (rk, list) in self.rack_locality.iter().enumerate() {
            if let Ok(pos) = list.binary_search(&t) {
                let cur = &self.rack_cursors[rk];
                if (pos as u32) < cur.get() {
                    cur.set(pos as u32);
                }
            }
        }
    }

    /// Pending -> AwaitingReconfig (Alg. 1 delayed local launch).
    pub fn mark_map_awaiting(&mut self, t: TaskId, target: NodeId) {
        let s = &mut self.maps[t.0 as usize];
        debug_assert!(s.is_pending());
        *s = TaskState::AwaitingReconfig { target };
        self.pending_map_count -= 1;
        self.awaiting_map_count += 1;
    }

    /// Pending/Awaiting -> Running. Returns the new attempt epoch (the
    /// coordinator stamps it on the completion event so stale completions
    /// from killed attempts are droppable).
    pub fn mark_map_launched(
        &mut self,
        t: TaskId,
        node: NodeId,
        tier: LocalityTier,
        now: SimTime,
    ) -> u32 {
        let s = &mut self.maps[t.0 as usize];
        match *s {
            TaskState::Pending => self.pending_map_count -= 1,
            TaskState::AwaitingReconfig { .. } => self.awaiting_map_count -= 1,
            _ => panic!("launching map {t:?} twice (job {:?})", self.id),
        }
        *s = TaskState::Running {
            node,
            started: now,
            tier,
        };
        self.running_map_count += 1;
        self.map_attempt[t.0 as usize] += 1;
        self.map_attempt[t.0 as usize]
    }

    /// Running -> Finished; flips to ReducePhase when the last map lands.
    pub fn mark_map_finished(&mut self, t: TaskId, now: SimTime) {
        let s = &mut self.maps[t.0 as usize];
        let TaskState::Running {
            node,
            started,
            tier,
        } = *s
        else {
            panic!("finishing non-running map {t:?}");
        };
        *s = TaskState::Finished {
            node,
            started,
            finished: now,
            tier,
        };
        self.running_map_count -= 1;
        self.finished_map_count += 1;
        match tier {
            LocalityTier::NodeLocal => self.local_maps += 1,
            LocalityTier::RackLocal => self.rack_maps += 1,
            LocalityTier::Remote => self.remote_maps += 1,
        }
        self.stats.record_map(crate::predictor::TaskSample {
            duration_s: (now - started).as_secs_f64(),
        });
        if self.map_finished() && self.phase == JobPhase::MapPhase {
            self.phase = JobPhase::ReducePhase;
            self.map_phase_finished_at = Some(now);
        }
    }

    pub fn mark_reduce_launched(&mut self, t: TaskId, node: NodeId, now: SimTime) -> u32 {
        let s = &mut self.reduces[t.0 as usize];
        debug_assert!(s.is_pending(), "launching reduce {t:?} twice");
        *s = TaskState::Running {
            node,
            started: now,
            tier: LocalityTier::Remote,
        };
        self.pending_reduce_count -= 1;
        self.running_reduce_count += 1;
        self.reduce_attempt[t.0 as usize] += 1;
        self.reduce_attempt[t.0 as usize]
    }

    pub fn mark_reduce_finished(&mut self, t: TaskId, now: SimTime) {
        let s = &mut self.reduces[t.0 as usize];
        let TaskState::Running { node, started, .. } = *s else {
            panic!("finishing non-running reduce {t:?}");
        };
        *s = TaskState::Finished {
            node,
            started,
            finished: now,
            tier: LocalityTier::Remote,
        };
        self.running_reduce_count -= 1;
        self.finished_reduce_count += 1;
        self.stats.record_reduce(crate::predictor::TaskSample {
            duration_s: (now - started).as_secs_f64(),
        });
        if self.finished_reduce_count == self.total_reduces() {
            self.phase = JobPhase::Done;
            self.finished_at = Some(now);
        }
    }

    // ---- failure / speculation transitions ----

    /// Current primary attempt epoch of map task `t`.
    pub fn map_attempt(&self, t: TaskId) -> u32 {
        self.map_attempt[t.0 as usize]
    }

    /// Current attempt epoch of reduce task `t`.
    pub fn reduce_attempt(&self, t: TaskId) -> u32 {
        self.reduce_attempt[t.0 as usize]
    }

    /// The live speculative copy of map task `t`, if any.
    pub fn spec_of(&self, t: TaskId) -> Option<SpecAttempt> {
        self.specs[t.0 as usize]
    }

    /// Number of live speculative copies across the job.
    pub fn live_specs(&self) -> u32 {
        self.spec_live
    }

    /// Launch a speculative (backup) copy of a *running* map. Returns the
    /// spec's attempt epoch. Task-state counters don't move — the task is
    /// still one Running task; the spec only occupies an extra slot.
    pub fn begin_spec_map(
        &mut self,
        t: TaskId,
        node: NodeId,
        tier: LocalityTier,
        now: SimTime,
    ) -> u32 {
        debug_assert!(self.maps[t.0 as usize].is_running(), "spec on non-running map {t:?}");
        debug_assert!(self.specs[t.0 as usize].is_none(), "double spec on map {t:?}");
        self.map_attempt[t.0 as usize] += 1;
        let attempt = self.map_attempt[t.0 as usize];
        self.specs[t.0 as usize] = Some(SpecAttempt {
            attempt,
            node,
            started: now,
            tier,
        });
        self.spec_live += 1;
        attempt
    }

    /// Remove and return the live spec copy of `t` (the primary won the
    /// race, or the spec's node died). The caller frees the spec's slot.
    pub fn take_spec(&mut self, t: TaskId) -> Option<SpecAttempt> {
        let s = self.specs[t.0 as usize].take();
        if s.is_some() {
            self.spec_live -= 1;
        }
        s
    }

    /// The spec copy finished first: Running -> Finished with the *spec's*
    /// node/tier/start. Returns the losing primary's `(node, tier)` so the
    /// coordinator can free its slot. The spec becomes the finished
    /// attempt; the primary's in-flight completion is now stale.
    pub fn mark_map_spec_finished(&mut self, t: TaskId, now: SimTime) -> (NodeId, LocalityTier) {
        let spec = self.take_spec(t).expect("spec finish without live spec");
        let s = &mut self.maps[t.0 as usize];
        let TaskState::Running { node, tier, .. } = *s else {
            panic!("spec finish on non-running map {t:?}");
        };
        *s = TaskState::Finished {
            node: spec.node,
            started: spec.started,
            finished: now,
            tier: spec.tier,
        };
        self.running_map_count -= 1;
        self.finished_map_count += 1;
        match spec.tier {
            LocalityTier::NodeLocal => self.local_maps += 1,
            LocalityTier::RackLocal => self.rack_maps += 1,
            LocalityTier::Remote => self.remote_maps += 1,
        }
        // The winner's epoch becomes the task's finished attempt.
        self.map_attempt[t.0 as usize] = spec.attempt;
        self.stats.record_map(crate::predictor::TaskSample {
            duration_s: (now - spec.started).as_secs_f64(),
        });
        if self.map_finished() && self.phase == JobPhase::MapPhase {
            self.phase = JobPhase::ReducePhase;
            self.map_phase_finished_at = Some(now);
        }
        (node, tier)
    }

    /// A crashed PM killed the running primary of map `t`. If a live spec
    /// copy survives the caller should promote it instead
    /// ([`Self::promote_spec`]). Running -> Pending; the epoch advances on
    /// the next launch, so the dead attempt's completion event is stale.
    /// Returns the dead attempt's `(node, tier)`.
    pub fn mark_map_killed(&mut self, t: TaskId) -> (NodeId, LocalityTier) {
        let s = &mut self.maps[t.0 as usize];
        let TaskState::Running { node, tier, .. } = *s else {
            panic!("killing non-running map {t:?}");
        };
        *s = TaskState::Pending;
        self.running_map_count -= 1;
        self.pending_map_count += 1;
        self.rollback_cursors(t.0);
        (node, tier)
    }

    /// The primary died but a spec copy survives: the spec becomes the new
    /// primary (task stays Running, no re-execution needed). Returns the
    /// promoted attempt.
    pub fn promote_spec(&mut self, t: TaskId) -> SpecAttempt {
        let spec = self.take_spec(t).expect("promoting without live spec");
        let s = &mut self.maps[t.0 as usize];
        debug_assert!(s.is_running(), "promoting spec of non-running map {t:?}");
        *s = TaskState::Running {
            node: spec.node,
            started: spec.started,
            tier: spec.tier,
        };
        self.map_attempt[t.0 as usize] = spec.attempt;
        spec
    }

    /// The live speculative copy of reduce task `t`, if any.
    pub fn reduce_spec_of(&self, t: TaskId) -> Option<SpecAttempt> {
        self.reduce_specs[t.0 as usize]
    }

    /// Launch a speculative (backup) copy of a *running* reduce. Returns
    /// the spec's attempt epoch. Mirrors [`Self::begin_spec_map`]: task
    /// counters don't move, the spec only occupies an extra reduce slot.
    pub fn begin_spec_reduce(&mut self, t: TaskId, node: NodeId, now: SimTime) -> u32 {
        debug_assert!(
            self.reduces[t.0 as usize].is_running(),
            "spec on non-running reduce {t:?}"
        );
        debug_assert!(
            self.reduce_specs[t.0 as usize].is_none(),
            "double spec on reduce {t:?}"
        );
        self.reduce_attempt[t.0 as usize] += 1;
        let attempt = self.reduce_attempt[t.0 as usize];
        self.reduce_specs[t.0 as usize] = Some(SpecAttempt {
            attempt,
            node,
            started: now,
            tier: LocalityTier::Remote,
        });
        self.spec_live += 1;
        attempt
    }

    /// Remove and return the live spec copy of reduce `t` (the primary won
    /// the race, or the spec's node died). The caller frees the slot.
    pub fn take_reduce_spec(&mut self, t: TaskId) -> Option<SpecAttempt> {
        let s = self.reduce_specs[t.0 as usize].take();
        if s.is_some() {
            self.spec_live -= 1;
        }
        s
    }

    /// The spec copy of reduce `t` finished first: Running -> Finished
    /// with the spec's node/start. Returns the losing primary's node so
    /// the coordinator can free its slot.
    pub fn mark_reduce_spec_finished(&mut self, t: TaskId, now: SimTime) -> NodeId {
        let spec = self
            .take_reduce_spec(t)
            .expect("spec finish without live reduce spec");
        let s = &mut self.reduces[t.0 as usize];
        let TaskState::Running { node, .. } = *s else {
            panic!("spec finish on non-running reduce {t:?}");
        };
        *s = TaskState::Finished {
            node: spec.node,
            started: spec.started,
            finished: now,
            tier: LocalityTier::Remote,
        };
        self.running_reduce_count -= 1;
        self.finished_reduce_count += 1;
        // The winner's epoch becomes the task's finished attempt.
        self.reduce_attempt[t.0 as usize] = spec.attempt;
        self.stats.record_reduce(crate::predictor::TaskSample {
            duration_s: (now - spec.started).as_secs_f64(),
        });
        if self.finished_reduce_count == self.total_reduces() {
            self.phase = JobPhase::Done;
            self.finished_at = Some(now);
        }
        node
    }

    /// The primary reduce died but a spec copy survives: the spec becomes
    /// the new primary (task stays Running, no re-execution needed).
    pub fn promote_reduce_spec(&mut self, t: TaskId) -> SpecAttempt {
        let spec = self
            .take_reduce_spec(t)
            .expect("promoting without live reduce spec");
        let s = &mut self.reduces[t.0 as usize];
        debug_assert!(s.is_running(), "promoting spec of non-running reduce {t:?}");
        *s = TaskState::Running {
            node: spec.node,
            started: spec.started,
            tier: LocalityTier::Remote,
        };
        self.reduce_attempt[t.0 as usize] = spec.attempt;
        spec
    }

    /// A crashed PM held the *output* of finished map `t` while the job is
    /// still in its map phase (Hadoop loses un-shuffled map output with
    /// the TaskTracker): Finished -> Pending for re-execution. Undoes the
    /// tier accounting; the recorded duration sample stays (it measured a
    /// real execution).
    pub fn mark_map_output_lost(&mut self, t: TaskId) {
        debug_assert_eq!(self.phase, JobPhase::MapPhase, "output loss after map phase");
        let s = &mut self.maps[t.0 as usize];
        let TaskState::Finished { tier, .. } = *s else {
            panic!("output loss on non-finished map {t:?}");
        };
        *s = TaskState::Pending;
        self.finished_map_count -= 1;
        self.pending_map_count += 1;
        match tier {
            LocalityTier::NodeLocal => self.local_maps -= 1,
            LocalityTier::RackLocal => self.rack_maps -= 1,
            LocalityTier::Remote => self.remote_maps -= 1,
        }
        self.rollback_cursors(t.0);
    }

    /// A crashed PM killed running reduce `t`: Running -> Pending. If a
    /// live spec copy survives the caller should promote it instead
    /// ([`Self::promote_reduce_spec`]). This is the one transition that
    /// rolls the reduce cursor back (reduces are otherwise strictly
    /// monotone). Returns the dead attempt's node.
    pub fn mark_reduce_killed(&mut self, t: TaskId) -> NodeId {
        let s = &mut self.reduces[t.0 as usize];
        let TaskState::Running { node, .. } = *s else {
            panic!("killing non-running reduce {t:?}");
        };
        *s = TaskState::Pending;
        self.running_reduce_count -= 1;
        self.pending_reduce_count += 1;
        if t.0 < self.reduce_cursor.get() {
            self.reduce_cursor.set(t.0);
        }
        node
    }

    /// Sanity invariant for the property tests.
    pub fn check_invariants(&self) -> Result<(), String> {
        let m = self.pending_map_count
            + self.running_map_count
            + self.finished_map_count
            + self.awaiting_map_count;
        if m != self.total_maps() {
            return Err(format!("job {:?}: map counters {m} != {}", self.id, self.total_maps()));
        }
        let r = self.pending_reduce_count + self.running_reduce_count + self.finished_reduce_count;
        if r != self.total_reduces() {
            return Err(format!(
                "job {:?}: reduce counters {r} != {}",
                self.id,
                self.total_reduces()
            ));
        }
        if self.local_maps + self.rack_maps + self.remote_maps != self.finished_map_count {
            return Err(format!("job {:?}: locality accounting broken", self.id));
        }
        let live = self
            .specs
            .iter()
            .chain(&self.reduce_specs)
            .filter(|s| s.is_some())
            .count() as u32;
        if live != self.spec_live {
            return Err(format!("job {:?}: spec_live {} != {live}", self.id, self.spec_live));
        }
        for (i, spec) in self.specs.iter().enumerate() {
            if spec.is_some() && !self.maps[i].is_running() {
                return Err(format!(
                    "job {:?}: spec copy of non-running map {i}",
                    self.id
                ));
            }
        }
        for (i, spec) in self.reduce_specs.iter().enumerate() {
            if spec.is_some() && !self.reduces[i].is_running() {
                return Err(format!(
                    "job {:?}: spec copy of non-running reduce {i}",
                    self.id
                ));
            }
        }
        // Cursor invariant: nothing before a pending cursor is pending
        // (otherwise the indexed iterators would silently skip tasks).
        if self.maps[..self.map_cursor.get() as usize]
            .iter()
            .any(|s| s.is_pending())
        {
            return Err(format!("job {:?}: map cursor passed a pending task", self.id));
        }
        if self.reduces[..self.reduce_cursor.get() as usize]
            .iter()
            .any(|s| s.is_pending())
        {
            return Err(format!("job {:?}: reduce cursor passed a pending task", self.id));
        }
        for (lists, cursors, what) in [
            (&self.locality, &self.local_cursors, "locality"),
            (&self.rack_locality, &self.rack_cursors, "rack"),
        ] {
            for (list, cursor) in lists.iter().zip(cursors) {
                if list[..cursor.get() as usize]
                    .iter()
                    .any(|&m| self.maps[m as usize].is_pending())
                {
                    return Err(format!(
                        "job {:?}: {what} cursor passed a pending task",
                        self.id
                    ));
                }
            }
        }
        Ok(())
    }

    /// Task handle helpers.
    pub fn map_ref(&self, t: TaskId) -> TaskRef {
        TaskRef::map(self.id, t.0)
    }

    pub fn reduce_ref(&self, t: TaskId) -> TaskRef {
        TaskRef::reduce(self.id, t.0)
    }
}

// ---- snapshot codec (docs/EVENT_LOG.md) ----
//
// Every field is serialized, including the derived locality/replica
// indexes and the lazily-pruned cursors: rebuilding them would be
// possible (they are functions of NameNode state), but carrying them
// verbatim keeps the restored `JobState` *bit-identical* to the
// original, which is what the snapshot/resume byte-identity tests pin.

pub(crate) fn enc_time(e: &mut Enc, t: SimTime) {
    e.u64(t.0);
}

pub(crate) fn dec_time(d: &mut Dec) -> Result<SimTime, String> {
    Ok(SimTime(d.u64()?))
}

pub(crate) fn enc_opt_time(e: &mut Enc, t: Option<SimTime>) {
    match t {
        None => e.bool(false),
        Some(t) => {
            e.bool(true);
            enc_time(e, t);
        }
    }
}

pub(crate) fn dec_opt_time(d: &mut Dec) -> Result<Option<SimTime>, String> {
    Ok(if d.bool()? { Some(dec_time(d)?) } else { None })
}

pub(crate) fn enc_tier(e: &mut Enc, t: LocalityTier) {
    e.u8(match t {
        LocalityTier::NodeLocal => 0,
        LocalityTier::RackLocal => 1,
        LocalityTier::Remote => 2,
    });
}

pub(crate) fn dec_tier(d: &mut Dec) -> Result<LocalityTier, String> {
    Ok(match d.u8()? {
        0 => LocalityTier::NodeLocal,
        1 => LocalityTier::RackLocal,
        2 => LocalityTier::Remote,
        b => return Err(format!("invalid locality tier tag {b}")),
    })
}

fn enc_task_state(e: &mut Enc, s: &TaskState) {
    match *s {
        TaskState::Pending => e.u8(0),
        TaskState::AwaitingReconfig { target } => {
            e.u8(1);
            e.u32(target.0);
        }
        TaskState::Running {
            node,
            started,
            tier,
        } => {
            e.u8(2);
            e.u32(node.0);
            enc_time(e, started);
            enc_tier(e, tier);
        }
        TaskState::Finished {
            node,
            started,
            finished,
            tier,
        } => {
            e.u8(3);
            e.u32(node.0);
            enc_time(e, started);
            enc_time(e, finished);
            enc_tier(e, tier);
        }
    }
}

fn dec_task_state(d: &mut Dec) -> Result<TaskState, String> {
    Ok(match d.u8()? {
        0 => TaskState::Pending,
        1 => TaskState::AwaitingReconfig {
            target: NodeId(d.u32()?),
        },
        2 => TaskState::Running {
            node: NodeId(d.u32()?),
            started: dec_time(d)?,
            tier: dec_tier(d)?,
        },
        3 => TaskState::Finished {
            node: NodeId(d.u32()?),
            started: dec_time(d)?,
            finished: dec_time(d)?,
            tier: dec_tier(d)?,
        },
        b => return Err(format!("invalid task-state tag {b}")),
    })
}

fn enc_u32_list(e: &mut Enc, v: &[u32]) {
    e.usize(v.len());
    for &x in v {
        e.u32(x);
    }
}

fn dec_u32_list(d: &mut Dec) -> Result<Vec<u32>, String> {
    let n = d.len(4)?;
    (0..n).map(|_| d.u32()).collect()
}

fn enc_nested_u32(e: &mut Enc, v: &[Vec<u32>]) {
    e.usize(v.len());
    for list in v {
        enc_u32_list(e, list);
    }
}

fn dec_nested_u32(d: &mut Dec) -> Result<Vec<Vec<u32>>, String> {
    let n = d.len(8)?;
    (0..n).map(|_| dec_u32_list(d)).collect()
}

fn job_type_tag(t: JobType) -> u8 {
    match t {
        JobType::WordCount => 0,
        JobType::Sort => 1,
        JobType::Grep => 2,
        JobType::PermutationGenerator => 3,
        JobType::InvertedIndex => 4,
    }
}

pub(crate) fn encode_job_spec(e: &mut Enc, s: &JobSpec) {
    e.u8(job_type_tag(s.job_type));
    e.f64(s.input_mb);
    e.u32(s.reducers);
    match s.deadline_s {
        None => e.bool(false),
        Some(dl) => {
            e.bool(true);
            e.f64(dl);
        }
    }
    e.f64(s.submit_s);
}

pub(crate) fn decode_job_spec(d: &mut Dec) -> Result<JobSpec, String> {
    let tag = d.u8()? as usize;
    let job_type = *ALL_JOB_TYPES
        .get(tag)
        .ok_or_else(|| format!("invalid job-type tag {tag}"))?;
    debug_assert_eq!(job_type_tag(job_type) as usize, tag);
    let input_mb = d.f64()?;
    let reducers = d.u32()?;
    let deadline_s = if d.bool()? { Some(d.f64()?) } else { None };
    let submit_s = d.f64()?;
    Ok(JobSpec {
        job_type,
        input_mb,
        reducers,
        deadline_s,
        submit_s,
    })
}

fn enc_spec_attempt(e: &mut Enc, s: &SpecAttempt) {
    e.u32(s.attempt);
    e.u32(s.node.0);
    enc_time(e, s.started);
    enc_tier(e, s.tier);
}

fn dec_spec_attempt(d: &mut Dec) -> Result<SpecAttempt, String> {
    Ok(SpecAttempt {
        attempt: d.u32()?,
        node: NodeId(d.u32()?),
        started: dec_time(d)?,
        tier: dec_tier(d)?,
    })
}

fn enc_spec_list(e: &mut Enc, v: &[Option<SpecAttempt>]) {
    e.usize(v.len());
    for s in v {
        match s {
            None => e.bool(false),
            Some(sp) => {
                e.bool(true);
                enc_spec_attempt(e, sp);
            }
        }
    }
}

fn dec_spec_list(d: &mut Dec) -> Result<Vec<Option<SpecAttempt>>, String> {
    let n = d.len(1)?;
    (0..n)
        .map(|_| {
            Ok(if d.bool()? {
                Some(dec_spec_attempt(d)?)
            } else {
                None
            })
        })
        .collect()
}

impl JobState {
    /// Serialize the full job state, field for field, in declaration order.
    pub(crate) fn encode(&self, e: &mut Enc) {
        e.u32(self.id.0);
        encode_job_spec(e, &self.spec);
        e.u32(self.input_file.0);
        enc_time(e, self.submitted);
        e.u8(match self.phase {
            JobPhase::MapPhase => 0,
            JobPhase::ReducePhase => 1,
            JobPhase::Done => 2,
        });
        e.usize(self.maps.len());
        for s in &self.maps {
            enc_task_state(e, s);
        }
        e.usize(self.reduces.len());
        for s in &self.reduces {
            enc_task_state(e, s);
        }
        enc_nested_u32(e, &self.locality);
        enc_nested_u32(e, &self.rack_locality);
        e.usize(self.replicas.len());
        for reps in &self.replicas {
            e.usize(reps.len());
            for n in reps {
                e.u32(n.0);
            }
        }
        e.usize(self.block_mb.len());
        for &mb in &self.block_mb {
            e.f64(mb);
        }
        e.usize(self.local_cursors.len());
        for c in &self.local_cursors {
            e.u32(c.get());
        }
        e.usize(self.rack_cursors.len());
        for c in &self.rack_cursors {
            e.u32(c.get());
        }
        e.u32(self.map_cursor.get());
        e.u32(self.reduce_cursor.get());
        e.u32(self.pending_map_count);
        e.u32(self.running_map_count);
        e.u32(self.finished_map_count);
        e.u32(self.awaiting_map_count);
        e.u32(self.pending_reduce_count);
        e.u32(self.running_reduce_count);
        e.u32(self.finished_reduce_count);
        enc_u32_list(e, &self.map_attempt);
        enc_u32_list(e, &self.reduce_attempt);
        enc_spec_list(e, &self.specs);
        enc_spec_list(e, &self.reduce_specs);
        e.u32(self.spec_live);
        e.u32(self.local_maps);
        e.u32(self.rack_maps);
        e.u32(self.remote_maps);
        let (mc, ms, rc, rs, sc, ss, pm, ps) = self.stats.raw();
        e.u64(mc);
        e.f64(ms);
        e.u64(rc);
        e.f64(rs);
        e.u64(sc);
        e.f64(ss);
        e.f64(pm);
        e.f64(ps);
        e.u32(self.alloc_map_slots);
        e.u32(self.alloc_reduce_slots);
        enc_opt_time(e, self.finished_at);
        enc_opt_time(e, self.map_phase_finished_at);
    }

    /// Inverse of [`Self::encode`]; bit-identical round trip.
    pub(crate) fn decode(d: &mut Dec) -> Result<Self, String> {
        let id = JobId(d.u32()?);
        let spec = decode_job_spec(d)?;
        let input_file = FileId(d.u32()?);
        let submitted = dec_time(d)?;
        let phase = match d.u8()? {
            0 => JobPhase::MapPhase,
            1 => JobPhase::ReducePhase,
            2 => JobPhase::Done,
            b => return Err(format!("invalid job-phase tag {b}")),
        };
        let n_maps = d.len(1)?;
        let maps: Vec<TaskState> = (0..n_maps)
            .map(|_| dec_task_state(d))
            .collect::<Result<_, _>>()?;
        let n_reduces = d.len(1)?;
        let reduces: Vec<TaskState> = (0..n_reduces)
            .map(|_| dec_task_state(d))
            .collect::<Result<_, _>>()?;
        let locality = dec_nested_u32(d)?;
        let rack_locality = dec_nested_u32(d)?;
        let n_rep = d.len(8)?;
        let replicas: Vec<Vec<NodeId>> = (0..n_rep)
            .map(|_| {
                let k = d.len(4)?;
                (0..k).map(|_| Ok(NodeId(d.u32()?))).collect()
            })
            .collect::<Result<_, String>>()?;
        let n_blocks = d.len(8)?;
        let block_mb: Vec<f64> = (0..n_blocks).map(|_| d.f64()).collect::<Result<_, _>>()?;
        let n_lc = d.len(4)?;
        let local_cursors: Vec<Cell<u32>> = (0..n_lc)
            .map(|_| Ok(Cell::new(d.u32()?)))
            .collect::<Result<_, String>>()?;
        let n_rc = d.len(4)?;
        let rack_cursors: Vec<Cell<u32>> = (0..n_rc)
            .map(|_| Ok(Cell::new(d.u32()?)))
            .collect::<Result<_, String>>()?;
        let map_cursor = Cell::new(d.u32()?);
        let reduce_cursor = Cell::new(d.u32()?);
        let pending_map_count = d.u32()?;
        let running_map_count = d.u32()?;
        let finished_map_count = d.u32()?;
        let awaiting_map_count = d.u32()?;
        let pending_reduce_count = d.u32()?;
        let running_reduce_count = d.u32()?;
        let finished_reduce_count = d.u32()?;
        let map_attempt = dec_u32_list(d)?;
        let reduce_attempt = dec_u32_list(d)?;
        let specs = dec_spec_list(d)?;
        let reduce_specs = dec_spec_list(d)?;
        let spec_live = d.u32()?;
        let local_maps = d.u32()?;
        let rack_maps = d.u32()?;
        let remote_maps = d.u32()?;
        let mc = d.u64()?;
        let ms = d.f64()?;
        let rc = d.u64()?;
        let rs = d.f64()?;
        let sc = d.u64()?;
        let ss = d.f64()?;
        let pm = d.f64()?;
        let ps = d.f64()?;
        let stats = JobStats::from_raw(mc, ms, rc, rs, sc, ss, pm, ps);
        let alloc_map_slots = d.u32()?;
        let alloc_reduce_slots = d.u32()?;
        let finished_at = dec_opt_time(d)?;
        let map_phase_finished_at = dec_opt_time(d)?;
        let job = Self {
            id,
            spec,
            input_file,
            submitted,
            phase,
            maps,
            reduces,
            locality,
            rack_locality,
            replicas,
            block_mb,
            local_cursors,
            rack_cursors,
            map_cursor,
            reduce_cursor,
            pending_map_count,
            running_map_count,
            finished_map_count,
            awaiting_map_count,
            pending_reduce_count,
            running_reduce_count,
            finished_reduce_count,
            map_attempt,
            reduce_attempt,
            specs,
            reduce_specs,
            spec_live,
            local_maps,
            rack_maps,
            remote_maps,
            stats,
            alloc_map_slots,
            alloc_reduce_slots,
            finished_at,
            map_phase_finished_at,
        };
        job.check_invariants()?;
        Ok(job)
    }
}
