//! Online task statistics per job (paper Eq. 1-3).
//!
//! `mu_m = (1/|C|) * sum(t_m)` over completed map tasks; until reduce
//! samples exist, `t_r = t_m` (homogeneous-cluster assumption, Eq. 3).
//! Shuffle copy time `t_s` is tracked from observed copy operations.

/// One completed task observation.
#[derive(Clone, Copy, Debug)]
pub struct TaskSample {
    pub duration_s: f64,
}

/// Rolling per-job statistics feeding the predictor.
#[derive(Clone, Debug, Default)]
pub struct JobStats {
    map_count: u64,
    map_sum: f64,
    reduce_count: u64,
    reduce_sum: f64,
    shuffle_count: u64,
    shuffle_sum: f64,
    /// Prior used before any map task completes (the paper runs an initial
    /// task wave to seed this; the scheduler gives brand-new jobs priority).
    prior_map_s: f64,
    prior_shuffle_s: f64,
}

impl JobStats {
    pub fn new(prior_map_s: f64, prior_shuffle_s: f64) -> Self {
        Self {
            prior_map_s,
            prior_shuffle_s,
            ..Default::default()
        }
    }

    pub fn record_map(&mut self, s: TaskSample) {
        self.map_count += 1;
        self.map_sum += s.duration_s;
    }

    pub fn record_reduce(&mut self, s: TaskSample) {
        self.reduce_count += 1;
        self.reduce_sum += s.duration_s;
    }

    pub fn record_shuffle_copy(&mut self, s: TaskSample) {
        self.shuffle_count += 1;
        self.shuffle_sum += s.duration_s;
    }

    /// Eq. 1: mean completed map-task duration (prior until |C| > 0).
    pub fn t_map(&self) -> f64 {
        if self.map_count == 0 {
            self.prior_map_s
        } else {
            self.map_sum / self.map_count as f64
        }
    }

    /// Eq. 3: reduce time equals map time until reduce data exists.
    pub fn t_reduce(&self) -> f64 {
        if self.reduce_count == 0 {
            self.t_map()
        } else {
            self.reduce_sum / self.reduce_count as f64
        }
    }

    /// Mean per-copy shuffle time.
    pub fn t_shuffle(&self) -> f64 {
        if self.shuffle_count == 0 {
            self.prior_shuffle_s
        } else {
            self.shuffle_sum / self.shuffle_count as f64
        }
    }

    /// True before the first map completion — the scheduler gives such
    /// jobs absolute priority (Alg. 2 preamble: "jobs with no completed or
    /// running tasks always take precedence").
    pub fn cold(&self) -> bool {
        self.map_count == 0
    }

    pub fn completed_maps(&self) -> u64 {
        self.map_count
    }

    pub fn completed_reduces(&self) -> u64 {
        self.reduce_count
    }

    /// Raw accumulator state for snapshot encoding, in field order:
    /// `(map_count, map_sum, reduce_count, reduce_sum, shuffle_count,
    /// shuffle_sum, prior_map_s, prior_shuffle_s)`.
    #[allow(clippy::type_complexity)]
    pub(crate) fn raw(&self) -> (u64, f64, u64, f64, u64, f64, f64, f64) {
        (
            self.map_count,
            self.map_sum,
            self.reduce_count,
            self.reduce_sum,
            self.shuffle_count,
            self.shuffle_sum,
            self.prior_map_s,
            self.prior_shuffle_s,
        )
    }

    /// Rebuild from a [`Self::raw`] capture (snapshot decoding).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn from_raw(
        map_count: u64,
        map_sum: f64,
        reduce_count: u64,
        reduce_sum: f64,
        shuffle_count: u64,
        shuffle_sum: f64,
        prior_map_s: f64,
        prior_shuffle_s: f64,
    ) -> Self {
        Self {
            map_count,
            map_sum,
            reduce_count,
            reduce_sum,
            shuffle_count,
            shuffle_sum,
            prior_map_s,
            prior_shuffle_s,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prior_then_mean() {
        let mut st = JobStats::new(10.0, 0.5);
        assert!(st.cold());
        assert_eq!(st.t_map(), 10.0);
        assert_eq!(st.t_reduce(), 10.0);
        st.record_map(TaskSample { duration_s: 4.0 });
        st.record_map(TaskSample { duration_s: 6.0 });
        assert!(!st.cold());
        assert_eq!(st.t_map(), 5.0);
        // Eq. 3: reduce mirrors map until reduce samples arrive.
        assert_eq!(st.t_reduce(), 5.0);
        st.record_reduce(TaskSample { duration_s: 9.0 });
        assert_eq!(st.t_reduce(), 9.0);
    }

    #[test]
    fn shuffle_tracking() {
        let mut st = JobStats::new(10.0, 0.25);
        assert_eq!(st.t_shuffle(), 0.25);
        st.record_shuffle_copy(TaskSample { duration_s: 0.1 });
        st.record_shuffle_copy(TaskSample { duration_s: 0.3 });
        assert!((st.t_shuffle() - 0.2).abs() < 1e-12);
    }
}
