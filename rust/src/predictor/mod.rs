//! Resource Estimation Model (paper §2.1).
//!
//! Tracks per-job task statistics online (Eq. 1) and answers the two
//! questions the scheduler asks on every heartbeat:
//!
//! * Eq. 10 — minimum `(n_m, n_r)` slots so job `j` finishes by deadline `D`;
//! * Eq. 7  — estimated remaining completion time (ETA) and slack.
//!
//! Two interchangeable backends implement the math:
//! [`NativePredictor`] (pure Rust, always available, used by unit tests and
//! as the cross-check oracle) and [`crate::runtime::XlaPredictor`] (the AOT
//! JAX/Pallas artifact executed via PJRT — the production hot path; one
//! batched call per heartbeat). `rust/tests/artifact_roundtrip.rs` asserts
//! they agree to 1e-4.

mod stats_tracker;

pub use stats_tracker::{JobStats, TaskSample};

/// Inputs to the Eq. 10 solver for one job, in the paper's symbols.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct JobDemand {
    /// `u_m` — total map tasks.
    pub map_tasks: f64,
    /// `v_r` — total reduce tasks.
    pub reduce_tasks: f64,
    /// `t_m` — estimated map task duration (seconds, Eq. 1).
    pub t_map: f64,
    /// `t_r` — estimated reduce task duration (= `t_m` under Eq. 3 until
    /// reduce samples exist).
    pub t_reduce: f64,
    /// `t_s` — per-copy shuffle time (seconds).
    pub t_shuffle: f64,
    /// `D` — remaining time until the deadline (seconds).
    pub deadline: f64,
}

/// Eq. 10 output: the minimal integral slot allocation.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SlotDemand {
    pub map_slots: u32,
    pub reduce_slots: u32,
    /// Deadline cannot be met at any allocation (C <= 0).
    pub infeasible: bool,
}

/// Per-job progress snapshot for the Eq. 7 estimator.
#[derive(Clone, Copy, Debug, Default)]
pub struct JobProgress {
    pub rem_map: f64,
    pub rem_reduce: f64,
    pub t_map: f64,
    pub t_reduce: f64,
    pub t_shuffle: f64,
    pub map_slots: f64,
    pub reduce_slots: f64,
    pub reduce_tasks: f64,
    pub deadline: f64,
    pub elapsed: f64,
}

/// Eq. 7 output.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Eta {
    /// Estimated remaining seconds until job completion.
    pub eta: f64,
    /// `D - elapsed - eta`; negative means a projected deadline miss.
    pub slack: f64,
}

/// Backend-independent predictor interface (batched — one call covers every
/// active job, matching the single-PJRT-execution-per-heartbeat design).
pub trait Predictor {
    fn solve_slots(&mut self, jobs: &[JobDemand]) -> Vec<SlotDemand>;
    fn estimate(&mut self, jobs: &[JobProgress]) -> Vec<Eta>;

    /// Wave-based Eq. 7 variant (discrete task waves; see
    /// `python/compile/kernels/wave_estimator.py`). Defaults to the fluid
    /// estimate for backends without the wave artifact.
    fn estimate_wave(&mut self, jobs: &[JobProgress]) -> Vec<Eta> {
        self.estimate(jobs)
    }
}

/// The (A, B, C) terms of Eq. 9 for one job.
#[inline]
pub fn abc(d: &JobDemand) -> (f64, f64, f64) {
    let a = d.map_tasks * d.t_map;
    let b = d.reduce_tasks * d.t_reduce;
    let c = d.deadline - d.map_tasks * d.reduce_tasks * d.t_shuffle;
    (a, b, c)
}

/// Build an Eq. 10 demand for a *fresh* job from its spec and the cost
/// model — the "what would the predictor say at submission" question the
/// Table 2 bench asks. At runtime the scheduler instead uses measured
/// Eq. 1 statistics (see `JobStats`).
pub fn demand_from_spec(
    cfg: &crate::config::SimConfig,
    spec: &crate::workloads::JobSpec,
) -> JobDemand {
    let cost = crate::mapreduce::TaskCost::new(cfg, spec);
    let maps = (spec.input_mb / cfg.block_mb).ceil().max(1.0);
    let inter_mb = cost.map_output_mb(spec.input_mb);
    let reducers = spec.reducers.max(1);
    JobDemand {
        map_tasks: maps,
        reduce_tasks: reducers as f64,
        t_map: cost.map_secs_nominal(cfg.block_mb, true),
        t_reduce: cost.reduce_secs_nominal(inter_mb, maps as u32, reducers),
        t_shuffle: cost.t_shuffle_estimate(inter_mb, maps as u32, reducers),
        deadline: spec.deadline_s.unwrap_or(f64::INFINITY),
    }
}

/// Pure-Rust reference backend.
#[derive(Default, Debug, Clone)]
pub struct NativePredictor;

impl NativePredictor {
    pub fn new() -> Self {
        Self
    }

    /// Scalar Eq. 10. Mirrors `python/compile/kernels/ref.py::slot_solver_ref`.
    pub fn solve_one(d: &JobDemand) -> SlotDemand {
        let (a, b, c) = abc(d);
        let (a, b) = (a.max(0.0), b.max(0.0));
        if c <= 0.0 {
            return SlotDemand {
                infeasible: true,
                ..Default::default()
            };
        }
        let (ra, rb) = (a.sqrt(), b.sqrt());
        let s = ra + rb;
        let n_m = (ra * s / c).ceil();
        let n_r = (rb * s / c).ceil();
        SlotDemand {
            map_slots: if a > 0.0 { n_m.max(1.0) as u32 } else { 0 },
            reduce_slots: if b > 0.0 { n_r.max(1.0) as u32 } else { 0 },
            infeasible: false,
        }
    }

    /// Scalar wave-based Eq. 7: `ceil(rem/n)*t` per phase. Mirrors
    /// `ref.py::wave_estimator_ref`. Always >= the fluid estimate.
    pub fn estimate_wave_one(p: &JobProgress) -> Eta {
        let n_m = p.map_slots.max(1.0);
        let n_r = p.reduce_slots.max(1.0);
        let eta = (p.rem_map / n_m).ceil() * p.t_map
            + (p.rem_reduce / n_r).ceil() * p.t_reduce
            + p.rem_map * p.reduce_tasks * p.t_shuffle;
        Eta {
            eta,
            slack: p.deadline - p.elapsed - eta,
        }
    }

    /// Scalar Eq. 7. Mirrors `ref.py::completion_estimator_ref`.
    pub fn estimate_one(p: &JobProgress) -> Eta {
        let n_m = p.map_slots.max(1.0);
        let n_r = p.reduce_slots.max(1.0);
        let eta = p.rem_map * p.t_map / n_m
            + p.rem_reduce * p.t_reduce / n_r
            + p.rem_map * p.reduce_tasks * p.t_shuffle;
        Eta {
            eta,
            slack: p.deadline - p.elapsed - eta,
        }
    }
}

impl Predictor for NativePredictor {
    fn solve_slots(&mut self, jobs: &[JobDemand]) -> Vec<SlotDemand> {
        jobs.iter().map(Self::solve_one).collect()
    }

    fn estimate(&mut self, jobs: &[JobProgress]) -> Vec<Eta> {
        jobs.iter().map(Self::estimate_one).collect()
    }

    fn estimate_wave(&mut self, jobs: &[JobProgress]) -> Vec<Eta> {
        jobs.iter().map(Self::estimate_wave_one).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demand(u_m: f64, v_r: f64, t_m: f64, t_r: f64, t_s: f64, d: f64) -> JobDemand {
        JobDemand {
            map_tasks: u_m,
            reduce_tasks: v_r,
            t_map: t_m,
            t_reduce: t_r,
            t_shuffle: t_s,
            deadline: d,
        }
    }

    #[test]
    fn eq10_closed_form() {
        // A=100, B=50, C=10 -> (18, 13); cross-checked with the kernels.
        let d = demand(100.0, 50.0, 1.0, 1.0, 0.0, 10.0);
        let s = NativePredictor::solve_one(&d);
        assert_eq!((s.map_slots, s.reduce_slots), (18, 13));
        assert!(!s.infeasible);
    }

    #[test]
    fn infeasible_when_shuffle_exceeds_deadline() {
        let d = demand(100.0, 50.0, 1.0, 1.0, 1.0, 10.0); // C = 10 - 5000
        assert!(NativePredictor::solve_one(&d).infeasible);
    }

    #[test]
    fn allocation_satisfies_eq7_bound() {
        // Defining property: the returned slots meet the deadline per Eq. 7.
        let mut rng = crate::util::Rng::new(3);
        for _ in 0..500 {
            let d = demand(
                rng.range_f64(1.0, 500.0).floor(),
                rng.range_f64(0.0, 64.0).floor(),
                rng.range_f64(0.5, 90.0),
                rng.range_f64(0.5, 90.0),
                rng.range_f64(0.0, 0.01),
                rng.range_f64(10.0, 5000.0),
            );
            let s = NativePredictor::solve_one(&d);
            if s.infeasible {
                continue;
            }
            let (a, b, c) = abc(&d);
            let lhs = if s.map_slots > 0 { a / s.map_slots as f64 } else { 0.0 }
                + if s.reduce_slots > 0 { b / s.reduce_slots as f64 } else { 0.0 };
            assert!(lhs <= c * (1.0 + 1e-9), "lhs {lhs} > C {c} for {d:?}");
        }
    }

    #[test]
    fn slots_monotone_in_deadline() {
        let mut rng = crate::util::Rng::new(4);
        for _ in 0..200 {
            let mut d = demand(
                rng.range_f64(1.0, 300.0).floor(),
                rng.range_f64(1.0, 32.0).floor(),
                rng.range_f64(0.5, 60.0),
                rng.range_f64(0.5, 60.0),
                0.0,
                rng.range_f64(5.0, 800.0),
            );
            let tight = NativePredictor::solve_one(&d);
            d.deadline *= 2.0;
            let loose = NativePredictor::solve_one(&d);
            assert!(loose.map_slots <= tight.map_slots);
            assert!(loose.reduce_slots <= tight.reduce_slots);
        }
    }

    #[test]
    fn eta_decomposes() {
        let p = JobProgress {
            rem_map: 10.0,
            rem_reduce: 4.0,
            t_map: 2.0,
            t_reduce: 2.0,
            t_shuffle: 0.1,
            map_slots: 2.0,
            reduce_slots: 2.0,
            reduce_tasks: 4.0,
            deadline: 30.0,
            elapsed: 0.0,
        };
        let e = NativePredictor::estimate_one(&p);
        assert!((e.eta - 18.0).abs() < 1e-12);
        assert!((e.slack - 12.0).abs() < 1e-12);
    }

    #[test]
    fn wave_estimate_never_below_fluid() {
        let mut rng = crate::util::Rng::new(9);
        for _ in 0..300 {
            let p = JobProgress {
                rem_map: rng.range_f64(0.0, 200.0).floor(),
                rem_reduce: rng.range_f64(0.0, 50.0).floor(),
                t_map: rng.range_f64(0.1, 60.0),
                t_reduce: rng.range_f64(0.1, 60.0),
                t_shuffle: rng.range_f64(0.0, 0.01),
                map_slots: rng.range_f64(1.0, 32.0).floor(),
                reduce_slots: rng.range_f64(1.0, 32.0).floor(),
                reduce_tasks: rng.range_f64(0.0, 50.0).floor(),
                deadline: 1000.0,
                elapsed: 0.0,
            };
            let fluid = NativePredictor::estimate_one(&p);
            let wave = NativePredictor::estimate_wave_one(&p);
            assert!(wave.eta >= fluid.eta - 1e-9, "{p:?}");
        }
    }

    #[test]
    fn wave_exact_waves() {
        let p = JobProgress {
            rem_map: 10.0,
            rem_reduce: 4.0,
            t_map: 5.0,
            t_reduce: 7.0,
            t_shuffle: 0.0,
            map_slots: 4.0,
            reduce_slots: 4.0,
            reduce_tasks: 4.0,
            deadline: 100.0,
            elapsed: 0.0,
        };
        let e = NativePredictor::estimate_wave_one(&p);
        assert!((e.eta - (3.0 * 5.0 + 7.0)).abs() < 1e-12);
    }

    #[test]
    fn batched_matches_scalar() {
        let mut p = NativePredictor::new();
        let jobs: Vec<JobDemand> = (0..10)
            .map(|i| demand(10.0 + i as f64, 4.0, 3.0, 3.0, 0.001, 120.0))
            .collect();
        let batch = p.solve_slots(&jobs);
        for (d, s) in jobs.iter().zip(&batch) {
            assert_eq!(*s, NativePredictor::solve_one(d));
        }
    }
}
