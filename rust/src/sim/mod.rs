//! Discrete-event simulation core.
//!
//! Deterministic single-threaded engine: a monotone clock in integer
//! milliseconds and a binary-heap event queue with FIFO tie-breaking (a
//! sequence number breaks timestamp ties so the schedule order is total and
//! reproducible — the determinism property tests rely on this).

mod queue;

pub use queue::EventQueue;

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// Simulated time in milliseconds since simulation start.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SimTime(pub u64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);

    pub fn from_secs_f64(s: f64) -> SimTime {
        debug_assert!(s >= 0.0, "negative sim time: {s}");
        SimTime((s.max(0.0) * 1e3).round() as u64)
    }

    pub fn from_millis(ms: u64) -> SimTime {
        SimTime(ms)
    }

    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e3
    }

    pub fn as_millis(self) -> u64 {
        self.0
    }

    pub fn saturating_sub(self, other: SimTime) -> SimTime {
        SimTime(self.0.saturating_sub(other.0))
    }
}

impl Add for SimTime {
    type Output = SimTime;
    fn add(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 + rhs.0)
    }
}

impl AddAssign for SimTime {
    fn add_assign(&mut self, rhs: SimTime) {
        self.0 += rhs.0;
    }
}

impl Sub for SimTime {
    type Output = SimTime;
    fn sub(self, rhs: SimTime) -> SimTime {
        debug_assert!(self.0 >= rhs.0, "SimTime underflow");
        SimTime(self.0 - rhs.0)
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.3}s", self.as_secs_f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions_roundtrip() {
        let t = SimTime::from_secs_f64(12.345);
        assert_eq!(t.as_millis(), 12345);
        assert!((t.as_secs_f64() - 12.345).abs() < 1e-9);
    }

    #[test]
    fn arithmetic() {
        let a = SimTime::from_millis(1500);
        let b = SimTime::from_millis(500);
        assert_eq!(a + b, SimTime::from_millis(2000));
        assert_eq!(a - b, SimTime::from_millis(1000));
        assert_eq!(b.saturating_sub(a), SimTime::ZERO);
    }

    #[test]
    fn ordering() {
        assert!(SimTime::from_millis(1) < SimTime::from_millis(2));
        assert_eq!(SimTime::ZERO, SimTime::from_millis(0));
    }
}
