//! Generic deterministic event queue.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::SimTime;

/// Min-heap of `(time, seq)`-ordered events. `seq` is a monotonically
/// increasing insertion counter, so events scheduled for the same instant
/// fire in insertion order — a total, reproducible order.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Reverse<Entry<E>>>,
    seq: u64,
    now: SimTime,
    popped: u64,
}

#[derive(Debug)]
struct Entry<E> {
    at: SimTime,
    seq: u64,
    ev: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self {
            heap: BinaryHeap::new(),
            seq: 0,
            now: SimTime::ZERO,
            popped: 0,
        }
    }

    /// Current simulated time (time of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Total events processed so far.
    pub fn processed(&self) -> u64 {
        self.popped
    }

    /// Schedule `ev` at absolute time `at`. Scheduling in the past is a
    /// logic error (panics in debug; clamped to `now` in release).
    pub fn schedule_at(&mut self, at: SimTime, ev: E) {
        debug_assert!(at >= self.now, "scheduling into the past: {at} < {}", self.now);
        let at = at.max(self.now);
        self.seq += 1;
        self.heap.push(Reverse(Entry {
            at,
            seq: self.seq,
            ev,
        }));
    }

    /// Schedule `ev` after a relative delay.
    pub fn schedule_in(&mut self, delay: SimTime, ev: E) {
        self.schedule_at(self.now + delay, ev);
    }

    /// Reserve a contiguous band of `len` sequence numbers and return the
    /// first. The insertion counter jumps past the band, so events later
    /// scheduled with [`Self::schedule_at_with_seq`] inside the band sort
    /// *before* (at equal timestamps) everything scheduled after the
    /// reservation — regardless of actual insertion time. This lets a
    /// caller that materializes events lazily (one outstanding at a time)
    /// reproduce the exact tie-break order of a caller that scheduled
    /// them all up front.
    pub fn reserve_seqs(&mut self, len: u64) -> u64 {
        let base = self.seq + 1;
        self.seq += len;
        base
    }

    /// Schedule `ev` at absolute time `at` with an explicit sequence
    /// number from a band previously obtained via [`Self::reserve_seqs`].
    /// The caller is responsible for using each reserved seq at most once
    /// (duplicates would still pop deterministically, but the band
    /// contract is one event per seq).
    pub fn schedule_at_with_seq(&mut self, at: SimTime, seq: u64, ev: E) {
        debug_assert!(at >= self.now, "scheduling into the past: {at} < {}", self.now);
        debug_assert!(seq <= self.seq, "seq {seq} outside any reserved band");
        let at = at.max(self.now);
        self.heap.push(Reverse(Entry { at, seq, ev }));
    }

    /// Force the clock forward to `t` without popping (used by tests to
    /// exercise timeout paths). Events scheduled before `t` still pop in
    /// order but with their original timestamps clamped monotonically.
    pub fn advance_to(&mut self, t: SimTime) {
        debug_assert!(t >= self.now);
        self.now = self.now.max(t);
    }

    /// Pop the next event, advancing the clock.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        self.heap.pop().map(|Reverse(e)| {
            debug_assert!(e.at >= self.now);
            self.now = e.at;
            self.popped += 1;
            (e.at, e.ev)
        })
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// The non-structural cursors `(now, seq, popped)` for snapshot
    /// encoding. `seq` must be restored exactly — reserved bands and the
    /// tie-break order of future insertions depend on it — and `popped`
    /// feeds the `events` metric, which the byte-identity contract covers.
    pub fn cursors(&self) -> (SimTime, u64, u64) {
        (self.now, self.seq, self.popped)
    }

    /// Pending entries as `(at, seq, ev)` in pop order. The heap's internal
    /// layout is not canonical (it depends on insertion history), so
    /// snapshots serialize this sorted view; rebuilding from it via
    /// [`Self::restore`] is behavior-identical because pops only ever see
    /// the `(at, seq)` order.
    pub fn entries_sorted(&self) -> Vec<(SimTime, u64, &E)> {
        let mut v: Vec<(SimTime, u64, &E)> = self
            .heap
            .iter()
            .map(|Reverse(e)| (e.at, e.seq, &e.ev))
            .collect();
        v.sort_unstable_by_key(|&(at, seq, _)| (at, seq));
        v
    }

    /// Rebuild a queue from snapshot state: cursors from
    /// [`Self::cursors`] plus the pending entries from
    /// [`Self::entries_sorted`].
    pub fn restore(now: SimTime, seq: u64, popped: u64, entries: Vec<(SimTime, u64, E)>) -> Self {
        let mut heap = BinaryHeap::with_capacity(entries.len());
        for (at, eseq, ev) in entries {
            debug_assert!(at >= now && eseq <= seq);
            heap.push(Reverse(Entry { at, seq: eseq, ev }));
        }
        Self {
            heap,
            seq,
            now,
            popped,
        }
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(SimTime::from_millis(30), "c");
        q.schedule_at(SimTime::from_millis(10), "a");
        q.schedule_at(SimTime::from_millis(20), "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
        assert_eq!(q.now(), SimTime::from_millis(30));
        assert_eq!(q.processed(), 3);
    }

    #[test]
    fn ties_break_fifo() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.schedule_at(SimTime::from_millis(5), i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn reserved_band_reproduces_upfront_tie_order() {
        // Up-front: two "static" events at t=5, then a "dynamic" one.
        let mut up = EventQueue::new();
        up.schedule_at(SimTime::from_millis(5), "a");
        up.schedule_at(SimTime::from_millis(5), "b");
        up.schedule_at(SimTime::from_millis(5), "dyn");
        let up_order: Vec<&str> = std::iter::from_fn(|| up.pop().map(|(_, e)| e)).collect();

        // Lazy: reserve the band first, schedule the dynamic event, then
        // fill the band out of insertion order — pops must match.
        let mut lazy = EventQueue::new();
        let band = lazy.reserve_seqs(2);
        lazy.schedule_at(SimTime::from_millis(5), "dyn");
        lazy.schedule_at_with_seq(SimTime::from_millis(5), band + 1, "b");
        lazy.schedule_at_with_seq(SimTime::from_millis(5), band, "a");
        let lazy_order: Vec<&str> = std::iter::from_fn(|| lazy.pop().map(|(_, e)| e)).collect();
        assert_eq!(up_order, lazy_order);
    }

    #[test]
    fn restore_reproduces_pop_order_and_cursors() {
        let mut q = EventQueue::new();
        for i in 0..50u64 {
            q.schedule_at(SimTime::from_millis(100 - i), i);
        }
        q.pop();
        q.pop();
        let (now, seq, popped) = q.cursors();
        let entries: Vec<(SimTime, u64, u64)> = q
            .entries_sorted()
            .into_iter()
            .map(|(at, s, &ev)| (at, s, ev))
            .collect();
        let mut r = EventQueue::restore(now, seq, popped, entries);
        assert_eq!(r.cursors(), q.cursors());
        loop {
            let (a, b) = (q.pop(), r.pop());
            assert_eq!(a, b);
            if a.is_none() {
                break;
            }
        }
        assert_eq!(r.processed(), q.processed());
        // New insertions continue the same seq stream.
        q.schedule_at(SimTime::from_millis(200), 999);
        r.schedule_at(SimTime::from_millis(200), 999);
        assert_eq!(q.pop(), r.pop());
    }

    #[test]
    fn relative_scheduling_tracks_clock() {
        let mut q = EventQueue::new();
        q.schedule_in(SimTime::from_millis(10), 1);
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, SimTime::from_millis(10));
        q.schedule_in(SimTime::from_millis(5), 2);
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, SimTime::from_millis(15));
    }

    #[test]
    fn clock_monotone_under_interleaving() {
        let mut q = EventQueue::new();
        let mut rng = crate::util::Rng::new(1);
        q.schedule_at(SimTime::from_millis(1), 0u64);
        let mut last = SimTime::ZERO;
        let mut n = 0;
        while let Some((t, _)) = q.pop() {
            assert!(t >= last);
            last = t;
            n += 1;
            if n < 1000 {
                // schedule 0-2 future events
                for _ in 0..rng.below(3) {
                    q.schedule_in(SimTime::from_millis(rng.below(50)), n);
                }
            }
        }
        assert!(n >= 1);
    }
}
