//! Deterministic PRNG: splitmix64 seeding + xoshiro256** core.
//!
//! The simulator must be bit-reproducible from a seed (the determinism
//! property tests depend on it), so we carry our own generator instead of
//! depending on `rand` (unavailable offline anyway).

/// xoshiro256** with splitmix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    mix64(*state)
}

/// The splitmix64 finalizer: a bijective avalanche mix. Public so seed
/// derivation (below) and tests can reuse it.
#[inline]
pub fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Derive the seed of one scenario's RNG stream from `(grid_seed, index)`.
///
/// Every scenario in a sweep gets an independent, reproducible stream that
/// depends only on these two values — never on thread count, scheduling
/// order, or any other run's state — which is what makes sweep artifacts
/// byte-identical at any `--threads` setting (see `harness::runner`).
#[inline]
pub fn derive_stream_seed(grid_seed: u64, index: u64) -> u64 {
    mix64(
        grid_seed
            .wrapping_add(index.wrapping_mul(0x9E37_79B9_7F4A_7C15))
            .wrapping_add(0x5EED_0F5C_E4A1_0B17),
    )
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (for per-subsystem RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// The raw xoshiro256** state, for snapshot encoding. Restoring via
    /// [`Rng::from_state`] resumes the stream at exactly this cursor.
    #[inline]
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a [`Rng::state`] capture.
    #[inline]
    pub fn from_state(s: [u64; 4]) -> Self {
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`; Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in `[lo, hi]` (inclusive).
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with mean `mean` (Poisson inter-arrival times).
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Standard normal via Box-Muller (no caching — fine for our rates).
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + std * z
    }

    /// Truncated normal: resample-free clamp (adequate for task jitter).
    pub fn normal_clamped(&mut self, mean: f64, std: f64, lo: f64, hi: f64) -> f64 {
        self.normal(mean, std).clamp(lo, hi)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit");
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Rng::new(11);
        let mean: f64 = (0..20_000).map(|_| r.exp(3.0)).sum::<f64>() / 20_000.0;
        assert!((mean - 3.0).abs() < 0.15, "mean {mean}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(13);
        for _ in 0..100 {
            let s = r.sample_indices(20, 5);
            let mut t = s.clone();
            t.sort_unstable();
            t.dedup();
            assert_eq!(t.len(), 5);
            assert!(s.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<u32>>());
    }

    #[test]
    fn derived_streams_deterministic_and_distinct() {
        assert_eq!(derive_stream_seed(42, 7), derive_stream_seed(42, 7));
        let mut seen = std::collections::HashSet::new();
        for idx in 0..1000u64 {
            assert!(seen.insert(derive_stream_seed(42, idx)), "collision at {idx}");
        }
        // Different grid seeds shift every stream.
        assert_ne!(derive_stream_seed(1, 0), derive_stream_seed(2, 0));
    }

    #[test]
    fn state_round_trip_resumes_stream() {
        let mut a = Rng::new(42);
        for _ in 0..37 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
