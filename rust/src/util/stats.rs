//! Summary statistics for metrics and the bench harness.

use std::collections::BTreeMap;

/// Online mean/min/max/count accumulator (Welford variance).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    sum: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self {
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            ..Default::default()
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn sum(&self) -> f64 {
        self.sum
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Raw second central moment (Welford `M2`), for serialization.
    pub fn m2(&self) -> f64 {
        self.m2
    }

    /// Rebuild a summary from its raw serialized fields (the inverse of
    /// reading `count`/`mean`/`m2`/`min`/`max`/`sum`). `n == 0` yields a
    /// fresh empty summary regardless of the other fields.
    pub fn from_raw(n: u64, mean: f64, m2: f64, min: f64, max: f64, sum: f64) -> Self {
        if n == 0 {
            return Self::new();
        }
        Self {
            n,
            mean,
            m2,
            min,
            max,
            sum,
        }
    }

    /// Merge another summary into this one (Chan et al. parallel
    /// variance). Merging an empty summary is a no-op.
    pub fn merge(&mut self, other: &Summary) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let n = n1 + n2;
        let delta = other.mean - self.mean;
        self.mean += delta * n2 / n;
        self.m2 += other.m2 + delta * delta * n1 * n2 / n;
        self.n += other.n;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Mergeable streaming quantile sketch over positive values, DDSketch
/// style: logarithmic buckets with relative accuracy `(γ-1)/(γ+1)`
/// (≈0.5% at the default γ = 1.01). Memory is bounded by the *value
/// range* (one bucket per γ-factor), never by the number of inserts —
/// the constant-memory replacement for [`Percentiles`] at streaming
/// scale. Values ≤ `MIN_VALUE` (including zero) collapse into a single
/// underflow bucket reported as 0.0.
#[derive(Clone, Debug, Default)]
pub struct QuantileSketch {
    /// Bucket index → count; bucket `i` covers `(γ^(i-1), γ^i]`.
    buckets: BTreeMap<i32, u64>,
    /// Count of values ≤ MIN_VALUE (reported as 0.0).
    zeros: u64,
    count: u64,
}

impl QuantileSketch {
    /// Relative-accuracy parameter: bucket `i` covers `(γ^(i-1), γ^i]`.
    pub const GAMMA: f64 = 1.01;
    /// Values at or below this are indistinguishable from zero.
    pub const MIN_VALUE: f64 = 1e-9;

    pub fn new() -> Self {
        Self::default()
    }

    fn bucket_index(x: f64) -> i32 {
        (x.ln() / Self::GAMMA.ln()).ceil() as i32
    }

    pub fn add(&mut self, x: f64) {
        self.count += 1;
        if !x.is_finite() || x <= Self::MIN_VALUE {
            self.zeros += 1;
            return;
        }
        *self.buckets.entry(Self::bucket_index(x)).or_insert(0) += 1;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Quantile estimate for `p` in [0, 100], using the same nearest-rank
    /// convention as [`Percentiles::pct`] so the two agree to within the
    /// sketch's relative accuracy on identical data.
    pub fn pct(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0) * (self.count as f64 - 1.0)).round() as u64;
        if rank < self.zeros {
            return 0.0;
        }
        let mut cum = self.zeros;
        for (&i, &c) in &self.buckets {
            cum += c;
            if cum > rank {
                // Midpoint of the bucket's value range, in relative terms.
                return 2.0 * Self::GAMMA.powi(i) / (Self::GAMMA + 1.0);
            }
        }
        // rank == count-1 fell off the end by rounding; return the top
        // bucket's estimate.
        let (&i, _) = self.buckets.iter().next_back().expect("non-empty sketch");
        2.0 * Self::GAMMA.powi(i) / (Self::GAMMA + 1.0)
    }

    /// Merge another sketch into this one (exact: bucket-wise addition).
    pub fn merge(&mut self, other: &QuantileSketch) {
        for (&i, &c) in &other.buckets {
            *self.buckets.entry(i).or_insert(0) += c;
        }
        self.zeros += other.zeros;
        self.count += other.count;
    }

    /// Serialize as `zeros` plus `idx:count` pairs in ascending index
    /// order (deterministic; the journal's content-hashable encoding).
    pub fn encode(&self) -> String {
        let mut s = format!("{}", self.zeros);
        for (&i, &c) in &self.buckets {
            s.push(' ');
            s.push_str(&format!("{i}:{c}"));
        }
        s
    }

    /// Inverse of [`Self::encode`]; `None` on any malformed field.
    pub fn decode(s: &str) -> Option<Self> {
        let mut parts = s.split_whitespace();
        let zeros: u64 = parts.next()?.parse().ok()?;
        let mut buckets = BTreeMap::new();
        let mut count = zeros;
        for p in parts {
            let (i, c) = p.split_once(':')?;
            let i: i32 = i.parse().ok()?;
            let c: u64 = c.parse().ok()?;
            count += c;
            buckets.insert(i, c);
        }
        Some(Self {
            buckets,
            zeros,
            count,
        })
    }
}

/// Exact percentile over a stored sample (fine at our scales).
#[derive(Clone, Debug, Default)]
pub struct Percentiles {
    xs: Vec<f64>,
    sorted: bool,
}

impl Percentiles {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// p in [0, 100]; nearest-rank.
    pub fn pct(&mut self, p: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            self.xs
                .sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            self.sorted = true;
        }
        let rank = ((p / 100.0) * (self.xs.len() as f64 - 1.0)).round() as usize;
        self.xs[rank.min(self.xs.len() - 1)]
    }

    pub fn median(&mut self) -> f64 {
        self.pct(50.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert!((s.var() - 5.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.min(), 0.0);
    }

    #[test]
    fn summary_merge_matches_sequential() {
        let xs: Vec<f64> = (0..64).map(|i| (i as f64 * 0.73).sin().abs() * 40.0 + 1.0).collect();
        let mut whole = Summary::new();
        for &x in &xs {
            whole.add(x);
        }
        let (mut a, mut b) = (Summary::new(), Summary::new());
        for &x in &xs[..20] {
            a.add(x);
        }
        for &x in &xs[20..] {
            b.add(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-9);
        assert!((a.std() - whole.std()).abs() < 1e-9);
        assert_eq!(a.min(), whole.min());
        assert_eq!(a.max(), whole.max());
    }

    #[test]
    fn summary_from_raw_round_trips() {
        let mut s = Summary::new();
        for x in [3.0, 1.5, 9.25] {
            s.add(x);
        }
        let r = Summary::from_raw(s.count(), s.mean(), s.m2(), s.min(), s.max(), s.sum());
        assert_eq!(r.count(), s.count());
        assert_eq!(r.mean().to_bits(), s.mean().to_bits());
        assert_eq!(r.std().to_bits(), s.std().to_bits());
        assert_eq!(Summary::from_raw(0, 0.0, 0.0, 0.0, 0.0, 0.0).mean(), 0.0);
    }

    #[test]
    fn sketch_tracks_exact_percentiles() {
        let mut sk = QuantileSketch::new();
        let mut ex = Percentiles::new();
        let mut state = 0x9e37_79b9_7f4a_7c15u64;
        for _ in 0..5000 {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let x = 1.0 + (state >> 11) as f64 / (1u64 << 53) as f64 * 900.0;
            sk.add(x);
            ex.add(x);
        }
        for p in [50.0, 90.0, 99.0] {
            let exact = ex.pct(p);
            let approx = sk.pct(p);
            assert!(
                (approx - exact).abs() / exact <= 0.01,
                "p{p}: sketch {approx} vs exact {exact}"
            );
        }
    }

    #[test]
    fn sketch_merge_and_codec() {
        let (mut a, mut b) = (QuantileSketch::new(), QuantileSketch::new());
        let mut whole = QuantileSketch::new();
        for i in 0..200 {
            let x = 0.5 + i as f64;
            if i % 2 == 0 {
                a.add(x);
            } else {
                b.add(x);
            }
            whole.add(x);
        }
        a.add(0.0);
        whole.add(0.0);
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        for p in [10.0, 50.0, 99.0] {
            assert_eq!(a.pct(p).to_bits(), whole.pct(p).to_bits());
        }
        let decoded = QuantileSketch::decode(&a.encode()).expect("codec");
        assert_eq!(decoded.count(), a.count());
        assert_eq!(decoded.encode(), a.encode());
        assert_eq!(decoded.pct(75.0).to_bits(), a.pct(75.0).to_bits());
    }

    #[test]
    fn percentiles() {
        let mut p = Percentiles::new();
        for i in 1..=100 {
            p.add(i as f64);
        }
        assert!((p.median() - 50.5).abs() <= 0.5); // nearest-rank: 50 or 51
        assert_eq!(p.pct(0.0), 1.0);
        assert_eq!(p.pct(100.0), 100.0);
        assert!((p.pct(95.0) - 95.0).abs() <= 1.0);
    }
}
