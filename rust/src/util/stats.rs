//! Summary statistics for metrics and the bench harness.

/// Online mean/min/max/count accumulator (Welford variance).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    sum: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self {
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            ..Default::default()
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn sum(&self) -> f64 {
        self.sum
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

/// Exact percentile over a stored sample (fine at our scales).
#[derive(Clone, Debug, Default)]
pub struct Percentiles {
    xs: Vec<f64>,
    sorted: bool,
}

impl Percentiles {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// p in [0, 100]; nearest-rank.
    pub fn pct(&mut self, p: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            self.xs
                .sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            self.sorted = true;
        }
        let rank = ((p / 100.0) * (self.xs.len() as f64 - 1.0)).round() as usize;
        self.xs[rank.min(self.xs.len() - 1)]
    }

    pub fn median(&mut self) -> f64 {
        self.pct(50.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert!((s.var() - 5.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.min(), 0.0);
    }

    #[test]
    fn percentiles() {
        let mut p = Percentiles::new();
        for i in 1..=100 {
            p.add(i as f64);
        }
        assert!((p.median() - 50.5).abs() <= 0.5); // nearest-rank: 50 or 51
        assert_eq!(p.pct(0.0), 1.0);
        assert_eq!(p.pct(100.0), 100.0);
        assert!((p.pct(95.0) - 95.0).abs() <= 1.0);
    }
}
