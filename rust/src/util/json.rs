//! Minimal JSON emitter (serde is unavailable offline). Write-only: we
//! never parse JSON, only export metrics/traces for external tooling.

use std::fmt::Write as _;

/// A JSON value builder.
#[derive(Clone, Debug)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn arr() -> Json {
        Json::Arr(Vec::new())
    }

    /// Add a field to an object (panics on non-object — programmer error).
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(fields) => fields.push((key.to_string(), val.into())),
            _ => panic!("set() on non-object"),
        }
        self
    }

    pub fn push(mut self, val: impl Into<Json>) -> Json {
        match &mut self {
            Json::Arr(xs) => xs.push(val.into()),
            _ => panic!("push() on non-array"),
        }
        self
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Num(x) => {
                if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for ch in s.chars() {
                    match ch {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Int(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Int(x as i64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Int(x as i64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Int(x as i64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested() {
        let j = Json::obj()
            .set("name", "wordcount")
            .set("jobs", 3u64)
            .set("ok", true)
            .set("xs", vec![1.5f64, 2.0]);
        assert_eq!(
            j.render(),
            r#"{"name":"wordcount","jobs":3,"ok":true,"xs":[1.5,2]}"#
        );
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.render(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn non_finite_is_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
        assert_eq!(Json::Num(f64::INFINITY).render(), "null");
    }
}
