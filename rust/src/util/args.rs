//! Hand-rolled CLI argument parsing (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, `--flag` and positionals:
//!
//! ```
//! use vcsched::util::args::Args;
//! let a = Args::parse_from(["simulate", "--seed=7", "--verbose"]);
//! assert_eq!(a.positional(0), Some("simulate"));
//! assert_eq!(a.get_u64("seed", 1), 7);
//! assert!(a.flag("verbose"));
//! ```

use std::collections::HashMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    positionals: Vec<String>,
    options: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse process arguments (skipping argv[0]).
    pub fn parse() -> Self {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn parse_from<I, S>(iter: I) -> Self
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut out = Args::default();
        let mut it = iter.into_iter().map(Into::into).peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positionals.push(arg);
            }
        }
        out
    }

    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positionals.get(i).map(|s| s.as_str())
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} wants u64, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get_u64(name, default as u64) as usize
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} wants f64, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_mixed() {
        // NOTE: `--flag positional` is ambiguous (the token after a bare
        // `--name` is greedily taken as its value); positionals must come
        // before options, or use the `--key=value` form.
        let a = Args::parse_from([
            "compare", "pos2", "--seed", "9", "--pms=20", "--verbose",
        ]);
        assert_eq!(a.positional(0), Some("compare"));
        assert_eq!(a.positional(1), Some("pos2"));
        assert_eq!(a.get_u64("seed", 0), 9);
        assert_eq!(a.get_usize("pms", 0), 20);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse_from(Vec::<String>::new());
        assert_eq!(a.get_u64("seed", 42), 42);
        assert_eq!(a.get_f64("rate", 1.5), 1.5);
        assert_eq!(a.get_str("sched", "fair"), "fair");
    }

    #[test]
    fn eq_form() {
        let a = Args::parse_from(["--x=1.25"]);
        assert_eq!(a.get_f64("x", 0.0), 1.25);
    }
}
