//! Small in-tree utilities standing in for crates unavailable offline:
//! PRNG (`rand`), JSON emitter (`serde_json`), CLI parsing (`clap`),
//! bench harness (`criterion`) and summary statistics.

pub mod args;
pub mod benchkit;
pub mod codec;
pub mod json;
pub mod logger;
pub mod rng;
pub mod stats;

pub use rng::Rng;

use std::path::{Path, PathBuf};

/// Resolve `rel` against the crate root so tests/benches/examples work
/// regardless of the current working directory.
pub fn repo_path(rel: &str) -> PathBuf {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    root.join(rel)
}

/// Integer ceiling division for non-negative operands.
#[inline]
pub fn ceil_div(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 3), 0);
        assert_eq!(ceil_div(1, 3), 1);
        assert_eq!(ceil_div(3, 3), 1);
        assert_eq!(ceil_div(4, 3), 2);
    }

    #[test]
    fn repo_path_finds_cargo_toml() {
        assert!(repo_path("Cargo.toml").exists());
    }
}
