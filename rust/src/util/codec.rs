//! Minimal little-endian binary codec for snapshots and event logs.
//!
//! The crate is deliberately dependency-free, so snapshot encoding is a
//! hand-rolled byte protocol rather than serde: every integer is fixed-width
//! little-endian, every float is its IEEE-754 bit pattern (`f64::to_bits`),
//! every sequence is a `u64` length prefix followed by its elements. That
//! makes the encoding *bit-exact* — two worlds encode to the same bytes iff
//! their observable state is identical, which is what the snapshot/resume
//! byte-identity contract and the replay state hashes rely on
//! (`docs/EVENT_LOG.md`).
//!
//! Decoding is bounds-checked and returns `Err(String)` on truncated or
//! malformed input; it never panics on untrusted bytes.

/// 64-bit FNV-1a offset basis.
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// 64-bit FNV-1a prime.
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a 64-bit hash of `bytes` — the same function the golden-report
/// tests use, so event-log and snapshot hashes are comparable artifacts.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Append-only little-endian encoder.
#[derive(Debug, Default)]
pub struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    pub fn new() -> Self {
        Self::default()
    }

    /// Finish encoding and hand back the bytes.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    /// Bytes encoded so far (checksum trailers hash this prefix).
    pub fn bytes(&self) -> &[u8] {
        &self.buf
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn bool(&mut self, v: bool) {
        self.buf.push(v as u8);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// `usize` travels as `u64` so the encoding is word-size independent.
    pub fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    /// IEEE-754 bit pattern: exact, including negative zero and NaN payloads.
    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    pub fn raw(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Length-prefixed UTF-8 string.
    pub fn str(&mut self, s: &str) {
        self.usize(s.len());
        self.buf.extend_from_slice(s.as_bytes());
    }
}

/// Bounds-checked little-endian decoder over a byte slice.
#[derive(Debug)]
pub struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Current read offset (checksum trailers hash `buf[..pos]`).
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// The full underlying slice (for checksum verification).
    pub fn all(&self) -> &'a [u8] {
        self.buf
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.remaining() < n {
            return Err(format!(
                "truncated input: need {n} bytes at offset {}, have {}",
                self.pos,
                self.remaining()
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    pub fn bool(&mut self) -> Result<bool, String> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            b => Err(format!("invalid bool byte {b:#x}")),
        }
    }

    pub fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn usize(&mut self) -> Result<usize, String> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| format!("length {v} overflows usize"))
    }

    /// A length prefix that is about to size an allocation: reject lengths
    /// the remaining input could not possibly back (`min_elem_bytes` is the
    /// smallest on-wire size of one element), so corrupt input cannot ask
    /// for multi-gigabyte buffers.
    pub fn len(&mut self, min_elem_bytes: usize) -> Result<usize, String> {
        let n = self.usize()?;
        if n.saturating_mul(min_elem_bytes.max(1)) > self.remaining() {
            return Err(format!(
                "implausible length {n} at offset {} ({} bytes remain)",
                self.pos,
                self.remaining()
            ));
        }
        Ok(n)
    }

    pub fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub fn str(&mut self) -> Result<String, String> {
        let n = self.len(1)?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|e| format!("invalid UTF-8 string: {e}"))
    }

    /// Decoding must consume everything it was given; trailing garbage is
    /// as much a format error as truncation.
    pub fn finish(self) -> Result<(), String> {
        if self.remaining() != 0 {
            return Err(format!("{} trailing bytes after decode", self.remaining()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_primitives() {
        let mut e = Enc::new();
        e.u8(7);
        e.bool(true);
        e.u32(0xdead_beef);
        e.u64(u64::MAX - 1);
        e.usize(42);
        e.f64(-0.0);
        e.f64(f64::NAN);
        e.str("héllo");
        let bytes = e.into_bytes();
        let mut d = Dec::new(&bytes);
        assert_eq!(d.u8().unwrap(), 7);
        assert!(d.bool().unwrap());
        assert_eq!(d.u32().unwrap(), 0xdead_beef);
        assert_eq!(d.u64().unwrap(), u64::MAX - 1);
        assert_eq!(d.usize().unwrap(), 42);
        assert_eq!(d.f64().unwrap().to_bits(), (-0.0f64).to_bits());
        assert!(d.f64().unwrap().is_nan());
        assert_eq!(d.str().unwrap(), "héllo");
        d.finish().unwrap();
    }

    #[test]
    fn truncation_is_an_error_not_a_panic() {
        let mut e = Enc::new();
        e.u64(5);
        let bytes = e.into_bytes();
        let mut d = Dec::new(&bytes[..4]);
        assert!(d.u64().is_err());
    }

    #[test]
    fn implausible_length_rejected() {
        let mut e = Enc::new();
        e.usize(1 << 40);
        let bytes = e.into_bytes();
        let mut d = Dec::new(&bytes);
        assert!(d.len(8).is_err());
    }

    #[test]
    fn trailing_bytes_rejected() {
        let mut e = Enc::new();
        e.u8(1);
        e.u8(2);
        let bytes = e.into_bytes();
        let mut d = Dec::new(&bytes);
        d.u8().unwrap();
        assert!(d.finish().is_err());
    }

    #[test]
    fn fnv_matches_known_vectors() {
        // Standard FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }
}
