//! Bench harness (criterion is unavailable offline). `cargo bench` targets
//! use `harness = false` and drive this: warmup + N timed iterations,
//! mean/p50/p95 reporting, and paper-style result tables.

use std::time::Instant;

use super::stats::{Percentiles, Summary};

/// Measure `f` for `iters` iterations after `warmup` runs.
pub fn measure<F: FnMut()>(label: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut s = Summary::new();
    let mut p = Percentiles::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed().as_secs_f64() * 1e6; // microseconds
        s.add(dt);
        p.add(dt);
    }
    BenchResult {
        label: label.to_string(),
        mean_us: s.mean(),
        std_us: s.std(),
        p50_us: p.pct(50.0),
        p95_us: p.pct(95.0),
        iters,
    }
}

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub label: String,
    pub mean_us: f64,
    pub std_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub iters: usize,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "{:<44} mean {:>10.2} us  p50 {:>10.2} us  p95 {:>10.2} us  (n={})",
            self.label, self.mean_us, self.p50_us, self.p95_us, self.iters
        );
    }
}

/// Fixed-width table printer for paper-figure reproductions.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let parts: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect();
            println!("| {} |", parts.join(" | "));
        };
        line(&self.headers);
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        println!("|-{}-|", sep.join("-|-"));
        for row in &self.rows {
            line(row);
        }
    }
}

/// `fmt_secs(1234.5)` -> "1234.5s"; keeps bench output uniform.
pub fn fmt_secs(x: f64) -> String {
    format!("{x:.1}s")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_iters() {
        let mut n = 0;
        let r = measure("noop", 2, 10, || n += 1);
        assert_eq!(n, 12);
        assert_eq!(r.iters, 10);
        assert!(r.mean_us >= 0.0);
        assert!(r.p95_us >= r.p50_us);
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new(&["job", "time"]);
        t.row(&["wordcount".into(), "12.3s".into()]);
        t.print();
    }
}
