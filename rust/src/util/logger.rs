//! Tiny self-contained logger (the `log`/`env_logger` crates are
//! unavailable offline). Level comes from `VCSCHED_LOG`
//! (error|warn|info|debug|trace), default warn.

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, most severe first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl Level {
    fn tag(self) -> &'static str {
        match self {
            Level::Error => "E",
            Level::Warn => "W",
            Level::Info => "I",
            Level::Debug => "D",
            Level::Trace => "T",
        }
    }
}

/// Maximum enabled level (atomic so the logger is thread-safe — the sweep
/// harness logs from worker threads).
static MAX_LEVEL: AtomicU8 = AtomicU8::new(Level::Warn as u8);

/// Install the logger configuration (idempotent; last call wins).
pub fn init() {
    let level = match std::env::var("VCSCHED_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("info") => Level::Info,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Warn,
    };
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Is `level` currently enabled?
pub fn enabled(level: Level) -> bool {
    level as u8 <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Emit one record to stderr if `level` is enabled.
pub fn log(level: Level, target: &str, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        eprintln!("[{} {target}] {args}", level.tag());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_and_level_gating() {
        init();
        init(); // idempotent
        log(Level::Warn, "logger", format_args!("logger smoke test"));
        // Pin the level directly (init() reads the real VCSCHED_LOG env
        // var, which would make env-dependent assertions flaky).
        MAX_LEVEL.store(Level::Warn as u8, Ordering::Relaxed);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Trace));
    }
}
