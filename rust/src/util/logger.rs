//! Tiny `log` facade backend (env_logger is unavailable offline).
//! Level comes from `VCSCHED_LOG` (error|warn|info|debug|trace), default warn.

use log::{Level, LevelFilter, Metadata, Record};

struct SimpleLogger;

static LOGGER: SimpleLogger = SimpleLogger;

impl log::Log for SimpleLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            let tag = match record.level() {
                Level::Error => "E",
                Level::Warn => "W",
                Level::Info => "I",
                Level::Debug => "D",
                Level::Trace => "T",
            };
            eprintln!("[{tag} {}] {}", record.target(), record.args());
        }
    }

    fn flush(&self) {}
}

/// Install the logger (idempotent).
pub fn init() {
    let level = match std::env::var("VCSCHED_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("info") => LevelFilter::Info,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        Ok("warn") | _ => LevelFilter::Warn,
    };
    if log::set_logger(&LOGGER).is_ok() {
        log::set_max_level(level);
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::warn!("logger smoke test");
    }
}
