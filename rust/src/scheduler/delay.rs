//! Delay Scheduling (Zaharia et al., EuroSys'10 — the paper's ref [16]):
//! Fair Scheduler ranking, but a job with no node-local task *waits* for
//! up to `patience` heartbeats before degrading its locality. Improves
//! locality without VM reconfiguration — the natural software-only
//! baseline against the paper's hot-plug approach.
//!
//! On racked topologies the wait is **tiered**, the two-level scheme of
//! Zaharia et al. §4.2 (and the rack-aware follow-ups, arXiv:1506.00425):
//! a skipped job first unlocks *rack-local* tasks after `patience`
//! heartbeats, and only unlocks *off-rack* tasks after `2 * patience`.
//! On the flat topology there is no rack tier, so the single threshold
//! degenerates to the original local-then-remote behaviour (byte-
//! identical to the seed).
//!
//! The per-job skip counters are **virtual**: the naive scheme walks
//! every active job after every heartbeat to increment-or-reset an
//! integer, an O(jobs)-per-heartbeat tail. Instead we keep one global
//! heartbeat counter `hb` and a per-job base `base[j]`, with
//! `skipped(j) = hb - base[j]` (and 0 whenever the job has no pending
//! maps). A map launch rebases the job to `hb + 1` (counting restarts
//! after this heartbeat) and a pending-maps 0→>0 transition — delivered
//! via `on_job_updated` — rebases it to `hb`, which together reproduce
//! the increment/reset walk exactly while touching only jobs that
//! launched or changed.

use crate::cluster::{LocalityTier, NodeId, PmId};
use crate::mapreduce::{JobId, JobState};
use crate::predictor::Predictor;
use crate::util::codec::{Dec, Enc};

use super::fair::{fair_key, FairKey};
use super::{
    greedy_fill, speculative_fill, Action, BlacklistPolicy, ClaimLedger, OrderIndex, SchedView,
    Scheduler, SchedulerKind,
};

#[derive(Debug)]
pub struct DelayScheduler {
    patience: u32,
    /// Completed heartbeat callbacks (the virtual clock).
    hb: u64,
    /// Per-job skip base: `skipped(j) = hb - base[j]` while pending > 0.
    base: Vec<u64>,
    /// Whether the job had pending maps at its last notification — the
    /// 0→>0 transition (crash re-pend, lost map output) must restart the
    /// skip count at zero, like the naive walk's reset-on-empty.
    had_pending: Vec<bool>,
    index: OrderIndex<FairKey>,
    covered: usize,
    /// Job id of slot 0 in `base`/`had_pending` — tracks the view's
    /// `jobs_base` so retired jobs cost no counter memory.
    win_base: usize,
    claims: ClaimLedger,
    blacklist: BlacklistPolicy,
}

impl DelayScheduler {
    pub fn new(patience: u32) -> Self {
        Self {
            patience,
            hb: 0,
            base: Vec::new(),
            had_pending: Vec::new(),
            index: OrderIndex::new(),
            covered: 0,
            win_base: 0,
            claims: ClaimLedger::new(),
            blacklist: BlacklistPolicy::default(),
        }
    }

    /// Worst locality tier `job` may accept after `skipped` fruitless
    /// heartbeats: node-only below `patience`; then rack-local (racked
    /// topologies) at `patience`; off-rack at `2 * patience` (or already
    /// at `patience` when there is no rack tier to wait for). Shared with
    /// the naive reference implementation (`scheduler::reference`).
    pub(crate) fn tier_cap(patience: u32, skipped: u32, racked: bool) -> LocalityTier {
        if !racked {
            if skipped >= patience {
                LocalityTier::Remote
            } else {
                LocalityTier::NodeLocal
            }
        } else if skipped >= patience.saturating_mul(2) {
            LocalityTier::Remote
        } else if skipped >= patience {
            LocalityTier::RackLocal
        } else {
            LocalityTier::NodeLocal
        }
    }

    /// The virtual skip counter, equal to what the naive per-heartbeat
    /// increment/reset walk would hold for `job` right now.
    fn skipped_for(&self, job: &JobState) -> u32 {
        if job.pending_maps() == 0 {
            return 0;
        }
        self.hb
            .saturating_sub(self.base[job.id.idx() - self.win_base])
            .min(u64::from(u32::MAX)) as u32
    }

    fn sync(&mut self, view: &SchedView) {
        let total = view.total_jobs();
        if self.covered > total {
            self.index.clear();
            self.base.clear();
            self.had_pending.clear();
            self.covered = 0;
            self.win_base = 0;
        }
        self.index.set_base(view.jobs_base);
        if view.jobs_base > self.win_base {
            let k = (view.jobs_base - self.win_base).min(self.base.len());
            self.base.drain(..k);
            self.had_pending.drain(..k);
            self.win_base = view.jobs_base;
        }
        if self.base.len() < view.jobs.len() {
            self.base.resize(view.jobs.len(), 0);
            self.had_pending.resize(view.jobs.len(), false);
        }
        for job in &view.jobs[self.covered.max(view.jobs_base) - view.jobs_base..] {
            let j = job.id.idx() - self.win_base;
            self.base[j] = self.hb;
            self.had_pending[j] = job.pending_maps() > 0;
            self.index.set_key(job.id, active_key(job));
        }
        self.covered = total;
    }
}

fn active_key(job: &JobState) -> Option<FairKey> {
    if job.is_done() {
        None
    } else {
        Some(fair_key(job))
    }
}

impl Scheduler for DelayScheduler {
    fn kind(&self) -> SchedulerKind {
        SchedulerKind::Delay
    }

    fn on_sim_start(&mut self, view: &SchedView) {
        self.index.clear();
        self.base.clear();
        self.had_pending.clear();
        self.covered = 0;
        self.win_base = 0;
        self.hb = 0;
        self.blacklist = BlacklistPolicy::new(view.cfg);
    }

    fn on_job_updated(&mut self, view: &SchedView, job: JobId) {
        self.sync(view);
        let j = view.slot(job);
        let js = &view.jobs[j];
        let pending = js.pending_maps() > 0;
        if pending && !self.had_pending[j] {
            self.base[j] = self.hb;
        }
        self.had_pending[j] = pending;
        self.index.set_key(job, active_key(js));
    }

    fn check_index(&self, view: &SchedView) -> Result<(), String> {
        let mut expect: Vec<(FairKey, JobId)> =
            view.active_jobs().map(|j| (fair_key(j), j.id)).collect();
        expect.sort_unstable();
        self.index.check_matches(&expect)?;
        self.claims.check_against(view.jobs)
    }

    fn on_job_added(
        &mut self,
        view: &SchedView,
        _job: JobId,
        _predictor: &mut dyn Predictor,
        _out: &mut Vec<Action>,
    ) {
        self.sync(view);
    }

    fn on_heartbeat(
        &mut self,
        view: &SchedView,
        node: NodeId,
        _predictor: &mut dyn Predictor,
        out: &mut Vec<Action>,
    ) {
        self.sync(view);
        // Blacklisted heartbeats launch nothing and do not advance the
        // virtual clock: the node offered no slot anyone could use, so
        // waiting jobs burn no patience on it (mirrored in the naive
        // reference, which early-returns before its skip walk).
        if self.blacklist.blocks_node(view, node) {
            return;
        }
        let racked = view.cluster.topology().is_racked();
        let patience = self.patience;
        let start = out.len();
        {
            let Self {
                ref index,
                ref mut claims,
                ref base,
                win_base,
                hb,
                ..
            } = *self;
            // A job degrades one locality tier per exhausted patience
            // window; skipped() inlined here against the borrowed fields.
            greedy_fill(
                view,
                node,
                index.iter().map(|j| view.slot(j)),
                claims,
                |job| {
                    let skipped = if job.pending_maps() == 0 {
                        0
                    } else {
                        hb.saturating_sub(base[job.id.idx() - win_base])
                            .min(u64::from(u32::MAX)) as u32
                    };
                    Self::tier_cap(patience, skipped, racked)
                },
                out,
            );
        }
        // Rebase every job that launched a map this heartbeat: its skip
        // count restarts after this round (`hb + 1`), exactly the naive
        // walk's reset-to-zero. Jobs that were skipped need no touch —
        // their virtual count grows with `hb`. O(actions), not O(jobs).
        for a in &out[start..] {
            if let Action::LaunchMap { job, .. } = a {
                self.base[job.idx() - self.win_base] = self.hb + 1;
            }
        }
        self.hb += 1;
        speculative_fill(view, node, out);
    }

    fn on_pm_failure(&mut self, view: &SchedView, pm: PmId) {
        self.blacklist.on_pm_failure(pm, view.now);
    }

    /// Delay's skip counters are history, not a function of the view: a
    /// freshly built scheduler would grant every waiting job a full new
    /// patience window. Snapshots therefore carry the virtual clock and
    /// the per-job bases; the fair-key index is derived state and is
    /// rebuilt from the restored view instead.
    fn encode_state(&self, e: &mut Enc) {
        e.u64(self.hb);
        e.usize(self.covered);
        e.usize(self.win_base);
        e.usize(self.base.len());
        for &b in &self.base {
            e.u64(b);
        }
        e.usize(self.had_pending.len());
        for &p in &self.had_pending {
            e.bool(p);
        }
        self.blacklist.encode(e);
    }

    fn restore_state(&mut self, d: &mut Dec, view: &SchedView) -> Result<(), String> {
        self.hb = d.u64()?;
        self.covered = d.usize()?;
        self.win_base = d.usize()?;
        if self.win_base != view.jobs_base {
            return Err(format!(
                "delay snapshot window base {} != view jobs_base {}",
                self.win_base, view.jobs_base
            ));
        }
        let n = d.len(8)?;
        self.base = (0..n).map(|_| d.u64()).collect::<Result<_, _>>()?;
        let n = d.len(1)?;
        self.had_pending = (0..n).map(|_| d.bool()).collect::<Result<_, _>>()?;
        self.index.clear();
        self.index.set_base(view.jobs_base);
        for job in view.jobs {
            if job.id.idx() < self.covered {
                self.index.set_key(job.id, active_key(job));
            }
        }
        self.blacklist.decode(d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::testutil::*;

    #[test]
    fn waits_before_going_remote() {
        let mut w = TestWorld::one_job_no_local_on(NodeId(0));
        let mut s = DelayScheduler::new(2);
        // Heartbeats 1 and 2: job has no local block on node 0 -> skipped.
        for _ in 0..2 {
            let a = w.heartbeat_with(&mut s, NodeId(0));
            assert!(
                a.iter().all(|x| !matches!(x, Action::LaunchMap { .. })),
                "must wait while under patience"
            );
        }
        // Heartbeat 3: patience exhausted -> remote launch allowed.
        let a = w.heartbeat_with(&mut s, NodeId(0));
        assert!(
            a.iter().any(|x| matches!(x, Action::LaunchMap { .. })),
            "must go remote after patience"
        );
    }

    #[test]
    fn zero_patience_equals_fair() {
        let mut w = TestWorld::one_job_no_local_on(NodeId(0));
        let mut s = DelayScheduler::new(0);
        let a = w.heartbeat_with(&mut s, NodeId(0));
        assert!(a.iter().any(|x| matches!(x, Action::LaunchMap { .. })));
    }

    #[test]
    fn tiered_patience_caps() {
        use LocalityTier::{NodeLocal, RackLocal, Remote};
        // Flat: a single threshold, the seed behaviour.
        assert_eq!(DelayScheduler::tier_cap(3, 2, false), NodeLocal);
        assert_eq!(DelayScheduler::tier_cap(3, 3, false), Remote);
        // Racked: rack-local unlocks at patience, off-rack at 2x.
        assert_eq!(DelayScheduler::tier_cap(3, 2, true), NodeLocal);
        assert_eq!(DelayScheduler::tier_cap(3, 3, true), RackLocal);
        assert_eq!(DelayScheduler::tier_cap(3, 5, true), RackLocal);
        assert_eq!(DelayScheduler::tier_cap(3, 6, true), Remote);
        // Zero patience goes remote immediately on either topology.
        assert_eq!(DelayScheduler::tier_cap(0, 0, true), Remote);
        assert_eq!(DelayScheduler::tier_cap(0, 0, false), Remote);
    }

    #[test]
    fn local_launch_resets_patience() {
        let mut w = TestWorld::two_jobs();
        let mut s = DelayScheduler::new(3);
        // A node that has local work: launches happen, counter stays 0.
        let node = w.node_with_local_for(0);
        let a = w.heartbeat_with(&mut s, node);
        assert!(a.iter().any(|x| matches!(x, Action::LaunchMap { .. })));
        assert_eq!(s.skipped_for(&w.view_jobs()[0]), 0);
    }

    #[test]
    fn virtual_counter_accumulates_without_launch() {
        let mut w = TestWorld::one_job_no_local_on(NodeId(0));
        let mut s = DelayScheduler::new(10);
        for expect in 0..3u32 {
            assert_eq!(
                s.hb as u32, expect,
                "one heartbeat callback per driven heartbeat"
            );
            let _ = w.heartbeat_with(&mut s, NodeId(0));
            assert_eq!(s.skipped_for(&w.view_jobs()[0]), expect + 1);
        }
    }
}
