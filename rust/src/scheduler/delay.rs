//! Delay Scheduling (Zaharia et al., EuroSys'10 — the paper's ref [16]):
//! Fair Scheduler ranking, but a job with no node-local task *waits* for
//! up to `patience` heartbeats before degrading its locality. Improves
//! locality without VM reconfiguration — the natural software-only
//! baseline against the paper's hot-plug approach.
//!
//! On racked topologies the wait is **tiered**, the two-level scheme of
//! Zaharia et al. §4.2 (and the rack-aware follow-ups, arXiv:1506.00425):
//! a skipped job first unlocks *rack-local* tasks after `patience`
//! heartbeats, and only unlocks *off-rack* tasks after `2 * patience`.
//! On the flat topology there is no rack tier, so the single threshold
//! degenerates to the original local-then-remote behaviour (byte-
//! identical to the seed). One skip counter per job is kept; any map
//! launch for the job resets it (a simplification of the paper's
//! per-level timers that keeps the state machine one integer).

use crate::cluster::{LocalityTier, NodeId};
use crate::predictor::Predictor;

use super::{
    greedy_fill, speculative_fill, Action, ClaimLedger, FairScheduler, SchedView, Scheduler,
    SchedulerKind,
};

#[derive(Debug)]
pub struct DelayScheduler {
    patience: u32,
    /// Heartbeats each job has been skipped for lack of a local task,
    /// indexed by job (dense — jobs are numbered in arrival order; absent
    /// == 0, the `HashMap` semantics of the seed without its per-entry
    /// allocation and hashing).
    skipped: Vec<u32>,
    /// Pooled job-order and claim buffers (reused every heartbeat).
    order: Vec<usize>,
    claims: ClaimLedger,
}

impl DelayScheduler {
    pub fn new(patience: u32) -> Self {
        Self {
            patience,
            skipped: Vec::new(),
            order: Vec::new(),
            claims: ClaimLedger::new(),
        }
    }

    /// Worst locality tier `job` may accept after `skipped` fruitless
    /// heartbeats: node-only below `patience`; then rack-local (racked
    /// topologies) at `patience`; off-rack at `2 * patience` (or already
    /// at `patience` when there is no rack tier to wait for). Shared with
    /// the naive reference implementation (`scheduler::reference`).
    pub(crate) fn tier_cap(patience: u32, skipped: u32, racked: bool) -> LocalityTier {
        if !racked {
            if skipped >= patience {
                LocalityTier::Remote
            } else {
                LocalityTier::NodeLocal
            }
        } else if skipped >= patience.saturating_mul(2) {
            LocalityTier::Remote
        } else if skipped >= patience {
            LocalityTier::RackLocal
        } else {
            LocalityTier::NodeLocal
        }
    }
}

impl Scheduler for DelayScheduler {
    fn kind(&self) -> SchedulerKind {
        SchedulerKind::Delay
    }

    fn on_heartbeat(
        &mut self,
        view: &SchedView,
        node: NodeId,
        _predictor: &mut dyn Predictor,
        out: &mut Vec<Action>,
    ) {
        FairScheduler::fair_order_into(view, &mut self.order);
        if self.skipped.len() < view.jobs.len() {
            self.skipped.resize(view.jobs.len(), 0);
        }
        // A job degrades one locality tier per exhausted patience window.
        let skipped = &self.skipped;
        let patience = self.patience;
        let racked = view.cluster.topology().is_racked();
        greedy_fill(
            view,
            node,
            &self.order,
            &mut self.claims,
            |job| Self::tier_cap(patience, skipped[job.id.idx()], racked),
            out,
        );
        // Update skip counters: jobs with pending maps that got nothing
        // local on this heartbeat accumulate patience; a map launch
        // resets it (Zaharia et al. §4.1). greedy_fill claims every map
        // it launches in this generation, so "did this job get a map
        // launch" is an O(1) ledger lookup, not a rescan of the
        // appended actions.
        for &ji in &self.order {
            let job = &view.jobs[ji];
            if job.pending_maps() == 0 {
                self.skipped[job.id.idx()] = 0;
            } else if self.claims.maps_claimed(job.id) > 0 {
                self.skipped[job.id.idx()] = 0;
            } else {
                self.skipped[job.id.idx()] += 1;
            }
        }
        speculative_fill(view, node, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::testutil::*;

    #[test]
    fn waits_before_going_remote() {
        let mut w = TestWorld::one_job_no_local_on(NodeId(0));
        let mut s = DelayScheduler::new(2);
        // Heartbeats 1 and 2: job has no local block on node 0 -> skipped.
        for _ in 0..2 {
            let a = w.heartbeat_with(&mut s, NodeId(0));
            assert!(
                a.iter().all(|x| !matches!(x, Action::LaunchMap { .. })),
                "must wait while under patience"
            );
        }
        // Heartbeat 3: patience exhausted -> remote launch allowed.
        let a = w.heartbeat_with(&mut s, NodeId(0));
        assert!(
            a.iter().any(|x| matches!(x, Action::LaunchMap { .. })),
            "must go remote after patience"
        );
    }

    #[test]
    fn zero_patience_equals_fair() {
        let mut w = TestWorld::one_job_no_local_on(NodeId(0));
        let mut s = DelayScheduler::new(0);
        let a = w.heartbeat_with(&mut s, NodeId(0));
        assert!(a.iter().any(|x| matches!(x, Action::LaunchMap { .. })));
    }

    #[test]
    fn tiered_patience_caps() {
        use LocalityTier::{NodeLocal, RackLocal, Remote};
        // Flat: a single threshold, the seed behaviour.
        assert_eq!(DelayScheduler::tier_cap(3, 2, false), NodeLocal);
        assert_eq!(DelayScheduler::tier_cap(3, 3, false), Remote);
        // Racked: rack-local unlocks at patience, off-rack at 2x.
        assert_eq!(DelayScheduler::tier_cap(3, 2, true), NodeLocal);
        assert_eq!(DelayScheduler::tier_cap(3, 3, true), RackLocal);
        assert_eq!(DelayScheduler::tier_cap(3, 5, true), RackLocal);
        assert_eq!(DelayScheduler::tier_cap(3, 6, true), Remote);
        // Zero patience goes remote immediately on either topology.
        assert_eq!(DelayScheduler::tier_cap(0, 0, true), Remote);
        assert_eq!(DelayScheduler::tier_cap(0, 0, false), Remote);
    }

    #[test]
    fn local_launch_resets_patience() {
        let mut w = TestWorld::two_jobs();
        let mut s = DelayScheduler::new(3);
        // A node that has local work: launches happen, counter stays 0.
        let node = w.node_with_local_for(0);
        let a = w.heartbeat_with(&mut s, node);
        assert!(a.iter().any(|x| matches!(x, Action::LaunchMap { .. })));
        assert_eq!(s.skipped.first().copied().unwrap_or(0), 0);
    }
}
