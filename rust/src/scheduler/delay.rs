//! Delay Scheduling (Zaharia et al., EuroSys'10 — the paper's ref [16]):
//! Fair Scheduler ranking, but a job with no node-local task *waits* for
//! up to `patience` heartbeats before accepting a remote task. Improves
//! locality without VM reconfiguration — the natural software-only
//! baseline against the paper's hot-plug approach.

use std::collections::HashMap;

use crate::cluster::NodeId;
use crate::mapreduce::JobId;
use crate::predictor::Predictor;

use super::{greedy_fill, Action, FairScheduler, SchedView, Scheduler, SchedulerKind};

#[derive(Debug)]
pub struct DelayScheduler {
    patience: u32,
    /// Heartbeats each job has been skipped for lack of a local task.
    skipped: HashMap<JobId, u32>,
}

impl DelayScheduler {
    pub fn new(patience: u32) -> Self {
        Self {
            patience,
            skipped: HashMap::new(),
        }
    }
}

impl Scheduler for DelayScheduler {
    fn kind(&self) -> SchedulerKind {
        SchedulerKind::Delay
    }

    fn on_heartbeat(
        &mut self,
        view: &SchedView,
        node: NodeId,
        _predictor: &mut dyn Predictor,
    ) -> Vec<Action> {
        let order = FairScheduler::fair_order(view);
        // A job may go remote once its skip counter exceeded patience.
        let skipped = &self.skipped;
        let patience = self.patience;
        let actions = greedy_fill(view, node, &order, |job| {
            skipped.get(&job.id).copied().unwrap_or(0) >= patience
        });
        // Update skip counters: jobs with pending maps that got nothing
        // local on this heartbeat accumulate patience; a local launch
        // resets it (Zaharia et al. §4.1).
        for &ji in &order {
            let job = &view.jobs[ji];
            if job.pending_maps() == 0 {
                self.skipped.remove(&job.id);
                continue;
            }
            let launched_for_job = actions.iter().any(|a| {
                matches!(a, Action::LaunchMap { job: j, .. } if *j == job.id)
            });
            if launched_for_job {
                self.skipped.remove(&job.id);
            } else {
                *self.skipped.entry(job.id).or_insert(0) += 1;
            }
        }
        actions
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::testutil::*;

    #[test]
    fn waits_before_going_remote() {
        let mut w = TestWorld::one_job_no_local_on(NodeId(0));
        let mut s = DelayScheduler::new(2);
        // Heartbeats 1 and 2: job has no local block on node 0 -> skipped.
        for _ in 0..2 {
            let a = w.heartbeat_with(&mut s, NodeId(0));
            assert!(
                a.iter().all(|x| !matches!(x, Action::LaunchMap { .. })),
                "must wait while under patience"
            );
        }
        // Heartbeat 3: patience exhausted -> remote launch allowed.
        let a = w.heartbeat_with(&mut s, NodeId(0));
        assert!(
            a.iter().any(|x| matches!(x, Action::LaunchMap { .. })),
            "must go remote after patience"
        );
    }

    #[test]
    fn zero_patience_equals_fair() {
        let mut w = TestWorld::one_job_no_local_on(NodeId(0));
        let mut s = DelayScheduler::new(0);
        let a = w.heartbeat_with(&mut s, NodeId(0));
        assert!(a.iter().any(|x| matches!(x, Action::LaunchMap { .. })));
    }

    #[test]
    fn local_launch_resets_patience() {
        let mut w = TestWorld::two_jobs();
        let mut s = DelayScheduler::new(3);
        // A node that has local work: launches happen, counter stays 0.
        let node = w.node_with_local_for(0);
        let a = w.heartbeat_with(&mut s, node);
        assert!(a.iter().any(|x| matches!(x, Action::LaunchMap { .. })));
        assert_eq!(s.skipped.get(&crate::mapreduce::JobId(0)), None);
    }
}
