//! Earliest-Deadline-First baseline: jobs ranked by absolute deadline
//! (best-effort jobs last, by submission), greedy local-else-remote fill.
//! This isolates the paper's *job ordering* from its reconfiguration
//! mechanism — the ablation between EDF and DeadlineVc measures what the
//! hot-plug machinery itself buys.

use crate::cluster::{LocalityTier, NodeId};
use crate::mapreduce::JobId;
use crate::predictor::Predictor;
use crate::sim::SimTime;

use super::{greedy_fill, speculative_fill, Action, ClaimLedger, SchedView, Scheduler, SchedulerKind};

/// Pooled `(deadline, submitted, id, index)` sort keys for
/// [`EdfScheduler::edf_order_into`] — `id` is unique, so sorting the
/// precomputed tuples unstably reproduces the stable
/// sort-by-cached-key order without allocating a key cache per heartbeat
/// (deadline_at() does float math; evaluating it inside the comparator
/// was ~10% of the scheduler profile).
pub(crate) type EdfKeys = Vec<(SimTime, SimTime, JobId, u32)>;

#[derive(Debug, Default)]
pub struct EdfScheduler {
    /// Pooled key/order/claim buffers (reused every heartbeat).
    keys: EdfKeys,
    order: Vec<usize>,
    claims: ClaimLedger,
}

impl EdfScheduler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Deadline order into `order` (pooled): earliest absolute deadline
    /// first; best-effort jobs after all deadlined jobs, oldest first.
    pub(crate) fn edf_order_into(view: &SchedView, keys: &mut EdfKeys, order: &mut Vec<usize>) {
        keys.clear();
        for (i, j) in view.jobs.iter().enumerate() {
            if j.is_done() {
                continue;
            }
            let deadline = j.deadline_at().unwrap_or(SimTime(u64::MAX));
            keys.push((deadline, j.submitted, j.id, i as u32));
        }
        keys.sort_unstable();
        order.clear();
        order.extend(keys.iter().map(|&(_, _, _, i)| i as usize));
    }

    /// Allocating convenience wrapper around [`Self::edf_order_into`]
    /// (tests and the naive reference implementations).
    pub(crate) fn edf_order(view: &SchedView) -> Vec<usize> {
        let (mut keys, mut order) = (Vec::new(), Vec::new());
        Self::edf_order_into(view, &mut keys, &mut order);
        order
    }
}

impl Scheduler for EdfScheduler {
    fn kind(&self) -> SchedulerKind {
        SchedulerKind::Edf
    }

    fn on_heartbeat(
        &mut self,
        view: &SchedView,
        node: NodeId,
        _predictor: &mut dyn Predictor,
        out: &mut Vec<Action>,
    ) {
        Self::edf_order_into(view, &mut self.keys, &mut self.order);
        greedy_fill(view, node, &self.order, &mut self.claims, |_| LocalityTier::Remote, out);
        speculative_fill(view, node, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::testutil::*;

    #[test]
    fn earliest_deadline_first() {
        let mut w = TestWorld::two_jobs_with_deadlines(900.0, 300.0);
        let actions = w.heartbeat_with(&mut EdfScheduler::new(), NodeId(0));
        let first_job = actions.iter().find_map(|a| match a {
            Action::LaunchMap { job, .. } => Some(job.0),
            _ => None,
        });
        assert_eq!(first_job, Some(1), "job 1 (D=300) must be served first");
    }

    #[test]
    fn best_effort_jobs_rank_last() {
        let w = TestWorld::deadline_and_best_effort();
        let view = w.view();
        let order = EdfScheduler::edf_order(&view);
        // job 1 has the deadline, job 0 is best-effort.
        assert_eq!(view.jobs[order[0]].id.0, 1);
    }
}
