//! Earliest-Deadline-First baseline: jobs ranked by absolute deadline
//! (best-effort jobs last, by submission), greedy local-else-remote fill.
//! This isolates the paper's *job ordering* from its reconfiguration
//! mechanism — the ablation between EDF and DeadlineVc measures what the
//! hot-plug machinery itself buys.
//!
//! The EDF key `(deadline, submitted)` is *static* per job, so the
//! persistent [`OrderIndex`] is written once at arrival and only ever
//! touched again to drop finished jobs — the per-heartbeat sort (and its
//! pooled key cache) is gone entirely.

use crate::cluster::{LocalityTier, NodeId, PmId};
use crate::mapreduce::{JobId, JobState};
use crate::predictor::Predictor;
use crate::sim::SimTime;
use crate::util::codec::{Dec, Enc};

use super::{
    greedy_fill, speculative_fill, Action, BlacklistPolicy, ClaimLedger, OrderIndex, SchedView,
    Scheduler, SchedulerKind,
};

/// Pooled `(deadline, submitted, id, index)` sort keys for
/// [`EdfScheduler::edf_order_into`] — `id` is unique, so sorting the
/// precomputed tuples unstably reproduces the stable
/// sort-by-cached-key order without allocating a key cache per heartbeat.
/// Retained for the from-scratch oracle and the DeadlineVc reference.
pub(crate) type EdfKeys = Vec<(SimTime, SimTime, JobId, u32)>;

/// The persistent EDF ranking key: absolute deadline (best-effort jobs
/// sort last via `u64::MAX`), then submission time; `JobId` breaks the
/// remaining ties inside the index.
pub(crate) type EdfKey = (SimTime, SimTime);

pub(crate) fn edf_key(job: &JobState) -> EdfKey {
    (
        job.deadline_at().unwrap_or(SimTime(u64::MAX)),
        job.submitted,
    )
}

#[derive(Debug, Default)]
pub struct EdfScheduler {
    index: OrderIndex<EdfKey>,
    covered: usize,
    claims: ClaimLedger,
    blacklist: BlacklistPolicy,
}

impl EdfScheduler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Deadline order into `order` (pooled): earliest absolute deadline
    /// first; best-effort jobs after all deadlined jobs, oldest first.
    /// Retained as the from-scratch oracle for the persistent index.
    pub(crate) fn edf_order_into(view: &SchedView, keys: &mut EdfKeys, order: &mut Vec<usize>) {
        keys.clear();
        for (i, j) in view.jobs.iter().enumerate() {
            if j.is_done() {
                continue;
            }
            let deadline = j.deadline_at().unwrap_or(SimTime(u64::MAX));
            keys.push((deadline, j.submitted, j.id, i as u32));
        }
        keys.sort_unstable();
        order.clear();
        order.extend(keys.iter().map(|&(_, _, _, i)| i as usize));
    }

    /// Allocating convenience wrapper around [`Self::edf_order_into`]
    /// (tests and the naive reference implementations).
    pub(crate) fn edf_order(view: &SchedView) -> Vec<usize> {
        let (mut keys, mut order) = (Vec::new(), Vec::new());
        Self::edf_order_into(view, &mut keys, &mut order);
        order
    }

    fn sync(&mut self, view: &SchedView) {
        let total = view.total_jobs();
        if self.covered > total {
            self.index.clear();
            self.covered = 0;
        }
        self.index.set_base(view.jobs_base);
        for job in &view.jobs[self.covered.max(view.jobs_base) - view.jobs_base..] {
            self.index.set_key(job.id, active_key(job));
        }
        self.covered = total;
    }
}

fn active_key(job: &JobState) -> Option<EdfKey> {
    if job.is_done() {
        None
    } else {
        Some(edf_key(job))
    }
}

impl Scheduler for EdfScheduler {
    fn kind(&self) -> SchedulerKind {
        SchedulerKind::Edf
    }

    fn on_sim_start(&mut self, view: &SchedView) {
        self.index.clear();
        self.covered = 0;
        self.blacklist = BlacklistPolicy::new(view.cfg);
    }

    fn on_job_updated(&mut self, view: &SchedView, job: JobId) {
        self.sync(view);
        self.index.set_key(job, active_key(view.job(job)));
    }

    fn check_index(&self, view: &SchedView) -> Result<(), String> {
        let mut expect: Vec<(EdfKey, JobId)> =
            view.active_jobs().map(|j| (edf_key(j), j.id)).collect();
        expect.sort_unstable();
        self.index.check_matches(&expect)?;
        for (got, &ji) in self.index.iter().zip(&Self::edf_order(view)) {
            if view.slot(got) != ji {
                return Err(format!(
                    "index order diverges from edf_order: {got:?} vs index {ji}"
                ));
            }
        }
        self.claims.check_against(view.jobs)
    }

    fn on_job_added(
        &mut self,
        view: &SchedView,
        _job: JobId,
        _predictor: &mut dyn Predictor,
        _out: &mut Vec<Action>,
    ) {
        self.sync(view);
    }

    fn on_heartbeat(
        &mut self,
        view: &SchedView,
        node: NodeId,
        _predictor: &mut dyn Predictor,
        out: &mut Vec<Action>,
    ) {
        self.sync(view);
        if self.blacklist.blocks_node(view, node) {
            return;
        }
        let Self {
            ref index,
            ref mut claims,
            ..
        } = *self;
        greedy_fill(
            view,
            node,
            index.iter().map(|j| view.slot(j)),
            claims,
            |_| LocalityTier::Remote,
            out,
        );
        speculative_fill(view, node, out);
    }

    fn on_pm_failure(&mut self, view: &SchedView, pm: PmId) {
        self.blacklist.on_pm_failure(pm, view.now);
    }

    fn encode_state(&self, enc: &mut Enc) {
        self.blacklist.encode(enc);
    }

    fn restore_state(&mut self, dec: &mut Dec, _view: &SchedView) -> Result<(), String> {
        self.blacklist.decode(dec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::testutil::*;

    #[test]
    fn earliest_deadline_first() {
        let mut w = TestWorld::two_jobs_with_deadlines(900.0, 300.0);
        let actions = w.heartbeat_with(&mut EdfScheduler::new(), NodeId(0));
        let first_job = actions.iter().find_map(|a| match a {
            Action::LaunchMap { job, .. } => Some(job.0),
            _ => None,
        });
        assert_eq!(first_job, Some(1), "job 1 (D=300) must be served first");
    }

    #[test]
    fn best_effort_jobs_rank_last() {
        let w = TestWorld::deadline_and_best_effort();
        let view = w.view();
        let order = EdfScheduler::edf_order(&view);
        // job 1 has the deadline, job 0 is best-effort.
        assert_eq!(view.jobs[order[0]].id.0, 1);
    }

    #[test]
    fn index_matches_edf_sort() {
        let w = TestWorld::two_jobs_with_deadlines(900.0, 300.0);
        let mut s = EdfScheduler::new();
        let view = w.view();
        for job in view.jobs {
            s.on_job_updated(&view, job.id);
        }
        s.check_index(&view).unwrap();
    }
}
