//! Earliest-Deadline-First baseline: jobs ranked by absolute deadline
//! (best-effort jobs last, by submission), greedy local-else-remote fill.
//! This isolates the paper's *job ordering* from its reconfiguration
//! mechanism — the ablation between EDF and DeadlineVc measures what the
//! hot-plug machinery itself buys.

use crate::cluster::{LocalityTier, NodeId};
use crate::predictor::Predictor;
use crate::sim::SimTime;

use super::{greedy_fill, Action, SchedView, Scheduler, SchedulerKind};

#[derive(Debug, Default)]
pub struct EdfScheduler;

impl EdfScheduler {
    pub fn new() -> Self {
        Self
    }

    /// Deadline order: earliest absolute deadline first; best-effort jobs
    /// after all deadlined jobs, oldest first.
    pub(crate) fn edf_order(view: &SchedView) -> Vec<usize> {
        let mut order: Vec<usize> = (0..view.jobs.len())
            .filter(|&i| !view.jobs[i].is_done())
            .collect();
        // cached: deadline_at() does float math; evaluating it inside the
        // comparator was ~10% of the scheduler profile.
        order.sort_by_cached_key(|&i| {
            let j = &view.jobs[i];
            (
                j.deadline_at().unwrap_or(SimTime(u64::MAX)),
                j.submitted,
                j.id,
            )
        });
        order
    }
}

impl Scheduler for EdfScheduler {
    fn kind(&self) -> SchedulerKind {
        SchedulerKind::Edf
    }

    fn on_heartbeat(
        &mut self,
        view: &SchedView,
        node: NodeId,
        _predictor: &mut dyn Predictor,
    ) -> Vec<Action> {
        let order = Self::edf_order(view);
        greedy_fill(view, node, &order, |_| LocalityTier::Remote)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::testutil::*;

    #[test]
    fn earliest_deadline_first() {
        let mut w = TestWorld::two_jobs_with_deadlines(900.0, 300.0);
        let actions = w.heartbeat_with(&mut EdfScheduler::new(), NodeId(0));
        let first_job = actions.iter().find_map(|a| match a {
            Action::LaunchMap { job, .. } => Some(job.0),
            _ => None,
        });
        assert_eq!(first_job, Some(1), "job 1 (D=300) must be served first");
    }

    #[test]
    fn best_effort_jobs_rank_last() {
        let w = TestWorld::deadline_and_best_effort();
        let view = w.view();
        let order = EdfScheduler::edf_order(&view);
        // job 1 has the deadline, job 0 is best-effort.
        assert_eq!(view.jobs[order[0]].id.0, 1);
    }
}
