//! Hadoop's default FIFO scheduler: jobs in submission order; on each
//! heartbeat the oldest unfinished job fills the node's free slots
//! (node-local map preferred, else any).

use crate::cluster::{LocalityTier, NodeId};
use crate::predictor::Predictor;

use super::{greedy_fill, speculative_fill, Action, ClaimLedger, SchedView, Scheduler, SchedulerKind};

#[derive(Debug, Default)]
pub struct FifoScheduler {
    /// Pooled job-order and claim buffers (reused every heartbeat).
    order: Vec<usize>,
    claims: ClaimLedger,
}

impl FifoScheduler {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Scheduler for FifoScheduler {
    fn kind(&self) -> SchedulerKind {
        SchedulerKind::Fifo
    }

    fn on_heartbeat(
        &mut self,
        view: &SchedView,
        node: NodeId,
        _predictor: &mut dyn Predictor,
        out: &mut Vec<Action>,
    ) {
        // Submission order == JobId order == index order.
        self.order.clear();
        self.order.extend((0..view.jobs.len()).filter(|&i| !view.jobs[i].is_done()));
        greedy_fill(view, node, &self.order, &mut self.claims, |_| LocalityTier::Remote, out);
        speculative_fill(view, node, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::testutil::*;

    #[test]
    fn oldest_job_first() {
        let mut w = TestWorld::two_jobs();
        let actions = w.heartbeat_with(&mut FifoScheduler::new(), NodeId(0));
        // All launches must belong to job 0 until it runs out of tasks.
        let jobs: Vec<u32> = actions
            .iter()
            .filter_map(|a| match a {
                Action::LaunchMap { job, .. } => Some(job.0),
                _ => None,
            })
            .collect();
        assert!(!jobs.is_empty());
        assert!(jobs.iter().all(|&j| j == 0), "FIFO must drain job 0 first: {jobs:?}");
    }

    #[test]
    fn fills_all_free_slots() {
        let mut w = TestWorld::two_jobs();
        let actions = w.heartbeat_with(&mut FifoScheduler::new(), NodeId(1));
        let maps = actions
            .iter()
            .filter(|a| matches!(a, Action::LaunchMap { .. }))
            .count();
        assert_eq!(maps, 2, "2 free map slots must be filled");
    }

    #[test]
    fn no_reduce_before_map_phase_done() {
        let mut w = TestWorld::two_jobs();
        let actions = w.heartbeat_with(&mut FifoScheduler::new(), NodeId(0));
        assert!(actions
            .iter()
            .all(|a| !matches!(a, Action::LaunchReduce { .. })));
    }
}
