//! Hadoop's default FIFO scheduler: jobs in submission order; on each
//! heartbeat the oldest unfinished job fills the node's free slots
//! (node-local map preferred, else any).

use crate::cluster::{LocalityTier, NodeId, PmId};
use crate::mapreduce::JobId;
use crate::predictor::Predictor;
use crate::util::codec::{Dec, Enc};

use super::{
    greedy_fill, speculative_fill, Action, BlacklistPolicy, ClaimLedger, OrderIndex, SchedView,
    Scheduler, SchedulerKind,
};

/// Submission order == JobId order, so the persistent index needs no key
/// at all: a `BTreeSet<((), JobId)>` of active jobs, pruned as jobs
/// finish. The heartbeat walks it lazily and stops once the node is full.
#[derive(Debug, Default)]
pub struct FifoScheduler {
    index: OrderIndex<()>,
    /// Jobs already inserted into the index (high-water mark).
    covered: usize,
    claims: ClaimLedger,
    blacklist: BlacklistPolicy,
}

impl FifoScheduler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert jobs that arrived since the last callback (`covered` counts
    /// *absolute* job ids, so the window base never double-inserts) and
    /// drop stale state when the world shrank (scheduler reuse across
    /// Worlds).
    fn sync(&mut self, view: &SchedView) {
        let total = view.total_jobs();
        if self.covered > total {
            self.index.clear();
            self.covered = 0;
        }
        self.index.set_base(view.jobs_base);
        for job in &view.jobs[self.covered.max(view.jobs_base) - view.jobs_base..] {
            self.index
                .set_key(job.id, if job.is_done() { None } else { Some(()) });
        }
        self.covered = total;
    }
}

impl Scheduler for FifoScheduler {
    fn kind(&self) -> SchedulerKind {
        SchedulerKind::Fifo
    }

    fn on_sim_start(&mut self, view: &SchedView) {
        self.index.clear();
        self.covered = 0;
        self.blacklist = BlacklistPolicy::new(view.cfg);
    }

    fn on_job_updated(&mut self, view: &SchedView, job: JobId) {
        self.sync(view);
        let done = view.job(job).is_done();
        self.index.set_key(job, if done { None } else { Some(()) });
    }

    fn check_index(&self, view: &SchedView) -> Result<(), String> {
        let expect: Vec<((), JobId)> = view.active_jobs().map(|j| ((), j.id)).collect();
        self.index.check_matches(&expect)?;
        self.claims.check_against(view.jobs)
    }

    fn on_job_added(
        &mut self,
        view: &SchedView,
        _job: JobId,
        _predictor: &mut dyn Predictor,
        _out: &mut Vec<Action>,
    ) {
        self.sync(view);
    }

    fn on_heartbeat(
        &mut self,
        view: &SchedView,
        node: NodeId,
        _predictor: &mut dyn Predictor,
        out: &mut Vec<Action>,
    ) {
        self.sync(view);
        if self.blacklist.blocks_node(view, node) {
            return;
        }
        let Self {
            ref index,
            ref mut claims,
            ..
        } = *self;
        greedy_fill(
            view,
            node,
            index.iter().map(|j| view.slot(j)),
            claims,
            |_| LocalityTier::Remote,
            out,
        );
        speculative_fill(view, node, out);
    }

    fn on_pm_failure(&mut self, view: &SchedView, pm: PmId) {
        self.blacklist.on_pm_failure(pm, view.now);
    }

    fn encode_state(&self, enc: &mut Enc) {
        self.blacklist.encode(enc);
    }

    fn restore_state(&mut self, dec: &mut Dec, _view: &SchedView) -> Result<(), String> {
        self.blacklist.decode(dec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::testutil::*;

    #[test]
    fn oldest_job_first() {
        let mut w = TestWorld::two_jobs();
        let actions = w.heartbeat_with(&mut FifoScheduler::new(), NodeId(0));
        // All launches must belong to job 0 until it runs out of tasks.
        let jobs: Vec<u32> = actions
            .iter()
            .filter_map(|a| match a {
                Action::LaunchMap { job, .. } => Some(job.0),
                _ => None,
            })
            .collect();
        assert!(!jobs.is_empty());
        assert!(jobs.iter().all(|&j| j == 0), "FIFO must drain job 0 first: {jobs:?}");
    }

    #[test]
    fn fills_all_free_slots() {
        let mut w = TestWorld::two_jobs();
        let actions = w.heartbeat_with(&mut FifoScheduler::new(), NodeId(1));
        let maps = actions
            .iter()
            .filter(|a| matches!(a, Action::LaunchMap { .. }))
            .count();
        assert_eq!(maps, 2, "2 free map slots must be filled");
    }

    #[test]
    fn no_reduce_before_map_phase_done() {
        let mut w = TestWorld::two_jobs();
        let actions = w.heartbeat_with(&mut FifoScheduler::new(), NodeId(0));
        assert!(actions
            .iter()
            .all(|a| !matches!(a, Action::LaunchReduce { .. })));
    }
}
