//! The retained **naive-scan reference schedulers** — the pre-index hot
//! path, kept verbatim so the optimized loop can be checked and measured
//! against it.
//!
//! The indexed schedulers (`fifo`/`fair`/`delay`/`edf`/`deadline_vc`)
//! replaced three O(jobs × tasks) patterns with O(1)-amortized ones:
//! filter-scan pending iterators (now lazily-pruned cursors in
//! `mapreduce::JobState`), per-heartbeat `HashSet` claim sets and the
//! `pending_reduces_iter().nth(skip)` reduce pick (now the
//! generation-stamped `ClaimLedger`), and freshly
//! allocated action/order vectors (now pooled buffers). This module keeps
//! the *original* structures — `HashSet` claims, `HashMap` counters,
//! `*_scan` iterators, per-heartbeat allocation — behind the same
//! [`Scheduler`] trait, so that:
//!
//! * `tests/differential_reference.rs` can run both implementations over
//!   the full scheduler × topology × seed matrix and assert **identical
//!   event logs and bitwise-equal reports** — the coordinator's
//!   event-sourced log ([`crate::coordinator::LogEntry`]) captures every
//!   scheduler-visible event together with the actions it emitted, so
//!   the comparison needs no bespoke recording probe (the optimization
//!   changes no simulated outcome, only wall time);
//! * `benches/simcore.rs` can report events/sec of the indexed loop
//!   against this baseline on the `stress` scenario and write the ratio
//!   into `BENCH_simcore.json`.
//!
//! The one deliberate departure from the seed: the DeadlineVc await
//! ledger is the same insertion-ordered `Vec` the optimized scheduler
//! uses (the seed's `HashMap` emitted CancelAwait actions in
//! nondeterministic iteration order — outcome-equivalent, since cancels
//! commute, but not stream-comparable).

use std::collections::{HashMap, HashSet};

use crate::cluster::{LocalityTier, NodeId, PmId};
use crate::config::SimConfig;
use crate::mapreduce::{JobId, JobState, TaskId};
use crate::predictor::Predictor;
use crate::sim::SimTime;

use super::deadline_vc::{choose_target_with, job_demand};
use super::{
    speculative_fill, Action, BlacklistPolicy, DeadlineVcScheduler, DvcTuning, EdfScheduler,
    FairScheduler, SchedView, Scheduler, SchedulerKind,
};

/// Build the naive reference implementation of `kind` (same policy, seed
/// data structures). Pair with [`SchedulerKind::build`] for differential
/// runs.
pub fn build_reference(kind: SchedulerKind, cfg: &SimConfig) -> Box<dyn Scheduler> {
    match kind {
        SchedulerKind::Fifo | SchedulerKind::Fair | SchedulerKind::Edf => Box::new(NaiveGreedy {
            kind,
            blacklist: BlacklistPolicy::new(cfg),
        }),
        SchedulerKind::Delay => Box::new(NaiveDelay {
            patience: cfg.delay_heartbeats,
            skipped: HashMap::new(),
            blacklist: BlacklistPolicy::new(cfg),
        }),
        SchedulerKind::DeadlineVc => Box::new(NaiveDeadlineVc::new(cfg)),
    }
}

/// Per-heartbeat claim set, the seed structure (see module docs).
type ClaimSet = HashSet<(JobId, TaskId)>;

fn next_unclaimed_local_scan(job: &JobState, node: NodeId, claimed: &ClaimSet) -> Option<TaskId> {
    job.pending_local_maps_scan(node)
        .find(|&t| !claimed.contains(&(job.id, t)))
}

fn next_unclaimed_rack_scan(job: &JobState, rack: u32, claimed: &ClaimSet) -> Option<TaskId> {
    job.pending_rack_maps_scan(rack)
        .find(|&t| !claimed.contains(&(job.id, t)))
}

fn next_unclaimed_any_scan(job: &JobState, claimed: &ClaimSet) -> Option<TaskId> {
    job.pending_maps_scan()
        .find(|&t| !claimed.contains(&(job.id, t)))
}

fn nth_pending_reduce_scan(job: &JobState, skip: u32) -> Option<TaskId> {
    job.pending_reduces_scan().nth(skip as usize)
}

/// The seed `greedy_fill`: fresh `HashSet`/`Vec` per heartbeat, linear
/// claimed-reduce count, naive scans.
fn greedy_fill_scan(
    view: &SchedView,
    node: NodeId,
    job_order: &[usize],
    max_tier_for: impl Fn(&JobState) -> LocalityTier,
) -> Vec<Action> {
    let mut actions = Vec::new();
    let vm = view.cluster.vm(node);
    let rack = view.cluster.rack_of(node);
    let racked = view.cluster.topology().is_racked();
    let mut free_map = vm.free_map_slots();
    let mut free_reduce = vm.free_reduce_slots();
    let mut claimed_maps = ClaimSet::new();
    let mut claimed_reduces: Vec<(JobId, u32)> = Vec::new();

    for &ji in job_order {
        let job = &view.jobs[ji];
        if job.is_done() {
            continue;
        }
        while free_map > 0 {
            let cap = max_tier_for(job);
            let pick = next_unclaimed_local_scan(job, node, &claimed_maps)
                .or_else(|| {
                    if racked && cap >= LocalityTier::RackLocal {
                        next_unclaimed_rack_scan(job, rack, &claimed_maps)
                    } else {
                        None
                    }
                })
                .or_else(|| {
                    if cap >= LocalityTier::Remote {
                        next_unclaimed_any_scan(job, &claimed_maps)
                    } else {
                        None
                    }
                });
            let Some(task) = pick else { break };
            claimed_maps.insert((job.id, task));
            actions.push(Action::LaunchMap {
                job: job.id,
                task,
                node,
            });
            free_map -= 1;
        }
        while free_reduce > 0 && job.map_finished() {
            let already: u32 = claimed_reduces
                .iter()
                .filter(|(j, _)| *j == job.id)
                .count() as u32;
            let Some(task) = nth_pending_reduce_scan(job, already) else { break };
            claimed_reduces.push((job.id, task.0));
            actions.push(Action::LaunchReduce {
                job: job.id,
                task,
                node,
            });
            free_reduce -= 1;
        }
    }
    actions
}

/// Naive FIFO / Fair / EDF: shared ordering policies (the order functions
/// are not what the index optimizes), naive greedy fill.
struct NaiveGreedy {
    kind: SchedulerKind,
    blacklist: BlacklistPolicy,
}

impl Scheduler for NaiveGreedy {
    fn kind(&self) -> SchedulerKind {
        self.kind
    }

    fn on_sim_start(&mut self, view: &SchedView) {
        self.blacklist = BlacklistPolicy::new(view.cfg);
    }

    fn on_pm_failure(&mut self, view: &SchedView, pm: PmId) {
        self.blacklist.on_pm_failure(pm, view.now);
    }

    fn on_heartbeat(
        &mut self,
        view: &SchedView,
        node: NodeId,
        _predictor: &mut dyn Predictor,
        out: &mut Vec<Action>,
    ) {
        if self.blacklist.blocks_node(view, node) {
            return;
        }
        let order: Vec<usize> = match self.kind {
            SchedulerKind::Fifo => (0..view.jobs.len())
                .filter(|&i| !view.jobs[i].is_done())
                .collect(),
            SchedulerKind::Fair => FairScheduler::fair_order(view),
            SchedulerKind::Edf => EdfScheduler::edf_order(view),
            _ => unreachable!("NaiveGreedy only wraps fifo/fair/edf"),
        };
        out.extend(greedy_fill_scan(view, node, &order, |_| {
            LocalityTier::Remote
        }));
        // The LATE pass is shared with the indexed schedulers verbatim:
        // it uses only plain scans, so it is honest reference code too.
        speculative_fill(view, node, out);
    }
}

/// Naive Delay scheduling: the seed's `HashMap` skip counters + naive
/// fill. The tier-cap policy is shared with the optimized scheduler.
struct NaiveDelay {
    patience: u32,
    skipped: HashMap<JobId, u32>,
    blacklist: BlacklistPolicy,
}

impl Scheduler for NaiveDelay {
    fn kind(&self) -> SchedulerKind {
        SchedulerKind::Delay
    }

    fn on_sim_start(&mut self, view: &SchedView) {
        self.skipped.clear();
        self.blacklist = BlacklistPolicy::new(view.cfg);
    }

    fn on_pm_failure(&mut self, view: &SchedView, pm: PmId) {
        self.blacklist.on_pm_failure(pm, view.now);
    }

    fn on_heartbeat(
        &mut self,
        view: &SchedView,
        node: NodeId,
        _predictor: &mut dyn Predictor,
        out: &mut Vec<Action>,
    ) {
        // Blacklisted heartbeats launch nothing and skip the patience
        // walk — waiting jobs burn no patience on a node that offered no
        // usable slot (mirrors the indexed scheduler's frozen virtual
        // clock).
        if self.blacklist.blocks_node(view, node) {
            return;
        }
        let order = FairScheduler::fair_order(view);
        let skipped = &self.skipped;
        let patience = self.patience;
        let racked = view.cluster.topology().is_racked();
        let actions = greedy_fill_scan(view, node, &order, |job| {
            let s = skipped.get(&job.id).copied().unwrap_or(0);
            super::DelayScheduler::tier_cap(patience, s, racked)
        });
        for &ji in &order {
            let job = &view.jobs[ji];
            if job.pending_maps() == 0 {
                self.skipped.remove(&job.id);
                continue;
            }
            let launched_for_job = actions
                .iter()
                .any(|a| matches!(a, Action::LaunchMap { job: j, .. } if *j == job.id));
            if launched_for_job {
                self.skipped.remove(&job.id);
            } else {
                *self.skipped.entry(job.id).or_insert(0) += 1;
            }
        }
        out.extend(actions);
        speculative_fill(view, node, out);
    }
}

/// Naive DeadlineVc: the seed heartbeat loop — per-heartbeat `HashSet`
/// claims, `HashMap` schedule counters, `nth(skip)` reduce picks, fresh
/// per-node slot vector — under the identical Alg. 1 + Alg. 2 policy.
struct NaiveDeadlineVc {
    tuning: DvcTuning,
    reconfig_timeout: SimTime,
    awaiting_since: Vec<(JobId, u32, SimTime)>,
    max_map_slots: u32,
    max_reduce_slots: u32,
    // Failure-reactive state, mirroring `DeadlineVcScheduler` (the naive
    // full sweep needs no dirty set — it recomputes every job anyway).
    replan: bool,
    pm_map_slots: u32,
    pm_reduce_slots: u32,
    live_map_slots: u32,
    live_reduce_slots: u32,
    blacklist: BlacklistPolicy,
}

impl NaiveDeadlineVc {
    fn new(cfg: &SimConfig) -> Self {
        let tuning = DvcTuning::default();
        Self {
            reconfig_timeout: SimTime::from_secs_f64(cfg.heartbeat_s * tuning.timeout_heartbeats),
            awaiting_since: Vec::new(),
            max_map_slots: cfg.total_map_slots(),
            max_reduce_slots: cfg.total_reduce_slots(),
            replan: cfg.failures.replan,
            pm_map_slots: cfg.vms_per_pm as u32 * cfg.base_vcpus,
            pm_reduce_slots: cfg.vms_per_pm as u32 * cfg.reduce_slots,
            live_map_slots: cfg.total_map_slots(),
            live_reduce_slots: cfg.total_reduce_slots(),
            blacklist: BlacklistPolicy::new(cfg),
            tuning,
        }
    }

    fn caps(&self) -> (u32, u32) {
        (self.live_map_slots.max(1), self.live_reduce_slots.max(1))
    }

    fn recompute_allocs(&self, view: &SchedView, predictor: &mut dyn Predictor) -> Vec<Action> {
        let mut ids = Vec::new();
        let mut demands = Vec::new();
        for job in view.active_jobs() {
            if let Some(d) = job_demand(job, view.now) {
                ids.push(job.id);
                demands.push(d);
            }
        }
        if demands.is_empty() {
            return Vec::new();
        }
        let solved = predictor.solve_slots(&demands);
        let (cap_m, cap_r) = self.caps();
        ids.iter()
            .zip(solved)
            .map(|(&job, s)| {
                let (m, r) = if s.infeasible {
                    (cap_m, cap_r)
                } else {
                    (s.map_slots.min(cap_m).max(1), s.reduce_slots.min(cap_r).max(1))
                };
                Action::SetAlloc {
                    job,
                    map_slots: m,
                    reduce_slots: r,
                }
            })
            .collect()
    }

    fn expire_awaiting(&mut self, view: &SchedView) -> Vec<Action> {
        let mut out = Vec::new();
        let now = view.now;
        let timeout = self.reconfig_timeout;
        self.awaiting_since.retain(|&(job, task, since)| {
            // A retired job is done: no awaiting tasks can remain for it.
            let Some(js) = view.job_get(job) else {
                return false;
            };
            if !js.map_state(TaskId(task)).is_awaiting() {
                return false;
            }
            if now.saturating_sub(since) > timeout {
                out.push(Action::CancelAwait {
                    job,
                    task: TaskId(task),
                });
                return false;
            }
            true
        });
        out
    }
}

impl Scheduler for NaiveDeadlineVc {
    fn kind(&self) -> SchedulerKind {
        SchedulerKind::DeadlineVc
    }

    fn on_sim_start(&mut self, view: &SchedView) {
        self.awaiting_since.clear();
        self.live_map_slots = self.max_map_slots;
        self.live_reduce_slots = self.max_reduce_slots;
        self.replan = view.cfg.failures.replan;
        self.blacklist = BlacklistPolicy::new(view.cfg);
    }

    fn on_pm_failure(&mut self, view: &SchedView, pm: PmId) {
        self.blacklist.on_pm_failure(pm, view.now);
        if self.replan {
            self.live_map_slots = self.live_map_slots.saturating_sub(self.pm_map_slots);
            self.live_reduce_slots = self.live_reduce_slots.saturating_sub(self.pm_reduce_slots);
        }
    }

    fn on_pm_recovery(&mut self, _view: &SchedView, _pm: PmId) {
        if self.replan {
            self.live_map_slots =
                (self.live_map_slots + self.pm_map_slots).min(self.max_map_slots);
            self.live_reduce_slots =
                (self.live_reduce_slots + self.pm_reduce_slots).min(self.max_reduce_slots);
        }
    }

    fn on_job_added(
        &mut self,
        view: &SchedView,
        _job: JobId,
        predictor: &mut dyn Predictor,
        out: &mut Vec<Action>,
    ) {
        out.extend(self.recompute_allocs(view, predictor));
    }

    fn on_task_finished(
        &mut self,
        view: &SchedView,
        _job: JobId,
        predictor: &mut dyn Predictor,
        out: &mut Vec<Action>,
    ) {
        out.extend(self.recompute_allocs(view, predictor));
    }

    fn on_heartbeat(
        &mut self,
        view: &SchedView,
        node: NodeId,
        _predictor: &mut dyn Predictor,
        out: &mut Vec<Action>,
    ) {
        let mut actions = self.expire_awaiting(view);
        // Failure-reactive gate, after the await-ledger bookkeeping (the
        // indexed scheduler does the same).
        if self.blacklist.blocks_node(view, node) {
            out.extend(actions);
            return;
        }
        let order = DeadlineVcScheduler::job_order(view);

        let mut free: Vec<u32> = (0..view.cluster.num_nodes())
            .map(|i| view.cluster.vm(NodeId(i as u32)).free_map_slots())
            .collect();
        let mut free_reduce = view.cluster.vm(node).free_reduce_slots();
        let racked = view.cluster.topology().is_racked();
        let my_rack = view.cluster.rack_of(node);
        let mut claimed = ClaimSet::new();
        let mut extra_sched: HashMap<JobId, u32> = HashMap::new();
        let mut released_this_hb = false;
        let mut routed = 0u32;
        let max_routed = self.tuning.max_routed;

        let passes: u8 = if self.tuning.spare_pass { 2 } else { 1 };
        for pass in 0..passes {
            'jobs: for &ji in &order {
                let job = &view.jobs[ji];
                if job.is_done() || job.map_finished() {
                    continue;
                }
                loop {
                    if free[node.idx()] == 0 && routed >= max_routed {
                        break 'jobs;
                    }
                    if pass == 0 {
                        let sched =
                            job.scheduled_maps() + extra_sched.get(&job.id).copied().unwrap_or(0);
                        if !job.cold() && sched >= job.alloc_map_slots {
                            break;
                        }
                    }
                    if free[node.idx()] > 0 {
                        if let Some(t) = next_unclaimed_local_scan(job, node, &claimed) {
                            claimed.insert((job.id, t));
                            *extra_sched.entry(job.id).or_insert(0) += 1;
                            actions.push(Action::LaunchMap { job: job.id, task: t, node });
                            free[node.idx()] -= 1;
                            continue;
                        }
                    }
                    let rack_pick = if racked && free[node.idx()] > 0 {
                        next_unclaimed_rack_scan(job, my_rack, &claimed)
                    } else {
                        None
                    };
                    let Some(t) = rack_pick.or_else(|| next_unclaimed_any_scan(job, &claimed))
                    else {
                        break;
                    };
                    let Some(target) = choose_target_with(self.tuning, view, job, t) else {
                        if free[node.idx()] > 0 {
                            claimed.insert((job.id, t));
                            *extra_sched.entry(job.id).or_insert(0) += 1;
                            actions.push(Action::LaunchMap { job: job.id, task: t, node });
                            free[node.idx()] -= 1;
                            continue;
                        }
                        break;
                    };
                    if self.blacklist.blocks_node(view, target) {
                        // Blacklisted target PM: no routing, no await —
                        // remote launch on the heartbeating node instead.
                        if free[node.idx()] > 0 {
                            claimed.insert((job.id, t));
                            *extra_sched.entry(job.id).or_insert(0) += 1;
                            actions.push(Action::LaunchMap { job: job.id, task: t, node });
                            free[node.idx()] -= 1;
                            continue;
                        }
                        break;
                    }
                    if free[target.idx()] > 0 && routed < max_routed {
                        claimed.insert((job.id, t));
                        *extra_sched.entry(job.id).or_insert(0) += 1;
                        actions.push(Action::LaunchMap { job: job.id, task: t, node: target });
                        free[target.idx()] -= 1;
                        routed += 1;
                        continue;
                    }
                    let release_ready = !self.tuning.await_requires_release
                        || view.cm.rq_depth(view.cluster.pm_of(target)) > 0;
                    if pass == 0
                        && release_ready
                        && !released_this_hb
                        && free[node.idx()] > 0
                        && view.cluster.vm(node).can_release_core()
                    {
                        claimed.insert((job.id, t));
                        *extra_sched.entry(job.id).or_insert(0) += 1;
                        self.awaiting_since.push((job.id, t.0, view.now));
                        actions.push(Action::AwaitReconfig {
                            job: job.id,
                            task: t,
                            target,
                            release_from: node,
                        });
                        released_this_hb = true;
                        free[node.idx()] -= 1;
                        continue;
                    }
                    if free[node.idx()] > 0 {
                        claimed.insert((job.id, t));
                        if pass == 0 {
                            *extra_sched.entry(job.id).or_insert(0) += 1;
                        }
                        actions.push(Action::LaunchMap { job: job.id, task: t, node });
                        free[node.idx()] -= 1;
                        continue;
                    }
                    break;
                }
            }
        }

        let mut extra_red: HashMap<JobId, u32> = HashMap::new();
        for pass in 0..passes {
            for &ji in &order {
                let job = &view.jobs[ji];
                if job.is_done() || !job.map_finished() {
                    continue;
                }
                while free_reduce > 0 {
                    let extra = extra_red.get(&job.id).copied().unwrap_or(0);
                    if pass == 0 && job.running_reduces() + extra >= job.alloc_reduce_slots {
                        break;
                    }
                    let Some(t) = nth_pending_reduce_scan(job, extra) else {
                        break;
                    };
                    *extra_red.entry(job.id).or_insert(0) += 1;
                    actions.push(Action::LaunchReduce { job: job.id, task: t, node });
                    free_reduce -= 1;
                }
                if free_reduce == 0 {
                    break;
                }
            }
        }

        if free[node.idx()] > 0 && !released_this_hb && view.cluster.vm(node).can_release_core() {
            actions.push(Action::RegisterRelease { node });
        }

        out.extend(actions);
        speculative_fill(view, node, out);
    }
}
