//! The paper's proposed scheduler: completion-time-based scheduling
//! (Algorithm 2) with map-task assignment through dynamic VM
//! reconfiguration (Algorithm 1).
//!
//! Per heartbeat from node `n`:
//! 1. Jobs are sorted by deadline (EDF); *cold* jobs — no completed or
//!    running tasks — take absolute precedence, oldest first (§4.2: they
//!    must bootstrap the Eq. 1 statistics).
//! 2. While `n` has free map slots: for each job `j` in order with
//!    `scheduled_maps < n_m(j)`:
//!    * launch a node-local pending map on `n` if one exists (Alg. 1 l.1);
//!    * else pick target `p` among the replica nodes of j's next pending
//!      map — deepest release queue first, else shallowest assign queue
//!      (Alg. 1 l.4-9). If `p` has a free slot the task launches there
//!      immediately (still data-local); otherwise the task is *delayed*:
//!      an assign entry is queued for `p`'s PM and `n`'s idle core is
//!      registered for release (Alg. 1 l.11-13).
//! 3. Reduce slots are filled for jobs past their map phase while
//!    `running_reduces < n_r(j)` (Alg. 2 l.10-13). Data locality is not
//!    considered for reducers (§4.2).
//! 4. A node with leftover free map slots and no local work registers its
//!    core for release so co-resident VMs can grow (Alg. 1 l.12).
//!
//! `(n_m, n_r)` come from the Resource Predictor (Eq. 10) and are
//! recomputed after every task completion (Alg. 2 l.17-20) over the
//! *remaining* work and *remaining* deadline.

use crate::cluster::NodeId;
use crate::config::SimConfig;
use crate::mapreduce::{JobId, JobState, TaskId};
use crate::predictor::{JobDemand, Predictor};
use crate::sim::SimTime;

use super::edf::EdfKeys;
use super::{
    next_unclaimed_any, next_unclaimed_local, next_unclaimed_rack, speculative_fill, Action,
    ClaimLedger, EdfScheduler, SchedView, Scheduler, SchedulerKind,
};

/// Tunable policy knobs — every mechanism of the proposed scheduler can
/// be ablated independently (see `rust/benches/ablation.rs`).
#[derive(Clone, Copy, Debug)]
pub struct DvcTuning {
    /// Alg. 1 node-choice weights (release-queue depth vs assign-queue
    /// depth) — mirrored by the locality XLA kernel.
    pub w_rq: f64,
    pub w_aq: f64,
    /// Only queue a delayed local launch when the target PM already has a
    /// registered release (off => speculative waits, the literal Alg. 1).
    pub await_requires_release: bool,
    /// Cross-node direct-local routings allowed per heartbeat.
    pub max_routed: u32,
    /// Work-conserving spare-capacity pass after the Alg. 2 cap pass.
    pub spare_pass: bool,
    /// Await-expiry timeout in heartbeats.
    pub timeout_heartbeats: f64,
}

impl Default for DvcTuning {
    fn default() -> Self {
        Self {
            w_rq: 1.0,
            w_aq: 0.5,
            await_requires_release: true,
            max_routed: 8,
            spare_pass: true,
            timeout_heartbeats: 4.0,
        }
    }
}

#[derive(Debug)]
pub struct DeadlineVcScheduler {
    pub tuning: DvcTuning,
    /// Give up on a delayed local launch after this long and fall back to
    /// a remote slot (guards against reconfiguration starvation; the
    /// paper argues the wait is negligible but a bound keeps liveness).
    reconfig_timeout: SimTime,
    /// `(job, map task, entered-awaiting-at)`, insertion-ordered. The
    /// seed kept a `HashMap` here; a `Vec` with `retain` keeps the expiry
    /// scan O(awaiting) while making the CancelAwait emission order
    /// deterministic (hash-map iteration order is not) — a prerequisite
    /// for the action-stream differential tests.
    awaiting_since: Vec<(JobId, u32, SimTime)>,
    /// Clamp predictor answers to the cluster's physical slot totals.
    max_map_slots: u32,
    max_reduce_slots: u32,
    // ---- pooled per-event buffers (allocation-free at steady state) ----
    claims: ClaimLedger,
    keys: EdfKeys,
    order: Vec<usize>,
    order_tmp: Vec<usize>,
    /// Per-node free-map-slot ledger for the current heartbeat.
    free: Vec<u32>,
    alloc_ids: Vec<JobId>,
    alloc_demands: Vec<JobDemand>,
}

/// Eq. 10 inputs for `job` over its remaining work (Alg. 2 l.19).
pub(crate) fn job_demand(job: &JobState, now: SimTime) -> Option<JobDemand> {
    let deadline_at = job.deadline_at()?;
    let remaining = deadline_at.saturating_sub(now).as_secs_f64();
    Some(JobDemand {
        map_tasks: (job.total_maps() - job.completed_maps()) as f64,
        reduce_tasks: (job.total_reduces() - job.completed_reduces()) as f64,
        t_map: job.stats.t_map(),
        t_reduce: job.stats.t_reduce(),
        t_shuffle: job.stats.t_shuffle(),
        deadline: remaining,
    })
}

/// Alg. 1 lines 4-9: choose the target node among the replicas of
/// `task`, preferring the deepest release queue, falling back to the
/// shallowest assign queue. Mirrors the `locality_score` kernel.
pub(crate) fn choose_target_with(
    tuning: DvcTuning,
    view: &SchedView,
    job: &JobState,
    task: TaskId,
) -> Option<NodeId> {
    let replicas = job.replica_nodes(task.0);
    if replicas.is_empty() {
        return None;
    }
    let score = |n: NodeId| {
        let pm = view.cluster.pm_of(n);
        tuning.w_rq * view.cm.rq_depth(pm) as f64 - tuning.w_aq * view.cm.aq_depth(pm) as f64
    };
    replicas
        .iter()
        .copied()
        .max_by(|&a, &b| {
            score(a)
                .partial_cmp(&score(b))
                .unwrap_or(std::cmp::Ordering::Equal)
                // deterministic tie-break: lower node id wins
                .then(b.0.cmp(&a.0))
        })
}

impl DeadlineVcScheduler {
    pub fn new(cfg: &SimConfig) -> Self {
        Self::with_tuning(cfg, DvcTuning::default())
    }

    pub fn with_tuning(cfg: &SimConfig, tuning: DvcTuning) -> Self {
        Self {
            reconfig_timeout: SimTime::from_secs_f64(
                cfg.heartbeat_s * tuning.timeout_heartbeats,
            ),
            awaiting_since: Vec::new(),
            max_map_slots: cfg.total_map_slots(),
            max_reduce_slots: cfg.total_reduce_slots(),
            tuning,
            claims: ClaimLedger::new(),
            keys: Vec::new(),
            order: Vec::new(),
            order_tmp: Vec::new(),
            free: Vec::new(),
            alloc_ids: Vec::new(),
            alloc_demands: Vec::new(),
        }
    }

    /// Recompute `(n_m, n_r)` for every active deadlined job — one batched
    /// predictor call (one PJRT execution on the XLA backend). This runs
    /// on every job arrival and task completion, so the id/demand staging
    /// buffers are pooled on the scheduler.
    fn recompute_allocs(
        &mut self,
        view: &SchedView,
        predictor: &mut dyn Predictor,
        out: &mut Vec<Action>,
    ) {
        self.alloc_ids.clear();
        self.alloc_demands.clear();
        for job in view.active_jobs() {
            if let Some(d) = job_demand(job, view.now) {
                self.alloc_ids.push(job.id);
                self.alloc_demands.push(d);
            }
        }
        if self.alloc_demands.is_empty() {
            return;
        }
        let solved = predictor.solve_slots(&self.alloc_demands);
        for (&job, s) in self.alloc_ids.iter().zip(solved) {
            // An infeasible deadline gets the full cluster: minimize
            // lateness (the paper leaves this case unspecified).
            let (m, r) = if s.infeasible {
                (self.max_map_slots, self.max_reduce_slots)
            } else {
                (
                    s.map_slots.min(self.max_map_slots).max(1),
                    s.reduce_slots.min(self.max_reduce_slots).max(1),
                )
            };
            out.push(Action::SetAlloc {
                job,
                map_slots: m,
                reduce_slots: r,
            });
        }
    }

    /// Test/ablation convenience around [`choose_target_with`].
    #[cfg(test)]
    fn choose_target(&self, view: &SchedView, job: &JobState, task: TaskId) -> Option<NodeId> {
        choose_target_with(self.tuning, view, job, task)
    }

    /// EDF order with cold jobs first (oldest cold job leads), built in
    /// pooled buffers. The cold partition is stable (== the seed's stable
    /// sort by `!cold()`).
    fn job_order_into(
        view: &SchedView,
        keys: &mut EdfKeys,
        order: &mut Vec<usize>,
        tmp: &mut Vec<usize>,
    ) {
        EdfScheduler::edf_order_into(view, keys, order);
        tmp.clear();
        tmp.extend(order.iter().copied().filter(|&i| view.jobs[i].cold()));
        tmp.extend(order.iter().copied().filter(|&i| !view.jobs[i].cold()));
        std::mem::swap(order, tmp);
    }

    /// Allocating convenience wrapper around [`Self::job_order_into`]
    /// (tests and the naive reference implementation).
    pub(crate) fn job_order(view: &SchedView) -> Vec<usize> {
        let (mut keys, mut order, mut tmp) = (Vec::new(), Vec::new(), Vec::new());
        Self::job_order_into(view, &mut keys, &mut order, &mut tmp);
        order
    }

    /// Expire AwaitingReconfig tasks that outlived the timeout.
    fn expire_awaiting(&mut self, view: &SchedView, out: &mut Vec<Action>) {
        let now = view.now;
        let timeout = self.reconfig_timeout;
        self.awaiting_since.retain(|&(job, task, since)| {
            let js = &view.jobs[job.idx()];
            let state = js.map_state(TaskId(task));
            if !state.is_awaiting() {
                return false; // launched or cancelled elsewhere
            }
            if now.saturating_sub(since) > timeout {
                out.push(Action::CancelAwait {
                    job,
                    task: TaskId(task),
                });
                return false;
            }
            true
        });
    }
}

impl Scheduler for DeadlineVcScheduler {
    fn kind(&self) -> SchedulerKind {
        SchedulerKind::DeadlineVc
    }

    /// Alg. 2 lines 1-2: initial allocation from priors.
    fn on_job_added(
        &mut self,
        view: &SchedView,
        _job: JobId,
        predictor: &mut dyn Predictor,
        out: &mut Vec<Action>,
    ) {
        self.recompute_allocs(view, predictor, out);
    }

    /// Alg. 2 lines 17-20.
    fn on_task_finished(
        &mut self,
        view: &SchedView,
        _job: JobId,
        predictor: &mut dyn Predictor,
        out: &mut Vec<Action>,
    ) {
        self.recompute_allocs(view, predictor, out);
    }

    fn on_heartbeat(
        &mut self,
        view: &SchedView,
        node: NodeId,
        _predictor: &mut dyn Predictor,
        out: &mut Vec<Action>,
    ) {
        self.expire_awaiting(view, out);
        Self::job_order_into(view, &mut self.keys, &mut self.order, &mut self.order_tmp);
        // One claim generation spans the whole heartbeat (both passes and
        // the reduce phase).
        self.claims.begin(view.jobs);

        // Slot ledger for this heartbeat: free map slots per node, so
        // direct-local routing to other nodes (Alg. 1 l.13) never
        // overfills a VM within one scheduling round.
        self.free.clear();
        for i in 0..view.cluster.num_nodes() {
            self.free.push(view.cluster.vm(NodeId(i as u32)).free_map_slots());
        }
        let mut free_reduce = view.cluster.vm(node).free_reduce_slots();
        // Rack-aware tie-break for the non-local pick: among tasks with no
        // replica on `n`, prefer one with a replica in n's *rack* — if it
        // ends up launching remotely on n the fetch stays off the shared
        // cross-rack core. Inert on the flat topology (no rack index).
        let racked = view.cluster.topology().is_racked();
        let my_rack = view.cluster.rack_of(node);
        let tuning = self.tuning;
        // Split the pooled state into disjoint field borrows for the
        // placement loop below.
        let Self {
            ref mut claims,
            ref order,
            ref mut free,
            ref mut awaiting_since,
            ..
        } = *self;
        let mut released_this_hb = false;
        // Bound cross-node routing per heartbeat (cost control; every
        // node heartbeats every 3 s so global work still spreads fast).
        let mut routed = 0u32;
        let max_routed = tuning.max_routed;

        // Two passes over the EDF order:
        //   pass 0 — guaranteed allocations (Alg. 2 caps enforced);
        //   pass 1 — spare capacity, work-conserving: same locality
        //            mechanism, caps ignored; remote fallback only for
        //            jobs already past their deadline. The paper's caps
        //            are *minimums* to meet deadlines — leaving surplus
        //            slots idle would forfeit the Fig. 2(b)/Fig. 3
        //            completion-time gains the paper reports.
        let passes: u8 = if tuning.spare_pass { 2 } else { 1 };
        for pass in 0..passes {
            // Each job drains under strict EDF priority: the earliest-
            // deadline job takes every placement it can before the next
            // job is considered. (O(jobs + launches); the naive restart-
            // from-top scan was ~40% of the scheduler profile.)
            'jobs: for &ji in order {
                let job = &view.jobs[ji];
                if job.is_done() || job.map_finished() {
                    continue;
                }
                loop {
                    // Global exhaustion: nothing can place anywhere.
                    if free[node.idx()] == 0 && routed >= max_routed {
                        break 'jobs;
                    }
                    if pass == 0 {
                        let sched = job.scheduled_maps() + claims.maps_claimed(job.id);
                        // Cold jobs bypass the cap to bootstrap statistics.
                        if !job.cold() && sched >= job.alloc_map_slots {
                            break;
                        }
                    }
                    // Alg. 1 lines 1-2: local task on the heartbeating node.
                    if free[node.idx()] > 0 {
                        if let Some(t) = next_unclaimed_local(job, node, claims) {
                            claims.claim_map(job.id, t);
                            out.push(Action::LaunchMap { job: job.id, task: t, node });
                            free[node.idx()] -= 1;
                            continue;
                        }
                    }
                    // Alg. 1 lines 3-13: non-local task. Prefer a task
                    // with a replica in n's rack only when n has a free
                    // slot — i.e. when the pick could fall back to a
                    // remote launch *on n*, where rack-nearness keeps the
                    // fetch off the shared core. In routing-only mode
                    // (free[n] == 0) keep the block-order pick: a
                    // rack-near preference there could select an
                    // unroutable task and skip a routable one.
                    let rack_pick = if racked && free[node.idx()] > 0 {
                        next_unclaimed_rack(job, my_rack, claims)
                    } else {
                        None
                    };
                    let Some(t) = rack_pick.or_else(|| next_unclaimed_any(job, claims))
                    else {
                        break;
                    };
                    let Some(target) = choose_target_with(tuning, view, job, t) else {
                        // No replica registered (degenerate input): remote.
                        if free[node.idx()] > 0 {
                            claims.claim_map(job.id, t);
                            out.push(Action::LaunchMap { job: job.id, task: t, node });
                            free[node.idx()] -= 1;
                            continue;
                        }
                        break;
                    };
                    // Target has spare capacity: immediate *data-local*
                    // launch on it (Alg. 1 line 13).
                    if free[target.idx()] > 0 && routed < max_routed {
                        claims.claim_map(job.id, t);
                        out.push(Action::LaunchMap { job: job.id, task: t, node: target });
                        free[target.idx()] -= 1;
                        routed += 1;
                        continue;
                    }
                    // Delayed launch through reconfiguration (guaranteed
                    // pass only — spare capacity must not strip cores).
                    // Only worth waiting when the target PM already has a
                    // registered release: the hot-plug then lands within
                    // ~hotplug_ms. Waiting speculatively under backlog
                    // loses more than the remote-read penalty (releases
                    // are rare when every core has local work), so
                    // otherwise we fall through to a remote launch.
                    let release_ready = !tuning.await_requires_release
                        || view.cm.rq_depth(view.cluster.pm_of(target)) > 0;
                    if pass == 0
                        && release_ready
                        && !released_this_hb
                        && free[node.idx()] > 0
                        && view.cluster.vm(node).can_release_core()
                    {
                        claims.claim_map(job.id, t);
                        awaiting_since.push((job.id, t.0, view.now));
                        out.push(Action::AwaitReconfig {
                            job: job.id,
                            task: t,
                            target,
                            release_from: node,
                        });
                        released_this_hb = true;
                        free[node.idx()] -= 1; // that core is now pledged
                        continue;
                    }
                    // No data-local placement available now: launch
                    // remotely on n (the EDF/Fair behaviour). Idling the
                    // slot instead costs more than the remote read.
                    // (The claim counts toward `maps_claimed` in either
                    // pass, but the Alg. 2 cap only reads it in pass 0 —
                    // same accounting the seed's `extra_sched` map kept.)
                    if free[node.idx()] > 0 {
                        claims.claim_map(job.id, t);
                        out.push(Action::LaunchMap { job: job.id, task: t, node });
                        free[node.idx()] -= 1;
                        continue;
                    }
                    break;
                }
            }
        }

        // ---- reduce phase (Alg. 2 lines 10-14 + spare pass) ----
        for pass in 0..passes {
            for &ji in order {
                let job = &view.jobs[ji];
                if job.is_done() || !job.map_finished() {
                    continue;
                }
                while free_reduce > 0 {
                    let extra = claims.reduces_claimed(job.id);
                    if pass == 0 && job.running_reduces() + extra >= job.alloc_reduce_slots {
                        break;
                    }
                    let Some(t) = claims.claim_next_reduce(job) else {
                        break;
                    };
                    out.push(Action::LaunchReduce { job: job.id, task: t, node });
                    free_reduce -= 1;
                }
                if free_reduce == 0 {
                    break;
                }
            }
        }

        // ---- Alg. 1 line 12: idle cores become releases ----
        // Unconditional (deduplicated in the CM): a node that still has a
        // free core after both passes has no runnable local work, so its
        // core is offered to co-resident VMs. This is what seeds the RQ
        // that makes release-gated awaits fire at all.
        if free[node.idx()] > 0
            && !released_this_hb
            && view.cluster.vm(node).can_release_core()
        {
            out.push(Action::RegisterRelease { node });
        }

        speculative_fill(view, node, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::testutil::*;

    fn sched(w: &TestWorld) -> DeadlineVcScheduler {
        DeadlineVcScheduler::new(&w.cfg())
    }

    #[test]
    fn cold_jobs_take_precedence() {
        let w = TestWorld::two_jobs_with_deadlines(300.0, 900.0);
        // Make job 0 (earlier deadline) warm, job 1 cold.
        let mut w = w;
        w.warm_up_job(0);
        let view = w.view();
        let order = DeadlineVcScheduler::job_order(&view);
        assert_eq!(view.jobs[order[0]].id.0, 1, "cold job first despite later deadline");
    }

    #[test]
    fn respects_map_slot_allocation() {
        let mut w = TestWorld::two_jobs_with_deadlines(300.0, 900.0);
        w.warm_up_job(0);
        w.warm_up_job(1);
        w.set_alloc(0, 1, 1);
        w.set_alloc(1, 1, 1);
        w.force_running_maps(0, 1); // job 0 at its cap
        let mut s = sched(&w);
        let actions = w.heartbeat_with(&mut s, w.node_with_local_for(1));
        for a in &actions {
            if let Action::LaunchMap { job, .. } = a {
                assert_ne!(job.0, 0, "job 0 is at its n_m cap");
            }
        }
    }

    #[test]
    fn prefers_local_launch_on_heartbeat_node() {
        let mut w = TestWorld::two_jobs();
        w.warm_up_job(0);
        w.warm_up_job(1);
        let node = w.node_with_local_for(0);
        let mut s = sched(&w);
        let actions = w.heartbeat_with(&mut s, node);
        let Some(Action::LaunchMap { job, task, node: n }) = actions
            .iter()
            .find(|a| matches!(a, Action::LaunchMap { .. }))
        else {
            panic!("expected a map launch: {actions:?}");
        };
        if *n == node {
            let js = &w.view_jobs()[job.idx()];
            assert!(js.map_is_local(*task, node), "launch on n must be local");
        }
    }

    #[test]
    fn nonlocal_task_routes_to_replica_node() {
        let mut w = TestWorld::one_job_no_local_on(NodeId(0));
        w.warm_up_job(0);
        w.fill_node_maps_except(NodeId(0)); // all other nodes busy
        // Register a release on every PM so a delayed local placement is
        // worth waiting for (otherwise the scheduler falls back remote).
        w.push_releases_everywhere();
        let mut s = sched(&w);
        let actions = w.heartbeat_with(&mut s, NodeId(0));
        // Node 0 has no replica of any pending block, other nodes are
        // full: expect an AwaitReconfig targeting a replica node.
        let awaits: Vec<_> = actions
            .iter()
            .filter(|a| matches!(a, Action::AwaitReconfig { .. }))
            .collect();
        assert_eq!(awaits.len(), 1, "exactly one delayed placement: {actions:?}");
        if let Action::AwaitReconfig { job, task, target, release_from } = awaits[0] {
            assert_eq!(*release_from, NodeId(0));
            let js = &w.view_jobs()[job.idx()];
            assert!(js.map_is_local(*task, *target), "target must hold the block");
        }
    }

    #[test]
    fn falls_back_remote_without_ready_release() {
        let mut w = TestWorld::one_job_no_local_on(NodeId(0));
        w.warm_up_job(0);
        w.fill_node_maps_except(NodeId(0));
        let mut s = sched(&w);
        let actions = w.heartbeat_with(&mut s, NodeId(0));
        // No release queue entries anywhere: waiting would stall, so the
        // task must launch remotely on the heartbeating node instead.
        assert!(
            actions.iter().all(|a| !matches!(a, Action::AwaitReconfig { .. })),
            "must not wait speculatively: {actions:?}"
        );
        assert!(
            actions.iter().any(|a| matches!(
                a,
                Action::LaunchMap { node, .. } if *node == NodeId(0)
            )),
            "must launch remotely on node 0: {actions:?}"
        );
    }

    #[test]
    fn choose_target_prefers_deep_release_queue() {
        let mut w = TestWorld::two_jobs();
        w.warm_up_job(0);
        let view = w.view();
        let job = &view.jobs[0];
        let t = job.pending_maps_iter().next().unwrap();
        let replicas = job.replica_nodes(t.0);
        assert!(replicas.len() >= 2);
        // Deepen the RQ of the last replica's PM.
        let favored = *replicas.last().unwrap();
        drop(view);
        w.push_release(favored);
        let view = w.view();
        let s = DeadlineVcScheduler::new(&w.cfg());
        let picked = s.choose_target(&view, &view.jobs[0], t).unwrap();
        assert_eq!(
            view.cluster.pm_of(picked),
            view.cluster.pm_of(favored),
            "deepest RQ PM must win"
        );
    }

    #[test]
    fn awaiting_tasks_expire() {
        let mut w = TestWorld::one_job_no_local_on(NodeId(0));
        w.warm_up_job(0);
        w.fill_node_maps_except(NodeId(0));
        // Stale releases that will never match (the releasing VMs are
        // fully busy), so the await is granted queue-entry but no core
        // ever arrives -> it must expire.
        w.push_releases_everywhere();
        let mut s = sched(&w);
        let actions = w.heartbeat_and_apply(&mut s, NodeId(0));
        assert!(actions
            .iter()
            .any(|a| matches!(a, Action::AwaitReconfig { .. })));
        // Advance past the timeout with no release ever arriving.
        w.advance(SimTime::from_secs_f64(60.0));
        let actions = w.heartbeat_with(&mut s, NodeId(0));
        assert!(
            actions
                .iter()
                .any(|a| matches!(a, Action::CancelAwait { .. })),
            "expired await must be cancelled: {actions:?}"
        );
    }
}
