//! The paper's proposed scheduler: completion-time-based scheduling
//! (Algorithm 2) with map-task assignment through dynamic VM
//! reconfiguration (Algorithm 1).
//!
//! Per heartbeat from node `n`:
//! 1. Jobs are sorted by deadline (EDF); *cold* jobs — no completed or
//!    running tasks — take absolute precedence, oldest first (§4.2: they
//!    must bootstrap the Eq. 1 statistics).
//! 2. While `n` has free map slots: for each job `j` in order with
//!    `scheduled_maps < n_m(j)`:
//!    * launch a node-local pending map on `n` if one exists (Alg. 1 l.1);
//!    * else pick target `p` among the replica nodes of j's next pending
//!      map — deepest release queue first, else shallowest assign queue
//!      (Alg. 1 l.4-9). If `p` has a free slot the task launches there
//!      immediately (still data-local); otherwise the task is *delayed*:
//!      an assign entry is queued for `p`'s PM and `n`'s idle core is
//!      registered for release (Alg. 1 l.11-13).
//! 3. Reduce slots are filled for jobs past their map phase while
//!    `running_reduces < n_r(j)` (Alg. 2 l.10-13). Data locality is not
//!    considered for reducers (§4.2).
//! 4. A node with leftover free map slots and no local work registers its
//!    core for release so co-resident VMs can grow (Alg. 1 l.12).
//!
//! `(n_m, n_r)` come from the Resource Predictor (Eq. 10) and are
//! recomputed after every task completion (Alg. 2 l.17-20) over the
//! *remaining* work and *remaining* deadline.
//!
//! # Delta reallocation
//!
//! The naive Alg. 2 loop re-solves Eq. 10 for **every** active deadlined
//! job on every arrival/completion — O(jobs) per event, the last
//! per-event O(jobs) cost in the simulator. Here the recompute set is
//! instead: the triggering job, jobs whose demand inputs changed since
//! the last event (`on_job_updated` dirt), and jobs whose *next-change
//! bound* expired. The bound exploits the closed form of Eq. 10: with
//! demand inputs fixed, `n_m = ceil(√A(√A+√B) / C)` only grows as the
//! remaining deadline `C = D_rem − K` shrinks, so the next output change
//! happens exactly when the remaining deadline crosses
//! `K + √A(√A+√B)/n_m` (and symmetrically for `n_r`, and `K` itself for
//! the infeasibility transition). Bounds sit in a lazy min-heap with a
//! conservative 2 ms margin — recomputing early is always harmless
//! because unchanged allocations are **suppressed** (no `SetAlloc`
//! emitted), which keeps the world's stored `alloc_*` bit-identical to
//! the naive full recompute at every event. The differential tests
//! compare action streams modulo that suppression and reports bit for
//! bit.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::cluster::{NodeId, PmId};
use crate::config::SimConfig;
use crate::mapreduce::{JobId, JobState, TaskId};
use crate::predictor::{abc, JobDemand, Predictor, SlotDemand};
use crate::sim::SimTime;
use crate::util::codec::{Dec, Enc};

use super::edf::EdfKeys;
use super::{
    next_unclaimed_any, next_unclaimed_local, next_unclaimed_rack, speculative_fill, Action,
    BlacklistPolicy, ClaimLedger, EdfScheduler, OrderIndex, SchedView, Scheduler, SchedulerKind,
};

/// Tunable policy knobs — every mechanism of the proposed scheduler can
/// be ablated independently (see `rust/benches/ablation.rs`).
#[derive(Clone, Copy, Debug)]
pub struct DvcTuning {
    /// Alg. 1 node-choice weights (release-queue depth vs assign-queue
    /// depth) — mirrored by the locality XLA kernel.
    pub w_rq: f64,
    pub w_aq: f64,
    /// Only queue a delayed local launch when the target PM already has a
    /// registered release (off => speculative waits, the literal Alg. 1).
    pub await_requires_release: bool,
    /// Cross-node direct-local routings allowed per heartbeat.
    pub max_routed: u32,
    /// Work-conserving spare-capacity pass after the Alg. 2 cap pass.
    pub spare_pass: bool,
    /// Await-expiry timeout in heartbeats.
    pub timeout_heartbeats: f64,
}

impl Default for DvcTuning {
    fn default() -> Self {
        Self {
            w_rq: 1.0,
            w_aq: 0.5,
            await_requires_release: true,
            max_routed: 8,
            spare_pass: true,
            timeout_heartbeats: 4.0,
        }
    }
}

/// The persistent scheduling-order key: cold jobs first (`false < true`),
/// then EDF `(deadline, submitted)`; `JobId` breaks remaining ties inside
/// the index. Reproduces [`DeadlineVcScheduler::job_order`]'s stable
/// cold-first partition of the EDF sort exactly.
pub(crate) type DvcKey = (bool, SimTime, SimTime);

pub(crate) fn dvc_key(job: &JobState) -> DvcKey {
    (
        !job.cold(),
        job.deadline_at().unwrap_or(SimTime(u64::MAX)),
        job.submitted,
    )
}

fn active_key(job: &JobState) -> Option<DvcKey> {
    if job.is_done() {
        None
    } else {
        Some(dvc_key(job))
    }
}

/// Generation-stamped per-node used-slot overlay for one heartbeat: the
/// free-map-slot ledger is `vm.free_map_slots() − used(n)`, so starting a
/// round is an O(1) generation bump instead of the former O(nodes)
/// rebuild of a dense free vector.
#[derive(Debug, Default)]
struct SlotOverlay {
    gen: u64,
    stamps: Vec<u64>,
    used: Vec<u32>,
}

impl SlotOverlay {
    fn begin(&mut self, nodes: usize) {
        self.gen += 1;
        if self.stamps.len() < nodes {
            self.stamps.resize(nodes, 0);
            self.used.resize(nodes, 0);
        }
    }

    fn used(&self, i: usize) -> u32 {
        if self.stamps[i] == self.gen {
            self.used[i]
        } else {
            0
        }
    }

    fn take(&mut self, i: usize) {
        if self.stamps[i] != self.gen {
            self.stamps[i] = self.gen;
            self.used[i] = 0;
        }
        self.used[i] += 1;
    }
}

/// Free map slots on `n` right now, net of this heartbeat's claims.
fn free_at(view: &SchedView, overlay: &SlotOverlay, n: NodeId) -> u32 {
    view.cluster
        .vm(n)
        .free_map_slots()
        .saturating_sub(overlay.used(n.idx()))
}

#[derive(Debug)]
pub struct DeadlineVcScheduler {
    pub tuning: DvcTuning,
    /// Give up on a delayed local launch after this long and fall back to
    /// a remote slot (guards against reconfiguration starvation; the
    /// paper argues the wait is negligible but a bound keeps liveness).
    reconfig_timeout: SimTime,
    /// `(job, map task, entered-awaiting-at)`, insertion-ordered. The
    /// seed kept a `HashMap` here; a `Vec` with `retain` keeps the expiry
    /// scan O(awaiting) while making the CancelAwait emission order
    /// deterministic (hash-map iteration order is not) — a prerequisite
    /// for the action-stream differential tests.
    awaiting_since: Vec<(JobId, u32, SimTime)>,
    /// Clamp predictor answers to the cluster's physical slot totals.
    max_map_slots: u32,
    max_reduce_slots: u32,
    // ---- failure-reactive re-planning (Eq. 10 against live supply) ----
    /// Re-plan on PM failure/recovery (`FailureModel::replan`): the Eq. 10
    /// clamp tracks the *live* slot supply instead of the configured
    /// total, so a shrunken cluster re-solves every deadline against what
    /// it can actually deliver (and relaxes again on recovery).
    replan: bool,
    /// Slots contributed by one PM (homogeneous VM placement).
    pm_map_slots: u32,
    pm_reduce_slots: u32,
    /// Current live supply; equal to `max_*` while every PM is up (and
    /// always, when `replan` is off).
    live_map_slots: u32,
    live_reduce_slots: u32,
    blacklist: BlacklistPolicy,
    // ---- persistent scheduling order ----
    index: OrderIndex<DvcKey>,
    covered: usize,
    /// Job id of slot 0 in `dirty_flag`/`bound_of` — tracks the view's
    /// `jobs_base` so retired jobs cost no per-job state.
    win_base: usize,
    // ---- delta Eq. 10 state ----
    /// Jobs whose demand inputs changed since the last alloc event.
    dirty_list: Vec<JobId>,
    dirty_flag: Vec<bool>,
    /// Lazy min-heap of next-change bounds; an entry is live iff it
    /// matches `bound_of` for its job.
    bound_heap: BinaryHeap<(Reverse<SimTime>, JobId)>,
    bound_of: Vec<Option<SimTime>>,
    /// Pooled candidate job indices for one recompute.
    cand: Vec<u32>,
    // ---- pooled per-event buffers (allocation-free at steady state) ----
    claims: ClaimLedger,
    overlay: SlotOverlay,
    alloc_ids: Vec<JobId>,
    alloc_demands: Vec<JobDemand>,
}

/// Eq. 10 inputs for `job` over its remaining work (Alg. 2 l.19).
pub(crate) fn job_demand(job: &JobState, now: SimTime) -> Option<JobDemand> {
    let deadline_at = job.deadline_at()?;
    let remaining = deadline_at.saturating_sub(now).as_secs_f64();
    Some(JobDemand {
        map_tasks: (job.total_maps() - job.completed_maps()) as f64,
        reduce_tasks: (job.total_reduces() - job.completed_reduces()) as f64,
        t_map: job.stats.t_map(),
        t_reduce: job.stats.t_reduce(),
        t_shuffle: job.stats.t_shuffle(),
        deadline: remaining,
    })
}

/// Alg. 1 lines 4-9: choose the target node among the replicas of
/// `task`, preferring the deepest release queue, falling back to the
/// shallowest assign queue. Mirrors the `locality_score` kernel.
pub(crate) fn choose_target_with(
    tuning: DvcTuning,
    view: &SchedView,
    job: &JobState,
    task: TaskId,
) -> Option<NodeId> {
    let replicas = job.replica_nodes(task.0);
    if replicas.is_empty() {
        return None;
    }
    let score = |n: NodeId| {
        let pm = view.cluster.pm_of(n);
        tuning.w_rq * view.cm.rq_depth(pm) as f64 - tuning.w_aq * view.cm.aq_depth(pm) as f64
    };
    replicas
        .iter()
        .copied()
        .max_by(|&a, &b| {
            score(a)
                .partial_cmp(&score(b))
                .unwrap_or(std::cmp::Ordering::Equal)
                // deterministic tie-break: lower node id wins
                .then(b.0.cmp(&a.0))
        })
}

/// Earliest future instant at which `job`'s *clamped* Eq. 10 output
/// could differ from the value just computed, assuming its demand inputs
/// stay fixed (any input change re-queues the job via `on_job_updated`).
/// `None` means the output can never change again without an input
/// change (infeasible, or pinned at the `(max, max)` clamp).
fn next_change_bound(
    job: &JobState,
    d: &JobDemand,
    s: SlotDemand,
    m_out: u32,
    r_out: u32,
    max_m: u32,
    max_r: u32,
) -> Option<SimTime> {
    if s.infeasible {
        // C only shrinks with time: infeasible stays infeasible and the
        // stored (max, max) never moves.
        return None;
    }
    if m_out == max_m && r_out == max_r {
        // Both components already pinned at the clamp; the infeasibility
        // transition would emit the same (max, max).
        return None;
    }
    let deadline_at = job.deadline_at()?;
    let (a, b, _) = abc(d);
    let (a, b) = (a.max(0.0), b.max(0.0));
    let k = d.map_tasks * d.reduce_tasks * d.t_shuffle;
    let (ra, rb) = (a.sqrt(), b.sqrt());
    let sum = ra + rb;
    // The output changes when the remaining deadline drops below the
    // largest of these thresholds (C = remaining − K):
    let mut r_thresh = k; // infeasibility: C reaches 0
    if a > 0.0 && m_out < max_m {
        // ceil(ra·sum / C) increments when C < ra·sum / n_m.
        r_thresh = r_thresh.max(k + ra * sum / f64::from(s.map_slots.max(1)));
    }
    if b > 0.0 && r_out < max_r {
        r_thresh = r_thresh.max(k + rb * sum / f64::from(s.reduce_slots.max(1)));
    }
    // Conservative margin (2 ms ≫ the f64 rounding of the inversion):
    // waking early costs one suppressed recompute; waking late would let
    // the stored allocation diverge from the naive full recompute.
    let thresh_ms = (r_thresh * 1000.0).ceil().max(0.0) as u64;
    Some(SimTime(
        deadline_at.0.saturating_sub(thresh_ms).saturating_sub(2),
    ))
}

impl DeadlineVcScheduler {
    pub fn new(cfg: &SimConfig) -> Self {
        Self::with_tuning(cfg, DvcTuning::default())
    }

    pub fn with_tuning(cfg: &SimConfig, tuning: DvcTuning) -> Self {
        Self {
            reconfig_timeout: SimTime::from_secs_f64(
                cfg.heartbeat_s * tuning.timeout_heartbeats,
            ),
            awaiting_since: Vec::new(),
            max_map_slots: cfg.total_map_slots(),
            max_reduce_slots: cfg.total_reduce_slots(),
            replan: cfg.failures.replan,
            pm_map_slots: cfg.vms_per_pm as u32 * cfg.base_vcpus,
            pm_reduce_slots: cfg.vms_per_pm as u32 * cfg.reduce_slots,
            live_map_slots: cfg.total_map_slots(),
            live_reduce_slots: cfg.total_reduce_slots(),
            blacklist: BlacklistPolicy::new(cfg),
            tuning,
            index: OrderIndex::new(),
            covered: 0,
            win_base: 0,
            dirty_list: Vec::new(),
            dirty_flag: Vec::new(),
            bound_heap: BinaryHeap::new(),
            bound_of: Vec::new(),
            cand: Vec::new(),
            claims: ClaimLedger::new(),
            overlay: SlotOverlay::default(),
            alloc_ids: Vec::new(),
            alloc_demands: Vec::new(),
        }
    }

    fn reset(&mut self) {
        self.index.clear();
        self.covered = 0;
        self.win_base = 0;
        self.dirty_list.clear();
        self.dirty_flag.clear();
        self.bound_heap.clear();
        self.bound_of.clear();
        self.awaiting_since.clear();
        self.live_map_slots = self.max_map_slots;
        self.live_reduce_slots = self.max_reduce_slots;
        self.blacklist.reset();
    }

    /// The Eq. 10 clamp ceiling: live supply under re-planning, the
    /// configured totals otherwise (live == max while replan is off). The
    /// `.max(1)` keeps a fully dark cluster from clamping a demand to 0.
    fn caps(&self) -> (u32, u32) {
        (self.live_map_slots.max(1), self.live_reduce_slots.max(1))
    }

    /// Supply changed (re-plan): every active deadlined job's clamped
    /// Eq. 10 answer may have moved, so mark them all dirty — the next
    /// alloc event recomputes exactly what the naive full sweep would.
    fn mark_all_dirty(&mut self, view: &SchedView) {
        self.sync(view);
        for job in view.active_jobs() {
            let j = view.slot(job.id);
            if !self.dirty_flag[j] {
                self.dirty_flag[j] = true;
                self.dirty_list.push(job.id);
            }
        }
    }

    /// Absorb jobs that arrived since the last callback; drop all state
    /// when the world shrank (scheduler reuse across Worlds).
    fn sync(&mut self, view: &SchedView) {
        let total = view.total_jobs();
        if self.covered > total {
            self.reset();
        }
        self.index.set_base(view.jobs_base);
        if view.jobs_base > self.win_base {
            // Retired jobs are done: their dirty flags are moot and their
            // bound-heap entries go dead (the pop-side liveness check
            // skips ids below the window).
            let k = (view.jobs_base - self.win_base).min(self.dirty_flag.len());
            self.dirty_flag.drain(..k);
            self.bound_of.drain(..k);
            self.win_base = view.jobs_base;
        }
        if self.dirty_flag.len() < view.jobs.len() {
            self.dirty_flag.resize(view.jobs.len(), false);
            self.bound_of.resize(view.jobs.len(), None);
        }
        for job in &view.jobs[self.covered.max(view.jobs_base) - view.jobs_base..] {
            self.index.set_key(job.id, active_key(job));
        }
        self.covered = total;
    }

    /// Delta Eq. 10 (see module docs): recompute `(n_m, n_r)` only for
    /// the triggering job, dirty jobs, and jobs whose next-change bound
    /// expired — in ascending job order, matching the naive full sweep —
    /// and emit `SetAlloc` only when the clamped value actually moved.
    fn recompute_allocs(
        &mut self,
        view: &SchedView,
        trigger: JobId,
        predictor: &mut dyn Predictor,
        out: &mut Vec<Action>,
    ) {
        self.sync(view);
        let now = view.now;
        self.cand.clear();
        if view.job_get(trigger).is_some() {
            self.cand.push(trigger.0);
        }
        for j in self.dirty_list.drain(..) {
            // Retired ids (done jobs dropped from the window) have
            // nothing left to recompute.
            let Some(slot) = j.idx().checked_sub(self.win_base) else {
                continue;
            };
            if let Some(f) = self.dirty_flag.get_mut(slot) {
                *f = false;
            }
            self.cand.push(j.0);
        }
        while let Some(&(Reverse(t), j)) = self.bound_heap.peek() {
            if t > now {
                break;
            }
            self.bound_heap.pop();
            // Live entry (not superseded by a later re-bound, not below
            // the retired-jobs window floor)?
            let slot = j.idx().checked_sub(self.win_base);
            let live =
                slot.and_then(|s| self.bound_of.get(s).copied().flatten()) == Some(t);
            if live {
                self.bound_of[j.idx() - self.win_base] = None;
                self.cand.push(j.0);
            }
        }
        self.cand.sort_unstable();
        self.cand.dedup();

        self.alloc_ids.clear();
        self.alloc_demands.clear();
        for &ji in &self.cand {
            let Some(slot) = (ji as usize).checked_sub(self.win_base) else {
                continue;
            };
            let Some(job) = view.jobs.get(slot) else {
                continue;
            };
            if job.is_done() {
                self.bound_of[slot] = None;
                continue;
            }
            let Some(d) = job_demand(job, now) else {
                self.bound_of[slot] = None;
                continue;
            };
            self.alloc_ids.push(job.id);
            self.alloc_demands.push(d);
        }
        if self.alloc_demands.is_empty() {
            return;
        }
        // Same batched predictor entry point as the naive sweep: Eq. 10
        // is a pure per-entry map, so a smaller batch yields bit-equal
        // per-job answers.
        let solved = predictor.solve_slots(&self.alloc_demands);
        let (cap_m, cap_r) = self.caps();
        for i in 0..self.alloc_ids.len() {
            let jid = self.alloc_ids[i];
            let s = solved[i];
            let d = self.alloc_demands[i];
            let job = &view.jobs[view.slot(jid)];
            // An infeasible deadline gets the full (live) cluster:
            // minimize lateness (the paper leaves this case unspecified).
            let (m, r) = if s.infeasible {
                (cap_m, cap_r)
            } else {
                (s.map_slots.min(cap_m).max(1), s.reduce_slots.min(cap_r).max(1))
            };
            if (m, r) != (job.alloc_map_slots, job.alloc_reduce_slots) {
                out.push(Action::SetAlloc {
                    job: jid,
                    map_slots: m,
                    reduce_slots: r,
                });
            }
            self.bound_of[view.slot(jid)] =
                match next_change_bound(job, &d, s, m, r, cap_m, cap_r)
                {
                    Some(t) => {
                        // Liveness: never re-arm in the past.
                        let t = t.max(SimTime(now.0 + 1));
                        self.bound_heap.push((Reverse(t), jid));
                        Some(t)
                    }
                    None => None,
                };
        }
    }

    /// Test/ablation convenience around [`choose_target_with`].
    #[cfg(test)]
    fn choose_target(&self, view: &SchedView, job: &JobState, task: TaskId) -> Option<NodeId> {
        choose_target_with(self.tuning, view, job, task)
    }

    /// EDF order with cold jobs first (oldest cold job leads), built in
    /// pooled buffers. The cold partition is stable (== the seed's stable
    /// sort by `!cold()`). Retained as the from-scratch oracle for the
    /// persistent index (naive reference, property tests).
    fn job_order_into(
        view: &SchedView,
        keys: &mut EdfKeys,
        order: &mut Vec<usize>,
        tmp: &mut Vec<usize>,
    ) {
        EdfScheduler::edf_order_into(view, keys, order);
        tmp.clear();
        tmp.extend(order.iter().copied().filter(|&i| view.jobs[i].cold()));
        tmp.extend(order.iter().copied().filter(|&i| !view.jobs[i].cold()));
        std::mem::swap(order, tmp);
    }

    /// Allocating convenience wrapper around [`Self::job_order_into`]
    /// (tests and the naive reference implementation).
    pub(crate) fn job_order(view: &SchedView) -> Vec<usize> {
        let (mut keys, mut order, mut tmp) = (Vec::new(), Vec::new(), Vec::new());
        Self::job_order_into(view, &mut keys, &mut order, &mut tmp);
        order
    }

    /// Expire AwaitingReconfig tasks that outlived the timeout.
    fn expire_awaiting(&mut self, view: &SchedView, out: &mut Vec<Action>) {
        let now = view.now;
        let timeout = self.reconfig_timeout;
        self.awaiting_since.retain(|&(job, task, since)| {
            // A retired job is done: no awaiting tasks can remain for it.
            let Some(js) = view.job_get(job) else {
                return false;
            };
            let state = js.map_state(TaskId(task));
            if !state.is_awaiting() {
                return false; // launched or cancelled elsewhere
            }
            if now.saturating_sub(since) > timeout {
                out.push(Action::CancelAwait {
                    job,
                    task: TaskId(task),
                });
                return false;
            }
            true
        });
    }
}

impl Scheduler for DeadlineVcScheduler {
    fn kind(&self) -> SchedulerKind {
        SchedulerKind::DeadlineVc
    }

    fn on_sim_start(&mut self, view: &SchedView) {
        self.reset();
        // Re-derive the cfg-dependent policy switches from the view's
        // config (scheduler reuse across Worlds), like the greedy
        // schedulers do for their blacklists.
        self.replan = view.cfg.failures.replan;
        self.blacklist = BlacklistPolicy::new(view.cfg);
    }

    fn on_pm_failure(&mut self, view: &SchedView, pm: PmId) {
        self.blacklist.on_pm_failure(pm, view.now);
        if self.replan {
            self.live_map_slots = self.live_map_slots.saturating_sub(self.pm_map_slots);
            self.live_reduce_slots = self.live_reduce_slots.saturating_sub(self.pm_reduce_slots);
            self.mark_all_dirty(view);
        }
    }

    fn on_pm_recovery(&mut self, view: &SchedView, _pm: PmId) {
        if self.replan {
            self.live_map_slots =
                (self.live_map_slots + self.pm_map_slots).min(self.max_map_slots);
            self.live_reduce_slots =
                (self.live_reduce_slots + self.pm_reduce_slots).min(self.max_reduce_slots);
            self.mark_all_dirty(view);
        }
    }

    fn on_job_updated(&mut self, view: &SchedView, job: JobId) {
        self.sync(view);
        let j = view.slot(job);
        self.index.set_key(job, active_key(&view.jobs[j]));
        if !self.dirty_flag[j] {
            self.dirty_flag[j] = true;
            self.dirty_list.push(job);
        }
    }

    fn check_index(&self, view: &SchedView) -> Result<(), String> {
        let mut expect: Vec<(DvcKey, JobId)> =
            view.active_jobs().map(|j| (dvc_key(j), j.id)).collect();
        expect.sort_unstable();
        self.index.check_matches(&expect)?;
        for (got, &ji) in self.index.iter().zip(&Self::job_order(view)) {
            if view.slot(got) != ji {
                return Err(format!(
                    "index order diverges from job_order: {got:?} vs index {ji}"
                ));
            }
        }
        self.claims.check_against(view.jobs)
    }

    /// Alg. 2 lines 1-2: initial allocation from priors.
    fn on_job_added(
        &mut self,
        view: &SchedView,
        job: JobId,
        predictor: &mut dyn Predictor,
        out: &mut Vec<Action>,
    ) {
        self.recompute_allocs(view, job, predictor, out);
    }

    /// Alg. 2 lines 17-20.
    fn on_task_finished(
        &mut self,
        view: &SchedView,
        job: JobId,
        predictor: &mut dyn Predictor,
        out: &mut Vec<Action>,
    ) {
        self.recompute_allocs(view, job, predictor, out);
    }

    fn on_heartbeat(
        &mut self,
        view: &SchedView,
        node: NodeId,
        _predictor: &mut dyn Predictor,
        out: &mut Vec<Action>,
    ) {
        self.sync(view);
        self.expire_awaiting(view, out);
        // Failure-reactive gate: a blacklisted node still expires its
        // await ledger (pure bookkeeping) but launches nothing new — no
        // maps, reduces, awaits, releases or spec copies.
        if self.blacklist.blocks_node(view, node) {
            return;
        }
        // One claim generation spans the whole heartbeat (both passes and
        // the reduce phase); the slot overlay likewise.
        self.claims.begin(view.jobs_base, view.jobs);
        self.overlay.begin(view.cluster.num_nodes());

        let mut free_reduce = view.cluster.vm(node).free_reduce_slots();
        // Rack-aware tie-break for the non-local pick: among tasks with no
        // replica on `n`, prefer one with a replica in n's *rack* — if it
        // ends up launching remotely on n the fetch stays off the shared
        // cross-rack core. Inert on the flat topology (no rack index).
        let racked = view.cluster.topology().is_racked();
        let my_rack = view.cluster.rack_of(node);
        let tuning = self.tuning;
        // Split the pooled state into disjoint field borrows for the
        // placement loop below.
        let Self {
            ref mut claims,
            ref index,
            ref mut overlay,
            ref mut awaiting_since,
            ref blacklist,
            ..
        } = *self;
        let mut released_this_hb = false;
        // Bound cross-node routing per heartbeat (cost control; every
        // node heartbeats every 3 s so global work still spreads fast).
        let mut routed = 0u32;
        let max_routed = tuning.max_routed;

        // Two passes over the persistent EDF-cold-first index:
        //   pass 0 — guaranteed allocations (Alg. 2 caps enforced);
        //   pass 1 — spare capacity, work-conserving: same locality
        //            mechanism, caps ignored; remote fallback only for
        //            jobs already past their deadline. The paper's caps
        //            are *minimums* to meet deadlines — leaving surplus
        //            slots idle would forfeit the Fig. 2(b)/Fig. 3
        //            completion-time gains the paper reports.
        let passes: u8 = if tuning.spare_pass { 2 } else { 1 };
        for pass in 0..passes {
            // Each job drains under strict EDF priority: the earliest-
            // deadline job takes every placement it can before the next
            // job is considered; the walk aborts as soon as nothing can
            // place anywhere, so a saturated cluster visits O(1) jobs.
            'jobs: for jid in index.iter() {
                let job = &view.jobs[view.slot(jid)];
                if job.is_done() || job.map_finished() {
                    continue;
                }
                loop {
                    // Global exhaustion: nothing can place anywhere.
                    if free_at(view, overlay, node) == 0 && routed >= max_routed {
                        break 'jobs;
                    }
                    if pass == 0 {
                        let sched = job.scheduled_maps() + claims.maps_claimed(job.id);
                        // Cold jobs bypass the cap to bootstrap statistics.
                        if !job.cold() && sched >= job.alloc_map_slots {
                            break;
                        }
                    }
                    // Alg. 1 lines 1-2: local task on the heartbeating node.
                    if free_at(view, overlay, node) > 0 {
                        if let Some(t) = next_unclaimed_local(job, node, claims) {
                            claims.claim_map(job.id, t);
                            out.push(Action::LaunchMap { job: job.id, task: t, node });
                            overlay.take(node.idx());
                            continue;
                        }
                    }
                    // Alg. 1 lines 3-13: non-local task. Prefer a task
                    // with a replica in n's rack only when n has a free
                    // slot — i.e. when the pick could fall back to a
                    // remote launch *on n*, where rack-nearness keeps the
                    // fetch off the shared core. In routing-only mode
                    // (free[n] == 0) keep the block-order pick: a
                    // rack-near preference there could select an
                    // unroutable task and skip a routable one.
                    let rack_pick = if racked && free_at(view, overlay, node) > 0 {
                        next_unclaimed_rack(job, my_rack, claims)
                    } else {
                        None
                    };
                    let Some(t) = rack_pick.or_else(|| next_unclaimed_any(job, claims))
                    else {
                        break;
                    };
                    let Some(target) = choose_target_with(tuning, view, job, t) else {
                        // No replica registered (degenerate input): remote.
                        if free_at(view, overlay, node) > 0 {
                            claims.claim_map(job.id, t);
                            out.push(Action::LaunchMap { job: job.id, task: t, node });
                            overlay.take(node.idx());
                            continue;
                        }
                        break;
                    };
                    // Never route new work onto a blacklisted PM: skip the
                    // data-local routing and the delayed await and fall
                    // through to a remote launch on the (non-blacklisted)
                    // heartbeating node instead.
                    if blacklist.blocks_node(view, target) {
                        if free_at(view, overlay, node) > 0 {
                            claims.claim_map(job.id, t);
                            out.push(Action::LaunchMap { job: job.id, task: t, node });
                            overlay.take(node.idx());
                            continue;
                        }
                        break;
                    }
                    // Target has spare capacity: immediate *data-local*
                    // launch on it (Alg. 1 line 13).
                    if free_at(view, overlay, target) > 0 && routed < max_routed {
                        claims.claim_map(job.id, t);
                        out.push(Action::LaunchMap { job: job.id, task: t, node: target });
                        overlay.take(target.idx());
                        routed += 1;
                        continue;
                    }
                    // Delayed launch through reconfiguration (guaranteed
                    // pass only — spare capacity must not strip cores).
                    // Only worth waiting when the target PM already has a
                    // registered release: the hot-plug then lands within
                    // ~hotplug_ms. Waiting speculatively under backlog
                    // loses more than the remote-read penalty (releases
                    // are rare when every core has local work), so
                    // otherwise we fall through to a remote launch.
                    let release_ready = !tuning.await_requires_release
                        || view.cm.rq_depth(view.cluster.pm_of(target)) > 0;
                    if pass == 0
                        && release_ready
                        && !released_this_hb
                        && free_at(view, overlay, node) > 0
                        && view.cluster.vm(node).can_release_core()
                    {
                        claims.claim_map(job.id, t);
                        awaiting_since.push((job.id, t.0, view.now));
                        out.push(Action::AwaitReconfig {
                            job: job.id,
                            task: t,
                            target,
                            release_from: node,
                        });
                        released_this_hb = true;
                        overlay.take(node.idx()); // that core is now pledged
                        continue;
                    }
                    // No data-local placement available now: launch
                    // remotely on n (the EDF/Fair behaviour). Idling the
                    // slot instead costs more than the remote read.
                    // (The claim counts toward `maps_claimed` in either
                    // pass, but the Alg. 2 cap only reads it in pass 0 —
                    // same accounting the seed's `extra_sched` map kept.)
                    if free_at(view, overlay, node) > 0 {
                        claims.claim_map(job.id, t);
                        out.push(Action::LaunchMap { job: job.id, task: t, node });
                        overlay.take(node.idx());
                        continue;
                    }
                    break;
                }
            }
        }

        // ---- reduce phase (Alg. 2 lines 10-14 + spare pass) ----
        for pass in 0..passes {
            for jid in index.iter() {
                let job = &view.jobs[view.slot(jid)];
                if job.is_done() || !job.map_finished() {
                    continue;
                }
                while free_reduce > 0 {
                    let extra = claims.reduces_claimed(job.id);
                    if pass == 0 && job.running_reduces() + extra >= job.alloc_reduce_slots {
                        break;
                    }
                    let Some(t) = claims.claim_next_reduce(job) else {
                        break;
                    };
                    out.push(Action::LaunchReduce { job: job.id, task: t, node });
                    free_reduce -= 1;
                }
                if free_reduce == 0 {
                    break;
                }
            }
        }

        // ---- Alg. 1 line 12: idle cores become releases ----
        // Unconditional (deduplicated in the CM): a node that still has a
        // free core after both passes has no runnable local work, so its
        // core is offered to co-resident VMs. This is what seeds the RQ
        // that makes release-gated awaits fire at all.
        if free_at(view, overlay, node) > 0
            && !released_this_hb
            && view.cluster.vm(node).can_release_core()
        {
            out.push(Action::RegisterRelease { node });
        }

        speculative_fill(view, node, out);
    }

    /// Snapshots carry everything the view cannot reproduce: the await
    /// ledger (entry order drives the deterministic CancelAwait emission),
    /// the delta-Eq.10 dirty set, the next-change bounds, the tuning
    /// knobs, the live slot supply (re-planning) and the blacklist crash
    /// ledger. Derived state is rebuilt on restore — the EDF-cold-first
    /// index from the restored jobs, the bound heap from the live
    /// `bound_of` entries (dead heap entries are ignored by the pop-side
    /// liveness check, so heap-vs-rebuilt ordering differences are
    /// unobservable), and `reconfig_timeout` from the tuning.
    fn encode_state(&self, e: &mut Enc) {
        e.f64(self.tuning.w_rq);
        e.f64(self.tuning.w_aq);
        e.bool(self.tuning.await_requires_release);
        e.u32(self.tuning.max_routed);
        e.bool(self.tuning.spare_pass);
        e.f64(self.tuning.timeout_heartbeats);
        e.usize(self.awaiting_since.len());
        for &(job, task, since) in &self.awaiting_since {
            e.u32(job.0);
            e.u32(task);
            e.u64(since.0);
        }
        e.usize(self.covered);
        e.usize(self.win_base);
        e.usize(self.dirty_list.len());
        for &j in &self.dirty_list {
            e.u32(j.0);
        }
        e.usize(self.dirty_flag.len());
        for &f in &self.dirty_flag {
            e.bool(f);
        }
        e.usize(self.bound_of.len());
        for &b in &self.bound_of {
            match b {
                Some(t) => {
                    e.bool(true);
                    e.u64(t.0);
                }
                None => e.bool(false),
            }
        }
        e.bool(self.replan);
        e.u32(self.live_map_slots);
        e.u32(self.live_reduce_slots);
        self.blacklist.encode(e);
    }

    fn restore_state(&mut self, d: &mut Dec, view: &SchedView) -> Result<(), String> {
        self.tuning = DvcTuning {
            w_rq: d.f64()?,
            w_aq: d.f64()?,
            await_requires_release: d.bool()?,
            max_routed: d.u32()?,
            spare_pass: d.bool()?,
            timeout_heartbeats: d.f64()?,
        };
        self.reconfig_timeout =
            SimTime::from_secs_f64(view.cfg.heartbeat_s * self.tuning.timeout_heartbeats);
        let n = d.len(16)?;
        self.awaiting_since.clear();
        for _ in 0..n {
            let job = JobId(d.u32()?);
            let task = d.u32()?;
            let since = SimTime(d.u64()?);
            self.awaiting_since.push((job, task, since));
        }
        self.covered = d.usize()?;
        self.win_base = d.usize()?;
        if self.win_base != view.jobs_base {
            return Err(format!(
                "deadline_vc snapshot window base {} != view jobs_base {}",
                self.win_base, view.jobs_base
            ));
        }
        let n = d.len(4)?;
        self.dirty_list = (0..n)
            .map(|_| d.u32().map(JobId))
            .collect::<Result<_, _>>()?;
        let n = d.len(1)?;
        self.dirty_flag = (0..n).map(|_| d.bool()).collect::<Result<_, _>>()?;
        let n = d.len(1)?;
        self.bound_of.clear();
        self.bound_heap.clear();
        for slot in 0..n {
            let b = if d.bool()? {
                Some(SimTime(d.u64()?))
            } else {
                None
            };
            if let Some(t) = b {
                self.bound_heap
                    .push((Reverse(t), JobId((self.win_base + slot) as u32)));
            }
            self.bound_of.push(b);
        }
        self.index.clear();
        self.index.set_base(view.jobs_base);
        for job in view.jobs {
            if job.id.idx() < self.covered {
                self.index.set_key(job.id, active_key(job));
            }
        }
        self.replan = d.bool()?;
        self.live_map_slots = d.u32()?;
        self.live_reduce_slots = d.u32()?;
        self.blacklist.decode(d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::testutil::*;

    fn sched(w: &TestWorld) -> DeadlineVcScheduler {
        DeadlineVcScheduler::new(&w.cfg())
    }

    #[test]
    fn cold_jobs_take_precedence() {
        let w = TestWorld::two_jobs_with_deadlines(300.0, 900.0);
        // Make job 0 (earlier deadline) warm, job 1 cold.
        let mut w = w;
        w.warm_up_job(0);
        let view = w.view();
        let order = DeadlineVcScheduler::job_order(&view);
        assert_eq!(view.jobs[order[0]].id.0, 1, "cold job first despite later deadline");
    }

    #[test]
    fn index_matches_job_order() {
        let mut w = TestWorld::two_jobs_with_deadlines(300.0, 900.0);
        w.warm_up_job(0);
        let mut s = sched(&w);
        let view = w.view();
        for job in view.jobs {
            s.on_job_updated(&view, job.id);
        }
        s.check_index(&view).unwrap();
    }

    #[test]
    fn respects_map_slot_allocation() {
        let mut w = TestWorld::two_jobs_with_deadlines(300.0, 900.0);
        w.warm_up_job(0);
        w.warm_up_job(1);
        w.set_alloc(0, 1, 1);
        w.set_alloc(1, 1, 1);
        w.force_running_maps(0, 1); // job 0 at its cap
        let mut s = sched(&w);
        let actions = w.heartbeat_with(&mut s, w.node_with_local_for(1));
        for a in &actions {
            if let Action::LaunchMap { job, .. } = a {
                assert_ne!(job.0, 0, "job 0 is at its n_m cap");
            }
        }
    }

    #[test]
    fn prefers_local_launch_on_heartbeat_node() {
        let mut w = TestWorld::two_jobs();
        w.warm_up_job(0);
        w.warm_up_job(1);
        let node = w.node_with_local_for(0);
        let mut s = sched(&w);
        let actions = w.heartbeat_with(&mut s, node);
        let Some(Action::LaunchMap { job, task, node: n }) = actions
            .iter()
            .find(|a| matches!(a, Action::LaunchMap { .. }))
        else {
            panic!("expected a map launch: {actions:?}");
        };
        if *n == node {
            let js = &w.view_jobs()[job.idx()];
            assert!(js.map_is_local(*task, node), "launch on n must be local");
        }
    }

    #[test]
    fn nonlocal_task_routes_to_replica_node() {
        let mut w = TestWorld::one_job_no_local_on(NodeId(0));
        w.warm_up_job(0);
        w.fill_node_maps_except(NodeId(0)); // all other nodes busy
        // Register a release on every PM so a delayed local placement is
        // worth waiting for (otherwise the scheduler falls back remote).
        w.push_releases_everywhere();
        let mut s = sched(&w);
        let actions = w.heartbeat_with(&mut s, NodeId(0));
        // Node 0 has no replica of any pending block, other nodes are
        // full: expect an AwaitReconfig targeting a replica node.
        let awaits: Vec<_> = actions
            .iter()
            .filter(|a| matches!(a, Action::AwaitReconfig { .. }))
            .collect();
        assert_eq!(awaits.len(), 1, "exactly one delayed placement: {actions:?}");
        if let Action::AwaitReconfig { job, task, target, release_from } = awaits[0] {
            assert_eq!(*release_from, NodeId(0));
            let js = &w.view_jobs()[job.idx()];
            assert!(js.map_is_local(*task, *target), "target must hold the block");
        }
    }

    #[test]
    fn falls_back_remote_without_ready_release() {
        let mut w = TestWorld::one_job_no_local_on(NodeId(0));
        w.warm_up_job(0);
        w.fill_node_maps_except(NodeId(0));
        let mut s = sched(&w);
        let actions = w.heartbeat_with(&mut s, NodeId(0));
        // No release queue entries anywhere: waiting would stall, so the
        // task must launch remotely on the heartbeating node instead.
        assert!(
            actions.iter().all(|a| !matches!(a, Action::AwaitReconfig { .. })),
            "must not wait speculatively: {actions:?}"
        );
        assert!(
            actions.iter().any(|a| matches!(
                a,
                Action::LaunchMap { node, .. } if *node == NodeId(0)
            )),
            "must launch remotely on node 0: {actions:?}"
        );
    }

    #[test]
    fn choose_target_prefers_deep_release_queue() {
        let mut w = TestWorld::two_jobs();
        w.warm_up_job(0);
        let view = w.view();
        let job = &view.jobs[0];
        let t = job.pending_maps_iter().next().unwrap();
        let replicas = job.replica_nodes(t.0);
        assert!(replicas.len() >= 2);
        // Deepen the RQ of the last replica's PM.
        let favored = *replicas.last().unwrap();
        drop(view);
        w.push_release(favored);
        let view = w.view();
        let s = DeadlineVcScheduler::new(&w.cfg());
        let picked = s.choose_target(&view, &view.jobs[0], t).unwrap();
        assert_eq!(
            view.cluster.pm_of(picked),
            view.cluster.pm_of(favored),
            "deepest RQ PM must win"
        );
    }

    #[test]
    fn awaiting_tasks_expire() {
        let mut w = TestWorld::one_job_no_local_on(NodeId(0));
        w.warm_up_job(0);
        w.fill_node_maps_except(NodeId(0));
        // Stale releases that will never match (the releasing VMs are
        // fully busy), so the await is granted queue-entry but no core
        // ever arrives -> it must expire.
        w.push_releases_everywhere();
        let mut s = sched(&w);
        let actions = w.heartbeat_and_apply(&mut s, NodeId(0));
        assert!(actions
            .iter()
            .any(|a| matches!(a, Action::AwaitReconfig { .. })));
        // Advance past the timeout with no release ever arriving.
        w.advance(SimTime::from_secs_f64(60.0));
        let actions = w.heartbeat_with(&mut s, NodeId(0));
        assert!(
            actions
                .iter()
                .any(|a| matches!(a, Action::CancelAwait { .. })),
            "expired await must be cancelled: {actions:?}"
        );
    }

    /// The delta recompute path must agree with a straight full solve at
    /// the same instant whenever it does recompute a job.
    #[test]
    fn delta_alloc_matches_full_solve_on_trigger() {
        let mut w = TestWorld::two_jobs_with_deadlines(300.0, 900.0);
        w.warm_up_job(0);
        w.warm_up_job(1);
        let mut s = sched(&w);
        let view = w.view();
        let mut pred = crate::predictor::NativePredictor::new();
        let mut out = Vec::new();
        for job in view.jobs {
            s.on_job_added(&view, job.id, &mut pred, &mut out);
        }
        // Every job got an initial allocation (stored value is u32::MAX).
        for job in view.jobs {
            let d = job_demand(job, view.now).unwrap();
            let solved = crate::predictor::NativePredictor::solve_one(&d);
            let expect = if solved.infeasible {
                (s.max_map_slots, s.max_reduce_slots)
            } else {
                (
                    solved.map_slots.min(s.max_map_slots).max(1),
                    solved.reduce_slots.min(s.max_reduce_slots).max(1),
                )
            };
            assert!(
                out.iter().any(|a| matches!(
                    a,
                    Action::SetAlloc { job: j, map_slots, reduce_slots }
                        if *j == job.id && (*map_slots, *reduce_slots) == expect
                )),
                "job {:?}: expected SetAlloc {expect:?} in {out:?}",
                job.id
            );
        }
    }
}
