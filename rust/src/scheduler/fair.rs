//! Hadoop Fair Scheduler (the paper's comparison baseline, [3]).
//!
//! Each job is its own pool with equal weight; the fair share of a job is
//! `total_slots / active_jobs`. On a heartbeat, jobs are ranked by
//! *deficit* (running tasks normalized by fair share, fewest first — the
//! most-starved job gets the slot), with submission time breaking ties.
//! Map tasks prefer node-local blocks but fall back to remote immediately
//! (locality patience is the Delay variant, `delay.rs`).

use crate::cluster::{LocalityTier, NodeId};
use crate::mapreduce::JobState;
use crate::predictor::Predictor;

use super::{greedy_fill, speculative_fill, Action, ClaimLedger, SchedView, Scheduler, SchedulerKind};

#[derive(Debug, Default)]
pub struct FairScheduler {
    /// Pooled job-order and claim buffers (reused every heartbeat).
    order: Vec<usize>,
    claims: ClaimLedger,
}

impl FairScheduler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Rank active jobs most-starved-first into `order` (pooled). The
    /// comparator's final `id` tie-break makes it a total order, so the
    /// in-place unstable sort yields exactly the stable sort's result
    /// without its temporary buffer.
    pub(crate) fn fair_order_into(view: &SchedView, order: &mut Vec<usize>) {
        order.clear();
        order.extend((0..view.jobs.len()).filter(|&i| !view.jobs[i].is_done()));
        if order.is_empty() {
            return;
        }
        let share = view.cfg.total_map_slots() as f64 / order.len() as f64;
        order.sort_unstable_by(|&a, &b| {
            let (ja, jb) = (&view.jobs[a], &view.jobs[b]);
            let da = deficit(ja, share);
            let db = deficit(jb, share);
            da.partial_cmp(&db)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(ja.submitted.cmp(&jb.submitted))
                .then(ja.id.cmp(&jb.id))
        });
    }

    /// Allocating convenience wrapper around [`Self::fair_order_into`]
    /// (tests and the naive reference implementations).
    pub(crate) fn fair_order(view: &SchedView) -> Vec<usize> {
        let mut order = Vec::new();
        Self::fair_order_into(view, &mut order);
        order
    }
}

fn deficit(job: &JobState, share: f64) -> f64 {
    let running = (job.running_maps() + job.running_reduces()) as f64;
    running / share.max(1e-9)
}

impl Scheduler for FairScheduler {
    fn kind(&self) -> SchedulerKind {
        SchedulerKind::Fair
    }

    fn on_heartbeat(
        &mut self,
        view: &SchedView,
        node: NodeId,
        _predictor: &mut dyn Predictor,
        out: &mut Vec<Action>,
    ) {
        Self::fair_order_into(view, &mut self.order);
        greedy_fill(view, node, &self.order, &mut self.claims, |_| LocalityTier::Remote, out);
        speculative_fill(view, node, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::testutil::*;

    #[test]
    fn starved_job_ranks_first() {
        let mut w = TestWorld::two_jobs();
        // Give job 0 lots of running tasks; job 1 none.
        w.force_running_maps(0, 3);
        let view = w.view();
        let order = FairScheduler::fair_order(&view);
        assert_eq!(view.jobs[order[0]].id.0, 1, "job 1 is most starved");
    }

    #[test]
    fn equal_deficit_breaks_by_submission() {
        let w = TestWorld::two_jobs();
        let view = w.view();
        let order = FairScheduler::fair_order(&view);
        assert_eq!(view.jobs[order[0]].id.0, 0);
    }

    #[test]
    fn shares_slots_between_jobs() {
        let mut w = TestWorld::two_jobs();
        // Node 0 heartbeat with 2 free slots and both jobs idle: after the
        // first launch job 0 has deficit > 0, but greedy_fill uses a single
        // ranking per heartbeat; over two heartbeats both jobs run.
        let a1 = w.heartbeat_and_apply(&mut FairScheduler::new(), NodeId(0));
        assert!(!a1.is_empty());
        let a2 = w.heartbeat_and_apply(&mut FairScheduler::new(), NodeId(1));
        let launched_jobs: std::collections::HashSet<u32> = a1
            .iter()
            .chain(&a2)
            .filter_map(|a| match a {
                Action::LaunchMap { job, .. } => Some(job.0),
                _ => None,
            })
            .collect();
        assert!(
            launched_jobs.contains(&0) && launched_jobs.contains(&1),
            "fair sharing must serve both jobs: {launched_jobs:?}"
        );
    }
}
