//! Hadoop Fair Scheduler (the paper's comparison baseline, [3]).
//!
//! Each job is its own pool with equal weight; the fair share of a job is
//! `total_slots / active_jobs`. On a heartbeat, jobs are ranked by
//! *deficit* (running tasks normalized by fair share, fewest first — the
//! most-starved job gets the slot), with submission time breaking ties.
//! Map tasks prefer node-local blocks but fall back to remote immediately
//! (locality patience is the Delay variant, `delay.rs`).
//!
//! The ranking is kept as a persistent [`OrderIndex`] keyed on
//! [`fair_key`] and re-keyed only when a job's running-task count
//! changes (`on_job_updated`): the fair share is a *positive constant*
//! within a heartbeat, so dividing the integer running counts by it is
//! strictly monotone and the deficit sort's order is exactly the key
//! order `(running, submitted, id)` — no per-heartbeat sort needed.

use crate::cluster::{LocalityTier, NodeId, PmId};
use crate::mapreduce::{JobId, JobState};
use crate::predictor::Predictor;
use crate::sim::SimTime;
use crate::util::codec::{Dec, Enc};

use super::{
    greedy_fill, speculative_fill, Action, BlacklistPolicy, ClaimLedger, OrderIndex, SchedView,
    Scheduler, SchedulerKind,
};

/// The persistent fair-ranking key; ties beyond it break on `JobId`
/// inside the index, matching the naive comparator's final tie-break.
pub(crate) type FairKey = (u32, SimTime);

/// Deficit rank of `job` as an exact integer key: running tasks, then
/// submission time. See the module docs for why this orders identically
/// to the floating-point deficit sort.
pub(crate) fn fair_key(job: &JobState) -> FairKey {
    (job.running_maps() + job.running_reduces(), job.submitted)
}

#[derive(Debug, Default)]
pub struct FairScheduler {
    index: OrderIndex<FairKey>,
    covered: usize,
    claims: ClaimLedger,
    blacklist: BlacklistPolicy,
}

impl FairScheduler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Rank active jobs most-starved-first into `order` (pooled). The
    /// comparator's final `id` tie-break makes it a total order, so the
    /// in-place unstable sort yields exactly the stable sort's result
    /// without its temporary buffer. Retained as the from-scratch oracle
    /// for the persistent index (naive references, property tests).
    pub(crate) fn fair_order_into(view: &SchedView, order: &mut Vec<usize>) {
        order.clear();
        order.extend((0..view.jobs.len()).filter(|&i| !view.jobs[i].is_done()));
        if order.is_empty() {
            return;
        }
        let share = view.cfg.total_map_slots() as f64 / order.len() as f64;
        order.sort_unstable_by(|&a, &b| {
            let (ja, jb) = (&view.jobs[a], &view.jobs[b]);
            let da = deficit(ja, share);
            let db = deficit(jb, share);
            da.partial_cmp(&db)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(ja.submitted.cmp(&jb.submitted))
                .then(ja.id.cmp(&jb.id))
        });
    }

    /// Allocating convenience wrapper around [`Self::fair_order_into`]
    /// (tests and the naive reference implementations).
    pub(crate) fn fair_order(view: &SchedView) -> Vec<usize> {
        let mut order = Vec::new();
        Self::fair_order_into(view, &mut order);
        order
    }

    fn sync(&mut self, view: &SchedView) {
        let total = view.total_jobs();
        if self.covered > total {
            self.index.clear();
            self.covered = 0;
        }
        self.index.set_base(view.jobs_base);
        for job in &view.jobs[self.covered.max(view.jobs_base) - view.jobs_base..] {
            self.index.set_key(job.id, active_key(job));
        }
        self.covered = total;
    }
}

fn active_key(job: &JobState) -> Option<FairKey> {
    if job.is_done() {
        None
    } else {
        Some(fair_key(job))
    }
}

fn deficit(job: &JobState, share: f64) -> f64 {
    let running = (job.running_maps() + job.running_reduces()) as f64;
    running / share.max(1e-9)
}

impl Scheduler for FairScheduler {
    fn kind(&self) -> SchedulerKind {
        SchedulerKind::Fair
    }

    fn on_sim_start(&mut self, view: &SchedView) {
        self.index.clear();
        self.covered = 0;
        self.blacklist = BlacklistPolicy::new(view.cfg);
    }

    fn on_job_updated(&mut self, view: &SchedView, job: JobId) {
        self.sync(view);
        self.index.set_key(job, active_key(view.job(job)));
    }

    fn check_index(&self, view: &SchedView) -> Result<(), String> {
        let mut expect: Vec<(FairKey, JobId)> =
            view.active_jobs().map(|j| (fair_key(j), j.id)).collect();
        expect.sort_unstable();
        self.index.check_matches(&expect)?;
        // The key order must reproduce the retained deficit sort exactly.
        for (got, &ji) in self.index.iter().zip(&Self::fair_order(view)) {
            if view.slot(got) != ji {
                return Err(format!(
                    "index order diverges from fair_order at job {got:?} vs index {ji}"
                ));
            }
        }
        self.claims.check_against(view.jobs)
    }

    fn on_job_added(
        &mut self,
        view: &SchedView,
        _job: JobId,
        _predictor: &mut dyn Predictor,
        _out: &mut Vec<Action>,
    ) {
        self.sync(view);
    }

    fn on_heartbeat(
        &mut self,
        view: &SchedView,
        node: NodeId,
        _predictor: &mut dyn Predictor,
        out: &mut Vec<Action>,
    ) {
        self.sync(view);
        if self.blacklist.blocks_node(view, node) {
            return;
        }
        let Self {
            ref index,
            ref mut claims,
            ..
        } = *self;
        greedy_fill(
            view,
            node,
            index.iter().map(|j| view.slot(j)),
            claims,
            |_| LocalityTier::Remote,
            out,
        );
        speculative_fill(view, node, out);
    }

    fn on_pm_failure(&mut self, view: &SchedView, pm: PmId) {
        self.blacklist.on_pm_failure(pm, view.now);
    }

    fn encode_state(&self, enc: &mut Enc) {
        self.blacklist.encode(enc);
    }

    fn restore_state(&mut self, dec: &mut Dec, _view: &SchedView) -> Result<(), String> {
        self.blacklist.decode(dec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::testutil::*;

    #[test]
    fn starved_job_ranks_first() {
        let mut w = TestWorld::two_jobs();
        // Give job 0 lots of running tasks; job 1 none.
        w.force_running_maps(0, 3);
        let view = w.view();
        let order = FairScheduler::fair_order(&view);
        assert_eq!(view.jobs[order[0]].id.0, 1, "job 1 is most starved");
    }

    #[test]
    fn equal_deficit_breaks_by_submission() {
        let w = TestWorld::two_jobs();
        let view = w.view();
        let order = FairScheduler::fair_order(&view);
        assert_eq!(view.jobs[order[0]].id.0, 0);
    }

    #[test]
    fn index_order_matches_fair_sort() {
        let mut w = TestWorld::two_jobs();
        w.force_running_maps(0, 3);
        let mut s = FairScheduler::new();
        let view = w.view();
        for job in view.jobs {
            s.on_job_updated(&view, job.id);
        }
        s.check_index(&view).unwrap();
        let order: Vec<usize> = s.index.iter().map(|j| j.idx()).collect();
        assert_eq!(order, FairScheduler::fair_order(&view));
    }

    #[test]
    fn shares_slots_between_jobs() {
        let mut w = TestWorld::two_jobs();
        // Node 0 heartbeat with 2 free slots and both jobs idle: after the
        // first launch job 0 has deficit > 0, but greedy_fill uses a single
        // ranking per heartbeat; over two heartbeats both jobs run.
        let a1 = w.heartbeat_and_apply(&mut FairScheduler::new(), NodeId(0));
        assert!(!a1.is_empty());
        let a2 = w.heartbeat_and_apply(&mut FairScheduler::new(), NodeId(1));
        let launched_jobs: std::collections::HashSet<u32> = a1
            .iter()
            .chain(&a2)
            .filter_map(|a| match a {
                Action::LaunchMap { job, .. } => Some(job.0),
                _ => None,
            })
            .collect();
        assert!(
            launched_jobs.contains(&0) && launched_jobs.contains(&1),
            "fair sharing must serve both jobs: {launched_jobs:?}"
        );
    }
}
