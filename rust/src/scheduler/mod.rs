//! Pluggable job schedulers.
//!
//! The coordinator drives a Hadoop-0.20-style protocol: every TaskTracker
//! (VM) heartbeats every `heartbeat_s`; the scheduler inspects an immutable
//! [`SchedView`] of the world and appends [`Action`]s to a pooled buffer,
//! which the coordinator validates and applies. Schedulers never mutate
//! world state directly — this keeps every policy replayable and lets the
//! property tests check the same invariants across all of them.
//!
//! # Hot-path bookkeeping
//!
//! Scheduler callbacks fire once per event, so their per-call cost *is*
//! the simulator's throughput. Two shared structures keep that cost O(1)
//! amortized per decision and allocation-free at steady state:
//!
//! * action buffers are owned by the coordinator and reused across events
//!   (callbacks take `out: &mut Vec<Action>` instead of returning a fresh
//!   `Vec`);
//! * within-heartbeat claims live in a generation-stamped `ClaimLedger`
//!   instead of a per-heartbeat `HashSet<(JobId, TaskId)>`: bumping the
//!   generation clears every claim in O(1), and the per-job reduce cursor
//!   replaces the O(claimed²) `pending_reduces_iter().nth(skip)` pattern;
//! * the scheduling order is a persistent [`OrderIndex`] (a `BTreeSet`
//!   keyed per policy) maintained across heartbeats via
//!   [`Scheduler::on_job_updated`] notifications from the coordinator —
//!   a heartbeat walks the index lazily and [`greedy_fill`] exits once
//!   the node is saturated, so re-keying is O(log jobs) per *changed*
//!   job instead of an O(jobs·log jobs) sort per heartbeat.
//!
//! The pre-index implementations are retained verbatim in [`reference`]
//! for differential testing and the `benches/simcore.rs` baseline.

mod deadline_vc;
mod delay;
mod edf;
mod fair;
mod fifo;
pub mod reference;
#[cfg(test)]
pub(crate) mod testutil;

pub use deadline_vc::{DeadlineVcScheduler, DvcTuning};
pub use delay::DelayScheduler;
pub use edf::EdfScheduler;
pub use fair::FairScheduler;
pub use fifo::FifoScheduler;

use crate::cluster::{Cluster, LocalityTier, NodeId, PmId};
use crate::config::SimConfig;
use crate::mapreduce::{JobId, JobState, TaskId};
use crate::predictor::Predictor;
use crate::reconfig::ConfigManager;
use crate::sim::SimTime;
use crate::util::codec::{Dec, Enc};

/// Which scheduler to run (CLI/bench selector).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulerKind {
    Fifo,
    Fair,
    Delay,
    Edf,
    /// The paper's proposed scheduler (Alg. 1 + Alg. 2).
    DeadlineVc,
}

impl SchedulerKind {
    pub fn name(self) -> &'static str {
        match self {
            SchedulerKind::Fifo => "fifo",
            SchedulerKind::Fair => "fair",
            SchedulerKind::Delay => "delay",
            SchedulerKind::Edf => "edf",
            SchedulerKind::DeadlineVc => "deadline_vc",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        Some(match s {
            "fifo" => SchedulerKind::Fifo,
            "fair" => SchedulerKind::Fair,
            "delay" => SchedulerKind::Delay,
            "edf" => SchedulerKind::Edf,
            "deadline_vc" | "proposed" => SchedulerKind::DeadlineVc,
            _ => return None,
        })
    }

    /// Parse a comma-separated scheduler list (`"fair,deadline_vc"`) —
    /// the `vcsched sweep --sched` axis override. `None` if any name is
    /// unknown; duplicates are preserved (the grid would double-count,
    /// which the caller surfaces as a user error in row counts).
    pub fn parse_list(s: &str) -> Option<Vec<SchedulerKind>> {
        s.split(',')
            .map(|part| SchedulerKind::from_name(part.trim()))
            .collect()
    }

    pub fn build(self, cfg: &SimConfig) -> Box<dyn Scheduler> {
        match self {
            SchedulerKind::Fifo => Box::new(FifoScheduler::new()),
            SchedulerKind::Fair => Box::new(FairScheduler::new()),
            SchedulerKind::Delay => Box::new(DelayScheduler::new(cfg.delay_heartbeats)),
            SchedulerKind::Edf => Box::new(EdfScheduler::new()),
            SchedulerKind::DeadlineVc => Box::new(DeadlineVcScheduler::new(cfg)),
        }
    }

    pub const ALL: [SchedulerKind; 5] = [
        SchedulerKind::Fifo,
        SchedulerKind::Fair,
        SchedulerKind::Delay,
        SchedulerKind::Edf,
        SchedulerKind::DeadlineVc,
    ];
}

/// Immutable world snapshot handed to schedulers.
///
/// `jobs` is a **window**: under streaming metrics the coordinator
/// retires completed jobs from the front of its job table, so
/// `jobs[0]` is the job with id `jobs_base`, not id 0. All policy
/// state keyed by job id must translate through [`SchedView::slot`]
/// (or the [`OrderIndex`]/[`ClaimLedger`] helpers, which do it
/// internally). Outside streaming mode `jobs_base` is always 0 and the
/// window is the complete job table.
pub struct SchedView<'a> {
    pub cfg: &'a SimConfig,
    pub cluster: &'a Cluster,
    pub jobs: &'a [JobState],
    /// Id of `jobs[0]` — jobs below this were retired (all done).
    pub jobs_base: usize,
    pub cm: &'a ConfigManager,
    pub now: SimTime,
}

impl SchedView<'_> {
    /// Indices of jobs that still have work (not Done).
    pub fn active_jobs(&self) -> impl Iterator<Item = &JobState> {
        self.jobs.iter().filter(|j| !j.is_done())
    }

    /// Window index of `id` into [`SchedView::jobs`]. Panics (underflow)
    /// on a retired id — retired jobs are done and schedulers are never
    /// handed their ids.
    pub fn slot(&self, id: JobId) -> usize {
        id.idx() - self.jobs_base
    }

    /// The job's current state (see [`SchedView::slot`]).
    pub fn job(&self, id: JobId) -> &JobState {
        &self.jobs[self.slot(id)]
    }

    /// Like [`SchedView::job`] but `None` for retired or out-of-range
    /// ids — for state that may lag retirement (await ledgers, bound
    /// heaps).
    pub fn job_get(&self, id: JobId) -> Option<&JobState> {
        id.idx()
            .checked_sub(self.jobs_base)
            .and_then(|s| self.jobs.get(s))
    }

    /// Jobs ever arrived: retired prefix + current window.
    pub fn total_jobs(&self) -> usize {
        self.jobs_base + self.jobs.len()
    }
}

/// A scheduling decision. The coordinator validates slot/queue capacity
/// before applying; an invalid action is a scheduler bug and panics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Action {
    /// Launch map task `task` of `job` on `node` (slot must be free).
    LaunchMap {
        job: JobId,
        task: TaskId,
        node: NodeId,
    },
    /// Launch reduce task (reduce slot must be free; job map phase done).
    LaunchReduce {
        job: JobId,
        task: TaskId,
        node: NodeId,
    },
    /// Alg. 1 lines 11-13: queue `task` for a delayed *local* launch on
    /// `target` (AQ entry on target's PM) and register the free core of
    /// `release_from` (RQ entry on its PM).
    AwaitReconfig {
        job: JobId,
        task: TaskId,
        target: NodeId,
        release_from: NodeId,
    },
    /// Register a free core without a paired assign (Alg. 1 line 12 when
    /// the heartbeating node simply has nothing local to run).
    RegisterRelease { node: NodeId },
    /// Give up on a delayed local launch (reconfiguration starved); the
    /// task returns to Pending and its AQ entry is cancelled.
    CancelAwait { job: JobId, task: TaskId },
    /// Update a job's slot allocation from the predictor (Alg. 2 line 2 /
    /// 19). Recorded by the coordinator into `JobState::alloc_*`.
    SetAlloc {
        job: JobId,
        map_slots: u32,
        reduce_slots: u32,
    },
    /// Launch a speculative (backup) copy of *running* map `task` on
    /// `node` (LATE-style; only valid when the failure model enables
    /// speculation, the task has no live spec copy yet, and `node` differs
    /// from the primary's node). First finisher wins; the coordinator
    /// kills the loser. Emitted by the shared [`speculative_fill`] pass,
    /// so every scheduler speculates under the same policy.
    LaunchSpeculativeMap {
        job: JobId,
        task: TaskId,
        node: NodeId,
    },
    /// Launch a speculative (backup) copy of *running* reduce `task` on
    /// `node` — the reduce-side mirror of [`Action::LaunchSpeculativeMap`]
    /// (same LATE trigger rules, same first-finisher-wins resolution).
    LaunchSpeculativeReduce {
        job: JobId,
        task: TaskId,
        node: NodeId,
    },
}

/// The scheduler interface (see module docs for the protocol). Callbacks
/// append to `out`, a buffer the coordinator owns, clears before each
/// call and reuses across events — the hot loop allocates no action
/// vectors at steady state.
pub trait Scheduler {
    fn kind(&self) -> SchedulerKind;

    fn name(&self) -> &'static str {
        self.kind().name()
    }

    /// First event of a `World` run. A scheduler instance may be reused
    /// across Worlds (job numbering restarts at zero), so persistent
    /// ordered indexes must drop state carried over from a previous run
    /// here. Stateless and reference schedulers ignore it.
    fn on_sim_start(&mut self, _view: &SchedView) {}

    /// A job's scheduling-relevant state changed since the last callback
    /// (task launched / finished / killed / re-pended, stats or
    /// allocation updated). The coordinator batches these notifications
    /// and flushes the batch immediately before the next scheduler
    /// callback, so a persistent index only re-keys jobs that actually
    /// changed. Over-notification is always safe; the reference
    /// schedulers (which re-sort from scratch) ignore it.
    fn on_job_updated(&mut self, _view: &SchedView, _job: JobId) {}

    /// Debug-only: verify any internal persistent index against a
    /// from-scratch recomputation. Called by the property tests after
    /// every event; production code never calls it.
    fn check_index(&self, _view: &SchedView) -> Result<(), String> {
        Ok(())
    }

    /// A new job appeared (Alg. 2 line 1-2).
    fn on_job_added(
        &mut self,
        _view: &SchedView,
        _job: JobId,
        _predictor: &mut dyn Predictor,
        _out: &mut Vec<Action>,
    ) {
    }

    /// Heartbeat from `node`; append assignments for its free slots.
    fn on_heartbeat(
        &mut self,
        view: &SchedView,
        node: NodeId,
        predictor: &mut dyn Predictor,
        out: &mut Vec<Action>,
    );

    /// A task of `job` finished (Alg. 2 lines 17-20).
    fn on_task_finished(
        &mut self,
        _view: &SchedView,
        _job: JobId,
        _predictor: &mut dyn Predictor,
        _out: &mut Vec<Action>,
    ) {
    }

    /// PM `pm` just crashed (notification only — `PmFailure` reduces to
    /// `Decision::None`, so no actions may be emitted here; the next
    /// heartbeat acts on the updated policy state). Drives the
    /// [`BlacklistPolicy`] and deadline_vc's live-slot re-planning.
    /// Replay-safe: replays apply logged heartbeat actions directly, so
    /// scheduler-side state needs no reconstruction there.
    fn on_pm_failure(&mut self, _view: &SchedView, _pm: PmId) {}

    /// PM `pm` came back (same notification-only contract).
    fn on_pm_recovery(&mut self, _view: &SchedView, _pm: PmId) {}

    /// Serialize policy state into a snapshot. The default writes nothing:
    /// fifo/fair/edf keep only an [`OrderIndex`] whose keys are pure
    /// functions of the view, and their heartbeat-side sync pass rebuilds
    /// it lazily — a freshly built instance is behavior-identical after
    /// resume. Schedulers with state the view cannot reproduce (delay's
    /// per-job wait counters, deadline_vc's award ledger) override both
    /// this and [`Scheduler::restore_state`].
    fn encode_state(&self, _enc: &mut Enc) {}

    /// Restore policy state written by [`Scheduler::encode_state`] on a
    /// scheduler of the same kind, with `view` reflecting the restored
    /// world (used to rebuild derived indexes). Default: nothing to do.
    fn restore_state(&mut self, _dec: &mut Dec, _view: &SchedView) -> Result<(), String> {
        Ok(())
    }
}

/// Within-heartbeat claim bookkeeping, pooled across heartbeats.
///
/// Launch actions are applied only after the scheduler returns, so tasks
/// claimed earlier in the same heartbeat still look Pending in the view
/// and must be skipped on later picks. The seed kept a per-heartbeat
/// `HashSet<(JobId, TaskId)>` plus a `Vec` of claimed reduces counted
/// with a linear filter (O(claimed²) per heartbeat) — both allocating on
/// the hottest path in the repo. This ledger replaces them with
/// generation-stamped arrays: a claim is a stamp equal to the current
/// generation, `begin` bumps the generation (clearing every claim in
/// O(1)) and the arrays are grown once per job/task, never freed.
#[derive(Debug, Default)]
pub(crate) struct ClaimLedger {
    gen: u64,
    /// Job id of slot 0 in the per-job arrays below — tracks the view's
    /// `jobs_base` so retired jobs cost no memory (the tentpole
    /// job-count-independence claim covers scheduler state too).
    base: usize,
    /// Absolute job-id high-water mark of sized slots: slots for ids in
    /// `base..covered` exist and are task-sized. The job list is
    /// append-only, so `begin` only ever sizes the new suffix.
    covered: usize,
    /// `[job][map task]` claim stamps; claimed iff `== gen`.
    map_stamps: Vec<Vec<u64>>,
    /// Per-job count of maps claimed this generation.
    map_count: Vec<u32>,
    map_count_gen: Vec<u64>,
    /// Per-job scan floor for the next reduce pick this generation — the
    /// incremental equivalent of `pending_reduces_iter().nth(claimed)`.
    reduce_from: Vec<u32>,
    reduce_from_gen: Vec<u64>,
    /// Per-job count of reduces claimed this generation.
    reduce_count: Vec<u32>,
    reduce_count_gen: Vec<u64>,
}

impl ClaimLedger {
    pub(crate) fn new() -> Self {
        Self::default()
    }

    /// Start a scheduling round: invalidate all claims (the O(1)
    /// generation bump), drop slots for jobs the view retired, and size
    /// the tables for jobs that arrived since the last round — only the
    /// changed prefix/suffix is touched, so the whole call is O(1) when
    /// the window didn't move.
    pub(crate) fn begin(&mut self, base: usize, jobs: &[JobState]) {
        self.gen += 1;
        if base < self.base {
            // Job numbering restarted (scheduler reuse across Worlds):
            // every slot is stale, start over.
            self.map_stamps.clear();
            self.map_count.clear();
            self.map_count_gen.clear();
            self.reduce_from.clear();
            self.reduce_from_gen.clear();
            self.reduce_count.clear();
            self.reduce_count_gen.clear();
            self.base = base;
            self.covered = base;
        } else if base > self.base {
            let k = (base - self.base).min(self.map_stamps.len());
            self.map_stamps.drain(..k);
            self.map_count.drain(..k);
            self.map_count_gen.drain(..k);
            self.reduce_from.drain(..k);
            self.reduce_from_gen.drain(..k);
            self.reduce_count.drain(..k);
            self.reduce_count_gen.drain(..k);
            self.base = base;
            self.covered = self.covered.max(base);
        }
        let total = base + jobs.len();
        if total > self.covered {
            let w = jobs.len();
            self.map_stamps.resize_with(w, Vec::new);
            self.map_count.resize(w, 0);
            self.map_count_gen.resize(w, 0);
            self.reduce_from.resize(w, 0);
            self.reduce_from_gen.resize(w, 0);
            self.reduce_count.resize(w, 0);
            self.reduce_count_gen.resize(w, 0);
            for (j, job) in jobs.iter().enumerate().skip(self.covered - base) {
                self.map_stamps[j].resize(job.total_maps() as usize, 0);
            }
            self.covered = total;
        }
    }

    pub(crate) fn claim_map(&mut self, job: JobId, t: TaskId) {
        let j = job.idx() - self.base;
        let count = self.maps_claimed(job) + 1;
        let stamps = &mut self.map_stamps[j];
        if stamps.len() <= t.0 as usize {
            // Self-healing under scheduler reuse across Worlds: a fresh
            // run restarts job numbering, so the high-water-sized prefix
            // can be stale. Stale *stamps* are harmless (`gen` is
            // monotone, so old stamps never equal the current round).
            stamps.resize(t.0 as usize + 1, 0);
        }
        stamps[t.0 as usize] = self.gen;
        self.map_count[j] = count;
        self.map_count_gen[j] = self.gen;
    }

    pub(crate) fn map_claimed(&self, job: JobId, t: TaskId) -> bool {
        self.map_stamps[job.idx() - self.base]
            .get(t.0 as usize)
            .is_some_and(|&s| s == self.gen)
    }

    /// Maps claimed for `job` this round.
    pub(crate) fn maps_claimed(&self, job: JobId) -> u32 {
        let j = job.idx() - self.base;
        if self.map_count_gen[j] == self.gen {
            self.map_count[j]
        } else {
            0
        }
    }

    /// Reduces claimed for `job` this round.
    pub(crate) fn reduces_claimed(&self, job: JobId) -> u32 {
        let j = job.idx() - self.base;
        if self.reduce_count_gen[j] == self.gen {
            self.reduce_count[j]
        } else {
            0
        }
    }

    /// Claim the next pending reduce of `job` not yet claimed this round.
    /// Claims are made in ascending index order, so "skip the claimed
    /// ones" is exactly "start after the last claim" — each call is O(1)
    /// amortized where `nth(claimed)` rescanned the array from the front.
    pub(crate) fn claim_next_reduce(&mut self, job: &JobState) -> Option<TaskId> {
        let j = job.id.idx() - self.base;
        let from = if self.reduce_from_gen[j] == self.gen {
            self.reduce_from[j]
        } else {
            0
        };
        let t = job.next_pending_reduce_at(from)?;
        self.reduce_from[j] = t.0 + 1;
        self.reduce_from_gen[j] = self.gen;
        self.reduce_count[j] = self.reduces_claimed(job.id) + 1;
        self.reduce_count_gen[j] = self.gen;
        Some(t)
    }

    /// Debug-only consistency check (property tests): the stamped claims
    /// of the *current* generation must agree with both the cached counts
    /// and the job state they were applied to. Valid after the claimed
    /// actions have been applied and only under a failure-free config
    /// (a PM crash re-pends Running maps without bumping the generation).
    pub fn check_against(&self, jobs: &[JobState]) -> Result<(), String> {
        for (j, job) in jobs.iter().enumerate() {
            if j >= self.map_stamps.len() {
                break;
            }
            let stamps = &self.map_stamps[j];
            let mut stamped = 0u32;
            for (ti, &s) in stamps.iter().enumerate().take(job.total_maps() as usize) {
                if s != self.gen {
                    continue;
                }
                stamped += 1;
                if job.map_state(TaskId(ti as u32)).is_pending() {
                    return Err(format!(
                        "job {j}: map {ti} claimed this round but still Pending"
                    ));
                }
            }
            if stamped != self.maps_claimed(job.id) {
                return Err(format!(
                    "job {j}: {} map stamps vs cached count {}",
                    stamped,
                    self.maps_claimed(job.id)
                ));
            }
            let claimed_r = self.reduces_claimed(job.id);
            let live_r = job.running_reduces() + job.completed_reduces();
            if claimed_r > live_r {
                return Err(format!(
                    "job {j}: {claimed_r} reduces claimed this round but only \
                     {live_r} running/completed"
                ));
            }
            if self.reduce_from_gen[j] == self.gen && self.reduce_from[j] > job.total_reduces() {
                return Err(format!(
                    "job {j}: reduce cursor {} past total {}",
                    self.reduce_from[j],
                    job.total_reduces()
                ));
            }
        }
        Ok(())
    }
}

/// A persistent scheduling-order index: the jobs the scheduler would
/// consider, kept sorted by a per-policy key across heartbeats instead of
/// re-sorted per heartbeat. `set_key` is O(log jobs) and touches the tree
/// only when the key actually changed; iteration yields jobs in exactly
/// the order the retained naive sort would produce (ties broken by
/// `JobId`, which every naive comparator also ends on).
#[derive(Debug, Default)]
pub(crate) struct OrderIndex<K: Ord + Copy> {
    set: std::collections::BTreeSet<(K, JobId)>,
    /// Window of cached keys: slot 0 holds job id `base`. Retired jobs
    /// (always key-`None`) are dropped via [`OrderIndex::set_base`] so
    /// the cache tracks the live window, not the full job history.
    key_of: Vec<Option<K>>,
    base: usize,
}

impl<K: Ord + Copy> OrderIndex<K> {
    pub(crate) fn new() -> Self {
        Self {
            set: std::collections::BTreeSet::new(),
            key_of: Vec::new(),
            base: 0,
        }
    }

    pub(crate) fn clear(&mut self) {
        self.set.clear();
        self.key_of.clear();
        self.base = 0;
    }

    /// Advance the window floor to the view's `jobs_base`, dropping the
    /// retired prefix. Retired jobs are done, so their cached keys must
    /// already be `None` (the coordinator delivers the final
    /// `on_job_updated` before retiring).
    pub(crate) fn set_base(&mut self, base: usize) {
        if base <= self.base {
            return;
        }
        let k = (base - self.base).min(self.key_of.len());
        debug_assert!(self.key_of[..k].iter().all(Option::is_none));
        self.key_of.drain(..k);
        self.base = base;
    }

    /// Insert, move or remove `job`. `None` removes (job done). No-op —
    /// and no tree touch — when the key is unchanged.
    pub(crate) fn set_key(&mut self, job: JobId, key: Option<K>) {
        let j = job.idx() - self.base;
        if self.key_of.len() <= j {
            self.key_of.resize(j + 1, None);
        }
        if self.key_of[j] == key {
            return;
        }
        if let Some(old) = self.key_of[j].take() {
            self.set.remove(&(old, job));
        }
        if let Some(k) = key {
            self.set.insert((k, job));
        }
        self.key_of[j] = key;
    }

    /// Jobs in key order (the scheduling order). Lazy — callers that
    /// early-exit once slots are exhausted never visit the tail.
    pub(crate) fn iter(&self) -> impl Iterator<Item = JobId> + '_ {
        self.set.iter().map(|&(_, j)| j)
    }

    /// Debug-only: assert the index holds exactly `expect` (job, key)
    /// pairs in the same order a from-scratch sort would produce.
    pub(crate) fn check_matches(&self, expect: &[(K, JobId)]) -> Result<(), String> {
        if self.set.len() != expect.len() {
            return Err(format!(
                "index has {} entries, from-scratch sort has {}",
                self.set.len(),
                expect.len()
            ));
        }
        for (got, want) in self.set.iter().zip(expect) {
            if got != want {
                return Err(format!("index entry {:?} != expected {:?}", got.1, want.1));
            }
        }
        Ok(())
    }
}

/// A PM is blacklisted once it crashes this many times inside the window.
pub(crate) const BLACKLIST_K: usize = 2;
/// Trailing window (seconds) over which crashes count toward the
/// blacklist; a blacklisted PM "proves itself" by simply staying up until
/// enough of its crash history ages out.
pub(crate) const BLACKLIST_WINDOW_S: f64 = 3600.0;

/// Failure-reactive launch gate shared by every scheduler (indexed and
/// naive reference alike): a PM that crashed [`BLACKLIST_K`]+ times
/// within the trailing [`BLACKLIST_WINDOW_S`] is *blacklisted* —
/// heartbeats from its VMs launch nothing new (no maps, reduces, spec
/// copies, awaits or releases) and deadline_vc stops routing work to its
/// nodes, until the crash history ages out. Enabled per-config
/// (`FailureModel::blacklist`); disabled it is a guaranteed no-op, so
/// failure-free runs stay byte-identical.
#[derive(Clone, Debug, Default)]
pub(crate) struct BlacklistPolicy {
    enabled: bool,
    /// Crash instants per PM, pruned to the window on insert (queries are
    /// `&self` and re-filter, so pruning is memoization only).
    crashes: Vec<Vec<SimTime>>,
}

impl BlacklistPolicy {
    pub(crate) fn new(cfg: &SimConfig) -> Self {
        Self {
            enabled: cfg.failures.blacklist,
            crashes: Vec::new(),
        }
    }

    fn window() -> SimTime {
        SimTime::from_secs_f64(BLACKLIST_WINDOW_S)
    }

    /// Record a crash of `pm` at `now` (no-op when disabled).
    pub(crate) fn on_pm_failure(&mut self, pm: PmId, now: SimTime) {
        if !self.enabled {
            return;
        }
        if self.crashes.len() <= pm.idx() {
            self.crashes.resize_with(pm.idx() + 1, Vec::new);
        }
        let list = &mut self.crashes[pm.idx()];
        list.retain(|&t| now.saturating_sub(t) <= Self::window());
        list.push(now);
    }

    /// Is `pm` currently blacklisted?
    pub(crate) fn blocks_pm(&self, pm: PmId, now: SimTime) -> bool {
        self.enabled
            && self.crashes.get(pm.idx()).is_some_and(|list| {
                list.iter()
                    .filter(|&&t| now.saturating_sub(t) <= Self::window())
                    .count()
                    >= BLACKLIST_K
            })
    }

    /// Is `node`'s PM currently blacklisted?
    pub(crate) fn blocks_node(&self, view: &SchedView, node: NodeId) -> bool {
        self.enabled && self.blocks_pm(view.cluster.pm_of(node), view.now)
    }

    /// Drop state carried over from a previous run (scheduler reuse
    /// across Worlds; called from `on_sim_start`).
    pub(crate) fn reset(&mut self) {
        self.crashes.clear();
    }

    /// Snapshot codec — the crash ledger is policy state the view cannot
    /// reproduce, so every scheduler's `encode_state` carries it.
    pub(crate) fn encode(&self, e: &mut Enc) {
        e.bool(self.enabled);
        e.usize(self.crashes.len());
        for list in &self.crashes {
            e.usize(list.len());
            for &t in list {
                e.u64(t.0);
            }
        }
    }

    pub(crate) fn decode(&mut self, d: &mut Dec) -> Result<(), String> {
        self.enabled = d.bool()?;
        let n = d.len(8)?;
        self.crashes = (0..n)
            .map(|_| {
                let k = d.len(8)?;
                (0..k).map(|_| Ok(SimTime(d.u64()?))).collect()
            })
            .collect::<Result<_, String>>()?;
        Ok(())
    }
}

/// Shared helper: launch as many tasks as `node` has free slots, scanning
/// `job_order` (indices into `view.jobs`). Used by the FIFO/Fair/Delay/EDF
/// baselines — pick the best-tier pending map the job's cap admits
/// (node-local > rack-local > off-rack; `max_tier_for` returns the worst
/// tier the job may accept on this heartbeat); reduces fill reduce slots
/// once the map phase is done. Under the flat topology the rack stage is
/// inert (no rack index exists), so `max_tier_for == Remote` reproduces
/// the seed's local-else-any behaviour exactly. Appends to `out`; the
/// caller's pooled `claims` ledger makes the whole call allocation-free.
pub(crate) fn greedy_fill(
    view: &SchedView,
    node: NodeId,
    job_order: impl IntoIterator<Item = usize>,
    claims: &mut ClaimLedger,
    max_tier_for: impl Fn(&JobState) -> LocalityTier,
    out: &mut Vec<Action>,
) {
    claims.begin(view.jobs_base, view.jobs);
    let vm = view.cluster.vm(node);
    let rack = view.cluster.rack_of(node);
    let racked = view.cluster.topology().is_racked();
    let mut free_map = vm.free_map_slots();
    let mut free_reduce = vm.free_reduce_slots();

    for ji in job_order {
        // Early exit once the node is saturated: no later job can launch
        // anything, so the visit count per heartbeat is bounded by the
        // slots filled, not the number of active jobs. (The naive
        // reference scans the full order; the skipped tail emits nothing
        // there either, so the action streams stay identical.)
        if free_map == 0 && free_reduce == 0 {
            break;
        }
        let job = &view.jobs[ji];
        if job.is_done() {
            continue;
        }
        // Map work.
        while free_map > 0 {
            let cap = max_tier_for(job);
            let pick = next_unclaimed_local(job, node, claims)
                .or_else(|| {
                    if racked && cap >= LocalityTier::RackLocal {
                        next_unclaimed_rack(job, rack, claims)
                    } else {
                        None
                    }
                })
                .or_else(|| {
                    if cap >= LocalityTier::Remote {
                        next_unclaimed_any(job, claims)
                    } else {
                        None
                    }
                });
            let Some(task) = pick else { break };
            claims.claim_map(job.id, task);
            out.push(Action::LaunchMap {
                job: job.id,
                task,
                node,
            });
            free_map -= 1;
        }
        // Reduce work (only after the map phase: Hadoop 0.20 semantics in
        // this engine — see mapreduce module docs).
        while free_reduce > 0 && job.map_finished() {
            let Some(task) = claims.claim_next_reduce(job) else { break };
            out.push(Action::LaunchReduce {
                job: job.id,
                task,
                node,
            });
            free_reduce -= 1;
        }
    }
}

/// Shared LATE-style speculation pass, appended to the end of **every**
/// scheduler's heartbeat (indexed and reference alike — it uses only plain
/// scans, no cursors or ledgers, so both paths stay action-identical).
///
/// Policy (see `docs/FAILURE_MODEL.md`), applied independently to the map
/// and reduce sides (each with its own one-per-heartbeat budget):
/// * only when the failure model enables speculation;
/// * at most **one** speculative map and **one** speculative reduce
///   launch per node-heartbeat;
/// * a job is map-eligible only when it has no pending or awaiting maps
///   (spare capacity would otherwise serve real work first) and at least
///   `spec_min_finished` finished maps (the duration estimate is warm);
///   reduce-eligible symmetrically: map phase done, no pending reduces,
///   `spec_min_finished`+ finished reduces;
/// * a running task is a straggler when its elapsed time exceeds
///   `spec_slowdown ×` the job's observed mean duration for its phase, it
///   has no live spec copy yet, and its primary runs on a *different*
///   node;
/// * among stragglers, pick the longest-running (ties: lowest job, then
///   lowest task id — strict `>` keeps the pick deterministic).
///
/// With speculation off this returns immediately, emitting nothing.
pub(crate) fn speculative_fill(view: &SchedView, node: NodeId, out: &mut Vec<Action>) {
    let fm = &view.cfg.failures;
    if !fm.speculation {
        return;
    }
    let vm = view.cluster.vm(node);
    // ---- map side ----
    // Slots already promised to this node earlier in this heartbeat.
    let promised = out
        .iter()
        .filter(|a| {
            matches!(a,
                Action::LaunchMap { node: n, .. }
                | Action::LaunchSpeculativeMap { node: n, .. } if *n == node)
        })
        .count() as u32;
    if vm.free_map_slots() > promised {
        let mut best: Option<(f64, JobId, TaskId)> = None;
        for job in view.active_jobs() {
            if job.pending_maps() > 0
                || job.awaiting_maps() > 0
                || job.running_maps() == 0
                || job.completed_maps() < fm.spec_min_finished
            {
                continue;
            }
            let threshold = fm.spec_slowdown * job.stats.t_map();
            for ti in 0..job.total_maps() {
                let t = TaskId(ti);
                let crate::mapreduce::TaskState::Running { node: pnode, started, .. } =
                    *job.map_state(t)
                else {
                    continue;
                };
                if pnode == node || job.spec_of(t).is_some() {
                    continue;
                }
                let elapsed = (view.now - started).as_secs_f64();
                if elapsed <= threshold {
                    continue;
                }
                if best.map_or(true, |(e, _, _)| elapsed > e) {
                    best = Some((elapsed, job.id, t));
                }
            }
        }
        if let Some((_, job, task)) = best {
            out.push(Action::LaunchSpeculativeMap { job, task, node });
        }
    }
    // ---- reduce side (same trigger rules, its own budget) ----
    let promised_r = out
        .iter()
        .filter(|a| {
            matches!(a,
                Action::LaunchReduce { node: n, .. }
                | Action::LaunchSpeculativeReduce { node: n, .. } if *n == node)
        })
        .count() as u32;
    if vm.free_reduce_slots() > promised_r {
        let mut best: Option<(f64, JobId, TaskId)> = None;
        for job in view.active_jobs() {
            if !job.map_finished()
                || job.pending_reduces() > 0
                || job.running_reduces() == 0
                || job.completed_reduces() < fm.spec_min_finished
            {
                continue;
            }
            let threshold = fm.spec_slowdown * job.stats.t_reduce();
            for ti in 0..job.total_reduces() {
                let t = TaskId(ti);
                let crate::mapreduce::TaskState::Running { node: pnode, started, .. } =
                    *job.reduce_state(t)
                else {
                    continue;
                };
                if pnode == node || job.reduce_spec_of(t).is_some() {
                    continue;
                }
                let elapsed = (view.now - started).as_secs_f64();
                if elapsed <= threshold {
                    continue;
                }
                if best.map_or(true, |(e, _, _)| elapsed > e) {
                    best = Some((elapsed, job.id, t));
                }
            }
        }
        if let Some((_, job, task)) = best {
            out.push(Action::LaunchSpeculativeReduce { job, task, node });
        }
    }
}

pub(crate) fn next_unclaimed_local(
    job: &JobState,
    node: NodeId,
    claims: &ClaimLedger,
) -> Option<TaskId> {
    job.pending_local_maps(node)
        .find(|&t| !claims.map_claimed(job.id, t))
}

/// First pending map task with a replica in `rack` not yet claimed this
/// heartbeat (the rack-local pick; empty under the flat topology).
pub(crate) fn next_unclaimed_rack(
    job: &JobState,
    rack: u32,
    claims: &ClaimLedger,
) -> Option<TaskId> {
    job.pending_rack_maps(rack)
        .find(|&t| !claims.map_claimed(job.id, t))
}

pub(crate) fn next_unclaimed_any(job: &JobState, claims: &ClaimLedger) -> Option<TaskId> {
    job.pending_maps_iter()
        .find(|&t| !claims.map_claimed(job.id, t))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_names_roundtrip() {
        for k in SchedulerKind::ALL {
            assert_eq!(SchedulerKind::from_name(k.name()), Some(k));
        }
        assert_eq!(
            SchedulerKind::from_name("proposed"),
            Some(SchedulerKind::DeadlineVc)
        );
        assert_eq!(SchedulerKind::from_name("bogus"), None);
    }

    #[test]
    fn parse_list_accepts_commas_and_rejects_typos() {
        assert_eq!(
            SchedulerKind::parse_list("fair, deadline_vc"),
            Some(vec![SchedulerKind::Fair, SchedulerKind::DeadlineVc])
        );
        assert_eq!(
            SchedulerKind::parse_list("edf"),
            Some(vec![SchedulerKind::Edf])
        );
        assert_eq!(SchedulerKind::parse_list("fair,bogus"), None);
    }

    #[test]
    fn build_constructs_every_kind() {
        let cfg = SimConfig::small();
        for k in SchedulerKind::ALL {
            let s = k.build(&cfg);
            assert_eq!(s.kind(), k);
            assert_eq!(s.name(), k.name());
        }
    }

    #[test]
    fn blacklist_trips_at_k_crashes_and_ages_out() {
        let mut cfg = SimConfig::small();
        cfg.failures.blacklist = true;
        let mut b = BlacklistPolicy::new(&cfg);
        let pm = PmId(3);
        let t = SimTime::from_secs_f64;
        b.on_pm_failure(pm, t(100.0));
        assert!(!b.blocks_pm(pm, t(100.0)), "one crash is not a pattern");
        b.on_pm_failure(pm, t(500.0));
        assert!(b.blocks_pm(pm, t(500.0)), "K=2 crashes in window trip it");
        // Only the crashed PM is blocked.
        assert!(!b.blocks_pm(PmId(0), t(500.0)));
        // The first crash ages out of the 3600s window; one in-window
        // crash remains, so the PM has proven itself back in.
        assert!(b.blocks_pm(pm, t(3700.0)));
        assert!(!b.blocks_pm(pm, t(3701.0)));
    }

    #[test]
    fn blacklist_disabled_is_inert_and_state_roundtrips() {
        let cfg = SimConfig::small();
        assert!(!cfg.failures.blacklist);
        let mut off = BlacklistPolicy::new(&cfg);
        let t = SimTime::from_secs_f64;
        off.on_pm_failure(PmId(1), t(10.0));
        off.on_pm_failure(PmId(1), t(20.0));
        assert!(!off.blocks_pm(PmId(1), t(20.0)), "disabled never blocks");

        let mut cfg_on = cfg.clone();
        cfg_on.failures.blacklist = true;
        let mut on = BlacklistPolicy::new(&cfg_on);
        on.on_pm_failure(PmId(2), t(10.0));
        on.on_pm_failure(PmId(2), t(20.0));
        let mut e = Enc::new();
        on.encode(&mut e);
        let bytes = e.into_bytes();
        let mut back = BlacklistPolicy::default();
        back.decode(&mut Dec::new(&bytes)).unwrap();
        assert!(back.blocks_pm(PmId(2), t(20.0)), "codec carries the ledger");
        assert!(!back.blocks_pm(PmId(0), t(20.0)));
        on.reset();
        assert!(!on.blocks_pm(PmId(2), t(20.0)), "reset drops crash history");
    }
}
