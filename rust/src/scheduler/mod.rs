//! Pluggable job schedulers.
//!
//! The coordinator drives a Hadoop-0.20-style protocol: every TaskTracker
//! (VM) heartbeats every `heartbeat_s`; the scheduler inspects an immutable
//! [`SchedView`] of the world and returns [`Action`]s, which the
//! coordinator validates and applies. Schedulers never mutate world state
//! directly — this keeps every policy replayable and lets the property
//! tests check the same invariants across all of them.

mod deadline_vc;
mod delay;
mod edf;
mod fair;
mod fifo;
#[cfg(test)]
pub(crate) mod testutil;

pub use deadline_vc::{DeadlineVcScheduler, DvcTuning};
pub use delay::DelayScheduler;
pub use edf::EdfScheduler;
pub use fair::FairScheduler;
pub use fifo::FifoScheduler;

use crate::cluster::{Cluster, LocalityTier, NodeId};
use crate::config::SimConfig;
use crate::mapreduce::{JobId, JobState, TaskId};
use crate::predictor::Predictor;
use crate::reconfig::ConfigManager;
use crate::sim::SimTime;

/// Which scheduler to run (CLI/bench selector).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulerKind {
    Fifo,
    Fair,
    Delay,
    Edf,
    /// The paper's proposed scheduler (Alg. 1 + Alg. 2).
    DeadlineVc,
}

impl SchedulerKind {
    pub fn name(self) -> &'static str {
        match self {
            SchedulerKind::Fifo => "fifo",
            SchedulerKind::Fair => "fair",
            SchedulerKind::Delay => "delay",
            SchedulerKind::Edf => "edf",
            SchedulerKind::DeadlineVc => "deadline_vc",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        Some(match s {
            "fifo" => SchedulerKind::Fifo,
            "fair" => SchedulerKind::Fair,
            "delay" => SchedulerKind::Delay,
            "edf" => SchedulerKind::Edf,
            "deadline_vc" | "proposed" => SchedulerKind::DeadlineVc,
            _ => return None,
        })
    }

    /// Parse a comma-separated scheduler list (`"fair,deadline_vc"`) —
    /// the `vcsched sweep --sched` axis override. `None` if any name is
    /// unknown; duplicates are preserved (the grid would double-count,
    /// which the caller surfaces as a user error in row counts).
    pub fn parse_list(s: &str) -> Option<Vec<SchedulerKind>> {
        s.split(',')
            .map(|part| SchedulerKind::from_name(part.trim()))
            .collect()
    }

    pub fn build(self, cfg: &SimConfig) -> Box<dyn Scheduler> {
        match self {
            SchedulerKind::Fifo => Box::new(FifoScheduler::new()),
            SchedulerKind::Fair => Box::new(FairScheduler::new()),
            SchedulerKind::Delay => Box::new(DelayScheduler::new(cfg.delay_heartbeats)),
            SchedulerKind::Edf => Box::new(EdfScheduler::new()),
            SchedulerKind::DeadlineVc => Box::new(DeadlineVcScheduler::new(cfg)),
        }
    }

    pub const ALL: [SchedulerKind; 5] = [
        SchedulerKind::Fifo,
        SchedulerKind::Fair,
        SchedulerKind::Delay,
        SchedulerKind::Edf,
        SchedulerKind::DeadlineVc,
    ];
}

/// Immutable world snapshot handed to schedulers.
pub struct SchedView<'a> {
    pub cfg: &'a SimConfig,
    pub cluster: &'a Cluster,
    pub jobs: &'a [JobState],
    pub cm: &'a ConfigManager,
    pub now: SimTime,
}

impl SchedView<'_> {
    /// Indices of jobs that still have work (not Done).
    pub fn active_jobs(&self) -> impl Iterator<Item = &JobState> {
        self.jobs.iter().filter(|j| !j.is_done())
    }
}

/// A scheduling decision. The coordinator validates slot/queue capacity
/// before applying; an invalid action is a scheduler bug and panics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Action {
    /// Launch map task `task` of `job` on `node` (slot must be free).
    LaunchMap {
        job: JobId,
        task: TaskId,
        node: NodeId,
    },
    /// Launch reduce task (reduce slot must be free; job map phase done).
    LaunchReduce {
        job: JobId,
        task: TaskId,
        node: NodeId,
    },
    /// Alg. 1 lines 11-13: queue `task` for a delayed *local* launch on
    /// `target` (AQ entry on target's PM) and register the free core of
    /// `release_from` (RQ entry on its PM).
    AwaitReconfig {
        job: JobId,
        task: TaskId,
        target: NodeId,
        release_from: NodeId,
    },
    /// Register a free core without a paired assign (Alg. 1 line 12 when
    /// the heartbeating node simply has nothing local to run).
    RegisterRelease { node: NodeId },
    /// Give up on a delayed local launch (reconfiguration starved); the
    /// task returns to Pending and its AQ entry is cancelled.
    CancelAwait { job: JobId, task: TaskId },
    /// Update a job's slot allocation from the predictor (Alg. 2 line 2 /
    /// 19). Recorded by the coordinator into `JobState::alloc_*`.
    SetAlloc {
        job: JobId,
        map_slots: u32,
        reduce_slots: u32,
    },
}

/// The scheduler interface (see module docs for the protocol).
pub trait Scheduler {
    fn kind(&self) -> SchedulerKind;

    fn name(&self) -> &'static str {
        self.kind().name()
    }

    /// A new job appeared (Alg. 2 line 1-2).
    fn on_job_added(&mut self, _view: &SchedView, _job: JobId, _predictor: &mut dyn Predictor) -> Vec<Action> {
        Vec::new()
    }

    /// Heartbeat from `node`; return assignments for its free slots.
    fn on_heartbeat(
        &mut self,
        view: &SchedView,
        node: NodeId,
        predictor: &mut dyn Predictor,
    ) -> Vec<Action>;

    /// A task of `job` finished (Alg. 2 lines 17-20).
    fn on_task_finished(
        &mut self,
        _view: &SchedView,
        _job: JobId,
        _predictor: &mut dyn Predictor,
    ) -> Vec<Action> {
        Vec::new()
    }
}

/// Shared helper: launch as many tasks as `node` has free slots, scanning
/// `job_order` (indices into `view.jobs`). Used by the FIFO/Fair/Delay/EDF
/// baselines — pick the best-tier pending map the job's cap admits
/// (node-local > rack-local > off-rack; `max_tier_for` returns the worst
/// tier the job may accept on this heartbeat); reduces fill reduce slots
/// once the map phase is done. Under the flat topology the rack stage is
/// inert (no rack index exists), so `max_tier_for == Remote` reproduces
/// the seed's local-else-any behaviour exactly.
pub(crate) fn greedy_fill(
    view: &SchedView,
    node: NodeId,
    job_order: &[usize],
    max_tier_for: impl Fn(&JobState) -> LocalityTier,
) -> Vec<Action> {
    let mut actions = Vec::new();
    let vm = view.cluster.vm(node);
    let rack = view.cluster.rack_of(node);
    let racked = view.cluster.topology().is_racked();
    let mut free_map = vm.free_map_slots();
    let mut free_reduce = vm.free_reduce_slots();
    // Track launches within this heartbeat so one task isn't picked twice.
    let mut claimed_maps = ClaimSet::new();
    let mut claimed_reduces: Vec<(JobId, u32)> = Vec::new();

    for &ji in job_order {
        let job = &view.jobs[ji];
        if job.is_done() {
            continue;
        }
        // Map work.
        while free_map > 0 {
            let cap = max_tier_for(job);
            let pick = next_unclaimed_local(job, node, &claimed_maps)
                .or_else(|| {
                    if racked && cap >= LocalityTier::RackLocal {
                        next_unclaimed_rack(job, rack, &claimed_maps)
                    } else {
                        None
                    }
                })
                .or_else(|| {
                    if cap >= LocalityTier::Remote {
                        next_unclaimed_any(job, &claimed_maps)
                    } else {
                        None
                    }
                });
            let Some(task) = pick else { break };
            claimed_maps.insert((job.id, task));
            actions.push(Action::LaunchMap {
                job: job.id,
                task,
                node,
            });
            free_map -= 1;
        }
        // Reduce work (only after the map phase: Hadoop 0.20 semantics in
        // this engine — see mapreduce module docs).
        while free_reduce > 0 && job.map_finished() {
            let already: u32 = claimed_reduces
                .iter()
                .filter(|(j, _)| *j == job.id)
                .count() as u32;
            let Some(task) = nth_pending_reduce(job, already) else { break };
            claimed_reduces.push((job.id, task.0));
            actions.push(Action::LaunchReduce {
                job: job.id,
                task,
                node,
            });
            free_reduce -= 1;
        }
    }
    actions
}

/// Set of (job, task) pairs claimed within one heartbeat (launch actions
/// are applied only after the scheduler returns, so claimed tasks still
/// look Pending in the view).
pub(crate) type ClaimSet = std::collections::HashSet<(JobId, TaskId)>;

pub(crate) fn next_unclaimed_local(
    job: &JobState,
    node: NodeId,
    claimed: &ClaimSet,
) -> Option<TaskId> {
    job.pending_local_maps(node)
        .find(|&t| !claimed.contains(&(job.id, t)))
}

/// First pending map task with a replica in `rack` not yet claimed this
/// heartbeat (the rack-local pick; empty under the flat topology).
pub(crate) fn next_unclaimed_rack(
    job: &JobState,
    rack: u32,
    claimed: &ClaimSet,
) -> Option<TaskId> {
    job.pending_rack_maps(rack)
        .find(|&t| !claimed.contains(&(job.id, t)))
}

pub(crate) fn next_unclaimed_any(job: &JobState, claimed: &ClaimSet) -> Option<TaskId> {
    job.pending_maps_iter()
        .find(|&t| !claimed.contains(&(job.id, t)))
}

fn nth_pending_reduce(job: &JobState, skip: u32) -> Option<TaskId> {
    job.pending_reduces_iter().nth(skip as usize)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_names_roundtrip() {
        for k in SchedulerKind::ALL {
            assert_eq!(SchedulerKind::from_name(k.name()), Some(k));
        }
        assert_eq!(
            SchedulerKind::from_name("proposed"),
            Some(SchedulerKind::DeadlineVc)
        );
        assert_eq!(SchedulerKind::from_name("bogus"), None);
    }

    #[test]
    fn parse_list_accepts_commas_and_rejects_typos() {
        assert_eq!(
            SchedulerKind::parse_list("fair, deadline_vc"),
            Some(vec![SchedulerKind::Fair, SchedulerKind::DeadlineVc])
        );
        assert_eq!(
            SchedulerKind::parse_list("edf"),
            Some(vec![SchedulerKind::Edf])
        );
        assert_eq!(SchedulerKind::parse_list("fair,bogus"), None);
    }

    #[test]
    fn build_constructs_every_kind() {
        let cfg = SimConfig::small();
        for k in SchedulerKind::ALL {
            let s = k.build(&cfg);
            assert_eq!(s.kind(), k);
            assert_eq!(s.name(), k.name());
        }
    }
}
