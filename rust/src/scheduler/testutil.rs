//! Shared fixtures for scheduler unit tests: a small pre-arrived world
//! with manual control over job/cluster state.

use crate::cluster::NodeId;
use crate::config::SimConfig;
use crate::coordinator::World;
use crate::mapreduce::JobId;
use crate::predictor::{NativePredictor, TaskSample};
use crate::sim::SimTime;
use crate::workloads::trace::JobTrace;
use crate::workloads::{JobSpec, JobType};

use super::{Action, SchedView, Scheduler};

pub struct TestWorld {
    world: World,
}

impl TestWorld {
    fn build(cfg: SimConfig, specs: Vec<JobSpec>) -> Self {
        let world = World::new(cfg, JobTrace::new(specs));
        let mut tw = Self { world };
        tw.arrive_all();
        tw
    }

    /// Pump arrival events (submit_s == 0) without running heartbeats: we
    /// drain the queue until every job is registered, using a scheduler
    /// that does nothing.
    fn arrive_all(&mut self) {
        struct Null;
        impl Scheduler for Null {
            fn kind(&self) -> super::SchedulerKind {
                super::SchedulerKind::Fifo
            }
            fn on_heartbeat(
                &mut self,
                _: &SchedView,
                _: NodeId,
                _: &mut dyn crate::predictor::Predictor,
                _: &mut Vec<Action>,
            ) {
            }
        }
        // Arrivals are scheduled at t=0 before any heartbeat offsets > 0;
        // node 0's heartbeat is also at t=0 but harmless with Null.
        let mut p = NativePredictor::new();
        let mut null = Null;
        while self.world.jobs.len() < self.expected_jobs() {
            let stepped = self.world.step_one(&mut null, &mut p);
            assert!(stepped, "queue drained before all jobs arrived");
        }
    }

    fn expected_jobs(&self) -> usize {
        self.world.trace_len()
    }

    // ---- constructors ----

    pub fn two_jobs() -> Self {
        Self::build(
            SimConfig::small(),
            vec![
                JobSpec::new(JobType::WordCount, 192.0),
                JobSpec::new(JobType::Grep, 192.0),
            ],
        )
    }

    pub fn two_jobs_with_deadlines(d0: f64, d1: f64) -> Self {
        Self::build(
            SimConfig::small(),
            vec![
                JobSpec::new(JobType::WordCount, 192.0).with_deadline(d0),
                JobSpec::new(JobType::Grep, 192.0).with_deadline(d1),
            ],
        )
    }

    pub fn deadline_and_best_effort() -> Self {
        Self::build(
            SimConfig::small(),
            vec![
                JobSpec::new(JobType::WordCount, 192.0),
                JobSpec::new(JobType::Grep, 192.0).with_deadline(400.0),
            ],
        )
    }

    /// One job none of whose blocks are replicated on `node` (found by
    /// seed search — placement is random but deterministic per seed).
    pub fn one_job_no_local_on(node: NodeId) -> Self {
        for seed in 0..200u64 {
            let cfg = SimConfig {
                seed,
                ..SimConfig::small()
            };
            let tw = Self::build(
                cfg,
                vec![JobSpec::new(JobType::WordCount, 128.0).with_deadline(600.0)],
            );
            let job = &tw.world.jobs[0];
            if job.pending_local_maps(node).next().is_none() {
                return tw;
            }
        }
        panic!("no seed found with zero blocks on {node:?}");
    }

    // ---- accessors ----

    pub fn cfg(&self) -> SimConfig {
        self.world.cfg.clone()
    }

    pub fn view(&self) -> SchedView<'_> {
        self.world.view()
    }

    pub fn view_jobs(&self) -> &[crate::mapreduce::JobState] {
        &self.world.jobs
    }

    /// A node that has a pending local map for job `ji`.
    pub fn node_with_local_for(&self, ji: usize) -> NodeId {
        let job = &self.world.jobs[ji];
        for n in 0..self.world.cluster.num_nodes() {
            let node = NodeId(n as u32);
            if job.pending_local_maps(node).next().is_some() {
                return node;
            }
        }
        panic!("no node with local work for job {ji}");
    }

    // ---- mutations ----

    /// Record a fake completed map so `cold()` turns false.
    pub fn warm_up_job(&mut self, ji: usize) {
        self.world.jobs[ji]
            .stats
            .record_map(TaskSample { duration_s: 15.0 });
    }

    pub fn set_alloc(&mut self, ji: usize, maps: u32, reduces: u32) {
        self.world.jobs[ji].alloc_map_slots = maps;
        self.world.jobs[ji].alloc_reduce_slots = reduces;
    }

    /// Launch `n` real map tasks of job `ji` (consumes slots, sets state).
    pub fn force_running_maps(&mut self, ji: usize, n: u32) {
        for _ in 0..n {
            let job = &self.world.jobs[ji];
            let t = job
                .pending_maps_iter()
                .next()
                .expect("pending map to force-run");
            let id = JobId(ji as u32);
            // find any node with a free map slot
            let node = (0..self.world.cluster.num_nodes())
                .map(|i| NodeId(i as u32))
                .find(|&nd| self.world.cluster.vm(nd).free_map_slots() > 0)
                .expect("free slot");
            let tier = self.world.jobs[ji].map_tier(t, node, &self.world.cluster);
            self.world.launch_map(id, t, node, tier);
        }
    }

    /// Mark every node except `keep` fully busy on map slots.
    pub fn fill_node_maps_except(&mut self, keep: NodeId) {
        for n in 0..self.world.cluster.num_nodes() {
            let node = NodeId(n as u32);
            if node == keep {
                continue;
            }
            let vm = self.world.cluster.vm_mut(node);
            vm.busy_map = vm.vcpus;
        }
    }

    pub fn push_release(&mut self, node: NodeId) {
        let pm = self.world.cluster.pm_of(node);
        self.world.cm.enqueue_release(pm, node);
    }

    /// Register one release entry per PM (first VM of each).
    pub fn push_releases_everywhere(&mut self) {
        for p in 0..self.world.cluster.num_pms() {
            let pm = crate::cluster::PmId(p as u32);
            let vm = self.world.cluster.pm(pm).vms[0];
            self.world.cm.enqueue_release(pm, vm);
        }
    }

    pub fn advance(&mut self, dt: SimTime) {
        self.world.advance(dt);
    }

    // ---- scheduler drivers ----

    /// Deliver an `on_job_updated` for every job, standing in for the
    /// coordinator's dirty-flush: TestWorld mutates job state directly
    /// (`force_running_maps`, `set_alloc`, …), so persistent scheduler
    /// indexes must be re-synced before each driven heartbeat.
    /// Over-notification is part of the callback's contract.
    fn notify_all(&self, s: &mut dyn Scheduler) {
        let view = self.world.view();
        for job in view.jobs {
            s.on_job_updated(&view, job.id);
        }
    }

    /// Fire one heartbeat; return actions WITHOUT applying them.
    pub fn heartbeat_with(&mut self, s: &mut dyn Scheduler, node: NodeId) -> Vec<Action> {
        self.notify_all(s);
        let mut p = NativePredictor::new();
        let mut out = Vec::new();
        s.on_heartbeat(&self.world.view(), node, &mut p, &mut out);
        out
    }

    /// Fire one heartbeat and apply the actions (plus queue matching).
    pub fn heartbeat_and_apply(&mut self, s: &mut dyn Scheduler, node: NodeId) -> Vec<Action> {
        self.notify_all(s);
        let mut p = NativePredictor::new();
        let mut out = Vec::new();
        s.on_heartbeat(&self.world.view(), node, &mut p, &mut out);
        self.world.apply_actions(&out);
        self.world.match_reconfigs();
        out
    }
}
