//! Execution-trace capture and rendering: a per-task event log that can
//! be exported as JSON (for external plotting) or rendered as an ASCII
//! Gantt chart (for quick terminal inspection of scheduler behaviour).

use crate::cluster::{LocalityTier, NodeId};
use crate::mapreduce::{JobId, TaskKind};
use crate::sim::SimTime;
use crate::util::json::Json;

/// One completed task span.
#[derive(Clone, Debug)]
pub struct TaskSpan {
    pub job: JobId,
    pub kind: TaskKind,
    pub task: u32,
    pub node: NodeId,
    pub start: SimTime,
    pub end: SimTime,
    /// Map only: input-fetch locality tier (reduces record `Remote`; see
    /// `mapreduce::TaskState`). Keeps the trace able to explain the
    /// per-tier locality split the run metrics report.
    pub tier: LocalityTier,
}

impl TaskSpan {
    /// Was this map's input node-local? (The seed trace schema's binary
    /// `local` flag, kept for the Gantt markers and locality cross-check.)
    pub fn is_local(&self) -> bool {
        self.tier == LocalityTier::NodeLocal
    }
}

/// One vCPU hot-plug marker.
#[derive(Clone, Debug)]
pub struct HotplugMark {
    pub at: SimTime,
    pub from: NodeId,
    pub to: NodeId,
}

/// Trace collector (opt-in: attach to a `World` via `enable_trace`).
#[derive(Clone, Debug, Default)]
pub struct TraceLog {
    pub spans: Vec<TaskSpan>,
    pub hotplugs: Vec<HotplugMark>,
}

impl TraceLog {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_span(&mut self, span: TaskSpan) {
        self.spans.push(span);
    }

    pub fn record_hotplug(&mut self, mark: HotplugMark) {
        self.hotplugs.push(mark);
    }

    pub fn is_empty(&self) -> bool {
        self.spans.is_empty() && self.hotplugs.is_empty()
    }

    /// Export as a JSON document (one object per span, Chrome-trace-like).
    pub fn to_json(&self) -> Json {
        let mut spans = Json::arr();
        for s in &self.spans {
            spans = spans.push(
                Json::obj()
                    .set("job", s.job.0 as u64)
                    .set(
                        "kind",
                        match s.kind {
                            TaskKind::Map => "map",
                            TaskKind::Reduce => "reduce",
                        },
                    )
                    .set("task", s.task as u64)
                    .set("node", s.node.0 as u64)
                    .set("start_s", s.start.as_secs_f64())
                    .set("end_s", s.end.as_secs_f64())
                    .set("local", s.is_local())
                    .set("tier", s.tier.name()),
            );
        }
        let mut hp = Json::arr();
        for h in &self.hotplugs {
            hp = hp.push(
                Json::obj()
                    .set("at_s", h.at.as_secs_f64())
                    .set("from", h.from.0 as u64)
                    .set("to", h.to.0 as u64),
            );
        }
        Json::obj().set("spans", spans).set("hotplugs", hp)
    }

    /// Render an ASCII Gantt chart: one row per node, time bucketed into
    /// `width` columns. Map tasks print the job id digit (`+` for
    /// rack-local, `*` for off-rack), reduce tasks print `r`.
    pub fn render_gantt(&self, num_nodes: usize, width: usize) -> String {
        let end = self
            .spans
            .iter()
            .map(|s| s.end)
            .max()
            .unwrap_or(SimTime::ZERO);
        if end == SimTime::ZERO || width == 0 {
            return String::from("(empty trace)\n");
        }
        let total = end.as_secs_f64();
        let mut rows = vec![vec![' '; width]; num_nodes];
        for s in &self.spans {
            let n = s.node.idx();
            if n >= num_nodes {
                continue;
            }
            let c0 = ((s.start.as_secs_f64() / total) * width as f64) as usize;
            let c1 = (((s.end.as_secs_f64() / total) * width as f64) as usize).max(c0 + 1);
            let ch = match (s.kind, s.tier) {
                (TaskKind::Reduce, _) => 'r',
                (TaskKind::Map, LocalityTier::NodeLocal) => {
                    char::from_digit(s.job.0 % 10, 10).unwrap_or('m')
                }
                (TaskKind::Map, LocalityTier::RackLocal) => '+',
                (TaskKind::Map, LocalityTier::Remote) => '*',
            };
            for c in c0..c1.min(width) {
                rows[n][c] = ch;
            }
        }
        let mut out = String::new();
        out.push_str(&format!(
            "Gantt ({total:.0}s across {width} cols; digits = node-local map of \
             job N, '+' = rack-local map, '*' = off-rack map, 'r' = reduce)\n"
        ));
        for (n, row) in rows.iter().enumerate() {
            out.push_str(&format!("node {n:>3} |"));
            out.extend(row.iter());
            out.push_str("|\n");
        }
        out
    }

    /// Locality ratio recomputed from spans (cross-check against metrics).
    pub fn span_locality_pct(&self) -> f64 {
        let maps: Vec<&TaskSpan> = self
            .spans
            .iter()
            .filter(|s| s.kind == TaskKind::Map)
            .collect();
        if maps.is_empty() {
            return 0.0;
        }
        100.0 * maps.iter().filter(|s| s.is_local()).count() as f64 / maps.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(job: u32, node: u32, s: f64, e: f64, tier: LocalityTier, kind: TaskKind) -> TaskSpan {
        TaskSpan {
            job: JobId(job),
            kind,
            task: 0,
            node: NodeId(node),
            start: SimTime::from_secs_f64(s),
            end: SimTime::from_secs_f64(e),
            tier,
        }
    }

    #[test]
    fn json_export_shape() {
        let mut t = TraceLog::new();
        t.record_span(span(1, 0, 0.0, 5.0, LocalityTier::NodeLocal, TaskKind::Map));
        t.record_hotplug(HotplugMark {
            at: SimTime::from_secs_f64(2.0),
            from: NodeId(0),
            to: NodeId(1),
        });
        let s = t.to_json().render();
        assert!(s.contains("\"kind\":\"map\""));
        assert!(s.contains("\"local\":true"));
        assert!(s.contains("\"tier\":\"node\""));
        assert!(s.contains("\"hotplugs\":[{\"at_s\":2"));
    }

    #[test]
    fn gantt_renders_rows_and_markers() {
        let mut t = TraceLog::new();
        t.record_span(span(3, 0, 0.0, 50.0, LocalityTier::NodeLocal, TaskKind::Map));
        t.record_span(span(4, 1, 50.0, 100.0, LocalityTier::Remote, TaskKind::Map));
        t.record_span(span(5, 1, 30.0, 50.0, LocalityTier::RackLocal, TaskKind::Map));
        t.record_span(span(4, 1, 0.0, 30.0, LocalityTier::Remote, TaskKind::Reduce));
        let g = t.render_gantt(2, 40);
        assert!(g.contains("node   0"));
        assert!(g.contains('3'), "{g}");
        assert!(g.contains('*'), "{g}");
        assert!(g.contains('+'), "{g}");
        assert!(g.contains('r'), "{g}");
    }

    #[test]
    fn empty_trace_renders() {
        let t = TraceLog::new();
        assert!(t.render_gantt(4, 10).contains("empty"));
    }

    #[test]
    fn span_locality_matches() {
        let mut t = TraceLog::new();
        t.record_span(span(0, 0, 0.0, 1.0, LocalityTier::NodeLocal, TaskKind::Map));
        t.record_span(span(0, 0, 0.0, 1.0, LocalityTier::RackLocal, TaskKind::Map));
        t.record_span(span(0, 0, 0.0, 1.0, LocalityTier::Remote, TaskKind::Reduce));
        assert_eq!(t.span_locality_pct(), 50.0);
    }
}
