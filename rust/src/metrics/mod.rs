//! Experiment metrics: per-job records and cluster-level aggregates.

pub mod trace_log;

pub use trace_log::{HotplugMark, TaskSpan, TraceLog};

use crate::mapreduce::JobId;
use crate::sim::SimTime;
use crate::util::json::Json;
use crate::util::stats::{QuantileSketch, Summary};
use crate::workloads::JobType;

/// Final record for one completed job.
#[derive(Clone, Debug)]
pub struct JobRecord {
    pub id: JobId,
    pub job_type: JobType,
    pub input_mb: f64,
    pub submitted: SimTime,
    pub finished: SimTime,
    pub completion_s: f64,
    pub map_phase_s: f64,
    pub deadline_s: Option<f64>,
    pub met_deadline: Option<bool>,
    /// Tiered map-locality split: node-local, rack-local, off-rack.
    /// `rack_maps` is always 0 under the flat topology, collapsing the
    /// split to the seed's binary local/remote accounting.
    pub local_maps: u32,
    pub rack_maps: u32,
    pub remote_maps: u32,
    pub maps: u32,
    pub reduces: u32,
}

impl JobRecord {
    /// Maps that were not node-local (rack-local + off-rack) — the seed
    /// metrics' "nonlocal" bucket.
    pub fn nonlocal_maps(&self) -> u32 {
        self.rack_maps + self.remote_maps
    }

    pub fn locality_pct(&self) -> f64 {
        let total = self.local_maps + self.nonlocal_maps();
        if total == 0 {
            0.0
        } else {
            100.0 * self.local_maps as f64 / total as f64
        }
    }
}

/// Failure-injection and speculation counters for one run. All zero with
/// the failure model off; the report emits the original seven regardless
/// so the JSON/CSV schema is identical across configurations (the
/// reduce-speculation trio is emitted only when nonzero — see
/// [`FailureStats::any_reduce_spec`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FailureStats {
    /// Fail-stop PM crashes delivered from the failure trace.
    pub pm_crashes: u64,
    /// Speculative (backup) map copies launched.
    pub speculative_launches: u64,
    /// Races the backup copy won (primary killed at spec completion).
    pub speculative_wins: u64,
    /// Attempts killed by speculation resolution or crashes of the backup
    /// — `speculative_launches - speculative_wins`-ish is pure waste.
    pub speculative_kills: u64,
    /// Map/reduce launches that re-ran work a crash destroyed (killed
    /// running attempts and lost un-shuffled map outputs).
    pub reexecuted_tasks: u64,
    /// HDFS replicas re-replicated off dead nodes.
    pub blocks_relocated: u64,
    /// Blocks that lost their last replica (restored from source).
    pub blocks_lost: u64,
    /// Speculative (backup) reduce copies launched.
    pub speculative_reduce_launches: u64,
    /// Reduce races the backup copy won.
    pub speculative_reduce_wins: u64,
    /// Reduce attempts killed by speculation resolution or crashes of the
    /// backup.
    pub speculative_reduce_kills: u64,
}

impl FailureStats {
    /// Any reduce-side speculation activity? The JSON report only emits
    /// the `speculative_reduce_*` keys when this is true, keeping the
    /// schema (and the golden byte pins) of non-speculating runs stable.
    pub fn any_reduce_spec(&self) -> bool {
        self.speculative_reduce_launches != 0
            || self.speculative_reduce_wins != 0
            || self.speculative_reduce_kills != 0
    }
}

/// Constant-memory aggregate over completed jobs: the streaming-mode
/// replacement for storing one [`JobRecord`] per job. Every derived
/// metric [`RunMetrics`] reports comes from these accumulators — Welford
/// mean/std, a mergeable quantile sketch for p50/p99, and integer tier/
/// deadline counters — folded in job-completion order, so on the same
/// run the scalar aggregates are bit-identical to the exact per-record
/// path (pinned by the streaming differential test).
#[derive(Clone, Debug)]
pub struct StreamAgg {
    pub completed: u64,
    /// Completion-time accumulator (mean/std/min/max, Welford).
    pub completion: Summary,
    /// Completion-time quantile sketch (p50/p99 at ~0.5% relative error).
    pub sketch: QuantileSketch,
    pub local_maps: u64,
    pub rack_maps: u64,
    pub remote_maps: u64,
    /// Jobs that carried a deadline.
    pub deadlined: u64,
    /// Deadlined jobs that missed.
    pub missed: u64,
    /// Latest job finish time (the makespan fold).
    pub max_finished_s: f64,
}

impl StreamAgg {
    pub fn new() -> Self {
        Self {
            completed: 0,
            completion: Summary::new(),
            sketch: QuantileSketch::new(),
            local_maps: 0,
            rack_maps: 0,
            remote_maps: 0,
            deadlined: 0,
            missed: 0,
            max_finished_s: 0.0,
        }
    }

    /// Fold one completed job in (the streaming `record_job` path).
    pub fn observe(&mut self, r: &JobRecord) {
        self.completed += 1;
        self.completion.add(r.completion_s);
        self.sketch.add(r.completion_s);
        self.local_maps += r.local_maps as u64;
        self.rack_maps += r.rack_maps as u64;
        self.remote_maps += r.remote_maps as u64;
        if let Some(met) = r.met_deadline {
            self.deadlined += 1;
            if !met {
                self.missed += 1;
            }
        }
        self.max_finished_s = self.max_finished_s.max(r.finished.as_secs_f64());
    }

    /// Aggregate an exact record set (the small-scale differential
    /// oracle: same fold, same order, same accumulators).
    pub fn from_records(records: &[JobRecord]) -> Self {
        let mut agg = Self::new();
        for r in records {
            agg.observe(r);
        }
        agg
    }

    /// Merge another run's aggregate in (cross-scenario pooling).
    pub fn merge(&mut self, other: &StreamAgg) {
        self.completed += other.completed;
        self.completion.merge(&other.completion);
        self.sketch.merge(&other.sketch);
        self.local_maps += other.local_maps;
        self.rack_maps += other.rack_maps;
        self.remote_maps += other.remote_maps;
        self.deadlined += other.deadlined;
        self.missed += other.missed;
        self.max_finished_s = self.max_finished_s.max(other.max_finished_s);
    }

    fn total_maps_finished(&self) -> u64 {
        self.local_maps + self.rack_maps + self.remote_maps
    }

    fn tier_pct(&self, count: u64) -> f64 {
        let total = self.total_maps_finished();
        if total == 0 {
            0.0
        } else {
            100.0 * count as f64 / total as f64
        }
    }

    fn miss_rate(&self) -> f64 {
        if self.deadlined == 0 {
            0.0
        } else {
            self.missed as f64 / self.deadlined as f64
        }
    }
}

impl Default for StreamAgg {
    fn default() -> Self {
        Self::new()
    }
}

/// Aggregated results of one simulation run.
///
/// Two storage modes behind one API: the exact path keeps a
/// [`JobRecord`] per job (accessible via [`RunMetrics::job_records`]);
/// the streaming path (`SimConfig::stream_metrics`) keeps only a
/// [`StreamAgg`], so memory never scales with trace length. All derived
/// metrics work in both modes; per-job lookups return `None`/empty in
/// streaming mode.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub scheduler: String,
    pub(crate) jobs: Vec<JobRecord>,
    /// `Some` iff the run streamed (then `jobs` is empty).
    pub(crate) stream: Option<StreamAgg>,
    pub makespan_s: f64,
    pub hotplugs: u64,
    pub heartbeats: u64,
    pub events: u64,
    pub predictor_calls: u64,
    /// Failure-injection counters (all zero with the model off).
    pub failures: FailureStats,
    /// Wall-clock seconds the simulation took to run (host time).
    pub wall_s: f64,
}

impl RunMetrics {
    /// Exact per-job records — empty when the run streamed (check
    /// [`RunMetrics::stream_agg`]).
    pub fn job_records(&self) -> &[JobRecord] {
        &self.jobs
    }

    /// The streaming aggregate, when this run streamed.
    pub fn stream_agg(&self) -> Option<&StreamAgg> {
        self.stream.as_ref()
    }

    /// Build an exact-mode result from parts (tests and tools outside
    /// the crate; the coordinator fills the fields directly).
    pub fn from_records(scheduler: &str, jobs: Vec<JobRecord>) -> Self {
        Self {
            scheduler: scheduler.to_string(),
            jobs,
            ..Default::default()
        }
    }

    pub fn completed_jobs(&self) -> usize {
        match &self.stream {
            Some(s) => s.completed as usize,
            None => self.jobs.len(),
        }
    }

    /// Jobs per simulated hour (the paper's headline "throughput of jobs").
    pub fn throughput_jobs_per_hour(&self) -> f64 {
        if self.makespan_s <= 0.0 {
            0.0
        } else {
            self.completed_jobs() as f64 / (self.makespan_s / 3600.0)
        }
    }

    pub fn mean_completion_s(&self) -> f64 {
        if let Some(s) = &self.stream {
            return s.completion.mean();
        }
        let mut s = Summary::new();
        for j in &self.jobs {
            s.add(j.completion_s);
        }
        s.mean()
    }

    fn total_maps_finished(&self) -> u64 {
        if let Some(s) = &self.stream {
            return s.total_maps_finished();
        }
        self.jobs
            .iter()
            .map(|j| (j.local_maps + j.rack_maps + j.remote_maps) as u64)
            .sum()
    }

    fn tier_pct(
        &self,
        count: impl Fn(&JobRecord) -> u32,
        streamed: impl Fn(&StreamAgg) -> u64,
    ) -> f64 {
        if let Some(s) = &self.stream {
            return s.tier_pct(streamed(s));
        }
        let total = self.total_maps_finished();
        if total == 0 {
            0.0
        } else {
            let c: u64 = self.jobs.iter().map(|j| count(j) as u64).sum();
            100.0 * c as f64 / total as f64
        }
    }

    /// Cluster-wide *node-local* map percentage (the seed's headline
    /// locality metric; see [`RunMetrics::rack_pct`] /
    /// [`RunMetrics::remote_pct`] for the other two tiers).
    pub fn locality_pct(&self) -> f64 {
        self.tier_pct(|j| j.local_maps, |s| s.local_maps)
    }

    /// Cluster-wide *rack-local* map percentage (0 on flat topologies).
    pub fn rack_pct(&self) -> f64 {
        self.tier_pct(|j| j.rack_maps, |s| s.rack_maps)
    }

    /// Cluster-wide *off-rack* map percentage. The three tier percentages
    /// sum to 100 (when any map finished).
    pub fn remote_pct(&self) -> f64 {
        self.tier_pct(|j| j.remote_maps, |s| s.remote_maps)
    }

    /// Deadline miss rate over jobs that had deadlines.
    pub fn miss_rate(&self) -> f64 {
        if let Some(s) = &self.stream {
            return s.miss_rate();
        }
        let with_deadline: Vec<_> = self
            .jobs
            .iter()
            .filter_map(|j| j.met_deadline)
            .collect();
        if with_deadline.is_empty() {
            0.0
        } else {
            with_deadline.iter().filter(|&&met| !met).count() as f64
                / with_deadline.len() as f64
        }
    }

    /// Mean completion time for one job type (Fig. 2 / Fig. 3 series).
    pub fn mean_completion_for(&self, t: JobType) -> Option<f64> {
        let xs: Vec<f64> = self
            .jobs
            .iter()
            .filter(|j| j.job_type == t)
            .map(|j| j.completion_s)
            .collect();
        if xs.is_empty() {
            None
        } else {
            Some(xs.iter().sum::<f64>() / xs.len() as f64)
        }
    }

    /// Completion time of the (type, input-size) cell — Fig. 2 lookup.
    pub fn completion_for(&self, t: JobType, input_mb: f64) -> Option<f64> {
        self.jobs
            .iter()
            .find(|j| j.job_type == t && (j.input_mb - input_mb).abs() < 1e-6)
            .map(|j| j.completion_s)
    }

    pub fn to_json(&self) -> Json {
        let mut jobs = Json::arr();
        for j in &self.jobs {
            jobs = jobs.push(
                Json::obj()
                    .set("id", j.id.0 as u64)
                    .set("type", j.job_type.name())
                    .set("input_mb", j.input_mb)
                    .set("completion_s", j.completion_s)
                    .set("map_phase_s", j.map_phase_s)
                    .set(
                        "deadline_s",
                        j.deadline_s.map(Json::Num).unwrap_or(Json::Null),
                    )
                    .set(
                        "met_deadline",
                        j.met_deadline.map(Json::Bool).unwrap_or(Json::Null),
                    )
                    .set("local_maps", j.local_maps as u64)
                    .set("rack_maps", j.rack_maps as u64)
                    .set("remote_maps", j.remote_maps as u64),
            );
        }
        let mut out = Json::obj()
            .set("scheduler", self.scheduler.as_str())
            .set("makespan_s", self.makespan_s)
            .set("throughput_jobs_per_hour", self.throughput_jobs_per_hour())
            .set("locality_pct", self.locality_pct())
            .set("rack_pct", self.rack_pct())
            .set("remote_pct", self.remote_pct())
            .set("miss_rate", self.miss_rate())
            .set("hotplugs", self.hotplugs)
            .set("heartbeats", self.heartbeats)
            .set("events", self.events)
            .set("predictor_calls", self.predictor_calls)
            .set("pm_crashes", self.failures.pm_crashes)
            .set("speculative_launches", self.failures.speculative_launches)
            .set("speculative_wins", self.failures.speculative_wins)
            .set("speculative_kills", self.failures.speculative_kills)
            .set("reexecuted_tasks", self.failures.reexecuted_tasks)
            .set("blocks_relocated", self.failures.blocks_relocated)
            .set("blocks_lost", self.failures.blocks_lost);
        if self.failures.any_reduce_spec() {
            // Conditional: absent on runs without reduce speculation so
            // pre-existing artifacts stay byte-identical.
            out = out
                .set(
                    "speculative_reduce_launches",
                    self.failures.speculative_reduce_launches,
                )
                .set(
                    "speculative_reduce_wins",
                    self.failures.speculative_reduce_wins,
                )
                .set(
                    "speculative_reduce_kills",
                    self.failures.speculative_reduce_kills,
                );
        }
        if let Some(s) = &self.stream {
            // Streaming runs carry no per-job array; emit the aggregate
            // figures the array would otherwise let a reader derive.
            out = out
                .set("completed_jobs", s.completed)
                .set("mean_completion_s", s.completion.mean())
                .set("std_completion_s", s.completion.std())
                .set("p50_completion_s", s.sketch.pct(50.0))
                .set("p99_completion_s", s.sketch.pct(99.0))
                .set("streamed", true);
        }
        out.set("jobs", jobs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record_tiered(
        t: JobType,
        comp: f64,
        local: u32,
        rack: u32,
        remote: u32,
        met: Option<bool>,
    ) -> JobRecord {
        JobRecord {
            id: JobId(0),
            job_type: t,
            input_mb: 100.0,
            submitted: SimTime::ZERO,
            finished: SimTime::from_secs_f64(comp),
            completion_s: comp,
            map_phase_s: comp * 0.6,
            deadline_s: met.map(|_| 100.0),
            met_deadline: met,
            local_maps: local,
            rack_maps: rack,
            remote_maps: remote,
            maps: local + rack + remote,
            reduces: 4,
        }
    }

    fn record(t: JobType, comp: f64, local: u32, nonlocal: u32, met: Option<bool>) -> JobRecord {
        record_tiered(t, comp, local, 0, nonlocal, met)
    }

    #[test]
    fn throughput_math() {
        let m = RunMetrics {
            jobs: vec![
                record(JobType::Grep, 10.0, 1, 0, None),
                record(JobType::Sort, 20.0, 1, 0, None),
            ],
            makespan_s: 1800.0,
            ..Default::default()
        };
        assert!((m.throughput_jobs_per_hour() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn locality_pct() {
        let m = RunMetrics {
            jobs: vec![
                record(JobType::Grep, 10.0, 3, 1, None),
                record(JobType::Sort, 20.0, 2, 2, None),
            ],
            ..Default::default()
        };
        assert!((m.locality_pct() - 62.5).abs() < 1e-9);
        // Flat records put everything nonlocal into the remote tier.
        assert_eq!(m.rack_pct(), 0.0);
        assert!((m.remote_pct() - 37.5).abs() < 1e-9);
    }

    #[test]
    fn tier_split_sums_to_hundred() {
        let m = RunMetrics {
            jobs: vec![
                record_tiered(JobType::Grep, 10.0, 4, 3, 1, None),
                record_tiered(JobType::Sort, 20.0, 2, 4, 2, None),
            ],
            ..Default::default()
        };
        assert!((m.locality_pct() - 37.5).abs() < 1e-9);
        assert!((m.rack_pct() - 43.75).abs() < 1e-9);
        assert!((m.remote_pct() - 18.75).abs() < 1e-9);
        assert!(
            (m.locality_pct() + m.rack_pct() + m.remote_pct() - 100.0).abs() < 1e-9
        );
        // Per-record shorthand still reports the binary split.
        assert_eq!(m.jobs[0].nonlocal_maps(), 4);
    }

    #[test]
    fn miss_rate_ignores_best_effort() {
        let m = RunMetrics {
            jobs: vec![
                record(JobType::Grep, 10.0, 1, 0, Some(true)),
                record(JobType::Sort, 20.0, 1, 0, Some(false)),
                record(JobType::WordCount, 30.0, 1, 0, None),
            ],
            ..Default::default()
        };
        assert!((m.miss_rate() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn json_renders() {
        let m = RunMetrics {
            scheduler: "fair".into(),
            jobs: vec![record(JobType::Grep, 10.0, 1, 0, Some(true))],
            makespan_s: 100.0,
            ..Default::default()
        };
        let s = m.to_json().render();
        assert!(s.contains("\"scheduler\":\"fair\""));
        assert!(s.contains("\"met_deadline\":true"));
    }

    #[test]
    fn stream_agg_matches_exact() {
        let records = vec![
            record_tiered(JobType::Grep, 10.0, 4, 3, 1, Some(true)),
            record_tiered(JobType::Sort, 20.0, 2, 4, 2, Some(false)),
            record_tiered(JobType::WordCount, 30.0, 1, 0, 5, None),
        ];
        let exact = RunMetrics {
            jobs: records.clone(),
            makespan_s: 100.0,
            ..Default::default()
        };
        let streamed = RunMetrics {
            stream: Some(StreamAgg::from_records(&records)),
            makespan_s: 100.0,
            ..Default::default()
        };
        assert_eq!(exact.completed_jobs(), streamed.completed_jobs());
        let pairs = [
            (
                exact.throughput_jobs_per_hour(),
                streamed.throughput_jobs_per_hour(),
            ),
            (exact.mean_completion_s(), streamed.mean_completion_s()),
            (exact.locality_pct(), streamed.locality_pct()),
            (exact.rack_pct(), streamed.rack_pct()),
            (exact.remote_pct(), streamed.remote_pct()),
            (exact.miss_rate(), streamed.miss_rate()),
        ];
        for (a, b) in pairs {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let s = streamed.to_json().render();
        assert!(s.contains("\"streamed\":true"));
        assert!(s.contains("\"completed_jobs\":3"));
        assert!(s.contains("\"jobs\":[]"));
        // Exact mode emits no streaming keys (byte-stable schema).
        assert!(!exact.to_json().render().contains("\"streamed\""));
    }

    #[test]
    fn per_type_lookup() {
        let m = RunMetrics {
            jobs: vec![
                record(JobType::Grep, 10.0, 1, 0, None),
                record(JobType::Grep, 30.0, 1, 0, None),
            ],
            ..Default::default()
        };
        assert_eq!(m.mean_completion_for(JobType::Grep), Some(20.0));
        assert_eq!(m.mean_completion_for(JobType::Sort), None);
    }
}
