//! vcsched CLI — the leader entrypoint.
//!
//! Subcommands:
//!   simulate    run one trace under one scheduler
//!   compare     run the same trace under two schedulers, print the diff
//!   fig2        reproduce Figure 2 (a: fair, b: proposed)
//!   fig3        reproduce Figure 3 (per-type comparison, Table-2 mix)
//!   table2      reproduce Table 2 (slot allocations)
//!   throughput  reproduce the 12% throughput headline
//!
//! Common flags: --sched <fifo|fair|delay|edf|deadline_vc> --seed N
//!   --pms N --scale MB_PER_GB --jobs N --xla (use the PJRT predictor)
//!   --json (machine-readable output)

use vcsched::config::SimConfig;
use vcsched::coordinator::{self, Report};
use vcsched::predictor::{NativePredictor, Predictor};
use vcsched::runtime::XlaPredictor;
use vcsched::scheduler::SchedulerKind;
use vcsched::util::args::Args;
use vcsched::util::benchkit::Table;
use vcsched::workloads::trace::JobTrace;
use vcsched::workloads::{JobType, ALL_JOB_TYPES};

fn main() {
    vcsched::util::logger::init();
    let args = Args::parse();
    let cmd = args.positional(0).unwrap_or("help");
    match cmd {
        "simulate" => cmd_simulate(&args),
        "compare" => cmd_compare(&args),
        "fig2" => cmd_fig2(&args),
        "fig3" => cmd_fig3(&args),
        "table2" => cmd_table2(&args),
        "throughput" => cmd_throughput(&args),
        "gantt" => cmd_gantt(&args),
        "export" => cmd_export(&args),
        _ => print_help(),
    }
}

fn cfg_from(args: &Args) -> SimConfig {
    let mut cfg = SimConfig::paper();
    cfg.pms = args.get_usize("pms", cfg.pms);
    cfg.seed = args.get_u64("seed", cfg.seed);
    cfg.validate().expect("invalid config");
    cfg
}

fn predictor_from(args: &Args) -> Box<dyn Predictor> {
    if args.flag("xla") {
        Box::new(XlaPredictor::load_default().expect(
            "failed to load artifacts/ — run `make artifacts` first",
        ))
    } else {
        Box::new(NativePredictor::new())
    }
}

fn sched_from(args: &Args, default: SchedulerKind) -> SchedulerKind {
    let name = args.get_str("sched", default.name());
    SchedulerKind::from_name(name)
        .unwrap_or_else(|| panic!("unknown scheduler {name:?}"))
}

fn scale(args: &Args) -> f64 {
    // MB of simulated input per paper-GB. 100 keeps the full fig2 grid
    // fast while preserving proportions; use 1024 for full-size runs.
    args.get_f64("scale", 100.0)
}

fn report_line(r: &Report) {
    println!(
        "{:<12} jobs={:<3} makespan={:>8.1}s mean_ct={:>8.1}s thpt={:>6.2}/h \
         locality={:>5.1}% misses={:>4.1}% hotplugs={}",
        r.scheduler,
        r.completed_jobs(),
        r.makespan_s,
        r.mean_completion_s(),
        r.throughput_jobs_per_hour(),
        r.locality_pct(),
        r.miss_rate() * 100.0,
        r.hotplugs
    );
}

fn cmd_simulate(args: &Args) {
    let cfg = cfg_from(args);
    let kind = sched_from(args, SchedulerKind::DeadlineVc);
    let n = args.get_usize("jobs", 25);
    let trace = JobTrace::poisson(&cfg, n, 5.0, 1.6..3.0, cfg.seed);
    let mut p = predictor_from(args);
    let r = coordinator::run_simulation_with(&cfg, kind, &trace, p.as_mut());
    if args.flag("json") {
        println!("{}", r.to_json().render());
    } else {
        report_line(&r);
    }
}

fn cmd_compare(args: &Args) {
    let cfg = cfg_from(args);
    let a = SchedulerKind::from_name(args.get_str("a", "fair")).expect("--a");
    let b = SchedulerKind::from_name(args.get_str("b", "deadline_vc")).expect("--b");
    let n = args.get_usize("jobs", 25);
    let trace = JobTrace::poisson(&cfg, n, 5.0, 1.6..3.0, cfg.seed);
    let (ra, rb) = coordinator::compare(&cfg, a, b, &trace);
    report_line(&ra);
    report_line(&rb);
    let gain = (rb.throughput_jobs_per_hour() / ra.throughput_jobs_per_hour() - 1.0) * 100.0;
    println!("throughput gain {}: {gain:+.1}%", b.name());
}

fn cmd_fig2(args: &Args) {
    let cfg = cfg_from(args);
    let trace = JobTrace::fig2_grid(scale(args));
    for (label, kind) in [
        ("Figure 2(a) — Fair Scheduler", SchedulerKind::Fair),
        ("Figure 2(b) — Proposed Scheduler", SchedulerKind::DeadlineVc),
    ] {
        let r = coordinator::run_simulation(&cfg, kind, &trace);
        println!("\n{label}");
        let mut t = Table::new(&["job", "2GB", "4GB", "6GB", "8GB", "10GB"]);
        for jt in ALL_JOB_TYPES {
            let mut row = vec![jt.name().to_string()];
            for gb in [2.0, 4.0, 6.0, 8.0, 10.0] {
                let mb = gb * scale(args);
                let v = r
                    .completion_for(jt, mb)
                    .map(|s| format!("{s:.0}s"))
                    .unwrap_or_else(|| "-".into());
                row.push(v);
            }
            t.row(&row);
        }
        t.print();
    }
}

fn cmd_fig3(args: &Args) {
    let cfg = cfg_from(args);
    let trace = JobTrace::table2(scale(args));
    let (fair, prop) = coordinator::compare(
        &cfg,
        SchedulerKind::Fair,
        SchedulerKind::DeadlineVc,
        &trace,
    );
    println!("Figure 3 — Job completion times, Fair vs Proposed (Table-2 mix)");
    let mut t = Table::new(&["job", "fair", "proposed", "delta"]);
    for jt in ALL_JOB_TYPES {
        let f = fair.mean_completion_for(jt).unwrap_or(0.0);
        let p = prop.mean_completion_for(jt).unwrap_or(0.0);
        t.row(&[
            jt.name().to_string(),
            format!("{f:.0}s"),
            format!("{p:.0}s"),
            format!("{:+.1}%", (p / f - 1.0) * 100.0),
        ]);
    }
    t.print();
}

fn cmd_table2(args: &Args) {
    let cfg = cfg_from(args);
    let mut p = predictor_from(args);
    println!("Table 2 — minimum slots to meet completion-time goals");
    let mut t = Table::new(&["job", "deadline", "input", "map slots", "reduce slots"]);
    let rows: [(JobType, f64, f64); 5] = [
        (JobType::Grep, 650.0, 10.0),
        (JobType::WordCount, 520.0, 5.0),
        (JobType::Sort, 500.0, 10.0),
        (JobType::PermutationGenerator, 850.0, 4.0),
        (JobType::InvertedIndex, 720.0, 8.0),
    ];
    for (jt, d, gb) in rows {
        let spec = vcsched::workloads::JobSpec::new(jt, gb * scale(args)).with_deadline(d);
        let demand = vcsched::predictor::demand_from_spec(&cfg, &spec);
        let s = p.solve_slots(&[demand])[0];
        t.row(&[
            jt.name().to_string(),
            format!("{d:.0}s"),
            format!("{gb:.0}GB"),
            s.map_slots.to_string(),
            s.reduce_slots.to_string(),
        ]);
    }
    t.print();
}

fn cmd_throughput(args: &Args) {
    let cfg = cfg_from(args);
    let n = args.get_usize("jobs", 30);
    let seeds = args.get_usize("runs", 3);
    let mut gains = Vec::new();
    for s in 0..seeds as u64 {
        let trace = JobTrace::poisson(&cfg, n, 5.0, 1.6..3.0, cfg.seed + s);
        let (fair, prop) = coordinator::compare(
            &cfg,
            SchedulerKind::Fair,
            SchedulerKind::DeadlineVc,
            &trace,
        );
        let g =
            (prop.throughput_jobs_per_hour() / fair.throughput_jobs_per_hour() - 1.0) * 100.0;
        println!(
            "seed {s}: fair {:.2}/h proposed {:.2}/h gain {g:+.1}%",
            fair.throughput_jobs_per_hour(),
            prop.throughput_jobs_per_hour()
        );
        gains.push(g);
    }
    let mean = gains.iter().sum::<f64>() / gains.len() as f64;
    println!("mean throughput gain: {mean:+.1}% (paper: ~12%)");
}

fn cmd_gantt(args: &Args) {
    use vcsched::coordinator::World;
    let cfg = cfg_from(args);
    let kind = sched_from(args, SchedulerKind::DeadlineVc);
    let n = args.get_usize("jobs", 8);
    let trace = JobTrace::poisson(&cfg, n, 10.0, 1.6..3.0, cfg.seed);
    let mut sched = kind.build(&cfg);
    let mut p = predictor_from(args);
    let mut world = World::new(cfg.clone(), trace);
    world.enable_trace();
    world.run(sched.as_mut(), p.as_mut());
    let tl = world.trace_log().unwrap();
    if args.flag("json") {
        println!("{}", tl.to_json().render());
    } else {
        print!("{}", tl.render_gantt(cfg.nodes(), args.get_usize("width", 100)));
        println!("span locality: {:.1}%", tl.span_locality_pct());
    }
}

/// Write every paper artifact's data as JSON + CSV under --out (default
/// results/): fig2a.csv, fig2b.csv, fig3.csv, table2.csv, headline.json.
fn cmd_export(args: &Args) {
    use std::fmt::Write as _;
    let cfg = cfg_from(args);
    let out = std::path::PathBuf::from(args.get_str("out", "results"));
    std::fs::create_dir_all(&out).expect("mkdir results");
    let scale = args.get_f64("scale", 1024.0);

    // fig2 a/b
    let trace = JobTrace::fig2_grid_on(&cfg, scale);
    for (name, kind) in [("fig2a", SchedulerKind::Fair), ("fig2b", SchedulerKind::DeadlineVc)] {
        let r = coordinator::run_simulation(&cfg, kind, &trace);
        let mut csv = String::from("job,input_gb,completion_s\n");
        for jt in ALL_JOB_TYPES {
            for gb in [2.0, 4.0, 6.0, 8.0, 10.0] {
                if let Some(ct) = r.completion_for(jt, gb * scale) {
                    let _ = writeln!(csv, "{},{gb},{ct:.1}", jt.name());
                }
            }
        }
        std::fs::write(out.join(format!("{name}.csv")), csv).unwrap();
        std::fs::write(
            out.join(format!("{name}.json")),
            r.to_json().render(),
        )
        .unwrap();
    }

    // fig3
    let trace = JobTrace::table2(scale);
    let (fair, prop) = coordinator::compare(&cfg, SchedulerKind::Fair, SchedulerKind::DeadlineVc, &trace);
    let mut csv = String::from("job,fair_s,proposed_s\n");
    for jt in ALL_JOB_TYPES {
        let _ = writeln!(
            csv,
            "{},{:.1},{:.1}",
            jt.name(),
            fair.mean_completion_for(jt).unwrap_or(0.0),
            prop.mean_completion_for(jt).unwrap_or(0.0)
        );
    }
    std::fs::write(out.join("fig3.csv"), csv).unwrap();

    // table2
    let mut p = predictor_from(args);
    let mut csv = String::from("job,deadline_s,input_gb,map_slots,reduce_slots\n");
    for (jt, d, gb) in [
        (JobType::Grep, 650.0, 10.0),
        (JobType::WordCount, 520.0, 5.0),
        (JobType::Sort, 500.0, 10.0),
        (JobType::PermutationGenerator, 850.0, 4.0),
        (JobType::InvertedIndex, 720.0, 8.0),
    ] {
        let spec = vcsched::workloads::JobSpec::new(jt, gb * scale).with_deadline(d);
        let s = p.solve_slots(&[vcsched::predictor::demand_from_spec(&cfg, &spec)])[0];
        let _ = writeln!(csv, "{},{d},{gb},{},{}", jt.name(), s.map_slots, s.reduce_slots);
    }
    std::fs::write(out.join("table2.csv"), csv).unwrap();

    // headline
    let runs = args.get_usize("runs", 3);
    let mut arr = vcsched::util::json::Json::arr();
    for s in 0..runs as u64 {
        let trace = JobTrace::poisson(&cfg, 30, 5.0, 1.6..3.0, cfg.seed + s);
        let (f, pr) = coordinator::compare(&cfg, SchedulerKind::Fair, SchedulerKind::DeadlineVc, &trace);
        arr = arr.push(
            vcsched::util::json::Json::obj()
                .set("seed", cfg.seed + s)
                .set("fair_thpt", f.throughput_jobs_per_hour())
                .set("proposed_thpt", pr.throughput_jobs_per_hour())
                .set("fair_locality", f.locality_pct())
                .set("proposed_locality", pr.locality_pct()),
        );
    }
    std::fs::write(out.join("headline.json"), arr.render()).unwrap();
    println!("wrote fig2a/b, fig3, table2, headline under {}", out.display());
}

fn print_help() {
    println!(
        "vcsched — deadline-aware MapReduce scheduling on virtual clusters\n\
         usage: vcsched <simulate|compare|fig2|fig3|table2|throughput|gantt|export> [flags]\n\
         flags: --sched K --a K --b K --seed N --pms N --jobs N --runs N\n\
         \x20      --scale MB_PER_GB --xla --json"
    );
}
