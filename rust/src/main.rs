//! vcsched CLI — the leader entrypoint.
//!
//! Subcommands:
//!   simulate    run one trace under one scheduler
//!   compare     run the same trace under two schedulers, print the diff
//!   fig2        reproduce Figure 2 (a: fair, b: proposed)
//!   fig3        reproduce Figure 3 (per-type comparison, Table-2 mix)
//!   table2      reproduce Table 2 (slot allocations)
//!   throughput  reproduce the 12% throughput headline
//!   sweep       run a scenario grid in parallel (harness::run_sweep)
//!
//! Common flags: --sched <fifo|fair|delay|edf|deadline_vc> --seed N
//!   --pms N --scale MB_PER_GB --jobs N --xla (use the PJRT predictor)
//!   --json (machine-readable output)
//!   --workload <gen|trace:FILE> (replay a trace file, streamed — see
//!   docs/TRACE_FORMAT.md) --stream (constant-memory metrics)
//!   --trace-out FILE (write the workload as a replayable trace file)
//!   --failures <PRESET|trace:FILE> (inject PM crashes from a named model
//!   or a failure-trace file; docs/FAILURE_MODEL.md)
//!   --failure-trace-out FILE (write the run's crash/recover timeline as
//!   a replayable failure-trace file)
//! Snapshot flags (simulate; see docs/EVENT_LOG.md):
//!   --snapshot-every N --snapshot-out FILE (write a resumable snapshot
//!   every N events) --snapshot-exit (stop after the first snapshot)
//!   --resume-from FILE (continue a snapshotted run to completion)
//!   --replay-to N (time-travel: rebuild state after logged decision N)
//! Sweep flags: --grid <default|quick|stress|stress-xl|stress-1m> --preset
//!   <fig4-throughput|fig5-locality|fig6-deadline-miss|fig7-failures|
//!   stress|stress-xl|stress-1m> --threads N
//!   --seeds N --mix M --profile <uniform|split-2x|long-tail>[,..]
//!   --topology <flat|racks-N|fat-tree-N>[,..] --arrival
//!   <steady|burst[-xRATE]>[,..] --failures
//!   <off|stragglers[-spec]|crash-low[-spec]|crash-high[-spec]|
//!   rack-outage[-blacklist|-replan]|trace:FILE>[,..]
//!   --workload <gen|trace:FILE>[,..] --stream
//!   --fresh (ignore the journal)
//!   --out DIR (artifact directory, default results/)

use vcsched::config::SimConfig;
use vcsched::coordinator::{self, Report, World};
use vcsched::predictor::{NativePredictor, Predictor};
use vcsched::runtime::XlaPredictor;
use vcsched::scheduler::{Scheduler, SchedulerKind};
use vcsched::util::args::Args;
use vcsched::util::benchkit::Table;
use vcsched::workloads::trace::JobTrace;
use vcsched::workloads::{JobType, ALL_JOB_TYPES};

fn main() {
    vcsched::util::logger::init();
    let args = Args::parse();
    let cmd = args.positional(0).unwrap_or("help");
    match cmd {
        "simulate" => cmd_simulate(&args),
        "compare" => cmd_compare(&args),
        "fig2" => cmd_fig2(&args),
        "fig3" => cmd_fig3(&args),
        "table2" => cmd_table2(&args),
        "throughput" => cmd_throughput(&args),
        "sweep" => cmd_sweep(&args),
        "gantt" => cmd_gantt(&args),
        "export" => cmd_export(&args),
        _ => print_help(),
    }
}

fn cfg_from(args: &Args) -> SimConfig {
    let mut cfg = SimConfig::paper();
    cfg.pms = args.get_usize("pms", cfg.pms);
    cfg.seed = args.get_u64("seed", cfg.seed);
    cfg.validate().expect("invalid config");
    cfg
}

fn predictor_from(args: &Args) -> Box<dyn Predictor> {
    if args.flag("xla") {
        Box::new(XlaPredictor::load_default().expect(
            "failed to load artifacts/ — run `make artifacts` first",
        ))
    } else {
        Box::new(NativePredictor::new())
    }
}

fn sched_from(args: &Args, default: SchedulerKind) -> SchedulerKind {
    let name = args.get_str("sched", default.name());
    SchedulerKind::from_name(name)
        .unwrap_or_else(|| panic!("unknown scheduler {name:?}"))
}

fn scale(args: &Args) -> f64 {
    // MB of simulated input per paper-GB. 100 keeps the full fig2 grid
    // fast while preserving proportions; use 1024 for full-size runs.
    args.get_f64("scale", 100.0)
}

fn report_line(r: &Report) {
    println!(
        "{:<12} jobs={:<3} makespan={:>8.1}s mean_ct={:>8.1}s thpt={:>6.2}/h \
         locality={:>5.1}% misses={:>4.1}% hotplugs={}",
        r.scheduler,
        r.completed_jobs(),
        r.makespan_s,
        r.mean_completion_s(),
        r.throughput_jobs_per_hour(),
        r.locality_pct(),
        r.miss_rate() * 100.0,
        r.hotplugs
    );
}

fn cmd_simulate(args: &Args) {
    use vcsched::config::FailureModel;
    use vcsched::harness::{FailureSpec, Workload};
    use vcsched::workloads::trace::{
        failure_trace, read_failure_trace_file, write_failure_trace_file, write_trace_file,
        TraceSource,
    };
    let mut cfg = cfg_from(args);
    if args.flag("stream") {
        cfg.stream_metrics = true;
    }
    if let Some(label) = args.get("failures") {
        let spec = FailureSpec::from_label(label).unwrap_or_else(|| {
            panic!(
                "unknown failures {label:?} (expected one of {:?} or trace:FILE)",
                FailureModel::NAMES
            )
        });
        cfg.failures = spec.model();
        cfg.failure_trace = spec.trace_file().map(str::to_string);
    }
    cfg.validate().expect("invalid config");
    if let Some(path) = args.get("failure-trace-out") {
        // Persist the run's crash/recover timeline as a replayable
        // failure-trace file. Replaying the written file (--failures
        // trace:FILE) reproduces the run byte-identically, and re-writing
        // from the replay reproduces the file byte-identically
        // (docs/FAILURE_MODEL.md).
        let pm_racks: Vec<u32> = (0..cfg.pms).map(|p| cfg.pm_rack(p)).collect();
        let events = match &cfg.failure_trace {
            Some(src) => read_failure_trace_file(src, &pm_racks)
                .unwrap_or_else(|e| panic!("--failures trace:{src}: {e}")),
            None => failure_trace(&cfg.failures, cfg.seed, &pm_racks),
        };
        write_failure_trace_file(std::path::Path::new(path), &events)
            .unwrap_or_else(|e| panic!("--failure-trace-out {path}: {e}"));
    }
    let kind = sched_from(args, SchedulerKind::DeadlineVc);
    let n = args.get_usize("jobs", 25);
    let mut source = match args.get("workload") {
        Some(label) => match Workload::from_label(label) {
            Some(Workload::TraceFile(path)) => TraceSource::from_file(&path)
                .unwrap_or_else(|e| panic!("--workload {label:?}: {e}")),
            Some(Workload::Generated) => {
                TraceSource::from_trace(JobTrace::poisson(&cfg, n, 5.0, 1.6..3.0, cfg.seed))
            }
            None => panic!("unknown workload {label:?} (expected gen or trace:FILE)"),
        },
        None => TraceSource::from_trace(JobTrace::poisson(&cfg, n, 5.0, 1.6..3.0, cfg.seed)),
    };
    if let Some(path) = args.get("trace-out") {
        // Persist the workload as a replayable trace file; the written
        // file replays byte-identically (docs/TRACE_FORMAT.md).
        let trace = source.materialize();
        write_trace_file(std::path::Path::new(path), &trace.jobs)
            .unwrap_or_else(|e| panic!("--trace-out {path}: {e}"));
        source = TraceSource::from_trace(trace);
    }
    let mut p = predictor_from(args);
    let snapshot_every = args.get_usize("snapshot-every", 0);
    let snapshot_out = args.get("snapshot-out");
    let snapshot_exit = args.flag("snapshot-exit");
    if snapshot_every > 0 && snapshot_out.is_none() {
        panic!("--snapshot-every requires --snapshot-out FILE");
    }

    let r = if let Some(path) = args.get("resume-from") {
        // Resume a snapshotted run (docs/EVENT_LOG.md). The snapshot
        // carries the scheduler (kind + state), so --sched is ignored;
        // the workload flags must rebuild the original trace source.
        let bytes =
            std::fs::read(path).unwrap_or_else(|e| panic!("--resume-from {path}: {e}"));
        let t0 = std::time::Instant::now();
        let (mut world, mut sched) = World::resume(cfg.clone(), source, &bytes)
            .unwrap_or_else(|e| panic!("--resume-from {path}: {e}"));
        if !run_stepping(
            &mut world,
            sched.as_mut(),
            p.as_mut(),
            snapshot_every,
            snapshot_out,
            snapshot_exit,
        ) {
            return;
        }
        let mut r = world.into_metrics(sched.kind().name());
        r.wall_s = t0.elapsed().as_secs_f64();
        r
    } else if let Some(nstr) = args.get("replay-to") {
        // Time-travel debugging: run once with the decision log on, then
        // deterministically rebuild the state right after decision N.
        let n: usize = nstr
            .parse()
            .unwrap_or_else(|_| panic!("--replay-to wants usize, got {nstr:?}"));
        let trace = source.materialize();
        let t0 = std::time::Instant::now();
        let mut sched = kind.build(&cfg);
        let mut world = World::new(cfg.clone(), trace.clone());
        world.enable_event_log();
        world.run(sched.as_mut(), p.as_mut());
        let log = world.take_event_log();
        let replayed = World::replay_to(cfg.clone(), TraceSource::from_trace(trace), &log, n);
        println!(
            "replay to {} of {} logged decisions: t={:.1}s state_hash={:016x}",
            n.min(log.len()),
            log.len(),
            replayed.now().as_secs_f64(),
            replayed.state_hash()
        );
        let mut r = world.into_metrics(kind.name());
        r.wall_s = t0.elapsed().as_secs_f64();
        r
    } else if snapshot_every > 0 {
        let t0 = std::time::Instant::now();
        let mut sched = kind.build(&cfg);
        let mut world = World::from_source(cfg.clone(), source);
        if !run_stepping(
            &mut world,
            sched.as_mut(),
            p.as_mut(),
            snapshot_every,
            snapshot_out,
            snapshot_exit,
        ) {
            return;
        }
        let mut r = world.into_metrics(kind.name());
        r.wall_s = t0.elapsed().as_secs_f64();
        r
    } else {
        coordinator::run_simulation_source(&cfg, kind, source, p.as_mut())
    };
    if args.flag("json") {
        println!("{}", r.to_json().render());
    } else {
        report_line(&r);
    }
}

/// Step `world` to completion at the same stop boundary as [`World::run`]
/// (so the report stays byte-equal to a plain run), writing a snapshot to
/// `out` every `every` events when `every > 0`. Returns false when
/// `exit_after` ended the run at the first snapshot — the world is
/// mid-run, so no report should be printed.
fn run_stepping(
    world: &mut World,
    sched: &mut dyn Scheduler,
    pred: &mut dyn Predictor,
    every: usize,
    out: Option<&str>,
    exit_after: bool,
) -> bool {
    let mut events = 0usize;
    // `!done()` first: a world resumed from a snapshot taken at the very
    // event that finished the run must process nothing further, exactly
    // like `World::run` (which breaks right after that event).
    while !world.done() && world.step_one(sched, pred) {
        events += 1;
        if every > 0 && events % every == 0 {
            let path = out.expect("--snapshot-every requires --snapshot-out FILE");
            let bytes = world
                .snapshot(sched)
                .unwrap_or_else(|e| panic!("snapshot: {e}"));
            std::fs::write(path, &bytes)
                .unwrap_or_else(|e| panic!("--snapshot-out {path}: {e}"));
            if exit_after {
                println!(
                    "snapshot after {events} events -> {path} ({} bytes)",
                    bytes.len()
                );
                return false;
            }
        }
    }
    true
}

fn cmd_compare(args: &Args) {
    let cfg = cfg_from(args);
    let a = SchedulerKind::from_name(args.get_str("a", "fair")).expect("--a");
    let b = SchedulerKind::from_name(args.get_str("b", "deadline_vc")).expect("--b");
    let n = args.get_usize("jobs", 25);
    let trace = JobTrace::poisson(&cfg, n, 5.0, 1.6..3.0, cfg.seed);
    let (ra, rb) = coordinator::compare(&cfg, a, b, &trace);
    report_line(&ra);
    report_line(&rb);
    let gain = (rb.throughput_jobs_per_hour() / ra.throughput_jobs_per_hour() - 1.0) * 100.0;
    println!("throughput gain {}: {gain:+.1}%", b.name());
}

fn cmd_fig2(args: &Args) {
    let cfg = cfg_from(args);
    let trace = JobTrace::fig2_grid(scale(args));
    for (label, kind) in [
        ("Figure 2(a) — Fair Scheduler", SchedulerKind::Fair),
        ("Figure 2(b) — Proposed Scheduler", SchedulerKind::DeadlineVc),
    ] {
        let r = coordinator::run_simulation(&cfg, kind, &trace);
        println!("\n{label}");
        let mut t = Table::new(&["job", "2GB", "4GB", "6GB", "8GB", "10GB"]);
        for jt in ALL_JOB_TYPES {
            let mut row = vec![jt.name().to_string()];
            for gb in [2.0, 4.0, 6.0, 8.0, 10.0] {
                let mb = gb * scale(args);
                let v = r
                    .completion_for(jt, mb)
                    .map(|s| format!("{s:.0}s"))
                    .unwrap_or_else(|| "-".into());
                row.push(v);
            }
            t.row(&row);
        }
        t.print();
    }
}

fn cmd_fig3(args: &Args) {
    let cfg = cfg_from(args);
    let trace = JobTrace::table2(scale(args));
    let (fair, prop) = coordinator::compare(
        &cfg,
        SchedulerKind::Fair,
        SchedulerKind::DeadlineVc,
        &trace,
    );
    println!("Figure 3 — Job completion times, Fair vs Proposed (Table-2 mix)");
    let mut t = Table::new(&["job", "fair", "proposed", "delta"]);
    for jt in ALL_JOB_TYPES {
        let f = fair.mean_completion_for(jt).unwrap_or(0.0);
        let p = prop.mean_completion_for(jt).unwrap_or(0.0);
        t.row(&[
            jt.name().to_string(),
            format!("{f:.0}s"),
            format!("{p:.0}s"),
            format!("{:+.1}%", (p / f - 1.0) * 100.0),
        ]);
    }
    t.print();
}

fn cmd_table2(args: &Args) {
    let cfg = cfg_from(args);
    let mut p = predictor_from(args);
    println!("Table 2 — minimum slots to meet completion-time goals");
    let mut t = Table::new(&["job", "deadline", "input", "map slots", "reduce slots"]);
    let rows: [(JobType, f64, f64); 5] = [
        (JobType::Grep, 650.0, 10.0),
        (JobType::WordCount, 520.0, 5.0),
        (JobType::Sort, 500.0, 10.0),
        (JobType::PermutationGenerator, 850.0, 4.0),
        (JobType::InvertedIndex, 720.0, 8.0),
    ];
    for (jt, d, gb) in rows {
        let spec = vcsched::workloads::JobSpec::new(jt, gb * scale(args)).with_deadline(d);
        let demand = vcsched::predictor::demand_from_spec(&cfg, &spec);
        let s = p.solve_slots(&[demand])[0];
        t.row(&[
            jt.name().to_string(),
            format!("{d:.0}s"),
            format!("{gb:.0}GB"),
            s.map_slots.to_string(),
            s.reduce_slots.to_string(),
        ]);
    }
    t.print();
}

fn cmd_throughput(args: &Args) {
    let cfg = cfg_from(args);
    let n = args.get_usize("jobs", 30);
    let seeds = args.get_usize("runs", 3);
    let mut gains = Vec::new();
    for s in 0..seeds as u64 {
        let trace = JobTrace::poisson(&cfg, n, 5.0, 1.6..3.0, cfg.seed + s);
        let (fair, prop) = coordinator::compare(
            &cfg,
            SchedulerKind::Fair,
            SchedulerKind::DeadlineVc,
            &trace,
        );
        let g =
            (prop.throughput_jobs_per_hour() / fair.throughput_jobs_per_hour() - 1.0) * 100.0;
        println!(
            "seed {s}: fair {:.2}/h proposed {:.2}/h gain {g:+.1}%",
            fair.throughput_jobs_per_hour(),
            prop.throughput_jobs_per_hour()
        );
        gains.push(g);
    }
    let mean = gains.iter().sum::<f64>() / gains.len() as f64;
    println!("mean throughput gain: {mean:+.1}% (paper: ~12%)");
}

/// `vcsched sweep`: expand a scenario grid (named preset or ad-hoc), run
/// it across worker threads — reusing journaled cells unless `--fresh` —
/// print the per-cell aggregate table (plus the baseline-vs-candidate
/// comparison for presets), and write `sweep.json` / `sweep.csv` /
/// `sweep.journal` artifacts under `--out` (default `results/`). The
/// JSON is byte-identical at any `--threads` setting and across
/// interrupt/resume cycles (see `harness` docs).
fn cmd_sweep(args: &Args) {
    use vcsched::cluster::Topology;
    use vcsched::config::{FailureModel, PmProfile};
    use vcsched::harness::{
        aggregate, aggregates_csv, compare_cells, comparison_json, figure_preset,
        run_sweep_resumable, sweep_json, FailureSpec, JobMix, Journal, ScenarioGrid, Workload,
        PRESET_NAMES,
    };
    use vcsched::workloads::trace::Arrival;

    let (mut grid, preset) = if let Some(name) = args.get("preset") {
        let (g, p) = figure_preset(name).unwrap_or_else(|| {
            panic!("unknown preset {name:?} (expected one of {PRESET_NAMES:?})")
        });
        (g, Some(p))
    } else {
        let grid_name = args.get_str("grid", "default");
        let g = match grid_name {
            "default" => ScenarioGrid::default_grid(),
            "quick" => ScenarioGrid::quick(),
            "stress" => ScenarioGrid::stress(),
            "stress-xl" => ScenarioGrid::stress_xl(),
            "stress-1m" => ScenarioGrid::stress_1m(),
            other => {
                panic!(
                    "unknown grid {other:?} (expected default|quick|stress|stress-xl|stress-1m)"
                )
            }
        };
        (g, None)
    };

    // Per-axis overrides (each collapses its axis to the given values).
    grid.grid_seed = args.get_u64("seed", grid.grid_seed);
    grid.seed_replicates = args.get_usize("seeds", grid.seed_replicates);
    grid.jobs_per_scenario = args.get_usize("jobs", grid.jobs_per_scenario);
    if let Some(v) = args.get("pms") {
        let pms = v
            .parse::<usize>()
            .unwrap_or_else(|_| panic!("--pms wants usize, got {v:?}"));
        grid.pm_counts = vec![pms];
    }
    if let Some(v) = args.get("scale") {
        let scale = v
            .parse::<f64>()
            .unwrap_or_else(|_| panic!("--scale wants f64, got {v:?}"));
        grid.scales = vec![scale];
    }
    if let Some(names) = args.get("sched") {
        grid.schedulers = SchedulerKind::parse_list(names)
            .unwrap_or_else(|| panic!("unknown scheduler in {names:?}"));
    }
    if let Some(name) = args.get("mix") {
        let mix = JobMix::from_name(name)
            .unwrap_or_else(|| panic!("unknown mix {name:?} (mixed or a job type)"));
        grid.mixes = vec![mix];
    }
    if let Some(names) = args.get("profile") {
        grid.profiles = names
            .split(',')
            .map(|p| {
                PmProfile::from_name(p.trim()).unwrap_or_else(|| {
                    panic!("unknown profile {p:?} (uniform|split-2x|long-tail)")
                })
            })
            .collect();
    }
    if let Some(labels) = args.get("topology") {
        grid.topologies = Topology::parse_list(labels).unwrap_or_else(|| {
            panic!("unknown topology in {labels:?} (flat|racks-N|fat-tree-N)")
        });
    }
    if let Some(labels) = args.get("arrival") {
        grid.arrivals = labels
            .split(',')
            .map(|a| {
                Arrival::from_label(a.trim()).unwrap_or_else(|| {
                    panic!("unknown arrival {a:?} (steady|burst[-xRATE])")
                })
            })
            .collect();
    }
    if let Some(names) = args.get("failures") {
        grid.failures = FailureSpec::parse_list(names).unwrap_or_else(|| {
            panic!(
                "unknown failure spec in {names:?} (expected one of {:?} or trace:FILE)",
                FailureModel::NAMES
            )
        });
    }
    if let Some(labels) = args.get("workload") {
        grid.workloads = labels
            .split(',')
            .map(|w| {
                Workload::from_label(w.trim()).unwrap_or_else(|| {
                    panic!("unknown workload {w:?} (expected gen or trace:FILE)")
                })
            })
            .collect();
    }
    if args.flag("stream") {
        grid.stream_metrics = true;
    }

    let default_threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let threads = args.get_usize("threads", default_threads);

    println!(
        "sweep {:?}: {} scenarios ({} schedulers x {} mixes x {} PM counts x \
         {} profiles x {} topologies x {} arrivals x {} scales x {} failure \
         models x {} workloads x {} seeds), {} jobs each, {threads} threads{}",
        grid.name,
        grid.len(),
        grid.schedulers.len(),
        grid.mixes.len(),
        grid.pm_counts.len(),
        grid.profiles.len(),
        grid.topologies.len(),
        grid.arrivals.len(),
        grid.scales.len(),
        grid.failures.len(),
        grid.workloads.len(),
        grid.seed_replicates,
        grid.jobs_per_scenario,
        if grid.stream_metrics { ", streaming metrics" } else { "" },
    );

    let out = std::path::PathBuf::from(args.get_str("out", "results"));
    std::fs::create_dir_all(&out).expect("mkdir artifact dir");
    let journal = Journal::new(out.join("sweep.journal"));
    if args.flag("fresh") {
        journal.clear().expect("clear sweep.journal");
    }

    let t0 = std::time::Instant::now();
    let (results, reused) = run_sweep_resumable(&grid, threads, &journal);
    let wall_s = t0.elapsed().as_secs_f64();
    if reused > 0 {
        println!(
            "resumed from {}: {reused}/{} cells reused, {} run fresh",
            journal.path().display(),
            results.len(),
            results.len() - reused
        );
    }
    let groups = aggregate(&results);

    let mut t = Table::new(&[
        "scheduler", "mix", "pms", "profile", "topology", "arrival", "failures",
        "mean_ct", "p50", "p99", "thpt/h", "node/rack/remote", "misses", "spec l/w/k",
    ]);
    for g in &groups {
        t.row(&[
            g.scheduler.clone(),
            g.mix.clone(),
            g.pms.to_string(),
            g.profile.clone(),
            g.topology.clone(),
            g.arrival.clone(),
            g.failures.clone(),
            format!("{:.1}±{:.1}s", g.mean_completion_s, g.std_completion_s),
            format!("{:.1}s", g.p50_completion_s),
            format!("{:.1}s", g.p99_completion_s),
            format!("{:.2}±{:.2}", g.mean_throughput_jph, g.std_throughput_jph),
            format!(
                "{:.1}/{:.1}/{:.1}%",
                g.mean_locality_pct, g.mean_rack_pct, g.mean_remote_pct
            ),
            format!("{:.0}%", g.mean_miss_rate * 100.0),
            format!("{}/{}/{}", g.spec_launches, g.spec_wins, g.spec_kills),
        ]);
    }
    t.print();

    let mut doc = sweep_json(&grid, &results, &groups);
    if let Some(p) = &preset {
        let rows = compare_cells(&groups, p);
        if rows.is_empty() {
            // Overrides can collapse away one side of the comparison
            // (e.g. --sched deadline_vc); don't fabricate a 0.0 headline.
            println!(
                "\n{}: comparison unavailable — the sweep must include both \
                 {} and {} (drop --sched or list both)",
                p.name,
                p.baseline.name(),
                p.candidate.name()
            );
        } else {
            print_comparison(p, &rows);
            doc = doc.set("comparison", comparison_json(p, &rows));
        }
    }

    let json = doc.render();
    std::fs::write(out.join("sweep.json"), &json).expect("write sweep.json");
    std::fs::write(out.join("sweep.csv"), aggregates_csv(&groups)).expect("write sweep.csv");

    // Journaled cells carry no wall-clock, so the speedup figure is only
    // meaningful when everything ran fresh this invocation.
    if reused == 0 {
        let sim_wall: f64 = results.iter().map(|r| r.report.wall_s).sum();
        println!(
            "\n{} scenarios in {wall_s:.2}s wall on {threads} threads \
             (sum of per-scenario sim time {sim_wall:.2}s, speedup x{:.2}); \
             artifacts: {}/sweep.json, {}/sweep.csv, {}/sweep.journal",
            results.len(),
            sim_wall / wall_s.max(1e-9),
            out.display(),
            out.display(),
            out.display()
        );
    } else {
        println!(
            "\n{} scenarios ({} fresh, {reused} from journal) in {wall_s:.2}s \
             wall on {threads} threads; artifacts: {}/sweep.json, \
             {}/sweep.csv, {}/sweep.journal",
            results.len(),
            results.len() - reused,
            out.display(),
            out.display(),
            out.display()
        );
    }
}

/// Print a preset's per-cell comparison table and tracked headline gain.
fn print_comparison(p: &vcsched::harness::Preset, rows: &[vcsched::harness::ComparisonRow]) {
    let unit = p.metric.gain_unit();
    println!("\n{} — {}", p.name, p.describes);
    let mut t = Table::new(&[
        "mix",
        "profile",
        "topology",
        "arrival",
        "failures",
        p.baseline.name(),
        p.candidate.name(),
        "gain",
    ]);
    for r in rows {
        t.row(&[
            r.mix.clone(),
            r.profile.clone(),
            r.topology.clone(),
            r.arrival.clone(),
            r.failures.clone(),
            format!("{:.2}", r.baseline),
            format!("{:.2}", r.candidate),
            format!("{:+.1}{unit}", r.gain),
        ]);
    }
    t.print();
    let headline = vcsched::harness::headline_gain(rows);
    match p.paper_gain {
        Some(paper) => println!(
            "headline {} gain {}: {headline:+.1}{unit} (paper: ~{paper:+.0}%)",
            p.metric.name(),
            p.candidate.name()
        ),
        None => println!(
            "headline {} gain {}: {headline:+.1}{unit}",
            p.metric.name(),
            p.candidate.name()
        ),
    }
}

fn cmd_gantt(args: &Args) {
    let cfg = cfg_from(args);
    let kind = sched_from(args, SchedulerKind::DeadlineVc);
    let n = args.get_usize("jobs", 8);
    let trace = JobTrace::poisson(&cfg, n, 10.0, 1.6..3.0, cfg.seed);
    let mut sched = kind.build(&cfg);
    let mut p = predictor_from(args);
    let mut world = World::new(cfg.clone(), trace);
    world.enable_trace();
    world.run(sched.as_mut(), p.as_mut());
    let tl = world.trace_log().unwrap();
    if args.flag("json") {
        println!("{}", tl.to_json().render());
    } else {
        print!("{}", tl.render_gantt(cfg.nodes(), args.get_usize("width", 100)));
        println!("span locality: {:.1}%", tl.span_locality_pct());
    }
}

/// Write every paper artifact's data as JSON + CSV under --out (default
/// results/): fig2a.csv, fig2b.csv, fig3.csv, table2.csv, headline.json.
fn cmd_export(args: &Args) {
    use std::fmt::Write as _;
    let cfg = cfg_from(args);
    let out = std::path::PathBuf::from(args.get_str("out", "results"));
    std::fs::create_dir_all(&out).expect("mkdir results");
    let scale = args.get_f64("scale", 1024.0);

    // fig2 a/b
    let trace = JobTrace::fig2_grid_on(&cfg, scale);
    for (name, kind) in [("fig2a", SchedulerKind::Fair), ("fig2b", SchedulerKind::DeadlineVc)] {
        let r = coordinator::run_simulation(&cfg, kind, &trace);
        let mut csv = String::from("job,input_gb,completion_s\n");
        for jt in ALL_JOB_TYPES {
            for gb in [2.0, 4.0, 6.0, 8.0, 10.0] {
                if let Some(ct) = r.completion_for(jt, gb * scale) {
                    let _ = writeln!(csv, "{},{gb},{ct:.1}", jt.name());
                }
            }
        }
        std::fs::write(out.join(format!("{name}.csv")), csv).unwrap();
        std::fs::write(
            out.join(format!("{name}.json")),
            r.to_json().render(),
        )
        .unwrap();
    }

    // fig3
    let trace = JobTrace::table2(scale);
    let (fair, prop) = coordinator::compare(&cfg, SchedulerKind::Fair, SchedulerKind::DeadlineVc, &trace);
    let mut csv = String::from("job,fair_s,proposed_s\n");
    for jt in ALL_JOB_TYPES {
        let _ = writeln!(
            csv,
            "{},{:.1},{:.1}",
            jt.name(),
            fair.mean_completion_for(jt).unwrap_or(0.0),
            prop.mean_completion_for(jt).unwrap_or(0.0)
        );
    }
    std::fs::write(out.join("fig3.csv"), csv).unwrap();

    // table2
    let mut p = predictor_from(args);
    let mut csv = String::from("job,deadline_s,input_gb,map_slots,reduce_slots\n");
    for (jt, d, gb) in [
        (JobType::Grep, 650.0, 10.0),
        (JobType::WordCount, 520.0, 5.0),
        (JobType::Sort, 500.0, 10.0),
        (JobType::PermutationGenerator, 850.0, 4.0),
        (JobType::InvertedIndex, 720.0, 8.0),
    ] {
        let spec = vcsched::workloads::JobSpec::new(jt, gb * scale).with_deadline(d);
        let s = p.solve_slots(&[vcsched::predictor::demand_from_spec(&cfg, &spec)])[0];
        let _ = writeln!(csv, "{},{d},{gb},{},{}", jt.name(), s.map_slots, s.reduce_slots);
    }
    std::fs::write(out.join("table2.csv"), csv).unwrap();

    // headline
    let runs = args.get_usize("runs", 3);
    let mut arr = vcsched::util::json::Json::arr();
    for s in 0..runs as u64 {
        let trace = JobTrace::poisson(&cfg, 30, 5.0, 1.6..3.0, cfg.seed + s);
        let (f, pr) = coordinator::compare(&cfg, SchedulerKind::Fair, SchedulerKind::DeadlineVc, &trace);
        arr = arr.push(
            vcsched::util::json::Json::obj()
                .set("seed", cfg.seed + s)
                .set("fair_thpt", f.throughput_jobs_per_hour())
                .set("proposed_thpt", pr.throughput_jobs_per_hour())
                .set("fair_locality", f.locality_pct())
                .set("proposed_locality", pr.locality_pct()),
        );
    }
    std::fs::write(out.join("headline.json"), arr.render()).unwrap();
    println!("wrote fig2a/b, fig3, table2, headline under {}", out.display());
}

fn print_help() {
    println!(
        "vcsched — deadline-aware MapReduce scheduling on virtual clusters\n\
         usage: vcsched <simulate|compare|fig2|fig3|table2|throughput|sweep|gantt|export> [flags]\n\
         flags: --sched K --a K --b K --seed N --pms N --jobs N --runs N\n\
         \x20      --scale MB_PER_GB --xla --json\n\
         \x20      --workload <gen|trace:FILE> --stream --trace-out FILE\n\
         \x20      (simulate: replay a trace file / constant-memory metrics /\n\
         \x20      write the workload as a replayable trace)\n\
         \x20      --failures <PRESET|trace:FILE> --failure-trace-out FILE\n\
         \x20      (simulate: inject PM crashes / write the crash timeline\n\
         \x20      as a replayable failure trace — see docs/FAILURE_MODEL.md)\n\
         \x20      --snapshot-every N --snapshot-out FILE --snapshot-exit\n\
         \x20      --resume-from FILE --replay-to N\n\
         \x20      (simulate: resumable snapshots + time-travel replay —\n\
         \x20      see docs/EVENT_LOG.md)\n\
         sweep: --grid <default|quick|stress|stress-xl|stress-1m> --preset\n\
         \x20      <fig4-throughput|fig5-locality|fig6-deadline-miss|\n\
         \x20      fig7-failures|stress|stress-xl|stress-1m>\n\
         \x20      --threads N --seeds N\n\
         \x20      --mix <mixed|TYPE> --sched K[,K..]\n\
         \x20      --profile <uniform|split-2x|long-tail>[,..]\n\
         \x20      --topology <flat|racks-N|fat-tree-N>[,..]\n\
         \x20      --arrival <steady|burst[-xRATE]>[,..]\n\
         \x20      --failures <off|stragglers[-spec]|crash-low[-spec]|\n\
         \x20      crash-high[-spec]|rack-outage[-blacklist|-replan]|trace:FILE>[,..]\n\
         \x20      --workload <gen|trace:FILE>[,..] --stream\n\
         \x20      --fresh --out DIR"
    );
}
