//! Simulation / cluster / workload configuration.
//!
//! `SimConfig::paper()` reproduces the paper's testbed: 20 physical
//! machines, Xen-style vCPU hot-plug, 2 VMs per PM, each VM statically
//! configured with 2 map + 2 reduce slots, Hadoop 0.20.2-era HDFS defaults
//! (64 MB blocks, 3x replication), 3 s heartbeats.

mod parser;

pub use parser::{parse_config_str, ConfigError};

use crate::cluster::Topology;

/// Per-PM capacity/speed heterogeneity profile (a `vcsched sweep` axis).
///
/// The seed reproduction assumed a homogeneous cluster; real virtualized
/// testbeds mix machine generations, and per-node heterogeneity materially
/// changes the locality/deadline trade-offs (arXiv:1808.08040). A profile
/// maps each physical-machine index to a core count and a relative speed:
///
/// * `uniform`   — every PM has `cores_per_pm` cores at speed 1.0 (the
///   paper's §5 testbed; the default);
/// * `split-2x`  — every second PM (even index) is a "big" machine with
///   twice the physical cores. VM layout is unchanged, so big PMs start
///   with spare cores the reconfigurator's Machine Managers can hot-plug;
/// * `long-tail` — every fourth PM (index % 4 == 3) is a half-speed
///   straggler: all task durations on its VMs double.
///
/// Speeds scale simulated task durations (a task on a speed-`s` machine
/// takes `nominal / s` seconds); core counts bound the per-PM hot-plug
/// budget through [`crate::cluster::Cluster`] invariants.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PmProfile {
    #[default]
    Uniform,
    Split2x,
    LongTail,
}

impl PmProfile {
    pub const ALL: [PmProfile; 3] =
        [PmProfile::Uniform, PmProfile::Split2x, PmProfile::LongTail];

    pub fn name(self) -> &'static str {
        match self {
            PmProfile::Uniform => "uniform",
            PmProfile::Split2x => "split-2x",
            PmProfile::LongTail => "long-tail",
        }
    }

    pub fn from_name(s: &str) -> Option<PmProfile> {
        Some(match s {
            "uniform" => PmProfile::Uniform,
            "split-2x" | "split2x" => PmProfile::Split2x,
            "long-tail" | "longtail" => PmProfile::LongTail,
            _ => return None,
        })
    }

    /// Physical cores of PM `idx` given the baseline `base` core count.
    pub fn cores(self, idx: usize, base: u32) -> u32 {
        match self {
            PmProfile::Uniform | PmProfile::LongTail => base,
            PmProfile::Split2x => {
                if idx % 2 == 0 {
                    base * 2
                } else {
                    base
                }
            }
        }
    }

    /// Relative machine speed of PM `idx` (1.0 = baseline; task durations
    /// divide by this).
    pub fn speed(self, idx: usize) -> f64 {
        match self {
            PmProfile::Uniform | PmProfile::Split2x => 1.0,
            PmProfile::LongTail => {
                if idx % 4 == 3 {
                    0.5
                } else {
                    1.0
                }
            }
        }
    }
}

/// Execution mode for the MapReduce engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Timing-only: intermediate/output sizes come from the workload cost
    /// model. Used by benches and large sweeps.
    Synthetic,
    /// Tasks really execute their map/reduce functions over generated
    /// corpus bytes; sizes and record counts are measured, timing is still
    /// simulated. Used by the E2E example and correctness tests.
    Real,
}

/// Full simulation configuration.
#[derive(Clone, Debug)]
pub struct SimConfig {
    // ---- physical cluster ----
    /// Number of physical machines (paper: 20).
    pub pms: usize,
    /// Physical cores per machine available to VMs (the baseline; the
    /// per-PM count is `pm_cores(idx)` under the active `pm_profile`).
    pub cores_per_pm: u32,
    /// Per-PM capacity/speed heterogeneity profile (paper testbed:
    /// uniform).
    pub pm_profile: PmProfile,
    /// Network topology: how PMs group into racks and how oversubscribed
    /// the cross-rack core is (paper testbed: a single flat rack).
    pub topology: Topology,
    /// VMs per physical machine.
    pub vms_per_pm: usize,
    /// Base virtual CPUs per VM (= base map slots; paper: 2).
    pub base_vcpus: u32,
    /// Reduce slots per VM (static; reconfiguration never touches them).
    pub reduce_slots: u32,
    /// vCPU hot-plug latency (Assign/Release round-trip through the MM).
    pub hotplug_ms: u64,

    // ---- HDFS ----
    /// Block size in MB (Hadoop 0.20.2 default: 64).
    pub block_mb: f64,
    /// Replication factor (default 3).
    pub replication: usize,

    // ---- network / io model ----
    /// Per-node NIC bandwidth, MB/s (remote map input fetch, shuffle).
    pub net_mbps: f64,
    /// Local disk scan bandwidth, MB/s.
    pub disk_mbps: f64,

    // ---- MapReduce runtime ----
    /// TaskTracker heartbeat interval, seconds (paper: 3 s).
    pub heartbeat_s: f64,
    /// Multiplicative task-duration jitter std-dev (0 = deterministic).
    pub jitter_std: f64,
    /// Execution mode (synthetic timing vs real data).
    pub exec: ExecMode,

    // ---- scheduler knobs ----
    /// Delay-scheduling patience in heartbeats (Delay scheduler baseline).
    pub delay_heartbeats: u32,
    /// Predictor priors (seconds) used before the first task completes.
    pub prior_map_s: f64,
    pub prior_shuffle_s: f64,

    // ---- misc ----
    pub seed: u64,
}

impl SimConfig {
    /// The paper's evaluation testbed (§5).
    pub fn paper() -> Self {
        Self {
            pms: 20,
            cores_per_pm: 4,
            pm_profile: PmProfile::Uniform,
            topology: Topology::Flat,
            vms_per_pm: 2,
            base_vcpus: 2,
            reduce_slots: 2,
            hotplug_ms: 100,
            block_mb: 64.0,
            replication: 3,
            net_mbps: 10.0,
            disk_mbps: 400.0,
            heartbeat_s: 3.0,
            jitter_std: 0.08,
            exec: ExecMode::Synthetic,
            delay_heartbeats: 3,
            prior_map_s: 20.0,
            prior_shuffle_s: 0.05,
            seed: 42,
        }
    }

    /// A small fast cluster for unit tests and the quickstart example.
    pub fn small() -> Self {
        Self {
            pms: 4,
            vms_per_pm: 2,
            ..Self::paper()
        }
    }

    /// Total VMs (= HDFS DataNodes = TaskTrackers).
    pub fn nodes(&self) -> usize {
        self.pms * self.vms_per_pm
    }

    /// Physical cores of PM `idx` under the active heterogeneity profile.
    pub fn pm_cores(&self, idx: usize) -> u32 {
        self.pm_profile.cores(idx, self.cores_per_pm)
    }

    /// Relative speed of PM `idx` under the active heterogeneity profile.
    pub fn pm_speed(&self, idx: usize) -> f64 {
        self.pm_profile.speed(idx)
    }

    /// Rack of PM `idx` under the active topology (0 when flat).
    pub fn pm_rack(&self, idx: usize) -> u32 {
        self.topology.rack_of_pm(idx)
    }

    /// Rack of node (VM) `idx`: a VM inherits its host PM's rack.
    pub fn node_rack(&self, idx: usize) -> u32 {
        self.pm_rack(idx / self.vms_per_pm.max(1))
    }

    /// Rack of every node, in node order (the layout HDFS placement and
    /// the per-job rack locality index are built from).
    pub fn node_racks(&self) -> Vec<u32> {
        (0..self.nodes()).map(|n| self.node_rack(n)).collect()
    }

    /// Mean PM speed across the cluster (1.0 when homogeneous).
    pub fn mean_pm_speed(&self) -> f64 {
        if self.pms == 0 {
            return 1.0;
        }
        (0..self.pms).map(|p| self.pm_speed(p)).sum::<f64>() / self.pms as f64
    }

    /// Speed-weighted base map slots: `Σ_pm vms_per_pm · base_vcpus ·
    /// speed(pm)`. This is the honest parallel-work capacity of a
    /// heterogeneous cluster (a half-speed node's slot retires work at
    /// half rate); equals `total_map_slots()` when homogeneous.
    pub fn effective_map_slots(&self) -> f64 {
        let per_pm = (self.vms_per_pm as u32 * self.base_vcpus) as f64;
        (0..self.pms).map(|p| self.pm_speed(p) * per_pm).sum()
    }

    /// Speed-weighted reduce slots (see [`Self::effective_map_slots`]).
    pub fn effective_reduce_slots(&self) -> f64 {
        let per_pm = (self.vms_per_pm as u32 * self.reduce_slots) as f64;
        (0..self.pms).map(|p| self.pm_speed(p) * per_pm).sum()
    }

    /// Total base map slots in the cluster.
    pub fn total_map_slots(&self) -> u32 {
        self.nodes() as u32 * self.base_vcpus
    }

    /// Total reduce slots in the cluster.
    pub fn total_reduce_slots(&self) -> u32 {
        self.nodes() as u32 * self.reduce_slots
    }

    /// Validate invariants; returns a human-readable complaint.
    pub fn validate(&self) -> Result<(), String> {
        if self.pms == 0 || self.vms_per_pm == 0 {
            return Err("cluster must have at least one PM and one VM".into());
        }
        if self.base_vcpus == 0 {
            return Err("VMs need at least one base vCPU".into());
        }
        for p in 0..self.pms {
            let cores = self.pm_cores(p);
            if self.vms_per_pm as u32 * self.base_vcpus > cores {
                return Err(format!(
                    "oversubscribed PM {p} ({} profile): {} VMs x {} vCPUs > {} cores",
                    self.pm_profile.name(),
                    self.vms_per_pm,
                    self.base_vcpus,
                    cores
                ));
            }
            if self.pm_speed(p) <= 0.0 {
                return Err(format!("PM {p} has non-positive speed"));
            }
        }
        self.topology.validate(self.pms)?;
        if self.replication == 0 || self.replication > self.nodes() {
            return Err(format!(
                "replication {} out of range 1..={}",
                self.replication,
                self.nodes()
            ));
        }
        if self.block_mb <= 0.0 || self.net_mbps <= 0.0 || self.disk_mbps <= 0.0 {
            return Err("block size and bandwidths must be positive".into());
        }
        if self.heartbeat_s <= 0.0 {
            return Err("heartbeat interval must be positive".into());
        }
        Ok(())
    }
}

impl Default for SimConfig {
    fn default() -> Self {
        Self::paper()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_preset_matches_testbed() {
        let c = SimConfig::paper();
        assert_eq!(c.pms, 20);
        assert_eq!(c.nodes(), 40);
        assert_eq!(c.base_vcpus, 2);
        assert_eq!(c.reduce_slots, 2);
        assert!((c.heartbeat_s - 3.0).abs() < 1e-12);
        assert_eq!(c.block_mb, 64.0);
        assert_eq!(c.replication, 3);
        c.validate().unwrap();
    }

    #[test]
    fn small_preset_valid() {
        SimConfig::small().validate().unwrap();
    }

    #[test]
    fn validation_catches_oversubscription() {
        let c = SimConfig {
            vms_per_pm: 3,
            cores_per_pm: 4,
            base_vcpus: 2,
            ..SimConfig::paper()
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn profile_names_roundtrip() {
        for p in PmProfile::ALL {
            assert_eq!(PmProfile::from_name(p.name()), Some(p));
        }
        assert_eq!(PmProfile::from_name("bogus"), None);
    }

    #[test]
    fn split2x_doubles_even_pm_cores() {
        let c = SimConfig {
            pm_profile: PmProfile::Split2x,
            ..SimConfig::paper()
        };
        c.validate().unwrap();
        assert_eq!(c.pm_cores(0), 8);
        assert_eq!(c.pm_cores(1), 4);
        assert_eq!(c.pm_speed(0), 1.0);
        // Slots don't grow with cores (VM layout fixed), so effective
        // capacity matches the uniform cluster.
        assert_eq!(c.effective_map_slots(), c.total_map_slots() as f64);
    }

    #[test]
    fn long_tail_slows_every_fourth_pm() {
        let c = SimConfig {
            pm_profile: PmProfile::LongTail,
            ..SimConfig::paper()
        };
        c.validate().unwrap();
        assert_eq!(c.pm_speed(3), 0.5);
        assert_eq!(c.pm_speed(0), 1.0);
        // 20 PMs: 5 stragglers at half speed.
        assert!((c.mean_pm_speed() - (15.0 + 2.5) / 20.0).abs() < 1e-12);
        assert!(c.effective_map_slots() < c.total_map_slots() as f64);
        assert!(c.effective_reduce_slots() < c.total_reduce_slots() as f64);
    }

    #[test]
    fn heterogeneous_validation_checks_every_pm() {
        // A PM profile cannot rescue an oversubscribed baseline: odd PMs
        // under split-2x still have only `cores_per_pm` cores.
        let c = SimConfig {
            vms_per_pm: 3,
            cores_per_pm: 4,
            base_vcpus: 2,
            pm_profile: PmProfile::Split2x,
            ..SimConfig::paper()
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn node_racks_follow_topology() {
        let c = SimConfig {
            topology: Topology::Racks(4),
            ..SimConfig::paper() // 20 PMs x 2 VMs
        };
        c.validate().unwrap();
        // PM i -> rack i % 4; nodes 2i, 2i+1 live on PM i.
        assert_eq!(c.node_rack(0), 0);
        assert_eq!(c.node_rack(1), 0);
        assert_eq!(c.node_rack(2), 1);
        assert_eq!(c.node_rack(9), 0); // PM 4 -> rack 0
        let racks = c.node_racks();
        assert_eq!(racks.len(), 40);
        // Equal racks: 10 nodes each.
        for r in 0..4u32 {
            assert_eq!(racks.iter().filter(|&&x| x == r).count(), 10);
        }
        // Flat: everything in rack 0.
        assert!(SimConfig::paper().node_racks().iter().all(|&r| r == 0));
    }

    #[test]
    fn validation_catches_bad_topology() {
        let c = SimConfig {
            topology: Topology::Racks(40),
            ..SimConfig::paper() // only 20 PMs
        };
        assert!(c.validate().is_err());
        let c = SimConfig {
            topology: Topology::Racks(4),
            ..SimConfig::paper()
        };
        c.validate().unwrap();
    }

    #[test]
    fn validation_catches_bad_replication() {
        let c = SimConfig {
            replication: 0,
            ..SimConfig::paper()
        };
        assert!(c.validate().is_err());
        let c = SimConfig {
            replication: 1000,
            ..SimConfig::paper()
        };
        assert!(c.validate().is_err());
    }
}
