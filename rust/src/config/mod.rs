//! Simulation / cluster / workload configuration.
//!
//! `SimConfig::paper()` reproduces the paper's testbed: 20 physical
//! machines, Xen-style vCPU hot-plug, 2 VMs per PM, each VM statically
//! configured with 2 map + 2 reduce slots, Hadoop 0.20.2-era HDFS defaults
//! (64 MB blocks, 3x replication), 3 s heartbeats.

mod parser;

pub use parser::{parse_config_str, ConfigError};

use crate::cluster::Topology;

/// Per-PM capacity/speed heterogeneity profile (a `vcsched sweep` axis).
///
/// The seed reproduction assumed a homogeneous cluster; real virtualized
/// testbeds mix machine generations, and per-node heterogeneity materially
/// changes the locality/deadline trade-offs (arXiv:1808.08040). A profile
/// maps each physical-machine index to a core count and a relative speed:
///
/// * `uniform`   — every PM has `cores_per_pm` cores at speed 1.0 (the
///   paper's §5 testbed; the default);
/// * `split-2x`  — every second PM (even index) is a "big" machine with
///   twice the physical cores. VM layout is unchanged, so big PMs start
///   with spare cores the reconfigurator's Machine Managers can hot-plug;
/// * `long-tail` — every fourth PM (index % 4 == 3) is a half-speed
///   straggler: all task durations on its VMs double.
///
/// Speeds scale simulated task durations (a task on a speed-`s` machine
/// takes `nominal / s` seconds); core counts bound the per-PM hot-plug
/// budget through [`crate::cluster::Cluster`] invariants.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PmProfile {
    #[default]
    Uniform,
    Split2x,
    LongTail,
}

impl PmProfile {
    pub const ALL: [PmProfile; 3] =
        [PmProfile::Uniform, PmProfile::Split2x, PmProfile::LongTail];

    pub fn name(self) -> &'static str {
        match self {
            PmProfile::Uniform => "uniform",
            PmProfile::Split2x => "split-2x",
            PmProfile::LongTail => "long-tail",
        }
    }

    pub fn from_name(s: &str) -> Option<PmProfile> {
        Some(match s {
            "uniform" => PmProfile::Uniform,
            "split-2x" | "split2x" => PmProfile::Split2x,
            "long-tail" | "longtail" => PmProfile::LongTail,
            _ => return None,
        })
    }

    /// Physical cores of PM `idx` given the baseline `base` core count.
    pub fn cores(self, idx: usize, base: u32) -> u32 {
        match self {
            PmProfile::Uniform | PmProfile::LongTail => base,
            PmProfile::Split2x => {
                if idx % 2 == 0 {
                    base * 2
                } else {
                    base
                }
            }
        }
    }

    /// Relative machine speed of PM `idx` (1.0 = baseline; task durations
    /// divide by this).
    pub fn speed(self, idx: usize) -> f64 {
        match self {
            PmProfile::Uniform | PmProfile::Split2x => 1.0,
            PmProfile::LongTail => {
                if idx % 4 == 3 {
                    0.5
                } else {
                    1.0
                }
            }
        }
    }
}

/// Failure-injection model (a `vcsched sweep` axis; see
/// `docs/FAILURE_MODEL.md` for the full semantics).
///
/// Three orthogonal mechanisms, all off by default so the failure-free
/// configuration reproduces the seed byte for byte:
///
/// * **PM crashes** — each physical machine fails after an exponential
///   up-time with mean `pm_mtbf_s`, stays down for about `pm_repair_s`,
///   and recovers. The crash/recover trace is pre-generated from a
///   dedicated per-scenario RNG stream
///   ([`crate::workloads::trace::failure_trace`]), so it never perturbs
///   the workload/jitter stream.
/// * **Stragglers** — with probability `straggler_prob` a launched task
///   draws a heavy-tailed (Pareto-`straggler_alpha`, capped at
///   `straggler_cap`) slowdown multiplier
///   ([`crate::mapreduce::straggler_multiplier`]).
/// * **Speculation** — LATE-style speculative re-execution of straggling
///   maps *and reduces*: once a job has `spec_min_finished` finished tasks
///   of the phase, a running task whose elapsed time exceeds
///   `spec_slowdown ×` the job's observed mean task duration is eligible
///   for a backup copy on an idle slot. First finisher wins; the
///   coordinator kills the loser.
///
/// Plus two *reactive-policy* switches (no injection of their own; they
/// change how schedulers respond to the crash signal):
///
/// * **Blacklisting** (`blacklist`) — a PM that crashed
///   [`crate::scheduler`]'s `BLACKLIST_K` times within its rolling window
///   is skipped for new launches until the window clears.
/// * **Re-planning** (`replan`) — deadline_vc recomputes Eq. 10 slot
///   demand against the live (post-crash) slot supply instead of the
///   static cluster capacity.
///
/// `rack_correlated` switches the crash generator from independent per-PM
/// exponentials to whole-rack outages (every PM of a rack fails and
/// recovers together; `pm_mtbf_s`/`pm_repair_s` then apply per rack).
///
/// Named presets form the `--failures` sweep axis:
///
/// ```
/// use vcsched::config::FailureModel;
///
/// let off = FailureModel::from_name("off").unwrap();
/// assert!(!off.enabled());
/// assert_eq!(off.label(), "off");
///
/// let m = FailureModel::from_name("crash-low-spec").unwrap();
/// assert!(m.enabled() && m.speculation && m.pm_mtbf_s > 0.0);
/// assert_eq!(m.label(), "crash-low-spec");
///
/// // Every preset name round-trips through its label.
/// for name in FailureModel::NAMES {
///     assert_eq!(FailureModel::from_name(name).unwrap().label(), name);
/// }
/// assert!(FailureModel::from_name("bogus").is_none());
/// ```
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FailureModel {
    /// Mean PM up-time between crashes, seconds (0 = crashes off).
    pub pm_mtbf_s: f64,
    /// Mean PM downtime after a crash, seconds.
    pub pm_repair_s: f64,
    /// Horizon over which crash events are generated; a crash already
    /// injected always gets its matching recovery even past the horizon.
    pub trace_horizon_s: f64,
    /// Per-task-launch probability of a straggler slowdown (0 = off).
    pub straggler_prob: f64,
    /// Pareto tail shape of the slowdown (smaller = heavier tail).
    pub straggler_alpha: f64,
    /// Upper clamp on the slowdown multiplier.
    pub straggler_cap: f64,
    /// LATE-style speculative execution of straggling maps.
    pub speculation: bool,
    /// Speculation trigger: elapsed > `spec_slowdown ×` observed mean.
    pub spec_slowdown: f64,
    /// Minimum finished maps in a job before it may speculate.
    pub spec_min_finished: u32,
    /// Whole-rack correlated outages instead of independent PM crashes
    /// (`pm_mtbf_s`/`pm_repair_s` apply per rack).
    pub rack_correlated: bool,
    /// Reactive policy: deprioritize repeatedly-crashing PMs for new
    /// launches (see `scheduler::BlacklistPolicy`).
    pub blacklist: bool,
    /// Reactive policy: deadline_vc recomputes Eq. 10 demand against the
    /// live slot supply after crashes.
    pub replan: bool,
}

impl FailureModel {
    /// The named presets, in sweep-axis order.
    pub const NAMES: [&'static str; 10] = [
        "off",
        "stragglers",
        "stragglers-spec",
        "crash-low",
        "crash-low-spec",
        "crash-high",
        "crash-high-spec",
        "rack-outage",
        "rack-outage-blacklist",
        "rack-outage-replan",
    ];

    /// No failures at all — the seed-identical default.
    pub fn off() -> Self {
        Self {
            pm_mtbf_s: 0.0,
            pm_repair_s: 0.0,
            trace_horizon_s: 0.0,
            straggler_prob: 0.0,
            straggler_alpha: 0.0,
            straggler_cap: 1.0,
            speculation: false,
            spec_slowdown: 1.8,
            spec_min_finished: 3,
            rack_correlated: false,
            blacklist: false,
            replan: false,
        }
    }

    /// Heavy-tailed stragglers only (no machine failures).
    pub fn stragglers() -> Self {
        Self {
            straggler_prob: 0.08,
            straggler_alpha: 1.5,
            straggler_cap: 8.0,
            ..Self::off()
        }
    }

    /// Stragglers + crashes at roughly one failure per machine-hour.
    pub fn crash_low() -> Self {
        Self {
            pm_mtbf_s: 3600.0,
            pm_repair_s: 180.0,
            trace_horizon_s: 6.0 * 3600.0,
            ..Self::stragglers()
        }
    }

    /// Stragglers + frequent crashes (one per machine per ~20 min).
    pub fn crash_high() -> Self {
        Self {
            pm_mtbf_s: 1200.0,
            pm_repair_s: 180.0,
            straggler_prob: 0.12,
            ..Self::crash_low()
        }
    }

    /// Correlated whole-rack outages, *pure* crash signal: no stragglers,
    /// no speculation. Purity keeps a generated rack-outage timeline and
    /// its recorded trace-file replay byte-identical (nothing else draws
    /// from the failure stream between crash events).
    pub fn rack_outage() -> Self {
        Self {
            pm_mtbf_s: 2400.0,
            pm_repair_s: 240.0,
            trace_horizon_s: 6.0 * 3600.0,
            rack_correlated: true,
            ..Self::off()
        }
    }

    /// The same model with speculation switched on.
    pub fn with_speculation(mut self) -> Self {
        self.speculation = true;
        self
    }

    /// The same model with PM blacklisting switched on.
    pub fn with_blacklist(mut self) -> Self {
        self.blacklist = true;
        self
    }

    /// The same model with deadline re-planning switched on.
    pub fn with_replan(mut self) -> Self {
        self.replan = true;
        self
    }

    pub fn from_name(s: &str) -> Option<Self> {
        Some(match s {
            "off" => Self::off(),
            "stragglers" => Self::stragglers(),
            "stragglers-spec" => Self::stragglers().with_speculation(),
            "crash-low" => Self::crash_low(),
            "crash-low-spec" => Self::crash_low().with_speculation(),
            "crash-high" => Self::crash_high(),
            "crash-high-spec" => Self::crash_high().with_speculation(),
            "rack-outage" => Self::rack_outage(),
            "rack-outage-blacklist" => Self::rack_outage().with_blacklist(),
            "rack-outage-replan" => Self::rack_outage().with_replan(),
            _ => return None,
        })
    }

    /// Parse a comma-separated preset list (`"off,crash-low-spec"`) — the
    /// `vcsched sweep --failures` axis override. `None` on any unknown
    /// name.
    pub fn parse_list(s: &str) -> Option<Vec<Self>> {
        s.split(',').map(|p| Self::from_name(p.trim())).collect()
    }

    /// Stable axis label: the preset name when the model matches one,
    /// otherwise an exact field encoding (journal keys depend on this
    /// being injective over distinct models).
    pub fn label(&self) -> String {
        for name in Self::NAMES {
            if Self::from_name(name).as_ref() == Some(self) {
                return name.to_string();
            }
        }
        format!(
            "custom-mtbf{}-rep{}-hz{}-p{}-a{}-cap{}-spec{}-sl{}-mf{}-rack{}-bl{}-rp{}",
            self.pm_mtbf_s,
            self.pm_repair_s,
            self.trace_horizon_s,
            self.straggler_prob,
            self.straggler_alpha,
            self.straggler_cap,
            self.speculation as u8,
            self.spec_slowdown,
            self.spec_min_finished,
            self.rack_correlated as u8,
            self.blacklist as u8,
            self.replan as u8,
        )
    }

    /// Does this model inject anything at all? `false` means the run must
    /// be byte-identical to a failure-free one.
    pub fn enabled(&self) -> bool {
        self.pm_mtbf_s > 0.0 || self.straggler_prob > 0.0 || self.speculation
    }

    /// Are PM crashes on?
    pub fn crashes(&self) -> bool {
        self.pm_mtbf_s > 0.0
    }

    fn validate(&self) -> Result<(), String> {
        if self.pm_mtbf_s < 0.0 || self.pm_repair_s < 0.0 || self.trace_horizon_s < 0.0 {
            return Err("failure times must be non-negative".into());
        }
        if self.crashes() && (self.pm_repair_s <= 0.0 || self.trace_horizon_s <= 0.0) {
            return Err("crashes need a positive repair time and trace horizon".into());
        }
        if !(0.0..=1.0).contains(&self.straggler_prob) {
            return Err("straggler_prob must be in [0, 1]".into());
        }
        if self.straggler_prob > 0.0 && (self.straggler_alpha <= 0.0 || self.straggler_cap < 1.0) {
            return Err("stragglers need alpha > 0 and cap >= 1".into());
        }
        if self.speculation && (self.spec_slowdown < 1.0 || self.spec_min_finished == 0) {
            return Err("speculation needs spec_slowdown >= 1 and spec_min_finished >= 1".into());
        }
        if self.rack_correlated && !self.crashes() {
            return Err("rack-correlated outages need pm_mtbf_s > 0".into());
        }
        Ok(())
    }
}

impl Default for FailureModel {
    fn default() -> Self {
        Self::off()
    }
}

/// Execution mode for the MapReduce engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Timing-only: intermediate/output sizes come from the workload cost
    /// model. Used by benches and large sweeps.
    Synthetic,
    /// Tasks really execute their map/reduce functions over generated
    /// corpus bytes; sizes and record counts are measured, timing is still
    /// simulated. Used by the E2E example and correctness tests.
    Real,
}

/// Full simulation configuration.
#[derive(Clone, Debug)]
pub struct SimConfig {
    // ---- physical cluster ----
    /// Number of physical machines (paper: 20).
    pub pms: usize,
    /// Physical cores per machine available to VMs (the baseline; the
    /// per-PM count is `pm_cores(idx)` under the active `pm_profile`).
    pub cores_per_pm: u32,
    /// Per-PM capacity/speed heterogeneity profile (paper testbed:
    /// uniform).
    pub pm_profile: PmProfile,
    /// Network topology: how PMs group into racks and how oversubscribed
    /// the cross-rack core is (paper testbed: a single flat rack).
    pub topology: Topology,
    /// VMs per physical machine.
    pub vms_per_pm: usize,
    /// Base virtual CPUs per VM (= base map slots; paper: 2).
    pub base_vcpus: u32,
    /// Reduce slots per VM (static; reconfiguration never touches them).
    pub reduce_slots: u32,
    /// vCPU hot-plug latency (Assign/Release round-trip through the MM).
    pub hotplug_ms: u64,

    // ---- HDFS ----
    /// Block size in MB (Hadoop 0.20.2 default: 64).
    pub block_mb: f64,
    /// Replication factor (default 3).
    pub replication: usize,

    // ---- network / io model ----
    /// Per-node NIC bandwidth, MB/s (remote map input fetch, shuffle).
    pub net_mbps: f64,
    /// Local disk scan bandwidth, MB/s.
    pub disk_mbps: f64,

    // ---- MapReduce runtime ----
    /// TaskTracker heartbeat interval, seconds (paper: 3 s).
    pub heartbeat_s: f64,
    /// Multiplicative task-duration jitter std-dev (0 = deterministic).
    pub jitter_std: f64,
    /// Execution mode (synthetic timing vs real data).
    pub exec: ExecMode,

    // ---- scheduler knobs ----
    /// Delay-scheduling patience in heartbeats (Delay scheduler baseline).
    pub delay_heartbeats: u32,
    /// Predictor priors (seconds) used before the first task completes.
    pub prior_map_s: f64,
    pub prior_shuffle_s: f64,

    // ---- failure injection ----
    /// Failure-injection model (default: [`FailureModel::off`], which is
    /// byte-identical to the pre-failure simulator).
    pub failures: FailureModel,
    /// Replay the crash/recover timeline from this recorded trace file
    /// (`docs/FAILURE_MODEL.md` grammar) instead of generating it from
    /// `failures`. The model's straggler/speculation/policy knobs still
    /// apply; its crash generator is bypassed.
    pub failure_trace: Option<String>,

    // ---- metrics ----
    /// Streaming-metrics mode: fold every finished job into constant-
    /// memory accumulators (Welford mean/std + quantile sketch) instead of
    /// storing a [`crate::metrics::JobRecord`] per job, and let the
    /// coordinator retire completed jobs' state so peak memory is bounded
    /// by the *active* job window, not the trace length. Off (the exact
    /// per-job path, byte-identical to previous releases) by default;
    /// requires `failures` off and [`ExecMode::Synthetic`].
    pub stream_metrics: bool,

    // ---- misc ----
    pub seed: u64,
}

impl SimConfig {
    /// The paper's evaluation testbed (§5).
    pub fn paper() -> Self {
        Self {
            pms: 20,
            cores_per_pm: 4,
            pm_profile: PmProfile::Uniform,
            topology: Topology::Flat,
            vms_per_pm: 2,
            base_vcpus: 2,
            reduce_slots: 2,
            hotplug_ms: 100,
            block_mb: 64.0,
            replication: 3,
            net_mbps: 10.0,
            disk_mbps: 400.0,
            heartbeat_s: 3.0,
            jitter_std: 0.08,
            exec: ExecMode::Synthetic,
            delay_heartbeats: 3,
            prior_map_s: 20.0,
            prior_shuffle_s: 0.05,
            failures: FailureModel::off(),
            failure_trace: None,
            stream_metrics: false,
            seed: 42,
        }
    }

    /// A small fast cluster for unit tests and the quickstart example.
    pub fn small() -> Self {
        Self {
            pms: 4,
            vms_per_pm: 2,
            ..Self::paper()
        }
    }

    /// Total VMs (= HDFS DataNodes = TaskTrackers).
    pub fn nodes(&self) -> usize {
        self.pms * self.vms_per_pm
    }

    /// Physical cores of PM `idx` under the active heterogeneity profile.
    pub fn pm_cores(&self, idx: usize) -> u32 {
        self.pm_profile.cores(idx, self.cores_per_pm)
    }

    /// Relative speed of PM `idx` under the active heterogeneity profile.
    pub fn pm_speed(&self, idx: usize) -> f64 {
        self.pm_profile.speed(idx)
    }

    /// Rack of PM `idx` under the active topology (0 when flat).
    pub fn pm_rack(&self, idx: usize) -> u32 {
        self.topology.rack_of_pm(idx)
    }

    /// Rack of node (VM) `idx`: a VM inherits its host PM's rack.
    pub fn node_rack(&self, idx: usize) -> u32 {
        self.pm_rack(idx / self.vms_per_pm.max(1))
    }

    /// Rack of every node, in node order (the layout HDFS placement and
    /// the per-job rack locality index are built from).
    pub fn node_racks(&self) -> Vec<u32> {
        (0..self.nodes()).map(|n| self.node_rack(n)).collect()
    }

    /// Mean PM speed across the cluster (1.0 when homogeneous).
    pub fn mean_pm_speed(&self) -> f64 {
        if self.pms == 0 {
            return 1.0;
        }
        (0..self.pms).map(|p| self.pm_speed(p)).sum::<f64>() / self.pms as f64
    }

    /// Speed-weighted base map slots: `Σ_pm vms_per_pm · base_vcpus ·
    /// speed(pm)`. This is the honest parallel-work capacity of a
    /// heterogeneous cluster (a half-speed node's slot retires work at
    /// half rate); equals `total_map_slots()` when homogeneous.
    pub fn effective_map_slots(&self) -> f64 {
        let per_pm = (self.vms_per_pm as u32 * self.base_vcpus) as f64;
        (0..self.pms).map(|p| self.pm_speed(p) * per_pm).sum()
    }

    /// Speed-weighted reduce slots (see [`Self::effective_map_slots`]).
    pub fn effective_reduce_slots(&self) -> f64 {
        let per_pm = (self.vms_per_pm as u32 * self.reduce_slots) as f64;
        (0..self.pms).map(|p| self.pm_speed(p) * per_pm).sum()
    }

    /// Total base map slots in the cluster.
    pub fn total_map_slots(&self) -> u32 {
        self.nodes() as u32 * self.base_vcpus
    }

    /// Total reduce slots in the cluster.
    pub fn total_reduce_slots(&self) -> u32 {
        self.nodes() as u32 * self.reduce_slots
    }

    /// Can this run see PM crashes — from the model's generator *or* a
    /// replayed failure-trace file?
    pub fn injects_crashes(&self) -> bool {
        self.failures.crashes() || self.failure_trace.is_some()
    }

    /// Stable 64-bit fingerprint over every configuration field, including
    /// the seed. Snapshots embed it so a resume against a *different*
    /// configuration (which could never reproduce the original run) is
    /// rejected up front instead of silently diverging. Enum fields encode
    /// through their stable axis labels, floats through their exact bit
    /// patterns (`docs/EVENT_LOG.md`).
    pub fn fingerprint(&self) -> u64 {
        use crate::util::codec::{fnv1a64, Enc};
        let mut e = Enc::new();
        e.usize(self.pms);
        e.u32(self.cores_per_pm);
        e.str(self.pm_profile.name());
        e.str(&self.topology.label());
        e.usize(self.vms_per_pm);
        e.u32(self.base_vcpus);
        e.u32(self.reduce_slots);
        e.u64(self.hotplug_ms);
        e.f64(self.block_mb);
        e.usize(self.replication);
        e.f64(self.net_mbps);
        e.f64(self.disk_mbps);
        e.f64(self.heartbeat_s);
        e.f64(self.jitter_std);
        e.u8(match self.exec {
            ExecMode::Synthetic => 0,
            ExecMode::Real => 1,
        });
        e.u32(self.delay_heartbeats);
        e.f64(self.prior_map_s);
        e.f64(self.prior_shuffle_s);
        e.f64(self.failures.pm_mtbf_s);
        e.f64(self.failures.pm_repair_s);
        e.f64(self.failures.trace_horizon_s);
        e.f64(self.failures.straggler_prob);
        e.f64(self.failures.straggler_alpha);
        e.f64(self.failures.straggler_cap);
        e.bool(self.failures.speculation);
        e.f64(self.failures.spec_slowdown);
        e.u32(self.failures.spec_min_finished);
        e.bool(self.failures.rack_correlated);
        e.bool(self.failures.blacklist);
        e.bool(self.failures.replan);
        match &self.failure_trace {
            None => e.bool(false),
            Some(path) => {
                e.bool(true);
                e.str(path);
            }
        }
        e.bool(self.stream_metrics);
        e.u64(self.seed);
        fnv1a64(e.bytes())
    }

    /// Validate invariants; returns a human-readable complaint.
    pub fn validate(&self) -> Result<(), String> {
        if self.pms == 0 || self.vms_per_pm == 0 {
            return Err("cluster must have at least one PM and one VM".into());
        }
        if self.base_vcpus == 0 {
            return Err("VMs need at least one base vCPU".into());
        }
        for p in 0..self.pms {
            let cores = self.pm_cores(p);
            if self.vms_per_pm as u32 * self.base_vcpus > cores {
                return Err(format!(
                    "oversubscribed PM {p} ({} profile): {} VMs x {} vCPUs > {} cores",
                    self.pm_profile.name(),
                    self.vms_per_pm,
                    self.base_vcpus,
                    cores
                ));
            }
            if self.pm_speed(p) <= 0.0 {
                return Err(format!("PM {p} has non-positive speed"));
            }
        }
        self.topology.validate(self.pms)?;
        if self.replication == 0 || self.replication > self.nodes() {
            return Err(format!(
                "replication {} out of range 1..={}",
                self.replication,
                self.nodes()
            ));
        }
        if self.block_mb <= 0.0 || self.net_mbps <= 0.0 || self.disk_mbps <= 0.0 {
            return Err("block size and bandwidths must be positive".into());
        }
        if self.heartbeat_s <= 0.0 {
            return Err("heartbeat interval must be positive".into());
        }
        self.failures.validate()?;
        if let Some(path) = &self.failure_trace {
            if path.is_empty() {
                return Err("failure_trace path must be non-empty".into());
            }
            if self.failures.crashes() {
                return Err(
                    "failure_trace replaces the crash generator; set pm_mtbf_s = 0".into(),
                );
            }
        }
        if self.stream_metrics
            && (self.failures.enabled()
                || self.failure_trace.is_some()
                || self.exec != ExecMode::Synthetic)
        {
            return Err(
                "stream_metrics requires failures off and synthetic execution (completed \
                 jobs are retired; crash re-execution and real-exec state need them kept)"
                    .into(),
            );
        }
        Ok(())
    }
}

impl Default for SimConfig {
    fn default() -> Self {
        Self::paper()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_preset_matches_testbed() {
        let c = SimConfig::paper();
        assert_eq!(c.pms, 20);
        assert_eq!(c.nodes(), 40);
        assert_eq!(c.base_vcpus, 2);
        assert_eq!(c.reduce_slots, 2);
        assert!((c.heartbeat_s - 3.0).abs() < 1e-12);
        assert_eq!(c.block_mb, 64.0);
        assert_eq!(c.replication, 3);
        c.validate().unwrap();
    }

    #[test]
    fn small_preset_valid() {
        SimConfig::small().validate().unwrap();
    }

    #[test]
    fn validation_catches_oversubscription() {
        let c = SimConfig {
            vms_per_pm: 3,
            cores_per_pm: 4,
            base_vcpus: 2,
            ..SimConfig::paper()
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn profile_names_roundtrip() {
        for p in PmProfile::ALL {
            assert_eq!(PmProfile::from_name(p.name()), Some(p));
        }
        assert_eq!(PmProfile::from_name("bogus"), None);
    }

    #[test]
    fn split2x_doubles_even_pm_cores() {
        let c = SimConfig {
            pm_profile: PmProfile::Split2x,
            ..SimConfig::paper()
        };
        c.validate().unwrap();
        assert_eq!(c.pm_cores(0), 8);
        assert_eq!(c.pm_cores(1), 4);
        assert_eq!(c.pm_speed(0), 1.0);
        // Slots don't grow with cores (VM layout fixed), so effective
        // capacity matches the uniform cluster.
        assert_eq!(c.effective_map_slots(), c.total_map_slots() as f64);
    }

    #[test]
    fn long_tail_slows_every_fourth_pm() {
        let c = SimConfig {
            pm_profile: PmProfile::LongTail,
            ..SimConfig::paper()
        };
        c.validate().unwrap();
        assert_eq!(c.pm_speed(3), 0.5);
        assert_eq!(c.pm_speed(0), 1.0);
        // 20 PMs: 5 stragglers at half speed.
        assert!((c.mean_pm_speed() - (15.0 + 2.5) / 20.0).abs() < 1e-12);
        assert!(c.effective_map_slots() < c.total_map_slots() as f64);
        assert!(c.effective_reduce_slots() < c.total_reduce_slots() as f64);
    }

    #[test]
    fn heterogeneous_validation_checks_every_pm() {
        // A PM profile cannot rescue an oversubscribed baseline: odd PMs
        // under split-2x still have only `cores_per_pm` cores.
        let c = SimConfig {
            vms_per_pm: 3,
            cores_per_pm: 4,
            base_vcpus: 2,
            pm_profile: PmProfile::Split2x,
            ..SimConfig::paper()
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn node_racks_follow_topology() {
        let c = SimConfig {
            topology: Topology::Racks(4),
            ..SimConfig::paper() // 20 PMs x 2 VMs
        };
        c.validate().unwrap();
        // PM i -> rack i % 4; nodes 2i, 2i+1 live on PM i.
        assert_eq!(c.node_rack(0), 0);
        assert_eq!(c.node_rack(1), 0);
        assert_eq!(c.node_rack(2), 1);
        assert_eq!(c.node_rack(9), 0); // PM 4 -> rack 0
        let racks = c.node_racks();
        assert_eq!(racks.len(), 40);
        // Equal racks: 10 nodes each.
        for r in 0..4u32 {
            assert_eq!(racks.iter().filter(|&&x| x == r).count(), 10);
        }
        // Flat: everything in rack 0.
        assert!(SimConfig::paper().node_racks().iter().all(|&r| r == 0));
    }

    #[test]
    fn validation_catches_bad_topology() {
        let c = SimConfig {
            topology: Topology::Racks(40),
            ..SimConfig::paper() // only 20 PMs
        };
        assert!(c.validate().is_err());
        let c = SimConfig {
            topology: Topology::Racks(4),
            ..SimConfig::paper()
        };
        c.validate().unwrap();
    }

    #[test]
    fn failure_presets_valid_and_distinct() {
        let mut labels = Vec::new();
        for name in FailureModel::NAMES {
            let fm = FailureModel::from_name(name).unwrap();
            fm.validate().unwrap();
            let c = SimConfig { failures: fm, ..SimConfig::paper() };
            c.validate().unwrap();
            labels.push(fm.label());
        }
        labels.sort();
        labels.dedup();
        assert_eq!(labels.len(), FailureModel::NAMES.len(), "labels must be injective");
        assert!(!FailureModel::off().enabled());
        assert!(FailureModel::stragglers().enabled());
        assert!(FailureModel::crash_high().crashes());
    }

    #[test]
    fn failure_validation_catches_bad_models() {
        let bad = FailureModel { pm_mtbf_s: 100.0, pm_repair_s: 0.0, ..FailureModel::off() };
        assert!(SimConfig { failures: bad, ..SimConfig::paper() }.validate().is_err());
        // The silent-zero-crashes footgun: MTBF set but the horizon left
        // at 0 would generate an empty timeline — rejected, not ignored.
        let bad = FailureModel {
            pm_mtbf_s: 100.0,
            pm_repair_s: 60.0,
            trace_horizon_s: 0.0,
            ..FailureModel::off()
        };
        assert!(SimConfig { failures: bad, ..SimConfig::paper() }.validate().is_err());
        let bad = FailureModel { straggler_prob: 1.5, ..FailureModel::off() };
        assert!(SimConfig { failures: bad, ..SimConfig::paper() }.validate().is_err());
        let bad = FailureModel { speculation: true, spec_slowdown: 0.5, ..FailureModel::off() };
        assert!(SimConfig { failures: bad, ..SimConfig::paper() }.validate().is_err());
        // Rack-correlated outages without a crash generator are vacuous.
        let bad = FailureModel { rack_correlated: true, ..FailureModel::off() };
        assert!(SimConfig { failures: bad, ..SimConfig::paper() }.validate().is_err());
        let custom = FailureModel { pm_mtbf_s: 777.0, ..FailureModel::crash_low() };
        assert!(custom.label().starts_with("custom-"));
        let custom = FailureModel { blacklist: true, ..FailureModel::rack_outage() };
        assert_eq!(custom.label(), "rack-outage-blacklist");
    }

    #[test]
    fn failure_trace_file_validation() {
        let mut c = SimConfig::paper();
        c.failure_trace = Some("f.trace".into());
        c.validate().unwrap();
        assert!(c.injects_crashes());
        assert!(!c.failures.crashes());
        // Policy flags compose with a replayed trace.
        c.failures.blacklist = true;
        c.validate().unwrap();
        // ... but a second crash source does not.
        c.failures = FailureModel::crash_low();
        assert!(c.validate().is_err());
        c.failures = FailureModel::off();
        c.failure_trace = Some(String::new());
        assert!(c.validate().is_err());
        // Trace replay keeps jobs alive for re-execution: no streaming.
        let mut c = SimConfig::paper();
        c.failure_trace = Some("f.trace".into());
        c.stream_metrics = true;
        assert!(c.validate().is_err());
    }

    #[test]
    fn failure_parse_list_follows_axis_convention() {
        let v = FailureModel::parse_list("off, crash-low-spec").unwrap();
        assert_eq!(v.len(), 2);
        assert_eq!(v[0], FailureModel::off());
        assert!(v[1].speculation);
        assert!(FailureModel::parse_list("off,nope").is_none());
    }

    #[test]
    fn fingerprint_stable_and_field_sensitive() {
        let a = SimConfig::paper();
        assert_eq!(a.fingerprint(), SimConfig::paper().fingerprint());
        let variants = [
            SimConfig { seed: 43, ..SimConfig::paper() },
            SimConfig { pms: 21, ..SimConfig::paper() },
            SimConfig { topology: Topology::Racks(4), ..SimConfig::paper() },
            SimConfig { failures: FailureModel::crash_low(), ..SimConfig::paper() },
            SimConfig { failures: FailureModel::rack_outage(), ..SimConfig::paper() },
            SimConfig {
                failures: FailureModel::rack_outage().with_blacklist(),
                ..SimConfig::paper()
            },
            SimConfig {
                failures: FailureModel::rack_outage().with_replan(),
                ..SimConfig::paper()
            },
            SimConfig { failure_trace: Some("f.trace".into()), ..SimConfig::paper() },
            SimConfig { stream_metrics: true, ..SimConfig::paper() },
            SimConfig { heartbeat_s: 2.0, ..SimConfig::paper() },
        ];
        for v in &variants {
            assert_ne!(a.fingerprint(), v.fingerprint());
        }
    }

    #[test]
    fn validation_catches_bad_replication() {
        let c = SimConfig {
            replication: 0,
            ..SimConfig::paper()
        };
        assert!(c.validate().is_err());
        let c = SimConfig {
            replication: 1000,
            ..SimConfig::paper()
        };
        assert!(c.validate().is_err());
    }
}
