//! Simulation / cluster / workload configuration.
//!
//! `SimConfig::paper()` reproduces the paper's testbed: 20 physical
//! machines, Xen-style vCPU hot-plug, 2 VMs per PM, each VM statically
//! configured with 2 map + 2 reduce slots, Hadoop 0.20.2-era HDFS defaults
//! (64 MB blocks, 3x replication), 3 s heartbeats.

mod parser;

pub use parser::{parse_config_str, ConfigError};

/// Execution mode for the MapReduce engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Timing-only: intermediate/output sizes come from the workload cost
    /// model. Used by benches and large sweeps.
    Synthetic,
    /// Tasks really execute their map/reduce functions over generated
    /// corpus bytes; sizes and record counts are measured, timing is still
    /// simulated. Used by the E2E example and correctness tests.
    Real,
}

/// Full simulation configuration.
#[derive(Clone, Debug)]
pub struct SimConfig {
    // ---- physical cluster ----
    /// Number of physical machines (paper: 20).
    pub pms: usize,
    /// Physical cores per machine available to VMs.
    pub cores_per_pm: u32,
    /// VMs per physical machine.
    pub vms_per_pm: usize,
    /// Base virtual CPUs per VM (= base map slots; paper: 2).
    pub base_vcpus: u32,
    /// Reduce slots per VM (static; reconfiguration never touches them).
    pub reduce_slots: u32,
    /// vCPU hot-plug latency (Assign/Release round-trip through the MM).
    pub hotplug_ms: u64,

    // ---- HDFS ----
    /// Block size in MB (Hadoop 0.20.2 default: 64).
    pub block_mb: f64,
    /// Replication factor (default 3).
    pub replication: usize,

    // ---- network / io model ----
    /// Per-node NIC bandwidth, MB/s (remote map input fetch, shuffle).
    pub net_mbps: f64,
    /// Local disk scan bandwidth, MB/s.
    pub disk_mbps: f64,

    // ---- MapReduce runtime ----
    /// TaskTracker heartbeat interval, seconds (paper: 3 s).
    pub heartbeat_s: f64,
    /// Multiplicative task-duration jitter std-dev (0 = deterministic).
    pub jitter_std: f64,
    /// Execution mode (synthetic timing vs real data).
    pub exec: ExecMode,

    // ---- scheduler knobs ----
    /// Delay-scheduling patience in heartbeats (Delay scheduler baseline).
    pub delay_heartbeats: u32,
    /// Predictor priors (seconds) used before the first task completes.
    pub prior_map_s: f64,
    pub prior_shuffle_s: f64,

    // ---- misc ----
    pub seed: u64,
}

impl SimConfig {
    /// The paper's evaluation testbed (§5).
    pub fn paper() -> Self {
        Self {
            pms: 20,
            cores_per_pm: 4,
            vms_per_pm: 2,
            base_vcpus: 2,
            reduce_slots: 2,
            hotplug_ms: 100,
            block_mb: 64.0,
            replication: 3,
            net_mbps: 10.0,
            disk_mbps: 400.0,
            heartbeat_s: 3.0,
            jitter_std: 0.08,
            exec: ExecMode::Synthetic,
            delay_heartbeats: 3,
            prior_map_s: 20.0,
            prior_shuffle_s: 0.05,
            seed: 42,
        }
    }

    /// A small fast cluster for unit tests and the quickstart example.
    pub fn small() -> Self {
        Self {
            pms: 4,
            vms_per_pm: 2,
            ..Self::paper()
        }
    }

    /// Total VMs (= HDFS DataNodes = TaskTrackers).
    pub fn nodes(&self) -> usize {
        self.pms * self.vms_per_pm
    }

    /// Total base map slots in the cluster.
    pub fn total_map_slots(&self) -> u32 {
        self.nodes() as u32 * self.base_vcpus
    }

    /// Total reduce slots in the cluster.
    pub fn total_reduce_slots(&self) -> u32 {
        self.nodes() as u32 * self.reduce_slots
    }

    /// Validate invariants; returns a human-readable complaint.
    pub fn validate(&self) -> Result<(), String> {
        if self.pms == 0 || self.vms_per_pm == 0 {
            return Err("cluster must have at least one PM and one VM".into());
        }
        if self.base_vcpus == 0 {
            return Err("VMs need at least one base vCPU".into());
        }
        if self.vms_per_pm as u32 * self.base_vcpus > self.cores_per_pm {
            return Err(format!(
                "oversubscribed PM: {} VMs x {} vCPUs > {} cores",
                self.vms_per_pm, self.base_vcpus, self.cores_per_pm
            ));
        }
        if self.replication == 0 || self.replication > self.nodes() {
            return Err(format!(
                "replication {} out of range 1..={}",
                self.replication,
                self.nodes()
            ));
        }
        if self.block_mb <= 0.0 || self.net_mbps <= 0.0 || self.disk_mbps <= 0.0 {
            return Err("block size and bandwidths must be positive".into());
        }
        if self.heartbeat_s <= 0.0 {
            return Err("heartbeat interval must be positive".into());
        }
        Ok(())
    }
}

impl Default for SimConfig {
    fn default() -> Self {
        Self::paper()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_preset_matches_testbed() {
        let c = SimConfig::paper();
        assert_eq!(c.pms, 20);
        assert_eq!(c.nodes(), 40);
        assert_eq!(c.base_vcpus, 2);
        assert_eq!(c.reduce_slots, 2);
        assert!((c.heartbeat_s - 3.0).abs() < 1e-12);
        assert_eq!(c.block_mb, 64.0);
        assert_eq!(c.replication, 3);
        c.validate().unwrap();
    }

    #[test]
    fn small_preset_valid() {
        SimConfig::small().validate().unwrap();
    }

    #[test]
    fn validation_catches_oversubscription() {
        let c = SimConfig {
            vms_per_pm: 3,
            cores_per_pm: 4,
            base_vcpus: 2,
            ..SimConfig::paper()
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn validation_catches_bad_replication() {
        let c = SimConfig {
            replication: 0,
            ..SimConfig::paper()
        };
        assert!(c.validate().is_err());
        let c = SimConfig {
            replication: 1000,
            ..SimConfig::paper()
        };
        assert!(c.validate().is_err());
    }
}
