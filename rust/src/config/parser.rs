//! `key = value` config-file parser (serde/toml unavailable offline).
//!
//! Accepts a flat subset of TOML: comments (`#`), blank lines and
//! `key = value` pairs; unknown keys are errors so typos don't silently
//! fall back to defaults.

use super::{ExecMode, FailureModel, PmProfile, SimConfig};
use crate::cluster::Topology;

/// Parse errors (hand-rolled Display/Error impls — `thiserror` is
/// unavailable offline).
#[derive(Debug)]
pub enum ConfigError {
    Syntax(usize, String),
    UnknownKey(usize, String),
    BadValue(usize, String, String),
    Invalid(String),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Syntax(line, got) => {
                write!(f, "line {line}: expected `key = value`, got {got:?}")
            }
            ConfigError::UnknownKey(line, key) => {
                write!(f, "line {line}: unknown key {key:?}")
            }
            ConfigError::BadValue(line, key, val) => {
                write!(f, "line {line}: bad value for {key}: {val:?}")
            }
            ConfigError::Invalid(msg) => write!(f, "invalid config: {msg}"),
        }
    }
}

impl std::error::Error for ConfigError {}

/// Parse `text` into a config, starting from `SimConfig::paper()` defaults.
pub fn parse_config_str(text: &str) -> Result<SimConfig, ConfigError> {
    let mut cfg = SimConfig::paper();
    for (i, raw) in text.lines().enumerate() {
        let lineno = i + 1;
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let Some((k, v)) = line.split_once('=') else {
            return Err(ConfigError::Syntax(lineno, raw.to_string()));
        };
        let (k, v) = (k.trim(), v.trim().trim_matches('"'));
        macro_rules! num {
            ($t:ty) => {
                v.parse::<$t>().map_err(|_| {
                    ConfigError::BadValue(lineno, k.to_string(), v.to_string())
                })?
            };
        }
        match k {
            "pms" => cfg.pms = num!(usize),
            "cores_per_pm" => cfg.cores_per_pm = num!(u32),
            "pm_profile" => {
                cfg.pm_profile = PmProfile::from_name(v).ok_or_else(|| {
                    ConfigError::BadValue(lineno, k.to_string(), v.to_string())
                })?
            }
            "topology" => {
                cfg.topology = Topology::from_label(v).ok_or_else(|| {
                    ConfigError::BadValue(lineno, k.to_string(), v.to_string())
                })?
            }
            "vms_per_pm" => cfg.vms_per_pm = num!(usize),
            "base_vcpus" => cfg.base_vcpus = num!(u32),
            "reduce_slots" => cfg.reduce_slots = num!(u32),
            "hotplug_ms" => cfg.hotplug_ms = num!(u64),
            "block_mb" => cfg.block_mb = num!(f64),
            "replication" => cfg.replication = num!(usize),
            "net_mbps" => cfg.net_mbps = num!(f64),
            "disk_mbps" => cfg.disk_mbps = num!(f64),
            "heartbeat_s" => cfg.heartbeat_s = num!(f64),
            "jitter_std" => cfg.jitter_std = num!(f64),
            "delay_heartbeats" => cfg.delay_heartbeats = num!(u32),
            "prior_map_s" => cfg.prior_map_s = num!(f64),
            "prior_shuffle_s" => cfg.prior_shuffle_s = num!(f64),
            "seed" => cfg.seed = num!(u64),
            "failures" => {
                cfg.failures = FailureModel::from_name(v).ok_or_else(|| {
                    ConfigError::BadValue(lineno, k.to_string(), v.to_string())
                })?
            }
            "exec" => {
                cfg.exec = match v {
                    "synthetic" => ExecMode::Synthetic,
                    "real" => ExecMode::Real,
                    _ => {
                        return Err(ConfigError::BadValue(
                            lineno,
                            k.to_string(),
                            v.to_string(),
                        ))
                    }
                }
            }
            _ => return Err(ConfigError::UnknownKey(lineno, k.to_string())),
        }
    }
    cfg.validate().map_err(ConfigError::Invalid)?;
    Ok(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let cfg = parse_config_str(
            r#"
            # testbed
            pms = 10
            vms_per_pm = 2
            block_mb = 32.0
            exec = "real"
            seed = 7
            "#,
        )
        .unwrap();
        assert_eq!(cfg.pms, 10);
        assert_eq!(cfg.block_mb, 32.0);
        assert_eq!(cfg.exec, ExecMode::Real);
        assert_eq!(cfg.seed, 7);
        // untouched keys keep paper defaults
        assert_eq!(cfg.replication, 3);
    }

    #[test]
    fn parses_pm_profile() {
        let cfg = parse_config_str("pm_profile = \"long-tail\"").unwrap();
        assert_eq!(cfg.pm_profile, PmProfile::LongTail);
        assert!(matches!(
            parse_config_str("pm_profile = \"warped\""),
            Err(ConfigError::BadValue(1, _, _))
        ));
    }

    #[test]
    fn parses_topology() {
        let cfg = parse_config_str("topology = \"racks-4\"").unwrap();
        assert_eq!(cfg.topology, Topology::Racks(4));
        let cfg = parse_config_str("topology = \"fat-tree-2\"").unwrap();
        assert_eq!(cfg.topology, Topology::FatTree(2));
        assert!(matches!(
            parse_config_str("topology = \"hypercube\""),
            Err(ConfigError::BadValue(1, _, _))
        ));
        // Validation still applies to the parsed combination.
        assert!(matches!(
            parse_config_str("pms = 2\ntopology = \"racks-4\""),
            Err(ConfigError::Invalid(_))
        ));
    }

    #[test]
    fn parses_failures() {
        let cfg = parse_config_str("failures = \"crash-low-spec\"").unwrap();
        assert_eq!(cfg.failures, FailureModel::crash_low().with_speculation());
        let cfg = parse_config_str("pms = 5").unwrap();
        assert_eq!(cfg.failures, FailureModel::off());
        assert!(matches!(
            parse_config_str("failures = \"meteor-strike\""),
            Err(ConfigError::BadValue(1, _, _))
        ));
    }

    #[test]
    fn rejects_unknown_key() {
        assert!(matches!(
            parse_config_str("bogus = 1"),
            Err(ConfigError::UnknownKey(1, _))
        ));
    }

    #[test]
    fn rejects_bad_value() {
        assert!(matches!(
            parse_config_str("pms = banana"),
            Err(ConfigError::BadValue(1, _, _))
        ));
    }

    #[test]
    fn rejects_syntax() {
        assert!(matches!(
            parse_config_str("just words"),
            Err(ConfigError::Syntax(1, _))
        ));
    }

    #[test]
    fn rejects_invalid_combination() {
        assert!(matches!(
            parse_config_str("vms_per_pm = 9"),
            Err(ConfigError::Invalid(_))
        ));
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let cfg = parse_config_str("\n# only comments\n\npms = 5 # inline\n").unwrap();
        assert_eq!(cfg.pms, 5);
    }
}
