//! Resource Reconfigurator (paper §4.1, Algorithm 1).
//!
//! Per physical machine a **Machine Manager** keeps two queues:
//! * the **Release Queue (RQ)** — VMs that registered a free core;
//! * the **Assign Queue (AQ)** — VMs that need an extra core to run a
//!   pending *local* map task.
//!
//! "As soon as both the AQ and RQ of the same system has at least an
//! entry, VM reconfigurations occur in the system: releasing a core from a
//! VM, and assigning a core to another VM in the same system." The
//! **Configuration Manager** (one per virtual cluster) drives the match
//! and reports the hot-plug pairs; the coordinator applies them to the
//! [`crate::cluster::Cluster`] after the configured hot-plug latency.

use std::collections::VecDeque;

use crate::cluster::{Cluster, NodeId, PmId};
use crate::mapreduce::{dec_task_ref, enc_task_ref, TaskRef};
use crate::util::codec::{Dec, Enc};

/// A granted reconfiguration: move one core `from` -> `to` (same PM) and
/// then launch `task` on `to`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Hotplug {
    pub pm: PmId,
    pub from: NodeId,
    pub to: NodeId,
    pub task: TaskRef,
}

/// Per-PM queues (the paper's MM state).
#[derive(Clone, Debug, Default)]
struct MachineManager {
    assign_q: VecDeque<(NodeId, TaskRef)>,
    release_q: VecDeque<NodeId>,
}

/// The Configuration Manager of one virtual cluster.
#[derive(Clone, Debug)]
pub struct ConfigManager {
    mms: Vec<MachineManager>,
    /// Total hot-plugs granted (metrics).
    pub hotplugs: u64,
}

impl ConfigManager {
    pub fn new(num_pms: usize) -> Self {
        Self {
            mms: vec![MachineManager::default(); num_pms],
            hotplugs: 0,
        }
    }

    /// Alg. 1 line 11: register a pending local task needing a core on
    /// `vm`. Duplicate registrations for the same task are the caller's
    /// bug (checked in debug).
    pub fn enqueue_assign(&mut self, pm: PmId, vm: NodeId, task: TaskRef) {
        let mm = &mut self.mms[pm.idx()];
        debug_assert!(
            !mm.assign_q.iter().any(|(_, t)| *t == task),
            "task {task:?} double-registered in AQ"
        );
        mm.assign_q.push_back((vm, task));
    }

    /// Alg. 1 line 12: register a free core on `vm`. Deduplicated: a VM's
    /// free core appears at most once (heartbeats would otherwise inflate
    /// the queue every 3 s while nothing matches).
    pub fn enqueue_release(&mut self, pm: PmId, vm: NodeId) {
        let mm = &mut self.mms[pm.idx()];
        if !mm.release_q.contains(&vm) {
            mm.release_q.push_back(vm);
        }
    }

    /// Queue depths used by the Alg. 1 node-choice scoring (and exported
    /// to the XLA placement kernel).
    pub fn rq_depth(&self, pm: PmId) -> usize {
        self.mms[pm.idx()].release_q.len()
    }

    pub fn aq_depth(&self, pm: PmId) -> usize {
        self.mms[pm.idx()].aq_len()
    }

    /// Match AQ/RQ entries on every PM against current cluster state,
    /// returning the hot-plugs to apply. Stale entries (releasing VM no
    /// longer has a free core; e.g. a reduce task took it) are dropped —
    /// the VM re-registers on a later heartbeat.
    ///
    /// A release from VM X matched with an assign *to VM X* is satisfied
    /// without any hot-plug (the core never leaves the VM); this happens
    /// when a slot freed between registration and matching.
    pub fn match_queues(&mut self, cluster: &Cluster) -> Vec<Hotplug> {
        let mut out = Vec::new();
        for (pm_idx, mm) in self.mms.iter_mut().enumerate() {
            let pm = PmId(pm_idx as u32);
            // A dead PM's MM is unreachable; its queues were purged at
            // crash time and anything enqueued since waits for recovery.
            if !cluster.pm_alive(pm) {
                continue;
            }
            while !mm.assign_q.is_empty() && !mm.release_q.is_empty() {
                // Drop stale releases first.
                let Some(&from) = mm.release_q.front() else { break };
                if !cluster.vm(from).can_release_core() {
                    mm.release_q.pop_front();
                    continue;
                }
                let (to, task) = mm.assign_q.pop_front().unwrap();
                mm.release_q.pop_front();
                self.hotplugs += 1;
                out.push(Hotplug { pm, from, to, task });
            }
        }
        out
    }

    /// Forget any queued state for `task` (job finished it elsewhere or it
    /// was cancelled).
    pub fn cancel_task(&mut self, task: TaskRef) {
        for mm in &mut self.mms {
            mm.assign_q.retain(|(_, t)| *t != task);
        }
    }

    /// A PM crashed: drop its MM's queues wholesale (the MM dies with the
    /// machine). Returns the tasks whose queued assigns were dropped, so
    /// the coordinator can put them back to Pending.
    pub fn purge_pm(&mut self, pm: PmId) -> Vec<TaskRef> {
        let mm = &mut self.mms[pm.idx()];
        mm.release_q.clear();
        mm.assign_q.drain(..).map(|(_, t)| t).collect()
    }

    /// Total queued assigns across the cluster (diagnostics).
    pub fn total_pending_assigns(&self) -> usize {
        self.mms.iter().map(|m| m.aq_len()).sum()
    }

    /// Snapshot encoding: per-MM queues in PM order (queue order matters —
    /// matching is FIFO) plus the grant counter.
    pub(crate) fn encode_state(&self, e: &mut Enc) {
        e.usize(self.mms.len());
        for mm in &self.mms {
            e.usize(mm.assign_q.len());
            for &(vm, task) in &mm.assign_q {
                e.u32(vm.0);
                enc_task_ref(e, task);
            }
            e.usize(mm.release_q.len());
            for &vm in &mm.release_q {
                e.u32(vm.0);
            }
        }
        e.u64(self.hotplugs);
    }

    /// Rebuild from [`Self::encode_state`] bytes.
    pub(crate) fn decode_state(d: &mut Dec) -> Result<Self, String> {
        let n_mms = d.len(16)?;
        let mut mms = Vec::with_capacity(n_mms);
        for _ in 0..n_mms {
            let mut mm = MachineManager::default();
            let n_aq = d.len(13)?;
            for _ in 0..n_aq {
                let vm = NodeId(d.u32()?);
                let task = dec_task_ref(d)?;
                mm.assign_q.push_back((vm, task));
            }
            let n_rq = d.len(4)?;
            for _ in 0..n_rq {
                mm.release_q.push_back(NodeId(d.u32()?));
            }
            mms.push(mm);
        }
        let hotplugs = d.u64()?;
        Ok(Self { mms, hotplugs })
    }
}

impl MachineManager {
    fn aq_len(&self) -> usize {
        self.assign_q.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::mapreduce::JobId;

    fn setup() -> (Cluster, ConfigManager) {
        let cfg = SimConfig::small(); // 4 PMs x 2 VMs x 2 vCPUs
        let c = Cluster::build(&cfg);
        let cm = ConfigManager::new(cfg.pms);
        (c, cm)
    }

    fn task(n: u32) -> TaskRef {
        TaskRef::map(JobId(0), n)
    }

    #[test]
    fn match_requires_both_queues() {
        let (c, mut cm) = setup();
        cm.enqueue_assign(PmId(0), NodeId(1), task(0));
        assert!(cm.match_queues(&c).is_empty(), "no release yet");
        cm.enqueue_release(PmId(0), NodeId(0));
        let grants = cm.match_queues(&c);
        assert_eq!(
            grants,
            vec![Hotplug {
                pm: PmId(0),
                from: NodeId(0),
                to: NodeId(1),
                task: task(0),
            }]
        );
        assert_eq!(cm.hotplugs, 1);
    }

    #[test]
    fn queues_are_per_pm() {
        let (c, mut cm) = setup();
        cm.enqueue_assign(PmId(0), NodeId(1), task(0));
        cm.enqueue_release(PmId(1), NodeId(2)); // different PM
        assert!(
            cm.match_queues(&c).is_empty(),
            "cross-PM transfer must never match (paper: CPU cannot cross \
             the physical boundary)"
        );
    }

    #[test]
    fn stale_release_dropped() {
        let (mut c, mut cm) = setup();
        cm.enqueue_release(PmId(0), NodeId(0));
        // Node 0's cores all become busy before matching.
        c.vm_mut(NodeId(0)).busy_map = 2;
        cm.enqueue_assign(PmId(0), NodeId(1), task(1));
        let grants = cm.match_queues(&c);
        assert!(grants.is_empty());
        assert_eq!(cm.rq_depth(PmId(0)), 0, "stale entry consumed");
        assert_eq!(cm.aq_depth(PmId(0)), 1, "assign still waiting");
    }

    #[test]
    fn fifo_matching_order() {
        let (c, mut cm) = setup();
        cm.enqueue_assign(PmId(0), NodeId(1), task(0));
        cm.enqueue_assign(PmId(0), NodeId(1), task(1));
        cm.enqueue_release(PmId(0), NodeId(0));
        let grants = cm.match_queues(&c);
        assert_eq!(grants.len(), 1);
        assert_eq!(grants[0].task, task(0), "FIFO: first registered first");
    }

    #[test]
    fn cancel_removes_assign() {
        let (c, mut cm) = setup();
        cm.enqueue_assign(PmId(0), NodeId(1), task(0));
        cm.cancel_task(task(0));
        cm.enqueue_release(PmId(0), NodeId(0));
        assert!(cm.match_queues(&c).is_empty());
        assert_eq!(cm.total_pending_assigns(), 0);
    }

    #[test]
    fn multiple_pms_match_independently() {
        let (c, mut cm) = setup();
        cm.enqueue_assign(PmId(0), NodeId(1), task(0));
        cm.enqueue_release(PmId(0), NodeId(0));
        cm.enqueue_assign(PmId(2), NodeId(5), task(1));
        cm.enqueue_release(PmId(2), NodeId(4));
        let grants = cm.match_queues(&c);
        assert_eq!(grants.len(), 2);
        let pms: Vec<u32> = grants.iter().map(|g| g.pm.0).collect();
        assert_eq!(pms, vec![0, 2]);
    }

    #[test]
    fn purge_pm_drops_queues_and_returns_tasks() {
        let (mut c, mut cm) = setup();
        cm.enqueue_assign(PmId(0), NodeId(1), task(0));
        cm.enqueue_assign(PmId(0), NodeId(0), task(1));
        cm.enqueue_release(PmId(0), NodeId(0));
        cm.enqueue_assign(PmId(1), NodeId(3), task(2)); // other PM untouched
        let dropped = cm.purge_pm(PmId(0));
        assert_eq!(dropped, vec![task(0), task(1)]);
        assert_eq!(cm.aq_depth(PmId(0)), 0);
        assert_eq!(cm.rq_depth(PmId(0)), 0);
        assert_eq!(cm.aq_depth(PmId(1)), 1);
        // Dead PMs never match even with both queues filled.
        cm.enqueue_assign(PmId(0), NodeId(1), task(3));
        cm.enqueue_release(PmId(0), NodeId(0));
        c.crash_pm(PmId(0));
        assert!(cm.match_queues(&c).is_empty());
        c.recover_pm(PmId(0));
        assert_eq!(cm.match_queues(&c).len(), 1);
    }

    #[test]
    fn grant_applies_to_cluster() {
        let (mut c, mut cm) = setup();
        cm.enqueue_assign(PmId(0), NodeId(1), task(0));
        cm.enqueue_release(PmId(0), NodeId(0));
        for g in cm.match_queues(&c) {
            c.transfer_core(g.from, g.to).unwrap();
        }
        assert_eq!(c.vm(NodeId(0)).vcpus, 1);
        assert_eq!(c.vm(NodeId(1)).vcpus, 3);
        c.check_invariants().unwrap();
    }
}
