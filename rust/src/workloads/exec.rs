//! Real map/reduce implementations for the five workloads.
//!
//! In `ExecMode::Real` the engine runs these over generated corpus blocks:
//! map emits (key, value) pairs, the engine hash-partitions them across
//! reducers, and reduce folds each key group. The E2E example checks the
//! distributed output equals a serial single-pass reference.

use super::corpus::Block;
use super::JobType;

/// One intermediate key-value pair.
pub type Pair = (String, String);

/// Run the map function of `job_type` over one input block.
pub fn run_map(job_type: JobType, block: &Block, pattern: &str) -> Vec<Pair> {
    match job_type {
        JobType::WordCount => block
            .lines
            .iter()
            .flat_map(|l| l.split_whitespace())
            .map(|w| (w.to_string(), "1".to_string()))
            .collect(),
        JobType::Sort => block
            .lines
            .iter()
            .map(|l| {
                let (k, v) = l.split_once('\t').unwrap_or((l.as_str(), ""));
                (k.to_string(), v.to_string())
            })
            .collect(),
        JobType::Grep => block
            .lines
            .iter()
            .flat_map(|l| l.split_whitespace())
            .filter(|w| *w == pattern)
            .map(|w| (w.to_string(), "1".to_string()))
            .collect(),
        JobType::PermutationGenerator => block
            .lines
            .iter()
            .flat_map(|s| permutations(s))
            .map(|p| (p, "1".to_string()))
            .collect(),
        JobType::InvertedIndex => {
            let doc = format!("doc{}", block.doc_id);
            block
                .lines
                .iter()
                .flat_map(|l| l.split_whitespace())
                .map(|w| (w.to_string(), doc.clone()))
                .collect()
        }
    }
}

/// Hash-partition pairs across `reducers` (Hadoop's default partitioner:
/// `hash(key) % R`).
pub fn partition(pairs: Vec<Pair>, reducers: u32) -> Vec<Vec<Pair>> {
    let mut parts = vec![Vec::new(); reducers as usize];
    partition_into(pairs, &mut parts);
    parts
}

/// Partition directly into pre-existing buckets (the exec engine's spill
/// path — avoids re-materialising the full pair vector per map task).
pub fn partition_into(pairs: Vec<Pair>, parts: &mut [Vec<Pair>]) {
    let r = parts.len() as u64;
    debug_assert!(r > 0);
    for (k, v) in pairs {
        let h = fxhash(k.as_bytes());
        parts[(h % r) as usize].push((k, v));
    }
}

/// Run the reduce function over one partition (sorted by key, grouped —
/// the "sort" step of the reduce task).
///
/// Implementation note: unstable sort + linear group scan; the obvious
/// BTreeMap grouping allocates a node per key and was the hot spot of the
/// real-exec engine (EXPERIMENTS.md §Perf).
pub fn run_reduce(job_type: JobType, mut pairs: Vec<Pair>) -> Vec<Pair> {
    pairs.sort_unstable(); // copy+sort phase
    let mut out: Vec<Pair> = Vec::new();
    let mut i = 0;
    while i < pairs.len() {
        let mut j = i + 1;
        while j < pairs.len() && pairs[j].0 == pairs[i].0 {
            j += 1;
        }
        let group = &pairs[i..j];
        let val = match job_type {
            JobType::WordCount | JobType::Grep | JobType::PermutationGenerator => {
                group.len().to_string()
            }
            // Identity reduce: first value of the (sorted) key group.
            JobType::Sort => group[0].1.clone(),
            JobType::InvertedIndex => {
                // group is sorted by (key, value); dedup doc names inline.
                let mut docs: Vec<&str> = Vec::with_capacity(group.len());
                for (_, d) in group {
                    if docs.last() != Some(&d.as_str()) {
                        docs.push(d);
                    }
                }
                docs.join(",")
            }
        };
        out.push((pairs[i].0.clone(), val));
        i = j;
    }
    out
}

/// Serial reference: map all blocks, single partition, reduce — the
/// ground truth the distributed engine must reproduce.
pub fn serial_reference(
    job_type: JobType,
    blocks: &[Block],
    pattern: &str,
) -> Vec<Pair> {
    let pairs: Vec<Pair> = blocks
        .iter()
        .flat_map(|b| run_map(job_type, b, pattern))
        .collect();
    run_reduce(job_type, pairs)
}

/// All permutations of a short string (bounded: inputs are <= 5 chars).
fn permutations(s: &str) -> Vec<String> {
    let chars: Vec<char> = s.chars().collect();
    if chars.len() > 5 {
        // Guard against factorial blow-up on malformed input.
        return vec![s.to_string()];
    }
    let mut out = Vec::new();
    let mut cs = chars;
    heap_permute(&mut cs, &mut out);
    out
}

fn heap_permute(cs: &mut Vec<char>, out: &mut Vec<String>) {
    let n = cs.len();
    let mut c = vec![0usize; n];
    out.push(cs.iter().collect());
    let mut i = 0;
    while i < n {
        if c[i] < i {
            if i % 2 == 0 {
                cs.swap(0, i);
            } else {
                cs.swap(c[i], i);
            }
            out.push(cs.iter().collect());
            c[i] += 1;
            i = 0;
        } else {
            c[i] = 0;
            i += 1;
        }
    }
}

/// FxHash-style multiply hash (stable across runs, unlike `DefaultHasher`
/// which is seeded per-process — determinism matters here).
#[inline]
pub fn fxhash(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(0x0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::corpus;
    use crate::util::Rng;

    #[test]
    fn wordcount_counts() {
        let block = Block {
            lines: vec!["a b a".into(), "b a".into()],
            doc_id: 0,
        };
        let out = run_reduce(
            JobType::WordCount,
            run_map(JobType::WordCount, &block, ""),
        );
        assert_eq!(
            out,
            vec![("a".into(), "3".into()), ("b".into(), "2".into())]
        );
    }

    #[test]
    fn grep_filters() {
        let block = Block {
            lines: vec!["x target y".into(), "target".into()],
            doc_id: 0,
        };
        let out = run_reduce(JobType::Grep, run_map(JobType::Grep, &block, "target"));
        assert_eq!(out, vec![("target".into(), "2".into())]);
    }

    #[test]
    fn sort_orders_keys() {
        let block = Block {
            lines: vec!["0000000009\tb".into(), "0000000001\ta".into()],
            doc_id: 0,
        };
        let out = run_reduce(JobType::Sort, run_map(JobType::Sort, &block, ""));
        let keys: Vec<&str> = out.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["0000000001", "0000000009"]);
    }

    #[test]
    fn inverted_index_lists_docs() {
        let b0 = Block {
            lines: vec!["alpha beta".into()],
            doc_id: 0,
        };
        let b1 = Block {
            lines: vec!["alpha".into()],
            doc_id: 1,
        };
        let pairs: Vec<Pair> = run_map(JobType::InvertedIndex, &b0, "")
            .into_iter()
            .chain(run_map(JobType::InvertedIndex, &b1, ""))
            .collect();
        let out = run_reduce(JobType::InvertedIndex, pairs);
        assert_eq!(
            out,
            vec![
                ("alpha".into(), "doc0,doc1".into()),
                ("beta".into(), "doc0".into()),
            ]
        );
    }

    #[test]
    fn permutations_complete() {
        let ps = permutations("abc");
        assert_eq!(ps.len(), 6);
        let mut sorted = ps.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), 6);
    }

    #[test]
    fn permutation_map_blows_up() {
        // selectivity >> 1: n chars -> n! strings.
        let block = Block {
            lines: vec!["abcd".into()],
            doc_id: 0,
        };
        let out = run_map(JobType::PermutationGenerator, &block, "");
        assert_eq!(out.len(), 24);
    }

    #[test]
    fn partition_covers_and_is_stable() {
        let pairs: Vec<Pair> = (0..100)
            .map(|i| (format!("k{i}"), "v".to_string()))
            .collect();
        let parts = partition(pairs.clone(), 4);
        assert_eq!(parts.iter().map(|p| p.len()).sum::<usize>(), 100);
        let parts2 = partition(pairs, 4);
        for (a, b) in parts.iter().zip(&parts2) {
            assert_eq!(a, b, "partitioner must be deterministic");
        }
    }

    #[test]
    fn distributed_equals_serial_all_types() {
        // The engine-level invariant, checked at workload level here:
        // partition + per-partition reduce == serial reference.
        let mut rng = Rng::new(5);
        for t in crate::workloads::ALL_JOB_TYPES {
            let blocks: Vec<Block> = (0..3)
                .map(|i| match t {
                    JobType::Sort => corpus::record_block(512, i, &mut rng),
                    JobType::PermutationGenerator => {
                        corpus::string_block(8, 3, i, &mut rng)
                    }
                    _ => corpus::text_block(512, i, &mut rng),
                })
                .collect();
            let pattern = "the";
            let serial = serial_reference(t, &blocks, pattern);
            let all_pairs: Vec<Pair> = blocks
                .iter()
                .flat_map(|b| run_map(t, b, pattern))
                .collect();
            let mut distributed: Vec<Pair> = partition(all_pairs, 3)
                .into_iter()
                .flat_map(|part| run_reduce(t, part))
                .collect();
            distributed.sort();
            assert_eq!(distributed, serial, "{t}");
        }
    }

    #[test]
    fn fxhash_stable() {
        assert_eq!(fxhash(b"abc"), fxhash(b"abc"));
        assert_ne!(fxhash(b"abc"), fxhash(b"abd"));
    }
}
