//! Synthetic text-corpus generation for `ExecMode::Real`.
//!
//! Generates deterministic pseudo-natural text (Zipf-distributed words
//! from a fixed vocabulary) and fixed-width random records for Sort, so
//! the real map/reduce implementations have honest bytes to chew on.

use crate::util::Rng;

/// A fixed vocabulary; frequencies follow Zipf(s=1) so word-count outputs
/// have realistic skew.
const VOCAB: &[&str] = &[
    "the", "of", "and", "to", "in", "a", "is", "that", "for", "it",
    "data", "cloud", "map", "reduce", "task", "job", "node", "slot",
    "virtual", "machine", "deadline", "locality", "schedule", "cluster",
    "hadoop", "block", "replica", "shuffle", "sort", "merge", "phase",
    "system", "time", "core", "queue", "assign", "release", "predict",
];

/// Deterministic Zipf sampler over `VOCAB`.
pub struct ZipfWords {
    cdf: Vec<f64>,
}

impl ZipfWords {
    pub fn new() -> Self {
        Self {
            cdf: zipf_cdf(VOCAB.len()),
        }
    }

    pub fn sample(&self, rng: &mut Rng) -> &'static str {
        let u = rng.f64();
        let i = self.cdf.partition_point(|&c| c < u);
        VOCAB[i.min(VOCAB.len() - 1)]
    }
}

impl Default for ZipfWords {
    fn default() -> Self {
        Self::new()
    }
}

/// Normalized Zipf(s=1) CDF over ranks `1..=n`: `cdf[i]` is the
/// probability of drawing a rank `<= i + 1`. Kept as its own function
/// (weights and CDF are separate values, not one vector mutated in place)
/// so the construction is checkable in isolation: the result is strictly
/// increasing and ends at 1.0 up to float rounding.
fn zipf_cdf(n: usize) -> Vec<f64> {
    debug_assert!(n > 0);
    let harmonic: f64 = (1..=n).map(|rank| 1.0 / rank as f64).sum();
    let mut cdf = Vec::with_capacity(n);
    let mut acc = 0.0;
    for rank in 1..=n {
        acc += 1.0 / rank as f64 / harmonic;
        cdf.push(acc);
    }
    cdf
}

/// One generated input block (the bytes a map task reads).
#[derive(Clone, Debug)]
pub struct Block {
    /// Lines of text (or records for Sort).
    pub lines: Vec<String>,
    /// Stable document id (for inverted index).
    pub doc_id: u32,
}

/// Generate a text block of roughly `size_bytes` Zipf words.
pub fn text_block(size_bytes: usize, doc_id: u32, rng: &mut Rng) -> Block {
    let zipf = ZipfWords::new();
    let mut lines = Vec::new();
    let mut total = 0usize;
    while total < size_bytes {
        let words_in_line = 6 + rng.below(10) as usize;
        let mut line = String::with_capacity(words_in_line * 6);
        for w in 0..words_in_line {
            if w > 0 {
                line.push(' ');
            }
            line.push_str(zipf.sample(rng));
        }
        total += line.len() + 1;
        lines.push(line);
    }
    Block { lines, doc_id }
}

/// Generate fixed-width sortable records ("<10-digit key>\t<payload>").
pub fn record_block(size_bytes: usize, doc_id: u32, rng: &mut Rng) -> Block {
    let mut lines = Vec::new();
    let mut total = 0usize;
    while total < size_bytes {
        let key = rng.below(10_000_000_000);
        let line = format!("{key:010}\tv{:08x}", rng.next_u64() as u32);
        total += line.len() + 1;
        lines.push(line);
    }
    Block { lines, doc_id }
}

/// Short random lowercase strings for the permutation generator
/// (factorial blow-up bounded by the tiny string length).
pub fn string_block(n_strings: usize, len: usize, doc_id: u32, rng: &mut Rng) -> Block {
    let mut lines = Vec::with_capacity(n_strings);
    for _ in 0..n_strings {
        let s: String = (0..len)
            .map(|_| (b'a' + rng.below(26) as u8) as char)
            .collect();
        lines.push(s);
    }
    Block { lines, doc_id }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn text_block_reaches_size() {
        let mut rng = Rng::new(1);
        let b = text_block(4096, 0, &mut rng);
        let bytes: usize = b.lines.iter().map(|l| l.len() + 1).sum();
        assert!(bytes >= 4096);
        assert!(bytes < 4096 + 200, "overshoot bounded by one line");
    }

    #[test]
    fn text_block_deterministic() {
        let a = text_block(1024, 0, &mut Rng::new(9));
        let b = text_block(1024, 0, &mut Rng::new(9));
        assert_eq!(a.lines, b.lines);
    }

    #[test]
    fn zipf_cdf_monotone_and_normalized() {
        for n in [1usize, 2, 10, VOCAB.len()] {
            let cdf = zipf_cdf(n);
            assert_eq!(cdf.len(), n);
            assert!(cdf[0] > 0.0);
            for w in cdf.windows(2) {
                assert!(w[1] > w[0], "CDF must be strictly increasing: {cdf:?}");
            }
            let last = *cdf.last().unwrap();
            assert!((last - 1.0).abs() < 1e-9, "CDF must end at 1.0, got {last}");
        }
    }

    #[test]
    fn zipf_skew() {
        let zipf = ZipfWords::new();
        let mut rng = Rng::new(2);
        let mut the_count = 0;
        let mut queue_count = 0;
        for _ in 0..20_000 {
            match zipf.sample(&mut rng) {
                "the" => the_count += 1,
                "queue" => queue_count += 1,
                _ => {}
            }
        }
        assert!(
            the_count > queue_count * 5,
            "rank-1 word must dominate rank-35: {the_count} vs {queue_count}"
        );
    }

    #[test]
    fn record_block_shape() {
        let mut rng = Rng::new(3);
        let b = record_block(2048, 0, &mut rng);
        for l in &b.lines {
            let (k, _v) = l.split_once('\t').expect("tab-separated");
            assert_eq!(k.len(), 10);
            assert!(k.chars().all(|c| c.is_ascii_digit()));
        }
    }

    #[test]
    fn string_block_shape() {
        let mut rng = Rng::new(4);
        let b = string_block(20, 4, 7, &mut rng);
        assert_eq!(b.lines.len(), 20);
        assert!(b.lines.iter().all(|s| s.len() == 4));
        assert_eq!(b.doc_id, 7);
    }
}
