//! Job-trace generation: the paper's experiment mixes, Poisson traces for
//! the throughput experiments, and the sweep harness's arrival-rate axis
//! (rate-multiplied Poisson plus a bursty regime).

use super::{JobSpec, JobType, ALL_JOB_TYPES};
use crate::config::{FailureModel, SimConfig};
use crate::util::rng::mix64;
use crate::util::Rng;

/// Jobs per burst under [`ArrivalRegime::Burst`].
const BURST_SIZE: usize = 5;
/// Intra-burst gaps are this fraction of the steady mean gap (bursts are
/// near-simultaneous submissions; the inter-burst gap re-balances so the
/// long-run arrival rate still matches the λ multiplier).
const BURST_INTRA_FRACTION: f64 = 0.05;

/// Shape of the arrival process.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArrivalRegime {
    /// Plain Poisson arrivals (exponential inter-arrival gaps).
    Steady,
    /// Arrivals come in bursts of `BURST_SIZE` (5) near-simultaneous
    /// jobs separated by long gaps, at the same long-run rate — the
    /// regime where slot contention (and the deadline scheduler's
    /// advantage) peaks.
    Burst,
}

/// One point on the sweep harness's arrival-rate axis: a Poisson λ
/// multiplier plus a regime.
///
/// `rate` multiplies the base arrival rate, so `rate = 2.0` halves the
/// mean inter-arrival gap. Labels are stable artifact keys: `steady`,
/// `steady-x2`, `burst`, `burst-x1.5`, ...
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Arrival {
    /// λ multiplier on the base arrival rate (must be > 0).
    pub rate: f64,
    pub regime: ArrivalRegime,
}

impl Arrival {
    /// The default axis point: plain Poisson at the base rate.
    pub const STEADY: Arrival = Arrival {
        rate: 1.0,
        regime: ArrivalRegime::Steady,
    };

    pub fn steady(rate: f64) -> Arrival {
        Arrival {
            rate,
            regime: ArrivalRegime::Steady,
        }
    }

    pub fn burst(rate: f64) -> Arrival {
        Arrival {
            rate,
            regime: ArrivalRegime::Burst,
        }
    }

    /// Stable label used in artifacts, CSV keys and the CLI.
    pub fn label(&self) -> String {
        let base = match self.regime {
            ArrivalRegime::Steady => "steady",
            ArrivalRegime::Burst => "burst",
        };
        if (self.rate - 1.0).abs() < 1e-12 {
            base.to_string()
        } else {
            format!("{base}-x{}", self.rate)
        }
    }

    /// Parse a label produced by [`Arrival::label`] (`steady`, `burst`,
    /// `steady-x2`, `burst-x1.5`).
    pub fn from_label(s: &str) -> Option<Arrival> {
        let (base, rate) = match s.split_once("-x") {
            Some((b, r)) => (b, r.parse::<f64>().ok()?),
            None => (s, 1.0),
        };
        if !(rate > 0.0 && rate.is_finite()) {
            return None;
        }
        match base {
            "steady" => Some(Arrival::steady(rate)),
            "burst" => Some(Arrival::burst(rate)),
            _ => None,
        }
    }

    /// Draw `n` non-decreasing submission times with base mean gap
    /// `base_gap_s` (seconds). Deterministic given `rng`.
    pub fn times(&self, n: usize, base_gap_s: f64, rng: &mut Rng) -> Vec<f64> {
        let gap = base_gap_s / self.rate;
        let mut out = Vec::with_capacity(n);
        let mut t = 0.0f64;
        for i in 0..n {
            if i > 0 {
                let mean = match self.regime {
                    ArrivalRegime::Steady => gap,
                    ArrivalRegime::Burst => {
                        if i % BURST_SIZE == 0 {
                            // Inter-burst gap sized so the long-run rate
                            // matches λ: BURST_SIZE jobs per
                            // BURST_SIZE * gap expected seconds.
                            gap * (BURST_SIZE as f64
                                - BURST_INTRA_FRACTION * (BURST_SIZE - 1) as f64)
                        } else {
                            gap * BURST_INTRA_FRACTION
                        }
                    }
                };
                t += rng.exp(mean);
            }
            out.push(t);
        }
        out
    }
}

/// An ordered set of job submissions.
#[derive(Clone, Debug, Default)]
pub struct JobTrace {
    pub jobs: Vec<JobSpec>,
}

impl JobTrace {
    pub fn new(jobs: Vec<JobSpec>) -> Self {
        let mut t = Self { jobs };
        t.jobs
            .sort_by(|a, b| a.submit_s.partial_cmp(&b.submit_s).unwrap());
        t
    }

    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// Figure 2 experiment: every workload at every input size, submitted
    /// together (the paper runs "the same set of experiments with the same
    /// input data" under both schedulers). `scale` shrinks the paper's GB
    /// sizes to simulator-friendly MB while keeping proportions.
    pub fn fig2_grid(scale_gb_to_mb: f64) -> Self {
        Self::fig2_grid_on(&SimConfig::paper(), scale_gb_to_mb)
    }

    /// Like [`JobTrace::fig2_grid`] with explicit cluster config (used to
    /// derive sane completion-time goals — the proposed scheduler is a
    /// deadline scheduler, so every job carries a goal as in §5).
    pub fn fig2_grid_on(cfg: &SimConfig, scale_gb_to_mb: f64) -> Self {
        let sizes_gb = [2.0, 4.0, 6.0, 8.0, 10.0];
        let mut jobs = Vec::new();
        for t in ALL_JOB_TYPES {
            for gb in sizes_gb {
                let mut spec = JobSpec::new(t, gb * scale_gb_to_mb);
                let d = ideal_completion_estimate(cfg, &spec) * 2.5;
                spec = spec.with_deadline(d);
                jobs.push(spec);
            }
        }
        Self::new(jobs)
    }

    /// Table 2 experiment: the five jobs with the paper's deadlines and
    /// input sizes (scaled by `scale_gb_to_mb` MB per paper-GB).
    pub fn table2(scale_gb_to_mb: f64) -> Self {
        let rows: [(JobType, f64, f64); 5] = [
            (JobType::Grep, 650.0, 10.0),
            (JobType::WordCount, 520.0, 5.0),
            (JobType::Sort, 500.0, 10.0),
            (JobType::PermutationGenerator, 850.0, 4.0),
            (JobType::InvertedIndex, 720.0, 8.0),
        ];
        Self::new(
            rows.iter()
                .map(|&(t, d, gb)| {
                    JobSpec::new(t, gb * scale_gb_to_mb).with_deadline(d)
                })
                .collect(),
        )
    }

    /// The paper's "random input sizes" mixed experiment: `n` jobs of
    /// random type/size with deadlines drawn as a multiple of the
    /// predictor's naive serial estimate, Poisson arrivals dense enough
    /// to keep the 80-slot cluster backlogged (the regime where the
    /// paper's throughput comparison is meaningful).
    pub fn paper_mix(cfg: &SimConfig, seed: u64) -> Self {
        Self::poisson(cfg, 25, 5.0, 1.6..3.0, seed)
    }

    /// Poisson trace: `n` jobs, exponential inter-arrivals with mean
    /// `mean_gap_s`, deadline factor drawn uniformly from `deadline_factor`
    /// (multiplied by an ideal-parallel completion estimate).
    pub fn poisson(
        cfg: &SimConfig,
        n: usize,
        mean_gap_s: f64,
        deadline_factor: std::ops::Range<f64>,
        seed: u64,
    ) -> Self {
        let mut rng = Rng::new(seed ^ 0x7ace);
        let mut jobs = Vec::with_capacity(n);
        let mut t = 0.0f64;
        for _ in 0..n {
            let jt = ALL_JOB_TYPES[rng.below(ALL_JOB_TYPES.len() as u64) as usize];
            // 16 .. 96 blocks (~1-6 GB at 64 MB blocks): the paper's
            // input-size regime, enough map waves for locality to matter.
            let input_mb = rng.range_f64(16.0, 96.0) * cfg.block_mb;
            let mut spec = JobSpec::new(jt, input_mb).at(t);
            let est = ideal_completion_estimate(cfg, &spec);
            let f = rng.range_f64(deadline_factor.start, deadline_factor.end);
            spec = spec.with_deadline(est * f);
            jobs.push(spec);
            t += rng.exp(mean_gap_s);
        }
        Self::new(jobs)
    }

    /// Like [`JobTrace::poisson`] but with an explicit [`Arrival`] axis
    /// point: the λ multiplier scales the base rate and the `burst`
    /// regime clusters submissions. Used by the sweep harness's
    /// arrival-rate axis.
    pub fn poisson_arrivals(
        cfg: &SimConfig,
        n: usize,
        base_gap_s: f64,
        arrival: Arrival,
        deadline_factor: std::ops::Range<f64>,
        seed: u64,
    ) -> Self {
        let mut rng = Rng::new(seed ^ 0x7ace);
        let times = arrival.times(n, base_gap_s, &mut rng);
        let mut jobs = Vec::with_capacity(n);
        for &t in &times {
            let jt = ALL_JOB_TYPES[rng.below(ALL_JOB_TYPES.len() as u64) as usize];
            let input_mb = rng.range_f64(16.0, 96.0) * cfg.block_mb;
            let mut spec = JobSpec::new(jt, input_mb).at(t);
            let est = ideal_completion_estimate(cfg, &spec);
            let f = rng.range_f64(deadline_factor.start, deadline_factor.end);
            spec = spec.with_deadline(est * f);
            jobs.push(spec);
        }
        Self::new(jobs)
    }
}

/// A streaming source of job submissions: the coordinator pulls one
/// [`JobSpec`] at a time (in non-decreasing `submit_s` order) instead of
/// iterating a materialized `Vec`, so trace length never bounds memory.
///
/// Three backends:
/// * [`TraceSource::from_trace`] wraps an existing [`JobTrace`] — the
///   compatibility path for hand-built traces (fig2/table2/ladders);
/// * [`TraceSource::poisson_arrivals`] generates the sweep harness's
///   Poisson/burst workload lazily, drawing each job from the *same* RNG
///   stream as the eager [`JobTrace::poisson_arrivals`] so the produced
///   specs are bit-identical (pinned by a unit test below);
/// * [`TraceSource::from_file`] replays a plain-text trace file, one job
///   per line (`submit_s,job_type,input_mb,reducers,deadline_s` — see
///   `docs/TRACE_FORMAT.md`), reading line by line.
#[derive(Debug)]
pub enum TraceSource {
    Materialized { jobs: Vec<JobSpec>, next: usize },
    Generated(Box<PoissonGen>),
    File(Box<FileSource>),
}

impl TraceSource {
    /// Wrap a materialized trace (already sorted by [`JobTrace::new`]).
    pub fn from_trace(trace: JobTrace) -> Self {
        TraceSource::Materialized {
            jobs: trace.jobs,
            next: 0,
        }
    }

    /// Lazy equivalent of [`JobTrace::poisson_arrivals`]: same arguments,
    /// same RNG stream, bit-identical specs, O(1) memory.
    pub fn poisson_arrivals(
        cfg: &SimConfig,
        n: usize,
        base_gap_s: f64,
        arrival: Arrival,
        deadline_factor: std::ops::Range<f64>,
        seed: u64,
    ) -> Self {
        TraceSource::Generated(Box::new(PoissonGen::new(
            cfg,
            n,
            base_gap_s,
            arrival,
            deadline_factor,
            seed,
        )))
    }

    /// Open a plain-text trace file for streaming replay. Errors on
    /// open/metadata problems; per-line format errors surface (with file
    /// and line number) when the offending line is pulled.
    pub fn from_file(path: &str) -> Result<Self, String> {
        Ok(TraceSource::File(Box::new(FileSource::open(path)?)))
    }

    /// Pull the next job; `None` once the source is exhausted.
    pub fn next_job(&mut self) -> Option<JobSpec> {
        match self {
            TraceSource::Materialized { jobs, next } => {
                let spec = jobs.get(*next).cloned()?;
                *next += 1;
                Some(spec)
            }
            TraceSource::Generated(g) => g.next_job(),
            TraceSource::File(f) => f.next_job(),
        }
    }

    /// Total number of jobs when known up front (`None` for file sources,
    /// which only learn their length at EOF).
    pub fn total_hint(&self) -> Option<usize> {
        match self {
            TraceSource::Materialized { jobs, .. } => Some(jobs.len()),
            TraceSource::Generated(g) => Some(g.n),
            TraceSource::File(_) => None,
        }
    }

    /// Drain into a materialized [`JobTrace`] (tests, small-scale tools).
    pub fn materialize(mut self) -> JobTrace {
        let mut jobs = Vec::new();
        while let Some(s) = self.next_job() {
            jobs.push(s);
        }
        JobTrace::new(jobs)
    }
}

/// Lazy generator behind [`TraceSource::poisson_arrivals`].
///
/// The eager constructor draws in two passes over one RNG stream: first
/// *all* submission times ([`Arrival::times`] — exactly one `exp` draw
/// per job after the first), then per-job attributes. Replaying that
/// stream lazily therefore needs two cursors into the same stream: the
/// times cursor starts at the stream head, the attributes cursor starts
/// `n-1` draws in (fast-forwarded once at construction). Each pull
/// advances both — O(1) memory, and the produced specs are bit-identical
/// to the eager path.
#[derive(Debug)]
pub struct PoissonGen {
    cfg: SimConfig,
    arrival: Arrival,
    base_gap_s: f64,
    deadline_factor: std::ops::Range<f64>,
    rng_times: Rng,
    rng_attrs: Rng,
    n: usize,
    i: usize,
    t: f64,
}

impl PoissonGen {
    fn new(
        cfg: &SimConfig,
        n: usize,
        base_gap_s: f64,
        arrival: Arrival,
        deadline_factor: std::ops::Range<f64>,
        seed: u64,
    ) -> Self {
        let rng_times = Rng::new(seed ^ 0x7ace);
        let mut rng_attrs = rng_times.clone();
        // `Arrival::times(n, ..)` consumes exactly one `next_u64` per
        // `exp` draw, `n - 1` draws total; the attribute pass starts
        // right after them.
        for _ in 1..n {
            rng_attrs.next_u64();
        }
        Self {
            cfg: cfg.clone(),
            arrival,
            base_gap_s,
            deadline_factor,
            rng_times,
            rng_attrs,
            n,
            i: 0,
            t: 0.0,
        }
    }

    fn next_job(&mut self) -> Option<JobSpec> {
        if self.i >= self.n {
            return None;
        }
        // Submission time: the same per-index mean selection as
        // `Arrival::times`, one draw per job after the first.
        if self.i > 0 {
            let gap = self.base_gap_s / self.arrival.rate;
            let mean = match self.arrival.regime {
                ArrivalRegime::Steady => gap,
                ArrivalRegime::Burst => {
                    if self.i % BURST_SIZE == 0 {
                        gap * (BURST_SIZE as f64
                            - BURST_INTRA_FRACTION * (BURST_SIZE - 1) as f64)
                    } else {
                        gap * BURST_INTRA_FRACTION
                    }
                }
            };
            self.t += self.rng_times.exp(mean);
        }
        // Attributes: the same draws, in the same order, as the eager
        // constructor's per-job loop body.
        let jt = ALL_JOB_TYPES[self.rng_attrs.below(ALL_JOB_TYPES.len() as u64) as usize];
        let input_mb = self.rng_attrs.range_f64(16.0, 96.0) * self.cfg.block_mb;
        let mut spec = JobSpec::new(jt, input_mb).at(self.t);
        let est = ideal_completion_estimate(&self.cfg, &spec);
        let f = self
            .rng_attrs
            .range_f64(self.deadline_factor.start, self.deadline_factor.end);
        spec = spec.with_deadline(est * f);
        self.i += 1;
        Some(spec)
    }
}

/// Streaming reader behind [`TraceSource::from_file`]; see
/// `docs/TRACE_FORMAT.md` for the line format.
#[derive(Debug)]
pub struct FileSource {
    path: String,
    lines: std::io::Lines<std::io::BufReader<std::fs::File>>,
    line_no: usize,
    last_submit: f64,
}

impl FileSource {
    fn open(path: &str) -> Result<Self, String> {
        use std::io::BufRead;
        let file =
            std::fs::File::open(path).map_err(|e| format!("open trace file {path}: {e}"))?;
        Ok(Self {
            path: path.to_string(),
            lines: std::io::BufReader::new(file).lines(),
            line_no: 0,
            last_submit: 0.0,
        })
    }

    fn next_job(&mut self) -> Option<JobSpec> {
        loop {
            let line = match self.lines.next()? {
                Ok(l) => l,
                Err(e) => panic!("{}:{}: read error: {e}", self.path, self.line_no + 1),
            };
            self.line_no += 1;
            let s = line.trim();
            if s.is_empty() || s.starts_with('#') {
                continue;
            }
            let spec = match parse_trace_line(s) {
                Ok(spec) => spec,
                Err(e) => panic!("{}:{}: {e}: {s:?}", self.path, self.line_no),
            };
            assert!(
                spec.submit_s >= self.last_submit,
                "{}:{}: submit times must be non-decreasing ({} < {})",
                self.path,
                self.line_no,
                spec.submit_s,
                self.last_submit
            );
            self.last_submit = spec.submit_s;
            return Some(spec);
        }
    }
}

/// Parse one trace-file line:
/// `submit_s,job_type,input_mb,reducers,deadline_s` with `-` for a
/// best-effort (absent) deadline; extra trailing fields are ignored for
/// forward compatibility. See `docs/TRACE_FORMAT.md`.
pub fn parse_trace_line(s: &str) -> Result<JobSpec, String> {
    let mut fields = s.split(',').map(str::trim);
    let mut next = |name: &str| fields.next().ok_or_else(|| format!("missing {name}"));
    let submit_s: f64 = next("submit_s")?
        .parse()
        .map_err(|_| "bad submit_s".to_string())?;
    let ty_name = next("job_type")?;
    let job_type =
        JobType::from_name(ty_name).ok_or_else(|| format!("unknown job_type {ty_name:?}"))?;
    let input_mb: f64 = next("input_mb")?
        .parse()
        .map_err(|_| "bad input_mb".to_string())?;
    let reducers: u32 = next("reducers")?
        .parse()
        .map_err(|_| "bad reducers".to_string())?;
    let deadline = next("deadline_s")?;
    let deadline_s = if deadline == "-" {
        None
    } else {
        Some(
            deadline
                .parse::<f64>()
                .map_err(|_| "bad deadline_s".to_string())?,
        )
    };
    if !(submit_s.is_finite() && submit_s >= 0.0) {
        return Err("submit_s must be finite and >= 0".into());
    }
    if !(input_mb.is_finite() && input_mb > 0.0) {
        return Err("input_mb must be finite and > 0".into());
    }
    if reducers == 0 {
        return Err("reducers must be >= 1".into());
    }
    if let Some(d) = deadline_s {
        if !(d.is_finite() && d > 0.0) {
            return Err("deadline_s must be finite and > 0".into());
        }
    }
    let mut spec = JobSpec::new(job_type, input_mb).at(submit_s);
    spec.reducers = reducers;
    spec.deadline_s = deadline_s;
    Ok(spec)
}

/// Render one job as a trace-file line — the exact inverse of
/// [`parse_trace_line`]: `{}`-formatted floats print the shortest
/// representation that parses back to the identical bits, so a written
/// trace replays byte-identically.
pub fn render_trace_line(spec: &JobSpec) -> String {
    let deadline = match spec.deadline_s {
        Some(d) => format!("{d}"),
        None => "-".to_string(),
    };
    format!(
        "{},{},{},{},{}",
        spec.submit_s,
        spec.job_type.name(),
        spec.input_mb,
        spec.reducers,
        deadline
    )
}

/// Write a full trace file (header comment + one line per job) for
/// [`TraceSource::from_file`] replay.
pub fn write_trace_file(path: &std::path::Path, jobs: &[JobSpec]) -> std::io::Result<()> {
    use std::io::Write;
    let mut out = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(out, "# vcsched job trace: submit_s,job_type,input_mb,reducers,deadline_s")?;
    for spec in jobs {
        writeln!(out, "{}", render_trace_line(spec))?;
    }
    out.flush()
}

/// One PM crash or recovery in a pre-generated failure trace.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FailureEvent {
    /// Absolute simulated time, seconds.
    pub at_s: f64,
    /// Physical machine index.
    pub pm: usize,
    /// `true` = the PM crashes at `at_s`; `false` = it recovers.
    pub crash: bool,
}

/// Seed-stream tag for the failure trace (and the coordinator's failure
/// RNG): keeps failure randomness fully separate from the workload and
/// jitter streams, so turning crashes on never perturbs task durations.
pub const FAILURE_STREAM_TAG: u64 = 0xfa11_0c0d_e5ee_d001;

/// Pre-generate the PM crash/recover timeline for one scenario.
/// `pm_racks[pm]` is each PM's rack (see [`SimConfig::pm_rack`]); its
/// length is the PM count.
///
/// Independent mode (the default): each PM alternates exponential
/// up-times (mean `fm.pm_mtbf_s`) and exponential down-times (mean
/// `fm.pm_repair_s`), starting alive at t=0. With `fm.rack_correlated`
/// the same alternation is drawn once per *rack* (ascending rack id) and
/// every member PM crashes/recovers together at the identical
/// timestamps. Crashes are generated until `fm.trace_horizon_s`; every
/// generated crash is always paired with its recovery even when the
/// recovery lands past the horizon, so no PM stays dead forever. Events
/// are sorted by `(time, pm)` — a total, reproducible order.
///
/// The RNG stream is derived from `seed` via a dedicated tag, NOT from the
/// simulation's main RNG: with crashes off this function returns an empty
/// vec without consuming any randomness, preserving byte-identity.
pub fn failure_trace(fm: &FailureModel, seed: u64, pm_racks: &[u32]) -> Vec<FailureEvent> {
    if !fm.crashes() {
        return Vec::new();
    }
    let mut rng = Rng::new(mix64(seed ^ FAILURE_STREAM_TAG));
    let mut out = Vec::new();
    if fm.rack_correlated {
        let mut racks: Vec<u32> = pm_racks.to_vec();
        racks.sort_unstable();
        racks.dedup();
        for rack in racks {
            let mut t = 0.0f64;
            loop {
                t += rng.exp(fm.pm_mtbf_s);
                if t >= fm.trace_horizon_s {
                    break;
                }
                let up = t + rng.exp(fm.pm_repair_s).max(1.0);
                for (pm, &r) in pm_racks.iter().enumerate() {
                    if r == rack {
                        out.push(FailureEvent { at_s: t, pm, crash: true });
                        out.push(FailureEvent { at_s: up, pm, crash: false });
                    }
                }
                t = up;
            }
        }
    } else {
        for pm in 0..pm_racks.len() {
            let mut t = 0.0f64;
            loop {
                t += rng.exp(fm.pm_mtbf_s);
                if t >= fm.trace_horizon_s {
                    break;
                }
                out.push(FailureEvent { at_s: t, pm, crash: true });
                t += rng.exp(fm.pm_repair_s).max(1.0);
                out.push(FailureEvent { at_s: t, pm, crash: false });
            }
        }
    }
    out.sort_by(|a, b| {
        a.at_s
            .partial_cmp(&b.at_s)
            .unwrap()
            .then(a.pm.cmp(&b.pm))
            .then(a.crash.cmp(&b.crash))
    });
    out
}

/// Target of one failure-trace line: a single PM or a whole rack.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailureTarget {
    Pm(usize),
    Rack(u32),
}

/// One parsed failure-trace line: a crash/repair interval.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FailureSpan {
    /// Crash time, seconds.
    pub fail_s: f64,
    /// Recovery time, seconds (strictly after `fail_s`).
    pub recover_s: f64,
    pub target: FailureTarget,
}

/// Parse one failure-trace line: `fail_s,recover_s,pm:<id>` or
/// `fail_s,recover_s,rack:<id>` (`docs/FAILURE_MODEL.md`). Extra trailing
/// fields are ignored for forward compatibility.
pub fn parse_failure_trace_line(s: &str) -> Result<FailureSpan, String> {
    let mut fields = s.split(',').map(str::trim);
    let mut next = |name: &str| fields.next().ok_or_else(|| format!("missing {name}"));
    let fail_s: f64 = next("fail_s")?
        .parse()
        .map_err(|_| "bad fail_s".to_string())?;
    let recover_s: f64 = next("recover_s")?
        .parse()
        .map_err(|_| "bad recover_s".to_string())?;
    let target_s = next("target")?;
    let target = match target_s.split_once(':') {
        Some(("pm", id)) => {
            FailureTarget::Pm(id.parse().map_err(|_| "bad pm id".to_string())?)
        }
        Some(("rack", id)) => {
            FailureTarget::Rack(id.parse().map_err(|_| "bad rack id".to_string())?)
        }
        _ => return Err(format!("target must be pm:<id> or rack:<id>, got {target_s:?}")),
    };
    if !(fail_s.is_finite() && fail_s >= 0.0) {
        return Err("fail_s must be finite and >= 0".into());
    }
    if !(recover_s.is_finite() && recover_s > fail_s) {
        return Err("recover_s must be finite and > fail_s".into());
    }
    Ok(FailureSpan { fail_s, recover_s, target })
}

/// Render one failure-trace line — the exact inverse of
/// [`parse_failure_trace_line`] (`{}`-formatted floats round-trip
/// bitwise, as for job-trace lines).
pub fn render_failure_trace_line(span: &FailureSpan) -> String {
    let target = match span.target {
        FailureTarget::Pm(id) => format!("pm:{id}"),
        FailureTarget::Rack(id) => format!("rack:{id}"),
    };
    format!("{},{},{}", span.fail_s, span.recover_s, target)
}

/// Write a crash/recover timeline as a failure-trace file: one
/// `fail_s,recover_s,pm:<id>` line per crash/recovery pair, sorted by
/// `(fail_s, pm)`. The inverse [`read_failure_trace_file`] reproduces the
/// event list byte-identically (the canonical-sort round-trip is pinned
/// by a unit test and the CI `cmp` smoke).
pub fn write_failure_trace_file(
    path: &std::path::Path,
    events: &[FailureEvent],
) -> std::io::Result<()> {
    use std::io::Write;
    // Pair each PM's alternating crash/recover sequence back into spans.
    let mut open: std::collections::HashMap<usize, f64> = std::collections::HashMap::new();
    let mut spans: Vec<(f64, usize, f64)> = Vec::with_capacity(events.len() / 2);
    for e in events {
        if e.crash {
            let prev = open.insert(e.pm, e.at_s);
            assert!(prev.is_none(), "pm {} crashed twice without recovering", e.pm);
        } else {
            let fail_s = open
                .remove(&e.pm)
                .unwrap_or_else(|| panic!("pm {} recovered without crashing", e.pm));
            spans.push((fail_s, e.pm, e.at_s));
        }
    }
    assert!(open.is_empty(), "unpaired crash events");
    spans.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
    let mut out = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(out, "# vcsched failure trace: fail_s,recover_s,pm|rack:<id>")?;
    for (fail_s, pm, recover_s) in spans {
        let span = FailureSpan {
            fail_s,
            recover_s,
            target: FailureTarget::Pm(pm),
        };
        writeln!(out, "{}", render_failure_trace_line(&span))?;
    }
    out.flush()
}

/// Read a failure-trace file back into the canonical crash/recover event
/// list: `rack:<id>` lines expand to every member PM (per `pm_racks`),
/// ids are range-checked, and the result is sorted exactly like
/// [`failure_trace`] output.
pub fn read_failure_trace_file(path: &str, pm_racks: &[u32]) -> Result<Vec<FailureEvent>, String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("open failure trace {path}: {e}"))?;
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let s = line.trim();
        if s.is_empty() || s.starts_with('#') {
            continue;
        }
        let span = parse_failure_trace_line(s).map_err(|e| format!("line {}: {e}", i + 1))?;
        let pms: Vec<usize> = match span.target {
            FailureTarget::Pm(pm) => {
                if pm >= pm_racks.len() {
                    return Err(format!(
                        "line {}: pm {pm} out of range (cluster has {})",
                        i + 1,
                        pm_racks.len()
                    ));
                }
                vec![pm]
            }
            FailureTarget::Rack(rack) => {
                let members: Vec<usize> = pm_racks
                    .iter()
                    .enumerate()
                    .filter(|(_, &r)| r == rack)
                    .map(|(pm, _)| pm)
                    .collect();
                if members.is_empty() {
                    return Err(format!("line {}: rack {rack} has no PMs", i + 1));
                }
                members
            }
        };
        for pm in pms {
            out.push(FailureEvent { at_s: span.fail_s, pm, crash: true });
            out.push(FailureEvent { at_s: span.recover_s, pm, crash: false });
        }
    }
    out.sort_by(|a, b| {
        a.at_s
            .partial_cmp(&b.at_s)
            .unwrap()
            .then(a.pm.cmp(&b.pm))
            .then(a.crash.cmp(&b.crash))
    });
    Ok(out)
}

/// Crude ideal-parallelism completion estimate used only to draw sane
/// deadlines for generated traces (NOT the paper's predictor).
///
/// Heterogeneity-aware: map-phase parallelism uses the *speed-weighted*
/// slot count ([`SimConfig::effective_map_slots`] — a half-speed
/// straggler's slot retires work at half rate), and reduce CPU time
/// divides by the mean PM speed. Under the uniform profile both collapse
/// to the homogeneous formula, so deadline-miss metrics stay comparable
/// across the `pm_profile` sweep axis.
pub fn ideal_completion_estimate(cfg: &SimConfig, spec: &JobSpec) -> f64 {
    let m = spec.job_type.cost_model();
    let maps = (spec.input_mb / cfg.block_mb).ceil().max(1.0);
    let map_slots = cfg.effective_map_slots();
    let red_slots = cfg.total_reduce_slots() as f64;
    let inter_mb = m.intermediate_mb(spec.input_mb);
    let reducers = (spec.reducers as f64).max(1.0);
    let map_time = maps * m.map_secs(cfg.block_mb) / map_slots.min(maps);
    let shuffle_time = inter_mb / cfg.net_mbps / reducers.min(red_slots);
    let waves = (reducers / red_slots.min(reducers)).ceil();
    let red_time = m.reduce_secs(inter_mb / reducers) * waves / cfg.mean_pm_speed();
    map_time + shuffle_time + red_time
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_grid_shape() {
        let t = JobTrace::fig2_grid(100.0);
        assert_eq!(t.len(), 25);
        // 2 GB -> 200 MB scaled
        assert!(t.jobs.iter().any(|j| (j.input_mb - 200.0).abs() < 1e-9));
        assert!(t.jobs.iter().any(|j| (j.input_mb - 1000.0).abs() < 1e-9));
    }

    #[test]
    fn table2_matches_paper_rows() {
        let t = JobTrace::table2(100.0);
        assert_eq!(t.len(), 5);
        let grep = t
            .jobs
            .iter()
            .find(|j| j.job_type == JobType::Grep)
            .unwrap();
        assert_eq!(grep.deadline_s, Some(650.0));
        assert!((grep.input_mb - 1000.0).abs() < 1e-9);
    }

    #[test]
    fn poisson_trace_sorted_and_deadlined() {
        let cfg = SimConfig::paper();
        let t = JobTrace::poisson(&cfg, 40, 30.0, 1.5..3.0, 9);
        assert_eq!(t.len(), 40);
        for w in t.jobs.windows(2) {
            assert!(w[0].submit_s <= w[1].submit_s);
        }
        for j in &t.jobs {
            let d = j.deadline_s.expect("all jobs deadlined");
            assert!(d > 0.0);
        }
    }

    #[test]
    fn poisson_deterministic() {
        let cfg = SimConfig::paper();
        let a = JobTrace::poisson(&cfg, 10, 30.0, 1.5..3.0, 4);
        let b = JobTrace::poisson(&cfg, 10, 30.0, 1.5..3.0, 4);
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x.job_type, y.job_type);
            assert_eq!(x.input_mb, y.input_mb);
            assert_eq!(x.submit_s, y.submit_s);
        }
    }

    #[test]
    fn estimate_positive_and_monotone() {
        let cfg = SimConfig::paper();
        let small = ideal_completion_estimate(&cfg, &JobSpec::new(JobType::Sort, 256.0));
        let large = ideal_completion_estimate(&cfg, &JobSpec::new(JobType::Sort, 2560.0));
        assert!(small > 0.0);
        assert!(large > small);
    }

    #[test]
    fn estimate_respects_pm_profile() {
        use crate::config::PmProfile;
        // Regression: the estimate used to assume homogeneous node speed,
        // which made deadlines too tight under slow-tail hardware (every
        // generated deadline was ~25% optimistic on a long-tail cluster,
        // inflating miss rates for reasons unrelated to the scheduler).
        let uniform = SimConfig::paper();
        let tail = SimConfig {
            pm_profile: PmProfile::LongTail,
            ..SimConfig::paper()
        };
        let split = SimConfig {
            pm_profile: PmProfile::Split2x,
            ..SimConfig::paper()
        };
        for mb in [256.0, 2560.0] {
            let spec = JobSpec::new(JobType::Sort, mb);
            let e_uni = ideal_completion_estimate(&uniform, &spec);
            let e_tail = ideal_completion_estimate(&tail, &spec);
            let e_split = ideal_completion_estimate(&split, &spec);
            // A straggler tail strictly slows the ideal estimate...
            assert!(e_tail > e_uni, "{e_tail} <= {e_uni} at {mb} MB");
            // ...while split-2x only adds spare cores (VM slots and
            // speeds unchanged), so the base-slot estimate is identical.
            assert!((e_split - e_uni).abs() < 1e-12);
        }
    }

    #[test]
    fn arrival_labels_roundtrip() {
        for a in [
            Arrival::STEADY,
            Arrival::steady(2.0),
            Arrival::burst(1.0),
            Arrival::burst(1.5),
        ] {
            assert_eq!(Arrival::from_label(&a.label()), Some(a));
        }
        assert_eq!(Arrival::STEADY.label(), "steady");
        assert_eq!(Arrival::burst(1.0).label(), "burst");
        assert_eq!(Arrival::steady(2.0).label(), "steady-x2");
        assert_eq!(Arrival::from_label("warp"), None);
        assert_eq!(Arrival::from_label("steady-x0"), None);
    }

    #[test]
    fn arrival_times_sorted_and_rate_scaled() {
        let mut rng = Rng::new(3);
        let t1 = Arrival::STEADY.times(400, 10.0, &mut rng);
        assert_eq!(t1.len(), 400);
        assert!(t1.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(t1[0], 0.0);
        // Doubling λ roughly halves the span.
        let mut rng = Rng::new(3);
        let t2 = Arrival::steady(2.0).times(400, 10.0, &mut rng);
        let (s1, s2) = (t1[399], t2[399]);
        assert!(s2 < s1 * 0.7, "span {s2} not ~half of {s1}");
    }

    #[test]
    fn burst_regime_clusters_arrivals_at_matched_rate() {
        let mut rng = Rng::new(9);
        let steady = Arrival::STEADY.times(500, 10.0, &mut rng);
        let mut rng = Rng::new(9);
        let burst = Arrival::burst(1.0).times(500, 10.0, &mut rng);
        // Long-run rate matches within sampling noise...
        let (ss, sb) = (steady[499], burst[499]);
        assert!(
            (sb / ss - 1.0).abs() < 0.25,
            "burst span {sb} vs steady span {ss}"
        );
        // ...but the gap distribution is far more dispersed: most gaps
        // tiny (intra-burst), a few huge (inter-burst).
        let gaps: Vec<f64> = burst.windows(2).map(|w| w[1] - w[0]).collect();
        let tiny = gaps.iter().filter(|&&g| g < 2.0).count();
        let huge = gaps.iter().filter(|&&g| g > 20.0).count();
        assert!(tiny > gaps.len() / 2, "only {tiny} intra-burst gaps");
        assert!(huge > 20, "only {huge} inter-burst gaps");
    }

    /// Rack layout of a 20-PM cluster in test helpers below.
    fn racks20(n_racks: u32) -> Vec<u32> {
        (0..20u32).map(|p| p % n_racks).collect()
    }

    #[test]
    fn failure_trace_off_is_empty_and_free() {
        assert!(failure_trace(&FailureModel::off(), 42, &racks20(1)).is_empty());
        assert!(failure_trace(&FailureModel::stragglers(), 42, &racks20(1)).is_empty());
    }

    #[test]
    fn failure_trace_well_formed() {
        let fm = FailureModel::crash_high();
        let tr = failure_trace(&fm, 7, &racks20(1));
        assert!(!tr.is_empty());
        // Deterministic.
        assert_eq!(tr, failure_trace(&fm, 7, &racks20(1)));
        // Different seeds diverge.
        assert_ne!(tr, failure_trace(&fm, 8, &racks20(1)));
        // Sorted by time.
        assert!(tr.windows(2).all(|w| w[0].at_s <= w[1].at_s));
        // Per PM: strictly alternating crash/recover starting with a
        // crash, times strictly increasing, every crash paired.
        for pm in 0..20 {
            let mine: Vec<_> = tr.iter().filter(|e| e.pm == pm).collect();
            assert_eq!(mine.len() % 2, 0, "pm {pm} has an unpaired event");
            let mut last = 0.0;
            for (i, e) in mine.iter().enumerate() {
                assert_eq!(e.crash, i % 2 == 0, "pm {pm} event {i} out of order");
                assert!(e.at_s > last);
                last = e.at_s;
            }
            // Crashes only within the horizon (recoveries may overflow).
            for e in mine.iter().filter(|e| e.crash) {
                assert!(e.at_s < fm.trace_horizon_s);
            }
        }
    }

    #[test]
    fn rack_outage_crashes_whole_racks_together() {
        let racks = racks20(4);
        let fm = FailureModel::rack_outage();
        let tr = failure_trace(&fm, 11, &racks);
        assert!(!tr.is_empty());
        assert_eq!(tr, failure_trace(&fm, 11, &racks));
        // Every event timestamp is shared by exactly the 5 PMs of one
        // rack: group by (time, crash) and check rack membership.
        use std::collections::HashMap;
        let mut groups: HashMap<(u64, bool), Vec<usize>> = HashMap::new();
        for e in &tr {
            groups.entry((e.at_s.to_bits(), e.crash)).or_default().push(e.pm);
        }
        for ((_, _), pms) in groups {
            assert_eq!(pms.len(), 5, "rack outage must cover the whole rack");
            let rack = racks[pms[0]];
            assert!(pms.iter().all(|&p| racks[p] == rack));
        }
        // Per-PM sequences still alternate crash/recover.
        for pm in 0..20 {
            let mine: Vec<_> = tr.iter().filter(|e| e.pm == pm).collect();
            for (i, e) in mine.iter().enumerate() {
                assert_eq!(e.crash, i % 2 == 0, "pm {pm} event {i} out of order");
            }
        }
    }

    #[test]
    fn failure_trace_file_round_trips_byte_identically() {
        for (fm, racks) in [
            (FailureModel::rack_outage(), racks20(4)),
            (FailureModel::crash_low(), racks20(1)),
        ] {
            let tr = failure_trace(&fm, 33, &racks);
            assert!(!tr.is_empty());
            let dir = std::env::temp_dir();
            let path = dir.join(format!("vcsched_failure_trace_rt_{}.txt", fm.label()));
            write_failure_trace_file(&path, &tr).expect("write failure trace");
            let back =
                read_failure_trace_file(path.to_str().unwrap(), &racks).expect("read back");
            assert_eq!(tr.len(), back.len());
            for (a, b) in tr.iter().zip(&back) {
                assert_eq!(a.at_s.to_bits(), b.at_s.to_bits());
                assert_eq!(a.pm, b.pm);
                assert_eq!(a.crash, b.crash);
            }
            let _ = std::fs::remove_file(&path);
        }
    }

    #[test]
    fn failure_trace_line_codec_and_rack_expansion() {
        let span = parse_failure_trace_line("10.5,70,rack:2").unwrap();
        assert_eq!(span.target, FailureTarget::Rack(2));
        assert_eq!(render_failure_trace_line(&span), "10.5,70,rack:2");
        let span = parse_failure_trace_line("0, 60, pm:7, extra").unwrap();
        assert_eq!(span.target, FailureTarget::Pm(7));
        for bad in [
            "",
            "10",
            "10,70",
            "x,70,pm:1",
            "10,x,pm:1",
            "10,70,node:1",
            "10,70,pm:x",
            "70,10,pm:1",  // recover before fail
            "10,10,pm:1",  // zero-length outage
            "-1,70,pm:1",
        ] {
            assert!(parse_failure_trace_line(bad).is_err(), "accepted {bad:?}");
        }
        // rack: expands to every member PM; out-of-range ids reject.
        let dir = std::env::temp_dir();
        let path = dir.join("vcsched_failure_trace_rack_unit.txt");
        std::fs::write(&path, "# comment\n5,65,rack:1\n100,160,pm:0\n").unwrap();
        let racks = vec![0u32, 1, 0, 1];
        let evs = read_failure_trace_file(path.to_str().unwrap(), &racks).unwrap();
        // rack 1 = PMs 1 and 3 -> 2 crash + 2 recover, plus pm 0's pair.
        assert_eq!(evs.len(), 6);
        assert_eq!(
            evs.iter().filter(|e| e.crash).map(|e| e.pm).collect::<Vec<_>>(),
            vec![1, 3, 0]
        );
        std::fs::write(&path, "5,65,pm:9\n").unwrap();
        assert!(read_failure_trace_file(path.to_str().unwrap(), &racks).is_err());
        std::fs::write(&path, "5,65,rack:7\n").unwrap();
        assert!(read_failure_trace_file(path.to_str().unwrap(), &racks).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn lazy_generator_is_bit_identical_to_eager_constructor() {
        // The streaming-source contract: TraceSource::poisson_arrivals
        // must draw the exact RNG sequence of JobTrace::poisson_arrivals,
        // so small-scale artifacts stay byte-identical when the
        // coordinator pulls jobs lazily. Pinned across seeds, regimes
        // and job counts (including the n=0 and n=1 edges).
        let cfg = SimConfig::paper();
        for seed in [1u64, 7, 42, 1234] {
            for arrival in [Arrival::STEADY, Arrival::steady(2.0), Arrival::burst(1.5)] {
                for n in [0usize, 1, 2, 37] {
                    let eager =
                        JobTrace::poisson_arrivals(&cfg, n, 5.0, arrival, 1.6..3.0, seed);
                    let lazy =
                        TraceSource::poisson_arrivals(&cfg, n, 5.0, arrival, 1.6..3.0, seed)
                            .materialize();
                    assert_eq!(eager.len(), lazy.len());
                    for (a, b) in eager.jobs.iter().zip(&lazy.jobs) {
                        assert_eq!(a.job_type, b.job_type);
                        assert_eq!(a.input_mb.to_bits(), b.input_mb.to_bits());
                        assert_eq!(a.reducers, b.reducers);
                        assert_eq!(a.submit_s.to_bits(), b.submit_s.to_bits());
                        assert_eq!(
                            a.deadline_s.map(f64::to_bits),
                            b.deadline_s.map(f64::to_bits)
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn trace_source_from_trace_streams_in_order() {
        let cfg = SimConfig::paper();
        let trace = JobTrace::paper_mix(&cfg, 3);
        let mut src = TraceSource::from_trace(trace.clone());
        assert_eq!(src.total_hint(), Some(trace.len()));
        let mut n = 0;
        while let Some(spec) = src.next_job() {
            assert_eq!(spec.submit_s.to_bits(), trace.jobs[n].submit_s.to_bits());
            n += 1;
        }
        assert_eq!(n, trace.len());
        assert!(src.next_job().is_none(), "exhausted source stays exhausted");
    }

    #[test]
    fn trace_line_codec_round_trips_bitwise() {
        let cfg = SimConfig::paper();
        let trace = JobTrace::poisson_arrivals(&cfg, 25, 5.0, Arrival::burst(2.0), 1.6..3.0, 9);
        for spec in &trace.jobs {
            let line = render_trace_line(spec);
            let back = parse_trace_line(&line).expect("rendered line parses");
            assert_eq!(back.job_type, spec.job_type);
            assert_eq!(back.submit_s.to_bits(), spec.submit_s.to_bits());
            assert_eq!(back.input_mb.to_bits(), spec.input_mb.to_bits());
            assert_eq!(back.reducers, spec.reducers);
            assert_eq!(
                back.deadline_s.map(f64::to_bits),
                spec.deadline_s.map(f64::to_bits)
            );
        }
        // Best-effort deadline renders as '-'.
        let spec = JobSpec::new(JobType::Grep, 640.0).at(1.5);
        let line = render_trace_line(&spec);
        assert!(line.ends_with(",-"), "{line}");
        assert_eq!(parse_trace_line(&line).unwrap().deadline_s, None);
    }

    #[test]
    fn trace_line_parser_rejects_malformed_input() {
        assert!(parse_trace_line("0,wordcount,640,4,100").is_ok());
        // Extra trailing fields are ignored (forward compatibility).
        assert!(parse_trace_line("0,wordcount,640,4,100,extra").is_ok());
        for bad in [
            "",
            "0",
            "x,wordcount,640,4,100",
            "0,warpdrive,640,4,100",
            "0,wordcount,-5,4,100",
            "0,wordcount,640,0,100",
            "0,wordcount,640,4,0",
            "-1,wordcount,640,4,100",
        ] {
            assert!(parse_trace_line(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn file_source_replays_written_trace() {
        let cfg = SimConfig::paper();
        let trace = JobTrace::poisson_arrivals(&cfg, 15, 5.0, Arrival::STEADY, 1.6..3.0, 21);
        let dir = std::env::temp_dir();
        let path = dir.join("vcsched_trace_roundtrip_unit.txt");
        write_trace_file(&path, &trace.jobs).expect("write trace");
        let src = TraceSource::from_file(path.to_str().unwrap()).expect("open trace");
        assert_eq!(src.total_hint(), None);
        let replay = src.materialize();
        assert_eq!(replay.len(), trace.len());
        for (a, b) in trace.jobs.iter().zip(&replay.jobs) {
            assert_eq!(a.job_type, b.job_type);
            assert_eq!(a.submit_s.to_bits(), b.submit_s.to_bits());
            assert_eq!(a.input_mb.to_bits(), b.input_mb.to_bits());
            assert_eq!(a.reducers, b.reducers);
            assert_eq!(
                a.deadline_s.map(f64::to_bits),
                b.deadline_s.map(f64::to_bits)
            );
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn poisson_arrivals_deterministic_and_deadlined() {
        let cfg = SimConfig::paper();
        let a = JobTrace::poisson_arrivals(&cfg, 20, 5.0, Arrival::burst(2.0), 1.6..3.0, 7);
        let b = JobTrace::poisson_arrivals(&cfg, 20, 5.0, Arrival::burst(2.0), 1.6..3.0, 7);
        assert_eq!(a.len(), 20);
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x.job_type, y.job_type);
            assert_eq!(x.input_mb, y.input_mb);
            assert_eq!(x.submit_s, y.submit_s);
            assert_eq!(x.deadline_s, y.deadline_s);
            assert!(x.deadline_s.unwrap() > 0.0);
        }
    }
}
