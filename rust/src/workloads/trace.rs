//! Job-trace generation: the paper's experiment mixes plus Poisson traces
//! for the throughput experiments.

use super::{JobSpec, JobType, ALL_JOB_TYPES};
use crate::config::SimConfig;
use crate::util::Rng;

/// An ordered set of job submissions.
#[derive(Clone, Debug, Default)]
pub struct JobTrace {
    pub jobs: Vec<JobSpec>,
}

impl JobTrace {
    pub fn new(jobs: Vec<JobSpec>) -> Self {
        let mut t = Self { jobs };
        t.jobs
            .sort_by(|a, b| a.submit_s.partial_cmp(&b.submit_s).unwrap());
        t
    }

    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// Figure 2 experiment: every workload at every input size, submitted
    /// together (the paper runs "the same set of experiments with the same
    /// input data" under both schedulers). `scale` shrinks the paper's GB
    /// sizes to simulator-friendly MB while keeping proportions.
    pub fn fig2_grid(scale_gb_to_mb: f64) -> Self {
        Self::fig2_grid_on(&SimConfig::paper(), scale_gb_to_mb)
    }

    /// Like [`JobTrace::fig2_grid`] with explicit cluster config (used to
    /// derive sane completion-time goals — the proposed scheduler is a
    /// deadline scheduler, so every job carries a goal as in §5).
    pub fn fig2_grid_on(cfg: &SimConfig, scale_gb_to_mb: f64) -> Self {
        let sizes_gb = [2.0, 4.0, 6.0, 8.0, 10.0];
        let mut jobs = Vec::new();
        for t in ALL_JOB_TYPES {
            for gb in sizes_gb {
                let mut spec = JobSpec::new(t, gb * scale_gb_to_mb);
                let d = ideal_completion_estimate(cfg, &spec) * 2.5;
                spec = spec.with_deadline(d);
                jobs.push(spec);
            }
        }
        Self::new(jobs)
    }

    /// Table 2 experiment: the five jobs with the paper's deadlines and
    /// input sizes (scaled by `scale_gb_to_mb` MB per paper-GB).
    pub fn table2(scale_gb_to_mb: f64) -> Self {
        let rows: [(JobType, f64, f64); 5] = [
            (JobType::Grep, 650.0, 10.0),
            (JobType::WordCount, 520.0, 5.0),
            (JobType::Sort, 500.0, 10.0),
            (JobType::PermutationGenerator, 850.0, 4.0),
            (JobType::InvertedIndex, 720.0, 8.0),
        ];
        Self::new(
            rows.iter()
                .map(|&(t, d, gb)| {
                    JobSpec::new(t, gb * scale_gb_to_mb).with_deadline(d)
                })
                .collect(),
        )
    }

    /// The paper's "random input sizes" mixed experiment: `n` jobs of
    /// random type/size with deadlines drawn as a multiple of the
    /// predictor's naive serial estimate, Poisson arrivals dense enough
    /// to keep the 80-slot cluster backlogged (the regime where the
    /// paper's throughput comparison is meaningful).
    pub fn paper_mix(cfg: &SimConfig, seed: u64) -> Self {
        Self::poisson(cfg, 25, 5.0, 1.6..3.0, seed)
    }

    /// Poisson trace: `n` jobs, exponential inter-arrivals with mean
    /// `mean_gap_s`, deadline factor drawn uniformly from `deadline_factor`
    /// (multiplied by an ideal-parallel completion estimate).
    pub fn poisson(
        cfg: &SimConfig,
        n: usize,
        mean_gap_s: f64,
        deadline_factor: std::ops::Range<f64>,
        seed: u64,
    ) -> Self {
        let mut rng = Rng::new(seed ^ 0x7ace);
        let mut jobs = Vec::with_capacity(n);
        let mut t = 0.0f64;
        for _ in 0..n {
            let jt = ALL_JOB_TYPES[rng.below(ALL_JOB_TYPES.len() as u64) as usize];
            // 16 .. 96 blocks (~1-6 GB at 64 MB blocks): the paper's
            // input-size regime, enough map waves for locality to matter.
            let input_mb = rng.range_f64(16.0, 96.0) * cfg.block_mb;
            let mut spec = JobSpec::new(jt, input_mb).at(t);
            let est = ideal_completion_estimate(cfg, &spec);
            let f = rng.range_f64(deadline_factor.start, deadline_factor.end);
            spec = spec.with_deadline(est * f);
            jobs.push(spec);
            t += rng.exp(mean_gap_s);
        }
        Self::new(jobs)
    }
}

/// Crude ideal-parallelism completion estimate used only to draw sane
/// deadlines for generated traces (NOT the paper's predictor).
pub fn ideal_completion_estimate(cfg: &SimConfig, spec: &JobSpec) -> f64 {
    let m = spec.job_type.cost_model();
    let maps = (spec.input_mb / cfg.block_mb).ceil().max(1.0);
    let map_slots = cfg.total_map_slots() as f64;
    let red_slots = cfg.total_reduce_slots() as f64;
    let inter_mb = m.intermediate_mb(spec.input_mb);
    let reducers = (spec.reducers as f64).max(1.0);
    let map_time = maps * m.map_secs(cfg.block_mb) / map_slots.min(maps);
    let shuffle_time = inter_mb / cfg.net_mbps / reducers.min(red_slots);
    let waves = (reducers / red_slots.min(reducers)).ceil();
    let red_time = m.reduce_secs(inter_mb / reducers) * waves;
    map_time + shuffle_time + red_time
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_grid_shape() {
        let t = JobTrace::fig2_grid(100.0);
        assert_eq!(t.len(), 25);
        // 2 GB -> 200 MB scaled
        assert!(t.jobs.iter().any(|j| (j.input_mb - 200.0).abs() < 1e-9));
        assert!(t.jobs.iter().any(|j| (j.input_mb - 1000.0).abs() < 1e-9));
    }

    #[test]
    fn table2_matches_paper_rows() {
        let t = JobTrace::table2(100.0);
        assert_eq!(t.len(), 5);
        let grep = t
            .jobs
            .iter()
            .find(|j| j.job_type == JobType::Grep)
            .unwrap();
        assert_eq!(grep.deadline_s, Some(650.0));
        assert!((grep.input_mb - 1000.0).abs() < 1e-9);
    }

    #[test]
    fn poisson_trace_sorted_and_deadlined() {
        let cfg = SimConfig::paper();
        let t = JobTrace::poisson(&cfg, 40, 30.0, 1.5..3.0, 9);
        assert_eq!(t.len(), 40);
        for w in t.jobs.windows(2) {
            assert!(w[0].submit_s <= w[1].submit_s);
        }
        for j in &t.jobs {
            let d = j.deadline_s.expect("all jobs deadlined");
            assert!(d > 0.0);
        }
    }

    #[test]
    fn poisson_deterministic() {
        let cfg = SimConfig::paper();
        let a = JobTrace::poisson(&cfg, 10, 30.0, 1.5..3.0, 4);
        let b = JobTrace::poisson(&cfg, 10, 30.0, 1.5..3.0, 4);
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x.job_type, y.job_type);
            assert_eq!(x.input_mb, y.input_mb);
            assert_eq!(x.submit_s, y.submit_s);
        }
    }

    #[test]
    fn estimate_positive_and_monotone() {
        let cfg = SimConfig::paper();
        let small = ideal_completion_estimate(&cfg, &JobSpec::new(JobType::Sort, 256.0));
        let large = ideal_completion_estimate(&cfg, &JobSpec::new(JobType::Sort, 2560.0));
        assert!(small > 0.0);
        assert!(large > small);
    }
}
