//! The paper's five evaluation workloads (§5) plus the machinery to
//! generate inputs and job traces.
//!
//! Each [`JobType`] carries two things:
//! * a **cost model** ([`CostModel`]) — per-MB map/reduce rates and the map
//!   selectivity (intermediate bytes out per input byte) that drive the
//!   simulator's timing in [`crate::config::ExecMode::Synthetic`] mode;
//! * a **real implementation** ([`exec`]) — actual map/reduce functions
//!   over generated corpus bytes, used in `ExecMode::Real` and by the
//!   correctness tests (output equivalence against a serial reference).
//!
//! [`trace`] additionally hosts the sweep harness's **arrival-rate axis**
//! ([`trace::Arrival`]): a Poisson λ multiplier plus a `burst` regime
//! that clusters submissions while preserving the long-run rate, and the
//! heterogeneity-aware [`trace::ideal_completion_estimate`] that keeps
//! generated deadlines honest under the `pm_profile` axis.
//!
//! ```
//! use vcsched::workloads::trace::Arrival;
//! use vcsched::util::Rng;
//!
//! // Doubling λ halves the mean inter-arrival gap; labels round-trip
//! // as stable artifact keys.
//! let a = Arrival::from_label("burst-x2").unwrap();
//! assert_eq!(a.rate, 2.0);
//! assert_eq!(a.label(), "burst-x2");
//! let times = a.times(10, 5.0, &mut Rng::new(42));
//! assert_eq!(times.len(), 10);
//! assert!(times.windows(2).all(|w| w[0] <= w[1]));
//! ```

pub mod corpus;
pub mod exec;
pub mod trace;

use std::fmt;

/// The five MapReduce applications evaluated in the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum JobType {
    /// Counts word occurrences (Hadoop sample app).
    WordCount,
    /// Sorts randomly generated records via the framework (identity
    /// map/reduce).
    Sort,
    /// Emits whether a word occurs — tiny intermediate data.
    Grep,
    /// Generates permutations of input strings — reduce-input heavy,
    /// large intermediate data (the paper's locality-insensitive case).
    PermutationGenerator,
    /// word -> sorted list of documents containing it.
    InvertedIndex,
}

pub const ALL_JOB_TYPES: [JobType; 5] = [
    JobType::WordCount,
    JobType::Sort,
    JobType::Grep,
    JobType::PermutationGenerator,
    JobType::InvertedIndex,
];

impl JobType {
    pub fn name(self) -> &'static str {
        match self {
            JobType::WordCount => "wordcount",
            JobType::Sort => "sort",
            JobType::Grep => "grep",
            JobType::PermutationGenerator => "permutation",
            JobType::InvertedIndex => "inverted_index",
        }
    }

    pub fn from_name(s: &str) -> Option<JobType> {
        ALL_JOB_TYPES.iter().copied().find(|t| t.name() == s)
    }

    /// Per-type cost model, calibrated so map tasks over 64 MB blocks
    /// finish "in less than a minute" (paper §5) on the simulated nodes.
    pub fn cost_model(self) -> CostModel {
        match self {
            // CPU-light scan; small intermediate output (word, 1) pairs
            // compress to a fraction of the input.
            JobType::WordCount => CostModel {
                map_mb_per_s: 4.0,
                reduce_mb_per_s: 25.0,
                selectivity: 0.20,
                output_ratio: 0.05,
                reduce_cpu_factor: 1.0,
            },
            // Identity map/reduce: all bytes cross the shuffle.
            JobType::Sort => CostModel {
                map_mb_per_s: 3.0,
                reduce_mb_per_s: 20.0,
                selectivity: 1.0,
                output_ratio: 1.0,
                reduce_cpu_factor: 1.2,
            },
            // Match-only: negligible intermediate data.
            JobType::Grep => CostModel {
                map_mb_per_s: 5.0,
                reduce_mb_per_s: 40.0,
                selectivity: 0.01,
                output_ratio: 0.005,
                reduce_cpu_factor: 0.8,
            },
            // Reduce-input heavy: intermediate blow-up (the paper calls
            // out "huge number of copy operations in shuffle phase").
            JobType::PermutationGenerator => CostModel {
                map_mb_per_s: 2.2,
                reduce_mb_per_s: 8.0,
                selectivity: 2.5,
                output_ratio: 1.5,
                reduce_cpu_factor: 1.6,
            },
            // Medium intermediate volume (word -> doc postings).
            JobType::InvertedIndex => CostModel {
                map_mb_per_s: 3.3,
                reduce_mb_per_s: 20.0,
                selectivity: 0.45,
                output_ratio: 0.30,
                reduce_cpu_factor: 1.1,
            },
        }
    }

    /// Default reduce-task count for an input of `input_mb` (roughly one
    /// reducer per GB, min 4 — mirrors common Hadoop practice and keeps
    /// the paper's slot numbers in range).
    pub fn default_reducers(self, input_mb: f64) -> u32 {
        let per_gb = match self {
            JobType::PermutationGenerator => 6.0, // heavy reducers, more of them
            JobType::Sort => 2.0,
            _ => 2.0,
        };
        ((input_mb / 1024.0 * per_gb).ceil() as u32).clamp(4, 48)
    }
}

impl fmt::Display for JobType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Synthetic-mode cost model for one job type.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Map processing rate over *local* input, MB/s per slot.
    pub map_mb_per_s: f64,
    /// Reduce processing rate over shuffled input, MB/s per slot.
    pub reduce_mb_per_s: f64,
    /// Intermediate bytes emitted per input byte by the map phase.
    pub selectivity: f64,
    /// Final output bytes per input byte.
    pub output_ratio: f64,
    /// Relative reduce CPU weight (sort/merge heaviness).
    pub reduce_cpu_factor: f64,
}

impl CostModel {
    /// Seconds a map task needs for a `block_mb` local block.
    pub fn map_secs(&self, block_mb: f64) -> f64 {
        block_mb / self.map_mb_per_s
    }

    /// Intermediate MB produced by a map task over `block_mb` input.
    pub fn intermediate_mb(&self, block_mb: f64) -> f64 {
        block_mb * self.selectivity
    }

    /// Seconds a reduce task needs to merge+reduce `shuffled_mb`.
    pub fn reduce_secs(&self, shuffled_mb: f64) -> f64 {
        shuffled_mb / self.reduce_mb_per_s * self.reduce_cpu_factor
    }
}

/// A submitted job description (what the user hands the JobTracker).
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub job_type: JobType,
    /// Total input size in MB.
    pub input_mb: f64,
    /// Number of reduce tasks.
    pub reducers: u32,
    /// Absolute completion-time goal in seconds from submission
    /// (None = best-effort; the deadline schedulers treat it as +inf).
    pub deadline_s: Option<f64>,
    /// Submission time offset from trace start, seconds.
    pub submit_s: f64,
}

impl JobSpec {
    pub fn new(job_type: JobType, input_mb: f64) -> Self {
        Self {
            job_type,
            input_mb,
            reducers: job_type.default_reducers(input_mb),
            deadline_s: None,
            submit_s: 0.0,
        }
    }

    pub fn with_deadline(mut self, d: f64) -> Self {
        self.deadline_s = Some(d);
        self
    }

    pub fn at(mut self, submit_s: f64) -> Self {
        self.submit_s = submit_s;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for t in ALL_JOB_TYPES {
            assert_eq!(JobType::from_name(t.name()), Some(t));
        }
        assert_eq!(JobType::from_name("nope"), None);
    }

    #[test]
    fn map_tasks_under_a_minute() {
        // Paper §5: "tasks of MapReduce jobs will be finished in less than
        // a minute" — calibration guard for every workload at 64 MB blocks.
        for t in ALL_JOB_TYPES {
            let m = t.cost_model();
            let secs = m.map_secs(64.0);
            assert!(
                secs > 3.0 && secs < 60.0,
                "{t}: map task {secs:.1}s out of calibrated range"
            );
        }
    }

    #[test]
    fn permutation_is_reduce_heavy() {
        // The paper's Fig. 3 rationale: permutation generator produces far
        // more intermediate data than the others.
        let perm = JobType::PermutationGenerator.cost_model();
        for t in ALL_JOB_TYPES {
            if t != JobType::PermutationGenerator {
                assert!(perm.selectivity >= t.cost_model().selectivity * 2.5);
            }
        }
    }

    #[test]
    fn grep_is_shuffle_light() {
        let g = JobType::Grep.cost_model();
        assert!(g.selectivity <= 0.01);
    }

    #[test]
    fn sort_is_identity() {
        let s = JobType::Sort.cost_model();
        assert_eq!(s.selectivity, 1.0);
        assert_eq!(s.output_ratio, 1.0);
    }

    #[test]
    fn default_reducers_scale() {
        assert!(
            JobType::WordCount.default_reducers(10240.0)
                >= JobType::WordCount.default_reducers(2048.0)
        );
        assert!(JobType::WordCount.default_reducers(64.0) >= 4);
        assert!(JobType::Sort.default_reducers(1e7) <= 64);
    }

    #[test]
    fn jobspec_builder() {
        let s = JobSpec::new(JobType::Grep, 2048.0)
            .with_deadline(650.0)
            .at(12.0);
        assert_eq!(s.job_type, JobType::Grep);
        assert_eq!(s.deadline_s, Some(650.0));
        assert_eq!(s.submit_s, 12.0);
        assert!(s.reducers >= 4);
    }
}
