//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! This is the only place the coordinator touches XLA. Artifacts are
//! produced once at build time by `python -m compile.aot` (L2 JAX model
//! calling the L1 Pallas kernels, lowered to HLO *text* — the xla crate's
//! xla_extension 0.5.1 rejects jax>=0.5 serialized protos). Each artifact
//! is compiled exactly once per process; executions reuse the compiled
//! executable and pre-sized input buffers, so the request path performs no
//! Python, no parsing and no recompilation.

mod executable;
mod predictor_xla;

pub use executable::{Artifact, ArtifactSet};
pub use predictor_xla::{PlacementQuery, XlaPredictor};

/// Padded batch shapes shared with `python/compile/model.py`.
/// Keep in sync with `MAX_JOBS` / `MAX_TASKS` / `MAX_NODES` there
/// (checked at load time against artifacts/MANIFEST.txt).
pub const MAX_JOBS: usize = 128;
/// Max pending map tasks scored per placement call.
pub const MAX_TASKS: usize = 256;
/// Max cluster nodes (VMs) per placement call.
pub const MAX_NODES: usize = 128;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constants_match_manifest_when_present() {
        let path = crate::util::repo_path("artifacts/MANIFEST.txt");
        let Ok(text) = std::fs::read_to_string(&path) else {
            eprintln!("skipping: no artifacts built");
            return;
        };
        let expect = format!(
            "MAX_JOBS={} MAX_TASKS={} MAX_NODES={}",
            MAX_JOBS, MAX_TASKS, MAX_NODES
        );
        assert!(
            text.contains(&expect),
            "artifact manifest disagrees with runtime constants: {text}"
        );
    }
}
