//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! This is the only place the coordinator touches XLA. Artifacts are
//! produced once at build time by `python -m compile.aot` (L2 JAX model
//! calling the L1 Pallas kernels, lowered to HLO *text* — the xla crate's
//! xla_extension 0.5.1 rejects jax>=0.5 serialized protos). Each artifact
//! is compiled exactly once per process; executions reuse the compiled
//! executable and pre-sized input buffers, so the request path performs no
//! Python, no parsing and no recompilation.
//!
//! The real PJRT path needs the `xla` (and `anyhow`) crates, which are not
//! available in the offline build environment; it is gated behind the
//! `xla` cargo feature. Without the feature an API-compatible `stub`
//! module is compiled instead: artifact loading returns `Err`, so every
//! caller takes its existing native-predictor fallback path.

#[cfg(feature = "xla")]
mod executable;
#[cfg(feature = "xla")]
mod predictor_xla;
#[cfg(not(feature = "xla"))]
mod stub;

#[cfg(feature = "xla")]
pub use executable::{Artifact, ArtifactSet};
#[cfg(feature = "xla")]
pub use predictor_xla::{PlacementQuery, XlaPredictor};
#[cfg(not(feature = "xla"))]
pub use stub::{Artifact, ArtifactSet, PlacementQuery, RuntimeError, XlaPredictor};

/// Padded batch shapes shared with `python/compile/model.py`.
/// Keep in sync with `MAX_JOBS` / `MAX_TASKS` / `MAX_NODES` there
/// (checked at load time against artifacts/MANIFEST.txt).
pub const MAX_JOBS: usize = 128;
/// Max pending map tasks scored per placement call.
pub const MAX_TASKS: usize = 256;
/// Max cluster nodes (VMs) per placement call.
pub const MAX_NODES: usize = 128;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constants_match_manifest_when_present() {
        let path = crate::util::repo_path("artifacts/MANIFEST.txt");
        let Ok(text) = std::fs::read_to_string(&path) else {
            eprintln!("skipping: no artifacts built");
            return;
        };
        let expect = format!(
            "MAX_JOBS={} MAX_TASKS={} MAX_NODES={}",
            MAX_JOBS, MAX_TASKS, MAX_NODES
        );
        assert!(
            text.contains(&expect),
            "artifact manifest disagrees with runtime constants: {text}"
        );
    }
}
