//! Offline stand-in for the PJRT runtime, compiled when the `xla` cargo
//! feature is off (the `xla`/`anyhow` crates are unavailable offline).
//!
//! Mirrors the public API of `executable.rs` / `predictor_xla.rs` exactly,
//! but every artifact load returns `Err`, so benches, examples and tests
//! that probe `XlaPredictor::load_default()` take their documented
//! native-predictor fallback path. Because an [`ArtifactSet`] can only be
//! obtained through the failing loaders, the `Predictor` methods are
//! unreachable by construction.

use std::path::Path;

use super::{MAX_NODES, MAX_TASKS};
use crate::predictor::{Eta, JobDemand, JobProgress, Predictor, SlotDemand};

/// Error produced by every stubbed load/execute entry point.
#[derive(Debug, Clone)]
pub struct RuntimeError(pub String);

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

pub type Result<T> = std::result::Result<T, RuntimeError>;

fn unavailable(what: &str) -> RuntimeError {
    RuntimeError(format!(
        "{what} requires the `xla` cargo feature (PJRT runtime not compiled \
         into this build; using the native predictor instead)"
    ))
}

/// Placeholder for one compiled artifact. Never constructed in stub builds
/// (the only constructor, [`ArtifactSet::load`], always fails).
pub struct Artifact {
    name: String,
    /// Wall time spent compiling (micro-bench observability parity).
    pub compile_time_ms: f64,
}

impl Artifact {
    pub fn name(&self) -> &str {
        &self.name
    }
}

/// The full set of predictor artifacts (stub: never loadable).
pub struct ArtifactSet {
    pub slot_solver: Artifact,
    pub locality: Artifact,
    pub estimator: Artifact,
    pub wave_estimator: Artifact,
}

impl ArtifactSet {
    pub fn load(dir: &Path) -> Result<Self> {
        Err(unavailable(&format!(
            "loading artifacts from {}",
            dir.display()
        )))
    }

    pub fn load_default() -> Result<Self> {
        Self::load(&crate::util::repo_path("artifacts"))
    }
}

/// Placement query for the locality artifact (Alg. 1 batched). Identical
/// layout to the real implementation so callers compile unchanged.
pub struct PlacementQuery {
    /// `has_data[t * MAX_NODES + n] = 1.0` iff task `t`'s input block is on
    /// node `n`. Row-major `[MAX_TASKS, MAX_NODES]`.
    pub has_data: Vec<f32>,
    /// Release-queue depth of each node's physical machine.
    pub rq: Vec<f32>,
    /// Assign-queue depth of each node's physical machine.
    pub aq: Vec<f32>,
    pub task_mask: Vec<f32>,
    pub node_mask: Vec<f32>,
    /// `(w_rq, w_aq)` — Alg. 1 preference weights.
    pub weights: [f32; 2],
}

impl PlacementQuery {
    pub fn new() -> Self {
        Self {
            has_data: vec![0.0; MAX_TASKS * MAX_NODES],
            rq: vec![0.0; MAX_NODES],
            aq: vec![0.0; MAX_NODES],
            task_mask: vec![0.0; MAX_TASKS],
            node_mask: vec![0.0; MAX_NODES],
            weights: [1.0, 0.5],
        }
    }

    pub fn clear(&mut self) {
        self.has_data.fill(0.0);
        self.rq.fill(0.0);
        self.aq.fill(0.0);
        self.task_mask.fill(0.0);
        self.node_mask.fill(0.0);
    }

    #[inline]
    pub fn set_has_data(&mut self, task: usize, node: usize) {
        self.has_data[task * MAX_NODES + node] = 1.0;
    }
}

impl Default for PlacementQuery {
    fn default() -> Self {
        Self::new()
    }
}

/// Predictor backed by the AOT artifacts (stub: never constructible, since
/// the only path to an [`ArtifactSet`] fails).
pub struct XlaPredictor {
    _set: ArtifactSet,
    /// Number of PJRT executions issued (micro-bench observability parity).
    pub calls: u64,
}

impl XlaPredictor {
    pub fn new(set: ArtifactSet) -> Self {
        Self { _set: set, calls: 0 }
    }

    pub fn load_default() -> Result<Self> {
        Ok(Self::new(ArtifactSet::load_default()?))
    }

    /// Alg. 1 placement: per-task best node (-1 when no replica reachable).
    pub fn place(&mut self, _q: &PlacementQuery) -> Result<Vec<i32>> {
        Err(unavailable("XlaPredictor::place"))
    }
}

impl Predictor for XlaPredictor {
    fn solve_slots(&mut self, _jobs: &[JobDemand]) -> Vec<SlotDemand> {
        unreachable!("stub XlaPredictor cannot be constructed (load always fails)")
    }

    fn estimate(&mut self, _jobs: &[JobProgress]) -> Vec<Eta> {
        unreachable!("stub XlaPredictor cannot be constructed (load always fails)")
    }

    fn estimate_wave(&mut self, _jobs: &[JobProgress]) -> Vec<Eta> {
        unreachable!("stub XlaPredictor cannot be constructed (load always fails)")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_fail_gracefully() {
        assert!(ArtifactSet::load_default().is_err());
        let err = XlaPredictor::load_default().err().expect("stub must fail");
        let msg = err.to_string();
        assert!(msg.contains("xla"), "{msg}");
    }

    #[test]
    fn placement_query_layout_matches_constants() {
        let mut q = PlacementQuery::new();
        assert_eq!(q.has_data.len(), MAX_TASKS * MAX_NODES);
        assert_eq!(q.rq.len(), MAX_NODES);
        q.set_has_data(1, 2);
        assert_eq!(q.has_data[MAX_NODES + 2], 1.0);
        q.clear();
        assert!(q.has_data.iter().all(|&x| x == 0.0));
    }
}
