//! One compiled PJRT executable per artifact, with f32-literal helpers.

use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{Context, Result};

/// A single HLO-text artifact compiled onto the PJRT CPU client.
pub struct Artifact {
    name: String,
    exe: xla::PjRtLoadedExecutable,
    /// Wall time spent compiling (exposed for the micro bench / EXPERIMENTS).
    pub compile_time_ms: f64,
}

impl Artifact {
    /// Load `path` (HLO text) and compile it on `client`.
    pub fn load(client: &xla::PjRtClient, path: &Path) -> Result<Self> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Self {
            name: path
                .file_name()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
            exe,
            compile_time_ms: t0.elapsed().as_secs_f64() * 1e3,
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with f32 vector inputs (each reshaped to `shapes[i]`) and
    /// return all outputs of the result tuple as f32 vectors.
    ///
    /// All our artifacts take f32 arrays and return a tuple; the one i32
    /// output (locality best_node) is converted on the python side? No —
    /// it stays i32; use [`Artifact::execute_mixed`] for that artifact.
    pub fn execute_f32(
        &self,
        inputs: &[(&[f32], &[usize])],
    ) -> Result<Vec<Vec<f32>>> {
        let literals = self.build_inputs(inputs)?;
        let result = self.run(&literals)?;
        let tuple = result.to_tuple()?;
        tuple
            .into_iter()
            .map(|lit| lit.to_vec::<f32>().map_err(Into::into))
            .collect()
    }

    /// Execute and decode a mixed (i32 first, f32 rest) result tuple —
    /// the shape of the locality artifact's (best_node, best_score).
    pub fn execute_i32_f32(
        &self,
        inputs: &[(&[f32], &[usize])],
    ) -> Result<(Vec<i32>, Vec<f32>)> {
        let literals = self.build_inputs(inputs)?;
        let result = self.run(&literals)?;
        let mut tuple = result.to_tuple()?;
        anyhow::ensure!(tuple.len() == 2, "expected 2-tuple from {}", self.name);
        let scores = tuple.pop().unwrap().to_vec::<f32>()?;
        let nodes = tuple.pop().unwrap().to_vec::<i32>()?;
        Ok((nodes, scores))
    }

    fn build_inputs(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<xla::Literal>> {
        inputs
            .iter()
            .map(|(data, shape)| {
                let lit = xla::Literal::vec1(data);
                if shape.len() == 1 {
                    debug_assert_eq!(data.len(), shape[0]);
                    Ok(lit)
                } else {
                    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                    lit.reshape(&dims).map_err(Into::into)
                }
            })
            .collect()
    }

    fn run(&self, literals: &[xla::Literal]) -> Result<xla::Literal> {
        let outs = self.exe.execute::<xla::Literal>(literals)?;
        anyhow::ensure!(!outs.is_empty() && !outs[0].is_empty(), "empty result");
        Ok(outs[0][0].to_literal_sync()?)
    }
}

/// The full set of predictor artifacts, plus the shared PJRT client.
pub struct ArtifactSet {
    pub slot_solver: Artifact,
    pub locality: Artifact,
    pub estimator: Artifact,
    pub wave_estimator: Artifact,
}

impl ArtifactSet {
    /// Load all three artifacts from `dir` (usually `artifacts/`).
    pub fn load(dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            slot_solver: Artifact::load(&client, &dir.join("slot_solver.hlo.txt"))?,
            locality: Artifact::load(&client, &dir.join("locality.hlo.txt"))?,
            estimator: Artifact::load(&client, &dir.join("estimator.hlo.txt"))?,
            wave_estimator: Artifact::load(&client, &dir.join("wave_estimator.hlo.txt"))?,
        })
    }

    /// Load from the repo-relative default directory.
    pub fn load_default() -> Result<Self> {
        Self::load(&default_artifact_dir())
    }
}

/// `artifacts/` resolved against the crate root (works from tests, benches
/// and examples regardless of cwd).
pub fn default_artifact_dir() -> PathBuf {
    crate::util::repo_path("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> Option<ArtifactSet> {
        let dir = default_artifact_dir();
        if !dir.join("slot_solver.hlo.txt").exists() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return None;
        }
        Some(ArtifactSet::load(&dir).expect("artifact load"))
    }

    #[test]
    fn slot_solver_executes() {
        let Some(set) = artifacts() else { return };
        let j = crate::runtime::MAX_JOBS;
        let mut a = vec![0f32; j];
        let mut b = vec![0f32; j];
        let mut c = vec![0f32; j];
        let mut m = vec![0f32; j];
        a[0] = 100.0;
        b[0] = 50.0;
        c[0] = 10.0;
        m[0] = 1.0;
        let shape = [j];
        let outs = set
            .slot_solver
            .execute_f32(&[
                (&a, &shape[..]),
                (&b, &shape[..]),
                (&c, &shape[..]),
                (&m, &shape[..]),
            ])
            .unwrap();
        assert_eq!(outs.len(), 2);
        // sqrt(100)*(10+7.071)/10 = 17.07 -> 18 ; sqrt(50)*17.071/10 -> 13
        assert_eq!(outs[0][0], 18.0);
        assert_eq!(outs[1][0], 13.0);
        assert!(outs[0][1..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn locality_executes() {
        let Some(set) = artifacts() else { return };
        let (t, n) = (crate::runtime::MAX_TASKS, crate::runtime::MAX_NODES);
        let mut hd = vec![0f32; t * n];
        hd[5] = 1.0; // task 0 has data on node 5
        hd[9] = 1.0; // ... and node 9
        let mut rq = vec![0f32; n];
        rq[9] = 4.0;
        let aq = vec![0f32; n];
        let mut tm = vec![0f32; t];
        tm[0] = 1.0;
        let nm = vec![1f32; n];
        let w = [1.0f32, 0.5];
        let (nodes, scores) = set
            .locality
            .execute_i32_f32(&[
                (&hd, &[t, n][..]),
                (&rq, &[n][..]),
                (&aq, &[n][..]),
                (&tm, &[t][..]),
                (&nm, &[n][..]),
                (&w, &[2][..]),
            ])
            .unwrap();
        assert_eq!(nodes[0], 9, "deepest release queue must win");
        assert_eq!(scores[0], 4.0);
        assert_eq!(nodes[1], -1, "masked task must be infeasible");
    }

    #[test]
    fn estimator_executes() {
        let Some(set) = artifacts() else { return };
        let j = crate::runtime::MAX_JOBS;
        let shape = [j];
        let mk = |v0: f32| {
            let mut v = vec![0f32; j];
            v[0] = v0;
            v
        };
        let args = [
            mk(10.0), // rem_map
            mk(4.0),  // rem_red
            mk(2.0),  // t_m
            mk(2.0),  // t_r
            mk(0.1),  // t_s
            mk(2.0),  // n_m
            mk(2.0),  // n_r
            mk(4.0),  // v_r
            mk(30.0), // deadline
            mk(0.0),  // elapsed
            mk(1.0),  // mask
        ];
        let refs: Vec<(&[f32], &[usize])> =
            args.iter().map(|v| (v.as_slice(), &shape[..])).collect();
        let outs = set.estimator.execute_f32(&refs).unwrap();
        assert!((outs[0][0] - 18.0).abs() < 1e-4, "eta {}", outs[0][0]);
        assert!((outs[1][0] - 12.0).abs() < 1e-4, "urgency {}", outs[1][0]);
    }
}
