//! XLA-artifact backend for the Resource Predictor + Alg. 1 placement.
//!
//! One PJRT execution per heartbeat per question; inputs are packed into
//! pre-allocated padded buffers (no per-call allocation on the hot path
//! beyond the PJRT literals themselves).

use anyhow::Result;

use super::{ArtifactSet, MAX_JOBS, MAX_NODES, MAX_TASKS};
use crate::predictor::{abc, Eta, JobDemand, JobProgress, Predictor, SlotDemand};

/// Placement query for the locality artifact (Alg. 1 batched).
pub struct PlacementQuery {
    /// `has_data[t * MAX_NODES + n] = 1.0` iff task `t`'s input block is on
    /// node `n`. Row-major `[MAX_TASKS, MAX_NODES]`.
    pub has_data: Vec<f32>,
    /// Release-queue depth of each node's physical machine.
    pub rq: Vec<f32>,
    /// Assign-queue depth of each node's physical machine.
    pub aq: Vec<f32>,
    pub task_mask: Vec<f32>,
    pub node_mask: Vec<f32>,
    /// `(w_rq, w_aq)` — Alg. 1 preference weights.
    pub weights: [f32; 2],
}

impl PlacementQuery {
    pub fn new() -> Self {
        Self {
            has_data: vec![0.0; MAX_TASKS * MAX_NODES],
            rq: vec![0.0; MAX_NODES],
            aq: vec![0.0; MAX_NODES],
            task_mask: vec![0.0; MAX_TASKS],
            node_mask: vec![0.0; MAX_NODES],
            weights: [1.0, 0.5],
        }
    }

    pub fn clear(&mut self) {
        self.has_data.fill(0.0);
        self.rq.fill(0.0);
        self.aq.fill(0.0);
        self.task_mask.fill(0.0);
        self.node_mask.fill(0.0);
    }

    #[inline]
    pub fn set_has_data(&mut self, task: usize, node: usize) {
        self.has_data[task * MAX_NODES + node] = 1.0;
    }
}

impl Default for PlacementQuery {
    fn default() -> Self {
        Self::new()
    }
}

/// Predictor backed by the three AOT artifacts.
pub struct XlaPredictor {
    set: ArtifactSet,
    // Pre-sized staging buffers (reused across calls).
    buf_a: Vec<f32>,
    buf_b: Vec<f32>,
    buf_c: Vec<f32>,
    buf_mask: Vec<f32>,
    est: [Vec<f32>; 11],
    /// Number of PJRT executions issued (micro-bench observability).
    pub calls: u64,
}

impl XlaPredictor {
    pub fn new(set: ArtifactSet) -> Self {
        Self {
            set,
            buf_a: vec![0.0; MAX_JOBS],
            buf_b: vec![0.0; MAX_JOBS],
            buf_c: vec![0.0; MAX_JOBS],
            buf_mask: vec![0.0; MAX_JOBS],
            est: std::array::from_fn(|_| vec![0.0; MAX_JOBS]),
            calls: 0,
        }
    }

    pub fn load_default() -> Result<Self> {
        Ok(Self::new(ArtifactSet::load_default()?))
    }

    /// Alg. 1 placement: per-task best node (-1 when no replica reachable).
    pub fn place(&mut self, q: &PlacementQuery) -> Result<Vec<i32>> {
        self.calls += 1;
        let (nodes, _scores) = self.set.locality.execute_i32_f32(&[
            (&q.has_data, &[MAX_TASKS, MAX_NODES][..]),
            (&q.rq, &[MAX_NODES][..]),
            (&q.aq, &[MAX_NODES][..]),
            (&q.task_mask, &[MAX_TASKS][..]),
            (&q.node_mask, &[MAX_NODES][..]),
            (&q.weights, &[2][..]),
        ])?;
        Ok(nodes)
    }

    fn solve_chunk(&mut self, jobs: &[JobDemand], out: &mut Vec<SlotDemand>) -> Result<()> {
        debug_assert!(jobs.len() <= MAX_JOBS);
        self.buf_a.fill(0.0);
        self.buf_b.fill(0.0);
        self.buf_c.fill(0.0);
        self.buf_mask.fill(0.0);
        for (i, d) in jobs.iter().enumerate() {
            let (a, b, c) = abc(d);
            self.buf_a[i] = a as f32;
            self.buf_b[i] = b as f32;
            self.buf_c[i] = c as f32;
            self.buf_mask[i] = 1.0;
        }
        self.calls += 1;
        let shape = [MAX_JOBS];
        let outs = self.set.slot_solver.execute_f32(&[
            (&self.buf_a, &shape[..]),
            (&self.buf_b, &shape[..]),
            (&self.buf_c, &shape[..]),
            (&self.buf_mask, &shape[..]),
        ])?;
        for i in 0..jobs.len() {
            let (a, b, c) = abc(&jobs[i]);
            let infeasible = c <= 0.0;
            out.push(SlotDemand {
                map_slots: outs[0][i] as u32,
                reduce_slots: outs[1][i] as u32,
                // The kernel returns 0 slots for infeasible entries; we also
                // flag entries whose map/reduce work is zero as feasible.
                infeasible: infeasible && (a > 0.0 || b > 0.0 || c <= 0.0),
            });
        }
        Ok(())
    }

    fn estimate_chunk_with(
        &mut self,
        jobs: &[JobProgress],
        out: &mut Vec<Eta>,
        wave: bool,
    ) -> Result<()> {
        debug_assert!(jobs.len() <= MAX_JOBS);
        for buf in self.est.iter_mut() {
            buf.fill(0.0);
        }
        for (i, p) in jobs.iter().enumerate() {
            self.est[0][i] = p.rem_map as f32;
            self.est[1][i] = p.rem_reduce as f32;
            self.est[2][i] = p.t_map as f32;
            self.est[3][i] = p.t_reduce as f32;
            self.est[4][i] = p.t_shuffle as f32;
            self.est[5][i] = p.map_slots as f32;
            self.est[6][i] = p.reduce_slots as f32;
            self.est[7][i] = p.reduce_tasks as f32;
            self.est[8][i] = p.deadline as f32;
            self.est[9][i] = p.elapsed as f32;
            self.est[10][i] = 1.0;
        }
        self.calls += 1;
        let shape = [MAX_JOBS];
        let inputs: Vec<(&[f32], &[usize])> =
            self.est.iter().map(|v| (v.as_slice(), &shape[..])).collect();
        let artifact = if wave {
            &self.set.wave_estimator
        } else {
            &self.set.estimator
        };
        let outs = artifact.execute_f32(&inputs)?;
        for i in 0..jobs.len() {
            out.push(Eta {
                eta: outs[0][i] as f64,
                slack: outs[1][i] as f64,
            });
        }
        Ok(())
    }
}

impl Predictor for XlaPredictor {
    fn solve_slots(&mut self, jobs: &[JobDemand]) -> Vec<SlotDemand> {
        let mut out = Vec::with_capacity(jobs.len());
        for chunk in jobs.chunks(MAX_JOBS) {
            self.solve_chunk(chunk, &mut out)
                .expect("PJRT slot_solver execution failed");
        }
        out
    }

    fn estimate(&mut self, jobs: &[JobProgress]) -> Vec<Eta> {
        let mut out = Vec::with_capacity(jobs.len());
        for chunk in jobs.chunks(MAX_JOBS) {
            self.estimate_chunk_with(chunk, &mut out, false)
                .expect("PJRT estimator execution failed");
        }
        out
    }

    fn estimate_wave(&mut self, jobs: &[JobProgress]) -> Vec<Eta> {
        let mut out = Vec::with_capacity(jobs.len());
        for chunk in jobs.chunks(MAX_JOBS) {
            self.estimate_chunk_with(chunk, &mut out, true)
                .expect("PJRT wave-estimator execution failed");
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::NativePredictor;

    fn predictor() -> Option<XlaPredictor> {
        match XlaPredictor::load_default() {
            Ok(p) => Some(p),
            Err(e) => {
                eprintln!("skipping XLA predictor tests: {e}");
                None
            }
        }
    }

    #[test]
    fn agrees_with_native_on_slots() {
        let Some(mut xp) = predictor() else { return };
        let mut rng = crate::util::Rng::new(21);
        let jobs: Vec<JobDemand> = (0..200)
            .map(|_| JobDemand {
                map_tasks: rng.range_f64(1.0, 400.0).floor(),
                reduce_tasks: rng.range_f64(0.0, 48.0).floor(),
                t_map: rng.range_f64(0.5, 80.0),
                t_reduce: rng.range_f64(0.5, 80.0),
                t_shuffle: rng.range_f64(0.0, 0.005),
                deadline: rng.range_f64(-50.0, 4000.0),
            })
            .collect();
        let got = xp.solve_slots(&jobs);
        let want = NativePredictor.solve_slots(&jobs);
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(
                (g.map_slots, g.reduce_slots),
                (w.map_slots, w.reduce_slots),
                "job {i}: {:?}",
                jobs[i]
            );
        }
    }

    #[test]
    fn agrees_with_native_on_eta() {
        let Some(mut xp) = predictor() else { return };
        let mut rng = crate::util::Rng::new(22);
        let jobs: Vec<JobProgress> = (0..150)
            .map(|_| JobProgress {
                rem_map: rng.range_f64(0.0, 200.0).floor(),
                rem_reduce: rng.range_f64(0.0, 50.0).floor(),
                t_map: rng.range_f64(0.5, 60.0),
                t_reduce: rng.range_f64(0.5, 60.0),
                t_shuffle: rng.range_f64(0.0, 0.01),
                map_slots: rng.range_f64(0.0, 32.0).floor(),
                reduce_slots: rng.range_f64(0.0, 32.0).floor(),
                reduce_tasks: rng.range_f64(0.0, 50.0).floor(),
                deadline: rng.range_f64(10.0, 5000.0),
                elapsed: rng.range_f64(0.0, 1000.0),
            })
            .collect();
        let got = xp.estimate(&jobs);
        let want = NativePredictor.estimate(&jobs);
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            let tol = 1e-3 * (1.0 + w.eta.abs());
            assert!((g.eta - w.eta).abs() < tol, "job {i}: {g:?} vs {w:?}");
            let tol = 1e-3 * (1.0 + w.slack.abs()) + 0.25;
            assert!((g.slack - w.slack).abs() < tol, "job {i}: {g:?} vs {w:?}");
        }
    }

    #[test]
    fn placement_prefers_release_queue() {
        let Some(mut xp) = predictor() else { return };
        let mut q = PlacementQuery::new();
        q.set_has_data(0, 3);
        q.set_has_data(0, 9);
        q.rq[9] = 4.0;
        q.task_mask[0] = 1.0;
        q.node_mask.fill(1.0);
        let nodes = xp.place(&q).unwrap();
        assert_eq!(nodes[0], 9);
        assert_eq!(nodes[1], -1);
    }

    #[test]
    fn wave_agrees_with_native() {
        let Some(mut xp) = predictor() else { return };
        let mut rng = crate::util::Rng::new(31);
        let jobs: Vec<JobProgress> = (0..100)
            .map(|_| JobProgress {
                rem_map: rng.range_f64(0.0, 200.0).floor(),
                rem_reduce: rng.range_f64(0.0, 50.0).floor(),
                t_map: rng.range_f64(0.5, 60.0),
                t_reduce: rng.range_f64(0.5, 60.0),
                t_shuffle: rng.range_f64(0.0, 0.01),
                map_slots: rng.range_f64(1.0, 32.0).floor(),
                reduce_slots: rng.range_f64(1.0, 32.0).floor(),
                reduce_tasks: rng.range_f64(0.0, 50.0).floor(),
                deadline: rng.range_f64(10.0, 5000.0),
                elapsed: rng.range_f64(0.0, 1000.0),
            })
            .collect();
        let got = xp.estimate_wave(&jobs);
        let want = NativePredictor.estimate_wave(&jobs);
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            let tol = 1e-3 * (1.0 + w.eta.abs());
            assert!((g.eta - w.eta).abs() < tol, "job {i}: {g:?} vs {w:?}");
        }
    }

    #[test]
    fn multi_chunk_batches() {
        let Some(mut xp) = predictor() else { return };
        let jobs: Vec<JobDemand> = (0..(MAX_JOBS * 2 + 7))
            .map(|i| JobDemand {
                map_tasks: (i % 50 + 1) as f64,
                reduce_tasks: 4.0,
                t_map: 2.0,
                t_reduce: 2.0,
                t_shuffle: 0.0,
                deadline: 100.0,
            })
            .collect();
        let got = xp.solve_slots(&jobs);
        assert_eq!(got.len(), jobs.len());
        let want = NativePredictor.solve_slots(&jobs);
        assert_eq!(got, want);
    }
}
