//! Append-only result journal: the persistence layer behind resumable
//! sweeps.
//!
//! Every completed scenario is appended as one line keyed by a **content
//! hash of the resolved scenario** (axes + derived stream seed + the trace
//! parameters that shape the run) — not by grid position. An interrupted
//! or *extended* grid therefore re-runs only the cells whose inputs
//! actually changed: cells whose hash is already journaled are loaded
//! back instead of re-simulated.
//!
//! The serialized report round-trips **exactly**: Rust's `{}` formatting
//! of `f64` emits the shortest string that parses back to the identical
//! bit pattern, so aggregates computed from resumed results are
//! byte-identical to an uninterrupted run (`tests/sweep_resume.rs` holds
//! this in place). Torn trailing lines from a killed process are ignored
//! on load.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use crate::mapreduce::JobId;
use crate::metrics::{FailureStats, JobRecord, RunMetrics, StreamAgg};
use crate::sim::SimTime;
use crate::util::stats::{QuantileSketch, Summary};
use crate::workloads::JobType;

use super::grid::{Scenario, ScenarioGrid};

/// Journal format version tag; bump on any line-format change so stale
/// journals are skipped instead of mis-parsed. (v2: tiered locality —
/// per-job `local,rack,remote` counts replaced `local,nonlocal`. v3:
/// failure/speculation counters appended after `predictor_calls`, and the
/// failure-model label joined the content hash. v4: the workload and
/// stream-metrics axes joined the content hash, and streamed runs journal
/// their constant-memory accumulators as a `@`-prefixed jobs field. v5:
/// reduce-speculation counters appended to the failure-counter field —
/// 7 counters became 10 — and the failures axis label may now name a
/// replayed trace file, `trace:<path>`.)
const VERSION: &str = "v5";

/// FNV-1a 64-bit over a byte string (stable across platforms/runs).
fn fnv64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Content hash identifying one scenario's full simulation input. Folds
/// in every axis value, the derived stream seed, the grid's trace
/// parameters — everything `run_scenario` depends on — plus the crate
/// version, so journals written by an older simulator are invalidated on
/// release bumps rather than silently replayed. (Within one version,
/// behavior-changing source edits still require `--fresh`; see the
/// README's resumable-sweeps section.)
pub fn scenario_key(grid: &ScenarioGrid, sc: &Scenario) -> u64 {
    let canon = format!(
        "{}|{}|{}|{}|{:016x}|{}|{}|{}|{}|{}|{}|{:016x}|{:016x}|{:016x}|{:016x}|{}|{}",
        env!("CARGO_PKG_VERSION"),
        sc.scheduler.name(),
        sc.mix.name(),
        sc.pms,
        sc.scale.to_bits(),
        sc.profile.name(),
        sc.topology.label(),
        sc.arrival.label(),
        sc.failures.label(),
        sc.replicate,
        grid.jobs_per_scenario,
        sc.stream_seed,
        grid.mean_gap_s.to_bits(),
        grid.deadline_factor.0.to_bits(),
        grid.deadline_factor.1.to_bits(),
        sc.workload.label(),
        sc.stream_metrics,
    );
    fnv64(canon.as_bytes())
}

/// Handle on a journal file. The file need not exist until the first
/// append; loads of a missing file return an empty map.
#[derive(Clone, Debug)]
pub struct Journal {
    path: PathBuf,
}

impl Journal {
    pub fn new(path: impl Into<PathBuf>) -> Self {
        Self { path: path.into() }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Load every parseable entry. Later duplicates win; malformed lines
    /// (e.g. a torn final line from a killed sweep) are skipped.
    pub fn load(&self) -> BTreeMap<u64, RunMetrics> {
        let mut out = BTreeMap::new();
        let Ok(text) = std::fs::read_to_string(&self.path) else {
            return out;
        };
        for line in text.lines() {
            if let Some((key, report)) = parse_line(line) {
                out.insert(key, report);
            }
        }
        out
    }

    /// Append one completed scenario. The line is written with a single
    /// `write_all` so concurrent appenders (worker threads serialized by
    /// the runner) never interleave partial lines.
    pub fn append(&self, key: u64, report: &RunMetrics) -> std::io::Result<()> {
        if let Some(dir) = self.path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)?;
        f.write_all(render_line(key, report).as_bytes())
    }

    /// Delete the journal file (the `--fresh` path). Missing file is ok.
    pub fn clear(&self) -> std::io::Result<()> {
        match std::fs::remove_file(&self.path) {
            Err(e) if e.kind() != std::io::ErrorKind::NotFound => Err(e),
            _ => Ok(()),
        }
    }
}

fn render_line(key: u64, r: &RunMetrics) -> String {
    let mut jobs = String::new();
    if let Some(agg) = r.stream_agg() {
        jobs = render_stream(agg);
    }
    for (i, j) in r.jobs.iter().enumerate() {
        if i > 0 {
            jobs.push(';');
        }
        let _ = write!(
            jobs,
            "{},{},{},{},{},{},{},{},{},{},{},{},{},{}",
            j.id.0,
            j.job_type.name(),
            j.input_mb,
            j.submitted.as_millis(),
            j.finished.as_millis(),
            j.completion_s,
            j.map_phase_s,
            opt_f64(j.deadline_s),
            opt_bool(j.met_deadline),
            j.local_maps,
            j.rack_maps,
            j.remote_maps,
            j.maps,
            j.reduces
        );
    }
    // The explicit job count plus the terminal "ok" sentinel reject lines
    // truncated by a mid-write kill even when the cut lands exactly on a
    // record boundary (every field before the sentinel would still parse).
    let f = &r.failures;
    format!(
        "{VERSION}\t{key:016x}\t{}\t{}\t{}\t{}\t{}\t{}\t{},{},{},{},{},{},{},{},{},{}\t{}\t{jobs}\tok\n",
        r.scheduler,
        r.makespan_s,
        r.hotplugs,
        r.heartbeats,
        r.events,
        r.predictor_calls,
        f.pm_crashes,
        f.speculative_launches,
        f.speculative_wins,
        f.speculative_kills,
        f.speculative_reduce_launches,
        f.speculative_reduce_wins,
        f.speculative_reduce_kills,
        f.reexecuted_tasks,
        f.blocks_relocated,
        f.blocks_lost,
        r.jobs.len()
    )
}

/// Streamed runs journal the accumulators, not per-job records: a `@`-
/// prefixed jobs field carrying the raw Welford moments (`{}` emits the
/// shortest string that parses back to the identical f64 bits, so the
/// summary round-trips exactly), the encoded quantile sketch, and the
/// integer tier/deadline counters. The explicit job count on a streamed
/// line is 0.
fn render_stream(a: &StreamAgg) -> String {
    let c = &a.completion;
    format!(
        "@{}|{},{},{},{},{},{}|{}|{},{},{},{},{},{}",
        a.completed,
        c.count(),
        c.mean(),
        c.m2(),
        c.min(),
        c.max(),
        c.sum(),
        a.sketch.encode(),
        a.local_maps,
        a.rack_maps,
        a.remote_maps,
        a.deadlined,
        a.missed,
        a.max_finished_s,
    )
}

fn parse_stream(s: &str) -> Option<StreamAgg> {
    let body = s.strip_prefix('@')?;
    let mut parts = body.split('|');
    let completed: u64 = parts.next()?.parse().ok()?;
    let sf: Vec<&str> = parts.next()?.split(',').collect();
    if sf.len() != 6 {
        return None;
    }
    let completion = Summary::from_raw(
        sf[0].parse().ok()?,
        sf[1].parse().ok()?,
        sf[2].parse().ok()?,
        sf[3].parse().ok()?,
        sf[4].parse().ok()?,
        sf[5].parse().ok()?,
    );
    let sketch = QuantileSketch::decode(parts.next()?)?;
    let cf: Vec<&str> = parts.next()?.split(',').collect();
    if cf.len() != 6 || parts.next().is_some() {
        return None;
    }
    Some(StreamAgg {
        completed,
        completion,
        sketch,
        local_maps: cf[0].parse().ok()?,
        rack_maps: cf[1].parse().ok()?,
        remote_maps: cf[2].parse().ok()?,
        deadlined: cf[3].parse().ok()?,
        missed: cf[4].parse().ok()?,
        max_finished_s: cf[5].parse().ok()?,
    })
}

fn opt_f64(v: Option<f64>) -> String {
    match v {
        Some(x) => format!("{x}"),
        None => "-".to_string(),
    }
}

fn opt_bool(v: Option<bool>) -> String {
    match v {
        Some(true) => "1".to_string(),
        Some(false) => "0".to_string(),
        None => "-".to_string(),
    }
}

fn parse_line(line: &str) -> Option<(u64, RunMetrics)> {
    let mut parts = line.split('\t');
    if parts.next()? != VERSION {
        return None;
    }
    let key = u64::from_str_radix(parts.next()?, 16).ok()?;
    let scheduler = parts.next()?.to_string();
    let makespan_s: f64 = parts.next()?.parse().ok()?;
    let hotplugs: u64 = parts.next()?.parse().ok()?;
    let heartbeats: u64 = parts.next()?.parse().ok()?;
    let events: u64 = parts.next()?.parse().ok()?;
    let predictor_calls: u64 = parts.next()?.parse().ok()?;
    let failures = parse_failures(parts.next()?)?;
    let njobs: usize = parts.next()?.parse().ok()?;
    let jobs_field = parts.next()?;
    if parts.next()? != "ok" || parts.next().is_some() {
        return None; // truncated mid-write or trailing garbage
    }
    let mut jobs = Vec::new();
    let mut stream = None;
    if jobs_field.starts_with('@') {
        if njobs != 0 {
            return None; // streamed lines carry no per-job records
        }
        stream = Some(parse_stream(jobs_field)?);
    } else if !jobs_field.is_empty() {
        for rec in jobs_field.split(';') {
            jobs.push(parse_job(rec)?);
        }
    }
    if jobs.len() != njobs {
        return None; // torn exactly on a record boundary
    }
    Some((
        key,
        RunMetrics {
            scheduler,
            jobs,
            stream,
            makespan_s,
            hotplugs,
            heartbeats,
            events,
            predictor_calls,
            failures,
            // Host wall-clock is deliberately not journaled (artifacts
            // exclude it; see harness::agg docs).
            wall_s: 0.0,
        },
    ))
}

fn parse_failures(s: &str) -> Option<FailureStats> {
    let f: Vec<&str> = s.split(',').collect();
    if f.len() != 10 {
        return None;
    }
    Some(FailureStats {
        pm_crashes: f[0].parse().ok()?,
        speculative_launches: f[1].parse().ok()?,
        speculative_wins: f[2].parse().ok()?,
        speculative_kills: f[3].parse().ok()?,
        speculative_reduce_launches: f[4].parse().ok()?,
        speculative_reduce_wins: f[5].parse().ok()?,
        speculative_reduce_kills: f[6].parse().ok()?,
        reexecuted_tasks: f[7].parse().ok()?,
        blocks_relocated: f[8].parse().ok()?,
        blocks_lost: f[9].parse().ok()?,
    })
}

fn parse_job(rec: &str) -> Option<JobRecord> {
    let f: Vec<&str> = rec.split(',').collect();
    if f.len() != 14 {
        return None;
    }
    Some(JobRecord {
        id: JobId(f[0].parse().ok()?),
        job_type: JobType::from_name(f[1])?,
        input_mb: f[2].parse().ok()?,
        submitted: SimTime::from_millis(f[3].parse().ok()?),
        finished: SimTime::from_millis(f[4].parse().ok()?),
        completion_s: f[5].parse().ok()?,
        map_phase_s: f[6].parse().ok()?,
        deadline_s: parse_opt_f64(f[7])?,
        met_deadline: parse_opt_bool(f[8])?,
        local_maps: f[9].parse().ok()?,
        rack_maps: f[10].parse().ok()?,
        remote_maps: f[11].parse().ok()?,
        maps: f[12].parse().ok()?,
        reduces: f[13].parse().ok()?,
    })
}

fn parse_opt_f64(s: &str) -> Option<Option<f64>> {
    if s == "-" {
        Some(None)
    } else {
        s.parse().ok().map(Some)
    }
}

fn parse_opt_bool(s: &str) -> Option<Option<bool>> {
    match s {
        "-" => Some(None),
        "1" => Some(Some(true)),
        "0" => Some(Some(false)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::{run_scenario, ScenarioGrid};

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("vcsched-journal-{}-{name}", std::process::id()));
        p
    }

    fn one_result() -> (ScenarioGrid, u64, RunMetrics) {
        let mut g = ScenarioGrid::quick();
        g.jobs_per_scenario = 3;
        let sc = g.scenarios().remove(0);
        let key = scenario_key(&g, &sc);
        let r = run_scenario(&g, &sc);
        (g, key, r.report)
    }

    #[test]
    fn report_roundtrips_exactly() {
        let (_g, key, report) = one_result();
        let line = render_line(key, &report);
        let (k2, parsed) = parse_line(line.trim_end()).expect("parse back");
        assert_eq!(k2, key);
        assert_eq!(parsed.scheduler, report.scheduler);
        assert_eq!(parsed.makespan_s.to_bits(), report.makespan_s.to_bits());
        assert_eq!(parsed.events, report.events);
        assert_eq!(parsed.failures, report.failures);
        assert_eq!(parsed.jobs.len(), report.jobs.len());
        for (a, b) in parsed.jobs.iter().zip(&report.jobs) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.job_type, b.job_type);
            assert_eq!(a.completion_s.to_bits(), b.completion_s.to_bits());
            assert_eq!(a.map_phase_s.to_bits(), b.map_phase_s.to_bits());
            assert_eq!(a.deadline_s.map(f64::to_bits), b.deadline_s.map(f64::to_bits));
            assert_eq!(a.met_deadline, b.met_deadline);
            assert_eq!(a.submitted, b.submitted);
            assert_eq!(a.finished, b.finished);
            assert_eq!(
                (a.local_maps, a.rack_maps, a.remote_maps, a.maps, a.reduces),
                (b.local_maps, b.rack_maps, b.remote_maps, b.maps, b.reduces)
            );
        }
    }

    #[test]
    fn load_skips_torn_and_foreign_lines() {
        let (_g, key, report) = one_result();
        let path = tmp("torn");
        let j = Journal::new(&path);
        let _ = j.clear();
        j.append(key, &report).unwrap();
        // Simulate a kill mid-write: torn lines and noise. The nastiest
        // tear lands exactly on a job-record boundary — every field still
        // parses, so only the count/sentinel checks can reject it.
        {
            use std::io::Write as _;
            let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(b"v5\tdeadbeef\tfair\t12.5").unwrap(); // truncated early
            f.write_all(b"\nv4\tdeadbeef\tfair\t12.5\tok\n").unwrap(); // stale version
            f.write_all(b"\nnot a journal line\n").unwrap();
            let full = render_line(0xfeed_f00d, &report);
            let boundary = full.rfind(';').expect("multi-job line");
            f.write_all(full[..boundary].as_bytes()).unwrap(); // torn on ';'
            f.write_all(b"\n").unwrap();
        }
        let loaded = j.load();
        assert_eq!(loaded.len(), 1, "only the intact line survives");
        assert!(loaded.contains_key(&key));
        j.clear().unwrap();
        assert!(j.load().is_empty());
    }

    #[test]
    fn streamed_report_roundtrips_exactly() {
        // A streaming-mode report journals its accumulators; parsing the
        // line back must restore every derived metric bit for bit.
        let mut g = ScenarioGrid::quick();
        g.jobs_per_scenario = 6;
        g.stream_metrics = true;
        let sc = g.scenarios().remove(0);
        let key = scenario_key(&g, &sc);
        let report = run_scenario(&g, &sc).report;
        let agg = report.stream_agg().expect("stream_metrics run must stream");
        assert!(report.job_records().is_empty());

        let line = render_line(key, &report);
        let (k2, parsed) = parse_line(line.trim_end()).expect("parse back");
        assert_eq!(k2, key);
        let pagg = parsed.stream_agg().expect("streamed flag survives");
        assert_eq!(pagg.completed, agg.completed);
        assert_eq!(pagg.completion.count(), agg.completion.count());
        assert_eq!(pagg.completion.mean().to_bits(), agg.completion.mean().to_bits());
        assert_eq!(pagg.completion.m2().to_bits(), agg.completion.m2().to_bits());
        assert_eq!(
            (pagg.local_maps, pagg.rack_maps, pagg.remote_maps),
            (agg.local_maps, agg.rack_maps, agg.remote_maps)
        );
        assert_eq!((pagg.deadlined, pagg.missed), (agg.deadlined, agg.missed));
        assert_eq!(pagg.max_finished_s.to_bits(), agg.max_finished_s.to_bits());
        assert_eq!(pagg.sketch.encode(), agg.sketch.encode());
        // Everything the artifacts derive matches too.
        assert_eq!(parsed.completed_jobs(), report.completed_jobs());
        assert_eq!(
            parsed.mean_completion_s().to_bits(),
            report.mean_completion_s().to_bits()
        );
        assert_eq!(parsed.miss_rate().to_bits(), report.miss_rate().to_bits());
        assert_eq!(parsed.to_json().render(), report.to_json().render());
    }

    #[test]
    fn key_depends_on_every_axis() {
        let mut g = ScenarioGrid::quick();
        g.jobs_per_scenario = 3;
        let scenarios = g.scenarios();
        let keys: std::collections::HashSet<u64> =
            scenarios.iter().map(|sc| scenario_key(&g, sc)).collect();
        assert_eq!(keys.len(), scenarios.len(), "keys must be distinct");
        // Changing a grid trace parameter re-keys everything.
        let mut g2 = g.clone();
        g2.mean_gap_s = 9.0;
        for sc in &scenarios {
            assert_ne!(scenario_key(&g, sc), scenario_key(&g2, sc));
        }
        // The topology axis enters the content hash: the same cell under
        // a different topology must re-run, not replay journaled numbers.
        for sc in &scenarios {
            let mut racked = sc.clone();
            racked.topology = crate::cluster::Topology::Racks(2);
            assert_ne!(scenario_key(&g, sc), scenario_key(&g, &racked));
        }
        // The failure-model axis enters the content hash too: results
        // simulated without failures must never be replayed for a cell
        // that injects them (and vice versa).
        for sc in &scenarios {
            let mut failing = sc.clone();
            failing.failures =
                crate::harness::FailureSpec::Preset(crate::config::FailureModel::crash_low());
            assert_ne!(scenario_key(&g, sc), scenario_key(&g, &failing));
            let mut traced = sc.clone();
            traced.failures = crate::harness::FailureSpec::TraceFile("f.txt".to_string());
            assert_ne!(scenario_key(&g, sc), scenario_key(&g, &traced));
            assert_ne!(scenario_key(&g, &failing), scenario_key(&g, &traced));
        }
        // The workload and streaming axes enter the content hash: a
        // trace-replay or streamed cell must never replay generated/exact
        // journaled numbers (and vice versa).
        for sc in &scenarios {
            let mut traced = sc.clone();
            traced.workload = crate::harness::Workload::TraceFile("t.txt".to_string());
            assert_ne!(scenario_key(&g, sc), scenario_key(&g, &traced));
            let mut streamed = sc.clone();
            streamed.stream_metrics = true;
            assert_ne!(scenario_key(&g, sc), scenario_key(&g, &streamed));
        }
        // ...but the key is position-independent content: the same
        // resolved scenario hashes identically regardless of grid object.
        assert_eq!(
            scenario_key(&g, &scenarios[1]),
            scenario_key(&g.clone(), &scenarios[1])
        );
    }
}
