//! Declarative scenario grids: the cartesian product of scheduler kind x
//! job mix x PM count x PM heterogeneity profile x network topology x
//! arrival pattern x input scale x failure model x seed replicate,
//! expanded into a flat, deterministically ordered scenario list.
//!
//! Each scenario derives its RNG stream seed from `(grid_seed,
//! scenario_index)` via [`crate::util::rng::derive_stream_seed`], so the
//! full `(SimConfig, JobTrace, SchedulerKind)` input of a run is a pure
//! function of the grid — independent of worker threads and execution
//! order. Because the stream seed folds in the scenario *index*, editing
//! an axis re-keys every scenario after the edit point; the resume
//! journal (see [`super::journal`]) keys results by a content hash of the
//! resolved scenario, so unchanged cells are still reused.

use crate::cluster::Topology;
use crate::config::{FailureModel, PmProfile, SimConfig};
use crate::scheduler::SchedulerKind;
use crate::util::rng::derive_stream_seed;
use crate::util::Rng;
use crate::workloads::trace::{ideal_completion_estimate, Arrival, JobTrace, TraceSource};
use crate::workloads::{JobSpec, JobType, ALL_JOB_TYPES};

/// What kind of jobs one scenario submits.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobMix {
    /// Poisson trace over all five workload types (the paper's "random
    /// input sizes" regime).
    Mixed,
    /// Every job is this single workload type, input sizes cycling through
    /// the paper's 2/4/6/8/10 GB ladder (scaled by the scenario's scale).
    Single(JobType),
}

impl JobMix {
    pub fn name(self) -> &'static str {
        match self {
            JobMix::Mixed => "mixed",
            JobMix::Single(t) => t.name(),
        }
    }

    pub fn from_name(s: &str) -> Option<JobMix> {
        if s == "mixed" {
            return Some(JobMix::Mixed);
        }
        JobType::from_name(s).map(JobMix::Single)
    }
}

/// Where one scenario's jobs come from.
///
/// `Generated` draws the trace from the scenario's derived stream seed
/// (the classic path — [`JobMix`] decides the shape). `TraceFile` replays
/// a plain-text trace file (see `docs/TRACE_FORMAT.md`) **streamed line
/// by line**, so trace length never bounds memory.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Workload {
    /// Synthesize the trace from the scenario seed (the default).
    Generated,
    /// Replay the job trace at this path (`--workload trace:<file>`).
    TraceFile(String),
}

impl Workload {
    /// Stable label carried into artifacts and journal keys.
    pub fn label(&self) -> String {
        match self {
            Workload::Generated => "gen".to_string(),
            Workload::TraceFile(p) => format!("trace:{p}"),
        }
    }

    /// Parse a `--workload` operand: `gen` or `trace:<file>`.
    pub fn from_label(s: &str) -> Option<Workload> {
        if s == "gen" {
            return Some(Workload::Generated);
        }
        s.strip_prefix("trace:")
            .filter(|p| !p.is_empty())
            .map(|p| Workload::TraceFile(p.to_string()))
    }
}

/// Where one scenario's PM failures come from: a named generator preset
/// (the [`FailureModel`] axis point) or a replayed failure-trace file
/// (`--failures trace:<file>`, see `docs/FAILURE_MODEL.md` for the line
/// grammar). A trace-file cell runs with the generator off — the file
/// *is* the failure schedule — so straggler and speculation knobs stay at
/// their defaults there.
#[derive(Clone, Debug, PartialEq)]
pub enum FailureSpec {
    /// Apply this failure model (generator presets; the default axis).
    Preset(FailureModel),
    /// Replay the failure trace at this path.
    TraceFile(String),
}

impl FailureSpec {
    /// The failure-free default point.
    pub fn off() -> FailureSpec {
        FailureSpec::Preset(FailureModel::off())
    }

    /// The failure model this cell's `SimConfig` carries ([`FailureModel::off`]
    /// for trace-file replay — the file replaces the generator).
    pub fn model(&self) -> FailureModel {
        match self {
            FailureSpec::Preset(m) => *m,
            FailureSpec::TraceFile(_) => FailureModel::off(),
        }
    }

    /// The failure-trace path, when this cell replays a file.
    pub fn trace_file(&self) -> Option<&str> {
        match self {
            FailureSpec::Preset(_) => None,
            FailureSpec::TraceFile(p) => Some(p),
        }
    }

    /// Stable label carried into artifacts and journal keys.
    pub fn label(&self) -> String {
        match self {
            FailureSpec::Preset(m) => m.label(),
            FailureSpec::TraceFile(p) => format!("trace:{p}"),
        }
    }

    /// Parse one `--failures` operand: a preset name or `trace:<file>`.
    pub fn from_label(s: &str) -> Option<FailureSpec> {
        if let Some(p) = s.strip_prefix("trace:") {
            return (!p.is_empty()).then(|| FailureSpec::TraceFile(p.to_string()));
        }
        FailureModel::from_name(s).map(FailureSpec::Preset)
    }

    /// Parse a comma-separated `--failures` axis override. `None` if any
    /// entry is unknown.
    pub fn parse_list(s: &str) -> Option<Vec<FailureSpec>> {
        s.split(',')
            .map(|part| FailureSpec::from_label(part.trim()))
            .collect()
    }
}

/// The declarative grid: every combination of the axis vectors becomes one
/// scenario per seed replicate. Axis vectors are public so callers apply
/// per-axis overrides before expansion (`vcsched sweep --pms 10 ...`).
#[derive(Clone, Debug)]
pub struct ScenarioGrid {
    /// Grid label carried into artifacts.
    pub name: String,
    /// Axis: scheduler under test.
    pub schedulers: Vec<SchedulerKind>,
    /// Axis: job mix.
    pub mixes: Vec<JobMix>,
    /// Axis: physical machine count.
    pub pm_counts: Vec<usize>,
    /// Axis: per-PM capacity/speed heterogeneity profile.
    pub profiles: Vec<PmProfile>,
    /// Axis: network topology (rack layout + cross-rack oversubscription).
    pub topologies: Vec<Topology>,
    /// Axis: arrival pattern (Poisson λ multiplier + steady/burst regime).
    pub arrivals: Vec<Arrival>,
    /// Axis: MB of simulated input per paper-GB (100 = fast, 1024 = full).
    pub scales: Vec<f64>,
    /// Axis: failure injection (generator preset or replayed trace file).
    /// Defaults to the single [`FailureSpec::off`] point, which keeps
    /// every run byte-identical to the failure-free simulator.
    pub failures: Vec<FailureSpec>,
    /// Axis: job source (seed-generated or a replayed trace file).
    /// Defaults to the single [`Workload::Generated`] point, which keeps
    /// every artifact byte-identical to pre-axis releases.
    pub workloads: Vec<Workload>,
    /// Run every cell with constant-memory streaming metrics
    /// ([`SimConfig::stream_metrics`]): Welford + quantile-sketch
    /// accumulators instead of per-job records, completed jobs retired.
    /// Off by default (the exact per-job path).
    pub stream_metrics: bool,
    /// Axis: seed replicate ids (only their count and position matter; the
    /// actual RNG stream comes from `(grid_seed, scenario_index)`).
    pub seed_replicates: usize,
    /// Jobs submitted per scenario.
    pub jobs_per_scenario: usize,
    /// Mean inter-arrival gap in seconds (Poisson arrivals).
    pub mean_gap_s: f64,
    /// Deadline factor range, multiplied onto the ideal-parallel estimate.
    pub deadline_factor: (f64, f64),
    /// Root seed of the whole sweep.
    pub grid_seed: u64,
}

impl ScenarioGrid {
    /// The default evaluation grid: all 5 schedulers x all 5 single-type
    /// mixes x the paper's 20-PM cluster x fast scale x 10 seed replicates
    /// = 250 scenarios.
    pub fn default_grid() -> Self {
        Self {
            name: "default".to_string(),
            schedulers: SchedulerKind::ALL.to_vec(),
            mixes: ALL_JOB_TYPES.iter().copied().map(JobMix::Single).collect(),
            pm_counts: vec![20],
            profiles: vec![PmProfile::Uniform],
            topologies: vec![Topology::Flat],
            arrivals: vec![Arrival::STEADY],
            scales: vec![100.0],
            failures: vec![FailureSpec::off()],
            workloads: vec![Workload::Generated],
            stream_metrics: false,
            seed_replicates: 10,
            jobs_per_scenario: 15,
            mean_gap_s: 5.0,
            deadline_factor: (1.6, 3.0),
            grid_seed: 42,
        }
    }

    /// The simulator-core stress grid (`--grid stress`, `--preset
    /// stress`, `benches/simcore.rs`): one scenario per scheduler at
    /// production-ish scale — 200 PMs (400 nodes, 800 map slots) across
    /// 8 racks and 2000 Poisson jobs on a 0.5 s mean gap, roughly the
    /// cluster's sustained service rate, so a standing backlog of
    /// partially-finished jobs forms. That is exactly the regime where
    /// the seed's per-heartbeat O(jobs × tasks) scans and O(jobs)
    /// `all_done` checks dominated the event loop. Fair (the paper
    /// baseline) vs deadline_vc (the paper scheduler, the hottest code
    /// path).
    pub fn stress() -> Self {
        Self {
            name: "stress".to_string(),
            schedulers: vec![SchedulerKind::Fair, SchedulerKind::DeadlineVc],
            mixes: vec![JobMix::Mixed],
            pm_counts: vec![200],
            profiles: vec![PmProfile::Uniform],
            topologies: vec![Topology::Racks(8)],
            arrivals: vec![Arrival::STEADY],
            scales: vec![100.0],
            failures: vec![FailureSpec::off()],
            workloads: vec![Workload::Generated],
            stream_metrics: false,
            seed_replicates: 1,
            jobs_per_scenario: 2000,
            mean_gap_s: 0.5,
            deadline_factor: (1.6, 3.0),
            grid_seed: 42,
        }
    }

    /// The extra-large stress grid (`--grid stress-xl`, `--preset
    /// stress-xl`, `benches/simcore.rs` under `SIMCORE_XL=1`): one
    /// scenario per scheduler at datacenter scale — 2000 PMs (4000
    /// nodes) on a 16-pod fat-tree and 50,000 Poisson jobs at a 0.1 s
    /// mean gap. Everything per-event must be O(log jobs) or better for
    /// this to finish inside the bench budget: the persistent scheduling
    /// indexes, the delta Eq. 10 reallocation, the claim ledger, the
    /// heartbeat slot overlay. CI smokes a truncated cell (`--jobs 60`);
    /// the full cell runs under the bench's wall-clock/RSS budget.
    pub fn stress_xl() -> Self {
        Self {
            name: "stress-xl".to_string(),
            schedulers: vec![SchedulerKind::Fair, SchedulerKind::DeadlineVc],
            mixes: vec![JobMix::Mixed],
            pm_counts: vec![2000],
            profiles: vec![PmProfile::Uniform],
            topologies: vec![Topology::FatTree(16)],
            arrivals: vec![Arrival::STEADY],
            scales: vec![100.0],
            failures: vec![FailureSpec::off()],
            workloads: vec![Workload::Generated],
            stream_metrics: false,
            seed_replicates: 1,
            jobs_per_scenario: 50_000,
            mean_gap_s: 0.1,
            deadline_factor: (1.6, 3.0),
            grid_seed: 42,
        }
    }

    /// The million-job streaming grid (`--grid stress-1m`, `--preset
    /// stress-1m`, `benches/simcore.rs` under `SIMCORE_1M=1`): one
    /// DeadlineVc scenario submitting 1,000,000 Poisson jobs to the
    /// stress cluster with `stream_metrics` on. Arrivals are pulled
    /// lazily from the generator and completed jobs are retired, so peak
    /// memory is bounded by the *active* job window — the bench asserts
    /// a flat RSS budget that does not scale with the job count.
    pub fn stress_1m() -> Self {
        Self {
            name: "stress-1m".to_string(),
            schedulers: vec![SchedulerKind::DeadlineVc],
            mixes: vec![JobMix::Mixed],
            pm_counts: vec![200],
            profiles: vec![PmProfile::Uniform],
            topologies: vec![Topology::Racks(8)],
            arrivals: vec![Arrival::STEADY],
            scales: vec![100.0],
            failures: vec![FailureSpec::off()],
            workloads: vec![Workload::Generated],
            stream_metrics: true,
            seed_replicates: 1,
            jobs_per_scenario: 1_000_000,
            mean_gap_s: 2.0,
            deadline_factor: (1.6, 3.0),
            grid_seed: 42,
        }
    }

    /// A small smoke grid for tests and the scaling bench: 2 schedulers x
    /// 2 mixes x small cluster x 2 seed replicates = 8 quick scenarios.
    pub fn quick() -> Self {
        Self {
            name: "quick".to_string(),
            schedulers: vec![SchedulerKind::Fair, SchedulerKind::DeadlineVc],
            mixes: vec![JobMix::Mixed, JobMix::Single(JobType::WordCount)],
            pm_counts: vec![4],
            profiles: vec![PmProfile::Uniform],
            topologies: vec![Topology::Flat],
            arrivals: vec![Arrival::STEADY],
            scales: vec![32.0],
            failures: vec![FailureSpec::off()],
            workloads: vec![Workload::Generated],
            stream_metrics: false,
            seed_replicates: 2,
            jobs_per_scenario: 5,
            mean_gap_s: 5.0,
            deadline_factor: (1.6, 3.0),
            grid_seed: 42,
        }
    }

    /// Total number of scenarios the grid expands to.
    pub fn len(&self) -> usize {
        self.schedulers.len()
            * self.mixes.len()
            * self.pm_counts.len()
            * self.profiles.len()
            * self.topologies.len()
            * self.arrivals.len()
            * self.scales.len()
            * self.failures.len()
            * self.workloads.len()
            * self.seed_replicates
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Expand the cartesian product in a fixed order (scheduler-major,
    /// seed-minor). The position in this list is the scenario index the
    /// RNG stream derives from, so the order is part of the grid contract.
    pub fn scenarios(&self) -> Vec<Scenario> {
        let mut out = Vec::with_capacity(self.len());
        for &scheduler in &self.schedulers {
            for &mix in &self.mixes {
                for &pms in &self.pm_counts {
                    for &profile in &self.profiles {
                        for &topology in &self.topologies {
                            for &arrival in &self.arrivals {
                                for &scale in &self.scales {
                                    for failures in &self.failures {
                                        for workload in &self.workloads {
                                            for replicate in 0..self.seed_replicates {
                                                let index = out.len();
                                                out.push(Scenario {
                                                    index,
                                                    scheduler,
                                                    mix,
                                                    pms,
                                                    profile,
                                                    topology,
                                                    arrival,
                                                    scale,
                                                    failures: failures.clone(),
                                                    workload: workload.clone(),
                                                    stream_metrics: self.stream_metrics,
                                                    replicate,
                                                    stream_seed: derive_stream_seed(
                                                        self.grid_seed,
                                                        index as u64,
                                                    ),
                                                });
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }
}

/// One fully resolved cell of the grid.
#[derive(Clone, Debug)]
pub struct Scenario {
    /// Position in the grid's expansion order.
    pub index: usize,
    pub scheduler: SchedulerKind,
    pub mix: JobMix,
    pub pms: usize,
    pub profile: PmProfile,
    pub topology: Topology,
    pub arrival: Arrival,
    pub scale: f64,
    /// Failure injection applied to this cell (preset or trace file).
    pub failures: FailureSpec,
    /// Job source for this cell (generated or a replayed trace file).
    pub workload: Workload,
    /// Whether this cell runs with streaming (constant-memory) metrics.
    pub stream_metrics: bool,
    /// Seed replicate number within the cell (for grouping/aggregation).
    pub replicate: usize,
    /// Derived RNG stream seed (`derive_stream_seed(grid_seed, index)`).
    pub stream_seed: u64,
}

impl Scenario {
    /// Cluster configuration for this scenario: the paper testbed with the
    /// PM-count and heterogeneity axes applied and the derived stream seed
    /// installed (the seed drives HDFS placement and task jitter inside
    /// the run).
    pub fn sim_config(&self) -> SimConfig {
        let mut cfg = SimConfig::paper();
        cfg.pms = self.pms;
        cfg.pm_profile = self.profile;
        cfg.topology = self.topology;
        cfg.failures = self.failures.model();
        cfg.failure_trace = self.failures.trace_file().map(str::to_string);
        cfg.stream_metrics = self.stream_metrics;
        cfg.seed = self.stream_seed;
        cfg
    }

    /// The job trace this scenario submits — a pure function of the
    /// scenario (grid parameters + derived stream seed). Submission times
    /// come from the scenario's [`Arrival`] axis point.
    pub fn job_trace(&self, grid: &ScenarioGrid, cfg: &SimConfig) -> JobTrace {
        if let Workload::TraceFile(path) = &self.workload {
            return TraceSource::from_file(path)
                .unwrap_or_else(|e| panic!("scenario {}: {e}", self.index))
                .materialize();
        }
        let n = grid.jobs_per_scenario;
        let (flo, fhi) = grid.deadline_factor;
        match self.mix {
            JobMix::Mixed => JobTrace::poisson_arrivals(
                cfg,
                n,
                grid.mean_gap_s,
                self.arrival,
                flo..fhi,
                self.stream_seed,
            ),
            JobMix::Single(jt) => {
                let mut rng = Rng::new(self.stream_seed ^ 0x51_41_6C);
                let times = self.arrival.times(n, grid.mean_gap_s, &mut rng);
                let sizes_gb = [2.0, 4.0, 6.0, 8.0, 10.0];
                let mut jobs = Vec::with_capacity(n);
                for (i, &t) in times.iter().enumerate() {
                    let gb = sizes_gb[i % sizes_gb.len()];
                    let mut spec = JobSpec::new(jt, gb * self.scale).at(t);
                    let est = ideal_completion_estimate(cfg, &spec);
                    let factor = rng.range_f64(flo, fhi);
                    spec = spec.with_deadline(est * factor);
                    jobs.push(spec);
                }
                JobTrace::new(jobs)
            }
        }
    }

    /// The streaming job source for this scenario. `Generated` + `Mixed`
    /// uses the lazy Poisson generator (same RNG stream as [`job_trace`],
    /// bit-identical specs, O(1) memory); `Generated` + `Single` falls
    /// back to the materialized trace (shape needs the full size ladder);
    /// `TraceFile` streams the file line by line.
    ///
    /// [`job_trace`]: Scenario::job_trace
    pub fn job_source(&self, grid: &ScenarioGrid, cfg: &SimConfig) -> Result<TraceSource, String> {
        match &self.workload {
            Workload::TraceFile(path) => TraceSource::from_file(path),
            Workload::Generated => match self.mix {
                JobMix::Mixed => {
                    let (flo, fhi) = grid.deadline_factor;
                    Ok(TraceSource::poisson_arrivals(
                        cfg,
                        grid.jobs_per_scenario,
                        grid.mean_gap_s,
                        self.arrival,
                        flo..fhi,
                        self.stream_seed,
                    ))
                }
                JobMix::Single(_) => Ok(TraceSource::from_trace(self.job_trace(grid, cfg))),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_grid_shape_matches_acceptance() {
        let g = ScenarioGrid::default_grid();
        assert_eq!(g.schedulers.len(), 5);
        assert_eq!(g.mixes.len(), 5);
        assert!(g.seed_replicates >= 10);
        assert_eq!(g.len(), 250);
        assert_eq!(g.scenarios().len(), 250);
    }

    #[test]
    fn profile_and_arrival_axes_multiply_the_grid() {
        let mut g = ScenarioGrid::quick();
        g.profiles = vec![PmProfile::Uniform, PmProfile::Split2x, PmProfile::LongTail];
        g.arrivals = vec![Arrival::STEADY, Arrival::burst(1.0)];
        assert_eq!(g.len(), ScenarioGrid::quick().len() * 6);
        let scenarios = g.scenarios();
        assert_eq!(scenarios.len(), g.len());
        // Every (profile, arrival) combination appears, and each
        // scenario's config/trace reflects its cell.
        for p in &g.profiles {
            for a in &g.arrivals {
                assert!(scenarios
                    .iter()
                    .any(|s| s.profile == *p && s.arrival == *a));
            }
        }
        let sc = scenarios
            .iter()
            .find(|s| s.profile == PmProfile::LongTail)
            .unwrap();
        let cfg = sc.sim_config();
        cfg.validate().unwrap();
        assert_eq!(cfg.pm_profile, PmProfile::LongTail);
        assert!(cfg.effective_map_slots() < cfg.total_map_slots() as f64);
    }

    #[test]
    fn topology_axis_multiplies_the_grid() {
        let mut g = ScenarioGrid::quick();
        g.topologies = vec![Topology::Flat, Topology::Racks(2), Topology::FatTree(2)];
        assert_eq!(g.len(), ScenarioGrid::quick().len() * 3);
        let scenarios = g.scenarios();
        assert_eq!(scenarios.len(), g.len());
        for t in &g.topologies {
            assert!(scenarios.iter().any(|s| s.topology == *t));
        }
        let sc = scenarios
            .iter()
            .find(|s| s.topology == Topology::Racks(2))
            .unwrap();
        let cfg = sc.sim_config();
        cfg.validate().unwrap();
        assert_eq!(cfg.topology, Topology::Racks(2));
        assert_eq!(cfg.node_racks().iter().filter(|&&r| r == 1).count(), cfg.nodes() / 2);
    }

    #[test]
    fn failures_axis_multiplies_the_grid() {
        let mut g = ScenarioGrid::quick();
        g.failures = vec![
            FailureSpec::off(),
            FailureSpec::Preset(FailureModel::crash_low()),
            FailureSpec::Preset(FailureModel::crash_low().with_speculation()),
        ];
        assert_eq!(g.len(), ScenarioGrid::quick().len() * 3);
        let scenarios = g.scenarios();
        assert_eq!(scenarios.len(), g.len());
        for fm in &g.failures {
            assert!(scenarios.iter().any(|s| s.failures == *fm));
        }
        // The model lands in the scenario's SimConfig verbatim.
        let sc = scenarios
            .iter()
            .find(|s| s.failures == FailureSpec::Preset(FailureModel::crash_low()))
            .unwrap();
        let cfg = sc.sim_config();
        cfg.validate().unwrap();
        assert_eq!(cfg.failures, FailureModel::crash_low());
        assert_eq!(cfg.failure_trace, None);
        // The default point stays failure-free.
        let off = scenarios
            .iter()
            .find(|s| !s.failures.model().enabled())
            .unwrap();
        assert!(!off.sim_config().failures.enabled());
    }

    #[test]
    fn failure_spec_labels_roundtrip_and_land_in_config() {
        assert_eq!(FailureSpec::from_label("off"), Some(FailureSpec::off()));
        assert_eq!(
            FailureSpec::from_label("rack-outage-blacklist"),
            Some(FailureSpec::Preset(
                FailureModel::rack_outage().with_blacklist()
            ))
        );
        assert_eq!(
            FailureSpec::from_label("trace:traces/outage.txt"),
            Some(FailureSpec::TraceFile("traces/outage.txt".to_string()))
        );
        assert_eq!(FailureSpec::from_label("trace:"), None);
        assert_eq!(FailureSpec::from_label("bogus"), None);
        for f in [
            FailureSpec::off(),
            FailureSpec::Preset(FailureModel::crash_high().with_speculation()),
            FailureSpec::TraceFile("a/b.txt".into()),
        ] {
            assert_eq!(FailureSpec::from_label(&f.label()), Some(f.clone()));
        }
        assert_eq!(
            FailureSpec::parse_list("off, crash-low, trace:x.txt"),
            Some(vec![
                FailureSpec::off(),
                FailureSpec::Preset(FailureModel::crash_low()),
                FailureSpec::TraceFile("x.txt".to_string()),
            ])
        );
        assert_eq!(FailureSpec::parse_list("off,bogus"), None);

        // A trace-file cell carries the path in SimConfig and keeps the
        // generator off.
        let mut g = ScenarioGrid::quick();
        g.failures = vec![FailureSpec::TraceFile("traces/outage.txt".into())];
        let sc = &g.scenarios()[0];
        let cfg = sc.sim_config();
        assert_eq!(cfg.failure_trace.as_deref(), Some("traces/outage.txt"));
        assert!(!cfg.failures.crashes());
        assert!(cfg.injects_crashes());
        cfg.validate().unwrap();
    }

    #[test]
    fn scenario_indices_and_seeds_are_stable() {
        let g = ScenarioGrid::quick();
        let a = g.scenarios();
        let b = g.scenarios();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.index, y.index);
            assert_eq!(x.stream_seed, y.stream_seed);
        }
        // Indices are dense and seeds unique.
        let mut seeds = std::collections::HashSet::new();
        for (i, sc) in a.iter().enumerate() {
            assert_eq!(sc.index, i);
            assert!(seeds.insert(sc.stream_seed));
        }
    }

    #[test]
    fn grid_seed_shifts_every_stream() {
        let g = ScenarioGrid::quick();
        let mut g2 = ScenarioGrid::quick();
        g2.grid_seed = 77;
        for (a, b) in g.scenarios().iter().zip(&g2.scenarios()) {
            assert_ne!(a.stream_seed, b.stream_seed);
        }
    }

    #[test]
    fn traces_are_pure_functions_of_the_scenario() {
        let g = ScenarioGrid::quick();
        for sc in g.scenarios() {
            let cfg = sc.sim_config();
            cfg.validate().unwrap();
            let a = sc.job_trace(&g, &cfg);
            let b = sc.job_trace(&g, &cfg);
            assert_eq!(a.len(), g.jobs_per_scenario);
            for (x, y) in a.jobs.iter().zip(&b.jobs) {
                assert_eq!(x.job_type, y.job_type);
                assert_eq!(x.input_mb, y.input_mb);
                assert_eq!(x.submit_s, y.submit_s);
                assert_eq!(x.deadline_s, y.deadline_s);
            }
        }
    }

    #[test]
    fn workload_labels_roundtrip() {
        assert_eq!(Workload::from_label("gen"), Some(Workload::Generated));
        assert_eq!(
            Workload::from_label("trace:traces/day1.txt"),
            Some(Workload::TraceFile("traces/day1.txt".to_string()))
        );
        assert_eq!(Workload::from_label("trace:"), None);
        assert_eq!(Workload::from_label("bogus"), None);
        for w in [Workload::Generated, Workload::TraceFile("a/b.txt".into())] {
            assert_eq!(Workload::from_label(&w.label()), Some(w.clone()));
        }
    }

    #[test]
    fn workload_axis_multiplies_the_grid() {
        let mut g = ScenarioGrid::quick();
        g.workloads = vec![
            Workload::Generated,
            Workload::TraceFile("traces/day1.txt".to_string()),
        ];
        assert_eq!(g.len(), ScenarioGrid::quick().len() * 2);
        let scenarios = g.scenarios();
        assert_eq!(scenarios.len(), g.len());
        for w in &g.workloads {
            assert!(scenarios.iter().any(|s| s.workload == *w));
        }
    }

    #[test]
    fn job_source_streams_the_same_mixed_trace() {
        // Generated + Mixed: the lazy source must materialize to exactly
        // the trace `job_trace` builds — same RNG stream, same specs.
        let g = ScenarioGrid::quick();
        for sc in g.scenarios().into_iter().filter(|s| s.mix == JobMix::Mixed) {
            let cfg = sc.sim_config();
            let eager = sc.job_trace(&g, &cfg);
            let lazy = sc.job_source(&g, &cfg).unwrap().materialize();
            assert_eq!(eager.len(), lazy.len());
            for (a, b) in eager.jobs.iter().zip(&lazy.jobs) {
                assert_eq!(a.job_type, b.job_type);
                assert_eq!(a.input_mb.to_bits(), b.input_mb.to_bits());
                assert_eq!(a.submit_s.to_bits(), b.submit_s.to_bits());
                assert_eq!(a.deadline_s.map(f64::to_bits), b.deadline_s.map(f64::to_bits));
            }
        }
    }

    #[test]
    fn stress_1m_grid_is_streaming_and_valid() {
        let g = ScenarioGrid::stress_1m();
        assert_eq!(g.len(), 1);
        assert!(g.stream_metrics);
        assert_eq!(g.jobs_per_scenario, 1_000_000);
        let sc = &g.scenarios()[0];
        assert!(sc.stream_metrics);
        let cfg = sc.sim_config();
        cfg.validate().unwrap();
        assert!(cfg.stream_metrics);
    }

    #[test]
    fn mix_names_roundtrip() {
        assert_eq!(JobMix::from_name("mixed"), Some(JobMix::Mixed));
        assert_eq!(
            JobMix::from_name("sort"),
            Some(JobMix::Single(JobType::Sort))
        );
        assert_eq!(JobMix::from_name("bogus"), None);
        for m in [JobMix::Mixed, JobMix::Single(JobType::Grep)] {
            assert_eq!(JobMix::from_name(m.name()), Some(m));
        }
    }
}
