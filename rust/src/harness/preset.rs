//! Named grid presets that pin the sweep axes to reproduce each paper
//! figure, plus the baseline-vs-candidate comparison tables they emit.
//!
//! The paper's evaluation (§5) compares the proposed deadline/VC
//! scheduler against Fair on a 20-machine virtual cluster. Each preset is
//! one figure's slice of the full design space, extended along the axes
//! the paper could not vary on real hardware (PM heterogeneity, arrival
//! regime):
//!
//! | preset               | headline metric      | axes swept                          |
//! |----------------------|----------------------|-------------------------------------|
//! | `fig4-throughput`    | jobs/hour            | profile ∈ {uniform, split-2x, long-tail} |
//! | `fig5-locality`      | map locality %       | profile ∈ {uniform, long-tail} × topology ∈ {flat, racks-4} × arrival ∈ {steady, burst} |
//! | `fig6-deadline-miss` | deadline-miss rate   | profile ∈ {uniform, split-2x} × arrival ∈ {steady, steady-x2, burst} |
//! | `fig7-failures`      | deadline-miss rate   | failures ∈ {off, crash-low[-spec], crash-high[-spec], rack-outage[-blacklist\|-replan]} |
//!
//! `fig5-locality` sweeps the network-topology axis because that is the
//! figure the three-tier locality split (node/rack/remote %) belongs to:
//! under `racks-4` the delay-scheduling literature's rack-local tier
//! appears between node-local and off-rack reads.
//!
//! Every preset pins `baseline = fair` and `candidate = deadline_vc`, so
//! the comparison table tracks the paper's 12% throughput-gain headline
//! as a first-class metric.

use crate::cluster::Topology;
use crate::config::{FailureModel, PmProfile};
use crate::scheduler::SchedulerKind;
use crate::workloads::trace::Arrival;

use super::agg::GroupStats;
use super::grid::{FailureSpec, JobMix, ScenarioGrid, Workload};

/// The per-cell metric a preset's comparison table is about.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HeadlineMetric {
    /// Mean throughput in jobs per simulated hour (higher is better).
    ThroughputJph,
    /// Mean map locality percentage (higher is better).
    LocalityPct,
    /// Mean deadline-miss rate in percent (lower is better).
    MissRatePct,
}

impl HeadlineMetric {
    pub fn name(self) -> &'static str {
        match self {
            HeadlineMetric::ThroughputJph => "throughput_jph",
            HeadlineMetric::LocalityPct => "locality_pct",
            HeadlineMetric::MissRatePct => "miss_rate_pct",
        }
    }

    /// Extract the metric from one aggregated grid cell.
    pub fn value(self, g: &GroupStats) -> f64 {
        match self {
            HeadlineMetric::ThroughputJph => g.mean_throughput_jph,
            HeadlineMetric::LocalityPct => g.mean_locality_pct,
            HeadlineMetric::MissRatePct => g.mean_miss_rate * 100.0,
        }
    }

    /// Candidate-vs-baseline gain. For ratio metrics (throughput) this is
    /// the relative gain in percent; for percentage metrics (locality,
    /// miss rate) it is the difference in percentage points, signed so
    /// positive always means "candidate better".
    pub fn gain(self, baseline: f64, candidate: f64) -> f64 {
        match self {
            HeadlineMetric::ThroughputJph => {
                if baseline <= 0.0 {
                    0.0
                } else {
                    (candidate / baseline - 1.0) * 100.0
                }
            }
            HeadlineMetric::LocalityPct => candidate - baseline,
            HeadlineMetric::MissRatePct => baseline - candidate,
        }
    }

    /// Unit suffix for the gain column (`%` relative vs `pp` points).
    pub fn gain_unit(self) -> &'static str {
        match self {
            HeadlineMetric::ThroughputJph => "%",
            HeadlineMetric::LocalityPct | HeadlineMetric::MissRatePct => "pp",
        }
    }
}

/// A named paper-figure preset: the pinned grid plus what its comparison
/// table reports.
#[derive(Clone, Debug)]
pub struct Preset {
    pub name: &'static str,
    /// One-line description printed above the comparison table.
    pub describes: &'static str,
    pub metric: HeadlineMetric,
    pub baseline: SchedulerKind,
    pub candidate: SchedulerKind,
    /// The paper's headline number for this comparison, if it states one
    /// (tracked in the artifact so drift is visible PR-over-PR).
    pub paper_gain: Option<f64>,
}

/// Every preset name, for help text and error messages.
pub const PRESET_NAMES: [&str; 7] = [
    "fig4-throughput",
    "fig5-locality",
    "fig6-deadline-miss",
    "fig7-failures",
    "stress",
    "stress-xl",
    "stress-1m",
];

/// Resolve a preset by name into its pinned grid and comparison spec.
pub fn preset(name: &str) -> Option<(ScenarioGrid, Preset)> {
    let base = |n: &str| ScenarioGrid {
        name: n.to_string(),
        schedulers: vec![SchedulerKind::Fair, SchedulerKind::DeadlineVc],
        mixes: vec![JobMix::Mixed],
        pm_counts: vec![20],
        profiles: vec![PmProfile::Uniform],
        topologies: vec![Topology::Flat],
        arrivals: vec![Arrival::STEADY],
        scales: vec![100.0],
        failures: vec![FailureSpec::off()],
        workloads: vec![Workload::Generated],
        stream_metrics: false,
        seed_replicates: 5,
        jobs_per_scenario: 15,
        mean_gap_s: 5.0,
        deadline_factor: (1.6, 3.0),
        grid_seed: 42,
    };
    match name {
        "fig4-throughput" => {
            let mut g = base(name);
            g.profiles = vec![PmProfile::Uniform, PmProfile::Split2x, PmProfile::LongTail];
            Some((
                g,
                Preset {
                    name: "fig4-throughput",
                    describes: "deadline_vc vs fair job throughput across PM \
                                heterogeneity profiles (paper §5 headline)",
                    metric: HeadlineMetric::ThroughputJph,
                    baseline: SchedulerKind::Fair,
                    candidate: SchedulerKind::DeadlineVc,
                    paper_gain: Some(12.0),
                },
            ))
        }
        "fig5-locality" => {
            let mut g = base(name);
            g.schedulers = vec![
                SchedulerKind::Fair,
                SchedulerKind::Delay,
                SchedulerKind::DeadlineVc,
            ];
            g.profiles = vec![PmProfile::Uniform, PmProfile::LongTail];
            g.topologies = vec![Topology::Flat, Topology::Racks(4)];
            g.arrivals = vec![Arrival::STEADY, Arrival::burst(1.0)];
            Some((
                g,
                Preset {
                    name: "fig5-locality",
                    describes: "map locality: reconfiguration-based local \
                                launches vs fair/delay baselines",
                    metric: HeadlineMetric::LocalityPct,
                    baseline: SchedulerKind::Fair,
                    candidate: SchedulerKind::DeadlineVc,
                    paper_gain: None,
                },
            ))
        }
        "fig6-deadline-miss" => {
            let mut g = base(name);
            g.schedulers = vec![
                SchedulerKind::Fair,
                SchedulerKind::Edf,
                SchedulerKind::DeadlineVc,
            ];
            g.profiles = vec![PmProfile::Uniform, PmProfile::Split2x];
            g.arrivals = vec![Arrival::STEADY, Arrival::steady(2.0), Arrival::burst(1.0)];
            Some((
                g,
                Preset {
                    name: "fig6-deadline-miss",
                    describes: "deadline-miss rate under load (λ multiplier + \
                                bursts) and heterogeneity",
                    metric: HeadlineMetric::MissRatePct,
                    baseline: SchedulerKind::Fair,
                    candidate: SchedulerKind::DeadlineVc,
                    paper_gain: None,
                },
            ))
        }
        "fig7-failures" => {
            let mut g = base(name);
            g.failures = vec![
                FailureSpec::off(),
                FailureSpec::Preset(FailureModel::crash_low()),
                FailureSpec::Preset(FailureModel::crash_low().with_speculation()),
                FailureSpec::Preset(FailureModel::crash_high()),
                FailureSpec::Preset(FailureModel::crash_high().with_speculation()),
                FailureSpec::Preset(FailureModel::rack_outage()),
                FailureSpec::Preset(FailureModel::rack_outage().with_blacklist()),
                FailureSpec::Preset(FailureModel::rack_outage().with_replan()),
            ];
            // Rack-correlated outages need racks to correlate over.
            g.topologies = vec![Topology::Racks(4)];
            Some((
                g,
                Preset {
                    name: "fig7-failures",
                    describes: "deadline-miss rate vs PM failure rate: lone \
                                crashes with/without speculation, plus \
                                rack-correlated outages with/without \
                                blacklisting and deadline re-planning (see \
                                docs/FAILURE_MODEL.md)",
                    metric: HeadlineMetric::MissRatePct,
                    baseline: SchedulerKind::Fair,
                    candidate: SchedulerKind::DeadlineVc,
                    paper_gain: None,
                },
            ))
        }
        "stress" => Some((
            ScenarioGrid::stress(),
            Preset {
                name: "stress",
                describes: "simulator-core stress: 200 PMs x 8 racks x 2000 \
                            saturating jobs per scheduler (fair vs \
                            deadline_vc throughput; events/sec guard — see \
                            benches/simcore.rs)",
                metric: HeadlineMetric::ThroughputJph,
                baseline: SchedulerKind::Fair,
                candidate: SchedulerKind::DeadlineVc,
                paper_gain: None,
            },
        )),
        "stress-xl" => Some((
            ScenarioGrid::stress_xl(),
            Preset {
                name: "stress-xl",
                describes: "datacenter-scale stress: 2000 PMs x 16-pod \
                            fat-tree x 50k saturating jobs per scheduler \
                            (persistent-index / delta-alloc scaling guard — \
                            see benches/simcore.rs, SIMCORE_XL=1)",
                metric: HeadlineMetric::ThroughputJph,
                baseline: SchedulerKind::Fair,
                candidate: SchedulerKind::DeadlineVc,
                paper_gain: None,
            },
        )),
        // A single-scheduler memory guard, not a comparison: baseline ==
        // candidate, so the comparison table is empty by construction and
        // the artifact carries the aggregate row only.
        "stress-1m" => Some((
            ScenarioGrid::stress_1m(),
            Preset {
                name: "stress-1m",
                describes: "million-job streaming stress: 1M Poisson jobs \
                            through deadline_vc with constant-memory \
                            accumulators and retired job state (flat-RSS \
                            guard — see benches/simcore.rs, SIMCORE_1M=1)",
                metric: HeadlineMetric::ThroughputJph,
                baseline: SchedulerKind::DeadlineVc,
                candidate: SchedulerKind::DeadlineVc,
                paper_gain: None,
            },
        )),
        _ => None,
    }
}

/// One row of a preset's comparison table: a non-scheduler grid cell with
/// the baseline and candidate metric values side by side.
#[derive(Clone, Debug)]
pub struct ComparisonRow {
    pub mix: String,
    pub pms: usize,
    pub profile: String,
    pub topology: String,
    pub arrival: String,
    pub failures: String,
    /// Workload label (`gen` or `trace:<file>`); a comparison axis only
    /// when the grid sweeps trace replays against generated traffic.
    pub workload: String,
    pub scale: f64,
    pub baseline: f64,
    pub candidate: f64,
    pub gain: f64,
}

/// Pair up baseline/candidate cells of the aggregated sweep and compute
/// the per-cell gain. Cells missing either scheduler are skipped (e.g.
/// when `--sched` collapsed the axis).
pub fn compare_cells(groups: &[GroupStats], preset: &Preset) -> Vec<ComparisonRow> {
    use std::collections::BTreeMap;
    // Key: everything but the scheduler axis.
    type CellKey = (String, usize, String, String, String, String, String, u64);
    let mut cells: BTreeMap<CellKey, (Option<f64>, Option<f64>)> = BTreeMap::new();
    for g in groups {
        let key = (
            g.mix.clone(),
            g.pms,
            g.profile.clone(),
            g.topology.clone(),
            g.arrival.clone(),
            g.failures.clone(),
            g.workload.clone(),
            g.scale.to_bits(),
        );
        let entry = cells.entry(key).or_insert((None, None));
        if g.scheduler == preset.baseline.name() {
            entry.0 = Some(preset.metric.value(g));
        } else if g.scheduler == preset.candidate.name() {
            entry.1 = Some(preset.metric.value(g));
        }
    }
    cells
        .into_iter()
        .filter_map(
            |((mix, pms, profile, topology, arrival, failures, workload, scale_bits), (b, c))| {
                let (baseline, candidate) = (b?, c?);
                Some(ComparisonRow {
                    mix,
                    pms,
                    profile,
                    topology,
                    arrival,
                    failures,
                    workload,
                    scale: f64::from_bits(scale_bits),
                    baseline,
                    candidate,
                    gain: preset.metric.gain(baseline, candidate),
                })
            },
        )
        .collect()
}

/// Mean gain across all comparison cells — the preset's tracked headline
/// (fig4: the paper's ~12% throughput number).
pub fn headline_gain(rows: &[ComparisonRow]) -> f64 {
    if rows.is_empty() {
        return 0.0;
    }
    rows.iter().map(|r| r.gain).sum::<f64>() / rows.len() as f64
}

/// The `comparison` section of a preset sweep's JSON artifact: per-cell
/// rows plus the tracked headline (and the paper's number when stated).
pub fn comparison_json(preset: &Preset, rows: &[ComparisonRow]) -> crate::util::json::Json {
    use crate::util::json::Json;
    let mut arr = Json::arr();
    for r in rows {
        let mut cell = Json::obj()
            .set("mix", r.mix.as_str())
            .set("pms", r.pms)
            .set("profile", r.profile.as_str())
            .set("topology", r.topology.as_str())
            .set("arrival", r.arrival.as_str())
            .set("failures", r.failures.as_str());
        // Emitted only off the default point so pre-axis artifacts stay
        // byte-identical.
        if r.workload != "gen" {
            cell = cell.set("workload", r.workload.as_str());
        }
        arr = arr.push(
            cell.set("scale", r.scale)
                .set(preset.baseline.name(), r.baseline)
                .set(preset.candidate.name(), r.candidate)
                .set("gain", r.gain),
        );
    }
    let mut obj = Json::obj()
        .set("preset", preset.name)
        .set("metric", preset.metric.name())
        .set("baseline", preset.baseline.name())
        .set("candidate", preset.candidate.name())
        .set("gain_unit", preset.metric.gain_unit())
        .set("headline_gain", headline_gain(rows));
    if let Some(p) = preset.paper_gain {
        obj = obj.set("paper_gain", p);
    }
    obj.set("cells", arr)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_preset_resolves_and_validates() {
        for name in PRESET_NAMES {
            let (grid, p) = preset(name).unwrap_or_else(|| panic!("{name}"));
            assert_eq!(grid.name, name);
            assert_eq!(p.name, name);
            assert!(grid.len() > 0);
            assert!(grid.schedulers.contains(&p.baseline));
            assert!(grid.schedulers.contains(&p.candidate));
            for sc in grid.scenarios() {
                sc.sim_config().validate().unwrap();
            }
        }
        assert!(preset("fig9-nope").is_none());
    }

    #[test]
    fn fig4_sweeps_heterogeneity_on_the_paper_testbed() {
        let (grid, p) = preset("fig4-throughput").unwrap();
        assert_eq!(grid.pm_counts, vec![20]);
        assert_eq!(grid.profiles.len(), 3);
        assert_eq!(p.metric, HeadlineMetric::ThroughputJph);
        assert_eq!(p.paper_gain, Some(12.0));
        // 2 schedulers x 1 mix x 3 profiles x 5 seeds.
        assert_eq!(grid.len(), 30);
    }

    #[test]
    fn fig5_sweeps_the_topology_axis() {
        let (grid, p) = preset("fig5-locality").unwrap();
        assert_eq!(
            grid.topologies,
            vec![Topology::Flat, Topology::Racks(4)]
        );
        assert_eq!(p.metric, HeadlineMetric::LocalityPct);
        // 3 schedulers x 1 mix x 2 profiles x 2 topologies x 2 arrivals
        // x 5 seeds.
        assert_eq!(grid.len(), 120);
        // The other presets stay on the flat (paper-testbed) topology.
        for name in ["fig4-throughput", "fig6-deadline-miss"] {
            let (g, _) = preset(name).unwrap();
            assert_eq!(g.topologies, vec![Topology::Flat]);
        }
    }

    #[test]
    fn fig7_sweeps_the_failure_axis() {
        let (grid, p) = preset("fig7-failures").unwrap();
        assert_eq!(grid.failures.len(), 8);
        assert!(grid.failures.contains(&FailureSpec::off()));
        assert!(grid
            .failures
            .iter()
            .any(|f| f.model().crashes() && f.model().speculation));
        // The reactive-policy cells: rack outages with blacklisting and
        // with deadline re-planning.
        assert!(grid
            .failures
            .iter()
            .any(|f| f.model().rack_correlated && f.model().blacklist));
        assert!(grid
            .failures
            .iter()
            .any(|f| f.model().rack_correlated && f.model().replan));
        // Rack-correlated cells need a racked topology to correlate over.
        assert_eq!(grid.topologies, vec![Topology::Racks(4)]);
        assert_eq!(p.metric, HeadlineMetric::MissRatePct);
        // 2 schedulers x 1 mix x 8 failure specs x 5 seeds.
        assert_eq!(grid.len(), 80);
        // The other presets stay failure-free (byte-identical runs).
        for name in ["fig4-throughput", "fig5-locality", "fig6-deadline-miss"] {
            let (g, _) = preset(name).unwrap();
            assert_eq!(g.failures, vec![FailureSpec::off()]);
        }
    }

    #[test]
    fn gain_sign_means_candidate_better() {
        assert!(HeadlineMetric::ThroughputJph.gain(10.0, 11.2) > 0.0);
        assert!(HeadlineMetric::LocalityPct.gain(80.0, 90.0) > 0.0);
        // Lower miss rate is better, so a drop is a positive gain.
        assert!(HeadlineMetric::MissRatePct.gain(30.0, 10.0) > 0.0);
        assert!(HeadlineMetric::MissRatePct.gain(10.0, 30.0) < 0.0);
    }

    #[test]
    fn compare_pairs_cells_and_headlines() {
        let (grid, p) = preset("fig4-throughput").unwrap();
        let mut quick = grid.clone();
        quick.seed_replicates = 1;
        quick.jobs_per_scenario = 3;
        quick.scales = vec![8.0];
        quick.profiles.truncate(2);
        let results = crate::harness::run_sweep(&quick, 2);
        let groups = crate::harness::aggregate(&results);
        let rows = compare_cells(&groups, &p);
        // One row per (mix, pms, profile, arrival, scale) cell.
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert!(r.baseline > 0.0);
            assert!(r.candidate > 0.0);
        }
        let h = headline_gain(&rows);
        assert!(h.is_finite());
    }
}
