//! Parallel scenario-sweep harness: declarative experiment grids over the
//! simulator, executed on a std-thread pool with bitwise-reproducible
//! results, resumable through an append-only journal, and aggregated into
//! JSON/CSV artifacts.
//!
//! The paper's headline comparison (§5) is one cell of a much larger
//! design space — scheduler x workload mix x cluster size x **PM
//! heterogeneity profile** x **network topology** x **arrival pattern** x
//! input scale x **failure model** x seed. This module turns the repo
//! from a one-shot figure reproducer into a grid-evaluation engine:
//!
//! * [`grid`] — [`ScenarioGrid`] declares the axes; expansion assigns each
//!   scenario a dense index and derives its RNG stream from
//!   `(grid_seed, scenario_index)`; the [`Workload`] axis swaps the
//!   seed-generated trace for a streamed trace-file replay, and
//!   `stream_metrics` switches cells to constant-memory accumulators;
//! * [`preset`] — named grids (`fig4-throughput`, `fig5-locality`,
//!   `fig6-deadline-miss`, `fig7-failures`) that pin the axes to
//!   reproduce each paper figure and emit a baseline-vs-candidate
//!   comparison table tracking the paper's 12% throughput-gain headline;
//! * [`runner`] — [`run_sweep`] executes scenarios as pure
//!   `(SimConfig, JobTrace, SchedulerKind) -> Report` functions across N
//!   worker threads; [`run_sweep_resumable`] consults the journal first
//!   and re-runs only missing cells;
//! * [`journal`] — append-only result log keyed by a content hash of the
//!   resolved scenario; reports round-trip exactly, so resumed aggregates
//!   are byte-identical to an uninterrupted run;
//! * [`agg`] — [`aggregate`] folds seed replicates into per-cell stats
//!   (mean/std, pooled p50/p99, locality, miss rate, throughput) and
//!   renders artifacts that are byte-identical at any thread count.
//!
//! Driven by `vcsched sweep` (see `main.rs`) and the
//! `benches/sweep_scaling.rs` smoke bench; the determinism contract is
//! enforced by `tests/sweep_determinism.rs` and the resume contract by
//! `tests/sweep_resume.rs`.
//!
//! # Examples
//!
//! Build a paper-figure preset and inspect its pinned grid:
//!
//! ```
//! use vcsched::harness::{preset::preset, ScenarioGrid};
//!
//! let (grid, spec) = preset("fig4-throughput").unwrap();
//! // 2 schedulers x 1 mix x 3 heterogeneity profiles x 5 seed
//! // replicates on the paper's 20-PM testbed.
//! assert_eq!(grid.len(), 30);
//! assert_eq!(grid.pm_counts, vec![20]);
//! assert_eq!(spec.baseline.name(), "fair");
//! assert_eq!(spec.candidate.name(), "deadline_vc");
//!
//! // Custom grids compose the same axes directly:
//! use vcsched::cluster::Topology;
//! use vcsched::config::PmProfile;
//! use vcsched::workloads::trace::Arrival;
//! let mut g = ScenarioGrid::quick();
//! g.profiles = vec![PmProfile::Uniform, PmProfile::LongTail];
//! g.topologies = vec![Topology::Flat, Topology::Racks(2)];
//! g.arrivals = vec![Arrival::STEADY, Arrival::burst(1.0)];
//! assert_eq!(g.len(), ScenarioGrid::quick().len() * 8);
//! ```
//!
//! Run a tiny sweep and aggregate it (deterministic at any thread count):
//!
//! ```
//! use vcsched::harness::{aggregate, run_sweep, ScenarioGrid};
//!
//! let mut g = ScenarioGrid::quick();
//! g.jobs_per_scenario = 2;
//! g.seed_replicates = 1;
//! let results = run_sweep(&g, 2);
//! assert_eq!(results.len(), g.len());
//! let groups = aggregate(&results);
//! assert!(groups.iter().all(|c| c.total_jobs == 2));
//! ```

pub mod agg;
pub mod grid;
pub mod journal;
pub mod preset;
pub mod runner;

pub use agg::{aggregate, aggregates_csv, sweep_json, GroupStats};
pub use grid::{FailureSpec, JobMix, Scenario, ScenarioGrid, Workload};
pub use journal::{scenario_key, Journal};
pub use preset::{
    compare_cells, comparison_json, headline_gain, preset as figure_preset, ComparisonRow,
    HeadlineMetric, Preset, PRESET_NAMES,
};
pub use runner::{
    run_scenario, run_scenarios, run_scenarios_with, run_sweep, run_sweep_resumable,
    ScenarioResult,
};
