//! Parallel scenario-sweep harness: declarative experiment grids over the
//! simulator, executed on a std-thread pool with bitwise-reproducible
//! results and aggregated into JSON/CSV artifacts.
//!
//! The paper's headline comparison (§5) is one cell of a much larger
//! design space — scheduler x workload mix x cluster size x input scale x
//! seed. This module turns the repo from a one-shot figure reproducer into
//! a grid-evaluation engine:
//!
//! * [`grid`] — [`ScenarioGrid`] declares the axes; expansion assigns each
//!   scenario a dense index and derives its RNG stream from
//!   `(grid_seed, scenario_index)`;
//! * [`runner`] — [`run_sweep`] executes scenarios as pure
//!   `(SimConfig, JobTrace, SchedulerKind) -> Report` functions across N
//!   worker threads, results ordered by scenario index;
//! * [`agg`] — [`aggregate`] folds seed replicates into per-cell stats
//!   (mean/std, pooled p50/p99, locality, miss rate, throughput) and
//!   renders artifacts that are byte-identical at any thread count.
//!
//! Driven by `vcsched sweep` (see `main.rs`) and the
//! `benches/sweep_scaling.rs` smoke bench; the determinism contract is
//! enforced by `tests/sweep_determinism.rs`.

pub mod agg;
pub mod grid;
pub mod runner;

pub use agg::{aggregate, aggregates_csv, sweep_json, GroupStats};
pub use grid::{JobMix, Scenario, ScenarioGrid};
pub use runner::{run_scenario, run_scenarios, run_sweep, ScenarioResult};
