//! Parallel sweep executor: a self-scheduling thread pool over the
//! scenario list (std threads only — no external crates).
//!
//! Work distribution is a single shared atomic cursor: every idle worker
//! claims the next unclaimed scenario, so no worker ever sits idle while
//! scenarios remain — the work-conservation property work-stealing deques
//! buy, collapsed to one global deque (optimal here because scenarios are
//! coarse-grained: each is a whole simulation, microseconds of claim
//! overhead against milliseconds-to-seconds of work).
//!
//! Determinism: each scenario is a pure function
//! `(SimConfig, JobTrace, SchedulerKind) -> Report` — the simulation owns
//! all of its mutable state ([`crate::coordinator::World`]) and draws its
//! randomness from the scenario's derived stream seed — and results are
//! written into a slot indexed by scenario index. The returned vector is
//! therefore bitwise identical at any thread count.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::coordinator;
use crate::metrics::RunMetrics;

use super::grid::{Scenario, ScenarioGrid};
use super::journal::{scenario_key, Journal};

/// One scenario's outcome: the resolved cell plus the full run report.
#[derive(Clone, Debug)]
pub struct ScenarioResult {
    pub scenario: Scenario,
    pub report: RunMetrics,
}

/// Run one scenario. Pure: the result depends only on `(grid, scenario)`
/// (a trace-file workload folds the file contents into that input).
///
/// Jobs come from the scenario's streaming [`TraceSource`] — for the
/// default generated-mixed workload this draws the identical RNG stream
/// the materialized `job_trace` path drew, so reports are byte-identical
/// to pre-streaming releases; for trace files and million-job cells it
/// keeps memory independent of trace length.
///
/// [`TraceSource`]: crate::workloads::trace::TraceSource
pub fn run_scenario(grid: &ScenarioGrid, scenario: &Scenario) -> ScenarioResult {
    let cfg = scenario.sim_config();
    cfg.validate().unwrap_or_else(|e| {
        panic!("scenario {} has an invalid config: {e}", scenario.index)
    });
    let source = scenario.job_source(grid, &cfg).unwrap_or_else(|e| {
        panic!("scenario {}: workload source failed: {e}", scenario.index)
    });
    let mut predictor = crate::predictor::NativePredictor::new();
    let report =
        coordinator::run_simulation_source(&cfg, scenario.scheduler, source, &mut predictor);
    ScenarioResult {
        scenario: scenario.clone(),
        report,
    }
}

/// Expand `grid` and run every scenario on `threads` workers. Results come
/// back in scenario-index order regardless of which worker ran what.
pub fn run_sweep(grid: &ScenarioGrid, threads: usize) -> Vec<ScenarioResult> {
    let scenarios = grid.scenarios();
    run_scenarios(grid, &scenarios, threads)
}

/// Run an explicit scenario list on `threads` workers (the `run_sweep`
/// core, exposed for partial/filtered sweeps).
pub fn run_scenarios(
    grid: &ScenarioGrid,
    scenarios: &[Scenario],
    threads: usize,
) -> Vec<ScenarioResult> {
    run_scenarios_with(grid, scenarios, threads, |_| {})
}

/// [`run_scenarios`] with a completion hook: `on_done` fires once per
/// scenario *as it finishes* (in completion order, serialized across
/// workers), which is what lets the resumable runner journal progress a
/// mid-sweep kill cannot lose. The returned vector is still ordered by
/// position in `scenarios`.
pub fn run_scenarios_with(
    grid: &ScenarioGrid,
    scenarios: &[Scenario],
    threads: usize,
    on_done: impl Fn(&ScenarioResult) + Sync,
) -> Vec<ScenarioResult> {
    let n = scenarios.len();
    let threads = threads.clamp(1, n.max(1));
    if threads == 1 {
        return scenarios
            .iter()
            .map(|sc| {
                let result = run_scenario(grid, sc);
                on_done(&result);
                result
            })
            .collect();
    }

    let cursor = AtomicUsize::new(0);
    // One lock serializes the hook (journal appends must not interleave);
    // results land in per-slot cells so ordering stays by index.
    let hook_lock = Mutex::new(());
    let slots: Vec<Mutex<Option<ScenarioResult>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let result = run_scenario(grid, &scenarios[i]);
                {
                    let _serialized = hook_lock.lock().unwrap();
                    on_done(&result);
                }
                *slots[i].lock().unwrap() = Some(result);
            });
        }
    });
    slots
        .into_iter()
        .enumerate()
        .map(|(i, slot)| {
            slot.into_inner()
                .unwrap()
                .unwrap_or_else(|| panic!("scenario {i} produced no result"))
        })
        .collect()
}

/// Resumable sweep: load previously journaled results, run only the
/// missing cells (journaling each as it completes), and return the full
/// result list in scenario-index order.
///
/// Because journaled reports round-trip exactly (see
/// [`super::journal`]), aggregates over the returned results are
/// byte-identical to an uninterrupted [`run_sweep`] of the same grid —
/// the contract `tests/sweep_resume.rs` enforces. Returns the results
/// plus how many cells were reused from the journal.
pub fn run_sweep_resumable(
    grid: &ScenarioGrid,
    threads: usize,
    journal: &Journal,
) -> (Vec<ScenarioResult>, usize) {
    let scenarios = grid.scenarios();
    let done = journal.load();
    let mut results: Vec<Option<ScenarioResult>> = scenarios
        .iter()
        .map(|sc| {
            done.get(&scenario_key(grid, sc)).map(|report| ScenarioResult {
                scenario: sc.clone(),
                report: report.clone(),
            })
        })
        .collect();
    let reused = results.iter().filter(|r| r.is_some()).count();
    let missing: Vec<Scenario> = scenarios
        .iter()
        .zip(&results)
        .filter(|(_, r)| r.is_none())
        .map(|(sc, _)| sc.clone())
        .collect();
    let fresh = run_scenarios_with(grid, &missing, threads, |r| {
        journal
            .append(scenario_key(grid, &r.scenario), &r.report)
            .unwrap_or_else(|e| {
                panic!("journal append failed at {}: {e}", journal.path().display())
            });
    });
    for r in fresh {
        let slot = &mut results[r.scenario.index];
        debug_assert!(slot.is_none());
        *slot = Some(r);
    }
    let results = results
        .into_iter()
        .enumerate()
        .map(|(i, r)| r.unwrap_or_else(|| panic!("scenario {i} unresolved")))
        .collect();
    (results, reused)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_grid() -> ScenarioGrid {
        let mut g = ScenarioGrid::quick();
        g.jobs_per_scenario = 3;
        g
    }

    #[test]
    fn single_thread_runs_every_scenario_in_order() {
        let g = tiny_grid();
        let results = run_sweep(&g, 1);
        assert_eq!(results.len(), g.len());
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.scenario.index, i);
            assert_eq!(r.report.completed_jobs(), g.jobs_per_scenario);
        }
    }

    #[test]
    fn parallel_results_match_serial_bitwise() {
        let g = tiny_grid();
        let serial = run_sweep(&g, 1);
        for threads in [2usize, 4] {
            let parallel = run_sweep(&g, threads);
            assert_eq!(serial.len(), parallel.len());
            for (a, b) in serial.iter().zip(&parallel) {
                assert_eq!(a.scenario.index, b.scenario.index);
                assert_eq!(a.report.makespan_s, b.report.makespan_s);
                assert_eq!(a.report.hotplugs, b.report.hotplugs);
                assert_eq!(a.report.events, b.report.events);
                let ca: Vec<f64> =
                    a.report.jobs.iter().map(|j| j.completion_s).collect();
                let cb: Vec<f64> =
                    b.report.jobs.iter().map(|j| j.completion_s).collect();
                let idx = a.scenario.index;
                assert_eq!(ca, cb, "scenario {idx} diverged at {threads} threads");
            }
        }
    }

    #[test]
    fn oversized_thread_count_is_clamped() {
        let mut g = tiny_grid();
        g.seed_replicates = 1;
        g.mixes.truncate(1);
        g.schedulers.truncate(1); // 1 scenario
        let results = run_sweep(&g, 64);
        assert_eq!(results.len(), 1);
    }
}
