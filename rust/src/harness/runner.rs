//! Parallel sweep executor: a self-scheduling thread pool over the
//! scenario list (std threads only — no external crates).
//!
//! Work distribution is a single shared atomic cursor: every idle worker
//! claims the next unclaimed scenario, so no worker ever sits idle while
//! scenarios remain — the work-conservation property work-stealing deques
//! buy, collapsed to one global deque (optimal here because scenarios are
//! coarse-grained: each is a whole simulation, microseconds of claim
//! overhead against milliseconds-to-seconds of work).
//!
//! Determinism: each scenario is a pure function
//! `(SimConfig, JobTrace, SchedulerKind) -> Report` — the simulation owns
//! all of its mutable state ([`crate::coordinator::World`]) and draws its
//! randomness from the scenario's derived stream seed — and results are
//! written into a slot indexed by scenario index. The returned vector is
//! therefore bitwise identical at any thread count.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::coordinator;
use crate::metrics::RunMetrics;

use super::grid::{Scenario, ScenarioGrid};

/// One scenario's outcome: the resolved cell plus the full run report.
#[derive(Clone, Debug)]
pub struct ScenarioResult {
    pub scenario: Scenario,
    pub report: RunMetrics,
}

/// Run one scenario. Pure: the result depends only on `(grid, scenario)`.
pub fn run_scenario(grid: &ScenarioGrid, scenario: &Scenario) -> ScenarioResult {
    let cfg = scenario.sim_config();
    cfg.validate().unwrap_or_else(|e| {
        panic!("scenario {} has an invalid config: {e}", scenario.index)
    });
    let trace = scenario.job_trace(grid, &cfg);
    let report = coordinator::run_simulation(&cfg, scenario.scheduler, &trace);
    ScenarioResult {
        scenario: scenario.clone(),
        report,
    }
}

/// Expand `grid` and run every scenario on `threads` workers. Results come
/// back in scenario-index order regardless of which worker ran what.
pub fn run_sweep(grid: &ScenarioGrid, threads: usize) -> Vec<ScenarioResult> {
    let scenarios = grid.scenarios();
    run_scenarios(grid, &scenarios, threads)
}

/// Run an explicit scenario list on `threads` workers (the `run_sweep`
/// core, exposed for partial/filtered sweeps).
pub fn run_scenarios(
    grid: &ScenarioGrid,
    scenarios: &[Scenario],
    threads: usize,
) -> Vec<ScenarioResult> {
    let n = scenarios.len();
    let threads = threads.clamp(1, n.max(1));
    if threads == 1 {
        return scenarios.iter().map(|sc| run_scenario(grid, sc)).collect();
    }

    let cursor = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<ScenarioResult>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let result = run_scenario(grid, &scenarios[i]);
                *slots[i].lock().unwrap() = Some(result);
            });
        }
    });
    slots
        .into_iter()
        .enumerate()
        .map(|(i, slot)| {
            slot.into_inner()
                .unwrap()
                .unwrap_or_else(|| panic!("scenario {i} produced no result"))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_grid() -> ScenarioGrid {
        let mut g = ScenarioGrid::quick();
        g.jobs_per_scenario = 3;
        g
    }

    #[test]
    fn single_thread_runs_every_scenario_in_order() {
        let g = tiny_grid();
        let results = run_sweep(&g, 1);
        assert_eq!(results.len(), g.len());
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.scenario.index, i);
            assert_eq!(r.report.completed_jobs(), g.jobs_per_scenario);
        }
    }

    #[test]
    fn parallel_results_match_serial_bitwise() {
        let g = tiny_grid();
        let serial = run_sweep(&g, 1);
        for threads in [2usize, 4] {
            let parallel = run_sweep(&g, threads);
            assert_eq!(serial.len(), parallel.len());
            for (a, b) in serial.iter().zip(&parallel) {
                assert_eq!(a.scenario.index, b.scenario.index);
                assert_eq!(a.report.makespan_s, b.report.makespan_s);
                assert_eq!(a.report.hotplugs, b.report.hotplugs);
                assert_eq!(a.report.events, b.report.events);
                let ca: Vec<f64> =
                    a.report.jobs.iter().map(|j| j.completion_s).collect();
                let cb: Vec<f64> =
                    b.report.jobs.iter().map(|j| j.completion_s).collect();
                let idx = a.scenario.index;
                assert_eq!(ca, cb, "scenario {idx} diverged at {threads} threads");
            }
        }
    }

    #[test]
    fn oversized_thread_count_is_clamped() {
        let mut g = tiny_grid();
        g.seed_replicates = 1;
        g.mixes.truncate(1);
        g.schedulers.truncate(1); // 1 scenario
        let results = run_sweep(&g, 64);
        assert_eq!(results.len(), 1);
    }
}
