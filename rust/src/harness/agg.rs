//! Sweep aggregation: group scenario results by grid cell (scheduler x
//! mix x PMs x profile x topology x arrival x scale x failure model),
//! fold the seed replicates into summary statistics, and render the
//! JSON/CSV artifacts.
//!
//! Everything here is deterministic: groups are keyed through a `BTreeMap`
//! (sorted iteration), statistics fold results in scenario-index order,
//! and host-dependent values (wall-clock) are deliberately excluded — the
//! artifacts are byte-identical across thread counts and runs.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::json::Json;
use crate::util::stats::{Percentiles, QuantileSketch, Summary};

use super::grid::{ScenarioGrid, Workload};
use super::runner::ScenarioResult;

/// Aggregated statistics of one grid cell across its seed replicates.
#[derive(Clone, Debug)]
pub struct GroupStats {
    pub scheduler: String,
    pub mix: String,
    pub pms: usize,
    /// PM heterogeneity profile label (`uniform`, `split-2x`, ...).
    pub profile: String,
    /// Network topology label (`flat`, `racks-4`, `fat-tree-4`, ...).
    pub topology: String,
    /// Arrival-pattern label (`steady`, `burst`, `steady-x2`, ...).
    pub arrival: String,
    /// Failure-model label (`off`, `crash-low-spec`, ...).
    pub failures: String,
    /// Workload label (`gen` or `trace:<file>`).
    pub workload: String,
    pub scale: f64,
    /// Seed replicates folded into this cell.
    pub seeds: usize,
    /// Jobs completed across all replicates.
    pub total_jobs: usize,
    /// Mean/stddev of per-replicate mean job completion time (seconds).
    pub mean_completion_s: f64,
    pub std_completion_s: f64,
    /// Percentiles over all job completion times pooled across replicates.
    pub p50_completion_s: f64,
    pub p99_completion_s: f64,
    /// Mean/stddev of per-replicate throughput (jobs per simulated hour).
    pub mean_throughput_jph: f64,
    pub std_throughput_jph: f64,
    /// Mean/stddev of per-replicate *node-local* map percentage.
    pub mean_locality_pct: f64,
    pub std_locality_pct: f64,
    /// Mean per-replicate *rack-local* map percentage (0 when flat).
    pub mean_rack_pct: f64,
    /// Mean per-replicate *off-rack* map percentage.
    pub mean_remote_pct: f64,
    /// Mean per-replicate deadline-miss rate (0..1).
    pub mean_miss_rate: f64,
    /// Mean per-replicate makespan (seconds).
    pub mean_makespan_s: f64,
    /// Total vCPU hot-plugs across replicates.
    pub hotplugs: u64,
    /// PM crashes injected across replicates.
    pub pm_crashes: u64,
    /// Speculative map copies launched across replicates.
    pub spec_launches: u64,
    /// Speculation races won by the backup copy.
    pub spec_wins: u64,
    /// Attempts killed by speculation resolution (wasted work).
    pub spec_kills: u64,
    /// Speculative reduce copies launched across replicates.
    pub spec_reduce_launches: u64,
    /// Reduce-speculation races won by the backup copy.
    pub spec_reduce_wins: u64,
    /// Reduce attempts killed by speculation resolution.
    pub spec_reduce_kills: u64,
    /// Task launches that re-ran crash-destroyed work.
    pub reexecuted_tasks: u64,
}

impl GroupStats {
    /// Did any replicate in this cell speculate a reduce? Artifacts emit
    /// the `spec_reduce_*` columns/keys only when true, keeping
    /// map-only-speculation and failure-free artifacts byte-identical.
    pub fn any_reduce_spec(&self) -> bool {
        self.spec_reduce_launches != 0 || self.spec_reduce_wins != 0 || self.spec_reduce_kills != 0
    }
}

/// Fold `results` into per-cell statistics, sorted by (scheduler, mix,
/// pms, profile, topology, arrival, failures, scale).
pub fn aggregate(results: &[ScenarioResult]) -> Vec<GroupStats> {
    // Key through the f64 bit pattern: scales come verbatim from the grid
    // axis, so identical cells have identical bits.
    type CellKey = (String, String, usize, String, String, String, String, String, u64);
    let mut cells: BTreeMap<CellKey, Vec<usize>> = BTreeMap::new();
    for (i, r) in results.iter().enumerate() {
        let key = (
            r.scenario.scheduler.name().to_string(),
            r.scenario.mix.name().to_string(),
            r.scenario.pms,
            r.scenario.profile.name().to_string(),
            r.scenario.topology.label(),
            r.scenario.arrival.label(),
            r.scenario.failures.label(),
            r.scenario.workload.label(),
            r.scenario.scale.to_bits(),
        );
        cells.entry(key).or_default().push(i);
    }

    let mut out = Vec::with_capacity(cells.len());
    for (
        (scheduler, mix, pms, profile, topology, arrival, failures, workload, scale_bits),
        members,
    ) in cells
    {
        let mut completion = Summary::new();
        let mut throughput = Summary::new();
        let mut locality = Summary::new();
        let mut rack = Summary::new();
        let mut remote = Summary::new();
        let mut miss = Summary::new();
        let mut makespan = Summary::new();
        let mut pooled = Percentiles::new();
        // Streamed replicates carry a quantile sketch instead of per-job
        // records. The sketch is mergeable across replicates; when any
        // member streamed, the cell's pooled percentiles come from the
        // merged sketch (exact members fold in alongside). All-exact
        // cells keep the exact pooled path, byte for byte.
        let mut pooled_sketch = QuantileSketch::new();
        let mut any_stream = false;
        let mut hotplugs = 0u64;
        let mut total_jobs = 0usize;
        let mut pm_crashes = 0u64;
        let mut spec_launches = 0u64;
        let mut spec_wins = 0u64;
        let mut spec_kills = 0u64;
        let mut spec_reduce_launches = 0u64;
        let mut spec_reduce_wins = 0u64;
        let mut spec_reduce_kills = 0u64;
        let mut reexecuted_tasks = 0u64;
        for &i in &members {
            let rep = &results[i].report;
            completion.add(rep.mean_completion_s());
            throughput.add(rep.throughput_jobs_per_hour());
            locality.add(rep.locality_pct());
            rack.add(rep.rack_pct());
            remote.add(rep.remote_pct());
            miss.add(rep.miss_rate());
            makespan.add(rep.makespan_s);
            hotplugs += rep.hotplugs;
            pm_crashes += rep.failures.pm_crashes;
            spec_launches += rep.failures.speculative_launches;
            spec_wins += rep.failures.speculative_wins;
            spec_kills += rep.failures.speculative_kills;
            spec_reduce_launches += rep.failures.speculative_reduce_launches;
            spec_reduce_wins += rep.failures.speculative_reduce_wins;
            spec_reduce_kills += rep.failures.speculative_reduce_kills;
            reexecuted_tasks += rep.failures.reexecuted_tasks;
            total_jobs += rep.completed_jobs();
            if let Some(agg) = rep.stream_agg() {
                any_stream = true;
                pooled_sketch.merge(&agg.sketch);
            } else {
                for j in rep.job_records() {
                    pooled.add(j.completion_s);
                    pooled_sketch.add(j.completion_s);
                }
            }
        }
        let (p50, p99) = if any_stream {
            (pooled_sketch.pct(50.0), pooled_sketch.pct(99.0))
        } else {
            (pooled.pct(50.0), pooled.pct(99.0))
        };
        out.push(GroupStats {
            scheduler,
            mix,
            pms,
            profile,
            topology,
            arrival,
            failures,
            workload,
            scale: f64::from_bits(scale_bits),
            seeds: members.len(),
            total_jobs,
            mean_completion_s: completion.mean(),
            std_completion_s: completion.std(),
            p50_completion_s: p50,
            p99_completion_s: p99,
            mean_throughput_jph: throughput.mean(),
            std_throughput_jph: throughput.std(),
            mean_locality_pct: locality.mean(),
            std_locality_pct: locality.std(),
            mean_rack_pct: rack.mean(),
            mean_remote_pct: remote.mean(),
            mean_miss_rate: miss.mean(),
            mean_makespan_s: makespan.mean(),
            hotplugs,
            pm_crashes,
            spec_launches,
            spec_wins,
            spec_kills,
            spec_reduce_launches,
            spec_reduce_wins,
            spec_reduce_kills,
            reexecuted_tasks,
        });
    }
    out
}

/// The sweep's JSON artifact: grid echo + per-scenario rows + aggregates.
/// Deliberately excludes wall-clock (host-dependent) so the document is
/// byte-identical for a given grid at any `--threads` setting.
pub fn sweep_json(
    grid: &ScenarioGrid,
    results: &[ScenarioResult],
    groups: &[GroupStats],
) -> Json {
    let mut grid_obj = Json::obj()
        .set("name", grid.name.as_str())
        .set("grid_seed", grid.grid_seed)
        .set(
            "schedulers",
            grid.schedulers
                .iter()
                .map(|s| s.name().to_string())
                .collect::<Vec<_>>(),
        )
        .set(
            "mixes",
            grid.mixes
                .iter()
                .map(|m| m.name().to_string())
                .collect::<Vec<_>>(),
        )
        .set(
            "pm_counts",
            grid.pm_counts.iter().map(|&p| p as u64).collect::<Vec<_>>(),
        )
        .set(
            "profiles",
            grid.profiles
                .iter()
                .map(|p| p.name().to_string())
                .collect::<Vec<_>>(),
        )
        .set(
            "topologies",
            grid.topologies
                .iter()
                .map(|t| t.label())
                .collect::<Vec<_>>(),
        )
        .set(
            "arrivals",
            grid.arrivals.iter().map(|a| a.label()).collect::<Vec<_>>(),
        )
        .set(
            "failures",
            grid.failures.iter().map(|f| f.label()).collect::<Vec<_>>(),
        );
    // The workload axis and the streaming switch are echoed only off
    // their defaults, so pre-axis sweep artifacts stay byte-identical.
    if grid.workloads != vec![Workload::Generated] {
        grid_obj = grid_obj.set(
            "workloads",
            grid.workloads.iter().map(|w| w.label()).collect::<Vec<_>>(),
        );
    }
    if grid.stream_metrics {
        grid_obj = grid_obj.set("stream_metrics", true);
    }
    grid_obj = grid_obj
        .set("scales", grid.scales.clone())
        .set("seed_replicates", grid.seed_replicates)
        .set("jobs_per_scenario", grid.jobs_per_scenario)
        .set("mean_gap_s", grid.mean_gap_s);
    grid_obj = grid_obj.set(
        "deadline_factor",
        vec![grid.deadline_factor.0, grid.deadline_factor.1],
    );
    grid_obj = grid_obj.set("scenarios", results.len());

    let mut rows = Json::arr();
    for r in results {
        let rep = &r.report;
        let mut row = Json::obj()
            .set("index", r.scenario.index)
            .set("scheduler", r.scenario.scheduler.name())
            .set("mix", r.scenario.mix.name())
            .set("pms", r.scenario.pms)
            .set("profile", r.scenario.profile.name())
            .set("topology", r.scenario.topology.label())
            .set("arrival", r.scenario.arrival.label())
            .set("failures", r.scenario.failures.label());
        if r.scenario.workload != Workload::Generated {
            row = row.set("workload", r.scenario.workload.label());
        }
        if rep.stream_agg().is_some() {
            row = row.set("streamed", true);
        }
        row = row
            .set("scale", r.scenario.scale)
            .set("replicate", r.scenario.replicate)
            .set("stream_seed", format!("{:#018x}", r.scenario.stream_seed))
            .set("jobs", rep.completed_jobs())
            .set("makespan_s", rep.makespan_s)
            .set("mean_completion_s", rep.mean_completion_s())
            .set("throughput_jobs_per_hour", rep.throughput_jobs_per_hour())
            .set("locality_pct", rep.locality_pct())
            .set("rack_pct", rep.rack_pct())
            .set("remote_pct", rep.remote_pct())
            .set("miss_rate", rep.miss_rate())
            .set("hotplugs", rep.hotplugs)
            .set("pm_crashes", rep.failures.pm_crashes)
            .set("spec_launches", rep.failures.speculative_launches)
            .set("spec_wins", rep.failures.speculative_wins)
            .set("spec_kills", rep.failures.speculative_kills);
        // Reduce-speculation counters appear only when the replicate
        // actually speculated a reduce, so earlier artifacts stay
        // byte-identical.
        if rep.failures.any_reduce_spec() {
            row = row
                .set(
                    "spec_reduce_launches",
                    rep.failures.speculative_reduce_launches,
                )
                .set("spec_reduce_wins", rep.failures.speculative_reduce_wins)
                .set("spec_reduce_kills", rep.failures.speculative_reduce_kills);
        }
        rows = rows.push(
            row.set("reexecuted_tasks", rep.failures.reexecuted_tasks)
                .set("events", rep.events),
        );
    }

    let mut aggs = Json::arr();
    for g in groups {
        let mut agg = Json::obj()
            .set("scheduler", g.scheduler.as_str())
            .set("mix", g.mix.as_str())
            .set("pms", g.pms)
            .set("profile", g.profile.as_str())
            .set("topology", g.topology.as_str())
            .set("arrival", g.arrival.as_str())
            .set("failures", g.failures.as_str());
        if g.workload != "gen" {
            agg = agg.set("workload", g.workload.as_str());
        }
        agg = agg
            .set("scale", g.scale)
            .set("seeds", g.seeds)
            .set("total_jobs", g.total_jobs)
            .set("mean_completion_s", g.mean_completion_s)
            .set("std_completion_s", g.std_completion_s)
            .set("p50_completion_s", g.p50_completion_s)
            .set("p99_completion_s", g.p99_completion_s)
            .set("mean_throughput_jph", g.mean_throughput_jph)
            .set("std_throughput_jph", g.std_throughput_jph)
            .set("mean_locality_pct", g.mean_locality_pct)
            .set("std_locality_pct", g.std_locality_pct)
            .set("mean_rack_pct", g.mean_rack_pct)
            .set("mean_remote_pct", g.mean_remote_pct)
            .set("mean_miss_rate", g.mean_miss_rate)
            .set("mean_makespan_s", g.mean_makespan_s)
            .set("hotplugs", g.hotplugs)
            .set("pm_crashes", g.pm_crashes)
            .set("spec_launches", g.spec_launches)
            .set("spec_wins", g.spec_wins)
            .set("spec_kills", g.spec_kills);
        if g.any_reduce_spec() {
            agg = agg
                .set("spec_reduce_launches", g.spec_reduce_launches)
                .set("spec_reduce_wins", g.spec_reduce_wins)
                .set("spec_reduce_kills", g.spec_reduce_kills);
        }
        aggs = aggs.push(agg.set("reexecuted_tasks", g.reexecuted_tasks));
    }

    Json::obj()
        .set("grid", grid_obj)
        .set("scenarios", rows)
        .set("aggregates", aggs)
}

/// Aggregates as CSV (one row per grid cell). The `spec_reduce_*` columns
/// appear only when some cell actually speculated a reduce, so the CSV of
/// failure-free (and map-only-speculation) sweeps stays byte-identical.
pub fn aggregates_csv(groups: &[GroupStats]) -> String {
    let reduce_spec = groups.iter().any(GroupStats::any_reduce_spec);
    let mut out = String::from(
        "scheduler,mix,pms,profile,topology,arrival,failures,scale,seeds,\
         total_jobs,mean_completion_s,std_completion_s,p50_completion_s,\
         p99_completion_s,mean_throughput_jph,std_throughput_jph,\
         mean_locality_pct,std_locality_pct,mean_rack_pct,mean_remote_pct,\
         mean_miss_rate,mean_makespan_s,hotplugs,pm_crashes,spec_launches,\
         spec_wins,spec_kills,",
    );
    if reduce_spec {
        out.push_str("spec_reduce_launches,spec_reduce_wins,spec_reduce_kills,");
    }
    out.push_str("reexecuted_tasks\n");
    for g in groups {
        let _ = write!(
            out,
            "{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},",
            g.scheduler,
            g.mix,
            g.pms,
            g.profile,
            g.topology,
            g.arrival,
            g.failures,
            g.scale,
            g.seeds,
            g.total_jobs,
            g.mean_completion_s,
            g.std_completion_s,
            g.p50_completion_s,
            g.p99_completion_s,
            g.mean_throughput_jph,
            g.std_throughput_jph,
            g.mean_locality_pct,
            g.std_locality_pct,
            g.mean_rack_pct,
            g.mean_remote_pct,
            g.mean_miss_rate,
            g.mean_makespan_s,
            g.hotplugs,
            g.pm_crashes,
            g.spec_launches,
            g.spec_wins,
            g.spec_kills
        );
        if reduce_spec {
            let _ = write!(
                out,
                "{},{},{},",
                g.spec_reduce_launches, g.spec_reduce_wins, g.spec_reduce_kills
            );
        }
        let _ = writeln!(out, "{}", g.reexecuted_tasks);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::runner::run_sweep;

    fn tiny_results() -> (ScenarioGrid, Vec<ScenarioResult>) {
        let mut g = ScenarioGrid::quick();
        g.jobs_per_scenario = 3;
        let results = run_sweep(&g, 2);
        (g, results)
    }

    #[test]
    fn groups_fold_seed_replicates() {
        let (g, results) = tiny_results();
        let groups = aggregate(&results);
        // quick(): 2 schedulers x 2 mixes x 1 pm x 1 scale = 4 cells.
        assert_eq!(groups.len(), 4);
        for grp in &groups {
            assert_eq!(grp.seeds, g.seed_replicates);
            assert_eq!(grp.total_jobs, g.seed_replicates * g.jobs_per_scenario);
            assert!(grp.mean_completion_s > 0.0);
            assert!(grp.p99_completion_s >= grp.p50_completion_s);
        }
        // Sorted by key: schedulers alphabetical.
        assert!(groups.windows(2).all(|w| w[0].scheduler <= w[1].scheduler));
    }

    #[test]
    fn json_and_csv_render_deterministically() {
        let (g, results) = tiny_results();
        let groups = aggregate(&results);
        let a = sweep_json(&g, &results, &groups).render();
        let b = sweep_json(&g, &results, &aggregate(&results)).render();
        assert_eq!(a, b);
        assert!(a.contains("\"aggregates\":["));
        assert!(a.contains("\"stream_seed\":\"0x"));
        let csv = aggregates_csv(&groups);
        assert_eq!(csv.lines().count(), groups.len() + 1);
        assert!(csv.starts_with("scheduler,mix,"));
    }

    #[test]
    fn artifacts_exclude_wall_clock() {
        let (g, results) = tiny_results();
        let groups = aggregate(&results);
        let json = sweep_json(&g, &results, &groups).render();
        assert!(!json.contains("wall"), "artifacts must stay host-independent");
    }
}
