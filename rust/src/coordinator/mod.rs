//! The leader: binds cluster, HDFS, MapReduce engine, reconfigurator and
//! scheduler into the discrete-event loop, and produces the run report.
//!
//! **Purity contract** (the sweep harness depends on this): a simulation
//! run is a pure function `(SimConfig, SchedulerKind, JobTrace) -> Report`.
//! Every piece of mutable state — cluster, NameNode, job tables, event
//! queue, RNG — lives inside the per-run [`World`]; nothing is process
//! global, and all randomness derives from `cfg.seed`. Runs may therefore
//! execute concurrently on any threads in any order and still produce
//! bitwise-identical reports (only `Report::wall_s`, the host wall-clock,
//! varies). `harness::run_sweep` spreads scenarios across a thread pool on
//! the strength of this contract; the `parallel_run_bitwise_equals_serial`
//! test below holds it in place.

mod exec_engine;
mod world;

pub use exec_engine::ExecEngine;
pub use world::{encode_event_log, Event, LogEntry, World};

use crate::config::SimConfig;
use crate::metrics::RunMetrics;
use crate::predictor::{NativePredictor, Predictor};
use crate::scheduler::SchedulerKind;
use crate::workloads::trace::{JobTrace, TraceSource};

/// Result of one simulation run.
pub type Report = RunMetrics;

/// Run a streaming [`TraceSource`] under `kind`: jobs are pulled on
/// demand (see [`World::from_source`]), so trace length never bounds
/// memory. With a [`TraceSource::from_trace`] source this is bit-identical
/// to [`run_simulation_with`] on the equivalent materialized trace.
pub fn run_simulation_source(
    cfg: &SimConfig,
    kind: SchedulerKind,
    source: TraceSource,
    predictor: &mut dyn Predictor,
) -> Report {
    cfg.validate().expect("invalid SimConfig");
    let t0 = std::time::Instant::now();
    let mut scheduler = kind.build(cfg);
    let mut world = World::from_source(cfg.clone(), source);
    world.run(scheduler.as_mut(), predictor);
    let mut report = world.into_metrics(kind.name());
    report.wall_s = t0.elapsed().as_secs_f64();
    report
}

/// Run `trace` under `kind` with the native (pure-Rust) predictor.
pub fn run_simulation(cfg: &SimConfig, kind: SchedulerKind, trace: &JobTrace) -> Report {
    let mut predictor = NativePredictor::new();
    run_simulation_with(cfg, kind, trace, &mut predictor)
}

/// Run with an explicit predictor backend (e.g.
/// [`crate::runtime::XlaPredictor`] — the AOT JAX/Pallas artifacts).
pub fn run_simulation_with(
    cfg: &SimConfig,
    kind: SchedulerKind,
    trace: &JobTrace,
    predictor: &mut dyn Predictor,
) -> Report {
    cfg.validate().expect("invalid SimConfig");
    let t0 = std::time::Instant::now();
    let mut scheduler = kind.build(cfg);
    let mut world = World::new(cfg.clone(), trace.clone());
    world.run(scheduler.as_mut(), predictor);
    let mut report = world.into_metrics(kind.name());
    report.wall_s = t0.elapsed().as_secs_f64();
    report
}

/// Run with an explicit scheduler instance (custom tunings/ablations).
pub fn run_simulation_custom(
    cfg: &SimConfig,
    scheduler: &mut dyn crate::scheduler::Scheduler,
    trace: &JobTrace,
    predictor: &mut dyn Predictor,
) -> Report {
    cfg.validate().expect("invalid SimConfig");
    let t0 = std::time::Instant::now();
    let mut world = World::new(cfg.clone(), trace.clone());
    world.run(scheduler, predictor);
    let mut report = world.into_metrics(scheduler.name());
    report.wall_s = t0.elapsed().as_secs_f64();
    report
}

/// Run the same trace under two schedulers and return both reports
/// (the paper's two-phase experimental procedure, §5).
pub fn compare(
    cfg: &SimConfig,
    a: SchedulerKind,
    b: SchedulerKind,
    trace: &JobTrace,
) -> (Report, Report) {
    (
        run_simulation(cfg, a, trace),
        run_simulation(cfg, b, trace),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::{JobSpec, JobType};

    fn small_trace() -> JobTrace {
        JobTrace::new(vec![
            JobSpec::new(JobType::WordCount, 192.0).with_deadline(900.0),
            JobSpec::new(JobType::Grep, 128.0).with_deadline(700.0).at(5.0),
        ])
    }

    #[test]
    fn every_scheduler_completes_all_jobs() {
        let cfg = SimConfig::small();
        let trace = small_trace();
        for kind in SchedulerKind::ALL {
            let r = run_simulation(&cfg, kind, &trace);
            assert_eq!(r.completed_jobs(), 2, "{}", kind.name());
            assert!(r.makespan_s > 0.0);
            for j in &r.jobs {
                assert!(j.completion_s > 0.0);
                assert_eq!(j.local_maps + j.rack_maps + j.remote_maps, j.maps);
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = SimConfig::small();
        let trace = small_trace();
        let a = run_simulation(&cfg, SchedulerKind::DeadlineVc, &trace);
        let b = run_simulation(&cfg, SchedulerKind::DeadlineVc, &trace);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.hotplugs, b.hotplugs);
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x.completion_s, y.completion_s);
        }
    }

    #[test]
    fn parallel_run_bitwise_equals_serial() {
        // The harness's purity contract: the same (cfg, kind, trace) on a
        // different thread yields a bitwise-identical report.
        let cfg = SimConfig::small();
        let trace = small_trace();
        let serial = run_simulation(&cfg, SchedulerKind::DeadlineVc, &trace);
        let threaded = std::thread::spawn({
            let cfg = cfg.clone();
            let trace = trace.clone();
            move || run_simulation(&cfg, SchedulerKind::DeadlineVc, &trace)
        })
        .join()
        .expect("threaded run panicked");
        assert_eq!(serial.makespan_s, threaded.makespan_s);
        assert_eq!(serial.hotplugs, threaded.hotplugs);
        assert_eq!(serial.events, threaded.events);
        for (a, b) in serial.jobs.iter().zip(&threaded.jobs) {
            assert_eq!(a.completion_s, b.completion_s);
            assert_eq!(a.local_maps, b.local_maps);
        }
    }

    #[test]
    fn flat_topology_reproduces_binary_locality() {
        use crate::cluster::Topology;
        // The `--topology flat` regression contract: an explicit flat
        // topology is the default, draws the identical RNG stream and
        // yields bitwise-equal reports — and never produces a rack tier.
        let trace = small_trace();
        for kind in SchedulerKind::ALL {
            let default_cfg = SimConfig::small();
            let explicit = SimConfig {
                topology: Topology::Flat,
                ..SimConfig::small()
            };
            let a = run_simulation(&default_cfg, kind, &trace);
            let b = run_simulation(&explicit, kind, &trace);
            assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
            assert_eq!(a.events, b.events);
            for (x, y) in a.jobs.iter().zip(&b.jobs) {
                assert_eq!(x.completion_s.to_bits(), y.completion_s.to_bits());
                assert_eq!(x.local_maps, y.local_maps);
                assert_eq!(x.remote_maps, y.remote_maps);
                assert_eq!(x.rack_maps, 0, "flat runs must have no rack tier");
            }
            assert_eq!(a.rack_pct(), 0.0);
        }
    }

    #[test]
    fn racked_topology_splits_three_tiers() {
        use crate::cluster::Topology;
        let cfg = SimConfig {
            topology: Topology::Racks(2),
            ..SimConfig::small()
        };
        // Enough backlogged jobs that some maps go rack-local/off-rack.
        let trace = crate::workloads::trace::JobTrace::poisson(&cfg, 8, 2.0, 1.6..3.0, 5);
        for kind in SchedulerKind::ALL {
            let r = run_simulation(&cfg, kind, &trace);
            assert_eq!(r.completed_jobs(), 8, "{}", kind.name());
            for j in &r.jobs {
                assert_eq!(j.local_maps + j.rack_maps + j.remote_maps, j.maps);
            }
            let total = r.locality_pct() + r.rack_pct() + r.remote_pct();
            assert!((total - 100.0).abs() < 1e-9, "{}: {total}", kind.name());
        }
    }

    #[test]
    fn different_seeds_change_layout() {
        let trace = small_trace();
        let a = run_simulation(&SimConfig::small(), SchedulerKind::Fair, &trace);
        let cfg2 = SimConfig {
            seed: 777,
            ..SimConfig::small()
        };
        let b = run_simulation(&cfg2, SchedulerKind::Fair, &trace);
        // Same totals, (almost surely) different placement/locality.
        assert_eq!(a.completed_jobs(), b.completed_jobs());
    }

    #[test]
    fn compare_runs_both() {
        let cfg = SimConfig::small();
        let (fair, prop) = compare(
            &cfg,
            SchedulerKind::Fair,
            SchedulerKind::DeadlineVc,
            &small_trace(),
        );
        assert_eq!(fair.scheduler, "fair");
        assert_eq!(prop.scheduler, "deadline_vc");
        assert_eq!(fair.completed_jobs(), prop.completed_jobs());
    }
}
