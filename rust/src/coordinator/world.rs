//! World state + the event loop.

use crate::cluster::{Cluster, LocalityTier, NodeId};
use crate::config::{ExecMode, SimConfig};
use crate::hdfs::NameNode;
use crate::mapreduce::{JobId, JobState, TaskCost, TaskId, TaskRef};
use crate::metrics::{HotplugMark, JobRecord, RunMetrics, TaskSpan, TraceLog};
use crate::predictor::Predictor;
use crate::reconfig::ConfigManager;
use crate::scheduler::{Action, SchedView, Scheduler};
use crate::sim::{EventQueue, SimTime};
use crate::util::Rng;
use crate::workloads::trace::JobTrace;
use crate::workloads::JobSpec;

use super::exec_engine::ExecEngine;

/// Discrete events driving the simulation.
#[derive(Clone, Copy, Debug)]
pub enum Event {
    /// Submission of trace job `idx`.
    JobArrival(u32),
    /// TaskTracker heartbeat (recurs every `heartbeat_s`).
    Heartbeat(NodeId),
    MapDone {
        job: JobId,
        task: TaskId,
        node: NodeId,
    },
    ReduceDone {
        job: JobId,
        task: TaskId,
        node: NodeId,
    },
    /// A granted vCPU hot-plug completed; launch the delayed local task.
    HotplugDone {
        from: NodeId,
        to: NodeId,
        task: TaskRef,
    },
}

/// All mutable simulation state.
pub struct World {
    pub cfg: SimConfig,
    pub cluster: Cluster,
    pub nn: NameNode,
    pub jobs: Vec<JobState>,
    costs: Vec<TaskCost>,
    pub cm: ConfigManager,
    queue: EventQueue<Event>,
    rng: Rng,
    pending_specs: Vec<JobSpec>,
    arrived: usize,
    /// Jobs that reached `JobPhase::Done` — kept in lockstep with the per-
    /// job transitions so [`World::all_done`] is O(1) per event instead of
    /// an O(jobs) scan (the scan is retained behind
    /// [`World::use_naive_all_done`] for the simcore bench baseline).
    done_jobs: usize,
    naive_all_done: bool,
    /// Per-job total intermediate shuffle MB, computed once at
    /// `JobArrival` (where it already seeds `JobStats`) and reused by
    /// every `launch_reduce` — the seed re-summed `block_mb ×
    /// map_output_mb` per reduce task, O(maps × reduces) per job.
    inter_mb: Vec<f64>,
    /// Pooled scheduler action buffer, cleared and reused on every event.
    action_buf: Vec<Action>,
    exec: Option<ExecEngine>,
    /// Cross-rack map-input fetches currently in flight — the load on the
    /// topology's shared core link. A fetch starting while `f` flows are
    /// active (itself included) gets `Topology::cross_rack_mbps(net, f)`
    /// for its whole duration (no re-fairing mid-flight; see
    /// `cluster::topology` docs). Always 0 on the flat topology.
    cross_rack_flows: u32,
    // metrics
    records: Vec<JobRecord>,
    trace_log: Option<TraceLog>,
    heartbeats: u64,
    predictor_calls_estimate: u64,
    /// Hard stop: no trace should need more than this many sim-days.
    max_sim_time: SimTime,
}

impl World {
    pub fn new(cfg: SimConfig, trace: JobTrace) -> Self {
        let cluster = Cluster::build(&cfg);
        let cm = ConfigManager::new(cfg.pms);
        let mut queue = EventQueue::new();
        // Stagger node heartbeats uniformly across the interval.
        let hb_ms = (cfg.heartbeat_s * 1e3) as u64;
        for n in 0..cfg.nodes() {
            let offset = hb_ms * n as u64 / cfg.nodes() as u64;
            queue.schedule_at(SimTime::from_millis(offset), Event::Heartbeat(NodeId(n as u32)));
        }
        for (i, spec) in trace.jobs.iter().enumerate() {
            queue.schedule_at(
                SimTime::from_secs_f64(spec.submit_s),
                Event::JobArrival(i as u32),
            );
        }
        let exec = match cfg.exec {
            ExecMode::Real => Some(ExecEngine::new(cfg.seed)),
            ExecMode::Synthetic => None,
        };
        let rng = Rng::new(cfg.seed);
        Self {
            cluster,
            nn: NameNode::new(),
            jobs: Vec::new(),
            costs: Vec::new(),
            cm,
            queue,
            rng,
            pending_specs: trace.jobs,
            arrived: 0,
            done_jobs: 0,
            naive_all_done: false,
            inter_mb: Vec::new(),
            action_buf: Vec::new(),
            exec,
            cross_rack_flows: 0,
            records: Vec::new(),
            trace_log: None,
            heartbeats: 0,
            predictor_calls_estimate: 0,
            max_sim_time: SimTime::from_secs_f64(30.0 * 24.0 * 3600.0),
            cfg,
        }
    }

    pub fn now(&self) -> SimTime {
        self.queue.now()
    }

    /// Advance the clock without processing events (test helper for
    /// timeout paths; panics if it would skip scheduled events backwards).
    pub fn advance(&mut self, dt: SimTime) {
        self.queue.advance_to(self.queue.now() + dt);
    }

    /// Every trace job arrived and finished. Checked after *every* event,
    /// so it runs off the `done_jobs` counter (O(1)) rather than scanning
    /// the job table — at stress scale the seed's `iter().all(is_done)`
    /// scan alone was O(jobs) × O(events) of the whole run.
    fn all_done(&self) -> bool {
        if self.naive_all_done {
            return self.arrived == self.pending_specs.len()
                && self.jobs.iter().all(|j| j.is_done());
        }
        debug_assert_eq!(
            self.done_jobs,
            self.jobs.iter().filter(|j| j.is_done()).count()
        );
        self.arrived == self.pending_specs.len() && self.done_jobs == self.jobs.len()
    }

    /// Opt back into the seed's O(jobs)-per-event `all_done` scan — the
    /// pre-index loop `benches/simcore.rs` measures the counter against.
    pub fn use_naive_all_done(&mut self) {
        self.naive_all_done = true;
    }

    /// Immutable snapshot for the scheduler.
    pub fn view(&self) -> SchedView<'_> {
        SchedView {
            cfg: &self.cfg,
            cluster: &self.cluster,
            jobs: &self.jobs,
            cm: &self.cm,
            now: self.queue.now(),
        }
    }

    /// Capture a per-task execution trace (Gantt/JSON export).
    pub fn enable_trace(&mut self) {
        self.trace_log = Some(TraceLog::new());
    }

    /// The captured trace, if enabled.
    pub fn trace_log(&self) -> Option<&TraceLog> {
        self.trace_log.as_ref()
    }

    /// Number of jobs in the driving trace (arrived or not).
    pub fn trace_len(&self) -> usize {
        self.pending_specs.len()
    }

    /// Process exactly one event; false when the queue is empty.
    pub fn step_one(
        &mut self,
        scheduler: &mut dyn Scheduler,
        predictor: &mut dyn Predictor,
    ) -> bool {
        match self.queue.pop() {
            Some((_, ev)) => {
                self.handle(ev, scheduler, predictor);
                true
            }
            None => false,
        }
    }

    /// Drive the loop to completion.
    pub fn run(&mut self, scheduler: &mut dyn Scheduler, predictor: &mut dyn Predictor) {
        while let Some((at, ev)) = self.queue.pop() {
            if at > self.max_sim_time {
                panic!(
                    "simulation exceeded {} — livelock? ({} jobs unfinished)",
                    self.max_sim_time,
                    self.jobs.iter().filter(|j| !j.is_done()).count()
                );
            }
            self.handle(ev, scheduler, predictor);
            if self.all_done() {
                break;
            }
        }
        assert!(
            self.all_done(),
            "event queue drained with {} unfinished jobs",
            self.jobs.iter().filter(|j| !j.is_done()).count()
        );
    }

    fn handle(
        &mut self,
        ev: Event,
        scheduler: &mut dyn Scheduler,
        predictor: &mut dyn Predictor,
    ) {
        match ev {
            Event::JobArrival(idx) => {
                let spec = self.pending_specs[idx as usize].clone();
                self.arrived += 1;
                let now = self.now();
                let id = JobId(self.jobs.len() as u32);
                let cost = TaskCost::new(&self.cfg, &spec);
                let mut job = JobState::create(
                    id,
                    spec,
                    &self.cfg,
                    &mut self.nn,
                    &mut self.rng,
                    now,
                );
                // Seed the shuffle prior from the cost model (the paper
                // estimates t_s from network bandwidth, §2.1 Table 1).
                let inter_mb: f64 = job
                    .block_mb
                    .iter()
                    .map(|&mb| cost.map_output_mb(mb))
                    .sum();
                job.stats = crate::predictor::JobStats::new(
                    self.cfg.prior_map_s,
                    cost.t_shuffle_estimate(inter_mb, job.total_maps(), job.total_reduces()),
                );
                self.jobs.push(job);
                self.costs.push(cost);
                // Cache the job-wide shuffle volume for launch_reduce.
                self.inter_mb.push(inter_mb);
                if let Some(exec) = &mut self.exec {
                    exec.register_job(id, &self.jobs[id.idx()]);
                }
                let mut actions = std::mem::take(&mut self.action_buf);
                actions.clear();
                scheduler.on_job_added(&self.view(), id, predictor, &mut actions);
                self.predictor_calls_estimate += 1;
                self.apply_actions(&actions);
                self.action_buf = actions;
            }
            Event::Heartbeat(node) => {
                self.heartbeats += 1;
                let mut actions = std::mem::take(&mut self.action_buf);
                actions.clear();
                scheduler.on_heartbeat(&self.view(), node, predictor, &mut actions);
                self.apply_actions(&actions);
                self.action_buf = actions;
                self.match_reconfigs();
                // Recurring heartbeat while work remains.
                if !self.all_done() {
                    self.queue.schedule_in(
                        SimTime::from_secs_f64(self.cfg.heartbeat_s),
                        Event::Heartbeat(node),
                    );
                }
            }
            Event::MapDone { job, task, node } => {
                let now = self.now();
                if let crate::mapreduce::TaskState::Running { started, tier, .. } =
                    *self.jobs[job.idx()].map_state(task)
                {
                    if let Some(tl) = &mut self.trace_log {
                        tl.record_span(TaskSpan {
                            job,
                            kind: crate::mapreduce::TaskKind::Map,
                            task: task.0,
                            node,
                            start: started,
                            end: now,
                            tier,
                        });
                    }
                    // The task's cross-rack fetch has left the shared core.
                    if tier == LocalityTier::Remote && self.cfg.topology.is_racked() {
                        debug_assert!(self.cross_rack_flows > 0);
                        self.cross_rack_flows = self.cross_rack_flows.saturating_sub(1);
                    }
                }
                self.jobs[job.idx()].mark_map_finished(task, now);
                let vm = self.cluster.vm_mut(node);
                debug_assert!(vm.busy_map > 0);
                vm.busy_map -= 1;
                if let Some(exec) = &mut self.exec {
                    exec.run_map_task(job, task, &self.jobs[job.idx()]);
                }
                let mut actions = std::mem::take(&mut self.action_buf);
                actions.clear();
                scheduler.on_task_finished(&self.view(), job, predictor, &mut actions);
                self.predictor_calls_estimate += 1;
                self.apply_actions(&actions);
                self.action_buf = actions;
                self.match_reconfigs();
            }
            Event::ReduceDone { job, task, node } => {
                let now = self.now();
                if let Some(tl) = &mut self.trace_log {
                    if let crate::mapreduce::TaskState::Running { started, .. } =
                        *self.jobs[job.idx()].reduce_state(task)
                    {
                        tl.record_span(TaskSpan {
                            job,
                            kind: crate::mapreduce::TaskKind::Reduce,
                            task: task.0,
                            node,
                            start: started,
                            end: now,
                            tier: LocalityTier::Remote,
                        });
                    }
                }
                self.jobs[job.idx()].mark_reduce_finished(task, now);
                let vm = self.cluster.vm_mut(node);
                debug_assert!(vm.busy_reduce > 0);
                vm.busy_reduce -= 1;
                if let Some(exec) = &mut self.exec {
                    exec.run_reduce_task(job, task, &self.jobs[job.idx()]);
                }
                if self.jobs[job.idx()].is_done() {
                    // The only transition into `JobPhase::Done` — keep the
                    // O(1) `all_done` counter in lockstep.
                    self.done_jobs += 1;
                    self.record_job(job);
                }
                let mut actions = std::mem::take(&mut self.action_buf);
                actions.clear();
                scheduler.on_task_finished(&self.view(), job, predictor, &mut actions);
                self.predictor_calls_estimate += 1;
                self.apply_actions(&actions);
                self.action_buf = actions;
                self.match_reconfigs();
            }
            Event::HotplugDone { from, to, task } => {
                // The released core was unplugged at grant time; now it
                // arrives at the target VM and the delayed task launches.
                self.cluster
                    .plug_spare_core(to)
                    .expect("hot-plug grant lost its spare core");
                if let Some(tl) = &mut self.trace_log {
                    let at = self.queue.now();
                    tl.record_hotplug(HotplugMark { at, from, to });
                }
                let job = task.job;
                let js = &self.jobs[job.idx()];
                let tid = task.id;
                if js.map_state(tid).is_awaiting() {
                    self.launch_map(job, tid, to, LocalityTier::NodeLocal);
                } else {
                    // Task was cancelled while the core was in flight; the
                    // core simply stays with the target VM (it can host
                    // any future local task or be re-released).
                }
            }
        }
    }

    /// Validate + apply scheduler actions.
    pub(crate) fn apply_actions(&mut self, actions: &[Action]) {
        for &a in actions {
            match a {
                Action::LaunchMap { job, task, node } => {
                    let tier = self.jobs[job.idx()].map_tier(task, node, &self.cluster);
                    assert!(
                        self.cluster.vm(node).free_map_slots() > 0,
                        "scheduler overfilled map slots on {node:?}"
                    );
                    self.launch_map(job, task, node, tier);
                }
                Action::LaunchReduce { job, task, node } => {
                    assert!(
                        self.cluster.vm(node).free_reduce_slots() > 0,
                        "scheduler overfilled reduce slots on {node:?}"
                    );
                    assert!(
                        self.jobs[job.idx()].map_finished(),
                        "reduce launched before map phase finished"
                    );
                    self.launch_reduce(job, task, node);
                }
                Action::AwaitReconfig {
                    job,
                    task,
                    target,
                    release_from,
                } => {
                    let js = &mut self.jobs[job.idx()];
                    debug_assert!(js.map_is_local(task, target));
                    js.mark_map_awaiting(task, target);
                    let tref = TaskRef::map(job, task.0);
                    self.cm
                        .enqueue_assign(self.cluster.pm_of(target), target, tref);
                    self.cm
                        .enqueue_release(self.cluster.pm_of(release_from), release_from);
                }
                Action::RegisterRelease { node } => {
                    self.cm.enqueue_release(self.cluster.pm_of(node), node);
                }
                Action::CancelAwait { job, task } => {
                    let tref = TaskRef::map(job, task.0);
                    self.cm.cancel_task(tref);
                    self.jobs[job.idx()].mark_map_await_cancelled(task);
                }
                Action::SetAlloc {
                    job,
                    map_slots,
                    reduce_slots,
                } => {
                    let js = &mut self.jobs[job.idx()];
                    js.alloc_map_slots = map_slots;
                    js.alloc_reduce_slots = reduce_slots;
                }
            }
        }
        debug_assert!(self.cluster.check_invariants().is_ok());
    }

    /// Match AQ/RQ queues and start granted hot-plugs.
    pub(crate) fn match_reconfigs(&mut self) {
        let grants = self.cm.match_queues(&self.cluster);
        for g in grants {
            match self.cluster.unplug_core(g.from) {
                Ok(()) => {
                    self.queue.schedule_in(
                        SimTime::from_millis(self.cfg.hotplug_ms),
                        Event::HotplugDone {
                            from: g.from,
                            to: g.to,
                            task: g.task,
                        },
                    );
                }
                Err(_) => {
                    // Release went stale between match and apply (shouldn't
                    // happen — match checks can_release — but stay safe):
                    // put the task back to pending.
                    let js = &mut self.jobs[g.task.job.idx()];
                    if js.map_state(g.task.id).is_awaiting() {
                        js.mark_map_await_cancelled(g.task.id);
                    }
                }
            }
        }
    }

    pub(crate) fn launch_map(
        &mut self,
        job: JobId,
        task: TaskId,
        node: NodeId,
        tier: LocalityTier,
    ) {
        let now = self.now();
        let js = &mut self.jobs[job.idx()];
        js.mark_map_launched(task, node, tier, now);
        self.cluster.vm_mut(node).busy_map += 1;
        let block_mb = js.block_mb[task.0 as usize];
        // Tiered input fetch: local disk scan, rack-local NIC read, or a
        // contended share of the topology's cross-rack core. On the flat
        // topology the remote tier reads at full NIC speed — the seed
        // model, byte for byte.
        let topo = self.cfg.topology;
        let io_mbps = match tier {
            LocalityTier::NodeLocal => self.cfg.disk_mbps,
            LocalityTier::RackLocal => topo.rack_mbps(self.cfg.net_mbps),
            LocalityTier::Remote => {
                if topo.is_racked() {
                    self.cross_rack_flows += 1;
                    topo.cross_rack_mbps(self.cfg.net_mbps, self.cross_rack_flows)
                } else {
                    self.cfg.net_mbps
                }
            }
        };
        // Heterogeneity: a task on a speed-s machine takes nominal/s time.
        let speed = self.cluster.vm(node).speed;
        let secs = self.costs[job.idx()].map_secs_at(block_mb, io_mbps, &mut self.rng) / speed;
        self.queue.schedule_in(
            SimTime::from_secs_f64(secs),
            Event::MapDone { job, task, node },
        );
    }

    fn launch_reduce(&mut self, job: JobId, task: TaskId, node: NodeId) {
        let now = self.now();
        let js = &mut self.jobs[job.idx()];
        js.mark_reduce_launched(task, node, now);
        self.cluster.vm_mut(node).busy_reduce += 1;
        // Shuffle volume: measured in real mode; in synthetic mode the
        // job-wide sum was computed once at JobArrival (identical fold,
        // identical f64) and cached — re-summing here was O(maps) per
        // reduce launch.
        let inter_mb = if let Some(exec) = &self.exec {
            exec.intermediate_mb(job)
        } else {
            self.inter_mb[job.idx()]
        };
        let js = &self.jobs[job.idx()];
        let speed = self.cluster.vm(node).speed;
        let secs = self.costs[job.idx()].reduce_secs(
            inter_mb,
            js.total_maps(),
            js.total_reduces(),
            &mut self.rng,
        ) / speed;
        self.queue.schedule_in(
            SimTime::from_secs_f64(secs),
            Event::ReduceDone { job, task, node },
        );
    }

    fn record_job(&mut self, job: JobId) {
        let js = &self.jobs[job.idx()];
        let completion = js.completion_time().expect("job done");
        self.records.push(JobRecord {
            id: js.id,
            job_type: js.spec.job_type,
            input_mb: js.spec.input_mb,
            submitted: js.submitted,
            finished: js.submitted + completion,
            completion_s: completion.as_secs_f64(),
            map_phase_s: js
                .map_phase_duration()
                .map(|d| d.as_secs_f64())
                .unwrap_or(0.0),
            deadline_s: js.spec.deadline_s,
            met_deadline: js.met_deadline(),
            local_maps: js.local_maps,
            rack_maps: js.rack_maps,
            remote_maps: js.remote_maps,
            maps: js.total_maps(),
            reduces: js.total_reduces(),
        });
    }

    /// Access the real-exec engine (E2E verification).
    pub fn exec_engine(&self) -> Option<&ExecEngine> {
        self.exec.as_ref()
    }

    pub fn into_metrics(self, scheduler: &str) -> RunMetrics {
        let makespan_s = self
            .records
            .iter()
            .map(|r| r.finished.as_secs_f64())
            .fold(0.0f64, f64::max);
        RunMetrics {
            scheduler: scheduler.to_string(),
            jobs: self.records,
            makespan_s,
            hotplugs: self.cm.hotplugs,
            heartbeats: self.heartbeats,
            events: self.queue.processed(),
            predictor_calls: self.predictor_calls_estimate,
            wall_s: 0.0,
        }
    }
}
