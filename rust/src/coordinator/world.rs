//! World state + the event loop.

use crate::cluster::{Cluster, LocalityTier, NodeId, PmId};
use crate::config::{ExecMode, SimConfig};
use crate::hdfs::NameNode;
use crate::mapreduce::{
    dec_task_ref, dec_time, decode_job_spec, enc_task_ref, enc_time, encode_job_spec,
    straggler_multiplier, JobId, JobState, TaskCost, TaskId, TaskRef, TaskState,
};
use crate::metrics::{
    FailureStats, HotplugMark, JobRecord, RunMetrics, StreamAgg, TaskSpan, TraceLog,
};
use crate::predictor::Predictor;
use crate::reconfig::ConfigManager;
use crate::scheduler::{Action, SchedView, Scheduler, SchedulerKind};
use crate::sim::{EventQueue, SimTime};
use crate::util::codec::{fnv1a64, Dec, Enc};
use crate::util::rng::mix64;
use crate::util::stats::QuantileSketch;
use crate::util::stats::Summary;
use crate::util::Rng;
use crate::workloads::trace::{
    failure_trace, read_failure_trace_file, JobTrace, TraceSource, FAILURE_STREAM_TAG,
};
use crate::workloads::{JobSpec, ALL_JOB_TYPES};

use super::exec_engine::ExecEngine;

/// Discrete events driving the simulation. Every state transition enters
/// the world through exactly one of these; [`World::reduce`] applies it
/// and reports which scheduler decision point (if any) it hit, so live
/// runs, snapshots and log replay all share one transition function.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Event {
    /// Submission of the `idx`-th arrival. Specs are *pulled* from the
    /// trace source one at a time: only the next pending arrival is ever
    /// scheduled, and handling it pulls + schedules the one after. The
    /// pop order is nonetheless bit-identical to scheduling every arrival
    /// up front, because all arrival sequence numbers come from one band
    /// reserved at construction (see `EventQueue::reserve_seqs`).
    JobArrival(u32),
    /// TaskTracker heartbeat (recurs every `heartbeat_s`).
    Heartbeat(NodeId),
    MapDone {
        job: JobId,
        task: TaskId,
        node: NodeId,
        /// Attempt epoch this completion belongs to (stamped at launch).
        /// A completion whose epoch no longer matches the task's current
        /// primary or speculative attempt is *stale* — its launch was
        /// killed by a PM crash or lost the speculation race — and is
        /// dropped. With failures off every task launches exactly once,
        /// so every epoch matches and the handler is the seed path.
        attempt: u32,
    },
    ReduceDone {
        job: JobId,
        task: TaskId,
        node: NodeId,
        /// Attempt epoch, as for [`Event::MapDone`]: stale when the
        /// attempt was crash-killed or lost the reduce speculation race.
        attempt: u32,
    },
    /// A granted vCPU hot-plug completed; launch the delayed local task.
    HotplugDone {
        from: NodeId,
        to: NodeId,
        task: TaskRef,
    },
    /// Fail-stop crash of a physical machine (from the failure trace).
    PmFailure(PmId),
    /// The crashed PM rejoins with empty VMs and no HDFS blocks.
    PmRecovery(PmId),
}

/// Scheduler decision point hit by a reduced event: which callback the
/// coordinator must invoke (against the post-reduce view) to obtain the
/// event's actions. `None` marks pure infrastructure transitions — stale
/// completions, hot-plug deliveries, failure events, heartbeats of dead
/// nodes — which never consult the scheduler and so never enter the
/// decision log.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Decision {
    None,
    JobAdded(JobId),
    Heartbeat(NodeId),
    TaskFinished(JobId),
}

/// One entry of the decision log: an event that hit a scheduler callback,
/// paired with the actions the scheduler returned for it. Events reducing
/// to no decision are not logged — [`World::replay_to`] re-derives their
/// effects from the deterministic reduce step, so the log pins exactly
/// (and only) the policy's choices.
#[derive(Clone, Debug, PartialEq)]
pub struct LogEntry {
    pub event: Event,
    pub actions: Vec<Action>,
}

/// Event wire format (snapshot queue section + encoded decision logs).
pub(crate) fn enc_event(e: &mut Enc, ev: Event) {
    match ev {
        Event::JobArrival(idx) => {
            e.u8(0);
            e.u32(idx);
        }
        Event::Heartbeat(node) => {
            e.u8(1);
            e.u32(node.0);
        }
        Event::MapDone { job, task, node, attempt } => {
            e.u8(2);
            e.u32(job.0);
            e.u32(task.0);
            e.u32(node.0);
            e.u32(attempt);
        }
        Event::ReduceDone { job, task, node, attempt } => {
            e.u8(3);
            e.u32(job.0);
            e.u32(task.0);
            e.u32(node.0);
            e.u32(attempt);
        }
        Event::HotplugDone { from, to, task } => {
            e.u8(4);
            e.u32(from.0);
            e.u32(to.0);
            enc_task_ref(e, task);
        }
        Event::PmFailure(pm) => {
            e.u8(5);
            e.u32(pm.0);
        }
        Event::PmRecovery(pm) => {
            e.u8(6);
            e.u32(pm.0);
        }
    }
}

/// Inverse of [`enc_event`].
pub(crate) fn dec_event(d: &mut Dec) -> Result<Event, String> {
    Ok(match d.u8()? {
        0 => Event::JobArrival(d.u32()?),
        1 => Event::Heartbeat(NodeId(d.u32()?)),
        2 => Event::MapDone {
            job: JobId(d.u32()?),
            task: TaskId(d.u32()?),
            node: NodeId(d.u32()?),
            attempt: d.u32()?,
        },
        3 => Event::ReduceDone {
            job: JobId(d.u32()?),
            task: TaskId(d.u32()?),
            node: NodeId(d.u32()?),
            attempt: d.u32()?,
        },
        4 => Event::HotplugDone {
            from: NodeId(d.u32()?),
            to: NodeId(d.u32()?),
            task: dec_task_ref(d)?,
        },
        5 => Event::PmFailure(PmId(d.u32()?)),
        6 => Event::PmRecovery(PmId(d.u32()?)),
        b => return Err(format!("invalid event tag {b}")),
    })
}

fn enc_action(e: &mut Enc, a: Action) {
    match a {
        Action::LaunchMap { job, task, node } => {
            e.u8(0);
            e.u32(job.0);
            e.u32(task.0);
            e.u32(node.0);
        }
        Action::LaunchSpeculativeMap { job, task, node } => {
            e.u8(1);
            e.u32(job.0);
            e.u32(task.0);
            e.u32(node.0);
        }
        Action::LaunchReduce { job, task, node } => {
            e.u8(2);
            e.u32(job.0);
            e.u32(task.0);
            e.u32(node.0);
        }
        Action::AwaitReconfig { job, task, target, release_from } => {
            e.u8(3);
            e.u32(job.0);
            e.u32(task.0);
            e.u32(target.0);
            e.u32(release_from.0);
        }
        Action::RegisterRelease { node } => {
            e.u8(4);
            e.u32(node.0);
        }
        Action::CancelAwait { job, task } => {
            e.u8(5);
            e.u32(job.0);
            e.u32(task.0);
        }
        Action::SetAlloc { job, map_slots, reduce_slots } => {
            e.u8(6);
            e.u32(job.0);
            e.u32(map_slots);
            e.u32(reduce_slots);
        }
        Action::LaunchSpeculativeReduce { job, task, node } => {
            e.u8(7);
            e.u32(job.0);
            e.u32(task.0);
            e.u32(node.0);
        }
    }
}

/// Canonical byte encoding of a decision log — the artifact golden-hash
/// tests and differential comparisons pin (`docs/EVENT_LOG.md`).
pub fn encode_event_log(log: &[LogEntry]) -> Vec<u8> {
    let mut e = Enc::new();
    e.usize(log.len());
    for entry in log {
        enc_event(&mut e, entry.event);
        e.usize(entry.actions.len());
        for &a in &entry.actions {
            enc_action(&mut e, a);
        }
    }
    e.into_bytes()
}

/// All mutable simulation state.
pub struct World {
    pub cfg: SimConfig,
    pub cluster: Cluster,
    pub nn: NameNode,
    /// Live job window: `jobs[0]` is the job with id `jobs_base`. Outside
    /// streaming mode the window is the full job table (`jobs_base == 0`,
    /// nothing is ever retired).
    pub jobs: Vec<JobState>,
    costs: Vec<TaskCost>,
    pub cm: ConfigManager,
    queue: EventQueue<Event>,
    rng: Rng,
    /// Streaming job source; arrivals are pulled one at a time.
    source: TraceSource,
    /// The spec of the next scheduled (not yet handled) arrival.
    next_spec: Option<JobSpec>,
    /// First sequence number of the band reserved for arrival events:
    /// arrival `i` is scheduled with seq `arrival_band + i`, reproducing
    /// the upfront-scheduling pop order exactly.
    arrival_band: u64,
    arrived: usize,
    /// Job id of `jobs[0]` — jobs below this were retired after
    /// completing (streaming mode only; see [`World::maybe_compact`]).
    jobs_base: usize,
    /// Length of the contiguous done prefix of `jobs` (the compaction
    /// candidate). Advanced on each job-done transition, O(1) amortized.
    done_prefix: usize,
    /// Jobs that reached `JobPhase::Done` — kept in lockstep with the per-
    /// job transitions so [`World::all_done`] is O(1) per event instead of
    /// an O(jobs) scan (the scan is retained behind
    /// [`World::use_naive_all_done`] for the simcore bench baseline).
    done_jobs: usize,
    naive_all_done: bool,
    /// Per-job total intermediate shuffle MB, computed once at
    /// `JobArrival` (where it already seeds `JobStats`) and reused by
    /// every `launch_reduce` — the seed re-summed `block_mb ×
    /// map_output_mb` per reduce task, O(maps × reduces) per job.
    inter_mb: Vec<f64>,
    /// Pooled scheduler action buffer, cleared and reused on every event.
    action_buf: Vec<Action>,
    /// Jobs mutated since the last scheduler callback, in mutation order
    /// (deduplicated via `dirty_flags`). Flushed as `on_job_updated`
    /// notifications immediately before every scheduler callback, so a
    /// scheduler's persistent indexes always see the current job state
    /// without scanning the job table. Over-notification is part of the
    /// callback contract — sites mark liberally.
    dirty: Vec<JobId>,
    dirty_flags: Vec<bool>,
    /// `on_sim_start` has been delivered (first `handle` call).
    started: bool,
    exec: Option<ExecEngine>,
    /// Cross-rack map-input fetches currently in flight — the load on the
    /// topology's shared core link. A fetch starting while `f` flows are
    /// active (itself included) gets `Topology::cross_rack_mbps(net, f)`
    /// for its whole duration (no re-fairing mid-flight; see
    /// `cluster::topology` docs). Always 0 on the flat topology.
    cross_rack_flows: u32,
    /// Dedicated failure/straggler RNG stream (`seed ^ FAILURE_STREAM_TAG`,
    /// never the main sim RNG): with the failure model off it is never
    /// drawn from, so the main stream — and the whole run — stays
    /// byte-identical to the no-failure seed.
    failure_rng: Rng,
    // metrics
    fail_stats: FailureStats,
    records: Vec<JobRecord>,
    /// Constant-memory metric accumulators (`cfg.stream_metrics`); when
    /// set, completed jobs fold into this instead of pushing a record.
    stream: Option<StreamAgg>,
    trace_log: Option<TraceLog>,
    /// Decision log (see [`LogEntry`]); captured only when enabled via
    /// [`World::enable_event_log`] — the hot path pays one branch.
    event_log: Option<Vec<LogEntry>>,
    heartbeats: u64,
    predictor_calls_estimate: u64,
    /// Hard stop: no trace should need more than this many sim-days.
    max_sim_time: SimTime,
}

impl World {
    /// Width of the reserved arrival sequence band — caps a run at 2^32
    /// arrivals, the range of `Event::JobArrival`'s index anyway.
    const ARRIVAL_SEQ_BAND: u64 = 1 << 32;

    pub fn new(cfg: SimConfig, trace: JobTrace) -> Self {
        Self::from_source(cfg, TraceSource::from_trace(trace))
    }

    /// Build a world driven by a streaming [`TraceSource`]: only the next
    /// pending arrival is materialized at any time, so trace length never
    /// bounds memory. With a [`TraceSource::from_trace`] source this is
    /// bit-identical to the old eager constructor (same RNG streams, same
    /// event pop order via the reserved arrival seq band).
    pub fn from_source(cfg: SimConfig, mut source: TraceSource) -> Self {
        let cluster = Cluster::build(&cfg);
        let cm = ConfigManager::new(cfg.pms);
        let mut queue = EventQueue::new();
        // Stagger node heartbeats uniformly across the interval.
        let hb_ms = (cfg.heartbeat_s * 1e3) as u64;
        for n in 0..cfg.nodes() {
            let offset = hb_ms * n as u64 / cfg.nodes() as u64;
            queue.schedule_at(SimTime::from_millis(offset), Event::Heartbeat(NodeId(n as u32)));
        }
        // Reserve the arrival seq band exactly where the upfront loop
        // used to schedule, then pull + schedule only the first arrival.
        let arrival_band = queue.reserve_seqs(Self::ARRIVAL_SEQ_BAND);
        let next_spec = source.next_job();
        if let Some(spec) = &next_spec {
            queue.schedule_at_with_seq(
                SimTime::from_secs_f64(spec.submit_s),
                arrival_band,
                Event::JobArrival(0),
            );
        }
        // Crash/recover timeline: replayed from a recorded trace file
        // when one is configured, else generated from the dedicated
        // failure stream — empty (zero events scheduled) unless the
        // model injects crashes.
        let pm_racks: Vec<u32> = (0..cfg.pms).map(|p| cfg.pm_rack(p)).collect();
        let failure_events = match &cfg.failure_trace {
            Some(path) => read_failure_trace_file(path, &pm_racks)
                .unwrap_or_else(|e| panic!("failure trace {path}: {e}")),
            None => failure_trace(&cfg.failures, cfg.seed, &pm_racks),
        };
        for fe in failure_events {
            let ev = if fe.crash {
                Event::PmFailure(PmId(fe.pm as u32))
            } else {
                Event::PmRecovery(PmId(fe.pm as u32))
            };
            queue.schedule_at(SimTime::from_secs_f64(fe.at_s), ev);
        }
        let exec = match cfg.exec {
            ExecMode::Real => Some(ExecEngine::new(cfg.seed)),
            ExecMode::Synthetic => None,
        };
        let rng = Rng::new(cfg.seed);
        let stream = if cfg.stream_metrics {
            Some(StreamAgg::new())
        } else {
            None
        };
        Self {
            cluster,
            nn: NameNode::new(),
            jobs: Vec::new(),
            costs: Vec::new(),
            cm,
            queue,
            rng,
            source,
            next_spec,
            arrival_band,
            arrived: 0,
            jobs_base: 0,
            done_prefix: 0,
            done_jobs: 0,
            naive_all_done: false,
            inter_mb: Vec::new(),
            action_buf: Vec::new(),
            dirty: Vec::new(),
            dirty_flags: Vec::new(),
            started: false,
            exec,
            cross_rack_flows: 0,
            failure_rng: Rng::new(mix64(cfg.seed ^ FAILURE_STREAM_TAG)),
            fail_stats: FailureStats::default(),
            records: Vec::new(),
            stream,
            trace_log: None,
            event_log: None,
            heartbeats: 0,
            predictor_calls_estimate: 0,
            max_sim_time: SimTime::from_secs_f64(30.0 * 24.0 * 3600.0),
            cfg,
        }
    }

    pub fn now(&self) -> SimTime {
        self.queue.now()
    }

    /// Advance the clock without processing events (test helper for
    /// timeout paths; panics if it would skip scheduled events backwards).
    pub fn advance(&mut self, dt: SimTime) {
        self.queue.advance_to(self.queue.now() + dt);
    }

    /// Every trace job arrived and finished. The source is exhausted
    /// exactly when no next arrival is staged (`next_spec` is `None`).
    /// Checked after *every* event, so it runs off the `done_jobs` counter
    /// (O(1)) rather than scanning the job table — at stress scale the
    /// seed's `iter().all(is_done)` scan alone was O(jobs) × O(events) of
    /// the whole run.
    fn all_done(&self) -> bool {
        if self.naive_all_done {
            return self.next_spec.is_none() && self.jobs.iter().all(|j| j.is_done());
        }
        debug_assert_eq!(
            self.done_jobs - self.jobs_base,
            self.jobs.iter().filter(|j| j.is_done()).count()
        );
        self.next_spec.is_none() && self.done_jobs == self.arrived
    }

    /// Window index of `id` into [`World::jobs`] (see `jobs_base`).
    #[inline]
    fn slot(&self, id: JobId) -> usize {
        id.idx() - self.jobs_base
    }

    /// Opt back into the seed's O(jobs)-per-event `all_done` scan — the
    /// pre-index loop `benches/simcore.rs` measures the counter against.
    pub fn use_naive_all_done(&mut self) {
        self.naive_all_done = true;
    }

    /// Immutable snapshot for the scheduler.
    pub fn view(&self) -> SchedView<'_> {
        SchedView {
            cfg: &self.cfg,
            cluster: &self.cluster,
            jobs: &self.jobs,
            jobs_base: self.jobs_base,
            cm: &self.cm,
            now: self.queue.now(),
        }
    }

    /// Capture a per-task execution trace (Gantt/JSON export).
    pub fn enable_trace(&mut self) {
        self.trace_log = Some(TraceLog::new());
    }

    /// The captured trace, if enabled.
    pub fn trace_log(&self) -> Option<&TraceLog> {
        self.trace_log.as_ref()
    }

    /// Capture the decision log: every event that reaches a scheduler
    /// callback, with the actions it returned (see [`LogEntry`]).
    pub fn enable_event_log(&mut self) {
        self.event_log = Some(Vec::new());
    }

    /// Take the captured decision log (empty if never enabled).
    pub fn take_event_log(&mut self) -> Vec<LogEntry> {
        self.event_log.take().unwrap_or_default()
    }

    /// Number of jobs in the driving trace (arrived or not). For file
    /// sources the total is only known at EOF, so this reports the
    /// arrivals seen so far.
    pub fn trace_len(&self) -> usize {
        self.source.total_hint().unwrap_or(self.arrived)
    }

    /// True once every arrived job has finished and no arrivals remain —
    /// the stop boundary [`Self::run`] uses. Public so external drivers
    /// (the CLI's snapshot loop) halt at the identical event, keeping
    /// their reports byte-equal to [`Self::run`]'s.
    pub fn done(&self) -> bool {
        self.all_done()
    }

    /// Process exactly one event; false when the queue is empty.
    pub fn step_one(
        &mut self,
        scheduler: &mut dyn Scheduler,
        predictor: &mut dyn Predictor,
    ) -> bool {
        match self.queue.pop() {
            Some((_, ev)) => {
                self.handle(ev, scheduler, predictor);
                true
            }
            None => false,
        }
    }

    /// Drive the loop to completion.
    pub fn run(&mut self, scheduler: &mut dyn Scheduler, predictor: &mut dyn Predictor) {
        while let Some((at, ev)) = self.queue.pop() {
            if at > self.max_sim_time {
                panic!(
                    "simulation exceeded {} — livelock? ({} jobs unfinished)",
                    self.max_sim_time,
                    self.jobs.iter().filter(|j| !j.is_done()).count()
                );
            }
            self.handle(ev, scheduler, predictor);
            if self.all_done() {
                break;
            }
        }
        assert!(
            self.all_done(),
            "event queue drained with {} unfinished jobs",
            self.jobs.iter().filter(|j| !j.is_done()).count()
        );
    }

    /// Record that `job`'s scheduler-visible state changed (task counts,
    /// phase, allocation, …) so the next [`Self::flush_dirty`] re-syncs
    /// the scheduler's persistent indexes for it.
    fn mark_dirty(&mut self, job: JobId) {
        let j = self.slot(job);
        if self.dirty_flags.len() <= j {
            self.dirty_flags.resize(self.jobs.len().max(j + 1), false);
        }
        if !self.dirty_flags[j] {
            self.dirty_flags[j] = true;
            self.dirty.push(job);
        }
    }

    /// Deliver one `on_job_updated` per job mutated since the previous
    /// scheduler callback, in mutation order. Called immediately before
    /// every scheduler callback: the scheduler thereby observes every
    /// state change exactly once, without ever scanning the job table.
    fn flush_dirty(&mut self, scheduler: &mut dyn Scheduler) {
        if self.dirty.is_empty() {
            return;
        }
        let dirty = std::mem::take(&mut self.dirty);
        for &j in &dirty {
            let s = self.slot(j);
            self.dirty_flags[s] = false;
        }
        {
            let view = self.view();
            for &j in &dirty {
                scheduler.on_job_updated(&view, j);
            }
        }
        // Hand the drained buffer back to the pool.
        self.dirty = dirty;
        self.dirty.clear();
    }

    /// Process one event: pure state transition ([`Self::reduce`]), then
    /// the scheduler callback the transition demanded (if any), then the
    /// event's post-effects. Replay substitutes logged actions for the
    /// callback and is otherwise this exact sequence.
    fn handle(
        &mut self,
        ev: Event,
        scheduler: &mut dyn Scheduler,
        predictor: &mut dyn Predictor,
    ) {
        if !self.started {
            // First event of this World: let the scheduler drop any
            // persistent state carried over from a previous run.
            self.started = true;
            scheduler.on_sim_start(&self.view());
        }
        // Failure events reduce to `Decision::None` (never logged); the
        // scheduler's policy hooks fire separately, and only when the
        // event takes effect — the generated trace alternates strictly,
        // but replayed trace files may repeat a state.
        let failure_hook = match ev {
            Event::PmFailure(pm) if self.cluster.pm_alive(pm) => Some((pm, true)),
            Event::PmRecovery(pm) if !self.cluster.pm_alive(pm) => Some((pm, false)),
            _ => None,
        };
        let decision = self.reduce(ev);
        if decision != Decision::None {
            let mut actions = std::mem::take(&mut self.action_buf);
            actions.clear();
            self.flush_dirty(scheduler);
            match decision {
                Decision::JobAdded(id) => {
                    scheduler.on_job_added(&self.view(), id, predictor, &mut actions);
                    self.predictor_calls_estimate += 1;
                }
                Decision::Heartbeat(node) => {
                    scheduler.on_heartbeat(&self.view(), node, predictor, &mut actions);
                }
                Decision::TaskFinished(job) => {
                    scheduler.on_task_finished(&self.view(), job, predictor, &mut actions);
                    self.predictor_calls_estimate += 1;
                }
                Decision::None => unreachable!(),
            }
            self.apply_actions(&actions);
            if let Some(log) = &mut self.event_log {
                log.push(LogEntry { event: ev, actions: actions.clone() });
            }
            self.action_buf = actions;
        }
        // Notification-only: no actions may be emitted here, so replay
        // (which has no scheduler) stays equivalent — the next heartbeat
        // acts on the updated policy state through logged actions.
        match failure_hook {
            Some((pm, true)) => scheduler.on_pm_failure(&self.view(), pm),
            Some((pm, false)) => scheduler.on_pm_recovery(&self.view(), pm),
            None => {}
        }
        self.post_effects(ev, decision);
    }

    /// Effects an event applies *after* its scheduler callback: follow-up
    /// reconfiguration matching, streaming compaction, and the recurring
    /// heartbeat. Keyed purely on `(event kind, decision ran?)`, so live
    /// runs and log replay share it verbatim.
    fn post_effects(&mut self, ev: Event, decision: Decision) {
        match ev {
            Event::Heartbeat(node) => {
                if decision != Decision::None {
                    self.match_reconfigs();
                }
                // Recurring heartbeat while work remains — even for dead
                // nodes, whose timers keep ticking (see `reduce`).
                if !self.all_done() {
                    self.queue.schedule_in(
                        SimTime::from_secs_f64(self.cfg.heartbeat_s),
                        Event::Heartbeat(node),
                    );
                }
            }
            Event::MapDone { .. } => {
                if decision != Decision::None {
                    self.match_reconfigs();
                }
            }
            Event::ReduceDone { .. } => {
                if decision != Decision::None {
                    self.match_reconfigs();
                    self.maybe_compact();
                }
            }
            _ => {}
        }
    }

    /// What [`Self::flush_dirty`] does to *world* state when there is no
    /// scheduler to notify (log replay): drain the dirty queue and reset
    /// its flags, leaving the same post-flush state behind.
    fn clear_dirty(&mut self) {
        let dirty = std::mem::take(&mut self.dirty);
        for &j in &dirty {
            let s = self.slot(j);
            self.dirty_flags[s] = false;
        }
        self.dirty = dirty;
        self.dirty.clear();
    }

    /// The pure(-state) transition step: apply `ev` to the world — job
    /// tables, cluster, HDFS, RNG streams, future completion events — and
    /// report which scheduler decision point it hit. No scheduler code
    /// runs in here; `handle` dispatches on the returned [`Decision`] and
    /// [`Self::replay_to`] applies logged actions instead.
    fn reduce(&mut self, ev: Event) -> Decision {
        match ev {
            Event::JobArrival(idx) => {
                debug_assert_eq!(idx as usize, self.arrived, "arrivals handled in order");
                let spec = self.next_spec.take().expect("arrival event without staged spec");
                self.arrived += 1;
                // Pull + schedule the next arrival immediately: its seq
                // comes from the reserved band, so even a same-timestamp
                // successor pops in exactly the upfront-scheduling order.
                if let Some(next) = self.source.next_job() {
                    debug_assert!(next.submit_s >= spec.submit_s, "trace not sorted");
                    let seq = self.arrival_band + self.arrived as u64;
                    self.queue.schedule_at_with_seq(
                        SimTime::from_secs_f64(next.submit_s),
                        seq,
                        Event::JobArrival(self.arrived as u32),
                    );
                    self.next_spec = Some(next);
                }
                let now = self.now();
                let id = JobId((self.jobs_base + self.jobs.len()) as u32);
                let cost = TaskCost::new(&self.cfg, &spec);
                let mut job = JobState::create(
                    id,
                    spec,
                    &self.cfg,
                    &mut self.nn,
                    &mut self.rng,
                    now,
                );
                // Seed the shuffle prior from the cost model (the paper
                // estimates t_s from network bandwidth, §2.1 Table 1).
                let inter_mb: f64 = job
                    .block_mb
                    .iter()
                    .map(|&mb| cost.map_output_mb(mb))
                    .sum();
                job.stats = crate::predictor::JobStats::new(
                    self.cfg.prior_map_s,
                    cost.t_shuffle_estimate(inter_mb, job.total_maps(), job.total_reduces()),
                );
                self.jobs.push(job);
                self.costs.push(cost);
                // Cache the job-wide shuffle volume for launch_reduce.
                self.inter_mb.push(inter_mb);
                if let Some(exec) = &mut self.exec {
                    exec.register_job(id, self.jobs.last().expect("just pushed"));
                }
                Decision::JobAdded(id)
            }
            Event::Heartbeat(node) => {
                // A dead TaskTracker sends no heartbeats, but its timer
                // keeps ticking so the cadence resumes unchanged on
                // recovery (zero drift in the surviving nodes' schedule);
                // post-effects reschedule the timer either way.
                if self.cluster.node_alive(node) {
                    self.heartbeats += 1;
                    Decision::Heartbeat(node)
                } else {
                    Decision::None
                }
            }
            Event::MapDone { job, task, node, attempt } => {
                if job.idx() < self.jobs_base {
                    return Decision::None; // job already retired (streaming reclaim)
                }
                let now = self.now();
                let s = self.slot(job);
                let js = &self.jobs[s];
                let spec = js.spec_of(task);
                let running = js.map_state(task).is_running();
                // Epoch check (see [`Event::MapDone`]): during a race the
                // primary's epoch is exactly one below the spec's (the
                // spec launch advanced it); otherwise the current epoch
                // *is* the primary's.
                let spec_won = running && spec.is_some_and(|s| s.attempt == attempt);
                let primary_done = running
                    && match spec {
                        Some(s) => attempt + 1 == s.attempt,
                        None => attempt == js.map_attempt(task),
                    };
                if !spec_won && !primary_done {
                    return Decision::None; // stale completion from a killed attempt
                }
                if spec_won {
                    // First-finisher wins: the backup beat the primary.
                    // Kill the loser — free its slot and retire its
                    // in-flight fetch; its completion event is now stale.
                    let sp = spec.expect("spec_won without spec");
                    let (loser_node, loser_tier) =
                        self.jobs[s].mark_map_spec_finished(task, now);
                    if let Some(tl) = &mut self.trace_log {
                        tl.record_span(TaskSpan {
                            job,
                            kind: crate::mapreduce::TaskKind::Map,
                            task: task.0,
                            node,
                            start: sp.started,
                            end: now,
                            tier: sp.tier,
                        });
                    }
                    self.end_remote_flow(sp.tier);
                    self.end_remote_flow(loser_tier);
                    let vm = self.cluster.vm_mut(loser_node);
                    debug_assert!(vm.busy_map > 0);
                    vm.busy_map -= 1;
                    self.fail_stats.speculative_wins += 1;
                    self.fail_stats.speculative_kills += 1;
                } else {
                    if let Some(sp) = spec {
                        // Primary finished first: kill the still-running
                        // backup copy and free its slot.
                        self.jobs[s].take_spec(task);
                        self.end_remote_flow(sp.tier);
                        let vm = self.cluster.vm_mut(sp.node);
                        debug_assert!(vm.busy_map > 0);
                        vm.busy_map -= 1;
                        self.fail_stats.speculative_kills += 1;
                    }
                    if let TaskState::Running { started, tier, .. } =
                        *self.jobs[s].map_state(task)
                    {
                        if let Some(tl) = &mut self.trace_log {
                            tl.record_span(TaskSpan {
                                job,
                                kind: crate::mapreduce::TaskKind::Map,
                                task: task.0,
                                node,
                                start: started,
                                end: now,
                                tier,
                            });
                        }
                        // The task's cross-rack fetch has left the shared core.
                        self.end_remote_flow(tier);
                    }
                    self.jobs[s].mark_map_finished(task, now);
                }
                let vm = self.cluster.vm_mut(node);
                debug_assert!(vm.busy_map > 0);
                vm.busy_map -= 1;
                if let Some(exec) = &mut self.exec {
                    exec.run_map_task(job, task, &self.jobs[s]);
                }
                self.mark_dirty(job);
                Decision::TaskFinished(job)
            }
            Event::ReduceDone { job, task, node, attempt } => {
                if job.idx() < self.jobs_base {
                    return Decision::None; // job already retired (streaming reclaim)
                }
                let now = self.now();
                let s = self.slot(job);
                let js = &self.jobs[s];
                let spec = js.reduce_spec_of(task);
                let running = js.reduce_state(task).is_running();
                // Epoch check mirrors [`Event::MapDone`]: during a race
                // the primary's epoch is exactly one below the spec's.
                let spec_won = running && spec.is_some_and(|sp| sp.attempt == attempt);
                let primary_done = running
                    && match spec {
                        Some(sp) => attempt + 1 == sp.attempt,
                        None => attempt == js.reduce_attempt(task),
                    };
                if !spec_won && !primary_done {
                    return Decision::None; // stale completion from a killed attempt
                }
                if spec_won {
                    // First-finisher wins: the backup beat the primary.
                    let sp = spec.expect("spec_won without spec");
                    let loser_node = self.jobs[s].mark_reduce_spec_finished(task, now);
                    if let Some(tl) = &mut self.trace_log {
                        tl.record_span(TaskSpan {
                            job,
                            kind: crate::mapreduce::TaskKind::Reduce,
                            task: task.0,
                            node,
                            start: sp.started,
                            end: now,
                            tier: LocalityTier::Remote,
                        });
                    }
                    let vm = self.cluster.vm_mut(loser_node);
                    debug_assert!(vm.busy_reduce > 0);
                    vm.busy_reduce -= 1;
                    self.fail_stats.speculative_reduce_wins += 1;
                    self.fail_stats.speculative_reduce_kills += 1;
                } else {
                    if let Some(sp) = spec {
                        // Primary finished first: kill the still-running
                        // backup copy and free its slot.
                        self.jobs[s].take_reduce_spec(task);
                        let vm = self.cluster.vm_mut(sp.node);
                        debug_assert!(vm.busy_reduce > 0);
                        vm.busy_reduce -= 1;
                        self.fail_stats.speculative_reduce_kills += 1;
                    }
                    if let Some(tl) = &mut self.trace_log {
                        if let TaskState::Running { started, .. } =
                            *self.jobs[s].reduce_state(task)
                        {
                            tl.record_span(TaskSpan {
                                job,
                                kind: crate::mapreduce::TaskKind::Reduce,
                                task: task.0,
                                node,
                                start: started,
                                end: now,
                                tier: LocalityTier::Remote,
                            });
                        }
                    }
                    self.jobs[s].mark_reduce_finished(task, now);
                }
                let vm = self.cluster.vm_mut(node);
                debug_assert!(vm.busy_reduce > 0);
                vm.busy_reduce -= 1;
                if let Some(exec) = &mut self.exec {
                    exec.run_reduce_task(job, task, &self.jobs[s]);
                }
                if self.jobs[s].is_done() {
                    // The only transition into `JobPhase::Done` — keep the
                    // O(1) `all_done` counter in lockstep.
                    self.done_jobs += 1;
                    self.record_job(job);
                    while self.done_prefix < self.jobs.len()
                        && self.jobs[self.done_prefix].is_done()
                    {
                        self.done_prefix += 1;
                    }
                }
                self.mark_dirty(job);
                Decision::TaskFinished(job)
            }
            Event::HotplugDone { from, to, task } => {
                if task.job.idx() < self.jobs_base {
                    return Decision::None; // job already retired (streaming reclaim)
                }
                // The target PM died while the core was in flight: the
                // crash reset already reclaimed every core, and the
                // awaiting task (if any) went back to pending with the
                // queue purge. Nothing to deliver.
                if !self.cluster.node_alive(to) {
                    return Decision::None;
                }
                // The released core was unplugged at grant time; now it
                // arrives at the target VM and the delayed task launches.
                if let Err(e) = self.cluster.plug_spare_core(to) {
                    // Only a crash between grant and delivery can void the
                    // spare (the reset snaps allocations back to base).
                    assert!(
                        self.cfg.injects_crashes(),
                        "hot-plug grant lost its spare core: {e:?}"
                    );
                    let s = self.slot(task.job);
                    let js = &mut self.jobs[s];
                    if js.map_state(task.id).is_awaiting() {
                        js.mark_map_await_cancelled(task.id);
                        self.mark_dirty(task.job);
                    }
                    return Decision::None;
                }
                if let Some(tl) = &mut self.trace_log {
                    let at = self.queue.now();
                    tl.record_hotplug(HotplugMark { at, from, to });
                }
                let job = task.job;
                let js = &self.jobs[self.slot(job)];
                let tid = task.id;
                if js.map_state(tid).is_awaiting() {
                    self.launch_map(job, tid, to, LocalityTier::NodeLocal);
                } else {
                    // Task was cancelled while the core was in flight; the
                    // core simply stays with the target VM (it can host
                    // any future local task or be re-released).
                }
                Decision::None
            }
            Event::PmFailure(pm) => {
                self.handle_pm_failure(pm);
                Decision::None
            }
            Event::PmRecovery(pm) => {
                // The machine rejoins with base-allocation VMs, empty map/
                // reduce slots and *no* HDFS blocks (its replicas were
                // re-replicated away at crash time; it refills only via
                // future job placements). The still-ticking heartbeat
                // timers pick it back up within one interval.
                if !self.cluster.pm_alive(pm) {
                    self.cluster.recover_pm(pm);
                }
                Decision::None
            }
        }
    }

    /// Fail-stop loss of a PM and everything on it (see
    /// `docs/FAILURE_MODEL.md` for the exact semantics):
    ///
    /// 1. running map attempts on its VMs are killed — or survive via a
    ///    live speculative copy on another machine (promotion);
    /// 2. speculative copies (map and reduce) on its VMs are dropped;
    /// 3. running reduces on its VMs go back to pending — or survive via
    ///    a live speculative copy on another machine (promotion);
    /// 4. un-shuffled map *outputs* it held (job still in its map phase)
    ///    go back to pending for re-execution;
    /// 5. its reconfiguration queues are purged (awaiting tasks cancel);
    /// 6. its VMs snap back to base allocation with zeroed slots;
    /// 7. every HDFS replica it held is re-replicated rack-aware onto the
    ///    surviving nodes (blocks losing their last replica are counted
    ///    lost and restored from the source dataset).
    fn handle_pm_failure(&mut self, pm: PmId) {
        if !self.cluster.pm_alive(pm) {
            return; // the trace alternates crash/recover; stay safe
        }
        self.fail_stats.pm_crashes += 1;
        for ji in 0..self.jobs.len() {
            if self.jobs[ji].is_done() {
                continue;
            }
            // Any live job may lose attempts, outputs or awaits below;
            // over-notifying the unaffected ones is harmless. (`ji` is a
            // window slot; ids are offset by the retired-jobs base, which
            // is always 0 here — failures exclude streaming mode.)
            self.mark_dirty(JobId((self.jobs_base + ji) as u32));
            for ti in 0..self.jobs[ji].total_maps() {
                let t = TaskId(ti);
                match *self.jobs[ji].map_state(t) {
                    TaskState::Running { node, tier, .. } => {
                        if let Some(s) = self.jobs[ji].spec_of(t) {
                            if self.cluster.pm_of(s.node) == pm {
                                // Dead backup copy: drop it. Its slot is
                                // reclaimed by the crash reset below.
                                self.jobs[ji].take_spec(t);
                                self.end_remote_flow(s.tier);
                                self.fail_stats.speculative_kills += 1;
                            }
                        }
                        if self.cluster.pm_of(node) == pm {
                            self.end_remote_flow(tier);
                            if self.jobs[ji].spec_of(t).is_some() {
                                // A live backup survives on another
                                // machine: it becomes the new primary.
                                self.jobs[ji].promote_spec(t);
                            } else {
                                self.jobs[ji].mark_map_killed(t);
                            }
                        }
                    }
                    TaskState::Finished { node, .. } => {
                        // Un-shuffled map output dies with its
                        // TaskTracker; once the map phase completes the
                        // output counts as durable (documented
                        // simplification — reduces never stall mid-phase).
                        if self.cluster.pm_of(node) == pm && !self.jobs[ji].map_finished() {
                            self.jobs[ji].mark_map_output_lost(t);
                        }
                    }
                    _ => {}
                }
            }
            for ti in 0..self.jobs[ji].total_reduces() {
                let t = TaskId(ti);
                if let TaskState::Running { node, .. } = *self.jobs[ji].reduce_state(t) {
                    if let Some(sp) = self.jobs[ji].reduce_spec_of(t) {
                        if self.cluster.pm_of(sp.node) == pm {
                            // Dead backup copy: drop it. Its slot is
                            // reclaimed by the crash reset below.
                            self.jobs[ji].take_reduce_spec(t);
                            self.fail_stats.speculative_reduce_kills += 1;
                        }
                    }
                    if self.cluster.pm_of(node) == pm {
                        if self.jobs[ji].reduce_spec_of(t).is_some() {
                            // A live backup survives on another
                            // machine: it becomes the new primary.
                            self.jobs[ji].promote_reduce_spec(t);
                        } else {
                            self.jobs[ji].mark_reduce_killed(t);
                        }
                    }
                }
            }
        }
        // Reconfiguration queues: assigns targeting the dead PM revert to
        // pending; its registered releases are void. In-flight hot-plug
        // grants are guarded at `HotplugDone`.
        for tref in self.cm.purge_pm(pm) {
            let s = self.slot(tref.job);
            let js = &mut self.jobs[s];
            if js.map_state(tref.id).is_awaiting() {
                js.mark_map_await_cancelled(tref.id);
                self.mark_dirty(tref.job);
            }
        }
        self.cluster.crash_pm(pm);
        // Rack-aware re-replication of every block the dead VMs held,
        // onto the post-crash set of alive nodes.
        let n = self.cluster.num_nodes();
        let racks: Vec<u32> = (0..n).map(|i| self.cluster.rack_of(NodeId(i as u32))).collect();
        let alive: Vec<bool> = (0..n).map(|i| self.cluster.node_alive(NodeId(i as u32))).collect();
        let vms = self.cluster.pm(pm).vms.clone();
        for node in vms {
            let (relocated, lost) = self.nn.fail_node(node, &racks, &alive, &mut self.failure_rng);
            self.fail_stats.blocks_relocated += relocated;
            self.fail_stats.blocks_lost += lost;
        }
    }

    /// Retire a map attempt's input fetch from the shared cross-rack core
    /// (no-op for local tiers and on the flat topology).
    fn end_remote_flow(&mut self, tier: LocalityTier) {
        if tier == LocalityTier::Remote && self.cfg.topology.is_racked() {
            debug_assert!(self.cross_rack_flows > 0);
            self.cross_rack_flows = self.cross_rack_flows.saturating_sub(1);
        }
    }

    /// Validate + apply scheduler actions.
    pub(crate) fn apply_actions(&mut self, actions: &[Action]) {
        for &a in actions {
            match a {
                Action::LaunchMap { job, task, node } => {
                    let tier = self.jobs[self.slot(job)].map_tier(task, node, &self.cluster);
                    assert!(
                        self.cluster.vm(node).free_map_slots() > 0,
                        "scheduler overfilled map slots on {node:?}"
                    );
                    self.launch_map(job, task, node, tier);
                }
                Action::LaunchSpeculativeMap { job, task, node } => {
                    assert!(
                        self.cluster.vm(node).free_map_slots() > 0,
                        "scheduler overfilled map slots on {node:?}"
                    );
                    let js = &self.jobs[self.slot(job)];
                    debug_assert!(
                        js.map_state(task).is_running() && js.spec_of(task).is_none(),
                        "speculative launch on a non-running or already-backed map"
                    );
                    self.launch_spec_map(job, task, node);
                }
                Action::LaunchReduce { job, task, node } => {
                    assert!(
                        self.cluster.vm(node).free_reduce_slots() > 0,
                        "scheduler overfilled reduce slots on {node:?}"
                    );
                    assert!(
                        self.jobs[self.slot(job)].map_finished(),
                        "reduce launched before map phase finished"
                    );
                    self.launch_reduce(job, task, node);
                }
                Action::LaunchSpeculativeReduce { job, task, node } => {
                    assert!(
                        self.cluster.vm(node).free_reduce_slots() > 0,
                        "scheduler overfilled reduce slots on {node:?}"
                    );
                    let js = &self.jobs[self.slot(job)];
                    debug_assert!(
                        js.reduce_state(task).is_running()
                            && js.reduce_spec_of(task).is_none(),
                        "speculative launch on a non-running or already-backed reduce"
                    );
                    self.launch_spec_reduce(job, task, node);
                }
                Action::AwaitReconfig {
                    job,
                    task,
                    target,
                    release_from,
                } => {
                    let s = self.slot(job);
                    let js = &mut self.jobs[s];
                    debug_assert!(js.map_is_local(task, target));
                    js.mark_map_awaiting(task, target);
                    self.mark_dirty(job);
                    let tref = TaskRef::map(job, task.0);
                    self.cm
                        .enqueue_assign(self.cluster.pm_of(target), target, tref);
                    self.cm
                        .enqueue_release(self.cluster.pm_of(release_from), release_from);
                }
                Action::RegisterRelease { node } => {
                    self.cm.enqueue_release(self.cluster.pm_of(node), node);
                }
                Action::CancelAwait { job, task } => {
                    let tref = TaskRef::map(job, task.0);
                    self.cm.cancel_task(tref);
                    let s = self.slot(job);
                    self.jobs[s].mark_map_await_cancelled(task);
                    self.mark_dirty(job);
                }
                Action::SetAlloc {
                    job,
                    map_slots,
                    reduce_slots,
                } => {
                    let s = self.slot(job);
                    let js = &mut self.jobs[s];
                    js.alloc_map_slots = map_slots;
                    js.alloc_reduce_slots = reduce_slots;
                    self.mark_dirty(job);
                }
            }
        }
        debug_assert!(self.cluster.check_invariants().is_ok());
    }

    /// Match AQ/RQ queues and start granted hot-plugs.
    pub(crate) fn match_reconfigs(&mut self) {
        let grants = self.cm.match_queues(&self.cluster);
        for g in grants {
            match self.cluster.unplug_core(g.from) {
                Ok(()) => {
                    self.queue.schedule_in(
                        SimTime::from_millis(self.cfg.hotplug_ms),
                        Event::HotplugDone {
                            from: g.from,
                            to: g.to,
                            task: g.task,
                        },
                    );
                }
                Err(_) => {
                    // Release went stale between match and apply (shouldn't
                    // happen — match checks can_release — but stay safe):
                    // put the task back to pending.
                    let s = self.slot(g.task.job);
                    let js = &mut self.jobs[s];
                    if js.map_state(g.task.id).is_awaiting() {
                        js.mark_map_await_cancelled(g.task.id);
                        self.mark_dirty(g.task.job);
                    }
                }
            }
        }
    }

    /// Tiered input-fetch bandwidth for a map launch: local disk scan,
    /// rack-local NIC read, or a contended share of the topology's
    /// cross-rack core (the new flow is counted). On the flat topology
    /// the remote tier reads at full NIC speed — the seed model, byte
    /// for byte.
    fn map_io_mbps(&mut self, tier: LocalityTier) -> f64 {
        let topo = self.cfg.topology;
        match tier {
            LocalityTier::NodeLocal => self.cfg.disk_mbps,
            LocalityTier::RackLocal => topo.rack_mbps(self.cfg.net_mbps),
            LocalityTier::Remote => {
                if topo.is_racked() {
                    self.cross_rack_flows += 1;
                    topo.cross_rack_mbps(self.cfg.net_mbps, self.cross_rack_flows)
                } else {
                    self.cfg.net_mbps
                }
            }
        }
    }

    pub(crate) fn launch_map(
        &mut self,
        job: JobId,
        task: TaskId,
        node: NodeId,
        tier: LocalityTier,
    ) {
        let now = self.now();
        let s = self.slot(job);
        let attempt = self.jobs[s].mark_map_launched(task, node, tier, now);
        self.mark_dirty(job);
        if attempt > 1 {
            // Epoch 1 is the first execution; anything later re-runs work
            // a crash destroyed (killed attempt or lost output).
            self.fail_stats.reexecuted_tasks += 1;
        }
        self.cluster.vm_mut(node).busy_map += 1;
        let block_mb = self.jobs[s].block_mb[task.0 as usize];
        let io_mbps = self.map_io_mbps(tier);
        // Heterogeneity: a task on a speed-s machine takes nominal/s time.
        // The straggler multiplier draws from the dedicated failure
        // stream only (1.0, zero draws, with stragglers off).
        let speed = self.cluster.vm(node).speed;
        let secs = self.costs[s].map_secs_at(block_mb, io_mbps, &mut self.rng) / speed
            * straggler_multiplier(&self.cfg.failures, &mut self.failure_rng);
        self.queue.schedule_in(
            SimTime::from_secs_f64(secs),
            Event::MapDone { job, task, node, attempt },
        );
    }

    /// Launch a speculative backup copy of running map `task` on `node`
    /// (the LATE race: whichever attempt's `MapDone` arrives first wins;
    /// the loser's completion is stale by epoch).
    fn launch_spec_map(&mut self, job: JobId, task: TaskId, node: NodeId) {
        let now = self.now();
        let s = self.slot(job);
        let tier = self.jobs[s].map_tier(task, node, &self.cluster);
        let attempt = self.jobs[s].begin_spec_map(task, node, tier, now);
        self.mark_dirty(job);
        self.cluster.vm_mut(node).busy_map += 1;
        self.fail_stats.speculative_launches += 1;
        let block_mb = self.jobs[s].block_mb[task.0 as usize];
        let io_mbps = self.map_io_mbps(tier);
        let speed = self.cluster.vm(node).speed;
        let secs = self.costs[s].map_secs_at(block_mb, io_mbps, &mut self.rng) / speed
            * straggler_multiplier(&self.cfg.failures, &mut self.failure_rng);
        self.queue.schedule_in(
            SimTime::from_secs_f64(secs),
            Event::MapDone { job, task, node, attempt },
        );
    }

    fn launch_reduce(&mut self, job: JobId, task: TaskId, node: NodeId) {
        let now = self.now();
        let s = self.slot(job);
        let attempt = self.jobs[s].mark_reduce_launched(task, node, now);
        self.mark_dirty(job);
        if attempt > 1 {
            self.fail_stats.reexecuted_tasks += 1;
        }
        self.cluster.vm_mut(node).busy_reduce += 1;
        // Shuffle volume: measured in real mode; in synthetic mode the
        // job-wide sum was computed once at JobArrival (identical fold,
        // identical f64) and cached — re-summing here was O(maps) per
        // reduce launch.
        let inter_mb = if let Some(exec) = &self.exec {
            exec.intermediate_mb(job)
        } else {
            self.inter_mb[s]
        };
        let js = &self.jobs[s];
        let speed = self.cluster.vm(node).speed;
        let secs = self.costs[s].reduce_secs(
            inter_mb,
            js.total_maps(),
            js.total_reduces(),
            &mut self.rng,
        ) / speed
            * straggler_multiplier(&self.cfg.failures, &mut self.failure_rng);
        self.queue.schedule_in(
            SimTime::from_secs_f64(secs),
            Event::ReduceDone { job, task, node, attempt },
        );
    }

    /// Launch a speculative backup copy of running reduce `task` on
    /// `node` (same LATE race as [`Self::launch_spec_map`]). Reduces
    /// shuffle from every mapper regardless of placement, so there is no
    /// locality tier and no cross-rack flow accounting.
    fn launch_spec_reduce(&mut self, job: JobId, task: TaskId, node: NodeId) {
        let now = self.now();
        let s = self.slot(job);
        let attempt = self.jobs[s].begin_spec_reduce(task, node, now);
        self.mark_dirty(job);
        self.cluster.vm_mut(node).busy_reduce += 1;
        self.fail_stats.speculative_reduce_launches += 1;
        let inter_mb = if let Some(exec) = &self.exec {
            exec.intermediate_mb(job)
        } else {
            self.inter_mb[s]
        };
        let js = &self.jobs[s];
        let speed = self.cluster.vm(node).speed;
        let secs = self.costs[s].reduce_secs(
            inter_mb,
            js.total_maps(),
            js.total_reduces(),
            &mut self.rng,
        ) / speed
            * straggler_multiplier(&self.cfg.failures, &mut self.failure_rng);
        self.queue.schedule_in(
            SimTime::from_secs_f64(secs),
            Event::ReduceDone { job, task, node, attempt },
        );
    }

    /// Reclaim the done prefix of the job window (streaming mode only):
    /// retire jobs — releasing their HDFS input files — and advance
    /// `jobs_base`. Triggered only when the prefix is both non-trivial
    /// and at least half the window, so total compaction work stays
    /// O(jobs) over a run and window capacity tracks ~2× the live jobs.
    /// Every retired job already delivered its final `on_job_updated`
    /// (job-done flushes before this runs), so scheduler window state
    /// drops the same prefix on its next sync.
    fn maybe_compact(&mut self) {
        if self.stream.is_none() {
            return;
        }
        let k = self.done_prefix;
        if k < 64 || k * 2 < self.jobs.len() {
            return;
        }
        for js in &self.jobs[..k] {
            self.nn.release_file(js.input_file);
        }
        self.jobs.drain(..k);
        self.costs.drain(..k);
        self.inter_mb.drain(..k);
        let kd = k.min(self.dirty_flags.len());
        debug_assert!(
            self.dirty_flags[..kd].iter().all(|f| !f),
            "retired a job with a pending dirty notification"
        );
        self.dirty_flags.drain(..kd);
        self.jobs_base += k;
        self.done_prefix = 0;
    }

    fn record_job(&mut self, job: JobId) {
        let js = &self.jobs[self.slot(job)];
        let completion = js.completion_time().expect("job done");
        let rec = JobRecord {
            id: js.id,
            job_type: js.spec.job_type,
            input_mb: js.spec.input_mb,
            submitted: js.submitted,
            finished: js.submitted + completion,
            completion_s: completion.as_secs_f64(),
            map_phase_s: js
                .map_phase_duration()
                .map(|d| d.as_secs_f64())
                .unwrap_or(0.0),
            deadline_s: js.spec.deadline_s,
            met_deadline: js.met_deadline(),
            local_maps: js.local_maps,
            rack_maps: js.rack_maps,
            remote_maps: js.remote_maps,
            maps: js.total_maps(),
            reduces: js.total_reduces(),
        };
        match &mut self.stream {
            Some(agg) => agg.observe(&rec),
            None => self.records.push(rec),
        }
    }

    /// Access the real-exec engine (E2E verification).
    pub fn exec_engine(&self) -> Option<&ExecEngine> {
        self.exec.as_ref()
    }

    // ---- snapshot / resume / replay ------------------------------------

    /// Snapshot container magic.
    const SNAP_MAGIC: [u8; 4] = *b"VCSS";
    /// Bump on any incompatible encoding change (`docs/EVENT_LOG.md`).
    /// v2: reduce-side speculation (per-job reduce spec list, three more
    /// failure counters) + failure-reactive scheduler policy state.
    const SNAP_VERSION: u8 = 2;

    /// Serialize the full world + `scheduler` policy state at the current
    /// event boundary. Layout: magic, version, config fingerprint, world
    /// section, scheduler kind + state, FNV-1a checksum trailer
    /// (`docs/EVENT_LOG.md`). Errors on worlds holding host-side state a
    /// snapshot cannot carry (real exec engine, in-progress captures).
    pub fn snapshot(&self, scheduler: &dyn Scheduler) -> Result<Vec<u8>, String> {
        if self.exec.is_some() {
            return Err(
                "snapshot requires synthetic exec mode (real mode holds host-side engine state)"
                    .into(),
            );
        }
        if self.trace_log.is_some() {
            return Err("snapshot while capturing a task trace is not supported".into());
        }
        if self.event_log.is_some() {
            return Err("snapshot while capturing a decision log is not supported".into());
        }
        let mut e = Enc::new();
        e.raw(&Self::SNAP_MAGIC);
        e.u8(Self::SNAP_VERSION);
        e.u64(self.cfg.fingerprint());
        self.encode_world_state(&mut e);
        let kind = scheduler.kind();
        let tag = SchedulerKind::ALL
            .iter()
            .position(|&k| k == kind)
            .expect("scheduler kind in ALL") as u8;
        e.u8(tag);
        scheduler.encode_state(&mut e);
        let sum = fnv1a64(e.bytes());
        e.u64(sum);
        Ok(e.into_bytes())
    }

    /// Restore a world and its scheduler from [`Self::snapshot`] bytes.
    /// `cfg` must be the snapshot's own config (fingerprint-checked) and
    /// `source` a fresh instance of the same trace source; the source is
    /// fast-forwarded to the snapshot's arrival cursor and cross-checked
    /// against the staged next spec, so a diverging trace is an error,
    /// not silent skew.
    pub fn resume(
        cfg: SimConfig,
        source: TraceSource,
        bytes: &[u8],
    ) -> Result<(Self, Box<dyn Scheduler>), String> {
        if bytes.len() < Self::SNAP_MAGIC.len() + 1 + 8 + 8 {
            return Err("snapshot too short".into());
        }
        let (body, tail) = bytes.split_at(bytes.len() - 8);
        let mut td = Dec::new(tail);
        let want = td.u64()?;
        td.finish()?;
        let got = fnv1a64(body);
        if got != want {
            return Err(format!(
                "snapshot checksum mismatch: stored {want:#018x}, computed {got:#018x}"
            ));
        }
        let mut d = Dec::new(body);
        let magic = [d.u8()?, d.u8()?, d.u8()?, d.u8()?];
        if magic != Self::SNAP_MAGIC {
            return Err("not a vcsched snapshot (bad magic)".into());
        }
        let version = d.u8()?;
        if version != Self::SNAP_VERSION {
            return Err(format!(
                "unsupported snapshot version {version} (expected {})",
                Self::SNAP_VERSION
            ));
        }
        let fp = d.u64()?;
        if fp != cfg.fingerprint() {
            return Err(
                "snapshot was taken under a different SimConfig (fingerprint mismatch)".into(),
            );
        }
        let mut w = World::from_source(cfg, source);
        if w.exec.is_some() {
            return Err("resume requires synthetic exec mode".into());
        }
        w.decode_world_state(&mut d)?;
        let tag = d.u8()? as usize;
        let kind = *SchedulerKind::ALL
            .get(tag)
            .ok_or_else(|| format!("invalid scheduler kind tag {tag}"))?;
        let mut scheduler = kind.build(&w.cfg);
        scheduler.restore_state(&mut d, &w.view())?;
        d.finish()?;
        Ok((w, scheduler))
    }

    /// FNV-1a hash over the canonical world-state encoding — the replay
    /// determinism witness (`replay_to(n)` twice must agree here).
    pub fn state_hash(&self) -> u64 {
        let mut e = Enc::new();
        self.encode_world_state(&mut e);
        fnv1a64(e.bytes())
    }

    /// Time-travel debugging: rebuild the world as it stood after the
    /// first `n` logged decisions by re-running the reduce step against a
    /// fresh source, substituting the logged actions for the scheduler.
    /// `n` clamps to the full log; replay panics if the log disagrees
    /// with the reduced event stream (wrong source or corrupted log).
    pub fn replay_to(cfg: SimConfig, source: TraceSource, log: &[LogEntry], n: usize) -> Self {
        let n = n.min(log.len());
        let mut w = World::from_source(cfg, source);
        // No scheduler to reset; the flag only gates on_sim_start.
        w.started = true;
        let mut i = 0;
        while i < n {
            let Some((_, ev)) = w.queue.pop() else { break };
            let decision = w.reduce(ev);
            if decision != Decision::None {
                let entry = &log[i];
                assert_eq!(
                    entry.event, ev,
                    "replay divergence at decision {i}: log vs live event"
                );
                w.clear_dirty();
                if !matches!(decision, Decision::Heartbeat(_)) {
                    w.predictor_calls_estimate += 1;
                }
                w.apply_actions(&entry.actions);
                i += 1;
            }
            w.post_effects(ev, decision);
        }
        w
    }

    /// Encode every field of simulator state a snapshot carries —
    /// everything except the rebuildable cost tables and host-side
    /// engines — in struct declaration order.
    fn encode_world_state(&self, e: &mut Enc) {
        // Event queue: cursors + pending entries in pop order.
        let (now, seq, popped) = self.queue.cursors();
        enc_time(e, now);
        e.u64(seq);
        e.u64(popped);
        let entries = self.queue.entries_sorted();
        e.usize(entries.len());
        for (at, eseq, ev) in entries {
            enc_time(e, at);
            e.u64(eseq);
            enc_event(e, *ev);
        }
        // RNG streams (xoshiro256** state words).
        for wd in self.rng.state() {
            e.u64(wd);
        }
        for wd in self.failure_rng.state() {
            e.u64(wd);
        }
        match &self.next_spec {
            None => e.bool(false),
            Some(s) => {
                e.bool(true);
                encode_job_spec(e, s);
            }
        }
        e.u64(self.arrival_band);
        e.usize(self.arrived);
        e.usize(self.jobs_base);
        e.usize(self.done_prefix);
        e.usize(self.done_jobs);
        e.bool(self.naive_all_done);
        self.cluster.encode_state(e);
        self.nn.encode_state(e);
        e.usize(self.jobs.len());
        for j in &self.jobs {
            j.encode(e);
        }
        e.usize(self.inter_mb.len());
        for &mb in &self.inter_mb {
            e.f64(mb);
        }
        self.cm.encode_state(e);
        e.usize(self.dirty.len());
        for &j in &self.dirty {
            e.u32(j.0);
        }
        e.usize(self.dirty_flags.len());
        for &f in &self.dirty_flags {
            e.bool(f);
        }
        e.bool(self.started);
        e.u32(self.cross_rack_flows);
        enc_fail_stats(e, &self.fail_stats);
        e.u64(self.heartbeats);
        e.u64(self.predictor_calls_estimate);
        e.usize(self.records.len());
        for r in &self.records {
            enc_job_record(e, r);
        }
        match &self.stream {
            None => e.bool(false),
            Some(agg) => {
                e.bool(true);
                enc_stream_agg(e, agg);
            }
        }
    }

    /// Inverse of [`Self::encode_world_state`], applied over a freshly
    /// constructed world (same config + fresh trace source).
    fn decode_world_state(&mut self, d: &mut Dec) -> Result<(), String> {
        let now = dec_time(d)?;
        let seq = d.u64()?;
        let popped = d.u64()?;
        // Min entry wire size: at (8) + seq (8) + smallest event (5).
        let n_entries = d.len(21)?;
        let mut entries = Vec::with_capacity(n_entries);
        for _ in 0..n_entries {
            let at = dec_time(d)?;
            let eseq = d.u64()?;
            let ev = dec_event(d)?;
            entries.push((at, eseq, ev));
        }
        self.queue = EventQueue::restore(now, seq, popped, entries);
        let mut rs = [0u64; 4];
        for wd in &mut rs {
            *wd = d.u64()?;
        }
        self.rng = Rng::from_state(rs);
        let mut fs = [0u64; 4];
        for wd in &mut fs {
            *wd = d.u64()?;
        }
        self.failure_rng = Rng::from_state(fs);
        let stored_next = if d.bool()? {
            Some(decode_job_spec(d)?)
        } else {
            None
        };
        let arrival_band = d.u64()?;
        if arrival_band != self.arrival_band {
            return Err(format!(
                "arrival seq band mismatch: snapshot {arrival_band}, rebuilt {}",
                self.arrival_band
            ));
        }
        let arrived = d.usize()?;
        // Fast-forward the fresh trace source to the snapshot's cursor:
        // construction pulled the first spec; each handled arrival pulled
        // one more. The final staged spec must match the snapshot's, so a
        // wrong or nondeterministic source fails loudly here.
        let mut cur = self.next_spec.take();
        for _ in 0..arrived {
            cur = self.source.next_job();
        }
        match (&cur, &stored_next) {
            (None, None) => {}
            (Some(a), Some(b)) => {
                let (mut ea, mut eb) = (Enc::new(), Enc::new());
                encode_job_spec(&mut ea, a);
                encode_job_spec(&mut eb, b);
                if ea.bytes() != eb.bytes() {
                    return Err(
                        "trace source diverged from snapshot (staged arrival differs)".into()
                    );
                }
            }
            _ => {
                return Err("trace source diverged from snapshot (arrival count)".into());
            }
        }
        self.next_spec = cur;
        self.arrived = arrived;
        self.jobs_base = d.usize()?;
        self.done_prefix = d.usize()?;
        self.done_jobs = d.usize()?;
        self.naive_all_done = d.bool()?;
        self.cluster.restore_state(d)?;
        self.nn = NameNode::decode_state(d)?;
        let n_jobs = d.len(32)?;
        let mut jobs = Vec::with_capacity(n_jobs);
        for _ in 0..n_jobs {
            jobs.push(JobState::decode(d)?);
        }
        // The cost tables are pure functions of (cfg, spec): rebuild.
        self.costs = jobs
            .iter()
            .map(|j| TaskCost::new(&self.cfg, &j.spec))
            .collect();
        self.jobs = jobs;
        let n_inter = d.len(8)?;
        if n_inter != self.jobs.len() {
            return Err(format!(
                "inter_mb table length {n_inter} != {} jobs",
                self.jobs.len()
            ));
        }
        let mut inter = Vec::with_capacity(n_inter);
        for _ in 0..n_inter {
            inter.push(d.f64()?);
        }
        self.inter_mb = inter;
        self.cm = ConfigManager::decode_state(d)?;
        let n_dirty = d.len(4)?;
        let mut dirty = Vec::with_capacity(n_dirty);
        for _ in 0..n_dirty {
            dirty.push(JobId(d.u32()?));
        }
        self.dirty = dirty;
        let n_flags = d.len(1)?;
        let mut flags = Vec::with_capacity(n_flags);
        for _ in 0..n_flags {
            flags.push(d.bool()?);
        }
        self.dirty_flags = flags;
        self.started = d.bool()?;
        self.cross_rack_flows = d.u32()?;
        self.fail_stats = dec_fail_stats(d)?;
        self.heartbeats = d.u64()?;
        self.predictor_calls_estimate = d.u64()?;
        let n_rec = d.len(67)?;
        let mut records = Vec::with_capacity(n_rec);
        for _ in 0..n_rec {
            records.push(dec_job_record(d)?);
        }
        self.records = records;
        self.stream = if d.bool()? {
            Some(dec_stream_agg(d)?)
        } else {
            None
        };
        if self.stream.is_some() != self.cfg.stream_metrics {
            return Err("snapshot streaming mode disagrees with config".into());
        }
        Ok(())
    }

    pub fn into_metrics(self, scheduler: &str) -> RunMetrics {
        let makespan_s = match &self.stream {
            Some(agg) => agg.max_finished_s,
            None => self
                .records
                .iter()
                .map(|r| r.finished.as_secs_f64())
                .fold(0.0f64, f64::max),
        };
        RunMetrics {
            scheduler: scheduler.to_string(),
            jobs: self.records,
            stream: self.stream,
            makespan_s,
            hotplugs: self.cm.hotplugs,
            heartbeats: self.heartbeats,
            events: self.queue.processed(),
            predictor_calls: self.predictor_calls_estimate,
            failures: self.fail_stats,
            wall_s: 0.0,
        }
    }
}

fn enc_fail_stats(e: &mut Enc, f: &FailureStats) {
    e.u64(f.pm_crashes);
    e.u64(f.speculative_launches);
    e.u64(f.speculative_wins);
    e.u64(f.speculative_kills);
    e.u64(f.speculative_reduce_launches);
    e.u64(f.speculative_reduce_wins);
    e.u64(f.speculative_reduce_kills);
    e.u64(f.reexecuted_tasks);
    e.u64(f.blocks_relocated);
    e.u64(f.blocks_lost);
}

fn dec_fail_stats(d: &mut Dec) -> Result<FailureStats, String> {
    Ok(FailureStats {
        pm_crashes: d.u64()?,
        speculative_launches: d.u64()?,
        speculative_wins: d.u64()?,
        speculative_kills: d.u64()?,
        speculative_reduce_launches: d.u64()?,
        speculative_reduce_wins: d.u64()?,
        speculative_reduce_kills: d.u64()?,
        reexecuted_tasks: d.u64()?,
        blocks_relocated: d.u64()?,
        blocks_lost: d.u64()?,
    })
}

fn enc_job_record(e: &mut Enc, r: &JobRecord) {
    e.u32(r.id.0);
    let tag = ALL_JOB_TYPES
        .iter()
        .position(|&t| t == r.job_type)
        .expect("job type in ALL") as u8;
    e.u8(tag);
    e.f64(r.input_mb);
    enc_time(e, r.submitted);
    enc_time(e, r.finished);
    e.f64(r.completion_s);
    e.f64(r.map_phase_s);
    match r.deadline_s {
        None => e.bool(false),
        Some(dl) => {
            e.bool(true);
            e.f64(dl);
        }
    }
    e.u8(match r.met_deadline {
        None => 0,
        Some(false) => 1,
        Some(true) => 2,
    });
    e.u32(r.local_maps);
    e.u32(r.rack_maps);
    e.u32(r.remote_maps);
    e.u32(r.maps);
    e.u32(r.reduces);
}

fn dec_job_record(d: &mut Dec) -> Result<JobRecord, String> {
    let id = JobId(d.u32()?);
    let tag = d.u8()? as usize;
    let job_type = *ALL_JOB_TYPES
        .get(tag)
        .ok_or_else(|| format!("invalid job-type tag {tag}"))?;
    Ok(JobRecord {
        id,
        job_type,
        input_mb: d.f64()?,
        submitted: dec_time(d)?,
        finished: dec_time(d)?,
        completion_s: d.f64()?,
        map_phase_s: d.f64()?,
        deadline_s: if d.bool()? { Some(d.f64()?) } else { None },
        met_deadline: match d.u8()? {
            0 => None,
            1 => Some(false),
            2 => Some(true),
            b => return Err(format!("invalid met-deadline tag {b}")),
        },
        local_maps: d.u32()?,
        rack_maps: d.u32()?,
        remote_maps: d.u32()?,
        maps: d.u32()?,
        reduces: d.u32()?,
    })
}

fn enc_stream_agg(e: &mut Enc, a: &StreamAgg) {
    e.u64(a.completed);
    e.u64(a.completion.count());
    e.f64(a.completion.mean());
    e.f64(a.completion.m2());
    e.f64(a.completion.min());
    e.f64(a.completion.max());
    e.f64(a.completion.sum());
    e.str(&a.sketch.encode());
    e.u64(a.local_maps);
    e.u64(a.rack_maps);
    e.u64(a.remote_maps);
    e.u64(a.deadlined);
    e.u64(a.missed);
    e.f64(a.max_finished_s);
}

fn dec_stream_agg(d: &mut Dec) -> Result<StreamAgg, String> {
    let completed = d.u64()?;
    let n = d.u64()?;
    let mean = d.f64()?;
    let m2 = d.f64()?;
    let min = d.f64()?;
    let max = d.f64()?;
    let sum = d.f64()?;
    let completion = Summary::from_raw(n, mean, m2, min, max, sum);
    let sketch_s = d.str()?;
    let sketch =
        QuantileSketch::decode(&sketch_s).ok_or_else(|| "malformed quantile sketch".to_string())?;
    Ok(StreamAgg {
        completed,
        completion,
        sketch,
        local_maps: d.u64()?,
        rack_maps: d.u64()?,
        remote_maps: d.u64()?,
        deadlined: d.u64()?,
        missed: d.u64()?,
        max_finished_s: d.f64()?,
    })
}
