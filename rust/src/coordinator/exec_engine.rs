//! Real-execution engine (`ExecMode::Real`).
//!
//! Generates deterministic corpus blocks per (job, block), runs the actual
//! map/reduce functions when the corresponding simulated task completes,
//! and keeps the partitioned intermediate data so the distributed output
//! can be verified against a serial reference. Timing stays simulated;
//! the *bytes* are real.
//!
//! **Not snapshotable**: the engine holds host-side corpus blocks and
//! partitioned intermediate pairs — megabytes of derived data that the
//! snapshot format (docs/EVENT_LOG.md) deliberately excludes.
//! [`crate::coordinator::World::snapshot`] therefore refuses to encode a
//! world running in real mode; snapshot/resume is a synthetic-mode
//! feature. (Everything here is deterministic from `seed` + the event
//! order, so a resumed world could in principle regenerate it, but no
//! caller needs that and the replay cost would be the full run anyway.)

use std::collections::HashMap;

use crate::mapreduce::{JobId, JobState, TaskId};
use crate::util::Rng;
use crate::workloads::corpus::{self, Block};
use crate::workloads::exec::{self, Pair};
use crate::workloads::JobType;

/// Bytes of real data generated per simulated MB (scale-down so 100s of
/// simulated MB stay cheap in host memory).
const BYTES_PER_SIM_MB: f64 = 2048.0;

/// Grep pattern used by every Grep job (the rank-1 corpus word).
pub const GREP_PATTERN: &str = "the";

struct JobExec {
    job_type: JobType,
    reducers: u32,
    blocks: Vec<Block>,
    /// Partitioned intermediate pairs, filled as map tasks finish.
    partitions: Vec<Vec<Pair>>,
    maps_done: u32,
    intermediate_bytes: u64,
    /// Reduce outputs, filled as reduce tasks finish.
    outputs: Vec<Vec<Pair>>,
}

/// Engine state across all real-mode jobs.
pub struct ExecEngine {
    seed: u64,
    jobs: HashMap<JobId, JobExec>,
}

impl ExecEngine {
    /// The pattern every Grep job searches for.
    pub fn pattern() -> &'static str {
        GREP_PATTERN
    }

    pub fn new(seed: u64) -> Self {
        Self {
            seed,
            jobs: HashMap::new(),
        }
    }

    /// Generate the job's input blocks (deterministic from seed/job/block).
    pub fn register_job(&mut self, id: JobId, js: &JobState) {
        let jt = js.spec.job_type;
        let blocks: Vec<Block> = js
            .block_mb
            .iter()
            .enumerate()
            .map(|(i, &mb)| {
                let mut rng =
                    Rng::new(self.seed ^ (id.0 as u64) << 32 ^ i as u64 ^ 0xB10C);
                let bytes = (mb * BYTES_PER_SIM_MB) as usize;
                match jt {
                    JobType::Sort => corpus::record_block(bytes, i as u32, &mut rng),
                    JobType::PermutationGenerator => {
                        corpus::string_block(bytes / 8, 4, i as u32, &mut rng)
                    }
                    _ => corpus::text_block(bytes, i as u32, &mut rng),
                }
            })
            .collect();
        let reducers = js.total_reduces();
        self.jobs.insert(
            id,
            JobExec {
                job_type: jt,
                reducers,
                blocks,
                partitions: vec![Vec::new(); reducers as usize],
                maps_done: 0,
                intermediate_bytes: 0,
                outputs: vec![Vec::new(); reducers as usize],
            },
        );
    }

    /// Execute the map function for a finished map task.
    pub fn run_map_task(&mut self, id: JobId, task: TaskId, _js: &JobState) {
        let je = self.jobs.get_mut(&id).expect("job registered");
        let block = &je.blocks[task.0 as usize];
        let pairs = exec::run_map(je.job_type, block, GREP_PATTERN);
        je.intermediate_bytes += pairs
            .iter()
            .map(|(k, v)| (k.len() + v.len()) as u64)
            .sum::<u64>();
        if je.maps_done == 0 {
            // First map task: size the partition buckets for the whole
            // job (incremental realloc growth was ~25% of the real-exec
            // profile — EXPERIMENTS.md §Perf).
            let per_part =
                pairs.len() * je.blocks.len() / je.reducers.max(1) as usize;
            for part in &mut je.partitions {
                part.reserve(per_part + per_part / 4);
            }
        }
        exec::partition_into(pairs, &mut je.partitions);
        je.maps_done += 1;
    }

    /// Execute the reduce function for a finished reduce task.
    pub fn run_reduce_task(&mut self, id: JobId, task: TaskId, _js: &JobState) {
        let je = self.jobs.get_mut(&id).expect("job registered");
        debug_assert_eq!(
            je.maps_done,
            je.blocks.len() as u32,
            "reduce ran before map phase completed"
        );
        let part = std::mem::take(&mut je.partitions[task.0 as usize]);
        je.outputs[task.0 as usize] = exec::run_reduce(je.job_type, part);
    }

    /// Measured intermediate volume in *simulated* MB.
    pub fn intermediate_mb(&self, id: JobId) -> f64 {
        self.jobs
            .get(&id)
            .map(|je| je.intermediate_bytes as f64 / BYTES_PER_SIM_MB)
            .unwrap_or(0.0)
    }

    /// Merged, sorted final output of a completed job.
    pub fn job_output(&self, id: JobId) -> Vec<Pair> {
        let je = &self.jobs[&id];
        let mut out: Vec<Pair> = je.outputs.iter().flatten().cloned().collect();
        out.sort();
        out
    }

    /// Serial reference over the same input blocks.
    pub fn serial_reference(&self, id: JobId) -> Vec<Pair> {
        let je = &self.jobs[&id];
        let mut out = exec::serial_reference(je.job_type, &je.blocks, GREP_PATTERN);
        out.sort();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExecMode, SimConfig};
    use crate::coordinator::run_simulation;
    use crate::scheduler::SchedulerKind;
    use crate::workloads::trace::JobTrace;
    use crate::workloads::JobSpec;

    #[test]
    fn real_mode_runs_and_engine_sizes_feed_timing() {
        let cfg = SimConfig {
            exec: ExecMode::Real,
            ..SimConfig::small()
        };
        let trace = JobTrace::new(vec![
            JobSpec::new(JobType::WordCount, 128.0).with_deadline(600.0)
        ]);
        let r = run_simulation(&cfg, SchedulerKind::DeadlineVc, &trace);
        assert_eq!(r.completed_jobs(), 1);
    }

    /// The E2E invariant: distributed output == serial reference, for every
    /// workload type, through the full scheduler + reconfiguration stack.
    #[test]
    fn distributed_output_matches_serial_reference() {
        use crate::coordinator::World;
        use crate::predictor::NativePredictor;

        for jt in crate::workloads::ALL_JOB_TYPES {
            let cfg = SimConfig {
                exec: ExecMode::Real,
                ..SimConfig::small()
            };
            let trace = JobTrace::new(vec![
                JobSpec::new(jt, 96.0).with_deadline(900.0)
            ]);
            let mut sched = SchedulerKind::DeadlineVc.build(&cfg);
            let mut pred = NativePredictor::new();
            let mut world = World::new(cfg, trace);
            world.run(sched.as_mut(), &mut pred);
            let exec = world.exec_engine().expect("real mode");
            let got = exec.job_output(JobId(0));
            let want = exec.serial_reference(JobId(0));
            assert!(!want.is_empty(), "{jt}: empty reference output");
            assert_eq!(got, want, "{jt}: distributed != serial");
        }
    }
}
