//! In-tree randomized property-testing helper (proptest is unavailable
//! offline). No shrinking — instead every failure prints the case index
//! and seed so `check_seeded` replays it exactly.
//!
//! ```
//! vcsched::prop::check(100, |rng| {
//!     let x = rng.below(1000);
//!     assert!(x < 1000);
//! });
//! ```

use crate::util::Rng;

/// Base seed; override with `VCSCHED_PROP_SEED` to replay a failure.
fn base_seed() -> u64 {
    std::env::var("VCSCHED_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// Run `f` for `cases` independently-seeded cases. Panics (with replay
/// instructions) on the first failing case.
pub fn check<F: FnMut(&mut Rng)>(cases: u64, f: F) {
    check_seeded(base_seed(), cases, f)
}

/// Like [`check`] with an explicit base seed.
pub fn check_seeded<F: FnMut(&mut Rng)>(base: u64, cases: u64, mut f: F) {
    for i in 0..cases {
        let case_seed = base ^ (i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng);
        }));
        if let Err(payload) = result {
            eprintln!(
                "property failed at case {i}/{cases} (case seed {case_seed:#x}).\n\
                 Replay with: VCSCHED_PROP_SEED={base} and case index {i}."
            );
            std::panic::resume_unwind(payload);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check(50, |rng| {
            let a = rng.below(100);
            let b = rng.below(100);
            assert!(a + b < 200);
        });
    }

    #[test]
    fn failure_replays_with_same_seed() {
        // Find a failing case under one seed, confirm determinism by
        // catching it twice with identical draws.
        let mut first: Option<u64> = None;
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            check_seeded(7, 50, |rng| {
                let x = rng.next_u64();
                if x % 5 == 0 {
                    first = Some(x);
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err());
        let mut second: Option<u64> = None;
        let r2 = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            check_seeded(7, 50, |rng| {
                let x = rng.next_u64();
                if x % 5 == 0 {
                    second = Some(x);
                    panic!("boom");
                }
            });
        }));
        assert!(r2.is_err());
        assert_eq!(first, second);
    }
}
