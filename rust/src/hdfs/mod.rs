//! HDFS-like storage substrate: block-structured files, k-way replication
//! across DataNodes (= VMs), and the NameNode metadata the schedulers
//! query for data locality.
//!
//! Placement is topology-aware ([`NameNode::create_file_placed`]):
//!
//! * on a **flat** (single-rack) layout each block's replicas land on
//!   `replication` distinct nodes chosen uniformly — Hadoop 0.20's
//!   rack-unaware default, byte-identical to the seed reproduction (the
//!   paper's testbed is a single rack);
//! * on a **racked** layout the default HDFS rack-aware policy applies:
//!   first replica on a uniformly chosen node, second on a node in a
//!   *different* rack, third on a different node of the *second* replica's
//!   rack, any further replicas uniform over the remaining nodes. A block
//!   therefore spans at least two racks (fault tolerance) while two of
//!   three replicas share a rack (read locality).

use std::collections::HashMap;

use crate::cluster::NodeId;
use crate::util::codec::{Dec, Enc};
use crate::util::Rng;

/// A stored file (one MapReduce job input or output).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct FileId(pub u32);

/// Block index within a file.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct BlockId {
    pub file: FileId,
    pub index: u32,
}

/// Metadata for one block.
#[derive(Clone, Debug)]
pub struct BlockInfo {
    pub id: BlockId,
    pub size_mb: f64,
    /// Nodes holding a replica (distinct).
    pub replicas: Vec<NodeId>,
}

/// NameNode: file -> blocks -> replica locations.
#[derive(Debug, Default)]
pub struct NameNode {
    files: HashMap<FileId, Vec<BlockInfo>>,
    next_file: u32,
}

impl NameNode {
    pub fn new() -> Self {
        Self::default()
    }

    /// Create a file of `total_mb` split into `block_mb` blocks, each
    /// replicated on `replication` distinct nodes of the `num_nodes`
    /// cluster, rack-unaware (single implicit rack). Returns the new
    /// file id.
    pub fn create_file(
        &mut self,
        total_mb: f64,
        block_mb: f64,
        replication: usize,
        num_nodes: usize,
        rng: &mut Rng,
    ) -> FileId {
        self.create_file_placed(total_mb, block_mb, replication, &vec![0; num_nodes], rng)
    }

    /// Like [`NameNode::create_file`] but with an explicit node -> rack
    /// layout (`node_racks[i]` is node `i`'s rack). A single-rack layout
    /// takes the legacy uniform-sampling path — drawing exactly the same
    /// RNG sequence as the pre-topology simulator — while a multi-rack
    /// layout applies the HDFS rack-aware policy (see module docs).
    pub fn create_file_placed(
        &mut self,
        total_mb: f64,
        block_mb: f64,
        replication: usize,
        node_racks: &[u32],
        rng: &mut Rng,
    ) -> FileId {
        let num_nodes = node_racks.len();
        assert!(block_mb > 0.0 && total_mb >= 0.0);
        assert!(replication >= 1 && replication <= num_nodes);
        let racked = node_racks.iter().any(|&r| r != node_racks[0]);
        let id = FileId(self.next_file);
        self.next_file += 1;
        let full_blocks = (total_mb / block_mb).floor() as u32;
        let tail = total_mb - full_blocks as f64 * block_mb;
        let mut blocks = Vec::new();
        let n_blocks = full_blocks + if tail > 1e-9 { 1 } else { 0 };
        for i in 0..n_blocks {
            let size = if i < full_blocks { block_mb } else { tail };
            let replicas = if racked {
                place_rack_aware(node_racks, replication, rng)
            } else {
                rng.sample_indices(num_nodes, replication)
                    .into_iter()
                    .map(|n| NodeId(n as u32))
                    .collect()
            };
            blocks.push(BlockInfo {
                id: BlockId { file: id, index: i },
                size_mb: size,
                replicas,
            });
        }
        self.files.insert(id, blocks);
        id
    }

    pub fn blocks(&self, file: FileId) -> &[BlockInfo] {
        self.files
            .get(&file)
            .map(|b| b.as_slice())
            .unwrap_or(&[])
    }

    pub fn num_blocks(&self, file: FileId) -> usize {
        self.blocks(file).len()
    }

    pub fn block(&self, id: BlockId) -> Option<&BlockInfo> {
        self.files.get(&id.file)?.get(id.index as usize)
    }

    /// Is a replica of `block` resident on `node`?
    pub fn is_local(&self, id: BlockId, node: NodeId) -> bool {
        self.block(id)
            .map(|b| b.replicas.contains(&node))
            .unwrap_or(false)
    }

    /// Build the inverted node -> block-indices map for one file (the
    /// locality index the scheduler keeps hot; see `mapreduce::JobState`).
    pub fn locality_index(&self, file: FileId, num_nodes: usize) -> Vec<Vec<u32>> {
        let mut idx = vec![Vec::new(); num_nodes];
        for b in self.blocks(file) {
            for &r in &b.replicas {
                idx[r.idx()].push(b.id.index);
            }
        }
        idx
    }

    /// Drop a file's metadata entirely (the job that read it retired and
    /// its window slot was reclaimed — see the coordinator's streaming
    /// mode). [`NameNode::blocks`] on a released file returns the empty
    /// slice, same as for a never-created id. Releasing an unknown file
    /// is a no-op.
    pub fn release_file(&mut self, file: FileId) {
        self.files.remove(&file);
    }

    /// A DataNode died: drop its replicas from every block and re-replicate
    /// each affected block onto an *alive* unchosen node (`alive[i]` is
    /// node `i`'s liveness), preferring the dead replica's rack-placement
    /// role — off the first replica's rack when possible, matching the
    /// rack-aware write policy. A block whose replicas are all lost is
    /// counted in the returned `(relocated, lost)`; it is restored from
    /// the (durable) source data onto fresh nodes, so reads never block,
    /// but the loss is reported to the metrics.
    ///
    /// Draws from `rng` only for blocks that actually held a replica on
    /// `node` — callers pass the dedicated failure RNG stream, never the
    /// workload stream.
    pub fn fail_node(
        &mut self,
        node: NodeId,
        node_racks: &[u32],
        alive: &[bool],
        rng: &mut Rng,
    ) -> (u64, u64) {
        let n = node_racks.len();
        let mut relocated = 0u64;
        let mut lost = 0u64;
        // Deterministic iteration: files in id order.
        let mut ids: Vec<FileId> = self.files.keys().copied().collect();
        ids.sort();
        for fid in ids {
            let blocks = self.files.get_mut(&fid).unwrap();
            for b in blocks {
                let Some(pos) = b.replicas.iter().position(|&r| r == node) else {
                    continue;
                };
                b.replicas.remove(pos);
                if b.replicas.is_empty() {
                    lost += 1;
                }
                // Re-replicate onto an alive, unchosen node: prefer a rack
                // other than the (new) first replica's, falling back to any
                // alive unchosen node (mirrors the write-path fallbacks).
                let first_rack = b.replicas.first().map(|r| node_racks[r.idx()]);
                let keep = |i: usize, off_rack: bool| {
                    alive[i]
                        && !b.replicas.contains(&NodeId(i as u32))
                        && (!off_rack || first_rack.map_or(true, |fr| node_racks[i] != fr))
                };
                let mut cands: Vec<usize> = (0..n).filter(|&i| keep(i, true)).collect();
                if cands.is_empty() {
                    cands = (0..n).filter(|&i| keep(i, false)).collect();
                }
                if let Some(&c) = cands.get(rng.below(cands.len().max(1) as u64) as usize) {
                    b.replicas.push(NodeId(c as u32));
                    relocated += 1;
                }
            }
        }
        (relocated, lost)
    }

    /// Snapshot encoding of the full NameNode state. Files are written in
    /// `FileId` order (the `HashMap` iteration order is not canonical), so
    /// equal metadata always encodes to equal bytes.
    pub(crate) fn encode_state(&self, e: &mut Enc) {
        e.u32(self.next_file);
        let mut ids: Vec<FileId> = self.files.keys().copied().collect();
        ids.sort();
        e.usize(ids.len());
        for fid in ids {
            e.u32(fid.0);
            let blocks = &self.files[&fid];
            e.usize(blocks.len());
            for b in blocks {
                debug_assert_eq!(b.id.file, fid);
                e.u32(b.id.index);
                e.f64(b.size_mb);
                e.usize(b.replicas.len());
                for r in &b.replicas {
                    e.u32(r.0);
                }
            }
        }
    }

    /// Rebuild a NameNode from [`Self::encode_state`] bytes.
    pub(crate) fn decode_state(d: &mut Dec) -> Result<Self, String> {
        let next_file = d.u32()?;
        let n_files = d.len(9)?;
        let mut files = HashMap::with_capacity(n_files);
        for _ in 0..n_files {
            let fid = FileId(d.u32()?);
            let n_blocks = d.len(16)?;
            let mut blocks = Vec::with_capacity(n_blocks);
            for _ in 0..n_blocks {
                let index = d.u32()?;
                let size_mb = d.f64()?;
                let n_reps = d.len(4)?;
                let mut replicas = Vec::with_capacity(n_reps);
                for _ in 0..n_reps {
                    replicas.push(NodeId(d.u32()?));
                }
                blocks.push(BlockInfo {
                    id: BlockId { file: fid, index },
                    size_mb,
                    replicas,
                });
            }
            if files.insert(fid, blocks).is_some() {
                return Err(format!("duplicate file {} in snapshot", fid.0));
            }
        }
        Ok(Self { files, next_file })
    }

    /// Fraction of (block, node) pairs that are replicas — diagnostic used
    /// by the locality_study example.
    pub fn replica_density(&self, file: FileId, num_nodes: usize) -> f64 {
        let blocks = self.blocks(file);
        if blocks.is_empty() || num_nodes == 0 {
            return 0.0;
        }
        let replicas: usize = blocks.iter().map(|b| b.replicas.len()).sum();
        replicas as f64 / (blocks.len() * num_nodes) as f64
    }
}

/// One block's replicas under the default HDFS rack-aware policy:
/// replica 1 on a uniform node (the "writer"), replica 2 off-rack,
/// replica 3 on a different node of replica 2's rack, the rest uniform
/// over unchosen nodes. Every step falls back to "any unchosen node"
/// when its candidate set is empty (degenerate layouts).
fn place_rack_aware(node_racks: &[u32], replication: usize, rng: &mut Rng) -> Vec<NodeId> {
    fn pick(cands: &[usize], rng: &mut Rng) -> usize {
        debug_assert!(!cands.is_empty());
        cands[rng.below(cands.len() as u64) as usize]
    }
    fn unchosen(n: usize, chosen: &[usize], keep: impl Fn(usize) -> bool) -> Vec<usize> {
        (0..n).filter(|&i| !chosen.contains(&i) && keep(i)).collect()
    }

    let n = node_racks.len();
    let mut chosen: Vec<usize> = Vec::with_capacity(replication);
    let all: Vec<usize> = (0..n).collect();
    chosen.push(pick(&all, rng));
    if replication >= 2 {
        let first_rack = node_racks[chosen[0]];
        let mut cands = unchosen(n, &chosen, |i| node_racks[i] != first_rack);
        if cands.is_empty() {
            cands = unchosen(n, &chosen, |_| true);
        }
        let c = pick(&cands, rng);
        chosen.push(c);
    }
    if replication >= 3 {
        let second_rack = node_racks[chosen[1]];
        let mut cands = unchosen(n, &chosen, |i| node_racks[i] == second_rack);
        if cands.is_empty() {
            cands = unchosen(n, &chosen, |_| true);
        }
        let c = pick(&cands, rng);
        chosen.push(c);
    }
    while chosen.len() < replication {
        let cands = unchosen(n, &chosen, |_| true);
        let c = pick(&cands, rng);
        chosen.push(c);
    }
    chosen.into_iter().map(|i| NodeId(i as u32)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nn_with_file(total_mb: f64, block_mb: f64) -> (NameNode, FileId) {
        let mut nn = NameNode::new();
        let mut rng = Rng::new(11);
        let f = nn.create_file(total_mb, block_mb, 3, 10, &mut rng);
        (nn, f)
    }

    #[test]
    fn block_count_and_sizes() {
        let (nn, f) = nn_with_file(200.0, 64.0);
        let blocks = nn.blocks(f);
        assert_eq!(blocks.len(), 4); // 3 full + 8MB tail
        assert_eq!(blocks[0].size_mb, 64.0);
        assert!((blocks[3].size_mb - 8.0).abs() < 1e-9);
        let total: f64 = blocks.iter().map(|b| b.size_mb).sum();
        assert!((total - 200.0).abs() < 1e-9);
    }

    #[test]
    fn exact_multiple_has_no_tail() {
        let (nn, f) = nn_with_file(128.0, 64.0);
        assert_eq!(nn.num_blocks(f), 2);
    }

    #[test]
    fn empty_file() {
        let (nn, f) = nn_with_file(0.0, 64.0);
        assert_eq!(nn.num_blocks(f), 0);
    }

    #[test]
    fn replicas_distinct_and_in_range() {
        let (nn, f) = nn_with_file(640.0, 64.0);
        for b in nn.blocks(f) {
            assert_eq!(b.replicas.len(), 3);
            let mut r: Vec<u32> = b.replicas.iter().map(|n| n.0).collect();
            r.sort_unstable();
            r.dedup();
            assert_eq!(r.len(), 3, "replicas must be distinct");
            assert!(r.iter().all(|&n| n < 10));
        }
    }

    #[test]
    fn is_local_consistent_with_replicas() {
        let (nn, f) = nn_with_file(320.0, 64.0);
        for b in nn.blocks(f) {
            for n in 0..10u32 {
                assert_eq!(
                    nn.is_local(b.id, NodeId(n)),
                    b.replicas.contains(&NodeId(n))
                );
            }
        }
    }

    #[test]
    fn locality_index_inverts() {
        let (nn, f) = nn_with_file(640.0, 64.0);
        let idx = nn.locality_index(f, 10);
        for (node, block_ids) in idx.iter().enumerate() {
            for &bi in block_ids {
                assert!(nn.is_local(
                    BlockId { file: f, index: bi },
                    NodeId(node as u32)
                ));
            }
        }
        // every replica appears exactly once in the index
        let total: usize = idx.iter().map(|v| v.len()).sum();
        assert_eq!(total, 10 * 3);
    }

    #[test]
    fn density_matches_replication() {
        let (nn, f) = nn_with_file(640.0, 64.0);
        assert!((nn.replica_density(f, 10) - 0.3).abs() < 1e-9);
    }

    #[test]
    fn flat_placement_matches_legacy_sampling() {
        // Regression guard for the `--topology flat` byte-identity
        // contract: a single-rack layout must draw exactly the RNG
        // sequence the pre-topology simulator drew (one
        // `sample_indices(n, k)` per block), so every flat run's
        // placement — and therefore its locality numbers — is unchanged.
        let mut nn = NameNode::new();
        let mut rng = Rng::new(11);
        let f = nn.create_file_placed(640.0, 64.0, 3, &[0; 10], &mut rng);
        let mut legacy = Rng::new(11);
        for b in nn.blocks(f) {
            let want: Vec<NodeId> = legacy
                .sample_indices(10, 3)
                .into_iter()
                .map(|n| NodeId(n as u32))
                .collect();
            assert_eq!(b.replicas, want);
        }
        // And create_file is exactly the flat wrapper.
        let mut nn2 = NameNode::new();
        let mut rng2 = Rng::new(11);
        let f2 = nn2.create_file(640.0, 64.0, 3, 10, &mut rng2);
        for (a, b) in nn.blocks(f).iter().zip(nn2.blocks(f2)) {
            assert_eq!(a.replicas, b.replicas);
        }
    }

    #[test]
    fn rack_aware_placement_spans_two_racks() {
        // 2 racks x 5 nodes: nodes 0-4 rack 0, nodes 5-9 rack 1.
        let racks: Vec<u32> = (0..10).map(|i| (i / 5) as u32).collect();
        let mut nn = NameNode::new();
        let mut rng = Rng::new(23);
        let f = nn.create_file_placed(64.0 * 40.0, 64.0, 3, &racks, &mut rng);
        for b in nn.blocks(f) {
            assert_eq!(b.replicas.len(), 3);
            let mut ids: Vec<u32> = b.replicas.iter().map(|n| n.0).collect();
            ids.sort_unstable();
            ids.dedup();
            assert_eq!(ids.len(), 3, "replicas must be distinct");
            let r: Vec<u32> = b.replicas.iter().map(|n| racks[n.idx()]).collect();
            // HDFS default: replica 2 off replica 1's rack, replica 3 on
            // replica 2's rack — exactly two racks, split 1 + 2.
            assert_ne!(r[0], r[1], "second replica must be off-rack");
            assert_eq!(r[1], r[2], "third replica shares the second's rack");
        }
    }

    #[test]
    fn rack_aware_degenerate_layouts_still_place() {
        // More replicas than the off-rack / same-rack candidate sets can
        // serve: fallbacks keep replicas distinct and complete.
        let racks = vec![0, 0, 0, 1]; // rack 1 has a single node
        let mut nn = NameNode::new();
        let mut rng = Rng::new(5);
        let f = nn.create_file_placed(256.0, 64.0, 4, &racks, &mut rng);
        for b in nn.blocks(f) {
            let mut ids: Vec<u32> = b.replicas.iter().map(|n| n.0).collect();
            ids.sort_unstable();
            ids.dedup();
            assert_eq!(ids.len(), 4);
        }
    }

    #[test]
    fn fail_node_rereplicates_onto_alive_nodes() {
        let racks: Vec<u32> = (0..10).map(|i| (i / 5) as u32).collect();
        let mut nn = NameNode::new();
        let mut rng = Rng::new(23);
        let f = nn.create_file_placed(64.0 * 40.0, 64.0, 3, &racks, &mut rng);
        let dead = NodeId(2);
        let mut alive = vec![true; 10];
        alive[dead.idx()] = false;
        let affected = nn
            .blocks(f)
            .iter()
            .filter(|b| b.replicas.contains(&dead))
            .count() as u64;
        assert!(affected > 0, "seed produced no replicas on node 2");
        let mut frng = Rng::new(99);
        let (relocated, lost) = nn.fail_node(dead, &racks, &alive, &mut frng);
        assert_eq!(relocated, affected);
        assert_eq!(lost, 0, "3-way replication survives one death");
        for b in nn.blocks(f) {
            assert_eq!(b.replicas.len(), 3, "replication restored");
            assert!(!b.replicas.contains(&dead), "dead replica dropped");
            let mut ids: Vec<u32> = b.replicas.iter().map(|n| n.0).collect();
            ids.sort_unstable();
            ids.dedup();
            assert_eq!(ids.len(), 3, "replicas stay distinct");
        }
        // Untouched nodes' data unaffected: the index still inverts.
        let idx = nn.locality_index(f, 10);
        assert!(idx[dead.idx()].is_empty());
    }

    #[test]
    fn fail_node_total_loss_counts_and_restores() {
        // Replication 1: killing a block's only node loses it; the
        // restore-from-source policy still re-replicates so reads and
        // re-executed maps never block.
        let mut nn = NameNode::new();
        let mut rng = Rng::new(7);
        let f = nn.create_file(256.0, 64.0, 1, 4, &mut rng);
        let dead = nn.blocks(f)[0].replicas[0];
        let mut alive = vec![true; 4];
        alive[dead.idx()] = false;
        let had = nn
            .blocks(f)
            .iter()
            .filter(|b| b.replicas.contains(&dead))
            .count() as u64;
        let mut frng = Rng::new(5);
        let (relocated, lost) = nn.fail_node(dead, &[0; 4], &alive, &mut frng);
        assert_eq!(lost, had);
        assert_eq!(relocated, had);
        for b in nn.blocks(f) {
            assert_eq!(b.replicas.len(), 1);
            assert!(!b.replicas.contains(&dead));
        }
    }

    #[test]
    fn file_ids_unique() {
        let mut nn = NameNode::new();
        let mut rng = Rng::new(3);
        let a = nn.create_file(64.0, 64.0, 1, 4, &mut rng);
        let b = nn.create_file(64.0, 64.0, 1, 4, &mut rng);
        assert_ne!(a, b);
    }
}
