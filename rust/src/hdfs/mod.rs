//! HDFS-like storage substrate: block-structured files, random k-way
//! replication across DataNodes (= VMs), and the NameNode metadata the
//! schedulers query for data locality.
//!
//! Placement follows Hadoop 0.20's rack-unaware default closely enough for
//! the paper's purposes: each block's replicas land on `replication`
//! distinct nodes chosen uniformly (the paper's testbed is a single rack).

use std::collections::HashMap;

use crate::cluster::NodeId;
use crate::util::Rng;

/// A stored file (one MapReduce job input or output).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct FileId(pub u32);

/// Block index within a file.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct BlockId {
    pub file: FileId,
    pub index: u32,
}

/// Metadata for one block.
#[derive(Clone, Debug)]
pub struct BlockInfo {
    pub id: BlockId,
    pub size_mb: f64,
    /// Nodes holding a replica (distinct).
    pub replicas: Vec<NodeId>,
}

/// NameNode: file -> blocks -> replica locations.
#[derive(Debug, Default)]
pub struct NameNode {
    files: HashMap<FileId, Vec<BlockInfo>>,
    next_file: u32,
}

impl NameNode {
    pub fn new() -> Self {
        Self::default()
    }

    /// Create a file of `total_mb` split into `block_mb` blocks, each
    /// replicated on `replication` distinct nodes of the `num_nodes`
    /// cluster. Returns the new file id.
    pub fn create_file(
        &mut self,
        total_mb: f64,
        block_mb: f64,
        replication: usize,
        num_nodes: usize,
        rng: &mut Rng,
    ) -> FileId {
        assert!(block_mb > 0.0 && total_mb >= 0.0);
        assert!(replication >= 1 && replication <= num_nodes);
        let id = FileId(self.next_file);
        self.next_file += 1;
        let full_blocks = (total_mb / block_mb).floor() as u32;
        let tail = total_mb - full_blocks as f64 * block_mb;
        let mut blocks = Vec::new();
        let n_blocks = full_blocks + if tail > 1e-9 { 1 } else { 0 };
        for i in 0..n_blocks {
            let size = if i < full_blocks { block_mb } else { tail };
            let replicas = rng
                .sample_indices(num_nodes, replication)
                .into_iter()
                .map(|n| NodeId(n as u32))
                .collect();
            blocks.push(BlockInfo {
                id: BlockId { file: id, index: i },
                size_mb: size,
                replicas,
            });
        }
        self.files.insert(id, blocks);
        id
    }

    pub fn blocks(&self, file: FileId) -> &[BlockInfo] {
        self.files
            .get(&file)
            .map(|b| b.as_slice())
            .unwrap_or(&[])
    }

    pub fn num_blocks(&self, file: FileId) -> usize {
        self.blocks(file).len()
    }

    pub fn block(&self, id: BlockId) -> Option<&BlockInfo> {
        self.files.get(&id.file)?.get(id.index as usize)
    }

    /// Is a replica of `block` resident on `node`?
    pub fn is_local(&self, id: BlockId, node: NodeId) -> bool {
        self.block(id)
            .map(|b| b.replicas.contains(&node))
            .unwrap_or(false)
    }

    /// Build the inverted node -> block-indices map for one file (the
    /// locality index the scheduler keeps hot; see `mapreduce::JobState`).
    pub fn locality_index(&self, file: FileId, num_nodes: usize) -> Vec<Vec<u32>> {
        let mut idx = vec![Vec::new(); num_nodes];
        for b in self.blocks(file) {
            for &r in &b.replicas {
                idx[r.idx()].push(b.id.index);
            }
        }
        idx
    }

    /// Fraction of (block, node) pairs that are replicas — diagnostic used
    /// by the locality_study example.
    pub fn replica_density(&self, file: FileId, num_nodes: usize) -> f64 {
        let blocks = self.blocks(file);
        if blocks.is_empty() || num_nodes == 0 {
            return 0.0;
        }
        let replicas: usize = blocks.iter().map(|b| b.replicas.len()).sum();
        replicas as f64 / (blocks.len() * num_nodes) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nn_with_file(total_mb: f64, block_mb: f64) -> (NameNode, FileId) {
        let mut nn = NameNode::new();
        let mut rng = Rng::new(11);
        let f = nn.create_file(total_mb, block_mb, 3, 10, &mut rng);
        (nn, f)
    }

    #[test]
    fn block_count_and_sizes() {
        let (nn, f) = nn_with_file(200.0, 64.0);
        let blocks = nn.blocks(f);
        assert_eq!(blocks.len(), 4); // 3 full + 8MB tail
        assert_eq!(blocks[0].size_mb, 64.0);
        assert!((blocks[3].size_mb - 8.0).abs() < 1e-9);
        let total: f64 = blocks.iter().map(|b| b.size_mb).sum();
        assert!((total - 200.0).abs() < 1e-9);
    }

    #[test]
    fn exact_multiple_has_no_tail() {
        let (nn, f) = nn_with_file(128.0, 64.0);
        assert_eq!(nn.num_blocks(f), 2);
    }

    #[test]
    fn empty_file() {
        let (nn, f) = nn_with_file(0.0, 64.0);
        assert_eq!(nn.num_blocks(f), 0);
    }

    #[test]
    fn replicas_distinct_and_in_range() {
        let (nn, f) = nn_with_file(640.0, 64.0);
        for b in nn.blocks(f) {
            assert_eq!(b.replicas.len(), 3);
            let mut r: Vec<u32> = b.replicas.iter().map(|n| n.0).collect();
            r.sort_unstable();
            r.dedup();
            assert_eq!(r.len(), 3, "replicas must be distinct");
            assert!(r.iter().all(|&n| n < 10));
        }
    }

    #[test]
    fn is_local_consistent_with_replicas() {
        let (nn, f) = nn_with_file(320.0, 64.0);
        for b in nn.blocks(f) {
            for n in 0..10u32 {
                assert_eq!(
                    nn.is_local(b.id, NodeId(n)),
                    b.replicas.contains(&NodeId(n))
                );
            }
        }
    }

    #[test]
    fn locality_index_inverts() {
        let (nn, f) = nn_with_file(640.0, 64.0);
        let idx = nn.locality_index(f, 10);
        for (node, block_ids) in idx.iter().enumerate() {
            for &bi in block_ids {
                assert!(nn.is_local(
                    BlockId { file: f, index: bi },
                    NodeId(node as u32)
                ));
            }
        }
        // every replica appears exactly once in the index
        let total: usize = idx.iter().map(|v| v.len()).sum();
        assert_eq!(total, 10 * 3);
    }

    #[test]
    fn density_matches_replication() {
        let (nn, f) = nn_with_file(640.0, 64.0);
        assert!((nn.replica_density(f, 10) - 0.3).abs() < 1e-9);
    }

    #[test]
    fn file_ids_unique() {
        let mut nn = NameNode::new();
        let mut rng = Rng::new(3);
        let a = nn.create_file(64.0, 64.0, 1, 4, &mut rng);
        let b = nn.create_file(64.0, 64.0, 1, 4, &mut rng);
        assert_ne!(a, b);
    }
}
