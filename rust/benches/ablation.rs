//! Ablation bench: isolate each mechanism of the proposed scheduler
//! (DESIGN.md §Perf calls these out as design choices to justify):
//!
//! * await gating — literal Alg. 1 (speculative waits) vs our
//!   release-gated waits;
//! * spare-capacity pass — strict Alg. 2 caps vs work-conserving;
//! * cross-node routing budget (max_routed);
//! * hot-plug latency sensitivity (Xen credit-scheduler cost sweep);
//! * fluid (Eq. 7) vs wave-based completion estimator accuracy against
//!   realized single-job runs.
//!
//!     cargo bench --offline --bench ablation

use vcsched::config::SimConfig;
use vcsched::coordinator::{run_simulation, run_simulation_custom};
use vcsched::predictor::{JobProgress, NativePredictor};
use vcsched::scheduler::{DeadlineVcScheduler, DvcTuning, SchedulerKind};
use vcsched::util::benchkit::Table;
use vcsched::workloads::trace::JobTrace;
use vcsched::workloads::{JobSpec, JobType};

fn run_tuned(cfg: &SimConfig, trace: &JobTrace, tuning: DvcTuning) -> vcsched::coordinator::Report {
    let mut s = DeadlineVcScheduler::with_tuning(cfg, tuning);
    let mut p = NativePredictor::new();
    run_simulation_custom(cfg, &mut s, trace, &mut p)
}

fn main() {
    let cfg = SimConfig::paper();
    let trace = JobTrace::paper_mix(&cfg, 17);

    println!("== mechanism ablation (25-job backlogged mix, seed 17) ==\n");
    let mut t = Table::new(&[
        "variant", "thpt/h", "mean_ct", "locality", "hotplugs",
    ]);
    let variants: Vec<(&str, DvcTuning)> = vec![
        ("full (default)", DvcTuning::default()),
        (
            "speculative awaits (literal Alg.1)",
            DvcTuning {
                await_requires_release: false,
                ..DvcTuning::default()
            },
        ),
        (
            "no spare pass (strict Alg.2 caps)",
            DvcTuning {
                spare_pass: false,
                ..DvcTuning::default()
            },
        ),
        (
            "no cross-node routing",
            DvcTuning {
                max_routed: 0,
                ..DvcTuning::default()
            },
        ),
        (
            "aggressive routing (32)",
            DvcTuning {
                max_routed: 32,
                ..DvcTuning::default()
            },
        ),
    ];
    for (name, tuning) in variants {
        let r = run_tuned(&cfg, &trace, tuning);
        t.row(&[
            name.to_string(),
            format!("{:.1}", r.throughput_jobs_per_hour()),
            format!("{:.1}s", r.mean_completion_s()),
            format!("{:.1}%", r.locality_pct()),
            r.hotplugs.to_string(),
        ]);
    }
    // Fair baseline row for reference.
    let fair = run_simulation(&cfg, SchedulerKind::Fair, &trace);
    t.row(&[
        "fair (baseline)".into(),
        format!("{:.1}", fair.throughput_jobs_per_hour()),
        format!("{:.1}s", fair.mean_completion_s()),
        format!("{:.1}%", fair.locality_pct()),
        "0".into(),
    ]);
    t.print();

    println!("\n== hot-plug latency sensitivity ==\n");
    let mut t = Table::new(&["hotplug latency", "thpt/h", "locality", "hotplugs"]);
    for ms in [0u64, 100, 500, 2000, 10000] {
        let cfg = SimConfig {
            hotplug_ms: ms,
            ..SimConfig::paper()
        };
        let r = run_simulation(&cfg, SchedulerKind::DeadlineVc, &trace);
        t.row(&[
            format!("{ms} ms"),
            format!("{:.1}", r.throughput_jobs_per_hour()),
            format!("{:.1}%", r.locality_pct()),
            r.hotplugs.to_string(),
        ]);
    }
    t.print();

    println!("\n== estimator accuracy: fluid Eq.7 vs wave-based (single jobs) ==\n");
    // Run each workload alone with a fixed slot allocation and compare the
    // realized map-phase + total times against both estimators' forecasts.
    let mut t = Table::new(&[
        "job", "actual", "fluid est", "wave est", "fluid err", "wave err",
    ]);
    let mut fluid_abs = 0.0f64;
    let mut wave_abs = 0.0f64;
    for jt in [JobType::WordCount, JobType::Sort, JobType::Grep, JobType::InvertedIndex] {
        let cfg = SimConfig {
            jitter_std: 0.0, // deterministic ground truth
            ..SimConfig::paper()
        };
        let spec = JobSpec::new(jt, 1500.0).with_deadline(1e6);
        let trace1 = JobTrace::new(vec![spec.clone()]);
        let r = run_simulation(&cfg, SchedulerKind::Fifo, &trace1);
        let actual = r.job_records()[0].completion_s;
        // Forecast with the cost model's nominal times and the full
        // cluster's slots (what FIFO effectively grants a lone job).
        let d = vcsched::predictor::demand_from_spec(&cfg, &spec);
        let maps = d.map_tasks;
        let p = JobProgress {
            rem_map: maps,
            rem_reduce: d.reduce_tasks,
            t_map: d.t_map,
            t_reduce: d.t_reduce,
            t_shuffle: 0.0, // sim overlaps copies inside reduce tasks
            map_slots: (cfg.total_map_slots() as f64).min(maps),
            reduce_slots: (cfg.total_reduce_slots() as f64).min(d.reduce_tasks),
            reduce_tasks: d.reduce_tasks,
            deadline: 1e6,
            elapsed: 0.0,
        };
        let fluid = NativePredictor::estimate_one(&p).eta;
        let wave = NativePredictor::estimate_wave_one(&p).eta;
        let fe = (fluid - actual).abs() / actual * 100.0;
        let we = (wave - actual).abs() / actual * 100.0;
        fluid_abs += fe;
        wave_abs += we;
        t.row(&[
            jt.name().to_string(),
            format!("{actual:.0}s"),
            format!("{fluid:.0}s"),
            format!("{wave:.0}s"),
            format!("{fe:.0}%"),
            format!("{we:.0}%"),
        ]);
    }
    t.print();
    println!(
        "\nmean |error|: fluid {:.0}% vs wave {:.0}% — the wave estimator's \
         discrete ceil(rem/n) matches Hadoop's wave execution better for \
         small task counts",
        fluid_abs / 4.0,
        wave_abs / 4.0
    );
}
