//! Bench FIG2: regenerate Figure 2(a)/(b) — per-workload completion times
//! at 2/4/6/8/10 GB under the Fair and proposed schedulers.
//!
//! Paper expectation (shape): completion time grows with input size for
//! every workload; the permutation generator is the slowest (shuffle-
//! bound); the proposed scheduler's times are <= Fair's for map-heavy
//! workloads. Absolute seconds differ from the paper's Xen testbed.
//!
//!     cargo bench --offline --bench fig2_completion_times

use vcsched::config::SimConfig;
use vcsched::coordinator;
use vcsched::scheduler::SchedulerKind;
use vcsched::util::benchkit::{measure, Table};
use vcsched::workloads::trace::JobTrace;
use vcsched::workloads::ALL_JOB_TYPES;

const SIZES_GB: [f64; 5] = [2.0, 4.0, 6.0, 8.0, 10.0];

fn main() {
    let cfg = SimConfig::paper();
    let scale = 1024.0; // full-size inputs (MB per paper-GB)
    let trace = JobTrace::fig2_grid_on(&cfg, scale);

    for (label, kind) in [
        ("Figure 2(a) — Fair Scheduler", SchedulerKind::Fair),
        ("Figure 2(b) — Proposed Scheduler", SchedulerKind::DeadlineVc),
    ] {
        let r = coordinator::run_simulation(&cfg, kind, &trace);
        println!(
            "\n{label}  (jobs={}, makespan={:.0}s, locality={:.1}%)",
            r.completed_jobs(),
            r.makespan_s,
            r.locality_pct()
        );
        let mut t = Table::new(&["job", "2GB", "4GB", "6GB", "8GB", "10GB"]);
        for jt in ALL_JOB_TYPES {
            let mut row = vec![jt.name().to_string()];
            for gb in SIZES_GB {
                let v = r
                    .completion_for(jt, gb * scale)
                    .map(|s| format!("{s:.0}s"))
                    .unwrap_or_else(|| "-".into());
                row.push(v);
            }
            t.row(&row);
        }
        t.print();

        // Shape checks the paper's figure implies.
        for jt in ALL_JOB_TYPES {
            let c2 = r.completion_for(jt, 2.0 * scale).unwrap();
            let c10 = r.completion_for(jt, 10.0 * scale).unwrap();
            assert!(
                c10 > c2,
                "{}: completion must grow with input ({c2:.0}s !< {c10:.0}s)",
                jt.name()
            );
        }
    }

    // Wall-clock cost of regenerating the whole figure.
    let res = measure("fig2 full grid (50 simulated jobs)", 1, 5, || {
        let _ = coordinator::run_simulation(&cfg, SchedulerKind::Fair, &trace);
        let _ = coordinator::run_simulation(&cfg, SchedulerKind::DeadlineVc, &trace);
    });
    println!();
    res.print();
}
