//! Bench FIG3: regenerate Figure 3 — per-job-type completion times, Fair
//! vs proposed, on the Table-2 job mix (deadlines + sizes from the paper).
//!
//! Paper expectation (shape): the proposed scheduler reduces completion
//! time for every workload EXCEPT the permutation generator, whose
//! reduce-input-heavy shuffle makes map locality immaterial — its times
//! are "almost same" (§5).
//!
//!     cargo bench --offline --bench fig3_comparison

use vcsched::config::SimConfig;
use vcsched::coordinator;
use vcsched::scheduler::SchedulerKind;
use vcsched::util::benchkit::{measure, Table};
use vcsched::workloads::trace::JobTrace;
use vcsched::workloads::{JobType, ALL_JOB_TYPES};

fn main() {
    let cfg = SimConfig::paper();
    let trace = JobTrace::table2(1024.0);
    let (fair, prop) = coordinator::compare(
        &cfg,
        SchedulerKind::Fair,
        SchedulerKind::DeadlineVc,
        &trace,
    );

    println!("Figure 3 — Job completion times, Fair vs Proposed (Table-2 mix)\n");
    let mut t = Table::new(&["job", "fair", "proposed", "delta"]);
    let mut deltas = Vec::new();
    for jt in ALL_JOB_TYPES {
        let f = fair.mean_completion_for(jt).unwrap();
        let p = prop.mean_completion_for(jt).unwrap();
        let d = (p / f - 1.0) * 100.0;
        deltas.push((jt, d));
        t.row(&[
            jt.name().to_string(),
            format!("{f:.0}s"),
            format!("{p:.0}s"),
            format!("{d:+.1}%"),
        ]);
    }
    t.print();

    // Shape assertions from the paper's discussion of Fig. 3.
    let perm = deltas
        .iter()
        .find(|(jt, _)| *jt == JobType::PermutationGenerator)
        .unwrap()
        .1;
    let others: Vec<f64> = deltas
        .iter()
        .filter(|(jt, _)| *jt != JobType::PermutationGenerator)
        .map(|(_, d)| *d)
        .collect();
    let mean_others = others.iter().sum::<f64>() / others.len() as f64;
    println!(
        "\npermutation delta {perm:+.1}% vs other-workloads mean {mean_others:+.1}% \
         (paper: permutation ~unchanged, others clearly reduced)"
    );
    assert!(
        mean_others < -5.0,
        "proposed must clearly reduce completion times of map-heavy workloads"
    );
    assert!(
        perm > mean_others,
        "permutation generator must benefit least (locality immaterial in \
         its shuffle-bound profile)"
    );
    println!(
        "locality: fair {:.1}% -> proposed {:.1}% | hotplugs {}",
        fair.locality_pct(),
        prop.locality_pct(),
        prop.hotplugs
    );

    let res = measure("fig3 pair of runs (10 simulated jobs)", 1, 10, || {
        let _ = coordinator::compare(
            &cfg,
            SchedulerKind::Fair,
            SchedulerKind::DeadlineVc,
            &trace,
        );
    });
    println!();
    res.print();
}
