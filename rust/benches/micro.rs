//! Micro-benchmarks: the L3 hot paths and the L1/L2 artifact path.
//!
//! * sim event throughput (events/s through the full stack)
//! * scheduler decision latency per heartbeat (each policy)
//! * predictor latency: native vs XLA/PJRT, per batch size
//! * Alg. 1 placement: native choose_target scan vs the locality kernel
//! * artifact compile time (one-off cost at coordinator start)
//!
//!     make artifacts && cargo bench --offline --bench micro

use vcsched::config::SimConfig;
use vcsched::coordinator;
use vcsched::predictor::{JobDemand, NativePredictor, Predictor};
use vcsched::runtime::{ArtifactSet, PlacementQuery, XlaPredictor, MAX_NODES, MAX_TASKS};
use vcsched::scheduler::SchedulerKind;
use vcsched::util::benchkit::measure;
use vcsched::util::Rng;
use vcsched::workloads::trace::JobTrace;

fn demands(n: usize, seed: u64) -> Vec<JobDemand> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| JobDemand {
            map_tasks: rng.range_f64(1.0, 300.0).floor(),
            reduce_tasks: rng.range_f64(1.0, 48.0).floor(),
            t_map: rng.range_f64(1.0, 60.0),
            t_reduce: rng.range_f64(1.0, 60.0),
            t_shuffle: rng.range_f64(0.0, 0.002),
            deadline: rng.range_f64(50.0, 2000.0),
        })
        .collect()
}

fn main() {
    let cfg = SimConfig::paper();

    // ---- end-to-end simulation event rate ----
    let trace = JobTrace::paper_mix(&cfg, 3);
    let mut events = 0u64;
    let r = measure("full simulation (25 jobs, proposed)", 1, 10, || {
        let rep = coordinator::run_simulation(&cfg, SchedulerKind::DeadlineVc, &trace);
        events = rep.events;
    });
    r.print();
    println!(
        "  -> {events} events per run = {:.0}k events/s",
        events as f64 / (r.mean_us / 1e6) / 1e3
    );

    // ---- per-scheduler wall time on an identical trace ----
    for kind in SchedulerKind::ALL {
        let r = measure(
            &format!("simulate 25 jobs [{}]", kind.name()),
            1,
            10,
            || {
                let _ = coordinator::run_simulation(&cfg, kind, &trace);
            },
        );
        r.print();
    }

    // ---- predictor latency ladder ----
    println!();
    let mut native = NativePredictor::new();
    for n in [1usize, 8, 64, 128, 256] {
        let d = demands(n, 99);
        let r = measure(&format!("native solve_slots n={n}"), 10, 2000, || {
            let _ = native.solve_slots(&d);
        });
        r.print();
    }
    match XlaPredictor::load_default() {
        Ok(mut xla) => {
            for n in [1usize, 64, 128, 256] {
                let d = demands(n, 99);
                let r = measure(&format!("xla    solve_slots n={n}"), 5, 200, || {
                    let _ = xla.solve_slots(&d);
                });
                r.print();
            }

            // ---- Alg. 1 placement kernel ----
            let mut q = PlacementQuery::new();
            let mut rng = Rng::new(5);
            for t in 0..MAX_TASKS {
                q.task_mask[t] = 1.0;
                for _ in 0..3 {
                    q.set_has_data(t, rng.below(MAX_NODES as u64) as usize);
                }
            }
            q.node_mask.fill(1.0);
            for n in 0..MAX_NODES {
                q.rq[n] = rng.below(4) as f32;
                q.aq[n] = rng.below(4) as f32;
            }
            let r = measure(
                &format!("xla place() {MAX_TASKS}x{MAX_NODES} score+argmax"),
                5,
                200,
                || {
                    let _ = xla.place(&q).unwrap();
                },
            );
            r.print();
        }
        Err(e) => eprintln!("skipping XLA micro-benches: {e}"),
    }

    // ---- artifact compile time (start-up cost) ----
    match ArtifactSet::load_default() {
        Ok(set) => {
            println!(
                "\nartifact compile times: slot_solver {:.1} ms, locality {:.1} ms, \
                 estimator {:.1} ms (once per coordinator start)",
                set.slot_solver.compile_time_ms,
                set.locality.compile_time_ms,
                set.estimator.compile_time_ms
            );
        }
        Err(e) => eprintln!("artifact load skipped: {e}"),
    }
}
