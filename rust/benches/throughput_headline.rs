//! Bench HEADLINE: the paper's abstract claim — "a gain of about 12%
//! increase in throughput of Jobs" for the proposed scheduler over the
//! Hadoop Fair Scheduler on a backlogged mixed workload.
//!
//! We run N seeds of the random-size mixed trace (paper §5's "random
//! input sizes" experiment) under both schedulers and report the mean
//! throughput gain plus the full baseline ladder (FIFO/Fair/Delay/EDF/
//! proposed) as an ablation: EDF isolates job ordering, Delay isolates
//! software-only locality patience, the proposed adds Eq. 10 allocation +
//! vCPU reconfiguration.
//!
//!     cargo bench --offline --bench throughput_headline

use vcsched::config::SimConfig;
use vcsched::coordinator;
use vcsched::scheduler::SchedulerKind;
use vcsched::util::benchkit::Table;
use vcsched::util::stats::Summary;
use vcsched::workloads::trace::JobTrace;

const SEEDS: u64 = 5;
const JOBS: usize = 30;

fn main() {
    let cfg = SimConfig::paper();

    // ---- headline: fair vs proposed over SEEDS traces ----
    let mut gain = Summary::new();
    let mut fair_thpt = Summary::new();
    let mut prop_thpt = Summary::new();
    let mut fair_loc = Summary::new();
    let mut prop_loc = Summary::new();
    for s in 0..SEEDS {
        let trace = JobTrace::poisson(&cfg, JOBS, 5.0, 1.6..3.0, cfg.seed + s);
        let (f, p) = coordinator::compare(
            &cfg,
            SchedulerKind::Fair,
            SchedulerKind::DeadlineVc,
            &trace,
        );
        gain.add((p.throughput_jobs_per_hour() / f.throughput_jobs_per_hour() - 1.0) * 100.0);
        fair_thpt.add(f.throughput_jobs_per_hour());
        prop_thpt.add(p.throughput_jobs_per_hour());
        fair_loc.add(f.locality_pct());
        prop_loc.add(p.locality_pct());
    }
    println!(
        "HEADLINE over {SEEDS} seeds x {JOBS} jobs: throughput gain mean {:+.1}% \
         (min {:+.1}%, max {:+.1}%) — paper claims ~12%",
        gain.mean(),
        gain.min(),
        gain.max()
    );
    println!(
        "  fair: {:.1} jobs/h @ {:.1}% locality | proposed: {:.1} jobs/h @ {:.1}% locality",
        fair_thpt.mean(),
        fair_loc.mean(),
        prop_thpt.mean(),
        prop_loc.mean()
    );
    assert!(
        gain.mean() > 5.0,
        "throughput gain {:.1}% too far below the paper's ~12%",
        gain.mean()
    );

    // ---- ablation ladder ----
    println!("\nAblation (same trace, seed {}):", cfg.seed);
    let trace = JobTrace::poisson(&cfg, JOBS, 5.0, 1.6..3.0, cfg.seed);
    let mut t = Table::new(&[
        "scheduler", "thpt/h", "mean_ct", "locality", "misses", "hotplugs",
    ]);
    for kind in SchedulerKind::ALL {
        let r = coordinator::run_simulation(&cfg, kind, &trace);
        t.row(&[
            kind.name().to_string(),
            format!("{:.1}", r.throughput_jobs_per_hour()),
            format!("{:.1}s", r.mean_completion_s()),
            format!("{:.1}%", r.locality_pct()),
            format!("{:.0}%", r.miss_rate() * 100.0),
            r.hotplugs.to_string(),
        ]);
    }
    t.print();
}
