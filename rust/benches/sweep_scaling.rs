//! Bench SWEEP: wall-clock of the parallel scenario-sweep harness on a
//! small grid at 1 thread vs all available cores, so future PRs can track
//! harness overhead. Writes `BENCH_sweep_scaling.json` next to Cargo.toml
//! and asserts the determinism contract (artifacts byte-identical across
//! thread counts) while it is at it.
//!
//!     cargo bench --offline --bench sweep_scaling

use std::time::Instant;

use vcsched::harness::{aggregate, run_sweep, sweep_json, ScenarioGrid};
use vcsched::util::benchkit::Table;
use vcsched::util::json::Json;

fn grid() -> ScenarioGrid {
    let mut g = ScenarioGrid::quick();
    // Enough replicates that the 8-core case has work to spread.
    g.seed_replicates = 8;
    g.jobs_per_scenario = 10;
    g
}

fn timed_sweep(g: &ScenarioGrid, threads: usize) -> (f64, String) {
    let t0 = Instant::now();
    let results = run_sweep(g, threads);
    let wall_s = t0.elapsed().as_secs_f64();
    let artifact = sweep_json(g, &results, &aggregate(&results)).render();
    (wall_s, artifact)
}

fn main() {
    let g = grid();
    let max_threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    println!(
        "sweep_scaling: {} scenarios x {} jobs, 1 vs {max_threads} threads",
        g.len(),
        g.jobs_per_scenario
    );

    // Warm-up (page in code paths, steady-state allocator).
    let _ = timed_sweep(&g, max_threads);

    let (serial_s, serial_artifact) = timed_sweep(&g, 1);
    let mut rows = vec![(1usize, serial_s)];
    let mut thread_points = vec![2usize, 4];
    if !thread_points.contains(&max_threads) && max_threads > 1 {
        thread_points.push(max_threads);
    }
    for &threads in thread_points.iter().filter(|&&t| t <= max_threads) {
        let (wall_s, artifact) = timed_sweep(&g, threads);
        assert_eq!(
            serial_artifact, artifact,
            "determinism violated at {threads} threads"
        );
        rows.push((threads, wall_s));
    }

    let mut t = Table::new(&["threads", "wall", "speedup"]);
    for &(threads, wall_s) in &rows {
        t.row(&[
            threads.to_string(),
            format!("{:.3}s", wall_s),
            format!("x{:.2}", serial_s / wall_s.max(1e-9)),
        ]);
    }
    t.print();

    let mut points = Json::arr();
    for &(threads, wall_s) in &rows {
        points = points.push(
            Json::obj()
                .set("threads", threads)
                .set("wall_s", wall_s)
                .set("speedup", serial_s / wall_s.max(1e-9)),
        );
    }
    let doc = Json::obj()
        .set("bench", "sweep_scaling")
        .set("scenarios", g.len())
        .set("jobs_per_scenario", g.jobs_per_scenario)
        .set("points", points)
        .render();
    let out = vcsched::util::repo_path("BENCH_sweep_scaling.json");
    std::fs::write(&out, doc).expect("write BENCH_sweep_scaling.json");
    println!("\nwrote {}", out.display());

    // Soft gate: available_parallelism() counts logical CPUs (SMT) and
    // shared runners may be loaded, so a miss is a warning, not a panic —
    // the determinism assertions above are the hard contract.
    if max_threads >= 4 {
        let best = rows
            .iter()
            .map(|&(_, w)| w)
            .fold(f64::INFINITY, f64::min);
        let speedup = serial_s / best.max(1e-9);
        if speedup >= 2.0 {
            println!("speedup gate passed: x{speedup:.2} >= x2.0");
        } else {
            eprintln!(
                "WARNING: only x{speedup:.2} speedup on {max_threads} logical \
                 CPUs (expected >= x2.0 on 4+ physical cores)"
            );
        }
    }
}
