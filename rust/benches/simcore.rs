//! Bench SIMCORE: events/sec of the simulator hot loop on the `stress`
//! scenario (200 PMs / 400 nodes / racks-8, saturating Poisson arrivals),
//! measured for the indexed event loop **and** for the retained pre-index
//! reference (`scheduler::reference` + the naive O(jobs) `all_done` scan),
//! so the speedup is a number in the artifact, not a claim in a commit
//! message. Writes `BENCH_simcore.json` next to Cargo.toml.
//!
//!     cargo bench --offline --bench simcore
//!
//! Both paths process the exact same event sequence (asserted below via
//! event counts and bitwise-equal makespans — the optimization changes no
//! simulated outcome), so events/sec ratios are pure wall-time ratios.
//!
//! `SIMCORE_JOBS` truncates the stress trace (default 400 — CI-sized; the
//! full 2000-job scenario is `SIMCORE_JOBS=2000`, where the naive
//! baseline's O(jobs × tasks) heartbeats and O(jobs)-per-event `all_done`
//! scans bite hardest).
//!
//! `SIMCORE_XL=1` additionally runs the `stress-xl` cell (2000 PMs /
//! 4000 nodes / 16-pod fat-tree, 50k jobs; `SIMCORE_XL_JOBS` truncates)
//! through the indexed loop only — the naive reference would take hours
//! there, which is the point — and **hard-asserts** a wall-clock and
//! peak-RSS budget per scheduler. The budgets are deliberately loose
//! (shared-runner noise) but an O(jobs) regression in the per-event path
//! blows through them by an order of magnitude.
//!
//! `SIMCORE_1M=1` runs the `stress-1m` cell (1,000,000 Poisson jobs
//! streamed through deadline_vc with `stream_metrics` on;
//! `SIMCORE_1M_JOBS` truncates) and **hard-asserts a flat peak-RSS
//! budget that does not scale with the job count** — arrivals are pulled
//! lazily, completed jobs are retired, and metrics fold into
//! constant-memory accumulators, so memory is bounded by the active job
//! window. It runs *first* so the `VmHWM` reading is not inflated by the
//! other cells.

use std::time::Instant;

use vcsched::coordinator::World;
use vcsched::harness::ScenarioGrid;
use vcsched::predictor::NativePredictor;
use vcsched::scheduler::reference::build_reference;
use vcsched::util::benchkit::Table;
use vcsched::util::json::Json;

/// Peak resident set size of this process in MiB (`VmHWM` from
/// `/proc/self/status`); 0.0 where procfs is unavailable (non-Linux).
/// Process-wide high-water mark: monotone across cells in one run, so
/// per-cell readings reflect the largest cell executed so far.
fn peak_rss_mib() -> f64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0.0;
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: f64 = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse()
                .unwrap_or(0.0);
            return kb / 1024.0;
        }
    }
    0.0
}

/// The stress-xl scaling guard: run each scheduler's cell through the
/// indexed loop under a hard wall-clock + peak-RSS budget and return the
/// JSON points. Budgets scale linearly with the truncated job count so
/// the CI smoke (`SIMCORE_XL_JOBS=60`) and the full 50k-job run assert
/// the same per-job envelope.
fn run_xl() -> Json {
    let grid_full = ScenarioGrid::stress_xl();
    let jobs: usize = std::env::var("SIMCORE_XL_JOBS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(grid_full.jobs_per_scenario);
    let mut grid = grid_full;
    grid.jobs_per_scenario = jobs;
    // Per-job envelope: 12 ms wall and 40 KiB resident per job, plus a
    // fixed floor for the 4000-node cluster itself. At 50k jobs that is
    // a 600 s / ~2 GiB ceiling; the indexed loop runs far under it, an
    // O(jobs)-per-event regression far over.
    let wall_budget_s = 30.0 + jobs as f64 * 0.012;
    let rss_budget_mib = 512.0 + jobs as f64 * 40.0 / 1024.0;
    println!(
        "\nsimcore-xl: stress-xl scenario ({} PMs, {}, {jobs} jobs) — budgets: \
         {wall_budget_s:.0}s wall, {rss_budget_mib:.0} MiB peak RSS",
        grid.pm_counts[0],
        grid.topologies[0].label(),
    );

    let mut t = Table::new(&["scheduler", "events", "wall", "ev/s", "peak rss"]);
    let mut points = Json::arr();
    for sc in &grid.scenarios() {
        let cfg = sc.sim_config();
        let trace = sc.job_trace(&grid, &cfg);
        let mut sched = sc.scheduler.build(&cfg);
        let mut pred = NativePredictor::new();
        let mut world = World::new(cfg, trace);
        let t0 = Instant::now();
        world.run(sched.as_mut(), &mut pred);
        let wall_s = t0.elapsed().as_secs_f64();
        let m = world.into_metrics(sc.scheduler.name());
        let rss_mib = peak_rss_mib();
        let eps = m.events as f64 / wall_s.max(1e-9);
        t.row(&[
            sc.scheduler.name().to_string(),
            m.events.to_string(),
            format!("{wall_s:.3}s"),
            format!("{eps:.0}"),
            format!("{rss_mib:.0} MiB"),
        ]);
        points = points.push(
            Json::obj()
                .set("scheduler", sc.scheduler.name())
                .set("jobs", jobs)
                .set("events", m.events)
                .set("wall_s", wall_s)
                .set("events_per_sec", eps)
                .set("peak_rss_mib", rss_mib)
                .set("wall_budget_s", wall_budget_s)
                .set("rss_budget_mib", rss_budget_mib),
        );
        // Hard gates: the whole point of the xl cell.
        assert!(
            wall_s <= wall_budget_s,
            "{}: stress-xl wall clock {wall_s:.1}s exceeds the {wall_budget_s:.0}s \
             budget — a per-event cost grew with job count",
            sc.scheduler.name()
        );
        assert!(
            rss_mib <= rss_budget_mib,
            "{}: stress-xl peak RSS {rss_mib:.0} MiB exceeds the {rss_budget_mib:.0} \
             MiB budget",
            sc.scheduler.name()
        );
    }
    t.print();
    Json::obj()
        .set("jobs", jobs)
        .set("wall_budget_s", wall_budget_s)
        .set("rss_budget_mib", rss_budget_mib)
        .set("points", points)
}

/// The million-job streaming memory guard (`SIMCORE_1M=1`): run the
/// `stress-1m` cell through the streaming source path and hard-assert a
/// **constant** peak-RSS budget. Unlike `run_xl`'s per-job envelope, the
/// budget here deliberately does NOT scale with the job count — that flat
/// line is the contract: memory is bounded by the active job window, so
/// 20k jobs (the CI smoke, `SIMCORE_1M_JOBS=20000`) and the full
/// 1,000,000-job run assert the identical ceiling.
fn run_1m() -> Json {
    let grid_full = ScenarioGrid::stress_1m();
    let jobs: usize = std::env::var("SIMCORE_1M_JOBS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(grid_full.jobs_per_scenario);
    let mut grid = grid_full;
    grid.jobs_per_scenario = jobs;
    // Job-count-independent: the active window on this cluster stays in
    // the hundreds of jobs, and the streaming accumulators are O(1).
    let rss_budget_mib = 512.0;
    println!(
        "simcore-1m: stress-1m scenario ({} PMs, {}, {jobs} jobs, streaming) — \
         budget: {rss_budget_mib:.0} MiB peak RSS, independent of job count",
        grid.pm_counts[0],
        grid.topologies[0].label(),
    );

    let mut t = Table::new(&["scheduler", "jobs", "events", "wall", "ev/s", "peak rss"]);
    let mut points = Json::arr();
    for sc in &grid.scenarios() {
        let cfg = sc.sim_config();
        let source = sc.job_source(&grid, &cfg).expect("stress-1m job source");
        let mut sched = sc.scheduler.build(&cfg);
        let mut pred = NativePredictor::new();
        let mut world = World::from_source(cfg, source);
        let t0 = Instant::now();
        world.run(sched.as_mut(), &mut pred);
        let wall_s = t0.elapsed().as_secs_f64();
        let m = world.into_metrics(sc.scheduler.name());
        let agg = m
            .stream_agg()
            .expect("stress-1m runs with stream_metrics on");
        assert_eq!(
            agg.completed as usize, jobs,
            "{}: streamed run must complete every job",
            sc.scheduler.name()
        );
        let rss_mib = peak_rss_mib();
        let eps = m.events as f64 / wall_s.max(1e-9);
        t.row(&[
            sc.scheduler.name().to_string(),
            jobs.to_string(),
            m.events.to_string(),
            format!("{wall_s:.3}s"),
            format!("{eps:.0}"),
            format!("{rss_mib:.0} MiB"),
        ]);
        points = points.push(
            Json::obj()
                .set("scheduler", sc.scheduler.name())
                .set("jobs", jobs)
                .set("completed", agg.completed)
                .set("events", m.events)
                .set("wall_s", wall_s)
                .set("events_per_sec", eps)
                .set("p50_completion_s", agg.sketch.pct(50.0))
                .set("p99_completion_s", agg.sketch.pct(99.0))
                .set("peak_rss_mib", rss_mib)
                .set("rss_budget_mib", rss_budget_mib),
        );
        // The hard gate: bounded memory, no matter how long the trace.
        assert!(
            rss_mib <= rss_budget_mib,
            "{}: stress-1m peak RSS {rss_mib:.0} MiB exceeds the flat \
             {rss_budget_mib:.0} MiB budget — per-job state is leaking past \
             the retirement window",
            sc.scheduler.name()
        );
    }
    t.print();
    Json::obj()
        .set("jobs", jobs)
        .set("rss_budget_mib", rss_budget_mib)
        .set("points", points)
}

fn main() {
    // The 1m memory guard runs FIRST: VmHWM is a process-wide high-water
    // mark, so the flat-RSS assertion must see a heap untouched by the
    // larger materialized cells below.
    let m1 = if std::env::var("SIMCORE_1M").as_deref() == Ok("1") {
        Some(run_1m())
    } else {
        None
    };

    let jobs: usize = std::env::var("SIMCORE_JOBS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(400);
    let mut grid = ScenarioGrid::stress();
    grid.jobs_per_scenario = jobs;
    let scenarios = grid.scenarios();
    println!(
        "simcore: stress scenario ({} PMs, {}, {jobs} jobs) — indexed loop vs \
         retained naive reference",
        grid.pm_counts[0],
        grid.topologies[0].label(),
    );

    let mut t = Table::new(&[
        "scheduler",
        "events",
        "indexed",
        "reference",
        "ev/s indexed",
        "ev/s reference",
        "speedup",
        "peak rss",
    ]);
    let mut points = Json::arr();
    let mut headline_speedup = 0.0f64;

    for sc in &scenarios {
        let cfg = sc.sim_config();
        let trace = sc.job_trace(&grid, &cfg);

        // Indexed path: the production loop, exactly as `run_simulation`
        // drives it.
        let mut sched = sc.scheduler.build(&cfg);
        let mut pred = NativePredictor::new();
        let mut world = World::new(cfg.clone(), trace.clone());
        let t0 = Instant::now();
        world.run(sched.as_mut(), &mut pred);
        let indexed_s = t0.elapsed().as_secs_f64();
        let indexed = world.into_metrics(sc.scheduler.name());

        // Reference path: naive schedulers + the O(jobs)-per-event
        // `all_done` scan — the pre-index loop.
        let mut sched = build_reference(sc.scheduler, &cfg);
        let mut pred = NativePredictor::new();
        let mut world = World::new(cfg.clone(), trace.clone());
        world.use_naive_all_done();
        let t0 = Instant::now();
        world.run(sched.as_mut(), &mut pred);
        let reference_s = t0.elapsed().as_secs_f64();
        let reference = world.into_metrics(sc.scheduler.name());

        // Differential guard: same events, same outcome, bit for bit —
        // down to every job record, so an indexing bug that only bites at
        // stress scale cannot hide behind matching totals.
        let name = sc.scheduler.name();
        assert_eq!(indexed.events, reference.events, "{name}: events");
        assert_eq!(indexed.hotplugs, reference.hotplugs, "{name}: hotplugs");
        assert_eq!(
            indexed.makespan_s.to_bits(),
            reference.makespan_s.to_bits(),
            "{name}: makespan diverged from the reference implementation"
        );
        assert_eq!(
            indexed.job_records().len(),
            reference.job_records().len(),
            "{name}: job count"
        );
        for (a, b) in indexed.job_records().iter().zip(reference.job_records()) {
            assert_eq!(
                a.completion_s.to_bits(),
                b.completion_s.to_bits(),
                "{name}: job {:?} completion diverged",
                a.id
            );
            assert_eq!(a.local_maps, b.local_maps, "{name}: job {:?} locality", a.id);
            assert_eq!(a.rack_maps, b.rack_maps, "{name}: job {:?} locality", a.id);
            assert_eq!(a.remote_maps, b.remote_maps, "{name}: job {:?} locality", a.id);
        }

        let eps = indexed.events as f64 / indexed_s.max(1e-9);
        let baseline_eps = reference.events as f64 / reference_s.max(1e-9);
        let speedup = eps / baseline_eps.max(1e-9);
        // Recorded, not asserted (the hard RSS gates live in the xl/1m
        // cells); process-peak semantics, see `peak_rss_mib`.
        let rss_mib = peak_rss_mib();
        if sc.scheduler == vcsched::scheduler::SchedulerKind::DeadlineVc {
            headline_speedup = speedup;
        }
        t.row(&[
            sc.scheduler.name().to_string(),
            indexed.events.to_string(),
            format!("{indexed_s:.3}s"),
            format!("{reference_s:.3}s"),
            format!("{eps:.0}"),
            format!("{baseline_eps:.0}"),
            format!("x{speedup:.2}"),
            format!("{rss_mib:.0} MiB"),
        ]);
        points = points.push(
            Json::obj()
                .set("scheduler", sc.scheduler.name())
                .set("events", indexed.events)
                .set("indexed_wall_s", indexed_s)
                .set("reference_wall_s", reference_s)
                .set("events_per_sec", eps)
                .set("baseline_events_per_sec", baseline_eps)
                .set("speedup", speedup)
                .set("peak_rss_mib", rss_mib),
        );
    }
    t.print();

    // The xl scaling guard is opt-in (SIMCORE_XL=1): the full 50k-job
    // cell is a minutes-long run; CI smokes it with SIMCORE_XL_JOBS=60.
    let xl = if std::env::var("SIMCORE_XL").as_deref() == Ok("1") {
        Some(run_xl())
    } else {
        None
    };

    let mut doc = Json::obj()
        .set("bench", "simcore")
        .set("scenario", "stress")
        .set("pms", grid.pm_counts[0])
        .set("topology", grid.topologies[0].label().as_str())
        .set("jobs", jobs)
        .set("headline_speedup", headline_speedup)
        .set("points", points);
    if let Some(xl) = xl {
        doc = doc.set("stress_xl", xl);
    }
    if let Some(m1) = m1 {
        doc = doc.set("stress_1m", m1);
    }
    let doc = doc.render();
    let out = vcsched::util::repo_path("BENCH_simcore.json");
    std::fs::write(&out, doc).expect("write BENCH_simcore.json");
    println!("\nwrote {}", out.display());

    // Soft gate, same policy as sweep_scaling: shared CI runners are
    // noisy, so a miss warns loudly rather than panicking — the hard
    // contract is the bitwise-equality assertions above plus the
    // differential test suite.
    if headline_speedup >= 2.0 {
        println!("speedup gate passed: deadline_vc x{headline_speedup:.2} >= x2.0");
    } else {
        eprintln!(
            "WARNING: deadline_vc indexed loop only x{headline_speedup:.2} over \
             the naive reference (expected >= x2.0 on the stress scenario)"
        );
    }
}
