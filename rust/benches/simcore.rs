//! Bench SIMCORE: events/sec of the simulator hot loop on the `stress`
//! scenario (200 PMs / 400 nodes / racks-8, saturating Poisson arrivals),
//! measured for the indexed event loop **and** for the retained pre-index
//! reference (`scheduler::reference` + the naive O(jobs) `all_done` scan),
//! so the speedup is a number in the artifact, not a claim in a commit
//! message. Writes `BENCH_simcore.json` next to Cargo.toml.
//!
//!     cargo bench --offline --bench simcore
//!
//! Both paths process the exact same event sequence (asserted below via
//! event counts and bitwise-equal makespans — the optimization changes no
//! simulated outcome), so events/sec ratios are pure wall-time ratios.
//!
//! `SIMCORE_JOBS` truncates the stress trace (default 400 — CI-sized; the
//! full 2000-job scenario is `SIMCORE_JOBS=2000`, where the naive
//! baseline's O(jobs × tasks) heartbeats and O(jobs)-per-event `all_done`
//! scans bite hardest).
//!
//! `SIMCORE_XL=1` additionally runs the `stress-xl` cell (2000 PMs /
//! 4000 nodes / 16-pod fat-tree, 50k jobs; `SIMCORE_XL_JOBS` truncates)
//! through the indexed loop only — the naive reference would take hours
//! there, which is the point — and **hard-asserts** a wall-clock and
//! peak-RSS budget per scheduler. The budgets are deliberately loose
//! (shared-runner noise) but an O(jobs) regression in the per-event path
//! blows through them by an order of magnitude.

use std::time::Instant;

use vcsched::coordinator::World;
use vcsched::harness::ScenarioGrid;
use vcsched::predictor::NativePredictor;
use vcsched::scheduler::reference::build_reference;
use vcsched::util::benchkit::Table;
use vcsched::util::json::Json;

/// Peak resident set size of this process in MiB (`VmHWM` from
/// `/proc/self/status`); 0.0 where procfs is unavailable (non-Linux).
fn peak_rss_mib() -> f64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0.0;
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: f64 = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse()
                .unwrap_or(0.0);
            return kb / 1024.0;
        }
    }
    0.0
}

/// The stress-xl scaling guard: run each scheduler's cell through the
/// indexed loop under a hard wall-clock + peak-RSS budget and return the
/// JSON points. Budgets scale linearly with the truncated job count so
/// the CI smoke (`SIMCORE_XL_JOBS=60`) and the full 50k-job run assert
/// the same per-job envelope.
fn run_xl() -> Json {
    let grid_full = ScenarioGrid::stress_xl();
    let jobs: usize = std::env::var("SIMCORE_XL_JOBS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(grid_full.jobs_per_scenario);
    let mut grid = grid_full;
    grid.jobs_per_scenario = jobs;
    // Per-job envelope: 12 ms wall and 40 KiB resident per job, plus a
    // fixed floor for the 4000-node cluster itself. At 50k jobs that is
    // a 600 s / ~2 GiB ceiling; the indexed loop runs far under it, an
    // O(jobs)-per-event regression far over.
    let wall_budget_s = 30.0 + jobs as f64 * 0.012;
    let rss_budget_mib = 512.0 + jobs as f64 * 40.0 / 1024.0;
    println!(
        "\nsimcore-xl: stress-xl scenario ({} PMs, {}, {jobs} jobs) — budgets: \
         {wall_budget_s:.0}s wall, {rss_budget_mib:.0} MiB peak RSS",
        grid.pm_counts[0],
        grid.topologies[0].label(),
    );

    let mut t = Table::new(&["scheduler", "events", "wall", "ev/s", "peak rss"]);
    let mut points = Json::arr();
    for sc in &grid.scenarios() {
        let cfg = sc.sim_config();
        let trace = sc.job_trace(&grid, &cfg);
        let mut sched = sc.scheduler.build(&cfg);
        let mut pred = NativePredictor::new();
        let mut world = World::new(cfg, trace);
        let t0 = Instant::now();
        world.run(sched.as_mut(), &mut pred);
        let wall_s = t0.elapsed().as_secs_f64();
        let m = world.into_metrics(sc.scheduler.name());
        let rss_mib = peak_rss_mib();
        let eps = m.events as f64 / wall_s.max(1e-9);
        t.row(&[
            sc.scheduler.name().to_string(),
            m.events.to_string(),
            format!("{wall_s:.3}s"),
            format!("{eps:.0}"),
            format!("{rss_mib:.0} MiB"),
        ]);
        points = points.push(
            Json::obj()
                .set("scheduler", sc.scheduler.name())
                .set("jobs", jobs)
                .set("events", m.events)
                .set("wall_s", wall_s)
                .set("events_per_sec", eps)
                .set("peak_rss_mib", rss_mib)
                .set("wall_budget_s", wall_budget_s)
                .set("rss_budget_mib", rss_budget_mib),
        );
        // Hard gates: the whole point of the xl cell.
        assert!(
            wall_s <= wall_budget_s,
            "{}: stress-xl wall clock {wall_s:.1}s exceeds the {wall_budget_s:.0}s \
             budget — a per-event cost grew with job count",
            sc.scheduler.name()
        );
        assert!(
            rss_mib <= rss_budget_mib,
            "{}: stress-xl peak RSS {rss_mib:.0} MiB exceeds the {rss_budget_mib:.0} \
             MiB budget",
            sc.scheduler.name()
        );
    }
    t.print();
    Json::obj()
        .set("jobs", jobs)
        .set("wall_budget_s", wall_budget_s)
        .set("rss_budget_mib", rss_budget_mib)
        .set("points", points)
}

fn main() {
    let jobs: usize = std::env::var("SIMCORE_JOBS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(400);
    let mut grid = ScenarioGrid::stress();
    grid.jobs_per_scenario = jobs;
    let scenarios = grid.scenarios();
    println!(
        "simcore: stress scenario ({} PMs, {}, {jobs} jobs) — indexed loop vs \
         retained naive reference",
        grid.pm_counts[0],
        grid.topologies[0].label(),
    );

    let mut t = Table::new(&[
        "scheduler",
        "events",
        "indexed",
        "reference",
        "ev/s indexed",
        "ev/s reference",
        "speedup",
    ]);
    let mut points = Json::arr();
    let mut headline_speedup = 0.0f64;

    for sc in &scenarios {
        let cfg = sc.sim_config();
        let trace = sc.job_trace(&grid, &cfg);

        // Indexed path: the production loop, exactly as `run_simulation`
        // drives it.
        let mut sched = sc.scheduler.build(&cfg);
        let mut pred = NativePredictor::new();
        let mut world = World::new(cfg.clone(), trace.clone());
        let t0 = Instant::now();
        world.run(sched.as_mut(), &mut pred);
        let indexed_s = t0.elapsed().as_secs_f64();
        let indexed = world.into_metrics(sc.scheduler.name());

        // Reference path: naive schedulers + the O(jobs)-per-event
        // `all_done` scan — the pre-index loop.
        let mut sched = build_reference(sc.scheduler, &cfg);
        let mut pred = NativePredictor::new();
        let mut world = World::new(cfg.clone(), trace.clone());
        world.use_naive_all_done();
        let t0 = Instant::now();
        world.run(sched.as_mut(), &mut pred);
        let reference_s = t0.elapsed().as_secs_f64();
        let reference = world.into_metrics(sc.scheduler.name());

        // Differential guard: same events, same outcome, bit for bit —
        // down to every job record, so an indexing bug that only bites at
        // stress scale cannot hide behind matching totals.
        let name = sc.scheduler.name();
        assert_eq!(indexed.events, reference.events, "{name}: events");
        assert_eq!(indexed.hotplugs, reference.hotplugs, "{name}: hotplugs");
        assert_eq!(
            indexed.makespan_s.to_bits(),
            reference.makespan_s.to_bits(),
            "{name}: makespan diverged from the reference implementation"
        );
        assert_eq!(indexed.jobs.len(), reference.jobs.len(), "{name}: job count");
        for (a, b) in indexed.jobs.iter().zip(&reference.jobs) {
            assert_eq!(
                a.completion_s.to_bits(),
                b.completion_s.to_bits(),
                "{name}: job {:?} completion diverged",
                a.id
            );
            assert_eq!(a.local_maps, b.local_maps, "{name}: job {:?} locality", a.id);
            assert_eq!(a.rack_maps, b.rack_maps, "{name}: job {:?} locality", a.id);
            assert_eq!(a.remote_maps, b.remote_maps, "{name}: job {:?} locality", a.id);
        }

        let eps = indexed.events as f64 / indexed_s.max(1e-9);
        let baseline_eps = reference.events as f64 / reference_s.max(1e-9);
        let speedup = eps / baseline_eps.max(1e-9);
        if sc.scheduler == vcsched::scheduler::SchedulerKind::DeadlineVc {
            headline_speedup = speedup;
        }
        t.row(&[
            sc.scheduler.name().to_string(),
            indexed.events.to_string(),
            format!("{indexed_s:.3}s"),
            format!("{reference_s:.3}s"),
            format!("{eps:.0}"),
            format!("{baseline_eps:.0}"),
            format!("x{speedup:.2}"),
        ]);
        points = points.push(
            Json::obj()
                .set("scheduler", sc.scheduler.name())
                .set("events", indexed.events)
                .set("indexed_wall_s", indexed_s)
                .set("reference_wall_s", reference_s)
                .set("events_per_sec", eps)
                .set("baseline_events_per_sec", baseline_eps)
                .set("speedup", speedup),
        );
    }
    t.print();

    // The xl scaling guard is opt-in (SIMCORE_XL=1): the full 50k-job
    // cell is a minutes-long run; CI smokes it with SIMCORE_XL_JOBS=60.
    let xl = if std::env::var("SIMCORE_XL").as_deref() == Ok("1") {
        Some(run_xl())
    } else {
        None
    };

    let mut doc = Json::obj()
        .set("bench", "simcore")
        .set("scenario", "stress")
        .set("pms", grid.pm_counts[0])
        .set("topology", grid.topologies[0].label().as_str())
        .set("jobs", jobs)
        .set("headline_speedup", headline_speedup)
        .set("points", points);
    if let Some(xl) = xl {
        doc = doc.set("stress_xl", xl);
    }
    let doc = doc.render();
    let out = vcsched::util::repo_path("BENCH_simcore.json");
    std::fs::write(&out, doc).expect("write BENCH_simcore.json");
    println!("\nwrote {}", out.display());

    // Soft gate, same policy as sweep_scaling: shared CI runners are
    // noisy, so a miss warns loudly rather than panicking — the hard
    // contract is the bitwise-equality assertions above plus the
    // differential test suite.
    if headline_speedup >= 2.0 {
        println!("speedup gate passed: deadline_vc x{headline_speedup:.2} >= x2.0");
    } else {
        eprintln!(
            "WARNING: deadline_vc indexed loop only x{headline_speedup:.2} over \
             the naive reference (expected >= x2.0 on the stress scenario)"
        );
    }
}
