//! Bench SIMCORE: events/sec of the simulator hot loop on the `stress`
//! scenario (200 PMs / 400 nodes / racks-8, saturating Poisson arrivals),
//! measured for the indexed event loop **and** for the retained pre-index
//! reference (`scheduler::reference` + the naive O(jobs) `all_done` scan),
//! so the speedup is a number in the artifact, not a claim in a commit
//! message. Writes `BENCH_simcore.json` next to Cargo.toml.
//!
//!     cargo bench --offline --bench simcore
//!
//! Both paths process the exact same event sequence (asserted below via
//! event counts and bitwise-equal makespans — the optimization changes no
//! simulated outcome), so events/sec ratios are pure wall-time ratios.
//!
//! `SIMCORE_JOBS` truncates the stress trace (default 400 — CI-sized; the
//! full 2000-job scenario is `SIMCORE_JOBS=2000`, where the naive
//! baseline's O(jobs × tasks) heartbeats and O(jobs)-per-event `all_done`
//! scans bite hardest).

use std::time::Instant;

use vcsched::coordinator::World;
use vcsched::harness::ScenarioGrid;
use vcsched::predictor::NativePredictor;
use vcsched::scheduler::reference::build_reference;
use vcsched::util::benchkit::Table;
use vcsched::util::json::Json;

fn main() {
    let jobs: usize = std::env::var("SIMCORE_JOBS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(400);
    let mut grid = ScenarioGrid::stress();
    grid.jobs_per_scenario = jobs;
    let scenarios = grid.scenarios();
    println!(
        "simcore: stress scenario ({} PMs, {}, {jobs} jobs) — indexed loop vs \
         retained naive reference",
        grid.pm_counts[0],
        grid.topologies[0].label(),
    );

    let mut t = Table::new(&[
        "scheduler",
        "events",
        "indexed",
        "reference",
        "ev/s indexed",
        "ev/s reference",
        "speedup",
    ]);
    let mut points = Json::arr();
    let mut headline_speedup = 0.0f64;

    for sc in &scenarios {
        let cfg = sc.sim_config();
        let trace = sc.job_trace(&grid, &cfg);

        // Indexed path: the production loop, exactly as `run_simulation`
        // drives it.
        let mut sched = sc.scheduler.build(&cfg);
        let mut pred = NativePredictor::new();
        let mut world = World::new(cfg.clone(), trace.clone());
        let t0 = Instant::now();
        world.run(sched.as_mut(), &mut pred);
        let indexed_s = t0.elapsed().as_secs_f64();
        let indexed = world.into_metrics(sc.scheduler.name());

        // Reference path: naive schedulers + the O(jobs)-per-event
        // `all_done` scan — the pre-index loop.
        let mut sched = build_reference(sc.scheduler, &cfg);
        let mut pred = NativePredictor::new();
        let mut world = World::new(cfg.clone(), trace.clone());
        world.use_naive_all_done();
        let t0 = Instant::now();
        world.run(sched.as_mut(), &mut pred);
        let reference_s = t0.elapsed().as_secs_f64();
        let reference = world.into_metrics(sc.scheduler.name());

        // Differential guard: same events, same outcome, bit for bit —
        // down to every job record, so an indexing bug that only bites at
        // stress scale cannot hide behind matching totals.
        let name = sc.scheduler.name();
        assert_eq!(indexed.events, reference.events, "{name}: events");
        assert_eq!(indexed.hotplugs, reference.hotplugs, "{name}: hotplugs");
        assert_eq!(
            indexed.makespan_s.to_bits(),
            reference.makespan_s.to_bits(),
            "{name}: makespan diverged from the reference implementation"
        );
        assert_eq!(indexed.jobs.len(), reference.jobs.len(), "{name}: job count");
        for (a, b) in indexed.jobs.iter().zip(&reference.jobs) {
            assert_eq!(
                a.completion_s.to_bits(),
                b.completion_s.to_bits(),
                "{name}: job {:?} completion diverged",
                a.id
            );
            assert_eq!(a.local_maps, b.local_maps, "{name}: job {:?} locality", a.id);
            assert_eq!(a.rack_maps, b.rack_maps, "{name}: job {:?} locality", a.id);
            assert_eq!(a.remote_maps, b.remote_maps, "{name}: job {:?} locality", a.id);
        }

        let eps = indexed.events as f64 / indexed_s.max(1e-9);
        let baseline_eps = reference.events as f64 / reference_s.max(1e-9);
        let speedup = eps / baseline_eps.max(1e-9);
        if sc.scheduler == vcsched::scheduler::SchedulerKind::DeadlineVc {
            headline_speedup = speedup;
        }
        t.row(&[
            sc.scheduler.name().to_string(),
            indexed.events.to_string(),
            format!("{indexed_s:.3}s"),
            format!("{reference_s:.3}s"),
            format!("{eps:.0}"),
            format!("{baseline_eps:.0}"),
            format!("x{speedup:.2}"),
        ]);
        points = points.push(
            Json::obj()
                .set("scheduler", sc.scheduler.name())
                .set("events", indexed.events)
                .set("indexed_wall_s", indexed_s)
                .set("reference_wall_s", reference_s)
                .set("events_per_sec", eps)
                .set("baseline_events_per_sec", baseline_eps)
                .set("speedup", speedup),
        );
    }
    t.print();

    let doc = Json::obj()
        .set("bench", "simcore")
        .set("scenario", "stress")
        .set("pms", grid.pm_counts[0])
        .set("topology", grid.topologies[0].label().as_str())
        .set("jobs", jobs)
        .set("headline_speedup", headline_speedup)
        .set("points", points)
        .render();
    let out = vcsched::util::repo_path("BENCH_simcore.json");
    std::fs::write(&out, doc).expect("write BENCH_simcore.json");
    println!("\nwrote {}", out.display());

    // Soft gate, same policy as sweep_scaling: shared CI runners are
    // noisy, so a miss warns loudly rather than panicking — the hard
    // contract is the bitwise-equality assertions above plus the
    // differential test suite.
    if headline_speedup >= 2.0 {
        println!("speedup gate passed: deadline_vc x{headline_speedup:.2} >= x2.0");
    } else {
        eprintln!(
            "WARNING: deadline_vc indexed loop only x{headline_speedup:.2} over \
             the naive reference (expected >= x2.0 on the stress scenario)"
        );
    }
}
