//! Bench T2: regenerate Table 2 — minimum map/reduce slot allocations for
//! the five jobs with the paper's deadlines and input sizes, computed by
//! BOTH predictor backends (native Rust and the AOT JAX/Pallas artifact
//! via PJRT), which must agree exactly.
//!
//! Absolute counts depend on task-time calibration (our simulated nodes
//! are not the paper's Xeons); the *shape* checks are: the permutation
//! generator is the only reduce-dominant allocation (paper: 15 maps vs
//! 16 reduces), and map-heavy jobs (grep) demand disproportionately many
//! map slots.
//!
//!     make artifacts && cargo bench --offline --bench table2_slots

use vcsched::config::SimConfig;
use vcsched::predictor::{demand_from_spec, NativePredictor, Predictor, SlotDemand};
use vcsched::runtime::XlaPredictor;
use vcsched::util::benchkit::{measure, Table};
use vcsched::workloads::{JobSpec, JobType};

const ROWS: [(JobType, f64, f64, u32, u32); 5] = [
    // (type, deadline s, input GB, paper map slots, paper reduce slots)
    (JobType::Grep, 650.0, 10.0, 24, 8),
    (JobType::WordCount, 520.0, 5.0, 14, 7),
    (JobType::Sort, 500.0, 10.0, 20, 11),
    (JobType::PermutationGenerator, 850.0, 4.0, 15, 16),
    (JobType::InvertedIndex, 720.0, 8.0, 12, 9),
];

fn main() {
    let cfg = SimConfig::paper();
    let mut native = NativePredictor::new();
    let mut xla = XlaPredictor::load_default().ok();
    if xla.is_none() {
        eprintln!("NOTE: artifacts/ missing — XLA column skipped (run `make artifacts`)");
    }

    let demands: Vec<_> = ROWS
        .iter()
        .map(|&(jt, d, gb, _, _)| {
            demand_from_spec(&cfg, &JobSpec::new(jt, gb * 1024.0).with_deadline(d))
        })
        .collect();
    let ours: Vec<SlotDemand> = native.solve_slots(&demands);
    let theirs: Option<Vec<SlotDemand>> = xla.as_mut().map(|p| p.solve_slots(&demands));

    println!("Table 2 — minimum slots to meet completion-time goals\n");
    let mut t = Table::new(&[
        "job", "deadline", "input", "ours m/r", "xla m/r", "paper m/r",
    ]);
    for (i, &(jt, d, gb, pm, pr)) in ROWS.iter().enumerate() {
        let o = ours[i];
        let x = theirs
            .as_ref()
            .map(|v| format!("{}/{}", v[i].map_slots, v[i].reduce_slots))
            .unwrap_or_else(|| "-".into());
        t.row(&[
            jt.name().to_string(),
            format!("{d:.0}s"),
            format!("{gb:.0}GB"),
            format!("{}/{}", o.map_slots, o.reduce_slots),
            x,
            format!("{pm}/{pr}"),
        ]);
    }
    t.print();

    // Cross-backend agreement (the artifact IS the native math, AOT'd).
    if let Some(theirs) = &theirs {
        assert_eq!(&ours, theirs, "native and XLA backends must agree");
        println!("\nnative == XLA artifact on all rows ✓");
    }

    // Shape: permutation is the only job demanding more reduce than map
    // slots (paper's 15/16); every other job is map-dominant.
    for (i, &(jt, ..)) in ROWS.iter().enumerate() {
        let o = ours[i];
        if jt == JobType::PermutationGenerator {
            assert!(
                o.reduce_slots >= o.map_slots,
                "permutation must be reduce-dominant (got {}/{})",
                o.map_slots,
                o.reduce_slots
            );
        } else {
            assert!(
                o.map_slots >= o.reduce_slots,
                "{} must be map-dominant (got {}/{})",
                jt.name(),
                o.map_slots,
                o.reduce_slots
            );
        }
    }
    println!("allocation shape matches the paper (perm reduce-dominant, rest map-dominant) ✓");

    // Predictor latency on this 5-job batch.
    let r = measure("native solve_slots (5 jobs)", 10, 1000, || {
        let _ = native.solve_slots(&demands);
    });
    r.print();
    if let Some(p) = xla.as_mut() {
        let r = measure("XLA/PJRT solve_slots (5 jobs, 128-padded)", 10, 200, || {
            let _ = p.solve_slots(&demands);
        });
        r.print();
    }
}
