//! Golden event-log regression test for the `stress` preset (truncated
//! to the same test-sized job count as `tests/stress_golden.rs`): the
//! **encoded decision log** — every scheduler-visible event with the
//! actions it produced, in the wire format of
//! `vcsched::coordinator::encode_event_log` (docs/EVENT_LOG.md) — must
//! be bitwise stable across commits, pinned by an FNV-1a hash checked
//! into the tree.
//!
//! Where `stress_report.hash` pins the *outcomes* (the rendered
//! reports), this pins the *causal record* that produced them: a change
//! can shuffle scheduler decisions while leaving aggregate metrics
//! unchanged, and this hash catches exactly that.
//!
//! The golden file starts life containing the word `bootstrap`; the
//! first run pins the real hash in place (commit the updated file). Any
//! later mismatch means a change moved a scheduling decision or the log
//! encoding itself on the stress scenario — if intentional (a policy
//! change or a documented encoding bump), re-bootstrap by writing
//! `bootstrap` into `tests/golden/stress_eventlog.hash` and re-running.

use vcsched::coordinator::{encode_event_log, World};
use vcsched::harness::ScenarioGrid;
use vcsched::predictor::NativePredictor;

/// FNV-1a 64-bit (same construction as the sweep journal's content
/// hash and the snapshot checksum trailer).
fn fnv64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

const GOLDEN: &str = concat!(
    env!("CARGO_MANIFEST_DIR"),
    "/tests/golden/stress_eventlog.hash"
);

/// Jobs per stress cell, matching `tests/stress_golden.rs` so the two
/// goldens pin the same truncated scenario set.
const JOBS: usize = 40;

#[test]
fn stress_preset_event_logs_are_bitwise_stable() {
    let mut grid = ScenarioGrid::stress();
    grid.jobs_per_scenario = JOBS;

    let mut encoded = Vec::new();
    for sc in &grid.scenarios() {
        let cfg = sc.sim_config();
        let trace = sc.job_trace(&grid, &cfg);
        let mut sched = sc.scheduler.build(&cfg);
        let mut pred = NativePredictor::new();
        let mut world = World::new(cfg, trace);
        world.enable_event_log();
        world.run(sched.as_mut(), &mut pred);
        let log = world.take_event_log();
        assert!(
            !log.is_empty(),
            "{}: stress cell produced an empty decision log",
            sc.scheduler.name()
        );
        encoded.extend_from_slice(&encode_event_log(&log));
    }

    let hash = format!("{:016x}", fnv64(&encoded));
    let golden = std::fs::read_to_string(GOLDEN)
        .unwrap_or_else(|e| panic!("missing golden file {GOLDEN}: {e}"))
        .trim()
        .to_string();
    if golden == "bootstrap" {
        // First run on this tree: pin the hash in place. The updated
        // file must be committed for the pin to take effect.
        std::fs::write(GOLDEN, format!("{hash}\n")).expect("pin golden hash");
        eprintln!(
            "eventlog golden bootstrapped: pinned {hash} — commit \
             tests/golden/stress_eventlog.hash"
        );
        return;
    }
    assert_eq!(
        golden, hash,
        "stress preset event-log hash drifted from the pinned golden — a change moved \
         a scheduling decision or the log encoding ({JOBS}-job stress cells); see \
         tests/golden/stress_eventlog.hash"
    );
}
