//! Cross-layer integration: the AOT JAX/Pallas artifacts executed via
//! PJRT must agree with the native Rust predictor over broad random
//! batches, and a full simulation driven by the XLA predictor must be
//! *identical* to the native-predictor run (the predictor is pure math;
//! backends must be interchangeable).
//!
//! Skipped gracefully when `artifacts/` has not been built.

use vcsched::config::SimConfig;
use vcsched::coordinator::run_simulation_with;
use vcsched::predictor::{JobDemand, JobProgress, NativePredictor, Predictor};
use vcsched::runtime::XlaPredictor;
use vcsched::scheduler::SchedulerKind;
use vcsched::util::Rng;
use vcsched::workloads::trace::JobTrace;

fn xla() -> Option<XlaPredictor> {
    match XlaPredictor::load_default() {
        Ok(p) => Some(p),
        Err(e) => {
            eprintln!("skipping artifact integration tests: {e}");
            None
        }
    }
}

#[test]
fn slot_solver_agreement_broad() {
    let Some(mut xp) = xla() else { return };
    let mut native = NativePredictor::new();
    let mut rng = Rng::new(0xA11CE);
    // Sweep extreme regimes: tiny/huge work, negative/huge deadlines.
    let mut demands = Vec::new();
    for scale in [0.01, 1.0, 100.0] {
        for _ in 0..300 {
            demands.push(JobDemand {
                map_tasks: (rng.range_f64(0.0, 500.0) * scale).floor(),
                reduce_tasks: (rng.range_f64(0.0, 64.0)).floor(),
                t_map: rng.range_f64(0.1, 90.0),
                t_reduce: rng.range_f64(0.1, 90.0),
                t_shuffle: rng.range_f64(0.0, 0.05),
                deadline: rng.range_f64(-100.0, 5000.0),
            });
        }
    }
    let got = xp.solve_slots(&demands);
    let want = native.solve_slots(&demands);
    let mut mismatches = 0;
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        if g.map_slots != w.map_slots || g.reduce_slots != w.reduce_slots {
            // f32-vs-f64 ceil boundary: allow off-by-one at most, rarely.
            let close = g.map_slots.abs_diff(w.map_slots) <= 1
                && g.reduce_slots.abs_diff(w.reduce_slots) <= 1;
            assert!(close, "case {i}: {g:?} vs {w:?} ({:?})", demands[i]);
            mismatches += 1;
        }
    }
    assert!(
        mismatches * 100 < demands.len(),
        "more than 1% off-by-one mismatches: {mismatches}/{}",
        demands.len()
    );
}

#[test]
fn estimator_agreement_broad() {
    let Some(mut xp) = xla() else { return };
    let mut native = NativePredictor::new();
    let mut rng = Rng::new(0xBEE);
    let jobs: Vec<JobProgress> = (0..500)
        .map(|_| JobProgress {
            rem_map: rng.range_f64(0.0, 500.0).floor(),
            rem_reduce: rng.range_f64(0.0, 64.0).floor(),
            t_map: rng.range_f64(0.1, 90.0),
            t_reduce: rng.range_f64(0.1, 90.0),
            t_shuffle: rng.range_f64(0.0, 0.05),
            map_slots: rng.range_f64(0.0, 80.0).floor(),
            reduce_slots: rng.range_f64(0.0, 80.0).floor(),
            reduce_tasks: rng.range_f64(0.0, 64.0).floor(),
            deadline: rng.range_f64(1.0, 5000.0),
            elapsed: rng.range_f64(0.0, 5000.0),
        })
        .collect();
    let got = xp.estimate(&jobs);
    let want = native.estimate(&jobs);
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        let tol = 1e-3 * (1.0 + w.eta.abs());
        assert!((g.eta - w.eta).abs() < tol, "case {i}: {g:?} vs {w:?}");
    }
}

/// Interchangeability: the full Table-2 simulation under the proposed
/// scheduler produces identical job completion times with either backend.
#[test]
fn simulation_identical_under_both_backends() {
    let Some(mut xp) = xla() else { return };
    let cfg = SimConfig::paper();
    let trace = JobTrace::table2(256.0);
    let mut native = NativePredictor::new();
    let a = run_simulation_with(&cfg, SchedulerKind::DeadlineVc, &trace, &mut native);
    let b = run_simulation_with(&cfg, SchedulerKind::DeadlineVc, &trace, &mut xp);
    assert_eq!(a.completed_jobs(), b.completed_jobs());
    assert_eq!(a.hotplugs, b.hotplugs, "reconfiguration paths diverged");
    for (x, y) in a.job_records().iter().zip(b.job_records()) {
        assert_eq!(
            x.completion_s, y.completion_s,
            "job {} diverged between predictor backends",
            x.id.0
        );
    }
}

/// The locality artifact implements Alg. 1's node choice exactly as the
/// scheduler's native scan: cross-check on random placement states.
#[test]
fn placement_kernel_matches_native_scan() {
    use vcsched::runtime::{PlacementQuery, MAX_NODES, MAX_TASKS};
    let Some(mut xp) = xla() else { return };
    let mut rng = Rng::new(0xD0C);
    for _case in 0..20 {
        let mut q = PlacementQuery::new();
        let live_nodes = 8 + rng.below(40) as usize;
        let live_tasks = 1 + rng.below(60) as usize;
        for n in 0..live_nodes {
            q.node_mask[n] = 1.0;
            q.rq[n] = rng.below(5) as f32;
            q.aq[n] = rng.below(5) as f32;
        }
        for t in 0..live_tasks {
            q.task_mask[t] = 1.0;
            for _ in 0..3 {
                q.set_has_data(t, rng.below(live_nodes as u64) as usize);
            }
        }
        let got = xp.place(&q).unwrap();
        // Native argmax over the same scoring.
        for t in 0..live_tasks {
            let mut best = -1i64;
            let mut best_score = f64::NEG_INFINITY;
            for n in 0..live_nodes {
                if q.has_data[t * MAX_NODES + n] < 0.5 {
                    continue;
                }
                let score =
                    q.weights[0] as f64 * q.rq[n] as f64 - q.weights[1] as f64 * q.aq[n] as f64;
                if score > best_score {
                    best_score = score;
                    best = n as i64;
                }
            }
            if best < 0 {
                assert_eq!(got[t], -1, "task {t}");
            } else {
                // Ties may resolve to a different node with equal score.
                let gn = got[t] as usize;
                let gs = q.weights[0] as f64 * q.rq[gn] as f64
                    - q.weights[1] as f64 * q.aq[gn] as f64;
                assert!(
                    (gs - best_score).abs() < 1e-6,
                    "task {t}: kernel picked node {gn} (score {gs}), best {best_score}"
                );
                assert!(q.has_data[t * MAX_NODES + gn] > 0.5);
            }
        }
        assert!(got[live_tasks..MAX_TASKS].iter().all(|&n| n == -1));
    }
}
